"""L2 model + AOT pipeline tests: shapes, manifest, HLO-text round-trip."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import build_artifacts, lower_artifact, to_hlo_text
from compile.model import (
    ModelConfig,
    build_all,
    build_count_step,
    build_denoise_step,
    build_spectrum_stats,
    example_args,
)

jax.config.update("jax_platform_name", "cpu")

SMALL = ModelConfig(
    num_buckets=128,
    read_len=40,
    reads_per_call=8,
    read_tile=4,
    bucket_tile=64,
    ks=[3, 5],
)


class TestModelShapes:
    def test_count_step_shape(self):
        fn = build_count_step(SMALL, 5)
        reads, counts = example_args(SMALL, "count_step")
        out = jax.eval_shape(fn, reads, counts)
        assert len(out) == 1
        assert out[0].shape == (SMALL.num_buckets,)
        assert out[0].dtype == jnp.float32

    def test_denoise_step_shape(self):
        fn = build_denoise_step(SMALL)
        out = jax.eval_shape(fn, *example_args(SMALL, "denoise_step"))
        assert out[0].shape == (SMALL.num_buckets,)

    def test_stats_shape(self):
        fn = build_spectrum_stats(SMALL)
        out = jax.eval_shape(fn, *example_args(SMALL, "spectrum_stats"))
        assert out[0].shape == (3,)

    def test_build_all_names(self):
        names = set(build_all(SMALL))
        assert names == {"count_k3", "count_k5", "denoise", "spectrum_stats"}

    def test_count_step_deterministic(self):
        fn = jax.jit(build_count_step(SMALL, 3))
        rng = np.random.default_rng(1)
        reads = jnp.asarray(
            rng.integers(0, 4, (SMALL.reads_per_call, SMALL.read_len)),
            dtype=jnp.int32,
        )
        counts = jnp.zeros((SMALL.num_buckets,), jnp.float32)
        a = np.asarray(fn(reads, counts)[0])
        b = np.asarray(fn(reads, counts)[0])
        np.testing.assert_array_equal(a, b)


class TestAot:
    def test_hlo_text_parses_as_entry_module(self):
        fn = build_spectrum_stats(SMALL)
        hlo, inputs, outputs = lower_artifact(
            "spectrum_stats", fn, example_args(SMALL, "spectrum_stats")
        )
        assert "ENTRY" in hlo and "HloModule" in hlo
        assert inputs[0]["shape"] == [SMALL.num_buckets]
        assert outputs[0]["shape"] == [3]

    def test_build_artifacts_manifest(self, tmp_path):
        out = str(tmp_path / "artifacts")
        manifest = build_artifacts(SMALL, out)
        # files exist and hashes match
        for name, ent in manifest["artifacts"].items():
            path = os.path.join(out, ent["file"])
            assert os.path.exists(path), name
            import hashlib

            with open(path) as f:
                assert (
                    hashlib.sha256(f.read().encode()).hexdigest()
                    == ent["sha256"]
                )
        # manifest.json is valid json and round-trips
        with open(os.path.join(out, "manifest.json")) as f:
            loaded = json.load(f)
        assert loaded == json.loads(json.dumps(manifest, sort_keys=True))
        geo = loaded["geometry"]
        assert geo["ks"] == [3, 5]
        assert geo["num_buckets"] == 128

    def test_count_artifact_io_signature(self, tmp_path):
        manifest = build_artifacts(SMALL, str(tmp_path / "a"))
        ent = manifest["artifacts"]["count_k3"]
        assert ent["inputs"] == [
            {"shape": [8, 40], "dtype": "int32"},
            {"shape": [128], "dtype": "float32"},
        ]
        assert ent["outputs"] == [{"shape": [128], "dtype": "float32"}]

    def test_hlo_executes_via_xla_client(self, tmp_path):
        """Compile the emitted HLO text back through the local CPU client and
        compare against direct jax execution -- the same numerics contract
        the Rust runtime relies on."""
        fn = build_denoise_step(SMALL)
        args = example_args(SMALL, "denoise_step")
        lowered = jax.jit(fn).lower(*args)
        hlo = to_hlo_text(lowered)
        # executing through jax directly:
        rng = np.random.default_rng(2)
        counts = rng.random(SMALL.num_buckets).astype(np.float32) * 9
        stencil = np.array([0.2, 0.6, 0.2, 0.0, 0.0], np.float32)[
            : 2 * SMALL.denoise_half_width + 1
        ]
        params = np.array([1.5, 0.25], np.float32)
        want = np.asarray(fn(counts, stencil, params)[0])
        got = np.asarray(
            jax.jit(fn)(jnp.asarray(counts), jnp.asarray(stencil),
                        jnp.asarray(params))[0]
        )
        np.testing.assert_allclose(got, want, rtol=1e-6)
        assert "ENTRY" in hlo


class TestGeometryValidation:
    def test_reads_per_call_must_tile(self):
        cfg = ModelConfig(
            num_buckets=64,
            read_len=20,
            reads_per_call=6,
            read_tile=4,
            bucket_tile=64,
            ks=[3],
        )
        fn = build_count_step(cfg, 3)
        with pytest.raises(ValueError):
            jax.eval_shape(fn, *example_args(cfg, "count_step"))
