"""Kernel-vs-oracle correctness: the core L1 signal.

Hypothesis sweeps shapes / k values / input distributions and asserts
allclose between each Pallas kernel (interpret=True) and its pure-jnp
oracle in ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.denoise import DenoiseSpec, make_denoise_fn
from compile.kernels.kmer_count import KmerCountSpec, make_count_fn
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _mk_reads(rng, r, l, invalid_frac=0.0):
    reads = rng.integers(0, 4, size=(r, l), dtype=np.int32)
    if invalid_frac > 0:
        mask = rng.random((r, l)) < invalid_frac
        reads = np.where(mask, 4, reads)
    return reads


# ---------------------------------------------------------------- kmer_count


class TestKmerCountFixed:
    """Deterministic cases covering the paper's k values at small scale."""

    @pytest.mark.parametrize("k", [3, 5, 33, 55, 77, 99, 127])
    @pytest.mark.parametrize("variant", ["onehot", "scatter"])
    def test_matches_ref_per_k(self, k, variant):
        l = max(k + 7, 40)
        spec = KmerCountSpec(
            k=k, read_len=l, num_buckets=256, read_tile=4, bucket_tile=64,
            variant=variant,
        )
        rng = np.random.default_rng(k)
        reads = _mk_reads(rng, 8, l)
        counts = np.zeros(256, np.float32)
        got = make_count_fn(spec)(jnp.asarray(reads), jnp.asarray(counts),
                                  spec.weights())
        want = ref.ref_kmer_count(spec, jnp.asarray(reads), jnp.asarray(counts))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want))

    def test_accumulates_into_counts(self):
        spec = KmerCountSpec(
            k=5, read_len=20, num_buckets=64, read_tile=2, bucket_tile=32
        )
        rng = np.random.default_rng(0)
        reads = _mk_reads(rng, 4, 20)
        base = rng.random(64).astype(np.float32) * 10
        fn = make_count_fn(spec)
        got = fn(jnp.asarray(reads), jnp.asarray(base), spec.weights())
        zero = fn(jnp.asarray(reads), jnp.zeros(64, jnp.float32),
                  spec.weights())
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(zero) + base, rtol=1e-6
        )

    def test_invalid_bases_masked(self):
        spec = KmerCountSpec(
            k=4, read_len=16, num_buckets=64, read_tile=2, bucket_tile=32
        )
        reads = np.full((2, 16), 4, np.int32)  # all invalid
        got = make_count_fn(spec)(
            jnp.asarray(reads), jnp.zeros(64, jnp.float32), spec.weights()
        )
        assert float(jnp.sum(got)) == 0.0

    def test_total_mass_equals_valid_windows(self):
        spec = KmerCountSpec(
            k=7, read_len=30, num_buckets=128, read_tile=4, bucket_tile=64
        )
        rng = np.random.default_rng(7)
        reads = _mk_reads(rng, 8, 30)  # all valid
        got = make_count_fn(spec)(
            jnp.asarray(reads), jnp.zeros(128, jnp.float32), spec.weights()
        )
        assert float(jnp.sum(got)) == 8 * spec.positions

    @pytest.mark.parametrize("variant", ["onehot", "scatter"])
    def test_multi_grid_both_dims(self, variant):
        # exercises bucket-outer accumulation across read tiles
        spec = KmerCountSpec(
            k=9, read_len=40, num_buckets=512, read_tile=4, bucket_tile=128,
            variant=variant,
        )
        rng = np.random.default_rng(9)
        reads = _mk_reads(rng, 16, 40, invalid_frac=0.05)
        counts = rng.random(512).astype(np.float32)
        got = make_count_fn(spec)(
            jnp.asarray(reads), jnp.asarray(counts), spec.weights()
        )
        want = ref.ref_kmer_count(spec, jnp.asarray(reads), jnp.asarray(counts))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6)

    def test_weights_match_python_pow(self):
        spec = KmerCountSpec(k=127, read_len=160, num_buckets=8192)
        w = np.asarray(spec.weights())
        assert w[-1] == 1 and w[-2] == 4
        assert all(0 <= x < 8192 for x in w)

    def test_rejects_bad_geometry(self):
        with pytest.raises(ValueError):
            KmerCountSpec(k=1, read_len=10, num_buckets=64)
        with pytest.raises(ValueError):
            KmerCountSpec(k=20, read_len=10, num_buckets=64)
        with pytest.raises(ValueError):
            KmerCountSpec(k=5, read_len=10, num_buckets=100, bucket_tile=64)
        with pytest.raises(ValueError):
            KmerCountSpec(k=5, read_len=10, num_buckets=64, bucket_tile=64,
                          variant="sorting")


@settings(max_examples=25, deadline=None)
@given(
    k=st.integers(2, 12),
    extra=st.integers(0, 12),
    tiles=st.integers(1, 3),
    bgrid=st.sampled_from([1, 2, 4]),
    invalid=st.floats(0, 0.3),
    variant=st.sampled_from(["onehot", "scatter"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kmer_count_hypothesis(k, extra, tiles, bgrid, invalid, variant, seed):
    """Property sweep: kernel == oracle over random geometry + inputs."""
    l = k + extra
    bucket_tile = 32
    spec = KmerCountSpec(
        k=k,
        read_len=l,
        num_buckets=bucket_tile * bgrid,
        read_tile=2,
        bucket_tile=bucket_tile,
        variant=variant,
    )
    rng = np.random.default_rng(seed)
    reads = _mk_reads(rng, 2 * tiles, l, invalid)
    counts = rng.random(spec.num_buckets).astype(np.float32)
    got = make_count_fn(spec)(
        jnp.asarray(reads), jnp.asarray(counts), spec.weights()
    )
    want = ref.ref_kmer_count(spec, jnp.asarray(reads), jnp.asarray(counts))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


# ------------------------------------------------------------------- denoise


class TestDenoiseFixed:
    def test_identity_stencil_above_threshold(self):
        spec = DenoiseSpec(num_buckets=64, half_width=1)
        c = np.arange(64, dtype=np.float32) + 10
        stencil = np.array([0, 1, 0], np.float32)
        params = np.array([0.0, 0.5], np.float32)
        got = make_denoise_fn(spec)(
            jnp.asarray(c), jnp.asarray(stencil), jnp.asarray(params)
        )
        np.testing.assert_allclose(np.asarray(got), c)

    def test_threshold_decays_low_coverage(self):
        spec = DenoiseSpec(num_buckets=8, half_width=0)
        c = np.array([1, 5, 1, 5, 1, 5, 1, 5], np.float32)
        got = make_denoise_fn(spec)(
            jnp.asarray(c),
            jnp.asarray([1.0], dtype=jnp.float32),
            jnp.asarray([2.0, 0.1], dtype=jnp.float32),
        )
        np.testing.assert_allclose(
            np.asarray(got), [0.1, 5, 0.1, 5, 0.1, 5, 0.1, 5], rtol=1e-6
        )

    def test_edges_zero_padded(self):
        spec = DenoiseSpec(num_buckets=16, half_width=2)
        c = np.ones(16, np.float32)
        stencil = np.ones(5, np.float32)
        got = make_denoise_fn(spec)(
            jnp.asarray(c),
            jnp.asarray(stencil),
            jnp.asarray([0.0, 1.0], dtype=jnp.float32),
        )
        # interior sums 5 ones; edges see clipped windows
        np.testing.assert_allclose(np.asarray(got)[2:-2], 5.0)
        np.testing.assert_allclose(np.asarray(got)[0], 3.0)
        np.testing.assert_allclose(np.asarray(got)[1], 4.0)


@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([16, 64, 256]),
    w=st.integers(0, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_denoise_hypothesis(b, w, seed):
    spec = DenoiseSpec(num_buckets=b, half_width=w)
    rng = np.random.default_rng(seed)
    c = (rng.random(b) * 20).astype(np.float32)
    stencil = rng.standard_normal(spec.taps).astype(np.float32)
    params = np.array([rng.random() * 5, rng.random()], np.float32)
    got = np.asarray(
        make_denoise_fn(spec)(
            jnp.asarray(c), jnp.asarray(stencil), jnp.asarray(params)
        )
    )
    want = np.asarray(
        ref.ref_denoise(
            spec, jnp.asarray(c), jnp.asarray(stencil), jnp.asarray(params)
        )
    )
    # Positions whose smoothed value sits within float noise of the
    # threshold may legitimately take either branch (kernel and oracle
    # accumulate the taps in different orders); exclude them.
    padded = np.pad(c, (w, w))
    smooth = sum(
        stencil[d] * padded[d : d + b] for d in range(spec.taps)
    )
    decisive = np.abs(smooth - params[0]) > 1e-4 * (1.0 + np.abs(smooth))
    np.testing.assert_allclose(
        got[decisive], want[decisive], rtol=2e-4, atol=1e-5
    )
