"""AOT lowering: JAX/Pallas -> HLO text artifacts + manifest.

This is the entire build-time Python surface.  ``make artifacts`` runs

    python -m compile.aot --out ../artifacts

once; the Rust binary is self-contained afterwards and Python never runs on
the request path.

Interchange format is **HLO text, not serialized HloModuleProto**: jax >=
0.5 emits protos with 64-bit instruction ids which the pinned
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the HLO text
parser reassigns ids and round-trips cleanly (see /opt/xla-example/README).
Lowering goes through stablehlo with ``return_tuple=True``; the Rust side
unwraps with ``to_tuple1()``.

Alongside the ``*.hlo.txt`` files we emit ``manifest.json``: per-artifact
input/output shapes + dtypes, geometry constants, and a SHA-256 of each HLO
file.  The Rust runtime treats the manifest as the single source of truth
and refuses to run against artifacts whose geometry disagrees with its
workload config.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .model import ModelConfig, build_all, example_args

MANIFEST_VERSION = 1


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _arg_entry(s: jax.ShapeDtypeStruct) -> dict:
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def lower_artifact(name: str, fn, args) -> tuple[str, list, list]:
    """Lower `fn` at `args`; returns (hlo_text, input_sig, output_sig)."""
    lowered = jax.jit(fn).lower(*args)
    out_tree = jax.eval_shape(fn, *args)
    outputs = [_arg_entry(o) for o in out_tree]
    inputs = [_arg_entry(a) for a in args]
    return to_hlo_text(lowered), inputs, outputs


def build_artifacts(cfg: ModelConfig, out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    artifacts = {}
    for name, fn in build_all(cfg).items():
        if name.startswith("count_k"):
            args = example_args(cfg, "count_step")
        elif name == "denoise":
            args = example_args(cfg, "denoise_step")
        else:
            args = example_args(cfg, name)
        hlo, inputs, outputs = lower_artifact(name, fn, args)
        fname = f"{name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            f.write(hlo)
        digest = hashlib.sha256(hlo.encode()).hexdigest()
        artifacts[name] = {
            "file": fname,
            "sha256": digest,
            "inputs": inputs,
            "outputs": outputs,
        }
        print(f"  {name}: {len(hlo)} chars -> {fname}")
    manifest = {
        "version": MANIFEST_VERSION,
        "geometry": {
            "num_buckets": cfg.num_buckets,
            "read_len": cfg.read_len,
            "reads_per_call": cfg.reads_per_call,
            "read_tile": cfg.read_tile,
            "bucket_tile": cfg.bucket_tile,
            "denoise_half_width": cfg.denoise_half_width,
            "count_variant": cfg.count_variant,
            "ks": list(cfg.ks),
        },
        "artifacts": artifacts,
    }
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath} ({len(artifacts)} artifacts)")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact dir")
    ap.add_argument("--buckets", type=int, default=None)
    ap.add_argument("--read-len", type=int, default=None)
    ap.add_argument("--reads-per-call", type=int, default=None)
    ap.add_argument(
        "--ks", default=None, help="comma-separated k list (default paper's)"
    )
    ap.add_argument(
        "--count-variant",
        default=None,
        choices=["onehot", "scatter"],
        help="count-kernel accumulation strategy (default: scatter, the "
        "CPU profile; onehot is the TPU-shaped formulation)",
    )
    ap.add_argument("--read-tile", type=int, default=None)
    ap.add_argument("--bucket-tile", type=int, default=None)
    args = ap.parse_args()
    kw = {}
    if args.count_variant is not None:
        kw["count_variant"] = args.count_variant
    if args.read_tile is not None:
        kw["read_tile"] = args.read_tile
    if args.bucket_tile is not None:
        kw["bucket_tile"] = args.bucket_tile
    if args.buckets is not None:
        kw["num_buckets"] = args.buckets
    if args.read_len is not None:
        kw["read_len"] = args.read_len
    if args.reads_per_call is not None:
        kw["reads_per_call"] = args.reads_per_call
    if args.ks:
        kw["ks"] = [int(x) for x in args.ks.split(",")]
    cfg = ModelConfig(**kw)
    build_artifacts(cfg, args.out)


if __name__ == "__main__":
    main()
