"""Layer-2 JAX model: the MiniMeta per-stage compute graph.

metaSPAdes-analog pipeline (DESIGN.md section 2): each k-stage consumes the
read set and evolves a bucketed k-mer spectrum:

    for each read chunk:   counts = count_step_k(chunk, counts)   # Pallas
    for each sweep:        counts = denoise_step(counts)          # Pallas
    summary = spectrum_stats(counts)                              # jnp

The Rust coordinator drives these step functions through PJRT; the *loop*
lives in Rust (it is what gets checkpointed), the *math* lives here.  Every
function below is AOT-lowered once by :mod:`aot` into an HLO-text artifact.

Default geometry (must match `MiniMetaConfig` defaults on the Rust side;
the artifact manifest is the single source of truth at runtime):

    B  = 8192   buckets
    L  = 160    bases per padded read row
    RC = 1024   reads per count_step call (one "work unit")
    ks = 33, 55, 77, 99, 127
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

import jax
import jax.numpy as jnp

from .kernels.denoise import DenoiseSpec, make_denoise_fn
from .kernels.kmer_count import KmerCountSpec, make_count_fn

DEFAULT_KS: List[int] = [33, 55, 77, 99, 127]


@dataclass(frozen=True)
class ModelConfig:
    """Geometry shared by all artifacts in one build."""

    num_buckets: int = 8192
    read_len: int = 160
    reads_per_call: int = 1024
    # CPU-profile tiling for the shipped interpret-mode artifacts: one
    # resident bucket tile (no hash recompute across bucket tiles) and a
    # large read tile (amortize grid-step overhead). The TPU profile
    # (read_tile=8, bucket_tile=2048, variant="onehot") is what
    # DESIGN.md section 3 sizes for VMEM/MXU; tests cover both.
    read_tile: int = 32
    bucket_tile: int = 8192
    denoise_half_width: int = 2
    count_variant: str = "scatter"
    ks: List[int] = field(default_factory=lambda: list(DEFAULT_KS))

    def count_spec(self, k: int) -> KmerCountSpec:
        return KmerCountSpec(
            k=k,
            read_len=self.read_len,
            num_buckets=self.num_buckets,
            read_tile=self.read_tile,
            bucket_tile=self.bucket_tile,
            variant=self.count_variant,
        )

    def denoise_spec(self) -> DenoiseSpec:
        return DenoiseSpec(
            num_buckets=self.num_buckets,
            half_width=self.denoise_half_width,
        )


def build_count_step(cfg: ModelConfig, k: int):
    """``count_step_k(reads i32[RC, L], counts f32[B]) -> (f32[B],)``.

    The hash weights for this k are baked in as a compile-time constant so
    the runtime artifact takes only (reads, counts) -- the Rust hot path
    never re-supplies static data.
    """
    spec = cfg.count_spec(k)
    count = make_count_fn(spec)
    weights = spec.weights()

    def count_step(reads, counts):
        return (count(reads, counts, weights),)

    return count_step


def build_denoise_step(cfg: ModelConfig):
    """``denoise_step(counts f32[B], stencil f32[2w+1], params f32[2]) -> (f32[B],)``.

    Stencil and [threshold, decay] stay runtime operands: the Rust stage
    driver anneals the threshold across sweeps (coverage cutoff schedule),
    so they change call-to-call.
    """
    denoise = make_denoise_fn(cfg.denoise_spec())

    def denoise_step(counts, stencil, params):
        return (denoise(counts, stencil, params),)

    return denoise_step


def build_spectrum_stats(cfg: ModelConfig):
    """``spectrum_stats(counts f32[B]) -> (f32[3],)``: [mass, occupied, max].

    Plain jnp (no Pallas): a cheap reduction the coordinator logs at stage
    boundaries and uses to sanity-check restored checkpoints.
    """

    def spectrum_stats(counts):
        c = counts.astype(jnp.float32)
        return (
            jnp.stack(
                [
                    jnp.sum(c),
                    jnp.sum((c > 0).astype(jnp.float32)),
                    jnp.max(c),
                ]
            ),
        )

    return spectrum_stats


def example_args(cfg: ModelConfig, name: str, k: int = 0):
    """ShapeDtypeStructs for AOT lowering of artifact `name`."""
    b = cfg.num_buckets
    if name == "count_step":
        return (
            jax.ShapeDtypeStruct((cfg.reads_per_call, cfg.read_len), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
        )
    if name == "denoise_step":
        taps = 2 * cfg.denoise_half_width + 1
        return (
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((taps,), jnp.float32),
            jax.ShapeDtypeStruct((2,), jnp.float32),
        )
    if name == "spectrum_stats":
        return (jax.ShapeDtypeStruct((b,), jnp.float32),)
    raise KeyError(name)


def build_all(cfg: ModelConfig) -> Dict[str, object]:
    """All artifacts for one build: name -> traceable fn returning a tuple."""
    out: Dict[str, object] = {}
    for k in cfg.ks:
        out[f"count_k{k}"] = build_count_step(cfg, k)
    out["denoise"] = build_denoise_step(cfg)
    out["spectrum_stats"] = build_spectrum_stats(cfg)
    return out
