"""Pure-jnp correctness oracles for the Pallas kernels.

These are the CORE correctness signal: straight-line jnp implementations of
exactly what the kernels must compute, with no blocking, no grid, no
one-hot-matmul restructuring.  pytest (and hypothesis sweeps) assert
allclose between each kernel and its oracle across shapes, k values and
input distributions.
"""

from __future__ import annotations

import jax.numpy as jnp

from .denoise import DenoiseSpec
from .kmer_count import KmerCountSpec


def ref_kmer_count(
    spec: KmerCountSpec, reads: jnp.ndarray, counts: jnp.ndarray
) -> jnp.ndarray:
    """Histogram of polynomial k-mer hashes, windows with any base > 3 skipped.

    reads: i32[R, L]; counts: f32[B] (accumulated into); returns f32[B].
    """
    reads = reads.astype(jnp.int32)
    k, p, b = spec.k, spec.positions, spec.num_buckets
    w = spec.weights()
    # windows[r, i, j] = reads[r, i + j]
    windows = jnp.stack(
        [reads[:, j : j + p] for j in range(k)], axis=-1
    )  # (R, P, k)
    h = jnp.mod(jnp.sum(windows * w[None, None, :], axis=-1), b)
    bad = jnp.any(windows > 3, axis=-1)
    h = jnp.where(bad, b, h)  # sentinel bucket B is dropped below
    hist = jnp.zeros((b + 1,), dtype=jnp.float32).at[h.reshape(-1)].add(1.0)
    return counts.astype(jnp.float32) + hist[:b]


def ref_denoise(
    spec: DenoiseSpec,
    counts: jnp.ndarray,
    stencil: jnp.ndarray,
    params: jnp.ndarray,
) -> jnp.ndarray:
    """Banded smoothing (zero-padded edges) + soft threshold.

    counts: f32[B]; stencil: f32[2w+1]; params: f32[2] = [threshold, decay].
    """
    b, w = spec.num_buckets, spec.half_width
    c = counts.astype(jnp.float32)
    padded = jnp.pad(c, (w, w))
    cols = jnp.stack(
        [padded[d : d + b] for d in range(spec.taps)], axis=-1
    )  # (B, taps)
    smooth = jnp.sum(cols * stencil[None, :].astype(jnp.float32), axis=-1)
    thr, decay = params[0], params[1]
    return jnp.where(smooth >= thr, smooth, smooth * decay)


def ref_spectrum_stats(counts: jnp.ndarray) -> tuple:
    """Stage summary statistics: (total mass, occupied buckets, max)."""
    c = counts.astype(jnp.float32)
    return (
        jnp.sum(c),
        jnp.sum((c > 0).astype(jnp.float32)),
        jnp.max(c),
    )
