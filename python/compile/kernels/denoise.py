"""Pallas spectral-denoise kernel: banded smoothing + soft threshold.

The assembly-graph-cleaning analog (DESIGN.md section 2): metaSPAdes spends
each k-stage's tail simplifying its de Bruijn graph (tip clipping, bulge
removal, low-coverage edge dropping).  On the bucketed k-mer spectrum this
maps to an iterated local operator:

    smooth[b] = sum_d stencil[d] * counts[b + d - w]      (banded matvec)
    out[b]    = smooth[b]                  if smooth[b] >= threshold
              = smooth[b] * decay          otherwise       (soft threshold)

i.e. one Jacobi-style relaxation sweep followed by suppression of
low-coverage buckets -- the same read/modify/threshold shape as coverage
cutoffs in real assemblers.  Each denoise *step* is one sweep; a stage runs
a configured number of sweeps, and mid-stage state (the evolving spectrum)
is exactly what transparent checkpoints capture and application-native
checkpoints lose.

Kernel structure: the spectrum is tiny relative to VMEM (B f32 = 32 KiB at
the default B=8192), so the whole array is a single block and the grid is
1 -- the interesting blocking lives in :mod:`kmer_count`.  The stencil halo
is handled with zero padding inside the kernel (edge buckets see a clipped
neighbourhood, matching the reference oracle).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


@dataclass(frozen=True)
class DenoiseSpec:
    """Static configuration of the denoise kernel."""

    num_buckets: int  # B
    half_width: int = 2  # w: stencil spans 2w+1 taps

    def __post_init__(self) -> None:
        if self.half_width < 0:
            raise ValueError("half_width must be >= 0")
        if self.num_buckets <= 2 * self.half_width:
            raise ValueError("num_buckets too small for stencil width")

    @property
    def taps(self) -> int:
        return 2 * self.half_width + 1


def _denoise_kernel(spec: DenoiseSpec, c_ref, s_ref, t_ref, o_ref):
    """c_ref: f32[B] counts; s_ref: f32[2w+1] stencil;
    t_ref: f32[2] (threshold, decay); o_ref: f32[B]."""
    b, w = spec.num_buckets, spec.half_width
    c = c_ref[...]
    # Zero-pad and take the 2w+1 shifted views; the taps are unrolled
    # (compile-time constant width) into a flat mul/add chain.
    padded = jnp.pad(c, (w, w))
    smooth = jnp.zeros((b,), dtype=jnp.float32)
    for d in range(spec.taps):
        smooth = smooth + s_ref[d] * padded[d : d + b]
    thr = t_ref[0]
    decay = t_ref[1]
    o_ref[...] = jnp.where(smooth >= thr, smooth, smooth * decay)


def make_denoise_fn(spec: DenoiseSpec):
    """Build ``denoise(counts f32[B], stencil f32[2w+1], params f32[2]) -> f32[B]``.

    ``params = [threshold, decay]``.  Returned callable wraps the
    pallas_call; jitted/lowered by `model.py`.
    """

    kernel = functools.partial(_denoise_kernel, spec)

    def denoise(
        counts: jnp.ndarray, stencil: jnp.ndarray, params: jnp.ndarray
    ):
        if counts.shape != (spec.num_buckets,):
            raise ValueError(f"counts must be ({spec.num_buckets},)")
        if stencil.shape != (spec.taps,):
            raise ValueError(f"stencil must be ({spec.taps},)")
        return pl.pallas_call(
            kernel,
            out_shape=jax.ShapeDtypeStruct(
                (spec.num_buckets,), jnp.float32
            ),
            interpret=True,
        )(
            counts.astype(jnp.float32),
            stencil.astype(jnp.float32),
            params.astype(jnp.float32),
        )

    return denoise
