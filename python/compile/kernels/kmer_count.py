"""Pallas k-mer counting kernel: rolling-hash histogram as one-hot matmul.

The GPU-native formulation of k-mer counting is a gather/scatter histogram
(atomic adds into a global table).  On TPU there is no scatter unit; the
MXU-friendly restructuring (DESIGN.md section 3, "Hardware adaptation") is:

1. For a tile of reads ``(TR, L)`` (2-bit base codes 0..3, code 4 = N/pad),
   compute the polynomial rolling hash of every k-window::

       h[r, p] = sum_j base[r, p + j] * w[j]  (mod B),   w[j] = 4^(k-1-j) mod B

   The weights are precomputed (arbitrary-precision in Python) and passed as
   an ``i32[k]`` operand so the same kernel body serves every k.

2. Windows containing an invalid base (code > 3) are redirected to the
   sentinel value ``B`` which one-hot-encodes to the zero row -- masked
   windows contribute nothing without a select on the accumulate path.

3. One-hot encode the flattened hashes against the *bucket tile* currently
   resident in VMEM and reduce with a matmul::

       partial = ones[1, TR*P] @ onehot[TR*P, BB]        # MXU contraction

   which is exactly a histogram restricted to buckets ``[jB*BB, (jB+1)*BB)``.

Grid layout: ``(nB, nR)`` with the bucket dimension OUTER so each output
block stays resident while all read tiles stream past it (the classic
"stationary accumulator" schedule; on real TPU this is the
``dimension_semantics=("parallel", "arbitrary")`` pattern).  The count tile
is initialised from ``counts_in`` on the first read tile and accumulated in
place afterwards.

VMEM budget per grid step (defaults TR=8, L=160, k=33 -> P=128, BB=2048):
reads tile 8*160*4 = 5 KiB, one-hot 1024*2048*4 = 8 MiB, count tile 8 KiB --
comfortably under the ~16 MiB VMEM target.  MXU work per step:
TR*P*BB ~= 2.1 MMACs.

Lowered with ``interpret=True``: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute; interpret mode lowers to
plain HLO with identical numerics (checked against :mod:`ref` by pytest).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


@dataclass(frozen=True)
class KmerCountSpec:
    """Static configuration of one compiled k-mer counting kernel.

    ``variant`` selects the accumulation strategy (both share the hash +
    masking front end and are checked against the same oracle):

    - ``"onehot"`` — the TPU-shaped formulation: one-hot encode against
      the resident bucket tile and reduce with a matmul (MXU systolic
      contraction). This is the structure DESIGN.md section 3 argues for
      on real hardware.
    - ``"scatter"`` — the CPU-profile formulation: a scatter-add
      histogram (``.at[].add``), which XLA's CPU backend executes ~500×
      faster than materializing the one-hot (EXPERIMENTS.md §Perf).
      Used for the shipped interpret-mode artifacts.
    """

    k: int  # k-mer length (window size)
    read_len: int  # L: bases per (padded) read row
    num_buckets: int  # B: histogram size; must be divisible by bucket_tile
    read_tile: int = 8  # TR: reads per grid step
    bucket_tile: int = 2048  # BB: bucket block per grid step
    variant: str = "onehot"

    def __post_init__(self) -> None:
        if self.variant not in ("onehot", "scatter"):
            raise ValueError(f"unknown variant '{self.variant}'")
        if self.k < 2:
            raise ValueError(f"k must be >= 2, got {self.k}")
        if self.k > self.read_len:
            raise ValueError(
                f"k={self.k} longer than read_len={self.read_len}"
            )
        if self.num_buckets % self.bucket_tile != 0:
            raise ValueError(
                f"num_buckets={self.num_buckets} not divisible by "
                f"bucket_tile={self.bucket_tile}"
            )
        # Hash accumulation is done in i32: the per-window partial sum is
        # bounded by 3 * B * k which must stay below 2^31.
        if 3 * self.num_buckets * self.k >= 2**31:
            raise ValueError("num_buckets * k too large for i32 hash path")

    @property
    def positions(self) -> int:
        """P: number of k-windows per read row."""
        return self.read_len - self.k + 1

    @property
    def bucket_grid(self) -> int:
        return self.num_buckets // self.bucket_tile

    def weights(self) -> jnp.ndarray:
        """Polynomial hash weights w[j] = 4^(k-1-j) mod B, as i32[k]."""
        b = self.num_buckets
        return jnp.asarray(
            [pow(4, self.k - 1 - j, b) for j in range(self.k)],
            dtype=jnp.int32,
        )


def _count_kernel(spec: KmerCountSpec, x_ref, w_ref, cin_ref, o_ref):
    """Kernel body for one (bucket tile, read tile) grid step.

    x_ref:   i32[TR, L]   read tile (base codes, 4 = invalid/pad)
    w_ref:   i32[k]       hash weights (same block every step)
    cin_ref: f32[BB]      incoming counts for this bucket tile
    o_ref:   f32[BB]      accumulated counts for this bucket tile
    """
    k, p, bb = spec.k, spec.positions, spec.bucket_tile
    x = x_ref[...]

    # Rolling polynomial hash + validity, unrolled over the k taps (k is a
    # compile-time constant; the slices are static so this lowers to a flat
    # chain of slice/mul/add -- no dynamic indexing in the hot loop).
    acc = jnp.zeros((spec.read_tile, p), dtype=jnp.int32)
    bad = jnp.zeros((spec.read_tile, p), dtype=jnp.bool_)
    for j in range(k):
        col = x[:, j : j + p]
        acc = acc + col * w_ref[j]
        bad = bad | (col > 3)
    h = jax.lax.rem(acc, jnp.int32(spec.num_buckets))
    # Invalid windows -> sentinel B: one-hot against any bucket tile is the
    # zero row, so they drop out of the histogram with no extra select.
    h = jnp.where(bad, jnp.int32(spec.num_buckets), h)

    # Restrict to the bucket tile owned by this grid step.
    j_b = pl.program_id(0)
    base = j_b * bb
    flat = h.reshape((spec.read_tile * p,))
    local = flat - base  # value in [0, BB) iff bucket lives in this tile

    if spec.variant == "onehot":
        # MXU contraction: ones[1, TR*P] @ onehot[TR*P, BB] == per-tile
        # histogram (the TPU-shaped path, DESIGN.md section 3).
        cols = jax.lax.broadcasted_iota(
            jnp.int32, (spec.read_tile * p, bb), 1
        )
        onehot = (local[:, None] == cols).astype(jnp.float32)
        ones = jnp.ones((1, spec.read_tile * p), dtype=jnp.float32)
        partial = jnp.dot(ones, onehot, preferred_element_type=jnp.float32)
        partial = partial.reshape((bb,))
    else:
        # CPU-profile scatter-add histogram. NOTE: negative indices would
        # *wrap* under JAX indexing (mode="drop" only drops fully
        # out-of-bounds values), so redirect everything outside this tile
        # — including the sentinel B for masked windows — to `bb`, which
        # "drop" then discards.
        in_tile = (flat >= base) & (flat < base + bb)
        safe = jnp.where(in_tile, local, bb)
        partial = (
            jnp.zeros((bb,), dtype=jnp.float32)
            .at[safe]
            .add(1.0, mode="drop")
        )

    i_r = pl.program_id(1)

    @pl.when(i_r == 0)
    def _init():
        o_ref[...] = cin_ref[...] + partial

    @pl.when(i_r != 0)
    def _accum():
        o_ref[...] = o_ref[...] + partial


def make_count_fn(spec: KmerCountSpec):
    """Build ``count(reads i32[R, L], counts f32[B], weights i32[k]) -> f32[B]``.

    R must be a multiple of ``spec.read_tile``.  The returned function is a
    plain jax-traceable callable wrapping the pallas_call; `model.py` jits
    and AOT-lowers it per k.
    """

    kernel = functools.partial(_count_kernel, spec)

    def count(reads: jnp.ndarray, counts: jnp.ndarray, weights: jnp.ndarray):
        if reads.ndim != 2 or reads.shape[1] != spec.read_len:
            raise ValueError(f"reads must be (R, {spec.read_len})")
        n_r = reads.shape[0] // spec.read_tile
        if n_r * spec.read_tile != reads.shape[0]:
            raise ValueError(
                f"R={reads.shape[0]} not a multiple of tile {spec.read_tile}"
            )
        grid = (spec.bucket_grid, n_r)
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[
                # read tile: streams along the inner grid dim
                pl.BlockSpec(
                    (spec.read_tile, spec.read_len), lambda jb, ir: (ir, 0)
                ),
                # weights: one small block, same every step
                pl.BlockSpec((spec.k,), lambda jb, ir: (0,)),
                # incoming counts: the bucket tile owned by jb
                pl.BlockSpec((spec.bucket_tile,), lambda jb, ir: (jb,)),
            ],
            out_specs=pl.BlockSpec(
                (spec.bucket_tile,), lambda jb, ir: (jb,)
            ),
            out_shape=jax.ShapeDtypeStruct(
                (spec.num_buckets,), jnp.float32
            ),
            interpret=True,
        )(reads.astype(jnp.int32), weights, counts.astype(jnp.float32))

    return count
