"""Layer-1 Pallas kernels for the MiniMeta assembler workload.

Two hot kernels, designed TPU-first (see DESIGN.md section 3) and lowered
with ``interpret=True`` so the resulting HLO runs on any PJRT backend,
including the Rust CPU client on the request path:

- :mod:`kmer_count` -- rolling-hash k-mer histogram restructured as a
  one-hot x matmul accumulation (MXU-friendly), gridded over read tiles
  and bucket tiles.
- :mod:`denoise` -- banded spectral smoothing + soft-threshold iteration
  (the assembly-graph cleaning analog).

:mod:`ref` holds the pure-jnp oracles the pytest suite checks against.
"""
