//! Property suite for the requeue scheduler's interleaving invariants:
//! over randomized job mixes, slot counts and requeue delays, no two
//! running attempts ever share a slot (concurrency never exceeds the
//! cluster width) and total busy time never exceeds slots × makespan.

use std::collections::HashMap;

use spoton::metrics::{EventKind, Timeline};
use spoton::sched::{Job, RequeueScheduler};
use spoton::sim::experiment::Experiment;
use spoton::simclock::SimDuration;
use spoton::util::proptest::{forall, shrink_none, Config};
use spoton::util::Prng;

/// One scheduler scenario drawn by the generator.
#[derive(Debug, Clone)]
struct Scenario {
    slots: u32,
    requeue_secs: u64,
    max_attempts: u32,
    /// Per job: (eviction interval minutes or 0 for none, protected).
    jobs: Vec<(u64, bool)>,
}

fn build_jobs(s: &Scenario) -> Vec<Job> {
    s.jobs
        .iter()
        .enumerate()
        .map(|(i, &(evict_mins, protected))| {
            let mut exp = Experiment::table1()
                .named("prop")
                .scale_stages(0.3)
                .seed(1000 + i as u64);
            if evict_mins > 0 {
                exp = exp.eviction_every(SimDuration::from_mins(evict_mins));
            }
            exp = if protected {
                exp.transparent(SimDuration::from_mins(10))
            } else {
                // unprotected + evictions can never finish: exercises the
                // requeue/abandon path within a bounded deadline
                exp.unprotected().deadline(SimDuration::from_hours(2))
            };
            Job { id: i as u32, name: format!("job-{i}"), experiment: exp }
        })
        .collect()
}

/// Reconstruct attempt intervals [(start_ms, end_ms)] from the cluster
/// timeline: each `JobStarted` opens an interval for its job, closed by
/// that job's next `JobRequeued` or `JobFinished`.
fn attempt_intervals(timeline: &Timeline) -> Result<Vec<(u64, u64)>, String> {
    let mut open: HashMap<String, u64> = HashMap::new();
    let mut intervals = Vec::new();
    for e in timeline.events() {
        match e.kind {
            EventKind::JobStarted => {
                let name = e
                    .detail
                    .split(" attempt")
                    .next()
                    .ok_or("unparseable JobStarted detail")?
                    .to_string();
                if open.insert(name.clone(), e.at.as_millis()).is_some() {
                    return Err(format!(
                        "{name} started while already running"
                    ));
                }
            }
            EventKind::JobRequeued | EventKind::JobFinished => {
                let name = e
                    .detail
                    .rsplit_once(" (")
                    .ok_or("unparseable end detail")?
                    .0
                    .to_string();
                let start = open.remove(&name).ok_or(format!(
                    "{name} ended without a running attempt"
                ))?;
                intervals.push((start, e.at.as_millis()));
            }
            _ => {}
        }
    }
    if !open.is_empty() {
        return Err(format!("attempts never ended: {:?}", open.keys()));
    }
    Ok(intervals)
}

fn check_scenario(s: &Scenario) -> Result<(), String> {
    let sched = RequeueScheduler {
        requeue_delay: SimDuration::from_secs(s.requeue_secs),
        max_attempts: s.max_attempts,
        slots: s.slots,
        fleet: None,
    };
    let (records, timeline) = sched
        .run_with_timeline(build_jobs(s))
        .map_err(|e| e.to_string())?;
    if records.len() != s.jobs.len() {
        return Err(format!(
            "{} jobs in, {} records out",
            s.jobs.len(),
            records.len()
        ));
    }
    if !timeline.is_monotone() {
        return Err("timeline not monotone".into());
    }

    let intervals = attempt_intervals(&timeline)?;

    // ---- no two attempts share a slot: concurrency ≤ slots ----
    // Sweep: close intervals before opening new ones at the same instant
    // (the scheduler fills freed slots at the same event time).
    let mut points: Vec<(u64, i64)> = Vec::new();
    for &(start, end) in &intervals {
        if end < start {
            return Err(format!("interval ends before it starts: {start}..{end}"));
        }
        points.push((start, 1));
        points.push((end, -1));
    }
    points.sort_by_key(|&(t, delta)| (t, delta));
    let mut running = 0i64;
    for (t, delta) in points {
        running += delta;
        if running > s.slots as i64 {
            return Err(format!(
                "{running} attempts share {} slot(s) at t={t}ms",
                s.slots
            ));
        }
    }

    // ---- total busy time ≤ slots × makespan ----
    let busy: u64 = intervals.iter().map(|(a, b)| b - a).sum();
    let makespan = intervals.iter().map(|&(_, b)| b).max().unwrap_or(0);
    if busy > s.slots as u64 * makespan {
        return Err(format!(
            "busy {busy}ms exceeds {} slot(s) x makespan {makespan}ms",
            s.slots
        ));
    }
    Ok(())
}

#[test]
fn prop_no_slot_sharing_and_bounded_busy_time() {
    forall(
        Config::default().cases(18).seed(0x5C_4ED),
        |rng: &mut Prng| Scenario {
            slots: 1 + rng.below(3) as u32,
            requeue_secs: rng.range_u64(30, 1200),
            max_attempts: 2 + rng.below(2) as u32,
            jobs: (0..1 + rng.below(4))
                .map(|_| {
                    if rng.chance(0.3) {
                        // doomed: unprotected with evictions
                        (rng.range_u64(20, 40), false)
                    } else if rng.chance(0.5) {
                        // stormy but protected
                        (rng.range_u64(15, 90), true)
                    } else {
                        // clean
                        (0, true)
                    }
                })
                .collect(),
        },
        shrink_none,
        check_scenario,
    );
}
