//! Sweep determinism suite: the merged output of a Monte Carlo sweep is
//! a pure function of (base experiment, seed list) — the thread count,
//! scheduling order, and whatever else ran earlier in the process must
//! never show through. Pinned by comparing full `RunResult` digests
//! (every field, costs bitwise, the whole timeline) and the reduced
//! distribution summaries across `threads = 1, 2, 8`.

use spoton::metrics::RecordLevel;
use spoton::report::distribution;
use spoton::sim::experiment::Experiment;
use spoton::sim::sweep::{run_digest, SeededRun};
use spoton::simclock::SimDuration;

const SEEDS: usize = 24;

fn base() -> Experiment {
    Experiment::table1()
        .named("determinism")
        .eviction_poisson(SimDuration::from_mins(60))
        .transparent(SimDuration::from_mins(15))
        .deadline(SimDuration::from_hours(30))
}

fn digests(runs: &[SeededRun]) -> Vec<(u64, String)> {
    runs.iter()
        .map(|r| (r.seed, run_digest(&r.result)))
        .collect()
}

#[test]
fn merged_results_identical_across_thread_counts() {
    let sweep = base().sweep().seed_range(0, SEEDS);
    let t1 = digests(&sweep.clone().threads(1).run().unwrap());
    let t2 = digests(&sweep.clone().threads(2).run().unwrap());
    let t8 = digests(&sweep.clone().threads(8).run().unwrap());
    assert_eq!(t1.len(), SEEDS);
    assert_eq!(t1, t2, "threads=2 diverged from threads=1");
    assert_eq!(t1, t8, "threads=8 diverged from threads=1");
}

#[test]
fn full_metrics_sweeps_are_also_thread_invariant() {
    // Full level keeps every timeline detail (instance ids, checkpoint
    // ids, notice event ids) — all of it must be per-run deterministic,
    // not process-global.
    let sweep = base().sweep().seed_range(100, 12).record(RecordLevel::Full);
    let t1 = sweep.clone().threads(1).run().unwrap();
    let t8 = sweep.clone().threads(8).run().unwrap();
    let d1 = digests(&t1);
    let d8 = digests(&t8);
    assert_eq!(d1, d8, "full-metrics sweep diverged across thread counts");
    // and Full runs really carry timelines
    assert!(t1.iter().all(|r| !r.result.timeline.events().is_empty()));
}

#[test]
fn distribution_summaries_identical_across_thread_counts() {
    let sweep = base().sweep().seed_range(0, SEEDS);
    let s1 = distribution::summarize(
        "determinism",
        &sweep.clone().threads(1).run().unwrap(),
    );
    let s8 = distribution::summarize(
        "determinism",
        &sweep.clone().threads(8).run().unwrap(),
    );
    // bitwise-equal JSON and identical rendered tables
    assert_eq!(
        spoton::json::to_string(&s1.to_json()),
        spoton::json::to_string(&s8.to_json())
    );
    assert_eq!(distribution::render(&s1), distribution::render(&s8));
}

#[test]
fn sweep_reruns_are_reproducible_in_one_process() {
    // Two sweeps of the same seeds in the same process, with other
    // sweeps interleaved between them, still match byte for byte.
    let sweep = base().sweep().seed_range(7, 8).threads(4);
    let first = digests(&sweep.clone().run().unwrap());
    // unrelated interleaved work (different scenario, different seeds)
    let _ = Experiment::table1()
        .eviction_every(SimDuration::from_mins(45))
        .transparent(SimDuration::from_mins(10))
        .sweep()
        .seed_range(900, 6)
        .threads(3)
        .run()
        .unwrap();
    let second = digests(&sweep.clone().run().unwrap());
    assert_eq!(first, second);
}

#[test]
fn traced_pool_sweeps_merge_deterministically() {
    // Traced spot markets on a sweep: an explicit price spike in one
    // pool, a seeded random walk in the other (regenerated per sweep
    // seed). The merged digests — including per-segment billing and the
    // PoolPriceChanged counters — must be identical at any thread count.
    use spoton::cloud::trace::{PricePoint, PriceTrace, PriceWalkCfg};
    use spoton::config::{
        EvictionPlanCfg, PlacementPolicyCfg, PoolCfg, PoolPricingCfg,
    };
    let spike = PriceTrace::new(vec![
        PricePoint { offset: SimDuration::ZERO, factor: 0.8 },
        PricePoint { offset: SimDuration::from_mins(60), factor: 1.7 },
    ])
    .expect("valid trace");
    let exp = Experiment::table1()
        .named("trace-determinism")
        .transparent(SimDuration::from_mins(15))
        .deadline(SimDuration::from_hours(30))
        .pool(
            PoolCfg::named("spiky")
                .pricing(PoolPricingCfg::Trace(spike))
                .eviction(EvictionPlanCfg::Poisson {
                    mean: SimDuration::from_mins(40),
                }),
        )
        .pool(
            PoolCfg::named("walker")
                .pricing(PoolPricingCfg::Walk(PriceWalkCfg::default()))
                .eviction(EvictionPlanCfg::Poisson {
                    mean: SimDuration::from_mins(90),
                }),
        )
        .placement(PlacementPolicyCfg::CheapestSpot);
    let sweep = exp.sweep().seed_range(0, 12);
    let t1 = sweep.clone().threads(1).run().unwrap();
    let t2 = sweep.clone().threads(2).run().unwrap();
    let t8 = sweep.clone().threads(8).run().unwrap();
    assert_eq!(digests(&t1), digests(&t2), "threads=2 diverged");
    assert_eq!(digests(&t1), digests(&t8), "threads=8 diverged");
    // the runs really replayed moving prices (counted even at the lean
    // Counts metrics level)
    assert!(t1.iter().all(|r| r
        .result
        .timeline
        .count(spoton::metrics::EventKind::PoolPriceChanged)
        > 0));
}

#[test]
fn adaptive_controller_sweeps_merge_deterministically() {
    // Adaptive interval controllers on a traced multi-pool market: the
    // Young/Daly estimator and the cost-aware price scaling are pure
    // functions of the run's own observations, so per-controller sweeps
    // must merge byte-identically at any thread count — and the
    // controllers must actually diverge from the fixed baseline.
    use spoton::cloud::trace::{PricePoint, PriceTrace};
    use spoton::config::{
        EvictionPlanCfg, IntervalControllerCfg, PlacementPolicyCfg, PoolCfg,
        PoolPricingCfg,
    };
    let spike = PriceTrace::new(vec![
        PricePoint { offset: SimDuration::ZERO, factor: 0.8 },
        PricePoint { offset: SimDuration::from_mins(75), factor: 1.6 },
    ])
    .expect("valid trace");
    let exp = Experiment::table1()
        .named("adaptive-determinism")
        .transparent(SimDuration::from_mins(30))
        .deadline(SimDuration::from_hours(30))
        .pool(
            PoolCfg::named("spiky")
                .pricing(PoolPricingCfg::Trace(spike))
                .eviction(EvictionPlanCfg::Poisson {
                    mean: SimDuration::from_mins(40),
                }),
        )
        .pool(PoolCfg::named("steady"))
        .placement(PlacementPolicyCfg::CheapestSpot);
    let controllers = [
        IntervalControllerCfg::Fixed,
        IntervalControllerCfg::young_daly(),
        IntervalControllerCfg::cost_aware(1.0),
    ];
    let sweep = exp.sweep().seed_range(0, 10);
    let per_thread: Vec<Vec<(String, Vec<(u64, String)>)>> = [1, 2, 8]
        .into_iter()
        .map(|threads| {
            sweep
                .clone()
                .threads(threads)
                .run_controllers(&controllers)
                .unwrap()
                .into_iter()
                .map(|cs| (cs.label.clone(), digests(&cs.runs)))
                .collect()
        })
        .collect();
    assert_eq!(per_thread[0], per_thread[1], "threads=2 diverged");
    assert_eq!(per_thread[0], per_thread[2], "threads=8 diverged");
    // labels arrive in controller order
    let labels: Vec<&str> =
        per_thread[0].iter().map(|(l, _)| l.as_str()).collect();
    assert_eq!(labels, ["fixed", "young-daly", "cost-aware/1"]);
    // the adaptive populations genuinely differ from the fixed baseline
    assert_ne!(
        per_thread[0][0].1, per_thread[0][1].1,
        "young-daly never deviated from fixed"
    );
    assert_ne!(
        per_thread[0][1].1, per_thread[0][2].1,
        "cost-aware never deviated from young-daly on a moving market"
    );
}

#[test]
fn cluster_sweeps_merge_deterministically() {
    // The multiplexed cluster engine under sweep: each seeded run packs
    // 200 Poisson-arriving jobs onto one capacity-8 pool (offered load
    // ~9 — the queue genuinely binds), the sweep fans runs across
    // threads, and the merged `cluster_digest`s — every job's full
    // `run_digest` plus the cluster admission timeline — must be
    // byte-identical at any thread count.
    use spoton::config::{ArrivalCfg, ClusterCfg};
    use spoton::sim::cluster::cluster_digest;
    use spoton::sim::SeededClusterRun;
    let mut exp = Experiment::table1()
        .named("cluster-determinism")
        .scale_stages(0.01)
        .eviction_poisson(SimDuration::from_mins(30))
        .transparent(SimDuration::from_mins(5))
        .deadline(SimDuration::from_hours(4000));
    exp.cfg.cluster = Some(
        ClusterCfg::with_count(200).capacity(8).arrival(
            ArrivalCfg::Poisson { mean: SimDuration::from_mins(2) },
        ),
    );
    let dig = |runs: &[SeededClusterRun]| -> Vec<(u64, String)> {
        runs.iter()
            .map(|r| (r.seed, cluster_digest(&r.result)))
            .collect()
    };
    let sweep = exp.cluster_sweep().seed_range(0, 4);
    let t1 = sweep.clone().threads(1).run().unwrap();
    let t2 = sweep.clone().threads(2).run().unwrap();
    let t8 = sweep.clone().threads(8).run().unwrap();
    let d1 = dig(&t1);
    assert_eq!(d1.len(), 4);
    assert_eq!(d1, dig(&t2), "threads=2 diverged from threads=1");
    assert_eq!(d1, dig(&t8), "threads=8 diverged from threads=1");
    // the contention is real in every seeded run: all jobs finish, the
    // pool saturates, and admissions actually queue
    for r in &t1 {
        assert_eq!(r.result.completed_jobs(), 200, "{}", r.result.summary());
        assert!(
            r.result.peak_in_flight > 1,
            "jobs must genuinely interleave: {}",
            r.result.summary()
        );
        assert_eq!(r.result.peak_in_flight_per_pool, vec![8]);
        assert!(
            r.result.queued_admissions() > 0,
            "capacity must bind: {}",
            r.result.summary()
        );
    }
}

#[test]
fn multi_pool_sweeps_merge_deterministically() {
    use spoton::config::{EvictionPlanCfg, PlacementPolicyCfg, PoolCfg};
    let exp = Experiment::table1()
        .named("fleet-determinism")
        .transparent(SimDuration::from_mins(15))
        .pool(PoolCfg::named("storm").price_factor(0.9).eviction(
            EvictionPlanCfg::Poisson { mean: SimDuration::from_mins(30) },
        ))
        .pool(PoolCfg::named("stable").price_factor(1.1))
        .placement(PlacementPolicyCfg::EvictionAware { penalty: 4.0 });
    let sweep = exp.sweep().seed_range(0, 12);
    let t1 = sweep.clone().threads(1).run().unwrap();
    let t8 = sweep.clone().threads(8).run().unwrap();
    assert_eq!(digests(&t1), digests(&t8));
    // per-pool attribution survives the reduced metrics level
    assert!(t1.iter().all(|r| r.result.pool_stats.len() == 2));
    let d = distribution::summarize("fleet-determinism", &t1);
    assert_eq!(d.pools.len(), 2);
}

// ---------------------------------------------------------------------
// Chaos-enabled sweeps: injected faults are drawn from per-run salted
// streams, so a sweep under full fault injection must merge exactly as
// deterministically as a healthy one — across threads and processes.
// ---------------------------------------------------------------------

const CHAOS_SCENARIO: &str = r#"
name = "chaos-determinism"
deadline_mins = 1800

[workload]
kind = "sleeper"
ks = [33, 55]
stage_secs = [60, 120]

[eviction]
plan = "poisson"
mean_mins = 45

[checkpoint]
method = "transparent"
interval_mins = 15
retain = 3

[checkpoint.retry]
attempts = 4
base_ms = 250
max_ms = 8000
factor = 2.0
jitter = 0.25

[chaos]
salt = 3
storms = 2
window_mins = 240

[chaos.storage]
write_fail_prob = 0.2
torn_write_prob = 0.1
corrupt_prob = 0.05
latency_spike_prob = 0.1
latency_spike_ms = 1500

[chaos.imds]
outages = 1
outage_mins = 20
degraded_poll_factor = 4
"#;

fn chaos_experiment() -> Experiment {
    use spoton::config::ScenarioConfig;
    Experiment {
        cfg: ScenarioConfig::from_str_toml(CHAOS_SCENARIO).unwrap(),
    }
}

#[test]
fn chaos_sweeps_merge_deterministically() {
    // Every chaos knob armed at once: flaky + torn + corrupting storage,
    // latency spikes, eviction storms, an IMDS outage with degraded
    // polling, and the retrying coordinator absorbing it all. The merged
    // digests — fault events, retry delays, fallback restores included —
    // must be byte-identical at any thread count.
    let sweep = chaos_experiment().sweep().seed_range(0, 12);
    let t1 = sweep.clone().threads(1).run().unwrap();
    let t2 = sweep.clone().threads(2).run().unwrap();
    let t8 = sweep.clone().threads(8).run().unwrap();
    assert_eq!(digests(&t1), digests(&t2), "threads=2 diverged");
    assert_eq!(digests(&t1), digests(&t8), "threads=8 diverged");
    // chaos genuinely fired: the two storms per run alone guarantee a
    // non-empty ledger, and the flaky store forces real retries
    let acc = spoton::report::faults::account_many(
        t1.iter().map(|r| &r.result.timeline),
    );
    assert!(acc.total() > 0, "no chaos events in a fully-armed sweep");
    assert!(
        acc.count(spoton::metrics::EventKind::ChaosStorm) > 0,
        "storms are scheduled unconditionally"
    );
}

#[test]
fn chaos_full_metrics_sweeps_are_thread_invariant() {
    // Full record level keeps every injected-fault detail line (fault
    // kinds, retry delays, storm rewrites) — all of it must merge
    // identically too.
    let sweep = chaos_experiment()
        .sweep()
        .seed_range(50, 8)
        .record(RecordLevel::Full);
    let t1 = sweep.clone().threads(1).run().unwrap();
    let t8 = sweep.clone().threads(8).run().unwrap();
    assert_eq!(digests(&t1), digests(&t8), "full chaos sweep diverged");
    assert!(t1.iter().all(|r| !r.result.timeline.events().is_empty()));
}

#[test]
fn chaos_cluster_sweeps_merge_deterministically() {
    // The multiplexed cluster engine under the same chaos plan: per-job
    // fault streams are decorrelated by job index but drawn from the
    // scenario seed, so the cluster digests must also be thread-
    // invariant.
    use spoton::config::{ArrivalCfg, ClusterCfg};
    use spoton::sim::cluster::cluster_digest;
    use spoton::sim::SeededClusterRun;
    let mut exp = chaos_experiment();
    exp.cfg.cluster = Some(
        ClusterCfg::with_count(12).capacity(4).arrival(
            ArrivalCfg::Poisson { mean: SimDuration::from_mins(5) },
        ),
    );
    let dig = |runs: &[SeededClusterRun]| -> Vec<(u64, String)> {
        runs.iter()
            .map(|r| (r.seed, cluster_digest(&r.result)))
            .collect()
    };
    let sweep = exp.cluster_sweep().seed_range(0, 4);
    let t1 = sweep.clone().threads(1).run().unwrap();
    let t2 = sweep.clone().threads(2).run().unwrap();
    let t8 = sweep.clone().threads(8).run().unwrap();
    assert_eq!(dig(&t1), dig(&t2), "threads=2 diverged");
    assert_eq!(dig(&t1), dig(&t8), "threads=8 diverged");
    for r in &t1 {
        assert_eq!(
            r.result.jobs.len(),
            12,
            "every job accounted for: {}",
            r.result.summary()
        );
    }
}

#[test]
fn autoscaled_bid_cluster_sweeps_merge_deterministically() {
    // The full bid-aware hybrid under market chaos: a traced spot pool
    // whose median-of-trace bid dies at the 40-min spike, an on-demand
    // fallback, deadline-SLA jobs, Poisson arrivals, and two seeded
    // price shocks spliced into the stream. Bids, outbid crossings and
    // autoscale shifts are all pure functions of per-run state, so the
    // merged cluster digests must be byte-identical at any thread count.
    use spoton::cloud::trace::{PricePoint, PriceTrace};
    use spoton::config::{
        ArrivalCfg, AutoscaleCfg, BidPolicyCfg, ChaosCfg, ChaosMarketCfg,
        ClusterCfg, EvictionPlanCfg, PlacementPolicyCfg, PoolCfg,
        PoolPricingCfg,
    };
    use spoton::metrics::EventKind;
    use spoton::sim::cluster::cluster_digest;
    use spoton::sim::SeededClusterRun;

    let spike = PriceTrace::new(vec![
        PricePoint { offset: SimDuration::ZERO, factor: 0.8 },
        PricePoint { offset: SimDuration::from_mins(40), factor: 1.8 },
    ])
    .expect("valid trace");
    let mut exp = Experiment::table1()
        .named("autoscale-determinism")
        .transparent(SimDuration::from_mins(10))
        .deadline(SimDuration::from_hours(10))
        .pool(
            PoolCfg::named("east")
                .pricing(PoolPricingCfg::Trace(spike))
                .eviction(EvictionPlanCfg::Poisson {
                    mean: SimDuration::from_mins(30),
                })
                .capacity(4),
        )
        .pool(PoolCfg::named("ondemand").spot(false).capacity(4))
        .placement(PlacementPolicyCfg::CheapestSpot);
    exp.cfg.workload.ks = vec![33, 55];
    exp.cfg.workload.stage_secs = vec![600, 600];
    exp.cfg.cluster = Some(ClusterCfg::with_count(8).arrival(
        ArrivalCfg::Poisson { mean: SimDuration::from_mins(2) },
    ));
    exp.cfg.job_deadline = Some(SimDuration::from_mins(240));
    exp.cfg.autoscale = Some(AutoscaleCfg {
        policy: BidPolicyCfg::Percentile { q: 0.5 },
        on_demand_pool: "ondemand".into(),
        slack: SimDuration::from_mins(30),
        max_queue: 6,
    });
    exp.cfg.chaos = Some(ChaosCfg {
        salt: 4,
        window: SimDuration::from_mins(120),
        market: ChaosMarketCfg {
            shocks: 2,
            factor: 1.5,
            duration: SimDuration::from_mins(10),
        },
        ..ChaosCfg::default()
    });

    let dig = |runs: &[SeededClusterRun]| -> Vec<(u64, String)> {
        runs.iter()
            .map(|r| (r.seed, cluster_digest(&r.result)))
            .collect()
    };
    let sweep = exp.cluster_sweep().seed_range(0, 6);
    let t1 = sweep.clone().threads(1).run().unwrap();
    let t2 = sweep.clone().threads(2).run().unwrap();
    let t8 = sweep.clone().threads(8).run().unwrap();
    let d1 = dig(&t1);
    assert_eq!(d1.len(), 6);
    assert_eq!(d1, dig(&t2), "threads=2 diverged from threads=1");
    assert_eq!(d1, dig(&t8), "threads=8 diverged from threads=1");

    // The hybrid mechanics genuinely fired across the population: jobs
    // really were outbid on the traced pool, and the autoscaler really
    // shifted placements onto the fallback.
    let outbids: usize = t1
        .iter()
        .flat_map(|r| &r.result.jobs)
        .map(|j| j.result.timeline.count(EventKind::PoolOutbid))
        .sum();
    let shifts: usize = t1
        .iter()
        .map(|r| r.result.timeline.count(EventKind::AutoscaleShift))
        .sum();
    assert!(outbids > 0, "the 1.8x spike must outbid median bids");
    assert!(shifts > 0, "outbid replacements must shift to on-demand");
    // every job carries a deadline verdict (the SLA layer is on)
    for r in &t1 {
        assert!(
            r.result
                .jobs
                .iter()
                .all(|j| j.result.deadline_missed.is_some()),
            "missing deadline verdicts: {}",
            r.result.summary()
        );
    }
}

// ---------------------------------------------------------------------
// Sharded (multi-process) sweeps: the `spoton sweep` runner must uphold
// across OS processes the same contract the in-process sweep upholds
// across threads — merged digests and summaries are a pure function of
// the plan, byte for byte, including across interrupt-and-resume.
// ---------------------------------------------------------------------

const SHARD_SCENARIO: &str = r#"
name = "shard-determinism"
deadline_mins = 1800

[workload]
kind = "sleeper"
ks = [33, 55]
stage_secs = [60, 120]

[eviction]
plan = "poisson"
mean_mins = 45

[checkpoint]
method = "transparent"
interval_mins = 15
"#;

fn shard_tmp(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!(
        "spoton-det-{tag}-{}-{}",
        std::process::id(),
        spoton::util::next_seq()
    ))
}

#[test]
fn sharded_sweeps_merge_byte_identically_across_process_counts() {
    use spoton::config::ScenarioConfig;
    use spoton::sim::shard::{
        fold_run_digests, ConfigVariant, SeedStream, ShardPlan, ShardRunner,
    };
    let cfg = ScenarioConfig::from_str_toml(SHARD_SCENARIO).unwrap();
    let specs = vec!["fixed".to_string(), "young-daly".to_string()];
    let plan = ShardPlan::new(
        "det",
        SeedStream::contiguous(0, 8),
        &specs,
        &cfg,
        SHARD_SCENARIO,
        4,
    )
    .unwrap();
    let run = |procs: usize| -> (String, Vec<u8>) {
        let dir = shard_tmp(&format!("procs{procs}"));
        let runner =
            ShardRunner::new(plan.clone(), &dir, env!("CARGO_BIN_EXE_spoton"))
                .procs(procs)
                .threads(2);
        runner.init(SHARD_SCENARIO).unwrap();
        let out = runner.run().unwrap();
        assert!(out.dead_letter.is_empty());
        assert!(out.reused.is_empty());
        let mut ran = out.ran.clone();
        ran.sort_unstable();
        assert_eq!(ran, vec![0, 1, 2, 3]);
        let merged = out.merged.expect("all shards completed");
        assert_eq!(merged.cells.len(), 16);
        let bytes = std::fs::read(dir.join("MERGED.json")).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
        (merged.digest, bytes)
    };
    let (d1, b1) = run(1);
    let (d4, b4) = run(4);
    assert_eq!(d1, d4, "process count leaked into the merged digest");
    assert_eq!(b1, b4, "process count leaked into MERGED.json");
    // and the multi-process digest equals the in-process sweep fold
    let mut in_process: Vec<String> = Vec::new();
    for spec in ["fixed", "young-daly"] {
        let mut c = cfg.clone();
        ConfigVariant::parse(spec).unwrap().apply(&mut c);
        let runs = Experiment { cfg: c }
            .sweep()
            .seed_range(0, 8)
            .threads(4)
            .run()
            .unwrap();
        in_process.extend(runs.iter().map(|r| run_digest(&r.result)));
    }
    assert_eq!(
        d1,
        fold_run_digests(in_process.iter()),
        "sharded digest diverged from the in-process sweep"
    );
}

#[test]
fn interrupted_sharded_sweeps_resume_byte_identically() {
    use spoton::config::ScenarioConfig;
    use spoton::sim::shard::{SeedStream, ShardPlan, ShardRunner};
    let cfg = ScenarioConfig::from_str_toml(SHARD_SCENARIO).unwrap();
    // a salted stream also exercises >2^53 seeds through the worker's
    // PLAN.json round trip
    let plan = ShardPlan::new(
        "resume-det",
        SeedStream::salted(0, 6, 0xdecaf),
        &["base".to_string(), "fixed".to_string()],
        &cfg,
        SHARD_SCENARIO,
        4,
    )
    .unwrap();
    let exe = env!("CARGO_BIN_EXE_spoton");

    // reference: one clean uninterrupted run
    let ref_dir = shard_tmp("resume-ref");
    let clean = ShardRunner::new(plan.clone(), &ref_dir, exe).procs(2);
    clean.init(SHARD_SCENARIO).unwrap();
    let reference = clean.run().unwrap().merged.expect("clean run merges");
    let ref_bytes = std::fs::read(ref_dir.join("MERGED.json")).unwrap();

    // interrupted: shards 1 and 2 die up front, no retries
    let dir = shard_tmp("resume");
    let broken = ShardRunner::new(plan.clone(), &dir, exe)
        .procs(2)
        .retries(0)
        .env("SPOTON_TEST_FAIL_SHARDS", "1,2");
    broken.init(SHARD_SCENARIO).unwrap();
    let out = broken.run().unwrap();
    assert!(out.merged.is_none(), "a partial sweep must not merge");
    assert!(!dir.join("MERGED.json").exists());
    let mut dead: Vec<usize> =
        out.dead_letter.iter().map(|d| d.shard).collect();
    dead.sort_unstable();
    assert_eq!(dead, vec![1, 2]);
    for d in &out.dead_letter {
        assert_eq!(d.attempts, 1, "retries(0) means a single attempt");
        assert!(d.reason.contains("exited"), "{}", d.reason);
        // the dead letter carries the full replayable cell list
        assert_eq!(d.cells.len(), plan.shard_range(d.shard).len());
        for (m, (config, seed)) in
            plan.shard_range(d.shard).zip(d.cells.iter())
        {
            let (ci, expect_seed) = plan.cell(m);
            assert_eq!(config.as_str(), plan.configs[ci].spec);
            assert_eq!(*seed, expect_seed);
        }
    }

    // resume with the fault cleared: exactly the missing shards re-run
    let resumed = ShardRunner::new(plan.clone(), &dir, exe).procs(2);
    let out2 = resumed.run().unwrap();
    assert_eq!(out2.reused, vec![0, 3]);
    let mut ran = out2.ran.clone();
    ran.sort_unstable();
    assert_eq!(ran, vec![1, 2]);
    assert!(out2.dead_letter.is_empty());
    let merged = out2.merged.expect("resume completes the sweep");
    assert_eq!(
        merged.digest, reference.digest,
        "interrupt-and-resume leaked into the merged digest"
    );
    assert_eq!(
        std::fs::read(dir.join("MERGED.json")).unwrap(),
        ref_bytes,
        "interrupt-and-resume leaked into MERGED.json"
    );
    let _ = std::fs::remove_dir_all(&ref_dir);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn chaos_sharded_sweeps_merge_byte_identically() {
    // The multi-process path under full fault injection: worker
    // processes draw the same per-run chaos streams as in-process
    // threads, so the merged artifact is process-count invariant AND
    // equal to the in-process sweep fold.
    use spoton::config::ScenarioConfig;
    use spoton::sim::shard::{
        fold_run_digests, SeedStream, ShardPlan, ShardRunner,
    };
    use spoton::sim::sweep::run_digest;
    let cfg = ScenarioConfig::from_str_toml(CHAOS_SCENARIO).unwrap();
    let plan = ShardPlan::new(
        "chaos-det",
        SeedStream::contiguous(0, 8),
        &["base".to_string()],
        &cfg,
        CHAOS_SCENARIO,
        4,
    )
    .unwrap();
    let run = |procs: usize| -> (String, Vec<u8>) {
        let dir = shard_tmp(&format!("chaos-procs{procs}"));
        let runner =
            ShardRunner::new(plan.clone(), &dir, env!("CARGO_BIN_EXE_spoton"))
                .procs(procs)
                .threads(2);
        runner.init(CHAOS_SCENARIO).unwrap();
        let out = runner.run().unwrap();
        assert!(out.dead_letter.is_empty());
        let merged = out.merged.expect("all shards completed");
        let bytes = std::fs::read(dir.join("MERGED.json")).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
        (merged.digest, bytes)
    };
    let (d1, b1) = run(1);
    let (d4, b4) = run(4);
    assert_eq!(d1, d4, "process count leaked into the chaos digest");
    assert_eq!(b1, b4, "process count leaked into MERGED.json");
    let runs = chaos_experiment()
        .sweep()
        .seed_range(0, 8)
        .threads(4)
        .run()
        .unwrap();
    assert_eq!(
        d1,
        fold_run_digests(runs.iter().map(|r| run_digest(&r.result))),
        "sharded chaos digest diverged from the in-process sweep"
    );
}

const BID_SHARD_SCENARIO: &str = r#"
name = "bid-shard-determinism"
deadline_mins = 1800

[workload]
kind = "sleeper"
ks = [33, 55]
stage_secs = [600, 900]

[checkpoint]
method = "transparent"
interval_mins = 5

[fleet]
placement = "cheapest-spot"

[pool.volatile]
bid = 0.09

[pool.volatile.price_walk]
start = 1.1
volatility = 0.3
step_mins = 2
steps = 30
floor = 0.5
ceil = 2.0

[pool.calm]
price_factor = 1.15
"#;

#[test]
fn bid_sharded_sweeps_merge_byte_identically() {
    // Bid-aware markets across OS processes: each seeded run regenerates
    // its own price walk, launches into the cheaper volatile pool under
    // a $0.09/h bid, and is outbid wherever the walk crosses it (the
    // replacement lands in the calm pool). Worker processes must draw
    // identical walks and identical crossings, so the merged artifact is
    // process-count invariant and equal to the in-process sweep fold.
    use spoton::config::ScenarioConfig;
    use spoton::sim::shard::{
        fold_run_digests, SeedStream, ShardPlan, ShardRunner,
    };
    use spoton::sim::sweep::run_digest;
    let cfg = ScenarioConfig::from_str_toml(BID_SHARD_SCENARIO).unwrap();
    let plan = ShardPlan::new(
        "bid-det",
        SeedStream::contiguous(0, 8),
        &["base".to_string()],
        &cfg,
        BID_SHARD_SCENARIO,
        4,
    )
    .unwrap();
    let run = |procs: usize| -> (String, Vec<u8>) {
        let dir = shard_tmp(&format!("bid-procs{procs}"));
        let runner =
            ShardRunner::new(plan.clone(), &dir, env!("CARGO_BIN_EXE_spoton"))
                .procs(procs)
                .threads(2);
        runner.init(BID_SHARD_SCENARIO).unwrap();
        let out = runner.run().unwrap();
        assert!(out.dead_letter.is_empty());
        let merged = out.merged.expect("all shards completed");
        let bytes = std::fs::read(dir.join("MERGED.json")).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
        (merged.digest, bytes)
    };
    let (d1, b1) = run(1);
    let (d4, b4) = run(4);
    assert_eq!(d1, d4, "process count leaked into the bid-sweep digest");
    assert_eq!(b1, b4, "process count leaked into MERGED.json");
    let runs = Experiment { cfg }
        .sweep()
        .seed_range(0, 8)
        .threads(4)
        .run()
        .unwrap();
    assert_eq!(
        d1,
        fold_run_digests(runs.iter().map(|r| run_digest(&r.result))),
        "sharded bid digest diverged from the in-process sweep"
    );
    // across 8 independent walks the $0.09 bid is crossed somewhere —
    // the sharded population really exercised the outbid path
    let outbids: usize = runs
        .iter()
        .map(|r| {
            r.result
                .timeline
                .count(spoton::metrics::EventKind::PoolOutbid)
        })
        .sum();
    assert!(outbids > 0, "no walk crossed the bid in 8 seeded runs");
}
