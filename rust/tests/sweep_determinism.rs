//! Sweep determinism suite: the merged output of a Monte Carlo sweep is
//! a pure function of (base experiment, seed list) — the thread count,
//! scheduling order, and whatever else ran earlier in the process must
//! never show through. Pinned by comparing full `RunResult` digests
//! (every field, costs bitwise, the whole timeline) and the reduced
//! distribution summaries across `threads = 1, 2, 8`.

use spoton::metrics::RecordLevel;
use spoton::report::distribution;
use spoton::sim::experiment::Experiment;
use spoton::sim::sweep::{run_digest, SeededRun};
use spoton::simclock::SimDuration;

const SEEDS: usize = 24;

fn base() -> Experiment {
    Experiment::table1()
        .named("determinism")
        .eviction_poisson(SimDuration::from_mins(60))
        .transparent(SimDuration::from_mins(15))
        .deadline(SimDuration::from_hours(30))
}

fn digests(runs: &[SeededRun]) -> Vec<(u64, String)> {
    runs.iter()
        .map(|r| (r.seed, run_digest(&r.result)))
        .collect()
}

#[test]
fn merged_results_identical_across_thread_counts() {
    let sweep = base().sweep().seed_range(0, SEEDS);
    let t1 = digests(&sweep.clone().threads(1).run().unwrap());
    let t2 = digests(&sweep.clone().threads(2).run().unwrap());
    let t8 = digests(&sweep.clone().threads(8).run().unwrap());
    assert_eq!(t1.len(), SEEDS);
    assert_eq!(t1, t2, "threads=2 diverged from threads=1");
    assert_eq!(t1, t8, "threads=8 diverged from threads=1");
}

#[test]
fn full_metrics_sweeps_are_also_thread_invariant() {
    // Full level keeps every timeline detail (instance ids, checkpoint
    // ids, notice event ids) — all of it must be per-run deterministic,
    // not process-global.
    let sweep = base().sweep().seed_range(100, 12).record(RecordLevel::Full);
    let t1 = sweep.clone().threads(1).run().unwrap();
    let t8 = sweep.clone().threads(8).run().unwrap();
    let d1 = digests(&t1);
    let d8 = digests(&t8);
    assert_eq!(d1, d8, "full-metrics sweep diverged across thread counts");
    // and Full runs really carry timelines
    assert!(t1.iter().all(|r| !r.result.timeline.events().is_empty()));
}

#[test]
fn distribution_summaries_identical_across_thread_counts() {
    let sweep = base().sweep().seed_range(0, SEEDS);
    let s1 = distribution::summarize(
        "determinism",
        &sweep.clone().threads(1).run().unwrap(),
    );
    let s8 = distribution::summarize(
        "determinism",
        &sweep.clone().threads(8).run().unwrap(),
    );
    // bitwise-equal JSON and identical rendered tables
    assert_eq!(
        spoton::json::to_string(&s1.to_json()),
        spoton::json::to_string(&s8.to_json())
    );
    assert_eq!(distribution::render(&s1), distribution::render(&s8));
}

#[test]
fn sweep_reruns_are_reproducible_in_one_process() {
    // Two sweeps of the same seeds in the same process, with other
    // sweeps interleaved between them, still match byte for byte.
    let sweep = base().sweep().seed_range(7, 8).threads(4);
    let first = digests(&sweep.clone().run().unwrap());
    // unrelated interleaved work (different scenario, different seeds)
    let _ = Experiment::table1()
        .eviction_every(SimDuration::from_mins(45))
        .transparent(SimDuration::from_mins(10))
        .sweep()
        .seed_range(900, 6)
        .threads(3)
        .run()
        .unwrap();
    let second = digests(&sweep.clone().run().unwrap());
    assert_eq!(first, second);
}

#[test]
fn traced_pool_sweeps_merge_deterministically() {
    // Traced spot markets on a sweep: an explicit price spike in one
    // pool, a seeded random walk in the other (regenerated per sweep
    // seed). The merged digests — including per-segment billing and the
    // PoolPriceChanged counters — must be identical at any thread count.
    use spoton::cloud::trace::{PricePoint, PriceTrace, PriceWalkCfg};
    use spoton::config::{
        EvictionPlanCfg, PlacementPolicyCfg, PoolCfg, PoolPricingCfg,
    };
    let spike = PriceTrace::new(vec![
        PricePoint { offset: SimDuration::ZERO, factor: 0.8 },
        PricePoint { offset: SimDuration::from_mins(60), factor: 1.7 },
    ])
    .expect("valid trace");
    let exp = Experiment::table1()
        .named("trace-determinism")
        .transparent(SimDuration::from_mins(15))
        .deadline(SimDuration::from_hours(30))
        .pool(
            PoolCfg::named("spiky")
                .pricing(PoolPricingCfg::Trace(spike))
                .eviction(EvictionPlanCfg::Poisson {
                    mean: SimDuration::from_mins(40),
                }),
        )
        .pool(
            PoolCfg::named("walker")
                .pricing(PoolPricingCfg::Walk(PriceWalkCfg::default()))
                .eviction(EvictionPlanCfg::Poisson {
                    mean: SimDuration::from_mins(90),
                }),
        )
        .placement(PlacementPolicyCfg::CheapestSpot);
    let sweep = exp.sweep().seed_range(0, 12);
    let t1 = sweep.clone().threads(1).run().unwrap();
    let t2 = sweep.clone().threads(2).run().unwrap();
    let t8 = sweep.clone().threads(8).run().unwrap();
    assert_eq!(digests(&t1), digests(&t2), "threads=2 diverged");
    assert_eq!(digests(&t1), digests(&t8), "threads=8 diverged");
    // the runs really replayed moving prices (counted even at the lean
    // Counts metrics level)
    assert!(t1.iter().all(|r| r
        .result
        .timeline
        .count(spoton::metrics::EventKind::PoolPriceChanged)
        > 0));
}

#[test]
fn adaptive_controller_sweeps_merge_deterministically() {
    // Adaptive interval controllers on a traced multi-pool market: the
    // Young/Daly estimator and the cost-aware price scaling are pure
    // functions of the run's own observations, so per-controller sweeps
    // must merge byte-identically at any thread count — and the
    // controllers must actually diverge from the fixed baseline.
    use spoton::cloud::trace::{PricePoint, PriceTrace};
    use spoton::config::{
        EvictionPlanCfg, IntervalControllerCfg, PlacementPolicyCfg, PoolCfg,
        PoolPricingCfg,
    };
    let spike = PriceTrace::new(vec![
        PricePoint { offset: SimDuration::ZERO, factor: 0.8 },
        PricePoint { offset: SimDuration::from_mins(75), factor: 1.6 },
    ])
    .expect("valid trace");
    let exp = Experiment::table1()
        .named("adaptive-determinism")
        .transparent(SimDuration::from_mins(30))
        .deadline(SimDuration::from_hours(30))
        .pool(
            PoolCfg::named("spiky")
                .pricing(PoolPricingCfg::Trace(spike))
                .eviction(EvictionPlanCfg::Poisson {
                    mean: SimDuration::from_mins(40),
                }),
        )
        .pool(PoolCfg::named("steady"))
        .placement(PlacementPolicyCfg::CheapestSpot);
    let controllers = [
        IntervalControllerCfg::Fixed,
        IntervalControllerCfg::young_daly(),
        IntervalControllerCfg::cost_aware(1.0),
    ];
    let sweep = exp.sweep().seed_range(0, 10);
    let per_thread: Vec<Vec<(String, Vec<(u64, String)>)>> = [1, 2, 8]
        .into_iter()
        .map(|threads| {
            sweep
                .clone()
                .threads(threads)
                .run_controllers(&controllers)
                .unwrap()
                .into_iter()
                .map(|cs| (cs.label.clone(), digests(&cs.runs)))
                .collect()
        })
        .collect();
    assert_eq!(per_thread[0], per_thread[1], "threads=2 diverged");
    assert_eq!(per_thread[0], per_thread[2], "threads=8 diverged");
    // labels arrive in controller order
    let labels: Vec<&str> =
        per_thread[0].iter().map(|(l, _)| l.as_str()).collect();
    assert_eq!(labels, ["fixed", "young-daly", "cost-aware/1"]);
    // the adaptive populations genuinely differ from the fixed baseline
    assert_ne!(
        per_thread[0][0].1, per_thread[0][1].1,
        "young-daly never deviated from fixed"
    );
    assert_ne!(
        per_thread[0][1].1, per_thread[0][2].1,
        "cost-aware never deviated from young-daly on a moving market"
    );
}

#[test]
fn cluster_sweeps_merge_deterministically() {
    // The multiplexed cluster engine under sweep: each seeded run packs
    // 200 Poisson-arriving jobs onto one capacity-8 pool (offered load
    // ~9 — the queue genuinely binds), the sweep fans runs across
    // threads, and the merged `cluster_digest`s — every job's full
    // `run_digest` plus the cluster admission timeline — must be
    // byte-identical at any thread count.
    use spoton::config::{ArrivalCfg, ClusterCfg};
    use spoton::sim::cluster::cluster_digest;
    use spoton::sim::SeededClusterRun;
    let mut exp = Experiment::table1()
        .named("cluster-determinism")
        .scale_stages(0.01)
        .eviction_poisson(SimDuration::from_mins(30))
        .transparent(SimDuration::from_mins(5))
        .deadline(SimDuration::from_hours(4000));
    exp.cfg.cluster = Some(
        ClusterCfg::with_count(200).capacity(8).arrival(
            ArrivalCfg::Poisson { mean: SimDuration::from_mins(2) },
        ),
    );
    let dig = |runs: &[SeededClusterRun]| -> Vec<(u64, String)> {
        runs.iter()
            .map(|r| (r.seed, cluster_digest(&r.result)))
            .collect()
    };
    let sweep = exp.cluster_sweep().seed_range(0, 4);
    let t1 = sweep.clone().threads(1).run().unwrap();
    let t2 = sweep.clone().threads(2).run().unwrap();
    let t8 = sweep.clone().threads(8).run().unwrap();
    let d1 = dig(&t1);
    assert_eq!(d1.len(), 4);
    assert_eq!(d1, dig(&t2), "threads=2 diverged from threads=1");
    assert_eq!(d1, dig(&t8), "threads=8 diverged from threads=1");
    // the contention is real in every seeded run: all jobs finish, the
    // pool saturates, and admissions actually queue
    for r in &t1 {
        assert_eq!(r.result.completed_jobs(), 200, "{}", r.result.summary());
        assert!(
            r.result.peak_in_flight > 1,
            "jobs must genuinely interleave: {}",
            r.result.summary()
        );
        assert_eq!(r.result.peak_in_flight_per_pool, vec![8]);
        assert!(
            r.result.queued_admissions() > 0,
            "capacity must bind: {}",
            r.result.summary()
        );
    }
}

#[test]
fn multi_pool_sweeps_merge_deterministically() {
    use spoton::config::{EvictionPlanCfg, PlacementPolicyCfg, PoolCfg};
    let exp = Experiment::table1()
        .named("fleet-determinism")
        .transparent(SimDuration::from_mins(15))
        .pool(PoolCfg::named("storm").price_factor(0.9).eviction(
            EvictionPlanCfg::Poisson { mean: SimDuration::from_mins(30) },
        ))
        .pool(PoolCfg::named("stable").price_factor(1.1))
        .placement(PlacementPolicyCfg::EvictionAware { penalty: 4.0 });
    let sweep = exp.sweep().seed_range(0, 12);
    let t1 = sweep.clone().threads(1).run().unwrap();
    let t8 = sweep.clone().threads(8).run().unwrap();
    assert_eq!(digests(&t1), digests(&t8));
    // per-pool attribution survives the reduced metrics level
    assert!(t1.iter().all(|r| r.result.pool_stats.len() == 2));
    let d = distribution::summarize("fleet-determinism", &t1);
    assert_eq!(d.pools.len(), 2);
}
