//! Fleet placement suite: cross-pool failover, placement-policy
//! comparison on seeded eviction storms, billing-attribution invariants,
//! and the 1-pool `StickyPool` fleet's byte-identity with the legacy
//! single-scale-set loop.

use spoton::config::{EvictionPlanCfg, PlacementPolicyCfg, PoolCfg};
use spoton::metrics::EventKind;
use spoton::sim::experiment::Experiment;
use spoton::sim::legacy;
use spoton::simclock::SimDuration;

/// The three-pool storm fleet the `fleet_failover` example demonstrates:
/// a cheap but heavily contended pool (frequent evictions, slow
/// replacements), a pricier stable pool, and a mid-price mid-churn pool.
fn storm_fleet(exp: Experiment) -> Experiment {
    exp.pool(
        PoolCfg::named("east-contended")
            .price_factor(0.9)
            .eviction(EvictionPlanCfg::Fixed {
                interval: SimDuration::from_mins(5),
            })
            .provisioning_delay(SimDuration::from_mins(20)),
    )
    .pool(
        PoolCfg::named("south-balanced")
            .price_factor(1.0)
            .eviction(EvictionPlanCfg::Poisson {
                mean: SimDuration::from_mins(45),
            })
            .provisioning_delay(SimDuration::from_secs(180)),
    )
    .pool(
        // on-demand-like reliability at a markup: never reclaimed
        PoolCfg::named("west-stable")
            .price_factor(1.2)
            .provisioning_delay(SimDuration::from_secs(90)),
    )
}

fn storm_experiment(policy: PlacementPolicyCfg) -> Experiment {
    storm_fleet(
        Experiment::table1()
            .named("storm")
            .transparent(SimDuration::from_mins(15))
            .seed(42),
    )
    .placement(policy)
}

#[test]
fn one_pool_sticky_fleet_matches_legacy_byte_for_byte() {
    // An explicit 1-pool fleet whose pool equals the cloud config must
    // reproduce the legacy single-scale-set loop exactly — the same
    // guarantee the equivalence suite pins for the implicit fleet.
    let eviction = EvictionPlanCfg::Fixed { interval: SimDuration::from_mins(90) };
    let exp = Experiment::table1()
        .named("one-pool")
        .eviction_every(SimDuration::from_mins(90))
        .transparent(SimDuration::from_mins(30))
        .pool(PoolCfg::named("pool-0").eviction(eviction))
        .placement(PlacementPolicyCfg::Sticky);

    let eng = exp.run_sleeper().expect("engine run");
    let mut store = exp.fresh_store();
    let mut factory = exp.sleeper_factory();
    let leg = legacy::run_reference(&exp.cfg, &mut store, &mut *factory)
        .expect("legacy run");

    assert_eq!(eng.completed, leg.completed);
    assert_eq!(eng.total, leg.total);
    assert_eq!(eng.evictions, leg.evictions);
    assert_eq!(eng.instances, leg.instances);
    assert_eq!(eng.termination_ok, leg.termination_ok);
    assert_eq!(eng.restores, leg.restores);
    assert_eq!(eng.lost_steps, leg.lost_steps);
    assert_eq!(eng.compute_cost.to_bits(), leg.compute_cost.to_bits());
    assert_eq!(eng.storage_cost.to_bits(), leg.storage_cost.to_bits());
    assert_eq!(eng.final_fingerprint, leg.final_fingerprint);
    assert_eq!(eng.stage_times, leg.stage_times);
    // identical (time, kind) timeline — no placement events leak into
    // single-pool runs
    assert_eq!(eng.timeline.events().len(), leg.timeline.events().len());
    for (a, b) in eng.timeline.events().iter().zip(leg.timeline.events()) {
        assert_eq!(a.at, b.at);
        assert_eq!(a.kind, b.kind);
    }
    assert_eq!(eng.timeline.count(EventKind::ReplacementRequested), 0);
    assert_eq!(eng.timeline.count(EventKind::PlacementDecided), 0);
}

#[test]
fn cross_pool_failover_moves_to_stable_pool() {
    let r = Experiment::table1()
        .named("failover")
        .transparent(SimDuration::from_mins(15))
        .pool(PoolCfg::named("storm").eviction(EvictionPlanCfg::Fixed {
            interval: SimDuration::from_mins(30),
        }))
        .pool(PoolCfg::named("stable").price_factor(1.2))
        .placement(PlacementPolicyCfg::EvictionAware { penalty: 4.0 })
        .run_sleeper()
        .unwrap();

    assert!(r.completed, "{}", r.summary());
    assert_eq!(r.pool_stats.len(), 2);
    let storm = &r.pool_stats[0];
    let stable = &r.pool_stats[1];
    // first instance lands in the cheap storm pool, gets evicted once,
    // and the policy fails over to the stable pool for the rest
    assert_eq!(storm.pool, "storm");
    assert_eq!(storm.launches, 1);
    assert_eq!(storm.evictions, 1);
    assert_eq!(stable.pool, "stable");
    assert_eq!(stable.launches, 1);
    assert_eq!(stable.evictions, 0);
    assert_eq!(r.instances, 2);
    assert_eq!(r.evictions, 1);

    // the placement chain is on the timeline, one request + decision per
    // launch, and the failover decision names the stable pool
    assert_eq!(
        r.timeline.count(EventKind::ReplacementRequested),
        r.instances as usize
    );
    assert_eq!(
        r.timeline.count(EventKind::PlacementDecided),
        r.instances as usize
    );
    let last_placement = r
        .timeline
        .events()
        .iter()
        .rev()
        .find(|e| e.kind == EventKind::PlacementDecided)
        .unwrap();
    assert!(
        last_placement.detail.contains("stable"),
        "failover placement: {}",
        last_placement.detail
    );
    assert!(r.timeline.is_monotone());
}

#[test]
fn billing_attribution_sums_to_run_cost() {
    for policy in [
        PlacementPolicyCfg::Sticky,
        PlacementPolicyCfg::CheapestSpot,
        PlacementPolicyCfg::EvictionAware { penalty: 4.0 },
    ] {
        let r = storm_experiment(policy.clone()).run_sleeper().unwrap();
        let attributed: f64 =
            r.pool_stats.iter().map(|p| p.compute_cost).sum();
        assert!(
            (attributed - r.compute_cost).abs() < 1e-9,
            "{}: pool attribution {attributed} != compute {}",
            policy.label(),
            r.compute_cost
        );
        let launches: u32 = r.pool_stats.iter().map(|p| p.launches).sum();
        assert_eq!(launches, r.instances, "{}", policy.label());
        let evictions: u32 = r.pool_stats.iter().map(|p| p.evictions).sum();
        assert_eq!(evictions, r.evictions, "{}", policy.label());
    }
}

#[test]
fn eviction_aware_beats_sticky_on_seeded_storm() {
    // Sticky rides the cheap contended pool through every eviction
    // (paying a 20-minute replacement each time, ballooning makespan and
    // the prorated storage bill); eviction-aware abandons it after being
    // burned and finishes hours earlier and cheaper.
    let sticky = storm_experiment(PlacementPolicyCfg::Sticky)
        .run_sleeper()
        .unwrap();
    let aware =
        storm_experiment(PlacementPolicyCfg::EvictionAware { penalty: 4.0 })
            .run_sleeper()
            .unwrap();
    assert!(sticky.completed, "{}", sticky.summary());
    assert!(aware.completed, "{}", aware.summary());
    assert!(
        sticky.evictions > aware.evictions,
        "sticky {} vs aware {} evictions",
        sticky.evictions,
        aware.evictions
    );
    assert!(
        aware.total < sticky.total,
        "aware makespan {} must beat sticky {}",
        aware.total,
        sticky.total
    );
    assert!(
        aware.total_cost() < sticky.total_cost(),
        "aware ${:.4} must beat sticky ${:.4}",
        aware.total_cost(),
        sticky.total_cost()
    );
}

#[test]
fn cheapest_spot_always_picks_the_cheapest_pool() {
    let r = Experiment::table1()
        .named("cheapest")
        .transparent(SimDuration::from_mins(30))
        .pool(PoolCfg::named("pricey").price_factor(1.3))
        .pool(PoolCfg::named("bargain").price_factor(0.8))
        .placement(PlacementPolicyCfg::CheapestSpot)
        .run_sleeper()
        .unwrap();
    assert!(r.completed);
    // no evictions anywhere: the single launch goes to the bargain pool
    assert_eq!(r.pool_stats[0].launches, 0, "pricey pool unused");
    assert_eq!(r.pool_stats[1].launches, 1);
    assert!((r.pool_stats[1].compute_cost - r.compute_cost).abs() < 1e-12);
}

#[test]
fn multi_pool_runs_are_deterministic_given_seed() {
    let run = || {
        storm_experiment(PlacementPolicyCfg::EvictionAware { penalty: 4.0 })
            .run_sleeper()
            .unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.total, b.total);
    assert_eq!(a.evictions, b.evictions);
    assert_eq!(a.final_fingerprint, b.final_fingerprint);
    assert_eq!(a.pool_stats, b.pool_stats);
    assert_eq!(a.timeline.events().len(), b.timeline.events().len());
}
