//! Property tests over the experiment driver: whole-run invariants that
//! must hold for *every* scenario, not just the paper's eight.
//!
//! Random scenarios (eviction plan × checkpoint method × notice ×
//! intervals × seeds) are generated with the in-repo proptest framework;
//! each run is checked against coordinator invariants.

use spoton::sim::experiment::Experiment;
use spoton::simclock::SimDuration;
use spoton::util::proptest::{forall, shrink_none, Config};
use spoton::util::Prng;

/// Generate a random-but-plausible experiment.
fn gen_experiment(rng: &mut Prng) -> Experiment {
    let mut e = Experiment::table1()
        .named("prop")
        .seed(rng.next_u64())
        .deadline(SimDuration::from_hours(40));
    // eviction plan
    e = match rng.below(4) {
        0 => e, // none
        1 => e.eviction_every(SimDuration::from_mins(rng.range_u64(20, 180))),
        2 => e.eviction_poisson(SimDuration::from_mins(rng.range_u64(30, 240))),
        _ => {
            let n = rng.range_u64(1, 5);
            e.eviction_trace(
                (0..n)
                    .map(|_| SimDuration::from_mins(rng.range_u64(10, 120)))
                    .collect(),
            )
        }
    };
    // checkpoint method — bias toward protected configs so most runs
    // complete
    e = match rng.below(6) {
        0 => e.unprotected(),
        1 | 2 => e.app_native(),
        _ => e.transparent(SimDuration::from_mins(rng.range_u64(5, 45))),
    };
    // notice + image size perturbations
    e = e
        .notice(SimDuration::from_secs(rng.range_u64(5, 120)))
        .state_gib(0.5 + rng.f64() * 6.0);
    e
}

#[test]
fn prop_run_invariants() {
    forall(
        Config::default().cases(60),
        gen_experiment,
        shrink_none,
        |exp| {
            let r = exp.run_sleeper().map_err(|e| e.to_string())?;

            // 1. timeline is time-ordered
            if !r.timeline.is_monotone() {
                return Err("timeline not monotone".into());
            }
            // 2. instance count == evictions + 1 when completed
            if r.completed && r.instances != r.evictions + 1 {
                return Err(format!(
                    "instances {} != evictions {} + 1",
                    r.instances, r.evictions
                ));
            }
            // 3. completed runs account every stage; totals are the sum
            if r.completed {
                if r.stage_times.len() != 5 {
                    return Err(format!(
                        "{} stage times recorded",
                        r.stage_times.len()
                    ));
                }
                let sum: u64 = r
                    .stage_times
                    .iter()
                    .map(|(_, d)| d.as_millis())
                    .sum();
                if sum != r.total.as_millis() {
                    return Err(format!(
                        "stage sum {sum} != total {}",
                        r.total.as_millis()
                    ));
                }
            }
            // 4. no-eviction runs lose nothing and use one instance
            if r.evictions == 0
                && (r.lost_steps != 0 || r.instances != 1 || r.restores != 0)
            {
                return Err("loss without evictions".into());
            }
            // 5. costs are non-negative and compute>0
            if r.compute_cost <= 0.0 || r.storage_cost < 0.0 {
                return Err("implausible costs".into());
            }
            // 6. termination checkpoints only exist for transparent runs
            let transparent = matches!(
                exp.cfg.checkpoint,
                spoton::config::CheckpointMethodCfg::Transparent { .. }
            );
            if !transparent && (r.termination_ok + r.termination_failed) > 0 {
                return Err("termination ckpt under non-transparent".into());
            }
            // 7. app checkpoints only exist for app-native runs
            let app = matches!(
                exp.cfg.checkpoint,
                spoton::config::CheckpointMethodCfg::AppNative
            );
            if !app && r.app_ckpts > 0 {
                return Err("app ckpt under non-app policy".into());
            }
            // 8. completed protected runs end bit-exact vs the
            //    uninterrupted reference
            if r.completed {
                let base = Experiment::table1()
                    .spoton_off()
                    .run_sleeper()
                    .map_err(|e| e.to_string())?;
                if r.final_fingerprint != base.final_fingerprint {
                    return Err("final state diverged".into());
                }
            }
            // 9. deterministic replay
            let again = exp.run_sleeper().map_err(|e| e.to_string())?;
            if again.total != r.total
                || again.evictions != r.evictions
                || again.final_fingerprint != r.final_fingerprint
            {
                return Err("rerun not deterministic".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_restores_never_exceed_crash_point() {
    // For every eviction+restore pair in the timeline, the restored step
    // must be <= the max step reached before the eviction (no time
    // travel forward), and restore events only follow launches.
    forall(
        Config::default().cases(40).seed(0xBEEF),
        gen_experiment,
        shrink_none,
        |exp| {
            let r = exp.run_sleeper().map_err(|e| e.to_string())?;
            use spoton::metrics::EventKind;
            let mut last: Option<EventKind> = None;
            for ev in r.timeline.events() {
                if ev.kind == EventKind::RestoreFromCheckpoint {
                    if last != Some(EventKind::InstanceLaunch) {
                        return Err(format!(
                            "restore not preceded by launch (was {last:?})"
                        ));
                    }
                }
                last = Some(ev.kind);
            }
            // every eviction notice precedes an instance eviction
            let notices = r.timeline.count(EventKind::EvictionNotice);
            let evicted = r.timeline.count(EventKind::InstanceEvicted);
            if notices != evicted {
                return Err(format!(
                    "{notices} notices vs {evicted} evictions"
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_transparent_dominates_app_native() {
    // Under identical fixed-interval evictions, transparent-protected
    // total time never exceeds app-native total time by more than noise
    // (the paper's central comparison, generalized over intervals).
    forall(
        Config::default().cases(20).seed(0x5EED),
        |rng| rng.range_u64(30, 150),
        spoton::util::proptest::shrinks_u64,
        |&mins| {
            let app = Experiment::table1()
                .eviction_every(SimDuration::from_mins(mins))
                .app_native()
                .deadline(SimDuration::from_hours(30))
                .run_sleeper()
                .map_err(|e| e.to_string())?;
            let tr = Experiment::table1()
                .eviction_every(SimDuration::from_mins(mins))
                .transparent(SimDuration::from_mins(15))
                .deadline(SimDuration::from_hours(30))
                .run_sleeper()
                .map_err(|e| e.to_string())?;
            if !tr.completed {
                return Err("transparent DNF".into());
            }
            // allow 2% slack for checkpoint-pause overhead at sparse
            // evictions where app-native loses almost nothing
            let limit = (app.total.as_millis() as f64 * 1.02) as u64;
            if tr.total.as_millis() > limit {
                return Err(format!(
                    "transparent {} slower than app {} at {mins}min",
                    tr.total, app.total
                ));
            }
            Ok(())
        },
    );
}
