//! Integration: whole-system simulated runs across module boundaries
//! (config → experiment → driver → cloud + checkpoint engine + storage).

use spoton::config::ScenarioConfig;
use spoton::metrics::EventKind;
use spoton::sim::experiment::Experiment;
use spoton::simclock::SimDuration;
use spoton::storage::{NfsStore, SharedStore, TransferModel};

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!(
        "spoton-it-{tag}-{}-{:x}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos() as u64
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

#[test]
fn scenario_file_drives_a_full_run() {
    // the CLI path: TOML -> ScenarioConfig -> Experiment -> result
    let toml = r#"
name = "it-row7"
seed = 11
[workload]
kind = "sleeper"
[eviction]
plan = "fixed"
interval_mins = 60
[checkpoint]
method = "transparent"
interval_mins = 30
"#;
    let cfg = ScenarioConfig::from_str_toml(toml).unwrap();
    let r = Experiment { cfg }.run_sleeper().unwrap();
    assert!(r.completed);
    assert!(r.evictions >= 2);
    assert!(r.termination_ok > 0);
    assert!(r.timeline.is_monotone());
}

#[test]
fn all_eight_table1_rows_reproduce_the_paper_shape() {
    let rows = spoton::report::paper_rows();
    let mut totals = std::collections::HashMap::new();
    for row in &rows {
        let r = row.experiment().run_sleeper().unwrap();
        assert!(r.completed, "{} did not finish", row.id);
        totals.insert(row.id, r.total);
    }
    let t = |id: &str| totals[id].as_millis() as f64;
    // row1 is exactly the calibration
    assert_eq!(totals["row1"].hms(), "3:03:26");
    // overhead ~1%
    assert!((t("row2") / t("row1") - 1.0) < 0.02);
    // app-native degrades with eviction frequency
    assert!(t("row4") > t("row3"));
    assert!(t("row3") > t("row1") * 1.05);
    // transparent stays within 8% of baseline
    for id in ["row5", "row6", "row7", "row8"] {
        assert!(
            t(id) < t("row1") * 1.08,
            "{id} drifted too far from baseline"
        );
        // and always beats the matching app-native row
    }
    assert!(t("row5") < t("row3"));
    assert!(t("row7") < t("row4"));
}

#[test]
fn nfs_backed_run_survives_share_reattach() {
    // run against a real directory; verify checkpoints really land on
    // disk and the share contents outlive the run (what a replacement
    // instance would mount)
    let dir = tmpdir("nfs");
    let model = TransferModel {
        bandwidth_mib_s: 250.0,
        latency: SimDuration::from_millis(20),
    };
    let exp = Experiment::table1()
        .named("nfs-run")
        .eviction_every(SimDuration::from_mins(75))
        .transparent(SimDuration::from_mins(20));
    {
        let mut store = NfsStore::open(&dir, model, Some(100.0)).unwrap();
        let mut factory = exp.sleeper_factory();
        let r = spoton::sim::SimDriver::new(&exp.cfg, &mut store)
            .run(&mut *factory)
            .unwrap();
        assert!(r.completed);
        assert!(r.evictions >= 2);
    }
    // reattach: a fresh NfsStore over the same root sees the checkpoints
    let mut store2 = NfsStore::open(&dir, model, Some(100.0)).unwrap();
    let latest =
        spoton::checkpoint::CheckpointStore::latest_valid(&mut store2, None)
            .unwrap();
    assert!(latest.is_some(), "checkpoints must persist on the share");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn local_scratch_is_never_needed_across_restarts() {
    // the eviction wipes instance-local state; the run must complete
    // regardless (everything restart-critical lives on the share)
    let mut scratch = spoton::storage::LocalScratch::new();
    scratch.put("tmp/intermediate", b"cache");
    let r = Experiment::table1()
        .eviction_every(SimDuration::from_mins(60))
        .transparent(SimDuration::from_mins(15))
        .run_sleeper()
        .unwrap();
    scratch.wipe(); // what the eviction does
    assert!(r.completed);
    assert!(scratch.is_empty());
}

#[test]
fn starvation_detected_not_hung() {
    // boundary-only app checkpoints + lifetime < longest stage: the
    // driver must terminate via the deadline, not loop forever
    let r = Experiment::table1()
        .named("starved")
        .eviction_every(SimDuration::from_mins(30))
        .app_native()
        .app_milestones(1)
        .deadline(SimDuration::from_hours(8))
        .run_sleeper()
        .unwrap();
    assert!(!r.completed);
    assert_eq!(r.timeline.count(EventKind::Aborted), 1);
    assert!(r.total >= SimDuration::from_hours(8));
    // it kept trying the whole time
    assert!(r.evictions >= 10);
}

#[test]
fn poisson_storms_complete_with_transparent_protection() {
    for seed in [1u64, 2, 3, 4, 5] {
        let r = Experiment::table1()
            .eviction_poisson(SimDuration::from_mins(45))
            .transparent(SimDuration::from_mins(15))
            .deadline(SimDuration::from_hours(30))
            .seed(seed)
            .run_sleeper()
            .unwrap();
        assert!(r.completed, "seed {seed}: {}", r.summary());
        // resumed state must match the uninterrupted fingerprint
        let base = Experiment::table1()
            .spoton_off()
            .run_sleeper()
            .unwrap();
        assert_eq!(
            r.final_fingerprint, base.final_fingerprint,
            "seed {seed} diverged"
        );
    }
}

#[test]
fn billing_reconciles_instance_uptimes() {
    let r = Experiment::table1()
        .eviction_every(SimDuration::from_mins(90))
        .transparent(SimDuration::from_mins(30))
        .run_sleeper()
        .unwrap();
    // sum of booked instance-hours x price == compute cost
    let total: f64 = r
        .invoice
        .items
        .iter()
        .filter(|i| i.resource.starts_with("vm/"))
        .map(|i| i.amount)
        .sum();
    assert!((total - r.compute_cost).abs() < 1e-9);
    // storage line exists for protected runs
    assert!(r
        .invoice
        .items
        .iter()
        .any(|i| i.resource.starts_with("storage/")));
}

#[test]
fn eviction_trace_replay_is_exact() {
    // a trace with two eviction offsets: exactly two evictions happen,
    // the third instance runs to completion
    let r = Experiment::table1()
        .eviction_trace(vec![
            SimDuration::from_mins(50),
            SimDuration::from_mins(40),
        ])
        .transparent(SimDuration::from_mins(15))
        .run_sleeper()
        .unwrap();
    assert!(r.completed);
    assert_eq!(r.evictions, 2);
    assert_eq!(r.instances, 3);
}
