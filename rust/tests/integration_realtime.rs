//! Integration: the real-time coordinator against a real localhost IMDS
//! HTTP endpoint and a real directory-backed NFS share — the full
//! wire-level path a deployment would exercise, at second scale.

use spoton::cloud::imds_http::ImdsHttp;
use spoton::config::CheckpointMethodCfg;
use spoton::coordinator::realtime::{
    RealtimeCoordinator, RealtimeOutcome, RealtimeParams, Transport,
};
use spoton::coordinator::CheckpointPolicy;
use spoton::httpd::http_post;
use spoton::metrics::EventKind;
use spoton::simclock::SimDuration;
use spoton::storage::{NfsStore, TransferModel};
use spoton::workload::sleeper::{Sleeper, SleeperCfg};
use spoton::workload::Workload;
use std::time::Duration;

fn share(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!(
        "spoton-rt-{tag}-{}-{:x}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos() as u64
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn store_at(dir: &std::path::Path) -> NfsStore {
    NfsStore::open(
        dir,
        TransferModel {
            bandwidth_mib_s: 250.0,
            latency: SimDuration::from_millis(1),
        },
        None,
    )
    .unwrap()
}

/// A sleeper slowed down so wall-clock events can interleave.
struct SlowSleeper {
    inner: Sleeper,
    delay: Duration,
}

impl Workload for SlowSleeper {
    fn name(&self) -> &str {
        self.inner.name()
    }
    fn num_stages(&self) -> u32 {
        self.inner.num_stages()
    }
    fn stage_label(&self, s: u32) -> String {
        self.inner.stage_label(s)
    }
    fn stage_steps(&self, s: u32) -> u64 {
        self.inner.stage_steps(s)
    }
    fn progress(&self) -> spoton::workload::Progress {
        self.inner.progress()
    }
    fn is_done(&self) -> bool {
        self.inner.is_done()
    }
    fn step(&mut self) -> anyhow::Result<spoton::workload::StepOutcome> {
        std::thread::sleep(self.delay);
        self.inner.step()
    }
    fn snapshot(&self) -> anyhow::Result<spoton::workload::Snapshot> {
        self.inner.snapshot()
    }
    fn restore(&mut self, b: &[u8]) -> anyhow::Result<()> {
        self.inner.restore(b)
    }
    fn app_snapshot(
        &self,
    ) -> anyhow::Result<Option<spoton::workload::Snapshot>> {
        self.inner.app_snapshot()
    }
    fn app_restore(&mut self, b: &[u8]) -> anyhow::Result<()> {
        self.inner.app_restore(b)
    }
    fn fingerprint(&self) -> u64 {
        self.inner.fingerprint()
    }
}

#[test]
fn evict_over_http_then_resume_to_bit_exact_completion() {
    let imds = ImdsHttp::spawn(30).unwrap();
    let dir = share("evict");
    let policy = || {
        CheckpointPolicy::new(CheckpointMethodCfg::Transparent {
            interval: SimDuration::from_secs(3600), // periodic via params
        })
    };

    // reference: uninterrupted
    let mut reference = Sleeper::new(SleeperCfg::small(), 9);
    while !reference.is_done() {
        reference.step().unwrap();
    }

    // attempt 1 on vm-0, ~2ms per step => ~400ms runtime; inject the
    // eviction over real HTTP after 60 ms
    let base = imds.base_url();
    let injector = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(60));
        let (status, body) = http_post(
            &format!("{base}/admin/simulate-eviction?resource=vm-0"),
            "",
        )
        .unwrap();
        assert_eq!(status, 200, "{body}");
    });

    let mut w = SlowSleeper {
        inner: Sleeper::new(SleeperCfg::small(), 9),
        delay: Duration::from_millis(2),
    };
    let mut store = store_at(&dir);
    let mut coord = RealtimeCoordinator::new(
        "vm-0",
        policy(),
        RealtimeParams {
            poll_interval: Duration::from_millis(10),
            periodic_interval: Some(Duration::from_millis(50)),
            run_timeout: Duration::from_secs(60),
            keep_checkpoints: 3,
        },
    );
    let out = coord
        .run(
            &mut w,
            &mut store,
            &Transport::Http { events_url: imds.events_url() },
        )
        .unwrap();
    injector.join().unwrap();
    assert_eq!(
        out,
        RealtimeOutcome::Evicted { termination_checkpoint: true },
        "timeline:\n{}",
        coord.timeline
    );
    assert!(coord.timeline.count(EventKind::EvictionNotice) == 1);
    let steps_at_eviction = w.progress().total_steps;
    assert!(steps_at_eviction > 0, "eviction landed before any work");
    assert!(!w.is_done(), "eviction must interrupt mid-run");

    // attempt 2 on vm-1 (replacement): restore from the share, finish
    let mut w2 = SlowSleeper {
        inner: Sleeper::new(SleeperCfg::small(), 9),
        delay: Duration::from_millis(0),
    };
    let mut store2 = store_at(&dir); // fresh mount, same share
    let mut coord2 = RealtimeCoordinator::new(
        "vm-1",
        policy(),
        RealtimeParams {
            poll_interval: Duration::from_millis(50),
            periodic_interval: Some(Duration::from_secs(3600)),
            run_timeout: Duration::from_secs(60),
            keep_checkpoints: 3,
        },
    );
    let out2 = coord2
        .run(
            &mut w2,
            &mut store2,
            &Transport::Http { events_url: imds.events_url() },
        )
        .unwrap();
    assert_eq!(out2, RealtimeOutcome::Completed, "{}", coord2.timeline);
    assert_eq!(coord2.timeline.count(EventKind::RestoreFromCheckpoint), 1);
    // the termination checkpoint captured >= the evicted progress's state;
    // resumed execution must converge to the uninterrupted fingerprint
    assert_eq!(w2.fingerprint(), reference.fingerprint());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn app_native_resume_over_http_loses_mid_milestone_work() {
    let imds = ImdsHttp::spawn(30).unwrap();
    let dir = share("app");
    let policy =
        || CheckpointPolicy::new(CheckpointMethodCfg::AppNative);

    let base = imds.base_url();
    let injector = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(60));
        http_post(
            &format!("{base}/admin/simulate-eviction?resource=vm-0"),
            "",
        )
        .unwrap();
    });

    let mut w = SlowSleeper {
        inner: Sleeper::new(SleeperCfg::small(), 10),
        delay: Duration::from_millis(2),
    };
    let mut store = store_at(&dir);
    let mut coord = RealtimeCoordinator::new(
        "vm-0",
        policy(),
        RealtimeParams {
            poll_interval: Duration::from_millis(10),
            periodic_interval: None,
            run_timeout: Duration::from_secs(60),
            keep_checkpoints: 5,
        },
    );
    let out = coord
        .run(
            &mut w,
            &mut store,
            &Transport::Http { events_url: imds.events_url() },
        )
        .unwrap();
    injector.join().unwrap();
    // app-native cannot take a termination checkpoint (paper §III-A)
    assert_eq!(
        out,
        RealtimeOutcome::Evicted { termination_checkpoint: false }
    );
    let evicted_at = w.progress().total_steps;

    // replacement restores from the last *milestone*, not the eviction
    // point
    let mut w2 = SlowSleeper {
        inner: Sleeper::new(SleeperCfg::small(), 10),
        delay: Duration::from_millis(0),
    };
    let mut store2 = store_at(&dir);
    let mut coord2 = RealtimeCoordinator::new(
        "vm-1",
        policy(),
        RealtimeParams {
            poll_interval: Duration::from_millis(100),
            periodic_interval: None,
            run_timeout: Duration::from_secs(60),
            keep_checkpoints: 5,
        },
    );
    // read restore step from the timeline by probing the share first
    let latest = spoton::checkpoint::CheckpointStore::latest_valid(
        &mut store2,
        Some(false),
    )
    .unwrap();
    let out2 = coord2
        .run(
            &mut w2,
            &mut store2,
            &Transport::Http { events_url: imds.events_url() },
        )
        .unwrap();
    assert_eq!(out2, RealtimeOutcome::Completed);
    if let Some(m) = latest {
        assert!(
            m.total_steps <= evicted_at,
            "milestone ckpt ({}) cannot be newer than the eviction point \
             ({evicted_at})",
            m.total_steps
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}
