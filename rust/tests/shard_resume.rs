//! Failure handling in the sharded sweep runner: torn artifacts are
//! rejected (never merged), the checkpointed manifest marks the shard
//! missing, a resume re-runs exactly that shard, and shards that keep
//! failing land in the dead-letter list with their full replayable cell
//! list. Workers are real OS processes (the `spoton` binary re-invoked),
//! faults are injected via the `SPOTON_TEST_*` hooks in
//! `spoton sweep-worker`.

use spoton::config::ScenarioConfig;
use spoton::sim::shard::{
    artifact_path, verify_artifact, SeedStream, ShardPlan, ShardRunner,
};

const SCENARIO: &str = r#"
name = "shard-resume"
deadline_mins = 1800

[workload]
kind = "sleeper"
ks = [33, 55]
stage_secs = [60, 120]

[eviction]
plan = "poisson"
mean_mins = 45

[checkpoint]
method = "transparent"
interval_mins = 15
"#;

const EXE: &str = env!("CARGO_BIN_EXE_spoton");

fn tmp(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!(
        "spoton-resume-{tag}-{}-{}",
        std::process::id(),
        spoton::util::next_seq()
    ))
}

fn plan(run_id: &str, seeds: usize, shards: usize) -> ShardPlan {
    let cfg = ScenarioConfig::from_str_toml(SCENARIO).unwrap();
    ShardPlan::new(
        run_id,
        SeedStream::contiguous(0, seeds),
        &["fixed".to_string()],
        &cfg,
        SCENARIO,
        shards,
    )
    .unwrap()
}

#[test]
fn partial_artifact_is_rejected_and_resume_reruns_exactly_that_shard() {
    let plan = plan("partial", 4, 2);
    let dir = tmp("partial");
    // Shard 1's worker writes half an artifact straight to the final
    // path (a kill mid-write with no atomic rename) and dies; no
    // retries, so it dead-letters immediately.
    let broken = ShardRunner::new(plan.clone(), &dir, EXE)
        .retries(0)
        .env("SPOTON_TEST_PARTIAL_SHARDS", "1");
    broken.init(SCENARIO).unwrap();
    let out = broken.run().unwrap();
    assert!(out.merged.is_none(), "a torn artifact must never merge");
    assert_eq!(out.dead_letter.len(), 1);
    assert_eq!(out.dead_letter[0].shard, 1);
    assert_eq!(out.dead_letter[0].attempts, 1);
    assert_eq!(
        out.dead_letter[0].cells.len(),
        plan.shard_range(1).len(),
        "dead letter must carry the full replayable cell list"
    );

    // the torn file is really on disk — and really rejected
    let torn = artifact_path(&dir, 1);
    assert!(torn.exists(), "fault injection should leave a partial file");
    assert!(verify_artifact(&dir, &plan, 1).is_err());
    assert!(verify_artifact(&dir, &plan, 0).is_ok());

    // the checkpointed manifest marks shard 1 missing, shard 0 done,
    // and records the dead letter
    let manifest_text =
        std::fs::read_to_string(dir.join("MANIFEST.json")).unwrap();
    let manifest = spoton::json::parse(&manifest_text).unwrap();
    let completed = manifest.req_array("completed").unwrap();
    assert_eq!(completed.len(), 1);
    assert_eq!(completed[0].req_u64("shard").unwrap(), 0);
    let dead = manifest.req_array("dead_letter").unwrap();
    assert_eq!(dead.len(), 1);
    assert_eq!(dead[0].req_u64("shard").unwrap(), 1);

    // resume with the fault cleared: shard 0 is reused, exactly shard 1
    // re-runs, and the re-written artifact verifies
    let resumed = ShardRunner::new(plan.clone(), &dir, EXE);
    let out2 = resumed.run().unwrap();
    assert_eq!(out2.reused, vec![0]);
    assert_eq!(out2.ran, vec![1]);
    assert!(out2.dead_letter.is_empty());
    assert!(out2.merged.is_some(), "resume must complete the sweep");
    assert!(verify_artifact(&dir, &plan, 1).is_ok());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn persistent_failures_exhaust_bounded_retries_then_dead_letter() {
    let plan = plan("retries", 2, 1);
    let dir = tmp("retries");
    let runner = ShardRunner::new(plan.clone(), &dir, EXE)
        .retries(1)
        .env("SPOTON_TEST_FAIL_SHARDS", "0");
    runner.init(SCENARIO).unwrap();
    let out = runner.run().unwrap();
    assert!(out.merged.is_none());
    assert!(out.ran.is_empty());
    assert_eq!(out.dead_letter.len(), 1);
    let d = &out.dead_letter[0];
    assert_eq!(d.shard, 0);
    assert_eq!(d.attempts, 2, "retries(1) = first attempt + one retry");
    assert!(d.reason.contains("exited"), "{}", d.reason);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupted_completed_artifact_is_detected_and_rerun_on_resume() {
    let plan = plan("corrupt", 4, 2);
    let dir = tmp("corrupt");
    let runner = ShardRunner::new(plan.clone(), &dir, EXE).procs(2);
    runner.init(SCENARIO).unwrap();
    assert!(runner.run().unwrap().merged.is_some());
    let merged_bytes = std::fs::read(dir.join("MERGED.json")).unwrap();

    // corrupt shard 1's checkpointed artifact behind the manifest's back
    let path = artifact_path(&dir, 1);
    let bytes = std::fs::read(&path).unwrap();
    std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();

    // resume: the recorded completion no longer matches the disk, so
    // exactly shard 1 is marked missing and re-run — and the merge comes
    // back byte-identical
    let out = ShardRunner::new(plan.clone(), &dir, EXE).run().unwrap();
    assert_eq!(out.reused, vec![0]);
    assert_eq!(out.ran, vec![1]);
    assert!(out.merged.is_some());
    assert_eq!(std::fs::read(dir.join("MERGED.json")).unwrap(), merged_bytes);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn a_run_directory_refuses_a_different_plan() {
    let dir = tmp("mismatch");
    let first = ShardRunner::new(plan("mismatch", 4, 2), &dir, EXE);
    first.init(SCENARIO).unwrap();
    // same directory, different work (more seeds) — init must bail
    // rather than let artifacts from two studies mix
    let other = ShardRunner::new(plan("mismatch", 6, 2), &dir, EXE);
    let err = other.init(SCENARIO).unwrap_err();
    assert!(format!("{err:#}").contains("different plan"), "{err:#}");
    let _ = std::fs::remove_dir_all(&dir);
}
