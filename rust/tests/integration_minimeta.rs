//! Integration: the full three-layer stack (Rust coordinator → PJRT →
//! AOT JAX/Pallas kernels) under simulated evictions.
//!
//! Gated on `artifacts/manifest.json` (run `make artifacts` first); each
//! test prints a skip note instead of failing when artifacts are absent
//! so `cargo test` stays meaningful in artifact-less checkouts.

use spoton::runtime::Runtime;
use spoton::sim::experiment::Experiment;
use spoton::simclock::SimDuration;
use std::cell::RefCell;
use std::path::PathBuf;
use std::rc::Rc;

fn runtime() -> Option<Rc<RefCell<Runtime>>> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Rc::new(RefCell::new(Runtime::load(&dir).unwrap())))
}

/// Shrink the workload so each run is a few seconds of wall time while
/// still making hundreds of PJRT calls.
fn small(mut e: Experiment) -> Experiment {
    e.cfg.workload.total_reads = 4 * 1024;
    e.cfg.workload.denoise_sweeps = 4;
    e
}

#[test]
fn evicted_minimeta_matches_uninterrupted_assembly() {
    let Some(rt) = runtime() else { return };
    let baseline = small(Experiment::table1().named("base").spoton_off())
        .run_minimeta(rt.clone())
        .unwrap();
    assert!(baseline.completed);

    let evicted = small(
        Experiment::table1()
            .named("evicted")
            .eviction_every(SimDuration::from_mins(45))
            .transparent(SimDuration::from_mins(15)),
    )
    .run_minimeta(rt)
    .unwrap();
    assert!(evicted.completed);
    assert!(evicted.evictions >= 2, "{}", evicted.summary());
    assert_eq!(
        baseline.final_fingerprint, evicted.final_fingerprint,
        "assembly state diverged across evictions"
    );
}

#[test]
fn app_native_minimeta_redoes_kernel_work() {
    let Some(rt) = runtime() else { return };
    let baseline = small(Experiment::table1().named("base").spoton_off())
        .run_minimeta(rt.clone())
        .unwrap();
    let app = small(
        Experiment::table1()
            .named("app")
            .eviction_every(SimDuration::from_mins(45))
            .app_native(),
    )
    .run_minimeta(rt)
    .unwrap();
    assert!(app.completed);
    assert!(app.lost_steps > 0, "app-native must lose milestone work");
    assert!(app.total > baseline.total);
    // even so, the final assembly is the same computation
    assert_eq!(baseline.final_fingerprint, app.final_fingerprint);
}

#[test]
fn minimeta_checkpoints_round_trip_through_real_nfs() {
    let Some(rt) = runtime() else { return };
    let dir = std::env::temp_dir().join(format!(
        "spoton-mm-nfs-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let r = small(
        Experiment::table1()
            .named("mm-nfs")
            .eviction_every(SimDuration::from_mins(60))
            .transparent(SimDuration::from_mins(20)),
    )
    .run_minimeta_on_nfs(rt, &dir)
    .unwrap();
    assert!(r.completed);
    assert!(r.evictions >= 1);
    // the share holds real files with real checksummed payloads
    let mut store = spoton::storage::NfsStore::open(
        &dir,
        spoton::storage::TransferModel {
            bandwidth_mib_s: 250.0,
            latency: SimDuration::from_millis(20),
        },
        None,
    )
    .unwrap();
    let latest =
        spoton::checkpoint::CheckpointStore::latest_valid(&mut store, None)
            .unwrap()
            .expect("checkpoint on share");
    let (payload, _) = spoton::checkpoint::CheckpointStore::fetch_payload(
        &mut store,
        &latest,
    )
    .unwrap();
    assert!(!payload.is_empty());
    let _ = std::fs::remove_dir_all(&dir);
}
