//! Lint fixture: malformed allow markers — each is an A1 finding and
//! suppresses nothing.

pub fn missing_reason(x: Option<u32>) -> u32 {
    // spoton-lint: allow(D3)
    x.unwrap() // line 6: D3 — marker above is invalid (no reason)
}

pub fn empty_reason(y: Option<u32>) -> u32 {
    // spoton-lint: allow(D3, reason = "")
    y.unwrap() // line 11: D3 — empty reason does not count
}

pub fn unknown_rule(z: Option<u32>) -> u32 {
    // spoton-lint: allow(D9, reason = "no such rule")
    z.unwrap() // line 16: D3 — unknown rule id
}
