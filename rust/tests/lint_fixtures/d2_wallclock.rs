//! Lint fixture: D2 — wall-clock and environment reads. Each violating
//! line carries exactly one trigger so the golden lines stay exact.

pub fn wall_clock() {
    let _t = std::time::Instant::now(); // line 5: D2 (Instant)
}

pub fn env_read() -> Option<String> {
    std::env::var("HOME").ok() // line 9: D2 (env::var)
}

pub fn thread_name() -> bool {
    std::thread::current().name().is_some() // line 13: D2 (thread::current)
}

#[cfg(test)]
mod tests {
    #[test]
    fn wall_clock_in_tests_is_fine() {
        let _ = std::time::Instant::now(); // exempt: test region
    }
}
