//! Lint fixture: D1 — unordered containers in an ordered path.
//! Scanned by `tests/lint_engine.rs` under a synthetic digest-path name;
//! the repo walker skips this directory, so these deliberate violations
//! never reach the baseline.

use std::collections::HashMap; // line 6: D1
use std::collections::BTreeMap;

pub fn digest_costs(costs: &HashMap<String, f64>) -> f64 {
    // iteration order leaks into the sum's rounding
    costs.values().sum()
}

pub fn ordered_is_fine(costs: &BTreeMap<String, f64>) -> f64 {
    costs.values().sum()
}
