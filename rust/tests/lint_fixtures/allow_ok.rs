//! Lint fixture: well-formed allow markers (standalone and trailing).

pub fn standalone_marker(x: Option<u32>) -> u32 {
    // spoton-lint: allow(D3, reason = "fixture: invariant set by caller")
    x.unwrap() // line 5: suppressed by the marker on line 4
}

pub fn trailing_marker(y: Option<u32>) -> u32 {
    y.unwrap() // spoton-lint: allow(D3, reason = "fixture: same-line allow")
}

pub fn not_covered(z: Option<u32>) -> u32 {
    z.unwrap() // line 13: D3 — no marker reaches this line
}
