//! Lint fixture: D4 — truncating casts in seed/index math.

pub fn truncates(seed: u64) -> u32 {
    seed as u32 // line 4: D4
}

pub fn widening_is_fine(cell: u32) -> u64 {
    cell as u64
}

pub fn float_is_fine(x: u64) -> f64 {
    x as f64
}
