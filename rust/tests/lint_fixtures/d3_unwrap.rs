//! Lint fixture: D3 — panicking unwrap/expect in library paths.

pub fn library_panics(x: Option<u32>, y: Option<u32>) -> u32 {
    let a = x.unwrap(); // line 4: D3
    let b = y.expect("present"); // line 5: D3
    a + b
}

struct Parser;
impl Parser {
    fn expect(&mut self, _b: u8) -> Result<(), ()> {
        Ok(())
    }
    fn run(&mut self) {
        // a user-defined `self.expect(…)` method is NOT Option::expect
        self.expect(b'{').ok();
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_in_tests_is_fine() {
        let v: Option<u32> = Some(1);
        assert_eq!(v.unwrap(), 1); // exempt: test region
    }
}
