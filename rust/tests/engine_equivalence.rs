//! Equivalence suite: the event-driven engine must reproduce the legacy
//! imperative loop's `RunResult` **exactly** — completion, durations,
//! eviction/checkpoint/restore counts, billing (bitwise f64), stage
//! times, `final_fingerprint`, and the timeline's full
//! (time, kind, detail) sequence — on every Table I scenario and across
//! seeded eviction/checkpoint sweeps.
//!
//! Every detail string is compared verbatim, including the
//! `EvictionNotice` event ids: the metadata service issues them from a
//! per-service counter (not a process-global sequence), so any two runs
//! of the same scenario — engine or legacy, whatever ran before them in
//! the process — produce identical timelines byte for byte.

use spoton::sim::RunResult;
use spoton::sim::experiment::Experiment;
use spoton::sim::legacy;
use spoton::simclock::SimDuration;
use spoton::util::proptest::{forall, shrink_none, Config};
use spoton::util::Prng;

/// Run through the engine (the production path: `SimDriver::run`).
fn run_engine(exp: &Experiment) -> RunResult {
    exp.run_sleeper().expect("engine run")
}

/// Run through the frozen legacy loop on an identical fresh share.
fn run_legacy(exp: &Experiment) -> RunResult {
    let mut store = exp.fresh_store();
    let mut factory = exp.sleeper_factory();
    legacy::run_reference(&exp.cfg, &mut store, &mut *factory)
        .expect("legacy run")
}

/// Field-by-field equality, with a diagnostic label.
fn assert_equivalent(label: &str, exp: &Experiment) {
    let eng = run_engine(exp);
    let leg = run_legacy(exp);

    assert_eq!(eng.completed, leg.completed, "{label}: completed");
    assert_eq!(eng.total, leg.total, "{label}: total");
    assert_eq!(eng.notices, leg.notices, "{label}: notices");
    assert_eq!(eng.evictions, leg.evictions, "{label}: evictions");
    assert_eq!(eng.instances, leg.instances, "{label}: instances");
    assert_eq!(
        eng.periodic_ckpts, leg.periodic_ckpts,
        "{label}: periodic_ckpts"
    );
    assert_eq!(
        eng.termination_ok, leg.termination_ok,
        "{label}: termination_ok"
    );
    assert_eq!(
        eng.termination_failed, leg.termination_failed,
        "{label}: termination_failed"
    );
    assert_eq!(eng.app_ckpts, leg.app_ckpts, "{label}: app_ckpts");
    assert_eq!(eng.restores, leg.restores, "{label}: restores");
    assert_eq!(eng.lost_steps, leg.lost_steps, "{label}: lost_steps");
    assert_eq!(
        eng.compute_cost.to_bits(),
        leg.compute_cost.to_bits(),
        "{label}: compute_cost ({} vs {})",
        eng.compute_cost,
        leg.compute_cost
    );
    assert_eq!(
        eng.storage_cost.to_bits(),
        leg.storage_cost.to_bits(),
        "{label}: storage_cost ({} vs {})",
        eng.storage_cost,
        leg.storage_cost
    );
    assert_eq!(eng.stage_times, leg.stage_times, "{label}: stage_times");
    assert_eq!(
        eng.final_fingerprint, leg.final_fingerprint,
        "{label}: final_fingerprint"
    );

    // timeline: identical (time, kind, detail) sequence — event ids are
    // per-metadata-service, so even notice details must match verbatim.
    assert_eq!(
        eng.timeline.events().len(),
        leg.timeline.events().len(),
        "{label}: timeline length"
    );
    for (i, (a, b)) in eng
        .timeline
        .events()
        .iter()
        .zip(leg.timeline.events())
        .enumerate()
    {
        assert_eq!(a.at, b.at, "{label}: timeline[{i}] time");
        assert_eq!(a.kind, b.kind, "{label}: timeline[{i}] kind");
        assert_eq!(a.detail, b.detail, "{label}: timeline[{i}] detail");
    }
}

/// String-based equivalence check for proptest integration: returns the
/// first divergence instead of panicking.
fn check_equivalent(exp: &Experiment) -> Result<(), String> {
    let eng = exp.run_sleeper().map_err(|e| e.to_string())?;
    let mut store = exp.fresh_store();
    let mut factory = exp.sleeper_factory();
    let leg = legacy::run_reference(&exp.cfg, &mut store, &mut *factory)
        .map_err(|e| e.to_string())?;
    let pairs: [(&str, String, String); 10] = [
        ("completed", format!("{:?}", eng.completed), format!("{:?}", leg.completed)),
        ("total", format!("{:?}", eng.total), format!("{:?}", leg.total)),
        ("evictions", eng.evictions.to_string(), leg.evictions.to_string()),
        ("instances", eng.instances.to_string(), leg.instances.to_string()),
        (
            "ckpts",
            format!(
                "{}p/{}t/{}f/{}a",
                eng.periodic_ckpts,
                eng.termination_ok,
                eng.termination_failed,
                eng.app_ckpts
            ),
            format!(
                "{}p/{}t/{}f/{}a",
                leg.periodic_ckpts,
                leg.termination_ok,
                leg.termination_failed,
                leg.app_ckpts
            ),
        ),
        ("restores", eng.restores.to_string(), leg.restores.to_string()),
        ("lost", eng.lost_steps.to_string(), leg.lost_steps.to_string()),
        (
            "cost",
            format!("{:x}", eng.compute_cost.to_bits()),
            format!("{:x}", leg.compute_cost.to_bits()),
        ),
        (
            "fingerprint",
            format!("{:016x}", eng.final_fingerprint),
            format!("{:016x}", leg.final_fingerprint),
        ),
        (
            "timeline",
            eng.timeline
                .events()
                .iter()
                .map(|e| format!("{}@{}", e.kind.as_str(), e.at.as_millis()))
                .collect::<Vec<_>>()
                .join(","),
            leg.timeline
                .events()
                .iter()
                .map(|e| format!("{}@{}", e.kind.as_str(), e.at.as_millis()))
                .collect::<Vec<_>>()
                .join(","),
        ),
    ];
    for (name, a, b) in pairs {
        if a != b {
            return Err(format!("{name} diverged: engine {a} != legacy {b}"));
        }
    }
    Ok(())
}

#[test]
fn all_table1_rows_are_byte_identical() {
    for row in spoton::report::paper_rows() {
        assert_equivalent(row.id, &row.experiment());
    }
}

#[test]
fn fixed_eviction_interval_sweep() {
    for mins in [20u64, 30, 45, 60, 75, 90, 120, 150] {
        let exp = Experiment::table1()
            .named("sweep")
            .eviction_every(SimDuration::from_mins(mins))
            .transparent(SimDuration::from_mins(15))
            .deadline(SimDuration::from_hours(30));
        assert_equivalent(&format!("fixed-{mins}m"), &exp);
    }
}

#[test]
fn app_native_eviction_sweep() {
    for mins in [30u64, 45, 60, 90] {
        let exp = Experiment::table1()
            .named("app-sweep")
            .eviction_every(SimDuration::from_mins(mins))
            .app_native()
            .deadline(SimDuration::from_hours(30));
        assert_equivalent(&format!("app-{mins}m"), &exp);
    }
}

#[test]
fn poisson_storm_seeds() {
    for seed in 1u64..=6 {
        let exp = Experiment::table1()
            .named("poisson")
            .eviction_poisson(SimDuration::from_mins(45))
            .transparent(SimDuration::from_mins(15))
            .deadline(SimDuration::from_hours(30))
            .seed(seed);
        assert_equivalent(&format!("poisson-seed{seed}"), &exp);
    }
}

#[test]
fn trace_replay() {
    let exp = Experiment::table1()
        .named("trace")
        .eviction_trace(
            [73u64, 22, 48, 95, 31, 180, 60]
                .iter()
                .map(|m| SimDuration::from_mins(*m))
                .collect(),
        )
        .transparent(SimDuration::from_mins(15))
        .deadline(SimDuration::from_hours(24));
    assert_equivalent("trace", &exp);
}

#[test]
fn constant_price_trace_is_byte_identical_to_legacy() {
    // A 1-pool fleet whose pool carries a *constant* price trace (factor
    // 1.0 pinned at t=0) must replay the legacy single-scale-set loop
    // byte for byte: no PoolPriceChanged events, identical invoices
    // (piecewise booking coalesces to the whole-uptime arithmetic), and
    // identical timelines — the oracle guarantee for the trace layer.
    use spoton::cloud::trace::PriceTrace;
    use spoton::config::{
        EvictionPlanCfg, PlacementPolicyCfg, PoolCfg, PoolPricingCfg,
    };
    let eviction =
        EvictionPlanCfg::Fixed { interval: SimDuration::from_mins(90) };
    let exp = Experiment::table1()
        .named("trace-const")
        .eviction_every(SimDuration::from_mins(90))
        .transparent(SimDuration::from_mins(30))
        .pool(
            PoolCfg::named("pool-0")
                .eviction(eviction)
                .pricing(PoolPricingCfg::Trace(
                    PriceTrace::constant(1.0).expect("valid trace"),
                )),
        )
        .placement(PlacementPolicyCfg::Sticky);
    assert_equivalent("trace-const", &exp);
}

#[test]
fn fixed_interval_controller_is_byte_identical_to_legacy() {
    // The adaptive-interval subsystem's identity element: an explicit
    // `FixedInterval` controller must leave every decision exactly where
    // the legacy loop's `periodic_due` test put it — same checkpoints at
    // the same instants, same billing bits, same timeline — across fixed
    // and seeded-Poisson eviction storms. The same discipline as the
    // constant-price-trace pin: the new subsystem is a strict superset.
    use spoton::config::IntervalControllerCfg;
    let exp = Experiment::table1()
        .named("ctl-fixed")
        .eviction_every(SimDuration::from_mins(90))
        .transparent(SimDuration::from_mins(30))
        .adaptive(IntervalControllerCfg::Fixed);
    assert_equivalent("ctl-fixed", &exp);
    for seed in 1u64..=3 {
        let exp = Experiment::table1()
            .named("ctl-fixed-poisson")
            .eviction_poisson(SimDuration::from_mins(45))
            .transparent(SimDuration::from_mins(15))
            .deadline(SimDuration::from_hours(30))
            .adaptive(IntervalControllerCfg::Fixed)
            .seed(seed);
        assert_equivalent(&format!("ctl-fixed-seed{seed}"), &exp);
    }
}

#[test]
fn short_notice_failed_termination_checkpoints() {
    let exp = Experiment::table1()
        .named("short-notice")
        .eviction_every(SimDuration::from_mins(90))
        .transparent(SimDuration::from_mins(30))
        .notice(SimDuration::from_secs(5));
    assert_equivalent("notice-5s", &exp);
}

#[test]
fn slow_poll_never_detects_in_time() {
    // poll interval ≫ notice: the coordinator's tick lands after the
    // reclaim instant, so even attached runs die at the deadline.
    let mut exp = Experiment::table1()
        .named("slow-poll")
        .eviction_every(SimDuration::from_mins(60))
        .transparent(SimDuration::from_mins(20))
        .deadline(SimDuration::from_hours(30));
    exp.cfg.cloud.poll_interval = SimDuration::from_secs(300);
    assert_equivalent("slow-poll", &exp);
}

#[test]
fn unprotected_starvation_aborts_identically() {
    let exp = Experiment::table1()
        .named("starved")
        .eviction_every(SimDuration::from_mins(100))
        .unprotected()
        .deadline(SimDuration::from_hours(9));
    assert_equivalent("starvation", &exp);
}

#[test]
fn detached_coordinator_dies_at_deadline() {
    let exp = Experiment::table1()
        .named("off")
        .spoton_off()
        .eviction_every(SimDuration::from_mins(80))
        .deadline(SimDuration::from_hours(12));
    assert_equivalent("spoton-off-evicted", &exp);
}

#[test]
fn milestone_starvation_app_native() {
    let exp = Experiment::table1()
        .named("milestone-starved")
        .eviction_every(SimDuration::from_mins(30))
        .app_native()
        .app_milestones(1)
        .deadline(SimDuration::from_hours(8));
    assert_equivalent("milestone-starvation", &exp);
}

/// Run `exp` as a one-job cluster (the job named after the scenario so
/// `run_digest` prefixes match) and return that job's `RunResult`.
fn run_cluster_single(exp: &Experiment) -> RunResult {
    use spoton::config::ClusterCfg;
    let mut cfg = exp.cfg.clone();
    cfg.cluster = Some(ClusterCfg {
        jobs: vec![cfg.name.clone()],
        ..ClusterCfg::default()
    });
    let cexp = Experiment { cfg };
    let mut r = cexp.run_cluster_sleeper().expect("cluster run");
    assert_eq!(r.jobs.len(), 1);
    assert_eq!(r.peak_in_flight, 1);
    assert_eq!(r.timeline.count(spoton::metrics::EventKind::JobQueued), 0);
    r.jobs.remove(0).result
}

#[test]
fn single_job_cluster_is_byte_identical_to_engine() {
    // The multiplexed cluster engine must degenerate *exactly* to the
    // per-run engine when the cluster holds one batch-arrival job: same
    // placement decisions, launch ids, eviction draws, checkpoint
    // instants, billing bits and timeline. Pinned through `run_digest`,
    // which serializes every field the sweep layer deduplicates on
    // (costs and fingerprints as raw bits, the full timeline verbatim).
    // Price *traces* are deliberately absent here: a cluster records
    // `PoolPriceChanged` once on the cluster-wide timeline rather than
    // per job, the one documented multi-job divergence.
    use spoton::sim::sweep::run_digest;
    let scenarios: Vec<(String, Experiment)> = vec![
        (
            "uninterrupted".into(),
            Experiment::table1().named("solo-base"),
        ),
        (
            "fixed-eviction".into(),
            Experiment::table1()
                .named("solo-fixed")
                .eviction_every(SimDuration::from_mins(90))
                .transparent(SimDuration::from_mins(30))
                .deadline(SimDuration::from_hours(30)),
        ),
        (
            "app-native".into(),
            Experiment::table1()
                .named("solo-app")
                .eviction_every(SimDuration::from_mins(45))
                .app_native()
                .deadline(SimDuration::from_hours(30)),
        ),
        (
            "short-notice".into(),
            Experiment::table1()
                .named("solo-notice")
                .eviction_every(SimDuration::from_mins(90))
                .transparent(SimDuration::from_mins(30))
                .notice(SimDuration::from_secs(5)),
        ),
        (
            "deadline-abort".into(),
            Experiment::table1()
                .named("solo-off")
                .spoton_off()
                .eviction_every(SimDuration::from_mins(80))
                .deadline(SimDuration::from_hours(12)),
        ),
    ];
    for (label, exp) in &scenarios {
        let eng = run_engine(exp);
        let clu = run_cluster_single(exp);
        assert_eq!(
            run_digest(&eng),
            run_digest(&clu),
            "{label}: single-job cluster diverged from the engine"
        );
    }
    // seeded poisson storms: the seed must thread through identically
    for seed in 1u64..=3 {
        let exp = Experiment::table1()
            .named("solo-poisson")
            .eviction_poisson(SimDuration::from_mins(45))
            .transparent(SimDuration::from_mins(15))
            .deadline(SimDuration::from_hours(30))
            .seed(seed);
        let eng = run_engine(&exp);
        let clu = run_cluster_single(&exp);
        assert_eq!(
            run_digest(&eng),
            run_digest(&clu),
            "poisson-seed{seed}: single-job cluster diverged from the engine"
        );
    }
}

#[test]
fn prop_engine_equals_legacy_on_random_scenarios() {
    // The randomized generator from the driver property suite: eviction
    // plan × checkpoint method × notice × poll × image size × seed.
    forall(
        Config::default().cases(45).seed(0xE0_07),
        |rng: &mut Prng| {
            let mut e = Experiment::table1()
                .named("prop-eq")
                .seed(rng.next_u64())
                .deadline(SimDuration::from_hours(40));
            e = match rng.below(4) {
                0 => e,
                1 => e.eviction_every(SimDuration::from_mins(
                    rng.range_u64(20, 180),
                )),
                2 => e.eviction_poisson(SimDuration::from_mins(
                    rng.range_u64(30, 240),
                )),
                _ => {
                    let n = rng.range_u64(1, 5);
                    e.eviction_trace(
                        (0..n)
                            .map(|_| {
                                SimDuration::from_mins(
                                    rng.range_u64(10, 120),
                                )
                            })
                            .collect(),
                    )
                }
            };
            e = match rng.below(6) {
                0 => e.unprotected(),
                1 | 2 => e.app_native(),
                _ => e.transparent(SimDuration::from_mins(
                    rng.range_u64(5, 45),
                )),
            };
            e = e
                .notice(SimDuration::from_secs(rng.range_u64(5, 120)))
                .state_gib(0.5 + rng.f64() * 6.0);
            e.cfg.cloud.poll_interval =
                SimDuration::from_secs(rng.range_u64(2, 60));
            e
        },
        shrink_none,
        check_equivalent,
    );
}

// ---------------------------------------------------------------------
// Chaos equivalence: the multiplexed cluster engine must stay a perfect
// superset of the single-run engine even under fault injection. Job 0's
// chaos streams (storage faults, backoff jitter) and the cluster-global
// fault plan (storms, IMDS outages) are derived so that a one-job
// cluster draws exactly what the engine draws.
// ---------------------------------------------------------------------

const CHAOS_EQUIV_SCENARIO: &str = r#"
name = "chaos-equiv"
deadline_mins = 1800
seed = 5

[workload]
kind = "sleeper"
ks = [33, 55]
stage_secs = [60, 120]

[eviction]
plan = "poisson"
mean_mins = 45

[checkpoint]
method = "transparent"
interval_mins = 15
retain = 3

[checkpoint.retry]
attempts = 4
base_ms = 250
max_ms = 8000
factor = 2.0
jitter = 0.25

[chaos]
salt = 9
storms = 2
window_mins = 240

[chaos.storage]
write_fail_prob = 0.25
torn_write_prob = 0.1
corrupt_prob = 0.05
latency_spike_prob = 0.1
latency_spike_ms = 1500

[chaos.imds]
outages = 1
outage_mins = 20
degraded_poll_factor = 4
"#;

#[test]
fn uncrossed_bid_is_byte_identical_to_no_bid() {
    // The bid-aware market's identity element: a bid the traced price
    // can never cross must be completely inert — no `PoolOutbid`, same
    // placement decisions, same piecewise invoices (bitwise), same
    // timeline — so bid-less configs keep their historical digests.
    use spoton::cloud::trace::{PricePoint, PriceTrace};
    use spoton::config::{
        EvictionPlanCfg, PlacementPolicyCfg, PoolCfg, PoolPricingCfg,
    };
    use spoton::metrics::EventKind;
    use spoton::sim::sweep::run_digest;

    let trace = PriceTrace::new(vec![
        PricePoint { offset: SimDuration::ZERO, factor: 0.8 },
        PricePoint { offset: SimDuration::from_mins(60), factor: 1.5 },
        PricePoint { offset: SimDuration::from_mins(150), factor: 1.1 },
    ])
    .expect("valid trace");
    let exp = |bid: Option<f64>| {
        let mut pool = PoolCfg::named("east")
            .eviction(EvictionPlanCfg::Fixed {
                interval: SimDuration::from_mins(90),
            })
            .pricing(PoolPricingCfg::Trace(trace.clone()));
        if let Some(b) = bid {
            pool = pool.bid(b);
        }
        Experiment::table1()
            .named("uncrossed-bid")
            .transparent(SimDuration::from_mins(30))
            .deadline(SimDuration::from_hours(30))
            .pool(pool)
            .placement(PlacementPolicyCfg::Sticky)
    };

    // $9/h sits far above the trace ceiling (1.5 × the spot catalog
    // price ≈ $0.11/h): the market can never cross it.
    let with_bid = run_engine(&exp(Some(9.0)));
    let without = run_engine(&exp(None));
    assert!(with_bid.evictions > 0, "plan must exercise replacements");
    assert_eq!(with_bid.timeline.count(EventKind::PoolOutbid), 0);
    assert_eq!(
        run_digest(&with_bid),
        run_digest(&without),
        "an uncrossed bid must be inert"
    );

    // Same pin through the multiplexed cluster engine: a 3-job cluster
    // on the bidded pool must digest identically to the bid-free one.
    use spoton::config::ClusterCfg;
    use spoton::sim::cluster::cluster_digest;
    let cluster = |bid: Option<f64>| {
        let mut e = exp(bid);
        e.cfg.fleet.pools[0].capacity = 3;
        e.cfg.cluster = Some(ClusterCfg::with_count(3));
        e.run_cluster_sleeper().expect("cluster run")
    };
    let c_with = cluster(Some(9.0));
    let c_without = cluster(None);
    assert_eq!(
        cluster_digest(&c_with),
        cluster_digest(&c_without),
        "an uncrossed bid must be inert in the cluster engine"
    );
}

#[test]
fn single_job_cluster_chaos_is_byte_identical_to_engine() {
    use spoton::config::{ClusterCfg, ScenarioConfig};
    use spoton::metrics::RecordLevel;
    use spoton::sim::sweep::run_digest;
    for seed in [5u64, 6, 7] {
        let mut cfg =
            ScenarioConfig::from_str_toml(CHAOS_EQUIV_SCENARIO).unwrap();
        cfg.seed = seed;
        cfg.metrics = RecordLevel::Full;
        let exp = Experiment { cfg: cfg.clone() };
        let eng = run_engine(&exp);

        let mut ccfg = cfg;
        ccfg.cluster = Some(ClusterCfg {
            jobs: vec![ccfg.name.clone()],
            ..ClusterCfg::default()
        });
        let mut r = Experiment { cfg: ccfg }
            .run_cluster_sleeper()
            .expect("cluster run");
        assert_eq!(r.jobs.len(), 1);
        let clu = r.jobs.remove(0).result;
        assert_eq!(
            run_digest(&eng),
            run_digest(&clu),
            "seed {seed}: chaos single-job cluster diverged from engine"
        );
    }
}
