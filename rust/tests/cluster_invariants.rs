//! Seeded property suite for the multiplexed cluster engine
//! (`spoton::sim::cluster`), pinning the two admission invariants the
//! design guarantees:
//!
//! 1. **Capacity**: the number of simultaneously-running instances in a
//!    pool never exceeds that pool's configured capacity, however stormy
//!    the eviction process — `peak_in_flight_per_pool[i] <= capacity`.
//! 2. **FIFO-per-priority**: queued jobs admit in strict queue order —
//!    lowest priority number first, FIFO within a priority, with
//!    head-of-line blocking (nobody behind the head jumps a full pool).
//!    Verified by replaying the cluster timeline's `JobQueued` /
//!    `JobAdmitted` events through a reference queue.

use std::collections::{BTreeMap, VecDeque};

use spoton::config::{ArrivalCfg, ClusterCfg, PoolCfg};
use spoton::metrics::EventKind;
use spoton::sim::experiment::Experiment;
use spoton::simclock::SimDuration;
use spoton::util::proptest::{forall, shrink_none, Config};
use spoton::util::Prng;

/// One randomized contended scenario.
#[derive(Debug, Clone)]
struct Case {
    jobs: usize,
    capacity: u32,
    priorities: Vec<u32>,
    arrival: ArrivalCfg,
    eviction_mean_mins: u64,
    seed: u64,
}

fn build(case: &Case) -> Experiment {
    let mut exp = Experiment::table1()
        .named("prop-cluster")
        .scale_stages(0.05)
        .eviction_poisson(SimDuration::from_mins(case.eviction_mean_mins))
        .transparent(SimDuration::from_mins(10))
        .deadline(SimDuration::from_hours(4000))
        .seed(case.seed);
    exp.cfg.cluster = Some(
        ClusterCfg::with_count(case.jobs)
            .capacity(case.capacity)
            .arrival(case.arrival.clone())
            .priorities(case.priorities.clone()),
    );
    exp
}

/// Replay the cluster timeline through a reference FIFO-per-priority
/// queue: every `JobAdmitted` must pop the head of the lowest-numbered
/// non-empty priority class, exactly as `try_admit_waiting` claims.
fn replay_fifo(
    events: &[spoton::metrics::TimelineEvent],
    priority_of: &BTreeMap<String, u32>,
) -> Result<(), String> {
    let mut waiting: BTreeMap<u32, VecDeque<String>> = BTreeMap::new();
    for e in events {
        match e.kind {
            EventKind::JobQueued => {
                let name = e
                    .detail
                    .split(' ')
                    .next()
                    .ok_or("empty JobQueued detail")?
                    .to_string();
                let prio = *priority_of
                    .get(&name)
                    .ok_or_else(|| format!("unknown job queued: {name}"))?;
                waiting.entry(prio).or_default().push_back(name);
            }
            EventKind::JobAdmitted => {
                let name = e
                    .detail
                    .split(' ')
                    .next()
                    .ok_or("empty JobAdmitted detail")?;
                let head = waiting
                    .values_mut()
                    .find(|q| !q.is_empty())
                    .and_then(|q| q.pop_front())
                    .ok_or_else(|| {
                        format!("{name} admitted with nothing waiting")
                    })?;
                if head != name {
                    return Err(format!(
                        "FIFO violated: admitted {name} while {head} \
                         was at the head of the queue"
                    ));
                }
            }
            _ => {}
        }
    }
    if waiting.values().any(|q| !q.is_empty()) {
        return Err("some queued jobs were never admitted".into());
    }
    Ok(())
}

fn check(case: &Case) -> Result<(), String> {
    let exp = build(case);
    let r = exp.run_cluster_sleeper().map_err(|e| e.to_string())?;

    // every job finishes under the generous deadline
    if r.completed_jobs() != case.jobs {
        return Err(format!(
            "only {}/{} jobs completed: {}",
            r.completed_jobs(),
            case.jobs,
            r.summary()
        ));
    }

    // capacity invariant, per pool and cluster-wide
    for (i, &peak) in r.peak_in_flight_per_pool.iter().enumerate() {
        if peak > case.capacity {
            return Err(format!(
                "pool {i} peaked at {peak} > capacity {}",
                case.capacity
            ));
        }
    }
    let total_cap =
        case.capacity * r.peak_in_flight_per_pool.len() as u32;
    if r.peak_in_flight > total_cap {
        return Err(format!(
            "cluster peaked at {} > fleet capacity {total_cap}",
            r.peak_in_flight
        ));
    }

    // every CapacityExhausted queues exactly one job
    let exhausted = r.timeline.count(EventKind::CapacityExhausted);
    let queued = r.timeline.count(EventKind::JobQueued);
    if exhausted != queued {
        return Err(format!(
            "{exhausted} CapacityExhausted vs {queued} JobQueued"
        ));
    }

    // FIFO-per-priority admission replay
    let ccfg = exp.cfg.cluster.as_ref().unwrap();
    let priority_of: BTreeMap<String, u32> = ccfg
        .jobs
        .iter()
        .enumerate()
        .map(|(i, n)| (n.clone(), ccfg.priority(i)))
        .collect();
    replay_fifo(r.timeline.events(), &priority_of)
}

#[test]
fn prop_capacity_and_fifo_hold_under_random_contention() {
    forall(
        Config::default().cases(30).seed(0xC1_05),
        |rng: &mut Prng| {
            let jobs = 2 + rng.below(9) as usize; // 2..=10
            let capacity = 1 + rng.below(3) as u32; // 1..=3
            let priorities = if rng.below(2) == 0 {
                Vec::new() // all priority 0
            } else {
                (0..jobs).map(|_| rng.below(3) as u32).collect()
            };
            let arrival = match rng.below(3) {
                0 => ArrivalCfg::Batch,
                1 => ArrivalCfg::Uniform {
                    spacing: SimDuration::from_mins(rng.range_u64(1, 30)),
                },
                _ => ArrivalCfg::Poisson {
                    mean: SimDuration::from_mins(rng.range_u64(2, 40)),
                },
            };
            Case {
                jobs,
                capacity,
                priorities,
                arrival,
                eviction_mean_mins: rng.range_u64(15, 120),
                seed: rng.next_u64(),
            }
        },
        shrink_none,
        check,
    );
}

#[test]
fn capacity_holds_per_pool_on_an_explicit_two_pool_fleet() {
    // Explicit fleet pools carry their own capacities; the implicit
    // `[cluster] capacity` knob is ignored. 7 batch jobs on a 2+1 fleet:
    // eviction-aware placement starts everyone in the cheap `big` pool
    // (capacity 2, deterministic 30-min evictions); the first eviction
    // drives big's observed rate up and funnels later placements into
    // the eviction-free `small` pool (capacity 1). Both pools see real
    // placements, neither ever exceeds its own cap.
    use spoton::config::{EvictionPlanCfg, PlacementPolicyCfg};
    let mut exp = Experiment::table1()
        .named("two-pool-cap")
        .scale_stages(0.05)
        .transparent(SimDuration::from_mins(10))
        .deadline(SimDuration::from_hours(4000))
        .pool(PoolCfg::named("big").capacity(2).eviction(
            EvictionPlanCfg::Fixed {
                interval: SimDuration::from_mins(30),
            },
        ))
        .pool(PoolCfg::named("small").capacity(1).price_factor(1.05))
        .placement(PlacementPolicyCfg::EvictionAware { penalty: 4.0 });
    exp.cfg.cluster = Some(ClusterCfg::with_count(7));
    let r = exp.run_cluster_sleeper().unwrap();
    assert_eq!(r.completed_jobs(), 7, "{}", r.summary());
    // per-pool capacity invariant
    assert!(r.peak_in_flight_per_pool[0] <= 2, "{}", r.summary());
    assert!(r.peak_in_flight_per_pool[1] <= 1, "{}", r.summary());
    assert!(
        r.peak_in_flight <= 3,
        "cluster-wide peak within fleet capacity: {}",
        r.summary()
    );
    // both pools were genuinely used: big saturates at batch admission,
    // small takes the post-eviction spillover
    assert_eq!(r.peak_in_flight_per_pool[0], 2, "{}", r.summary());
    assert_eq!(r.peak_in_flight_per_pool[1], 1, "{}", r.summary());
    // 7 jobs on <= 3 slots at batch arrival: at least 4 queued
    assert!(r.queued_admissions() >= 4, "{}", r.summary());
    assert!(r.timeline.is_monotone());
}
