//! Integration tests for the `spoton lint` engine.
//!
//! Three layers of coverage:
//!
//! 1. **Golden fixtures** — the deliberately-violating files under
//!    `tests/lint_fixtures/` (skipped by the repo walker) are scanned
//!    under synthetic repo-relative paths that put each rule in scope,
//!    and the exact `(rule, line)` set is asserted.
//! 2. **Mutation checks on real repo files** — each rule is proven to
//!    fire by appending a violation to an actual source file that is
//!    clean at HEAD and asserting exactly one new finding with the right
//!    rule id and computed line.
//! 3. **The repo gate** — `lint_repo` over this checkout must be clean:
//!    every finding fixed or carrying a reasoned allow marker, and the
//!    committed baseline neither exceeded nor stale.

use spoton::analysis::{
    self, check_cargo_toml, check_source, Baseline, Diag, LintConfig,
    RuleId,
};
use std::path::{Path, PathBuf};

fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("..")
}

fn fixture(name: &str) -> String {
    let p = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/lint_fixtures")
        .join(name);
    std::fs::read_to_string(&p)
        .unwrap_or_else(|e| panic!("reading {}: {e}", p.display()))
}

fn read_repo(rel: &str) -> String {
    let p = repo_root().join(rel);
    std::fs::read_to_string(&p)
        .unwrap_or_else(|e| panic!("reading {}: {e}", p.display()))
}

/// Repo config with every path-scoped rule additionally scoped onto the
/// given synthetic path (same pattern as the unit tests in
/// `analysis::rules`).
fn scoped(path: &str) -> LintConfig {
    let mut cfg = LintConfig::repo_default();
    cfg.ordered_paths.push(path.to_string());
    cfg.cast_paths.push(path.to_string());
    cfg
}

/// `(rule, line)` pairs sorted by line then rule — the golden shape.
fn golden(diags: &[Diag]) -> Vec<(u32, &'static str)> {
    let mut g: Vec<(u32, &'static str)> =
        diags.iter().map(|d| (d.line, d.rule.as_str())).collect();
    g.sort();
    g
}

/// 1-based line of the first line containing `needle`.
fn line_of(text: &str, needle: &str) -> u32 {
    let idx = text
        .lines()
        .position(|l| l.contains(needle))
        .unwrap_or_else(|| panic!("needle '{needle}' not found"));
    u32::try_from(idx).unwrap() + 1
}

// ---------------------------------------------------------------- golden

#[test]
fn d1_fixture_golden() {
    let path = "rust/src/report/lint_fixture_d1.rs";
    let diags = check_source(path, &fixture("d1_digest.rs"), &scoped(path));
    assert_eq!(golden(&diags), vec![(6, "D1"), (9, "D1")], "{diags:?}");
    assert!(diags.iter().all(|d| d.path == path));
    // diagnostics render as clickable file:line with the rule id
    let line = format!("{}", diags[0]);
    assert!(
        line.starts_with("rust/src/report/lint_fixture_d1.rs:6: D1 "),
        "{line}"
    );
}

#[test]
fn d2_fixture_golden() {
    let path = "rust/src/sim/lint_fixture_d2.rs";
    let diags =
        check_source(path, &fixture("d2_wallclock.rs"), &scoped(path));
    assert_eq!(
        golden(&diags),
        vec![(5, "D2"), (9, "D2"), (13, "D2")],
        "{diags:?}"
    );
}

#[test]
fn d2_fixture_is_exempt_in_allowlisted_module() {
    // the same source under a wall-clock-allowlisted path is clean
    let diags = check_source(
        "rust/src/coordinator/realtime.rs",
        &fixture("d2_wallclock.rs"),
        &LintConfig::repo_default(),
    );
    assert!(diags.is_empty(), "{diags:?}");
}

#[test]
fn d3_fixture_golden() {
    let path = "rust/src/lint_fixture_d3.rs";
    let diags = check_source(path, &fixture("d3_unwrap.rs"), &scoped(path));
    // the `self.expect(…)` call and the `#[cfg(test)]` unwrap are silent
    assert_eq!(golden(&diags), vec![(4, "D3"), (5, "D3")], "{diags:?}");
}

#[test]
fn d3_fixture_is_exempt_under_tests() {
    let diags = check_source(
        "rust/tests/lint_fixture_d3.rs",
        &fixture("d3_unwrap.rs"),
        &LintConfig::repo_default(),
    );
    assert!(diags.is_empty(), "{diags:?}");
}

#[test]
fn d4_fixture_golden() {
    let path = "rust/src/util/lint_fixture_d4.rs";
    let diags = check_source(path, &fixture("d4_cast.rs"), &scoped(path));
    // only the narrowing cast fires; `as u64` / `as f64` are silent
    assert_eq!(golden(&diags), vec![(4, "D4")], "{diags:?}");
}

// --------------------------------------------------------- allow markers

#[test]
fn allow_markers_with_reason_suppress_exactly_their_line() {
    let path = "rust/src/lint_fixture_allow.rs";
    let diags = check_source(path, &fixture("allow_ok.rs"), &scoped(path));
    // standalone marker covers line 5, trailing marker covers line 9;
    // the uncovered unwrap on line 13 still fires, and no A1 appears
    assert_eq!(golden(&diags), vec![(13, "D3")], "{diags:?}");
}

#[test]
fn malformed_allow_markers_are_a1_and_suppress_nothing() {
    let path = "rust/src/lint_fixture_allow_bad.rs";
    let diags = check_source(path, &fixture("allow_bad.rs"), &scoped(path));
    assert_eq!(
        golden(&diags),
        vec![
            (5, "A1"),
            (6, "D3"),
            (10, "A1"),
            (11, "D3"),
            (15, "A1"),
            (16, "D3"),
        ],
        "{diags:?}"
    );
    let a1: Vec<&Diag> =
        diags.iter().filter(|d| d.rule == RuleId::A1).collect();
    assert!(a1[0].message.contains("reason"), "{}", a1[0].message);
    assert!(a1[1].message.contains("empty"), "{}", a1[1].message);
    assert!(a1[2].message.contains("'D9'"), "{}", a1[2].message);
}

// -------------------------------------------------------------- baseline

#[test]
fn baseline_suppresses_old_findings_but_not_new_ones() {
    let path = "rust/src/lint_fixture_d3.rs";
    let cfg = scoped(path);
    let src = fixture("d3_unwrap.rs");
    let old = check_source(path, &src, &cfg);
    assert_eq!(old.len(), 2);
    let base = Baseline::from_diags(&old);

    // unchanged debt: clean
    assert!(base.compare(&old).clean());

    // one more violation in the same file: exactly one new group,
    // counting 2 baselined vs 3 current
    let mutated =
        format!("{src}pub fn extra(w: Option<u32>) -> u32 {{ w.unwrap() }}\n");
    let now = check_source(path, &mutated, &cfg);
    assert_eq!(now.len(), 3, "{now:?}");
    let cmp = base.compare(&now);
    assert_eq!(cmp.new_groups.len(), 1, "{:?}", cmp.new_groups);
    assert!(cmp.stale.is_empty());
    assert_eq!(cmp.new_groups[0].rule, "D3");
    assert_eq!(cmp.new_groups[0].path, path);
    assert_eq!(cmp.new_groups[0].baselined, 2);
    assert_eq!(cmp.new_groups[0].current, 3);

    // shrunk debt: the ratchet flags the baseline as stale instead
    let cmp = base.compare(&old[..1]);
    assert!(cmp.new_groups.is_empty());
    assert_eq!(cmp.stale.len(), 1);
}

// ---------------------------------------- mutation checks on real files

/// Assert `rel` is clean at HEAD, then that appending `addition` yields
/// exactly one new finding of `rule` on the appended line.
fn assert_mutation_fires(rel: &str, addition: &str, rule: RuleId) {
    let cfg = LintConfig::repo_default();
    let src = read_repo(rel);
    let before = check_source(rel, &src, &cfg);
    assert!(before.is_empty(), "{rel} not clean at HEAD: {before:?}");
    assert!(src.ends_with('\n'), "{rel} lacks trailing newline");
    let mutated = format!("{src}{addition}\n");
    let diags = check_source(rel, &mutated, &cfg);
    assert_eq!(diags.len(), 1, "{rel}: {diags:?}");
    assert_eq!(diags[0].rule, rule, "{rel}: {diags:?}");
    assert_eq!(diags[0].path, rel);
    assert_eq!(diags[0].line, line_of(&mutated, "__lint_mut"));
}

#[test]
fn mutation_d1_fires_in_report_path() {
    assert_mutation_fires(
        "rust/src/report/table1.rs",
        "fn __lint_mut(m: &std::collections::HashMap<u32, u32>) -> usize \
         { m.len() }",
        RuleId::D1,
    );
}

#[test]
fn mutation_d2_fires_in_sim_engine() {
    assert_mutation_fires(
        "rust/src/sim/cluster.rs",
        "fn __lint_mut() -> u64 { \
         std::time::Instant::now().elapsed().as_secs() }",
        RuleId::D2,
    );
}

#[test]
fn mutation_d3_fires_in_checkpoint_store() {
    assert_mutation_fires(
        "rust/src/checkpoint/store.rs",
        "fn __lint_mut(x: Option<u32>) -> u32 { x.unwrap() }",
        RuleId::D3,
    );
}

#[test]
fn mutation_d4_fires_in_billing_math() {
    assert_mutation_fires(
        "rust/src/cloud/billing.rs",
        "fn __lint_mut(x: u64) -> u32 { x as u32 }",
        RuleId::D4,
    );
}

#[test]
fn mutation_d5_fires_on_dependency_creep() {
    let cfg = LintConfig::repo_default();
    let text = read_repo("rust/Cargo.toml");
    let before = check_cargo_toml("rust/Cargo.toml", &text, &cfg);
    assert!(before.is_empty(), "rust/Cargo.toml not clean: {before:?}");

    // a dev-dependency is creep by definition
    let mutated = format!("{text}\n[dev-dependencies]\ntempfile = \"3\"\n");
    let diags = check_cargo_toml("rust/Cargo.toml", &mutated, &cfg);
    assert_eq!(diags.len(), 1, "{diags:?}");
    assert_eq!(diags[0].rule, RuleId::D5);
    assert_eq!(diags[0].line, line_of(&mutated, "tempfile"));
    assert!(diags[0].message.contains("tempfile"), "{}", diags[0].message);

    // removing the pjrt feature gate is also a D5 failure
    let gateless = text.replace("pjrt", "pjrt_renamed");
    let diags = check_cargo_toml("rust/Cargo.toml", &gateless, &cfg);
    assert!(
        diags.iter().any(|d| d.rule == RuleId::D5
            && d.message.contains("pjrt")),
        "{diags:?}"
    );
}

// -------------------------------------------------------- the repo gate

#[test]
fn repo_lint_is_clean_at_head() {
    let root = repo_root();
    let cfg = LintConfig::repo_default();
    let report = analysis::lint_repo(&root, &cfg)
        .expect("lint pass over the checkout");
    let listing: Vec<String> =
        report.diags.iter().map(|d| d.to_string()).collect();
    assert!(
        report.diags.is_empty(),
        "HEAD must lint clean (fix it or add a reasoned allow marker):\n{}",
        listing.join("\n")
    );
    assert!(report.clean(), "baseline is stale or exceeded");
    assert!(
        report.files_scanned > 50,
        "suspiciously few files scanned: {}",
        report.files_scanned
    );
}

#[test]
fn committed_baseline_matches_engine_serialization() {
    // the checked-in file must be byte-identical to what the engine
    // writes, otherwise --fix-baseline would produce spurious diffs
    let path = repo_root().join(analysis::BASELINE_PATH);
    let committed = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()));
    let loaded = Baseline::load(&path).expect("parse committed baseline");
    let mut expect = spoton::json::to_string_pretty(&loaded.to_json());
    expect.push('\n');
    assert_eq!(committed, expect, "run `spoton lint --fix-baseline`");
}

#[test]
fn lint_report_json_is_deterministic() {
    let root = repo_root();
    let cfg = LintConfig::repo_default();
    let a = analysis::lint_repo(&root, &cfg).unwrap();
    let b = analysis::lint_repo(&root, &cfg).unwrap();
    let ja = spoton::json::to_string_pretty(&a.to_json());
    let jb = spoton::json::to_string_pretty(&b.to_json());
    assert_eq!(ja, jb);
    let v = spoton::json::parse(&ja).expect("report JSON parses");
    assert_eq!(v.req_u64("version").unwrap(), 1);
    assert!(a.render().contains("spoton lint: clean"));
}
