//! Ablation: the starvation case (paper §IV).
//!
//! "Some long-running jobs relying solely on application-specific
//! checkpointing may never be able to complete if the time between
//! application checkpointing is longer than the lifetime of a spot
//! instance. The transparent checkpointing can effectively overcome this
//! limit."
//!
//! We force checkpoint milestones to stage boundaries only
//! (milestones_per_stage = 1) and shrink the spot lifetime below the
//! longest stage: app-native must loop forever (caught by the scenario
//! deadline); transparent at any reasonable interval completes.

use spoton::report::table::TextTable;
use spoton::sim::experiment::Experiment;
use spoton::simclock::SimDuration;

fn main() -> anyhow::Result<()> {
    // Longest stage is K99 at 40:19; sweep lifetimes across it.
    let lifetimes_min = [50u64, 40, 35, 30];
    let mut t = TextTable::new(&[
        "Spot lifetime",
        "App-native outcome",
        "App evictions",
        "Transparent 15m outcome",
        "Transparent evictions",
    ]);
    let mut app_starved_at_least_once = false;
    for mins in lifetimes_min {
        let app = Experiment::table1()
            .named("app-boundary-only")
            .eviction_every(SimDuration::from_mins(mins))
            .app_native()
            .app_milestones(1) // checkpoints at stage boundaries only
            .deadline(SimDuration::from_hours(12))
            .run_sleeper()?;
        let tr = Experiment::table1()
            .named("transparent")
            .eviction_every(SimDuration::from_mins(mins))
            .transparent(SimDuration::from_mins(15))
            .deadline(SimDuration::from_hours(12))
            .run_sleeper()?;
        if !app.completed {
            app_starved_at_least_once = true;
        }
        assert!(
            tr.completed,
            "transparent must complete at lifetime {mins}min"
        );
        t.row(&[
            format!("{mins} min"),
            if app.completed {
                format!("completed in {}", app.total.hms())
            } else {
                format!("STARVED (aborted after {})", app.total.hms())
            },
            app.evictions.to_string(),
            format!("completed in {}", tr.total.hms()),
            tr.evictions.to_string(),
        ]);
    }
    println!(
        "\nAblation — starvation: app checkpoints at stage boundaries only\n"
    );
    print!("{}", t.render());
    assert!(
        app_starved_at_least_once,
        "app-native should starve once lifetime < longest stage"
    );
    println!(
        "\nstarvation shape check PASSED (app-native starves; transparent \
         completes)"
    );
    Ok(())
}
