//! Perf micro-benches: the hot paths behind EXPERIMENTS.md §Perf.
//!
//! * L1/L2 via PJRT: k-mer count step, denoise sweep, stats reduction
//!   (per-call latency on the request path).
//! * L3: snapshot serialize/restore, checkpoint write/scan/restore
//!   against the in-memory and directory-backed shares, IMDS document
//!   serve+parse, HTTP poll round trip, event-queue schedule/cancel/pop
//!   churn, end-to-end simulated experiment throughput (full metrics and
//!   the sweep's lean `RecordLevel::Counts` configuration).
//!
//! Timed results are also written to `BENCH_hotpath.json`
//! (`util::bench::BenchReport`) so the perf trajectory is diffable
//! across commits.

use spoton::checkpoint::{CheckpointStore, CheckpointWriter, CkptKind};
use spoton::cloud::imds_http::ImdsHttp;
use spoton::coordinator::ScheduledEventsMonitor;
use spoton::metrics::RecordLevel;
use spoton::runtime::{Arg, Runtime};
use spoton::sim::experiment::Experiment;
use spoton::simclock::{EventQueue, SimDuration, SimTime};
use spoton::storage::{BlobStore, NfsStore, SharedStore, TransferModel};
use spoton::util::bench::{bench_fn, section, BenchReport};
use spoton::util::Prng;
use spoton::workload::reads::{ReadGen, ReadGenCfg};
use spoton::workload::sleeper::{Sleeper, SleeperCfg};
use spoton::workload::Workload;

fn main() -> anyhow::Result<()> {
    let mut report = BenchReport::new("hotpath");
    // ---------------- L1/L2: PJRT request path ----------------
    match Runtime::load(&spoton::runtime::default_artifacts_dir()) {
        Ok(mut rt) => {
            let g = rt.geometry().clone();
            let b = g.num_buckets as usize;
            let gen = ReadGen::new(ReadGenCfg {
                row_len: g.read_len as usize,
                read_len: g.read_len as usize - 10,
                ..ReadGenCfg::default()
            });
            let chunk = gen.chunk_i32(0, g.reads_per_call as usize);
            let counts = vec![0f32; b];

            section("L1 kmer-count step (PJRT, per chunk of 1024 reads)");
            for k in [33u32, 127] {
                let name = format!("count_k{k}");
                rt.executable(&name)?; // compile outside timing
                let exe = rt.executable(&name)?;
                let stats = bench_fn(3, 20, || {
                    let out = exe
                        .call_f32(&[Arg::I32(&chunk), Arg::F32(&counts)])
                        .unwrap();
                    std::hint::black_box(out);
                });
                let windows = g.reads_per_call
                    * (g.read_len - k as u64 + 1);
                println!(
                    "  k={k:<3} {stats}\n        -> {:.1} Mwindows/s",
                    windows as f64 / stats.mean.as_secs_f64() / 1e6
                );
            }

            section("L2 denoise sweep + stats (PJRT)");
            let taps = 2 * g.denoise_half_width as usize + 1;
            let stencil = vec![1.0 / taps as f32; taps];
            let params = vec![1.5f32, 0.5];
            rt.executable("denoise")?;
            let exe = rt.executable("denoise")?;
            let stats = bench_fn(3, 50, || {
                let out = exe
                    .call_f32(&[
                        Arg::F32(&counts),
                        Arg::F32(&stencil),
                        Arg::F32(&params),
                    ])
                    .unwrap();
                std::hint::black_box(out);
            });
            println!("  denoise        {stats}");
            rt.executable("spectrum_stats")?;
            let exe = rt.executable("spectrum_stats")?;
            let stats = bench_fn(3, 50, || {
                let out = exe.call_f32(&[Arg::F32(&counts)]).unwrap();
                std::hint::black_box(out);
            });
            println!("  spectrum_stats {stats}");
        }
        Err(e) => {
            eprintln!("skipping PJRT benches (artifacts unavailable: {e})")
        }
    }

    // ---------------- L3: checkpoint engine ----------------
    section("L3 snapshot serialize / restore (sleeper, 8-word state)");
    let mut w = Sleeper::new(SleeperCfg::small(), 3);
    for _ in 0..50 {
        w.step()?;
    }
    let stats = bench_fn(10, 2000, || {
        std::hint::black_box(w.snapshot().unwrap());
    });
    println!("  snapshot   {stats}");
    report.stat("l3.snapshot", &stats);
    let mut reuse = w.snapshot()?;
    let stats = bench_fn(10, 2000, || {
        w.snapshot_into(&mut reuse).unwrap();
        std::hint::black_box(&reuse);
    });
    println!("  snap_into  {stats}");
    report.stat("l3.snapshot_into", &stats);
    let snap = w.snapshot()?;
    let mut w2 = Sleeper::new(SleeperCfg::small(), 3);
    let stats = bench_fn(10, 2000, || {
        w2.restore(&snap.bytes).unwrap();
    });
    println!("  restore    {stats}");
    report.stat("l3.restore", &stats);

    section("L3 checkpoint write+commit (BlobStore vs NfsStore)");
    let mut blob = BlobStore::for_tests();
    let mut writer = CheckpointWriter::new();
    let stats = bench_fn(5, 500, || {
        let out = writer
            .write(&mut blob, SimTime::ZERO, CkptKind::Periodic, &w, &snap)
            .unwrap();
        std::hint::black_box(out);
    });
    println!("  blob  write  {stats}");
    report.stat("l3.ckpt_write_blob", &stats);
    let nfs_dir = std::env::temp_dir()
        .join(format!("spoton-perf-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&nfs_dir);
    let mut nfs = NfsStore::open(
        &nfs_dir,
        TransferModel {
            bandwidth_mib_s: 250.0,
            latency: SimDuration::from_millis(20),
        },
        None,
    )?;
    let mut writer2 = CheckpointWriter::new();
    let stats = bench_fn(5, 200, || {
        let out = writer2
            .write(&mut nfs, SimTime::ZERO, CkptKind::Periodic, &w, &snap)
            .unwrap();
        std::hint::black_box(out);
    });
    println!("  nfs   write  {stats}");
    report.stat("l3.ckpt_write_nfs", &stats);

    section("L3 checkpoint scan + latest_valid (100 checkpoints on share)");
    let mut blob2 = BlobStore::for_tests();
    let mut writer3 = CheckpointWriter::new();
    for _ in 0..100 {
        writer3
            .write(&mut blob2, SimTime::ZERO, CkptKind::Periodic, &w, &snap)
            .unwrap();
    }
    let stats = bench_fn(3, 100, || {
        let m = CheckpointStore::latest_valid(&mut blob2, Some(true)).unwrap();
        std::hint::black_box(m);
    });
    println!("  latest_valid {stats}");
    report.stat("l3.latest_valid", &stats);

    section("L3 IMDS document serve + parse (in-proc)");
    let mut svc = spoton::cloud::metadata::MetadataService::new();
    for i in 0..4 {
        svc.post_preempt(&format!("vm-{i}"), SimTime::from_secs(30));
    }
    let mut mon = ScheduledEventsMonitor::new("vm-3");
    let stats = bench_fn(10, 2000, || {
        mon.reset();
        std::hint::black_box(mon.poll_inproc(&svc).unwrap());
    });
    println!("  poll_inproc  {stats}");
    report.stat("l3.poll_inproc", &stats);

    section("L3 IMDS HTTP poll round trip (localhost TCP)");
    let imds = ImdsHttp::spawn(30)?;
    let url = imds.events_url();
    let mut mon2 = ScheduledEventsMonitor::new("vm-0");
    let stats = bench_fn(5, 200, || {
        mon2.reset();
        std::hint::black_box(mon2.poll_http(&url).unwrap());
    });
    println!("  poll_http    {stats}");
    report.stat("l3.poll_http", &stats);

    section("L3 event-queue schedule/cancel/pop churn (simclock::EventQueue)");
    const QUEUE_N: usize = 4096;
    let stats = bench_fn(5, 200, || {
        let mut q = EventQueue::new();
        let mut rng = Prng::new(42);
        for _ in 0..QUEUE_N {
            q.schedule(SimTime::from_secs(rng.below(1_000_000)), ());
        }
        while let Some(s) = q.pop() {
            std::hint::black_box(&s);
        }
    });
    println!("  schedule+pop   {stats}");
    println!(
        "        -> {:.1} Mevents/s",
        QUEUE_N as f64 / stats.mean.as_secs_f64() / 1e6
    );
    report.stat("l3.queue_schedule_pop", &stats);
    let stats = bench_fn(5, 200, || {
        let mut q = EventQueue::new();
        let mut rng = Prng::new(42);
        let mut tokens = Vec::with_capacity(QUEUE_N);
        for _ in 0..QUEUE_N {
            tokens
                .push(q.schedule(SimTime::from_secs(rng.below(1_000_000)), ()));
        }
        for (i, &t) in tokens.iter().enumerate() {
            if i % 3 == 0 {
                q.cancel(t);
            }
        }
        while let Some(s) = q.pop() {
            std::hint::black_box(&s);
        }
    });
    println!("  +cancel churn  {stats}");
    report.stat("l3.queue_cancel_churn", &stats);

    section("L3 end-to-end simulated experiment (sleeper, full Table-I row)");
    let stats = bench_fn(2, 20, || {
        let r = Experiment::table1()
            .eviction_every(SimDuration::from_mins(60))
            .transparent(SimDuration::from_mins(15))
            .run_sleeper()
            .unwrap();
        std::hint::black_box(r);
    });
    println!("  row-per-run  {stats}");
    println!(
        "  -> {:.1} simulated-runs/s ({} simulated hours each)",
        stats.throughput_per_sec(),
        3.2
    );
    report.stat("l3.row_per_run", &stats);
    let lean_exp = Experiment::table1()
        .eviction_every(SimDuration::from_mins(60))
        .transparent(SimDuration::from_mins(15))
        .metrics(RecordLevel::Counts);
    let lean = bench_fn(2, 20, || {
        std::hint::black_box(lean_exp.run_sleeper().unwrap());
    });
    println!("  row lean     {lean} (Counts metrics level)");
    report.stat("l3.row_per_run_lean", &lean);

    section("L3 event engine vs legacy loop (same scenario, fresh shares)");
    let exp = Experiment::table1()
        .eviction_every(SimDuration::from_mins(60))
        .transparent(SimDuration::from_mins(15));
    let engine_stats = bench_fn(2, 20, || {
        std::hint::black_box(exp.run_sleeper().unwrap());
    });
    println!("  engine       {engine_stats}");
    report.stat("l3.engine", &engine_stats);
    let legacy_stats = bench_fn(2, 20, || {
        let mut store = exp.fresh_store();
        let mut factory = exp.sleeper_factory();
        std::hint::black_box(
            spoton::sim::legacy::run_reference(
                &exp.cfg,
                &mut store,
                &mut *factory,
            )
            .unwrap(),
        );
    });
    println!("  legacy loop  {legacy_stats}");
    report.stat("l3.legacy_loop", &legacy_stats);

    section("L3 multiplexed cluster engine (64 jobs, capacity 8)");
    // the contended-fleet hot path: one queue, one live fleet, jobs
    // interleaving as subject-tagged events (full figure in
    // `benches/perf_cluster.rs` / BENCH_cluster.json)
    let mut cluster_exp = Experiment::table1()
        .scale_stages(0.02)
        .eviction_poisson(SimDuration::from_mins(40))
        .transparent(SimDuration::from_mins(10))
        .deadline(SimDuration::from_hours(4000))
        .metrics(RecordLevel::Counts);
    cluster_exp.cfg.cluster =
        Some(spoton::config::ClusterCfg::with_count(64).capacity(8));
    let probe = cluster_exp.run_cluster_sleeper()?;
    let cluster_events = probe.events_processed;
    let stats = bench_fn(2, 10, || {
        std::hint::black_box(cluster_exp.run_cluster_sleeper().unwrap());
    });
    let eps = cluster_events as f64 / stats.mean.as_secs_f64();
    println!("  64-job run   {stats}");
    println!(
        "  -> {:.2} Mevents/s sustained ({cluster_events} events per run, \
         peak {} in flight)",
        eps / 1e6,
        probe.peak_in_flight
    );
    report.stat("l3.cluster_64jobs", &stats);
    report.value("l3.cluster_events_per_run", cluster_events);
    report.value("l3.cluster_events_per_sec", eps);

    let _ = std::fs::remove_dir_all(&nfs_dir);
    report.write()?;
    Ok(())
}
