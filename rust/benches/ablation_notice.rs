//! Ablation: termination checkpoints are opportunistic (paper §II/§III-B).
//!
//! "Unlike the periodic checkpoints, termination checkpoints are
//! opportunistic due to their possible failures caused by the short
//! eviction notification (e.g. seconds to a few minutes)" — Azure
//! guarantees a *minimum* of 30 s.
//!
//! Sweeps notice duration × checkpoint-image size and reports the
//! termination-checkpoint success rate and the end-to-end cost of
//! failures (longer reruns from older periodic checkpoints).

use spoton::report::table::TextTable;
use spoton::sim::experiment::Experiment;
use spoton::simclock::SimDuration;

fn main() -> anyhow::Result<()> {
    let notices_s = [5u64, 10, 20, 30, 60, 120];
    let sizes_gib = [1.0f64, 3.0, 8.0];
    let mut t = TextTable::new(&[
        "Notice",
        "Image size",
        "Term ok",
        "Term failed",
        "Total time",
        "vs baseline",
    ]);
    let baseline = Experiment::table1().spoton_off().run_sleeper()?.total;
    for &gib in &sizes_gib {
        for &notice in &notices_s {
            let r = Experiment::table1()
                .named("notice-sweep")
                .eviction_every(SimDuration::from_mins(60))
                .transparent(SimDuration::from_mins(30))
                .notice(SimDuration::from_secs(notice))
                .state_gib(gib)
                .run_sleeper()?;
            assert!(r.completed);
            let delta = r.total.as_millis() as f64
                / baseline.as_millis() as f64
                - 1.0;
            t.row(&[
                format!("{notice} s"),
                format!("{gib} GiB"),
                r.termination_ok.to_string(),
                r.termination_failed.to_string(),
                r.total.hms(),
                format!("{:+.1}%", delta * 100.0),
            ]);
        }
    }
    println!(
        "\nAblation — eviction notice vs checkpoint image size \
         (transparent 30m, evictions every 60m, NFS 250 MiB/s)\n"
    );
    print!("{}", t.render());

    // Shape: at 30s/3GiB (the Azure-realistic point) termination ckpts
    // succeed; at 5s/3GiB they all fail.
    let ok_point = Experiment::table1()
        .eviction_every(SimDuration::from_mins(60))
        .transparent(SimDuration::from_mins(30))
        .notice(SimDuration::from_secs(30))
        .state_gib(3.0)
        .run_sleeper()?;
    assert!(ok_point.termination_ok > 0 && ok_point.termination_failed == 0);
    let fail_point = Experiment::table1()
        .eviction_every(SimDuration::from_mins(60))
        .transparent(SimDuration::from_mins(30))
        .notice(SimDuration::from_secs(5))
        .state_gib(3.0)
        .run_sleeper()?;
    assert!(fail_point.termination_ok == 0 && fail_point.termination_failed > 0);
    assert!(
        fail_point.total > ok_point.total,
        "failed termination ckpts must cost time"
    );
    println!("\nnotice-sweep shape checks PASSED");
    Ok(())
}
