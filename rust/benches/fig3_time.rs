//! Bench: reproduce **Fig 3** — execution time with application-native vs
//! transparent checkpointing on spot instances.
//!
//! Paper claim: "transparent checkpointing also adds about additional
//! 15–40% time savings over application checkpoint."

use spoton::report::figures::render_fig3;
use spoton::sim::experiment::Experiment;
use spoton::simclock::SimDuration;

fn main() -> anyhow::Result<()> {
    let use_minimeta = std::env::var("SPOTON_BENCH_WORKLOAD")
        .map(|v| v == "minimeta")
        .unwrap_or(false);
    let rt = if use_minimeta {
        Some(std::rc::Rc::new(std::cell::RefCell::new(
            spoton::runtime::Runtime::load(
                &spoton::runtime::default_artifacts_dir(),
            )?,
        )))
    } else {
        None
    };
    let run = |e: Experiment| -> anyhow::Result<_> {
        Ok(match &rt {
            Some(rt) => e.run_minimeta(rt.clone())?,
            None => e.run_sleeper()?,
        })
    };

    let mut rendered = Vec::new();
    for mins in [90u64, 60] {
        let app = run(Experiment::table1()
            .named("app")
            .eviction_every(SimDuration::from_mins(mins))
            .app_native())?;
        let tr = run(Experiment::table1()
            .named("transparent")
            .eviction_every(SimDuration::from_mins(mins))
            .transparent(SimDuration::from_mins(30)))?;
        rendered.push((format!("evict every {mins} min"), app, tr));
    }
    let pairs: Vec<(&str, _, _)> = rendered
        .iter()
        .map(|(l, a, t)| (l.as_str(), a, t))
        .collect();
    print!("{}", render_fig3(&pairs));

    println!();
    for (label, app, tr) in &rendered {
        let saving =
            1.0 - tr.total.as_millis() as f64 / app.total.as_millis() as f64;
        println!(
            "{label}: transparent saves {:.1}% of execution time \
             (paper band: 15–40% at 60min)",
            saving * 100.0
        );
        assert!(
            app.total > tr.total,
            "transparent must be faster than app-native"
        );
    }
    // the 60-minute pair is the paper's strongest case; require a solid
    // double-digit saving there
    let (_, app60, tr60) = &rendered[1];
    let saving60 =
        1.0 - tr60.total.as_millis() as f64 / app60.total.as_millis() as f64;
    assert!(
        saving60 > 0.10,
        "60-min transparent saving {saving60:.3} below plausible band"
    );
    println!("fig3 shape checks PASSED");
    Ok(())
}
