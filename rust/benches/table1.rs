//! Bench: reproduce **Table I** — execution time of the metaSPAdes-analog
//! workload under every Spot-on configuration the paper reports.
//!
//! Default runs the full three-layer stack (MiniMeta via PJRT). Set
//! `SPOTON_BENCH_WORKLOAD=sleeper` for the fast calibration workload.
//!
//! We don't expect to match the paper's absolute numbers (their substrate
//! was Azure; ours is a calibrated simulator) — the *shape* is the claim
//! under test: rows 1–2 nearly equal (coordinator overhead ~1%),
//! application-native rows blow up with eviction frequency, transparent
//! rows stay near baseline.

use spoton::report::{paper_rows, render_comparison};
use spoton::runtime::Runtime;
use std::cell::RefCell;
use std::rc::Rc;

fn main() -> anyhow::Result<()> {
    let workload = std::env::var("SPOTON_BENCH_WORKLOAD")
        .unwrap_or_else(|_| "minimeta".into());
    let rt = if workload == "minimeta" {
        let dir = spoton::runtime::default_artifacts_dir();
        match Runtime::load(&dir) {
            Ok(rt) => Some(Rc::new(RefCell::new(rt))),
            Err(e) => {
                eprintln!(
                    "artifacts unavailable ({e}); falling back to sleeper"
                );
                None
            }
        }
    } else {
        None
    };

    let t0 = std::time::Instant::now();
    let mut results = Vec::new();
    for row in paper_rows() {
        let started = std::time::Instant::now();
        let exp = row.experiment();
        let result = match &rt {
            Some(rt) => exp.run_minimeta(rt.clone())?,
            None => exp.run_sleeper()?,
        };
        eprintln!(
            "  {}: simulated {} of cloud time in {:?} wall",
            row.id,
            result.total,
            started.elapsed()
        );
        results.push((row, result));
    }

    println!("\nTable I — Comparisons on execution time of the metaSPAdes-analog");
    println!(
        "workload ({} workload, {:?} total wall time)\n",
        if rt.is_some() { "MiniMeta/PJRT" } else { "sleeper" },
        t0.elapsed()
    );
    print!("{}", render_comparison(&results));

    // Shape assertions (the paper's qualitative claims).
    let total =
        |id: &str| results.iter().find(|(r, _)| r.id == id).unwrap().1.total;
    let baseline = total("row1");
    let overhead = total("row2").as_millis() as f64
        / baseline.as_millis() as f64
        - 1.0;
    println!("\nShape checks:");
    println!(
        "  coordinator overhead (row2 vs row1): {:.2}% (paper: ~1.1%)",
        overhead * 100.0
    );
    let app90 = total("row3");
    let app60 = total("row4");
    let t90 = total("row5").min(total("row6"));
    let t60 = total("row7").min(total("row8"));
    println!(
        "  app-native slowdown: 90min {:+.1}%, 60min {:+.1}% (paper: +17.9%, +46.3%)",
        (app90.as_millis() as f64 / baseline.as_millis() as f64 - 1.0) * 100.0,
        (app60.as_millis() as f64 / baseline.as_millis() as f64 - 1.0) * 100.0,
    );
    println!(
        "  transparent slowdown: 90min {:+.1}%, 60min {:+.1}% (paper: ≈0%)",
        (t90.as_millis() as f64 / baseline.as_millis() as f64 - 1.0) * 100.0,
        (t60.as_millis() as f64 / baseline.as_millis() as f64 - 1.0) * 100.0,
    );
    assert!(app60 > app90, "more evictions must hurt app-native more");
    assert!(app90 > t90, "transparent must beat app-native at 90min");
    assert!(app60 > t60, "transparent must beat app-native at 60min");
    assert!(overhead < 0.03, "coordinator overhead out of band");
    println!("  all shape checks PASSED");
    Ok(())
}
