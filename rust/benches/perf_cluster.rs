//! Multiplexed cluster engine throughput: the perf figure behind the
//! contended-fleet tentpole.
//!
//! One thousand jobs share a capacity-bound pool through the
//! `sim::cluster` engine — every job an interleaved stream of
//! subject-tagged events on **one** queue around **one** live fleet —
//! and the same thousand-job workload replays through the older
//! one-engine-per-attempt `sched::RequeueScheduler` path for the
//! apples-to-apples wall-clock comparison. Results land in
//! `BENCH_cluster.json`:
//!
//! * `cluster.events_per_sec` — sustained events/sec through the
//!   multiplexed engine (events popped / mean wall-clock);
//! * `requeue.run_1000_jobs` — the baseline's wall-clock on the same
//!   jobs with `slots == capacity`;
//! * `speedup_vs_requeue` — multiplexed over baseline (target >= 2x:
//!   the baseline rebuilds a full engine per attempt, so every eviction
//!   re-pays config cloning and store setup the multiplexed engine
//!   amortizes).

use spoton::config::ClusterCfg;
use spoton::metrics::RecordLevel;
use spoton::sched::{Job, RequeueScheduler};
use spoton::sim::experiment::Experiment;
use spoton::simclock::SimDuration;
use spoton::util::bench::{bench_fn, section, BenchReport};

const JOBS: usize = 1000;
const CAPACITY: u32 = 32;

/// The shared per-job scenario: short scaled stages so the bench stays
/// in the engine hot path, a storm mean well under the job length so
/// evictions (and therefore requeue attempts) genuinely happen, and the
/// lean `Counts` metrics level both engines use in sweeps.
fn base() -> Experiment {
    Experiment::table1()
        .named("cluster-bench")
        .scale_stages(0.01)
        .eviction_poisson(SimDuration::from_mins(6))
        .transparent(SimDuration::from_mins(5))
        .deadline(SimDuration::from_hours(4000))
        .metrics(RecordLevel::Counts)
}

fn main() -> anyhow::Result<()> {
    let mut report = BenchReport::new("cluster");
    report.value("jobs", JOBS as u64);
    report.value("capacity", CAPACITY as u64);

    section(&format!(
        "multiplexed cluster engine ({JOBS} jobs, capacity {CAPACITY})"
    ));
    let mut exp = base();
    exp.cfg.cluster =
        Some(ClusterCfg::with_count(JOBS).capacity(CAPACITY));
    // one untimed run for the workload-shape numbers
    let probe = exp.run_cluster_sleeper()?;
    assert_eq!(
        probe.completed_jobs(),
        JOBS,
        "bench scenario must complete: {}",
        probe.summary()
    );
    println!("  {}", probe.summary());
    let events = probe.events_processed;
    let stats = bench_fn(1, 3, || {
        std::hint::black_box(exp.run_cluster_sleeper().unwrap());
    });
    let events_per_sec = events as f64 / stats.mean.as_secs_f64();
    println!("  run          {stats}");
    println!(
        "  -> {:.2} Mevents/s sustained ({events} events per run)",
        events_per_sec / 1e6
    );
    report.stat("cluster.run_1000_jobs", &stats);
    report.value("cluster.events_processed", events);
    report.value("cluster.events_per_sec", events_per_sec);
    report.value(
        "cluster.queued_admissions",
        probe.queued_admissions() as u64,
    );

    section(&format!(
        "requeue-scheduler baseline ({JOBS} jobs, slots {CAPACITY})"
    ));
    // The pre-tentpole cluster idiom: `slots` concurrent jobs over a
    // shared fleet config — but every attempt deep-clones the scenario,
    // rebuilds the fleet (pool state resets between attempts) and spins
    // a fresh engine, which is exactly the setup cost the multiplexed
    // engine amortizes into one long-lived cluster.
    let job_exp = base();
    let mk_jobs = || -> Vec<Job> {
        (0..JOBS as u32)
            .map(|i| Job {
                id: i,
                name: format!("job-{i}"),
                experiment: job_exp.clone().seed(i as u64),
            })
            .collect()
    };
    let shared_fleet = spoton::config::FleetCfg {
        pools: vec![spoton::config::PoolCfg::named("pool-0").eviction(
            spoton::config::EvictionPlanCfg::Poisson {
                mean: SimDuration::from_mins(6),
            },
        )],
        placement: spoton::config::PlacementPolicyCfg::Sticky,
    };
    let sched = RequeueScheduler {
        requeue_delay: SimDuration::from_secs(300),
        max_attempts: 16,
        slots: CAPACITY,
        fleet: Some(shared_fleet),
    };
    let records = sched.run(mk_jobs())?;
    assert_eq!(records.len(), JOBS);
    assert!(
        records.iter().all(|r| r.completed),
        "baseline must complete the same workload"
    );
    let baseline = bench_fn(1, 3, || {
        std::hint::black_box(sched.run(mk_jobs()).unwrap());
    });
    println!("  run          {baseline}");
    report.stat("requeue.run_1000_jobs", &baseline);

    let speedup =
        baseline.mean.as_secs_f64() / stats.mean.as_secs_f64();
    println!(
        "\nmultiplexed vs requeue baseline: {:.2}x wall-clock \
         ({:?} vs {:?} mean)",
        speedup, stats.mean, baseline.mean
    );
    report.value("speedup_vs_requeue", speedup);

    report.write()?;
    Ok(())
}
