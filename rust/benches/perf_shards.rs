//! Sharded sweep runner throughput: the perf figure behind the
//! multi-process Monte Carlo tentpole.
//!
//! One plan (cluster scenario, `SEEDS` seeds × one config = `SEEDS`
//! cells in `SHARDS` shards) runs through `sim::shard::ShardRunner` at
//! P ∈ {1, 2, 4} worker processes — real `spoton sweep-worker` OS
//! processes over a fresh run directory each time — and the merged
//! digests are asserted byte-identical across every P before any number
//! is reported. Results land in `BENCH_shards.json`:
//!
//! * `procs_P.secs` / `procs_P.runs_per_sec` — best-of-2 wall-clock and
//!   aggregate sweep throughput at P workers;
//! * `speedup_4p_vs_1p` — the headline scaling figure (asserted >= 1.8x
//!   when the host actually has >= 4 cores; reported either way);
//! * `resume.one_shard_secs` — re-running exactly one lost shard out of
//!   `SHARDS` plus the re-merge (the checkpointed-progress payoff:
//!   interruption costs one shard, not the sweep);
//! * `resume.merge_only_secs` — a fully-complete resume (pure
//!   verify + merge, no simulation at all).

use spoton::config::ScenarioConfig;
use spoton::sim::shard::{artifact_path, SeedStream, ShardPlan, ShardRunner};
use spoton::util::bench::{section, BenchReport};
use std::time::Instant;

const SEEDS: usize = 32;
const SHARDS: usize = 8;

/// Each cell is a 24-job contended cluster run: enough engine work
/// (~tens of ms) that process-level parallelism, not spawn overhead,
/// dominates the wall-clock.
const SCENARIO: &str = r#"
name = "shard-bench"
deadline_mins = 240000

[workload]
kind = "sleeper"
ks = [33, 55]
stage_secs = [2, 3]

[eviction]
plan = "poisson"
mean_mins = 6

[checkpoint]
method = "transparent"
interval_mins = 5

[cluster]
jobs = 24
capacity = 8
"#;

fn tmp(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!(
        "spoton-shard-bench-{tag}-{}-{}",
        std::process::id(),
        spoton::util::next_seq()
    ))
}

fn main() -> anyhow::Result<()> {
    let cfg = ScenarioConfig::from_str_toml(SCENARIO)?;
    let plan = ShardPlan::new(
        "bench",
        SeedStream::contiguous(0, SEEDS),
        &["base".to_string()],
        &cfg,
        SCENARIO,
        SHARDS,
    )?;
    let exe = env!("CARGO_BIN_EXE_spoton");
    let cells = plan.cells();
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);

    let mut report = BenchReport::new("shards");
    report
        .value("cells", cells as u64)
        .value("shards", SHARDS as u64)
        .value("host_cores", cores as u64);

    let mut digests: Vec<String> = Vec::new();
    let mut best_secs: Vec<f64> = Vec::new();
    for procs in [1usize, 2, 4] {
        section(&format!(
            "sharded sweep: {cells} cells, {SHARDS} shards, {procs} proc(s)"
        ));
        let mut best = f64::INFINITY;
        let mut digest = String::new();
        for _rep in 0..2 {
            let dir = tmp(&format!("p{procs}"));
            let runner =
                ShardRunner::new(plan.clone(), &dir, exe).procs(procs);
            runner.init(SCENARIO)?;
            let t0 = Instant::now();
            let out = runner.run()?;
            let secs = t0.elapsed().as_secs_f64();
            assert!(out.dead_letter.is_empty(), "bench workers must not die");
            digest = out.merged.expect("bench sweep must complete").digest;
            best = best.min(secs);
            let _ = std::fs::remove_dir_all(&dir);
        }
        let rps = cells as f64 / best;
        println!("  best of 2: {best:.3}s  ->  {rps:.1} runs/sec");
        report
            .value(&format!("procs_{procs}.secs"), best)
            .value(&format!("procs_{procs}.runs_per_sec"), rps);
        digests.push(digest);
        best_secs.push(best);
    }

    // process count must be invisible in the output before any perf
    // number means anything
    assert!(
        digests.windows(2).all(|w| w[0] == w[1]),
        "merged digests diverged across process counts"
    );
    report.value("digest", digests[0].as_str());

    let speedup = best_secs[0] / best_secs[2];
    println!("\n4 procs vs 1: {speedup:.2}x ({cores} host cores)");
    report.value("speedup_4p_vs_1p", speedup);
    if cores >= 4 {
        assert!(
            speedup >= 1.8,
            "expected >= 1.8x at 4 procs on a {cores}-core host, \
             got {speedup:.2}x"
        );
    } else {
        println!("  (floor not asserted on a {cores}-core host)");
    }

    section("resume: one lost shard vs merge-only");
    let dir = tmp("resume");
    let runner = ShardRunner::new(plan.clone(), &dir, exe).procs(2);
    runner.init(SCENARIO)?;
    runner.run()?.merged.expect("seed run must complete");
    std::fs::remove_file(artifact_path(&dir, SHARDS - 1))?;
    let t0 = Instant::now();
    let out = runner.run()?;
    let one_shard = t0.elapsed().as_secs_f64();
    assert_eq!(out.ran, vec![SHARDS - 1], "exactly the lost shard re-runs");
    assert_eq!(out.reused.len(), SHARDS - 1);
    let resumed = out.merged.expect("resume must complete");
    assert_eq!(resumed.digest, digests[0], "resume changed the digest");
    let t0 = Instant::now();
    let out = runner.run()?;
    let merge_only = t0.elapsed().as_secs_f64();
    assert!(out.ran.is_empty(), "nothing should re-run when complete");
    println!(
        "  one shard: {one_shard:.3}s   merge-only: {merge_only:.3}s   \
         (full sweep at 2 procs: {:.3}s)",
        best_secs[1]
    );
    report
        .value("resume.one_shard_secs", one_shard)
        .value("resume.merge_only_secs", merge_only);
    let _ = std::fs::remove_dir_all(&dir);

    report.write()?;
    Ok(())
}
