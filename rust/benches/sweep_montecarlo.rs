//! Monte Carlo sweep throughput: thousands of seeded Table-I runs.
//!
//! Reproduction-scale evaluation needs distributions, not point
//! estimates, so this bench measures the population path end to end:
//!
//! * single-run baselines — the same Table-I row once with full metrics
//!   (the `perf_hotpath` "row-per-run" baseline) and once on the sweep's
//!   lean per-run configuration (`RecordLevel::Counts` + queue/buffer
//!   optimizations);
//! * the parallel sweep — `SWEEP_RUNS` seeded runs (default 10,000)
//!   fanned over `SWEEP_THREADS` workers, reporting aggregate
//!   simulated-runs/s and the per-run mean;
//! * a Poisson eviction sweep whose merged population feeds the
//!   `report::distribution` summaries, with a digest spot-check that the
//!   merge is byte-identical across thread counts.
//!
//! Results land in `BENCH_sweep.json` (see `util::bench::BenchReport`).

use spoton::metrics::RecordLevel;
use spoton::report::distribution;
use spoton::sim::experiment::Experiment;
use spoton::sim::sweep::run_digest;
use spoton::simclock::SimDuration;
use spoton::util::bench::{bench_fn, section, BenchReport};
use std::time::Instant;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let runs = env_usize("SWEEP_RUNS", 10_000);
    let threads = env_usize(
        "SWEEP_THREADS",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    );
    let mut report = BenchReport::new("sweep");
    report.value("runs", runs as u64).value("threads", threads as u64);

    // The perf_hotpath "row-per-run" scenario: Table I row-5 analog.
    let row = Experiment::table1()
        .named("mc-row5")
        .eviction_every(SimDuration::from_mins(60))
        .transparent(SimDuration::from_mins(15));

    section("single run, full metrics (perf_hotpath row-per-run baseline)");
    let full_exp = row.clone();
    let full = bench_fn(2, 20, || {
        std::hint::black_box(full_exp.run_sleeper().unwrap());
    });
    println!("  row-per-run       {full}");
    report.stat("single.row_per_run_full", &full);

    section("single run, lean sweep config (Counts level)");
    let lean_exp = row.clone().metrics(RecordLevel::Counts);
    let lean = bench_fn(2, 20, || {
        std::hint::black_box(lean_exp.run_sleeper().unwrap());
    });
    println!("  row-per-run lean  {lean}");
    report.stat("single.row_per_run_lean", &lean);

    // The honest per-run comparison is single-thread vs single-thread:
    // lean (Counts level + queue/buffer optimizations) against the full
    // row-per-run baseline. Thread fan-out must not be allowed to mask a
    // per-run regression in the tracked trajectory.
    let per_run_speedup =
        full.mean.as_nanos() as f64 / (lean.mean.as_nanos().max(1) as f64);
    println!(
        "  per-run mean (lean) vs row-per-run baseline: {per_run_speedup:.2}x"
    );
    report.value("single.per_run_speedup_vs_full", per_run_speedup);

    section("parallel sweep (fixed-eviction Table-I row)");
    let sweep = row.sweep().seed_range(0, runs).threads(threads);
    let t0 = Instant::now();
    let merged = sweep.run()?;
    let wall = t0.elapsed();
    let completed = merged.iter().filter(|r| r.result.completed).count();
    // wall/run at N threads: the aggregate throughput number, NOT a
    // per-run cost (that's single.row_per_run_lean above).
    let wall_per_run_ns = wall.as_nanos() as u64 / (runs.max(1) as u64);
    let runs_per_sec = runs as f64 / wall.as_secs_f64();
    let parallel_speedup =
        lean.mean.as_nanos() as f64 / (wall_per_run_ns.max(1) as f64);
    println!(
        "  {runs} runs on {threads} thread(s): {wall:.3?} wall, \
         {runs_per_sec:.1} simulated-runs/s, {wall_per_run_ns} ns wall/run \
         ({completed} completed)"
    );
    println!(
        "  thread-level speedup vs lean single run: {parallel_speedup:.2}x"
    );
    report
        .value("sweep.wall_ns", wall.as_nanos() as u64)
        .value("sweep.runs_per_sec", runs_per_sec)
        .value("sweep.wall_per_run_ns", wall_per_run_ns)
        .value("sweep.completed", completed as u64)
        .value("sweep.parallel_speedup_vs_lean", parallel_speedup);
    drop(merged);

    section("Poisson eviction sweep -> distribution summary");
    let poisson = Experiment::table1()
        .named("mc-poisson75")
        .eviction_poisson(SimDuration::from_mins(75))
        .transparent(SimDuration::from_mins(15));
    let n_dist = runs.min(2000);
    let t0 = Instant::now();
    let merged = poisson.sweep().seed_range(0, n_dist).threads(threads).run()?;
    let wall = t0.elapsed();
    let dist = distribution::summarize("mc-poisson75", &merged);
    println!(
        "  {n_dist} runs in {wall:.3?} ({:.1} runs/s)",
        n_dist as f64 / wall.as_secs_f64()
    );
    print!("{}", distribution::render(&dist));
    report.value("poisson.distributions", dist.to_json());
    report.value(
        "poisson.runs_per_sec",
        n_dist as f64 / wall.as_secs_f64(),
    );

    section("adaptive interval controllers (BENCH_policy.json)");
    // The policy/ subsystem on the sweep path: the same storm once per
    // controller, reporting per-controller throughput and distribution
    // summaries to a separate BENCH_policy.json payload.
    let mut policy_report = BenchReport::new("policy");
    let n_policy = runs.min(1000);
    policy_report
        .value("runs", n_policy as u64)
        .value("threads", threads as u64);
    let storm = Experiment::table1()
        .named("mc-adaptive")
        .eviction_poisson(SimDuration::from_mins(35))
        .transparent(SimDuration::from_mins(30))
        .notice(SimDuration::from_secs(10))
        .deadline(SimDuration::from_hours(30));
    let controllers = [
        spoton::config::IntervalControllerCfg::Fixed,
        spoton::config::IntervalControllerCfg::young_daly(),
        spoton::config::IntervalControllerCfg::cost_aware(1.0),
    ];
    for cfg in &controllers {
        let label = cfg.label();
        let sweep = storm
            .clone()
            .adaptive(cfg.clone())
            .sweep()
            .seed_range(0, n_policy)
            .threads(threads);
        let t0 = Instant::now();
        let merged = sweep.run()?;
        let wall = t0.elapsed();
        let dist = distribution::summarize(&label, &merged);
        println!(
            "  {label:<14} {n_policy} runs in {wall:.3?} ({:.1} runs/s), \
             cost mean ${:.4}, makespan p95 {:.0}s",
            n_policy as f64 / wall.as_secs_f64(),
            dist.total_cost.mean,
            dist.makespan_secs.p95,
        );
        let key = label.replace('/', "_");
        policy_report
            .value(format!("{key}.runs_per_sec").as_str(),
                   n_policy as f64 / wall.as_secs_f64())
            .value(format!("{key}.distributions").as_str(), dist.to_json());
    }
    // adaptive sweeps must stay thread-invariant like everything else
    let check = storm
        .clone()
        .adaptive(spoton::config::IntervalControllerCfg::young_daly())
        .sweep()
        .seed_range(0, runs.min(100));
    let a = check.clone().threads(1).run()?;
    let b = check.clone().threads(threads.max(2)).run()?;
    assert!(
        a.iter().zip(&b).all(|(x, y)| {
            x.seed == y.seed && run_digest(&x.result) == run_digest(&y.result)
        }),
        "adaptive sweep diverged across thread counts"
    );
    println!("  young-daly digests byte-identical across thread counts: ok");
    policy_report.write()?;

    section("merge determinism spot check (threads = 1 vs sweep threads)");
    let n_check = runs.min(200);
    let base = poisson.sweep().seed_range(0, n_check);
    let a = base.clone().threads(1).run()?;
    let b = base.clone().threads(threads.max(2)).run()?;
    let identical = a
        .iter()
        .zip(&b)
        .all(|(x, y)| {
            x.seed == y.seed && run_digest(&x.result) == run_digest(&y.result)
        });
    assert!(identical, "merged sweep output diverged across thread counts");
    println!("  {n_check} seeds byte-identical across thread counts: ok");
    report.value("determinism.checked_seeds", n_check as u64);

    report.write()?;
    Ok(())
}
