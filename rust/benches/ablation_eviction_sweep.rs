//! Ablation: eviction-interval sweep (paper §IV's closing claim).
//!
//! "Naturally, had eviction time interval been shorter, the percentage of
//! time and cost saved by running metaSPAdes with Spot-On transparent
//! checkpointing on Spot Instances would increase further."
//!
//! Sweeps the injected eviction interval and reports app-native vs
//! transparent totals + the transparent advantage, which must widen
//! monotonically (modulo milestone-alignment luck) as evictions become
//! more frequent.

use spoton::report::table::TextTable;
use spoton::sim::experiment::Experiment;
use spoton::simclock::SimDuration;

fn main() -> anyhow::Result<()> {
    let intervals_min = [120u64, 90, 60, 45, 30];
    let mut t = TextTable::new(&[
        "Eviction interval",
        "Application",
        "Transparent 15m",
        "Transparent saving",
        "App evictions",
        "Transparent evictions",
    ]);
    let mut savings = Vec::new();
    for mins in intervals_min {
        let app = Experiment::table1()
            .named("app")
            .eviction_every(SimDuration::from_mins(mins))
            .app_native()
            .deadline(SimDuration::from_hours(24))
            .run_sleeper()?;
        let tr = Experiment::table1()
            .named("tr")
            .eviction_every(SimDuration::from_mins(mins))
            .transparent(SimDuration::from_mins(15))
            .deadline(SimDuration::from_hours(24))
            .run_sleeper()?;
        let saving = if app.completed {
            1.0 - tr.total.as_millis() as f64 / app.total.as_millis() as f64
        } else {
            1.0
        };
        savings.push((mins, saving, app.completed));
        t.row(&[
            format!("every {mins} min"),
            if app.completed { app.total.hms() } else { "DNF".into() },
            tr.total.hms(),
            format!("{:.1}%", saving * 100.0),
            app.evictions.to_string(),
            tr.evictions.to_string(),
        ]);
        assert!(tr.completed, "transparent must always complete");
    }
    println!("\nAblation — eviction interval sweep (sleeper calibration)\n");
    print!("{}", t.render());

    // Claim check: advantage at the most frequent interval must exceed
    // the advantage at the least frequent one.
    let first = savings.first().unwrap().1;
    let last = savings.last().unwrap().1;
    println!(
        "\ntransparent saving grows from {:.1}% (120min) to {:.1}% (30min)",
        first * 100.0,
        last * 100.0
    );
    assert!(
        last > first,
        "transparent advantage must widen with eviction frequency"
    );
    println!("eviction-sweep shape check PASSED");
    Ok(())
}
