//! Ablation: coordinator overhead (paper §III-C rows 1–2: "Spot-on
//! introduces little overhead") and the periodic-checkpoint-interval
//! trade-off (more frequent dumps = more freeze pauses but less lost work
//! per eviction).

use spoton::report::table::TextTable;
use spoton::sim::experiment::Experiment;
use spoton::simclock::SimDuration;

fn main() -> anyhow::Result<()> {
    // 1. coordinator attach overhead
    let off = Experiment::table1().spoton_off().run_sleeper()?;
    let on = Experiment::table1().run_sleeper()?;
    println!("\nAblation — coordinator overhead (no evictions, no ckpts)\n");
    println!("  Spot-on OFF: {}", off.total.hms());
    println!("  Spot-on ON : {}", on.total.hms());
    let ratio =
        on.total.as_millis() as f64 / off.total.as_millis() as f64 - 1.0;
    println!(
        "  overhead: {:.2}% (paper rows 1-2: {:.2}%)",
        ratio * 100.0,
        (11132.0 / 11006.0 - 1.0) * 100.0
    );
    assert!(ratio < 0.03);

    // 2. periodic interval trade-off under fixed evictions
    let mut t = TextTable::new(&[
        "Ckpt interval",
        "Total",
        "Periodic ckpts",
        "Steps lost",
        "vs baseline",
    ]);
    println!(
        "\nAblation — transparent checkpoint interval (evictions every \
         60 min, 5 s notice so termination ckpts fail and periodic \
         spacing is what matters)\n"
    );
    let mut totals = Vec::new();
    for mins in [5u64, 10, 15, 30, 60, 120] {
        let r = Experiment::table1()
            .named("interval-sweep")
            .eviction_every(SimDuration::from_mins(60))
            .transparent(SimDuration::from_mins(mins))
            .notice(SimDuration::from_secs(5))
            .deadline(SimDuration::from_hours(24))
            .run_sleeper()?;
        // An interval sparser than the eviction period can never commit a
        // checkpoint before the instance dies: the run starves (paper
        // section IV) and is reported as DNF.
        assert_eq!(r.completed, mins < 60, "interval {mins}min");
        let delta =
            r.total.as_millis() as f64 / off.total.as_millis() as f64 - 1.0;
        totals.push((mins, r.total));
        t.row(&[
            format!("{mins} min"),
            if r.completed { r.total.hms() } else { "DNF".into() },
            r.periodic_ckpts.to_string(),
            r.lost_steps.to_string(),
            format!("{:+.1}%", delta * 100.0),
        ]);
    }
    print!("{}", t.render());

    // Shape: very sparse checkpointing (120m > eviction interval) must be
    // worse than a sensible interval (15m).
    let t15 = totals.iter().find(|(m, _)| *m == 15).unwrap().1;
    let t120 = totals.iter().find(|(m, _)| *m == 120).unwrap().1;
    assert!(
        t120 > t15,
        "checkpointing sparser than the eviction interval must cost time"
    );
    println!("\noverhead/interval shape checks PASSED");
    Ok(())
}
