//! Bench: reproduce **Fig 2** — cost of on-demand vs checkpoint-protected
//! spot execution.
//!
//! Paper claims: checkpoint-protected spot saves ~77% over on-demand from
//! the price cut alone (D8s_v3: $0.38/h vs $0.076/h, minus checkpoint
//! overheads and the NFS share), and transparent checkpointing pushes
//! savings "up to 86%" against the most expensive protected on-demand
//! comparator.

use spoton::report::figures::render_fig2;
use spoton::sim::experiment::Experiment;
use spoton::simclock::SimDuration;

fn main() -> anyhow::Result<()> {
    // Fig 2 is a cost model over the Table I runs; the sleeper workload
    // reproduces the identical timing/billing maths at a fraction of the
    // wall time (set SPOTON_BENCH_WORKLOAD=minimeta to run the full stack).
    let use_minimeta = std::env::var("SPOTON_BENCH_WORKLOAD")
        .map(|v| v == "minimeta")
        .unwrap_or(false);
    let rt = if use_minimeta {
        Some(std::rc::Rc::new(std::cell::RefCell::new(
            spoton::runtime::Runtime::load(
                &spoton::runtime::default_artifacts_dir(),
            )?,
        )))
    } else {
        None
    };
    let run = |e: Experiment| -> anyhow::Result<_> {
        Ok(match &rt {
            Some(rt) => e.run_minimeta(rt.clone())?,
            None => e.run_sleeper()?,
        })
    };

    let ondemand = run(Experiment::table1()
        .named("on-demand baseline")
        .spoton_off()
        .ondemand())?;
    let app90 = run(Experiment::table1()
        .named("spot + application, evict 90m")
        .eviction_every(SimDuration::from_mins(90))
        .app_native())?;
    let app60 = run(Experiment::table1()
        .named("spot + application, evict 60m")
        .eviction_every(SimDuration::from_mins(60))
        .app_native())?;
    let tr90 = run(Experiment::table1()
        .named("spot + transparent 30m, evict 90m")
        .eviction_every(SimDuration::from_mins(90))
        .transparent(SimDuration::from_mins(30)))?;
    let tr60 = run(Experiment::table1()
        .named("spot + transparent 30m, evict 60m")
        .eviction_every(SimDuration::from_mins(60))
        .transparent(SimDuration::from_mins(30)))?;

    print!(
        "{}",
        render_fig2(&[
            ("on-demand (no ckpt)", &ondemand),
            ("spot + app ckpt, evict 90m", &app90),
            ("spot + app ckpt, evict 60m", &app60),
            ("spot + transparent 30m, evict 90m", &tr90),
            ("spot + transparent 30m, evict 60m", &tr60),
        ])
    );

    // Headline claims.
    let save_spot = 1.0 - tr90.total_cost() / ondemand.total_cost();
    println!(
        "\nspot+transparent vs on-demand saving: {:.1}% (paper: 77%+, \
         \"up to 86%\")",
        save_spot * 100.0
    );
    // The paper's strongest comparator: the longest (most expensive)
    // protected run priced on-demand vs transparent on spot.
    let worst_ondemand_cost = app60.total.as_hours_f64() * 0.38;
    let save_max = 1.0 - tr60.total_cost() / worst_ondemand_cost;
    println!(
        "transparent-spot vs app-ckpt-on-demand saving: {:.1}% (paper: up \
         to 86%)",
        save_max * 100.0
    );
    assert!(save_spot > 0.70, "headline spot saving out of band");
    assert!(save_max > 0.78, "max saving out of band");
    println!("fig2 shape checks PASSED");
    Ok(())
}
