//! Run instrumentation: timelines and stage timers.
//!
//! Every experiment run produces a [`Timeline`] (ordered record of
//! launches, checkpoints, notices, evictions, restores, stage
//! completions) and a [`StageTimes`] accumulator whose per-stage *wall*
//! durations — including interruptions, restores and re-done work — are
//! exactly what the paper's Table I reports per k.
//!
//! Recording is gated by a [`RecordLevel`]: at [`RecordLevel::Full`]
//! (the default) every event is kept with its detail string; at
//! [`RecordLevel::Counts`] the timeline keeps only per-kind counters —
//! no event `Vec` growth, no detail `String` allocation, no debug-log
//! formatting — which is what lets the Monte Carlo sweep driver
//! ([`crate::sim::sweep`]) run thousands of seeded experiments per
//! second. Use [`Timeline::record_with`] on hot paths so the detail
//! closure is never even called at the reduced level.

use crate::simclock::{SimDuration, SimTime};
use std::borrow::Cow;
use std::fmt;

/// What happened.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    InstanceLaunch,
    RestoreFromCheckpoint,
    CheckpointCommitted,
    CheckpointFailed,
    EvictionNotice,
    InstanceEvicted,
    /// A replacement was requested from the fleet (multi-pool runs).
    ReplacementRequested,
    /// The placement policy picked the replacement's pool (multi-pool
    /// runs; detail names the pool).
    PlacementDecided,
    /// A pool's traced spot price moved (detail names the pool and the
    /// old/new hourly price).
    PoolPriceChanged,
    StageComplete,
    WorkloadDone,
    Aborted,
    // --- job-queue events (the requeue scheduler's cluster timeline) ---
    JobSubmitted,
    JobStarted,
    JobRequeued,
    JobFinished,
    // --- cluster-engine admission events (the multiplexed cluster's
    //     shared timeline; see `crate::sim::cluster`) ---
    /// A job could not start because its chosen pool was at capacity.
    CapacityExhausted,
    /// A job entered the FIFO-per-priority admission queue.
    JobQueued,
    /// A previously queued job was admitted to a freed slot.
    JobAdmitted,
    // --- chaos + degradation accounting (see `crate::sim::chaos`).
    //     When adding a variant, append it here AND at the end of
    //     [`EventKind::ALL`] — the exhaustive match in
    //     `tests::kind_indices_are_dense` refuses to compile until every
    //     variant is listed, which keeps the per-kind counter array
    //     correctly sized. Appending (never inserting) keeps existing
    //     discriminants — and thereby digests — stable. ---
    /// An injected checkpoint-write failure (storage chaos).
    ChaosWriteFault,
    /// An injected torn write: half the object landed, then the
    /// connection died.
    ChaosTornWrite,
    /// An injected storage latency spike on a successful write.
    ChaosLatencySpike,
    /// A snapshot was silently corrupted in storage (caught later by
    /// restore-time manifest verification).
    ChaosCorruption,
    /// A coordinated eviction storm fired across every pool.
    ChaosStorm,
    /// The IMDS scheduled-events endpoint went dark (first poll to
    /// notice an outage window).
    ImdsOutage,
    /// A poll ran against a dark endpoint; the monitor degraded to the
    /// slower cadence instead of silently losing the notice.
    PollDegraded,
    /// A failed checkpoint commit was retried under the backoff policy.
    CkptRetried,
    /// Restore skipped an unverifiable generation and fell back to an
    /// older one.
    RestoreFallback,
    /// Restore exhausted every retained generation without finding a
    /// verifiable one (the run restarts from scratch).
    UnrecoveredRestore,
    // --- bid-aware market + autoscale events (see `crate::autoscale`).
    //     Digest-gated like the chaos kinds: bid-less runs keep their
    //     pre-bid digests byte for byte. ---
    /// A traced price epoch crossed a live instance's bid: the market
    /// reclaims the instance (notice fires from the crossing; billing
    /// stops at the crossing boundary).
    PoolOutbid,
    /// A job with a `[job] deadline_mins` SLA finished (or aborted) past
    /// its deadline.
    DeadlineMissed,
    /// The autoscaler overrode the placement policy to shift a job
    /// between spot pools and the on-demand fallback (detail names the
    /// reason and the target pool).
    AutoscaleShift,
}

/// Number of [`EventKind`] variants (sizes the per-kind counter array).
const N_KINDS: usize = EventKind::ALL.len();

impl EventKind {
    /// Every variant, in discriminant order.
    pub const ALL: [EventKind; 32] = [
        EventKind::InstanceLaunch,
        EventKind::RestoreFromCheckpoint,
        EventKind::CheckpointCommitted,
        EventKind::CheckpointFailed,
        EventKind::EvictionNotice,
        EventKind::InstanceEvicted,
        EventKind::ReplacementRequested,
        EventKind::PlacementDecided,
        EventKind::PoolPriceChanged,
        EventKind::StageComplete,
        EventKind::WorkloadDone,
        EventKind::Aborted,
        EventKind::JobSubmitted,
        EventKind::JobStarted,
        EventKind::JobRequeued,
        EventKind::JobFinished,
        EventKind::CapacityExhausted,
        EventKind::JobQueued,
        EventKind::JobAdmitted,
        EventKind::ChaosWriteFault,
        EventKind::ChaosTornWrite,
        EventKind::ChaosLatencySpike,
        EventKind::ChaosCorruption,
        EventKind::ChaosStorm,
        EventKind::ImdsOutage,
        EventKind::PollDegraded,
        EventKind::CkptRetried,
        EventKind::RestoreFallback,
        EventKind::UnrecoveredRestore,
        EventKind::PoolOutbid,
        EventKind::DeadlineMissed,
        EventKind::AutoscaleShift,
    ];

    /// The chaos/degradation kinds appended by the fault-injection
    /// subsystem. Digest writers skip these when their count is zero so
    /// chaos-free runs produce byte-identical digests to pre-chaos
    /// builds.
    pub fn is_chaos(self) -> bool {
        matches!(
            self,
            EventKind::ChaosWriteFault
                | EventKind::ChaosTornWrite
                | EventKind::ChaosLatencySpike
                | EventKind::ChaosCorruption
                | EventKind::ChaosStorm
                | EventKind::ImdsOutage
                | EventKind::PollDegraded
                | EventKind::CkptRetried
                | EventKind::RestoreFallback
                | EventKind::UnrecoveredRestore
        )
    }

    /// Kinds whose zero counts are *omitted* from run/cluster digests:
    /// the chaos kinds plus the bid/autoscale kinds. Gating on observed
    /// counts keeps digests of runs that never see these events
    /// byte-identical to digests minted before the kinds existed, while
    /// any injected fault / outbid / missed deadline still lands in the
    /// digest.
    pub fn is_digest_gated(self) -> bool {
        self.is_chaos()
            || matches!(
                self,
                EventKind::PoolOutbid
                    | EventKind::DeadlineMissed
                    | EventKind::AutoscaleShift
            )
    }

    pub fn as_str(self) -> &'static str {
        match self {
            EventKind::InstanceLaunch => "launch",
            EventKind::RestoreFromCheckpoint => "restore",
            EventKind::CheckpointCommitted => "ckpt",
            EventKind::CheckpointFailed => "ckpt-failed",
            EventKind::EvictionNotice => "notice",
            EventKind::InstanceEvicted => "evicted",
            EventKind::ReplacementRequested => "replace-req",
            EventKind::PlacementDecided => "placement",
            EventKind::PoolPriceChanged => "price",
            EventKind::StageComplete => "stage-done",
            EventKind::WorkloadDone => "done",
            EventKind::Aborted => "aborted",
            EventKind::JobSubmitted => "job-submitted",
            EventKind::JobStarted => "job-started",
            EventKind::JobRequeued => "job-requeued",
            EventKind::JobFinished => "job-finished",
            EventKind::CapacityExhausted => "capacity-exhausted",
            EventKind::JobQueued => "job-queued",
            EventKind::JobAdmitted => "job-admitted",
            EventKind::ChaosWriteFault => "chaos-write-fault",
            EventKind::ChaosTornWrite => "chaos-torn-write",
            EventKind::ChaosLatencySpike => "chaos-latency",
            EventKind::ChaosCorruption => "chaos-corrupt",
            EventKind::ChaosStorm => "chaos-storm",
            EventKind::ImdsOutage => "imds-outage",
            EventKind::PollDegraded => "poll-degraded",
            EventKind::CkptRetried => "ckpt-retried",
            EventKind::RestoreFallback => "restore-fallback",
            EventKind::UnrecoveredRestore => "restore-unrecovered",
            EventKind::PoolOutbid => "outbid",
            EventKind::DeadlineMissed => "deadline-missed",
            EventKind::AutoscaleShift => "autoscale",
        }
    }
}

/// How much the timeline records per event.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RecordLevel {
    /// Every event with its detail string (the default; what reports,
    /// examples and the equivalence suite consume).
    #[default]
    Full,
    /// Per-kind counters only: `Timeline::count` still works, but no
    /// event records or detail strings are kept. The sweep hot path.
    Counts,
}

/// One timeline record.
#[derive(Debug, Clone)]
pub struct TimelineEvent {
    pub at: SimTime,
    pub kind: EventKind,
    /// Borrowed for the fixed messages, owned for formatted ones — no
    /// allocation when the detail is a static literal.
    pub detail: Cow<'static, str>,
}

/// Ordered event record for one run.
#[derive(Debug, Clone, Default)]
pub struct Timeline {
    level: RecordLevel,
    events: Vec<TimelineEvent>,
    counts: [u32; N_KINDS],
}

impl Timeline {
    pub fn new() -> Self {
        Self::default()
    }

    /// A timeline recording at the given level.
    pub fn with_level(level: RecordLevel) -> Self {
        Self { level, ..Self::default() }
    }

    pub fn level(&self) -> RecordLevel {
        self.level
    }

    /// Record an event whose detail is already built (or free: a static
    /// literal, or a `String` that exists anyway). For details that need
    /// a `format!`, prefer [`Timeline::record_with`].
    pub fn record(
        &mut self,
        at: SimTime,
        kind: EventKind,
        detail: impl Into<Cow<'static, str>>,
    ) {
        self.counts[kind as usize] += 1;
        if self.level == RecordLevel::Full {
            let detail = detail.into();
            log::debug!("{at:?} {}: {detail}", kind.as_str());
            self.events.push(TimelineEvent { at, kind, detail });
        }
    }

    /// Record an event with a lazily-built detail: the closure runs only
    /// at [`RecordLevel::Full`], so reduced-level runs skip the `format!`
    /// allocation entirely.
    pub fn record_with<F: FnOnce() -> String>(
        &mut self,
        at: SimTime,
        kind: EventKind,
        detail: F,
    ) {
        self.counts[kind as usize] += 1;
        if self.level == RecordLevel::Full {
            let detail = detail();
            log::debug!("{at:?} {}: {detail}", kind.as_str());
            self.events.push(TimelineEvent {
                at,
                kind,
                detail: Cow::Owned(detail),
            });
        }
    }

    /// Recorded events (empty at [`RecordLevel::Counts`]).
    pub fn events(&self) -> &[TimelineEvent] {
        &self.events
    }

    /// How many events of `kind` were recorded. Counted at every level.
    pub fn count(&self, kind: EventKind) -> usize {
        self.counts[kind as usize] as usize
    }

    /// Events are recorded in nondecreasing time order (asserted by
    /// tests; the DES must never reorder).
    pub fn is_monotone(&self) -> bool {
        self.events.windows(2).all(|w| w[0].at <= w[1].at)
    }
}

impl fmt::Display for Timeline {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for e in &self.events {
            writeln!(
                f,
                "  {:>10} {:<12} {}",
                format!("{:?}", e.at),
                e.kind.as_str(),
                e.detail
            )?;
        }
        Ok(())
    }
}

/// Per-stage wall-duration accumulator.
#[derive(Debug, Clone, Default)]
pub struct StageTimes {
    /// (label, wall duration) per completed stage, in completion order.
    completed: Vec<(String, SimDuration)>,
    current_started: Option<SimTime>,
}

impl StageTimes {
    pub fn new() -> Self {
        Self::default()
    }

    /// Call when a stage begins (first launch and after each stage ends).
    pub fn stage_started(&mut self, at: SimTime) {
        self.current_started = Some(at);
    }

    /// Call when a stage completes; records its wall duration.
    pub fn stage_completed(&mut self, label: &str, at: SimTime) {
        let started = self
            .current_started
            // spoton-lint: allow(D3, reason = "recorder pairs every stage_completed with a stage_started")
            .expect("stage_completed without stage_started");
        self.completed.push((label.to_string(), at.since(started)));
        self.current_started = Some(at);
    }

    pub fn completed(&self) -> &[(String, SimDuration)] {
        &self.completed
    }

    pub fn total(&self) -> SimDuration {
        self.completed
            .iter()
            .fold(SimDuration::ZERO, |acc, (_, d)| acc + *d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timeline_counts_and_order() {
        let mut t = Timeline::new();
        t.record(SimTime::from_secs(1), EventKind::InstanceLaunch, "vm-0");
        t.record(SimTime::from_secs(5), EventKind::CheckpointCommitted, "id 0");
        t.record(SimTime::from_secs(5), EventKind::EvictionNotice, "evt-1");
        t.record(SimTime::from_secs(9), EventKind::InstanceEvicted, "vm-0");
        assert_eq!(t.count(EventKind::CheckpointCommitted), 1);
        assert_eq!(t.count(EventKind::EvictionNotice), 1);
        assert_eq!(t.count(EventKind::Aborted), 0);
        assert!(t.is_monotone());
        let s = t.to_string();
        assert!(s.contains("notice"));
    }

    #[test]
    fn kind_indices_are_dense() {
        // Every variant's discriminant indexes the counter array; the
        // exhaustive match below breaks the build when a variant is
        // added without extending EventKind::ALL (and thereby N_KINDS).
        let mut t = Timeline::new();
        for (i, &k) in EventKind::ALL.iter().enumerate() {
            assert_eq!(k as usize, i, "{}", k.as_str());
            t.record(SimTime::from_secs(i as u64), k, "x");
            assert_eq!(t.count(k), 1, "{}", k.as_str());
            match k {
                EventKind::InstanceLaunch
                | EventKind::RestoreFromCheckpoint
                | EventKind::CheckpointCommitted
                | EventKind::CheckpointFailed
                | EventKind::EvictionNotice
                | EventKind::InstanceEvicted
                | EventKind::ReplacementRequested
                | EventKind::PlacementDecided
                | EventKind::PoolPriceChanged
                | EventKind::StageComplete
                | EventKind::WorkloadDone
                | EventKind::Aborted
                | EventKind::JobSubmitted
                | EventKind::JobStarted
                | EventKind::JobRequeued
                | EventKind::JobFinished
                | EventKind::CapacityExhausted
                | EventKind::JobQueued
                | EventKind::JobAdmitted
                | EventKind::ChaosWriteFault
                | EventKind::ChaosTornWrite
                | EventKind::ChaosLatencySpike
                | EventKind::ChaosCorruption
                | EventKind::ChaosStorm
                | EventKind::ImdsOutage
                | EventKind::PollDegraded
                | EventKind::CkptRetried
                | EventKind::RestoreFallback
                | EventKind::UnrecoveredRestore
                | EventKind::PoolOutbid
                | EventKind::DeadlineMissed
                | EventKind::AutoscaleShift => {}
            }
        }
        assert_eq!(t.events().len(), EventKind::ALL.len());
    }

    #[test]
    fn gated_kinds_are_a_contiguous_tail() {
        // the digest writers rely on every digest-gated kind (chaos +
        // bid/autoscale) sorting after every ungated kind, so skipping
        // zero-count gated kinds reproduces the pre-gating digest byte
        // for byte
        let first_gated = EventKind::ALL
            .iter()
            .position(|k| k.is_digest_gated())
            .expect("gated kinds exist");
        for (i, k) in EventKind::ALL.iter().enumerate() {
            assert_eq!(k.is_digest_gated(), i >= first_gated, "{}", k.as_str());
            // every chaos kind is digest-gated (chaos ⊆ gated)
            if k.is_chaos() {
                assert!(k.is_digest_gated(), "{}", k.as_str());
            }
        }
        assert_eq!(first_gated, 19, "ungated kind count is pinned");
    }

    #[test]
    fn counts_level_keeps_counters_but_no_events() {
        let mut t = Timeline::with_level(RecordLevel::Counts);
        let mut detail_built = false;
        t.record(SimTime::from_secs(1), EventKind::InstanceLaunch, "vm-0");
        t.record_with(SimTime::from_secs(2), EventKind::EvictionNotice, || {
            detail_built = true;
            "expensive".to_string()
        });
        assert_eq!(t.count(EventKind::InstanceLaunch), 1);
        assert_eq!(t.count(EventKind::EvictionNotice), 1);
        assert!(t.events().is_empty(), "Counts level must not keep events");
        assert!(!detail_built, "detail closure must not run at Counts level");
        assert!(t.is_monotone());
    }

    #[test]
    fn full_level_evaluates_lazy_detail() {
        let mut t = Timeline::new();
        t.record_with(SimTime::from_secs(3), EventKind::Aborted, || {
            format!("deadline {}", 42)
        });
        assert_eq!(t.events().len(), 1);
        assert_eq!(t.events()[0].detail, "deadline 42");
        assert_eq!(t.count(EventKind::Aborted), 1);
    }

    #[test]
    fn stage_times_accumulate_wall_durations() {
        let mut s = StageTimes::new();
        s.stage_started(SimTime::from_secs(0));
        s.stage_completed("K33", SimTime::from_secs(2030));
        // interruption inside K55 still lands in K55's wall time
        s.stage_completed("K55", SimTime::from_secs(2030 + 2333 + 600));
        assert_eq!(s.completed()[0].1.as_secs(), 2030);
        assert_eq!(s.completed()[1].1.as_secs(), 2933);
        assert_eq!(s.total().as_secs(), 2030 + 2933);
    }

    #[test]
    #[should_panic(expected = "without stage_started")]
    fn stage_completed_requires_start() {
        let mut s = StageTimes::new();
        s.stage_completed("K33", SimTime::from_secs(1));
    }
}
