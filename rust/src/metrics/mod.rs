//! Run instrumentation: timelines and stage timers.
//!
//! Every experiment run produces a [`Timeline`] (ordered record of
//! launches, checkpoints, notices, evictions, restores, stage
//! completions) and a [`StageTimes`] accumulator whose per-stage *wall*
//! durations — including interruptions, restores and re-done work — are
//! exactly what the paper's Table I reports per k.

use crate::simclock::{SimDuration, SimTime};
use std::fmt;

/// What happened.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    InstanceLaunch,
    RestoreFromCheckpoint,
    CheckpointCommitted,
    CheckpointFailed,
    EvictionNotice,
    InstanceEvicted,
    /// A replacement was requested from the fleet (multi-pool runs).
    ReplacementRequested,
    /// The placement policy picked the replacement's pool (multi-pool
    /// runs; detail names the pool).
    PlacementDecided,
    StageComplete,
    WorkloadDone,
    Aborted,
    // --- job-queue events (the requeue scheduler's cluster timeline) ---
    JobSubmitted,
    JobStarted,
    JobRequeued,
    JobFinished,
}

impl EventKind {
    pub fn as_str(self) -> &'static str {
        match self {
            EventKind::InstanceLaunch => "launch",
            EventKind::RestoreFromCheckpoint => "restore",
            EventKind::CheckpointCommitted => "ckpt",
            EventKind::CheckpointFailed => "ckpt-failed",
            EventKind::EvictionNotice => "notice",
            EventKind::InstanceEvicted => "evicted",
            EventKind::ReplacementRequested => "replace-req",
            EventKind::PlacementDecided => "placement",
            EventKind::StageComplete => "stage-done",
            EventKind::WorkloadDone => "done",
            EventKind::Aborted => "aborted",
            EventKind::JobSubmitted => "job-submitted",
            EventKind::JobStarted => "job-started",
            EventKind::JobRequeued => "job-requeued",
            EventKind::JobFinished => "job-finished",
        }
    }
}

/// One timeline record.
#[derive(Debug, Clone)]
pub struct TimelineEvent {
    pub at: SimTime,
    pub kind: EventKind,
    pub detail: String,
}

/// Ordered event record for one run.
#[derive(Debug, Clone, Default)]
pub struct Timeline {
    events: Vec<TimelineEvent>,
}

impl Timeline {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(
        &mut self,
        at: SimTime,
        kind: EventKind,
        detail: impl Into<String>,
    ) {
        let detail = detail.into();
        log::debug!("{at:?} {}: {detail}", kind.as_str());
        self.events.push(TimelineEvent { at, kind, detail });
    }

    pub fn events(&self) -> &[TimelineEvent] {
        &self.events
    }

    pub fn count(&self, kind: EventKind) -> usize {
        self.events.iter().filter(|e| e.kind == kind).count()
    }

    /// Events are recorded in nondecreasing time order (asserted by
    /// tests; the DES must never reorder).
    pub fn is_monotone(&self) -> bool {
        self.events.windows(2).all(|w| w[0].at <= w[1].at)
    }
}

impl fmt::Display for Timeline {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for e in &self.events {
            writeln!(
                f,
                "  {:>10} {:<12} {}",
                format!("{:?}", e.at),
                e.kind.as_str(),
                e.detail
            )?;
        }
        Ok(())
    }
}

/// Per-stage wall-duration accumulator.
#[derive(Debug, Clone, Default)]
pub struct StageTimes {
    /// (label, wall duration) per completed stage, in completion order.
    completed: Vec<(String, SimDuration)>,
    current_started: Option<SimTime>,
}

impl StageTimes {
    pub fn new() -> Self {
        Self::default()
    }

    /// Call when a stage begins (first launch and after each stage ends).
    pub fn stage_started(&mut self, at: SimTime) {
        self.current_started = Some(at);
    }

    /// Call when a stage completes; records its wall duration.
    pub fn stage_completed(&mut self, label: &str, at: SimTime) {
        let started = self
            .current_started
            .expect("stage_completed without stage_started");
        self.completed.push((label.to_string(), at.since(started)));
        self.current_started = Some(at);
    }

    pub fn completed(&self) -> &[(String, SimDuration)] {
        &self.completed
    }

    pub fn total(&self) -> SimDuration {
        self.completed
            .iter()
            .fold(SimDuration::ZERO, |acc, (_, d)| acc + *d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timeline_counts_and_order() {
        let mut t = Timeline::new();
        t.record(SimTime::from_secs(1), EventKind::InstanceLaunch, "vm-0");
        t.record(SimTime::from_secs(5), EventKind::CheckpointCommitted, "id 0");
        t.record(SimTime::from_secs(5), EventKind::EvictionNotice, "evt-1");
        t.record(SimTime::from_secs(9), EventKind::InstanceEvicted, "vm-0");
        assert_eq!(t.count(EventKind::CheckpointCommitted), 1);
        assert_eq!(t.count(EventKind::EvictionNotice), 1);
        assert_eq!(t.count(EventKind::Aborted), 0);
        assert!(t.is_monotone());
        let s = t.to_string();
        assert!(s.contains("notice"));
    }

    #[test]
    fn stage_times_accumulate_wall_durations() {
        let mut s = StageTimes::new();
        s.stage_started(SimTime::from_secs(0));
        s.stage_completed("K33", SimTime::from_secs(2030));
        // interruption inside K55 still lands in K55's wall time
        s.stage_completed("K55", SimTime::from_secs(2030 + 2333 + 600));
        assert_eq!(s.completed()[0].1.as_secs(), 2030);
        assert_eq!(s.completed()[1].1.as_secs(), 2933);
        assert_eq!(s.total().as_secs(), 2030 + 2933);
    }

    #[test]
    #[should_panic(expected = "without stage_started")]
    fn stage_completed_requires_start() {
        let mut s = StageTimes::new();
        s.stage_completed("K33", SimTime::from_secs(1));
    }
}
