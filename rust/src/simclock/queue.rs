//! Deterministic discrete-event queue.
//!
//! A thin priority queue over `(SimTime, seq)` with FIFO tie-breaking:
//! events scheduled for the same instant fire in scheduling order, which
//! makes whole-experiment timelines reproducible byte-for-byte from a seed
//! (a property the determinism tests and the resume invariant rely on).

use super::SimTime;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// An event of type `E` scheduled at a virtual instant.
#[derive(Debug, Clone)]
pub struct Scheduled<E> {
    pub at: SimTime,
    pub seq: u64,
    pub event: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}

impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first.
        other
            .at
            .cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Event queue with deterministic ordering.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Scheduled<E>>,
    next_seq: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self { heap: BinaryHeap::new(), next_seq: 0 }
    }

    /// Schedule `event` at absolute time `at`; returns its sequence id.
    pub fn schedule(&mut self, at: SimTime, event: E) -> u64 {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Scheduled { at, seq, event });
        seq
    }

    /// Time of the next event, if any.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|s| s.at)
    }

    /// Pop the earliest event.
    pub fn pop(&mut self) -> Option<Scheduled<E>> {
        self.heap.pop()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Drop all pending events (e.g. when an instance dies, its timers go
    /// with it).
    pub fn clear(&mut self) {
        self.heap.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{forall, shrinks_vec, Config};

    #[test]
    fn orders_by_time() {
        let mut q = EventQueue::new();
        q.schedule(SimTime::from_secs(30), "c");
        q.schedule(SimTime::from_secs(10), "a");
        q.schedule(SimTime::from_secs(20), "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|s| s.event))
            .collect();
        assert_eq!(order, ["a", "b", "c"]);
    }

    #[test]
    fn fifo_on_ties() {
        let mut q = EventQueue::new();
        let t = SimTime::from_secs(5);
        for i in 0..10 {
            q.schedule(t, i);
        }
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|s| s.event))
            .collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn peek_matches_pop() {
        let mut q = EventQueue::new();
        q.schedule(SimTime::from_secs(3), ());
        q.schedule(SimTime::from_secs(1), ());
        assert_eq!(q.peek_time(), Some(SimTime::from_secs(1)));
        q.pop();
        assert_eq!(q.peek_time(), Some(SimTime::from_secs(3)));
        q.pop();
        assert_eq!(q.peek_time(), None);
    }

    #[test]
    fn clear_empties() {
        let mut q = EventQueue::new();
        q.schedule(SimTime::from_secs(1), ());
        q.clear();
        assert!(q.is_empty());
    }

    #[test]
    fn prop_pop_order_is_sorted_and_stable() {
        // Property: popping yields (time, seq) in nondecreasing time order,
        // and among equal times, increasing seq.
        forall(
            Config::default().cases(200),
            |rng| {
                (0..rng.range_u64(0, 40))
                    .map(|_| rng.below(20))
                    .collect::<Vec<u64>>()
            },
            shrinks_vec,
            |times| {
                let mut q = EventQueue::new();
                for &t in times {
                    q.schedule(SimTime::from_secs(t), ());
                }
                let mut prev: Option<(SimTime, u64)> = None;
                while let Some(s) = q.pop() {
                    if let Some((pt, ps)) = prev {
                        if s.at < pt {
                            return Err(format!("time went back: {:?}", s.at));
                        }
                        if s.at == pt && s.seq < ps {
                            return Err("tie broke out of order".into());
                        }
                    }
                    prev = Some((s.at, s.seq));
                }
                Ok(())
            },
        );
    }
}
