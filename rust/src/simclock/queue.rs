//! Deterministic discrete-event queue.
//!
//! A thin priority queue over `(SimTime, seq)` with FIFO tie-breaking:
//! events scheduled for the same instant fire in scheduling order, which
//! makes whole-experiment timelines reproducible byte-for-byte from a seed
//! (a property the determinism tests and the resume invariant rely on).
//!
//! Every `schedule` returns a **token** (the sequence id) that can later
//! be passed to [`EventQueue::cancel`]. Cancellation is lazy — tombstoned
//! entries are skipped at pop time — so dropping one instance's pending
//! timers never disturbs other instances' (or other jobs') events the way
//! [`EventQueue::clear`] would. This is what lets the simulation engine
//! and the multi-slot scheduler share one queue.
//!
//! Tokens are dense (0, 1, 2, …), so liveness is tracked as a flat
//! per-token state vector plus a live counter instead of a `HashSet`:
//! `schedule`/`cancel`/`pop`/`peek_time` touch one byte by index — no
//! hashing on the engine's hot path (every event pop used to probe the
//! set at least twice). The state vector grows one byte per event ever
//! scheduled on this queue, which for even the largest simulated runs is
//! a few KiB; lazy-purge semantics are unchanged and pinned by the
//! property tests below.

use super::{SimDuration, SimTime};
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// An event of type `E` scheduled at a virtual instant.
#[derive(Debug, Clone)]
pub struct Scheduled<E> {
    pub at: SimTime,
    pub seq: u64,
    pub event: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}

impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first.
        other
            .at
            .cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Lifecycle of one issued token (one byte per token ever issued).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum TokenState {
    /// Scheduled, not yet popped or cancelled.
    Live,
    /// Cancelled; its heap entry is a tombstone awaiting lazy purge.
    Cancelled,
    /// Popped, purged, or cleared — no heap entry remains.
    Dead,
}

/// Event queue with deterministic ordering and token cancellation.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Scheduled<E>>,
    /// `states[seq]` is the lifecycle of token `seq`; `states.len()` is
    /// the next sequence id.
    states: Vec<TokenState>,
    /// Number of `Live` tokens (== the queue's logical length).
    live: usize,
    /// `subjects[s]` holds the tokens scheduled under subject `s` via
    /// [`EventQueue::schedule_for`]. Lists are pruned lazily: popped and
    /// cancelled tokens linger until the subject's next
    /// [`EventQueue::cancel_subject`], where cancelling a dead token is a
    /// free no-op. Grown on demand — untagged schedules pay nothing.
    subjects: Vec<Vec<u64>>,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self {
            heap: BinaryHeap::new(),
            states: Vec::new(),
            live: 0,
            subjects: Vec::new(),
        }
    }

    /// Schedule `event` at absolute time `at`; returns its cancellation
    /// token (the sequence id).
    pub fn schedule(&mut self, at: SimTime, event: E) -> u64 {
        let seq = self.states.len() as u64;
        self.heap.push(Scheduled { at, seq, event });
        self.states.push(TokenState::Live);
        self.live += 1;
        seq
    }

    /// Schedule `event` `delay` after `now` (the common handler idiom:
    /// "this completes after its modeled cost").
    pub fn schedule_in(
        &mut self,
        now: SimTime,
        delay: SimDuration,
        event: E,
    ) -> u64 {
        self.schedule(now + delay, event)
    }

    /// Schedule `event` at `at` under a **subject** — a caller-chosen
    /// dense id (a job index, an instance slot) whose pending events can
    /// later be dropped wholesale with [`EventQueue::cancel_subject`].
    /// This is the targeted-dispatch primitive of the multiplexed cluster
    /// engine: thousands of jobs share one queue, and one job's death
    /// cancels exactly its own timers without scanning the heap or any
    /// other job's bookkeeping.
    pub fn schedule_for(&mut self, subject: usize, at: SimTime, event: E) -> u64 {
        let token = self.schedule(at, event);
        if subject >= self.subjects.len() {
            self.subjects.resize_with(subject + 1, Vec::new);
        }
        self.subjects[subject].push(token);
        token
    }

    /// [`EventQueue::schedule_for`] with a relative delay.
    pub fn schedule_for_in(
        &mut self,
        subject: usize,
        now: SimTime,
        delay: SimDuration,
        event: E,
    ) -> u64 {
        self.schedule_for(subject, now + delay, event)
    }

    /// Cancel every still-pending event scheduled under `subject`;
    /// returns how many were actually live. Tokens already popped or
    /// individually cancelled are skipped for free. O(events ever tagged
    /// with this subject since its last `cancel_subject`).
    pub fn cancel_subject(&mut self, subject: usize) -> usize {
        let Some(tokens) = self.subjects.get_mut(subject) else {
            return 0;
        };
        let tokens = std::mem::take(tokens);
        let mut cancelled = 0;
        for token in tokens {
            if self.cancel(token) {
                cancelled += 1;
            }
        }
        cancelled
    }

    /// Cancel a previously scheduled event by token. Returns whether the
    /// event was still pending (false: already fired or already
    /// cancelled). O(1); the entry is dropped lazily at pop time.
    pub fn cancel(&mut self, token: u64) -> bool {
        match self.states.get_mut(token as usize) {
            Some(state) if *state == TokenState::Live => {
                *state = TokenState::Cancelled;
                self.live -= 1;
                true
            }
            _ => false,
        }
    }

    /// Drop any cancelled entries sitting on top of the heap.
    fn purge_top(&mut self) {
        while let Some(top) = self.heap.peek() {
            let state = &mut self.states[top.seq as usize];
            if *state == TokenState::Live {
                return;
            }
            *state = TokenState::Dead;
            self.heap.pop();
        }
    }

    /// Time of the next (live) event, if any.
    pub fn peek_time(&mut self) -> Option<SimTime> {
        self.purge_top();
        self.heap.peek().map(|s| s.at)
    }

    /// Pop the earliest live event.
    pub fn pop(&mut self) -> Option<Scheduled<E>> {
        self.purge_top();
        let s = self.heap.pop()?;
        self.states[s.seq as usize] = TokenState::Dead;
        self.live -= 1;
        Some(s)
    }

    /// Number of live (non-cancelled) events.
    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Drop all pending events. Prefer [`EventQueue::cancel`] with the
    /// tokens you own when the queue is shared — `clear` nukes everyone's
    /// timers, not just yours.
    pub fn clear(&mut self) {
        self.heap.clear();
        for s in &mut self.states {
            *s = TokenState::Dead;
        }
        for s in &mut self.subjects {
            s.clear();
        }
        self.live = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{forall, shrinks_vec, Config};

    #[test]
    fn orders_by_time() {
        let mut q = EventQueue::new();
        q.schedule(SimTime::from_secs(30), "c");
        q.schedule(SimTime::from_secs(10), "a");
        q.schedule(SimTime::from_secs(20), "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|s| s.event))
            .collect();
        assert_eq!(order, ["a", "b", "c"]);
    }

    #[test]
    fn fifo_on_ties() {
        let mut q = EventQueue::new();
        let t = SimTime::from_secs(5);
        for i in 0..10 {
            q.schedule(t, i);
        }
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|s| s.event))
            .collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn peek_matches_pop() {
        let mut q = EventQueue::new();
        q.schedule(SimTime::from_secs(3), ());
        q.schedule(SimTime::from_secs(1), ());
        assert_eq!(q.peek_time(), Some(SimTime::from_secs(1)));
        q.pop();
        assert_eq!(q.peek_time(), Some(SimTime::from_secs(3)));
        q.pop();
        assert_eq!(q.peek_time(), None);
    }

    #[test]
    fn clear_empties() {
        let mut q = EventQueue::new();
        q.schedule(SimTime::from_secs(1), ());
        q.clear();
        assert!(q.is_empty());
    }

    #[test]
    fn tokens_are_dead_after_clear() {
        let mut q = EventQueue::new();
        let a = q.schedule(SimTime::from_secs(1), "a");
        let b = q.schedule(SimTime::from_secs(2), "b");
        q.cancel(b);
        q.clear();
        assert!(!q.cancel(a), "cleared token must refuse cancel");
        assert!(!q.cancel(b), "cancelled-then-cleared token too");
        // the sequence keeps counting; fresh schedules work normally
        let c = q.schedule(SimTime::from_secs(3), "c");
        assert!(c > b);
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop().unwrap().event, "c");
    }

    #[test]
    fn schedule_in_is_relative() {
        let mut q = EventQueue::new();
        let now = SimTime::from_secs(100);
        q.schedule_in(now, SimDuration::from_secs(5), "later");
        q.schedule_in(now, SimDuration::ZERO, "now");
        let first = q.pop().unwrap();
        assert_eq!(first.event, "now");
        assert_eq!(first.at, now);
        let second = q.pop().unwrap();
        assert_eq!(second.event, "later");
        assert_eq!(second.at, SimTime::from_secs(105));
    }

    #[test]
    fn cancel_drops_only_the_target() {
        let mut q = EventQueue::new();
        let a = q.schedule(SimTime::from_secs(1), "a");
        let b = q.schedule(SimTime::from_secs(2), "b");
        let c = q.schedule(SimTime::from_secs(3), "c");
        assert_eq!(q.len(), 3);
        assert!(q.cancel(b));
        assert_eq!(q.len(), 2);
        // cancelling twice (or a popped/unknown token) is a no-op
        assert!(!q.cancel(b));
        assert!(!q.cancel(9999));
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|s| s.event))
            .collect();
        assert_eq!(order, ["a", "c"]);
        // tokens of popped events are dead
        assert!(!q.cancel(a));
        assert!(!q.cancel(c));
    }

    #[test]
    fn cancelled_head_is_skipped_by_peek_and_pop() {
        let mut q = EventQueue::new();
        let head = q.schedule(SimTime::from_secs(1), "head");
        q.schedule(SimTime::from_secs(2), "tail");
        q.cancel(head);
        assert_eq!(q.peek_time(), Some(SimTime::from_secs(2)));
        assert_eq!(q.pop().unwrap().event, "tail");
        assert!(q.is_empty());
        assert_eq!(q.peek_time(), None);
    }

    #[test]
    fn cancel_everything_leaves_empty_queue() {
        let mut q = EventQueue::new();
        let tokens: Vec<u64> =
            (0..5).map(|i| q.schedule(SimTime::from_secs(i), i)).collect();
        for t in tokens {
            assert!(q.cancel(t));
        }
        assert!(q.is_empty());
        assert_eq!(q.len(), 0);
        assert!(q.pop().is_none());
    }

    #[test]
    fn cancel_subject_drops_only_that_subjects_events() {
        let mut q = EventQueue::new();
        q.schedule_for(0, SimTime::from_secs(1), "job0-a");
        q.schedule_for(1, SimTime::from_secs(2), "job1-a");
        q.schedule_for(0, SimTime::from_secs(3), "job0-b");
        q.schedule(SimTime::from_secs(4), "untagged");
        assert_eq!(q.cancel_subject(0), 2);
        assert_eq!(q.len(), 2);
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|s| s.event))
            .collect();
        assert_eq!(order, ["job1-a", "untagged"]);
    }

    #[test]
    fn cancel_subject_skips_popped_and_cancelled_tokens() {
        let mut q = EventQueue::new();
        q.schedule_for(3, SimTime::from_secs(1), "fired");
        let t = q.schedule_for(3, SimTime::from_secs(2), "cancelled");
        q.schedule_for(3, SimTime::from_secs(3), "pending");
        assert_eq!(q.pop().unwrap().event, "fired");
        assert!(q.cancel(t));
        // only "pending" is still live under subject 3
        assert_eq!(q.cancel_subject(3), 1);
        assert!(q.is_empty());
        // the subject's list was drained: a second sweep is a no-op, and
        // fresh schedules under the same subject work normally
        assert_eq!(q.cancel_subject(3), 0);
        q.schedule_for(3, SimTime::from_secs(4), "fresh");
        assert_eq!(q.cancel_subject(3), 1);
        // unknown subjects are a no-op too
        assert_eq!(q.cancel_subject(999), 0);
    }

    #[test]
    fn schedule_for_in_is_relative() {
        let mut q = EventQueue::new();
        let now = SimTime::from_secs(50);
        q.schedule_for_in(0, now, SimDuration::from_secs(5), "later");
        assert_eq!(q.pop().unwrap().at, SimTime::from_secs(55));
    }

    #[test]
    fn clear_resets_subject_lists() {
        let mut q = EventQueue::new();
        q.schedule_for(0, SimTime::from_secs(1), "a");
        q.clear();
        assert_eq!(q.cancel_subject(0), 0);
        q.schedule_for(0, SimTime::from_secs(2), "b");
        assert_eq!(q.cancel_subject(0), 1);
    }

    #[test]
    fn prop_subject_cancellation_matches_per_token_cancellation() {
        // Tagging events across a handful of subjects and cancelling one
        // subject must behave exactly like cancelling that subject's
        // tokens one by one: survivors pop in unchanged order.
        forall(
            Config::default().cases(100),
            |rng| {
                let n = rng.range_u64(0, 30);
                (0..n)
                    .map(|_| (rng.below(10), rng.below(4)))
                    .collect::<Vec<(u64, u64)>>()
            },
            shrinks_vec,
            |plan| {
                let mut tagged = EventQueue::new();
                let mut manual = EventQueue::new();
                let mut manual_tokens = Vec::new();
                for (i, &(t, subj)) in plan.iter().enumerate() {
                    let at = SimTime::from_secs(t);
                    tagged.schedule_for(subj as usize, at, i);
                    manual_tokens.push((subj, manual.schedule(at, i)));
                }
                let doomed = 0u64;
                let n_live = tagged.cancel_subject(doomed as usize);
                let mut n_manual = 0;
                for &(subj, token) in &manual_tokens {
                    if subj == doomed && manual.cancel(token) {
                        n_manual += 1;
                    }
                }
                if n_live != n_manual {
                    return Err(format!(
                        "cancel_subject dropped {n_live}, per-token {n_manual}"
                    ));
                }
                loop {
                    match (tagged.pop(), manual.pop()) {
                        (None, None) => return Ok(()),
                        (a, b)
                            if a.as_ref().map(|s| (s.at, s.seq, s.event))
                                != b.as_ref().map(|s| (s.at, s.seq, s.event)) =>
                        {
                            return Err(format!("diverged: {a:?} vs {b:?}"))
                        }
                        _ => {}
                    }
                }
            },
        );
    }

    #[test]
    fn prop_pop_order_is_sorted_and_stable() {
        // Property: popping yields (time, seq) in nondecreasing time order,
        // and among equal times, increasing seq.
        forall(
            Config::default().cases(200),
            |rng| {
                (0..rng.range_u64(0, 40))
                    .map(|_| rng.below(20))
                    .collect::<Vec<u64>>()
            },
            shrinks_vec,
            |times| {
                let mut q = EventQueue::new();
                for &t in times {
                    q.schedule(SimTime::from_secs(t), ());
                }
                let mut prev: Option<(SimTime, u64)> = None;
                while let Some(s) = q.pop() {
                    if let Some((pt, ps)) = prev {
                        if s.at < pt {
                            return Err(format!("time went back: {:?}", s.at));
                        }
                        if s.at == pt && s.seq < ps {
                            return Err("tie broke out of order".into());
                        }
                    }
                    prev = Some((s.at, s.seq));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_cancellation_preserves_order_of_survivors() {
        // Schedule N events, cancel a pseudo-random subset, verify the
        // survivors pop in exactly the order they would have anyway.
        forall(
            Config::default().cases(100),
            |rng| {
                let n = rng.range_u64(0, 30);
                (0..n)
                    .map(|_| (rng.below(10), rng.chance(0.4)))
                    .collect::<Vec<(u64, bool)>>()
            },
            shrinks_vec,
            |plan| {
                let mut q = EventQueue::new();
                let mut keep = Vec::new();
                let mut tokens = Vec::new();
                for (i, &(t, _)) in plan.iter().enumerate() {
                    tokens.push(q.schedule(SimTime::from_secs(t), i));
                }
                for (i, &(t, cancel)) in plan.iter().enumerate() {
                    if cancel {
                        if !q.cancel(tokens[i]) {
                            return Err("live token refused cancel".into());
                        }
                    } else {
                        keep.push((t, i));
                    }
                }
                keep.sort();
                if q.len() != keep.len() {
                    return Err(format!(
                        "len {} != survivors {}",
                        q.len(),
                        keep.len()
                    ));
                }
                let got: Vec<(u64, usize)> =
                    std::iter::from_fn(|| q.pop())
                        .map(|s| (s.at.as_secs(), s.event))
                        .collect();
                if got != keep {
                    return Err(format!("order {got:?} != {keep:?}"));
                }
                Ok(())
            },
        );
    }
}
