//! Virtual time: the discrete-event backbone of the simulator.
//!
//! The paper's experiments span hours of Azure wall clock (Table I runs are
//! ~3–4.5 h each). The hybrid design (DESIGN.md §6) runs workload compute
//! for real through PJRT while *charging* time — compute progress,
//! checkpoint I/O, instance provisioning, eviction notices — against this
//! virtual clock, so a full Table I reproduction finishes in seconds
//! without changing any code path.
//!
//! [`SimTime`]/[`SimDuration`] are millisecond-resolution fixed-point
//! values; [`EventQueue`] is a deterministic priority queue (ties broken by
//! insertion sequence, so identical seeds give identical timelines) with
//! relative scheduling (`schedule_in`) and per-event cancellation tokens.
//! It is the spine of the whole simulator: [`crate::sim::engine`] runs
//! every experiment as typed events on it, and [`crate::sched`]'s
//! multi-slot requeue scheduler interleaves whole jobs on a shared one.

mod queue;

pub use queue::{EventQueue, Scheduled};

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// A point in virtual time (milliseconds since experiment start).
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimTime(pub u64);

/// A span of virtual time (milliseconds).
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimDuration(pub u64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0);

    pub fn from_secs(s: u64) -> Self {
        SimTime(s * 1000)
    }

    pub fn as_millis(self) -> u64 {
        self.0
    }

    pub fn as_secs(self) -> u64 {
        self.0 / 1000
    }

    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1000.0
    }

    /// Duration since an earlier instant (saturating).
    pub fn since(self, earlier: SimTime) -> SimDuration {
        SimDuration(self.0.saturating_sub(earlier.0))
    }
}

impl SimDuration {
    pub const ZERO: SimDuration = SimDuration(0);

    pub fn from_millis(ms: u64) -> Self {
        SimDuration(ms)
    }

    pub fn from_secs(s: u64) -> Self {
        SimDuration(s * 1000)
    }

    pub fn from_secs_f64(s: f64) -> Self {
        assert!(s >= 0.0 && s.is_finite(), "bad duration {s}");
        SimDuration((s * 1000.0).round() as u64)
    }

    pub fn from_mins(m: u64) -> Self {
        SimDuration(m * 60_000)
    }

    pub fn from_hours(h: u64) -> Self {
        SimDuration(h * 3_600_000)
    }

    pub fn as_millis(self) -> u64 {
        self.0
    }

    pub fn as_secs(self) -> u64 {
        self.0 / 1000
    }

    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1000.0
    }

    pub fn as_hours_f64(self) -> f64 {
        self.0 as f64 / 3_600_000.0
    }

    /// Scale by a float factor (for overhead fractions / calibration).
    pub fn mul_f64(self, f: f64) -> SimDuration {
        assert!(f >= 0.0 && f.is_finite());
        SimDuration((self.0 as f64 * f).round() as u64)
    }

    pub fn is_zero(self) -> bool {
        self.0 == 0
    }

    /// Paper-style `H:MM:SS` rendering.
    pub fn hms(self) -> String {
        crate::util::fmt::hms(self.as_secs())
    }
}

impl Add<SimDuration> for SimTime {
    type Output = SimTime;
    fn add(self, rhs: SimDuration) -> SimTime {
        SimTime(self.0 + rhs.0)
    }
}

impl AddAssign<SimDuration> for SimTime {
    fn add_assign(&mut self, rhs: SimDuration) {
        self.0 += rhs.0;
    }
}

impl Add for SimDuration {
    type Output = SimDuration;
    fn add(self, rhs: SimDuration) -> SimDuration {
        SimDuration(self.0 + rhs.0)
    }
}

impl AddAssign for SimDuration {
    fn add_assign(&mut self, rhs: SimDuration) {
        self.0 += rhs.0;
    }
}

impl Sub for SimDuration {
    type Output = SimDuration;
    fn sub(self, rhs: SimDuration) -> SimDuration {
        SimDuration(self.0.saturating_sub(rhs.0))
    }
}

impl fmt::Debug for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "T+{}", SimDuration(self.0).hms())
    }
}

impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self:?}")
    }
}

impl fmt::Debug for SimDuration {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.hms())
    }
}

impl fmt::Display for SimDuration {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.hms())
    }
}

/// The virtual clock: strictly monotone, owned by the experiment driver.
#[derive(Debug, Default, Clone)]
pub struct Clock {
    now: SimTime,
}

impl Clock {
    pub fn new() -> Self {
        Self { now: SimTime::ZERO }
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Advance by a duration.
    pub fn advance(&mut self, d: SimDuration) {
        self.now += d;
    }

    /// Advance to an absolute instant; panics on time travel.
    pub fn advance_to(&mut self, t: SimTime) {
        assert!(
            t >= self.now,
            "clock moved backwards: {:?} -> {t:?}",
            self.now
        );
        self.now = t;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic() {
        let t = SimTime::from_secs(10) + SimDuration::from_secs(5);
        assert_eq!(t.as_secs(), 15);
        assert_eq!(t.since(SimTime::from_secs(9)).as_secs(), 6);
        assert_eq!(t.since(SimTime::from_secs(99)), SimDuration::ZERO);
        assert_eq!(
            SimDuration::from_mins(90).as_secs(),
            5400
        );
        assert_eq!(SimDuration::from_hours(3).as_millis(), 10_800_000);
    }

    #[test]
    fn mul_f64_rounds() {
        assert_eq!(SimDuration::from_millis(1000).mul_f64(1.5).as_millis(), 1500);
        assert_eq!(SimDuration::from_millis(3).mul_f64(0.5).as_millis(), 2);
    }

    #[test]
    fn from_secs_f64() {
        assert_eq!(SimDuration::from_secs_f64(1.2345).as_millis(), 1235);
    }

    #[test]
    fn display_matches_paper_format() {
        assert_eq!(SimDuration::from_secs(11006).to_string(), "3:03:26");
        assert_eq!(format!("{:?}", SimTime::from_secs(2030)), "T+33:50");
    }

    #[test]
    fn clock_monotone() {
        let mut c = Clock::new();
        c.advance(SimDuration::from_secs(5));
        c.advance_to(SimTime::from_secs(7));
        assert_eq!(c.now().as_secs(), 7);
    }

    #[test]
    #[should_panic(expected = "backwards")]
    fn clock_rejects_time_travel() {
        let mut c = Clock::new();
        c.advance_to(SimTime::from_secs(10));
        c.advance_to(SimTime::from_secs(9));
    }
}
