//! Bid policies and the hybrid spot/on-demand autoscaler.
//!
//! Spot markets are auctions: a consumer names a **bid** — the maximum
//! hourly price it is willing to pay — and keeps its instance only while
//! the market price stays at or below that bid. When the price crosses
//! the bid the provider *outbids* the instance: the eviction notice
//! fires from the crossing and billing stops at the crossing boundary
//! (the cloud layer's `PoolOutbid` path). This module supplies the two
//! decision layers above that mechanism:
//!
//! * **[`BidPolicy`]** — *how much to bid* on a spot pool. Three
//!   strategies from the spot-market literature:
//!   * [`FixedMargin`]: current price × `(1 + margin)` — the naive
//!     "bid a bit over market" baseline.
//!   * [`PercentileOfTrace`]: base price × the `q`-quantile of the
//!     pool's traced factor stream — application-centric bidding à la
//!     Khatua et al.: the quantile directly bounds the fraction of
//!     trace time the market spends above the bid.
//!   * [`ReliabilityAware`]: a fixed margin inflated by the pool's
//!     observed eviction rate — reliability-aware bidding à la
//!     Voorsluys & Buyya: pools seen to churn earn defensive bids.
//! * **[`Autoscaler`]** — *where to place* a deadline-SLA job. It wraps
//!   the cluster's [`PlacementPolicy`](crate::cloud::fleet::PlacementPolicy)
//!   and overrides its pick with the on-demand fallback pool when the
//!   job's SLA is at risk: time-to-deadline inside the configured
//!   slack, the admission queue past its depth bound, or no viable bid
//!   on the chosen spot pool (the policy's bid is already under the
//!   market). On-demand pools never evict but bill the undiscounted
//!   catalog price, so every shift trades cost for attainment — the
//!   frontier [`crate::report::frontier`] tabulates.
//!
//! Both layers are pure functions of the fleet's deterministic state
//! (prices, traces, observed evictions) — no RNG, no wall clock — so
//! autoscaled sweeps stay byte-identical at any thread or process
//! count, and scenarios without an `[autoscale]` section (or bids) run
//! byte-identical to the bid-free engine
//! (`tests/engine_equivalence.rs`).
//!
//! # TOML reference
//!
//! ```toml
//! [job]
//! deadline_mins = 600          # per-job SLA: finishing later (or not
//!                              # at all) records DeadlineMissed
//!
//! [pool.east]
//! price_trace = "east-spike.trace"
//! bid = 0.12                   # static $/h bid: outbid when the traced
//!                              # price crosses above it
//!
//! [pool.fallback]
//! kind = "on-demand"           # never evicts; bills the undiscounted
//!                              # catalog price; no bid, no trace
//!
//! [autoscale]
//! policy = "percentile"        # "fixed-margin" | "percentile" | "reliability"
//! percentile = 0.9             # q for "percentile" (in (0, 1])
//! # margin = 0.25              # for "fixed-margin" / "reliability" (>= 0)
//! # reliability_weight = 4.0   # for "reliability" (>= 0)
//! on_demand_pool = "fallback"  # must name a kind = "on-demand" pool
//! slack_mins = 90              # shift to on-demand inside this
//!                              # time-to-deadline
//! max_queue = 4                # shift while >= this many jobs wait
//! ```
//!
//! `[autoscale]` requires `[job] deadline_mins` (the slack rule is
//! meaningless without a deadline) and a cluster scenario; every other
//! inert combination is rejected at parse *and* build with the
//! offending key named ([`crate::config::scenario`]).

use crate::cloud::fleet::{Fleet, PoolId};
use crate::config::{AutoscaleCfg, BidPolicyCfg};
use crate::simclock::SimDuration;
use anyhow::{bail, Result};

/// A bidding strategy for spot placements: given the fleet's current
/// deterministic state, name the maximum hourly price to attach to a
/// launch in `pool`.
///
/// Implementations must be pure functions of the fleet (no RNG, no
/// interior state) — the determinism suite runs autoscaled sweeps at
/// several thread counts and requires byte-identical artifacts.
pub trait BidPolicy: std::fmt::Debug {
    /// Human-readable strategy label (stable across runs; used in
    /// reports and event details).
    fn label(&self) -> String;

    /// The bid ($/h) this strategy names for a launch in `pool` now.
    fn bid(&self, fleet: &Fleet, pool: PoolId) -> f64;
}

/// Bid the pool's current effective price times `1 + margin`.
#[derive(Debug, Clone, Copy)]
pub struct FixedMargin {
    pub margin: f64,
}

impl BidPolicy for FixedMargin {
    fn label(&self) -> String {
        format!("fixed-margin/{}", self.margin)
    }

    fn bid(&self, fleet: &Fleet, pool: PoolId) -> f64 {
        fleet.pool_price(pool) * (1.0 + self.margin)
    }
}

/// Bid the pool's *base* price times the `q`-quantile of its full
/// traced factor stream ([`Fleet::factor_quantile`]) — Khatua-style
/// application-centric bidding: with `q = 0.9` the market spends at
/// most 10% of trace time above the bid.
#[derive(Debug, Clone, Copy)]
pub struct PercentileOfTrace {
    pub q: f64,
}

impl BidPolicy for PercentileOfTrace {
    fn label(&self) -> String {
        format!("percentile/{}", self.q)
    }

    fn bid(&self, fleet: &Fleet, pool: PoolId) -> f64 {
        fleet.pool_base_price(pool) * fleet.factor_quantile(pool, self.q)
    }
}

/// Fixed margin inflated by the pool's observed eviction rate
/// ([`Fleet::pool_eviction_rate`]) — Voorsluys & Buyya-style
/// reliability-aware bidding: `current × (1 + margin × (1 + weight ×
/// eviction_rate))`, so churny pools earn defensive bids.
#[derive(Debug, Clone, Copy)]
pub struct ReliabilityAware {
    pub margin: f64,
    pub weight: f64,
}

impl BidPolicy for ReliabilityAware {
    fn label(&self) -> String {
        format!("reliability/{}/{}", self.margin, self.weight)
    }

    fn bid(&self, fleet: &Fleet, pool: PoolId) -> f64 {
        let rate = fleet.pool_eviction_rate(pool);
        fleet.pool_price(pool) * (1.0 + self.margin * (1.0 + self.weight * rate))
    }
}

/// Build a [`BidPolicy`] from its validated config (re-validates, so a
/// hand-constructed [`BidPolicyCfg`] can't smuggle a NaN past the
/// parser).
pub fn build_bid_policy(cfg: &BidPolicyCfg) -> Result<Box<dyn BidPolicy>> {
    cfg.validate()?;
    Ok(match *cfg {
        BidPolicyCfg::FixedMargin { margin } => Box::new(FixedMargin { margin }),
        BidPolicyCfg::Percentile { q } => Box::new(PercentileOfTrace { q }),
        BidPolicyCfg::Reliability { margin, weight } => {
            Box::new(ReliabilityAware { margin, weight })
        }
    })
}

/// Why the autoscaler shifted (or kept) a job on the on-demand pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShiftReason {
    /// Time-to-deadline dropped inside the configured slack.
    DeadlinePressure,
    /// The admission queue reached the configured depth bound.
    QueuePressure,
    /// The bid policy's bid is already below the spot pool's market
    /// price — launching would be born outbid.
    NoViableBid,
    /// The inner placement policy itself picked the on-demand pool;
    /// not a shift, so no `AutoscaleShift` event is recorded.
    Placement,
}

impl std::fmt::Display for ShiftReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            ShiftReason::DeadlinePressure => "deadline pressure",
            ShiftReason::QueuePressure => "queue pressure",
            ShiftReason::NoViableBid => "no viable bid",
            ShiftReason::Placement => "placement",
        })
    }
}

/// The autoscaler's verdict for one placement.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ScaleDecision {
    /// Launch in `pool` on spot, carrying `bid` when the pool is traced
    /// (untraced spot pools have static prices — nothing to outbid).
    Spot { pool: PoolId, bid: Option<f64> },
    /// Launch in the on-demand fallback pool instead.
    OnDemand { reason: ShiftReason },
}

/// Hybrid spot/on-demand autoscaler ([module docs](self)): consulted at
/// every placement (admission and replacement alike), it either
/// endorses the inner placement's spot pick — attaching the bid
/// policy's bid — or overrides it with the on-demand fallback when the
/// deadline SLA is at risk.
#[derive(Debug)]
pub struct Autoscaler {
    policy: Box<dyn BidPolicy>,
    /// Resolved id of the `kind = "on-demand"` fallback pool.
    pub on_demand: PoolId,
    slack: SimDuration,
    max_queue: u32,
}

impl Autoscaler {
    /// Build from config against the fleet it will steer. Fails when
    /// the named fallback pool is missing or is not on-demand.
    pub fn new(cfg: &AutoscaleCfg, fleet: &Fleet) -> Result<Self> {
        cfg.validate()?;
        let Some(on_demand) = (0..fleet.num_pools())
            .map(PoolId)
            .find(|&p| fleet.pool_name(p) == cfg.on_demand_pool)
        else {
            bail!(
                "autoscale.on_demand_pool '{}' does not name a pool in \
                 the fleet",
                cfg.on_demand_pool
            );
        };
        if fleet.pool_is_spot(on_demand) {
            bail!(
                "autoscale.on_demand_pool '{}' is a spot pool — the \
                 fallback must be kind = \"on-demand\"",
                cfg.on_demand_pool
            );
        }
        Ok(Self {
            policy: build_bid_policy(&cfg.policy)?,
            on_demand,
            slack: cfg.slack,
            max_queue: cfg.max_queue,
        })
    }

    /// The bid strategy's label (for reports).
    pub fn policy_label(&self) -> String {
        self.policy.label()
    }

    /// Decide where one placement lands. `inner` is the wrapped
    /// placement policy's pick; `time_to_deadline` is the job's
    /// remaining SLA budget (`Some(ZERO)` when already past due, `None`
    /// when the scenario has no job deadline); `queue_depth` is the
    /// number of jobs waiting for admission.
    ///
    /// Pressure rules run in a fixed order — deadline, then queue, then
    /// bid viability — so the recorded shift reason is deterministic.
    pub fn decide(
        &self,
        fleet: &Fleet,
        inner: PoolId,
        time_to_deadline: Option<SimDuration>,
        queue_depth: u32,
    ) -> ScaleDecision {
        if let Some(ttd) = time_to_deadline {
            if ttd <= self.slack {
                return ScaleDecision::OnDemand {
                    reason: ShiftReason::DeadlinePressure,
                };
            }
        }
        if queue_depth >= self.max_queue {
            return ScaleDecision::OnDemand {
                reason: ShiftReason::QueuePressure,
            };
        }
        if inner == self.on_demand {
            return ScaleDecision::OnDemand {
                reason: ShiftReason::Placement,
            };
        }
        if fleet.pool_traced(inner) {
            let bid = self.policy.bid(fleet, inner);
            if bid >= fleet.pool_price(inner) {
                ScaleDecision::Spot {
                    pool: inner,
                    bid: Some(bid),
                }
            } else {
                ScaleDecision::OnDemand {
                    reason: ShiftReason::NoViableBid,
                }
            }
        } else {
            // Static spot price: nothing can cross a bid, so don't
            // carry one.
            ScaleDecision::Spot { pool: inner, bid: None }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::trace::{PricePoint, PriceTrace};
    use crate::config::{PoolCfg, PoolPricingCfg};

    /// Two-pool fleet: traced spot "east" (opens at 1.25×, spikes to
    /// 2.5× at 80 min) + static on-demand "fallback".
    fn hybrid_fleet() -> Fleet {
        let trace = PriceTrace::new(vec![
            PricePoint { offset: SimDuration::ZERO, factor: 1.25 },
            PricePoint { offset: SimDuration::from_mins(80), factor: 2.5 },
        ])
        .unwrap();
        let cfgs = vec![
            PoolCfg::named("east").pricing(PoolPricingCfg::Trace(trace)),
            PoolCfg::named("fallback").spot(false),
        ];
        Fleet::new(&cfgs, 7).expect("fleet builds")
    }

    fn autoscale_cfg() -> AutoscaleCfg {
        AutoscaleCfg {
            policy: BidPolicyCfg::FixedMargin { margin: 0.5 },
            on_demand_pool: "fallback".into(),
            slack: SimDuration::from_mins(60),
            max_queue: 4,
        }
    }

    #[test]
    fn fixed_margin_bids_over_current_price() {
        let fleet = hybrid_fleet();
        let east = PoolId(0);
        let p = FixedMargin { margin: 0.25 };
        let price = fleet.pool_price(east);
        assert!((p.bid(&fleet, east) - price * 1.25).abs() < 1e-12);
        assert_eq!(p.label(), "fixed-margin/0.25");
    }

    #[test]
    fn percentile_bid_is_base_times_factor_quantile() {
        let fleet = hybrid_fleet();
        let east = PoolId(0);
        let p = PercentileOfTrace { q: 1.0 };
        let want = fleet.pool_base_price(east) * fleet.factor_quantile(east, 1.0);
        assert!((p.bid(&fleet, east) - want).abs() < 1e-12);
    }

    #[test]
    fn reliability_bid_collapses_to_fixed_margin_on_clean_pool() {
        // No evictions observed yet, so the weight term is inert.
        let fleet = hybrid_fleet();
        let east = PoolId(0);
        let r = ReliabilityAware { margin: 0.3, weight: 8.0 };
        let f = FixedMargin { margin: 0.3 };
        assert!((r.bid(&fleet, east) - f.bid(&fleet, east)).abs() < 1e-12);
    }

    #[test]
    fn build_rejects_invalid_cfg() {
        let err = build_bid_policy(&BidPolicyCfg::Percentile { q: 0.0 })
            .expect_err("q = 0 must fail");
        assert!(err.to_string().contains("percentile"), "got: {err}");
    }

    #[test]
    fn new_resolves_fallback_and_rejects_spot_fallback() {
        let fleet = hybrid_fleet();
        let auto = Autoscaler::new(&autoscale_cfg(), &fleet).expect("builds");
        assert_eq!(auto.on_demand, PoolId(1));

        let mut bad = autoscale_cfg();
        bad.on_demand_pool = "east".into();
        let err = Autoscaler::new(&bad, &fleet).expect_err("spot fallback");
        assert!(err.to_string().contains("spot pool"), "got: {err}");

        let mut missing = autoscale_cfg();
        missing.on_demand_pool = "nope".into();
        let err = Autoscaler::new(&missing, &fleet).expect_err("missing pool");
        assert!(err.to_string().contains("does not name"), "got: {err}");
    }

    #[test]
    fn decide_orders_pressure_rules_deterministically() {
        let fleet = hybrid_fleet();
        let auto = Autoscaler::new(&autoscale_cfg(), &fleet).expect("builds");
        let east = PoolId(0);

        // Deadline pressure wins even when the queue is also deep.
        assert_eq!(
            auto.decide(&fleet, east, Some(SimDuration::from_mins(30)), 99),
            ScaleDecision::OnDemand { reason: ShiftReason::DeadlinePressure }
        );
        // Past due clamps to ZERO upstream; still deadline pressure.
        assert_eq!(
            auto.decide(&fleet, east, Some(SimDuration::ZERO), 0),
            ScaleDecision::OnDemand { reason: ShiftReason::DeadlinePressure }
        );
        // Queue pressure next.
        assert_eq!(
            auto.decide(&fleet, east, Some(SimDuration::from_hours(8)), 4),
            ScaleDecision::OnDemand { reason: ShiftReason::QueuePressure }
        );
        // Inner already picked the fallback: keep it, no shift event.
        assert_eq!(
            auto.decide(&fleet, PoolId(1), None, 0),
            ScaleDecision::OnDemand { reason: ShiftReason::Placement }
        );
        // Calm spot placement carries the policy's bid.
        match auto.decide(&fleet, east, Some(SimDuration::from_hours(8)), 0) {
            ScaleDecision::Spot { pool, bid: Some(bid) } => {
                assert_eq!(pool, east);
                let want = fleet.pool_price(east) * 1.5;
                assert!((bid - want).abs() < 1e-12);
            }
            other => panic!("expected spot with bid, got {other:?}"),
        }
    }

    #[test]
    fn decide_shifts_when_bid_is_under_market() {
        // Trace opens at its *peak* (2×) and relaxes later (1×), so a
        // bottom-quantile bid is deterministically under the market at
        // placement time.
        let trace = PriceTrace::new(vec![
            PricePoint { offset: SimDuration::ZERO, factor: 2.0 },
            PricePoint { offset: SimDuration::from_mins(30), factor: 1.0 },
        ])
        .unwrap();
        let cfgs = vec![
            PoolCfg::named("east").pricing(PoolPricingCfg::Trace(trace)),
            PoolCfg::named("fallback").spot(false),
        ];
        let fleet = Fleet::new(&cfgs, 7).expect("fleet builds");
        let mut cfg = autoscale_cfg();
        cfg.policy = BidPolicyCfg::Percentile { q: 0.01 };
        let auto = Autoscaler::new(&cfg, &fleet).expect("builds");
        assert_eq!(
            auto.decide(&fleet, PoolId(0), None, 0),
            ScaleDecision::OnDemand { reason: ShiftReason::NoViableBid }
        );
    }
}
