//! Eviction monitoring over the scheduled-events service (paper §III-B).
//!
//! The coordinator polls the metadata endpoint; a `Preempt` event for its
//! own instance is an eviction notice with a `NotBefore` deadline (≥30 s
//! out). The monitor works against both transports:
//!
//! * in-process [`MetadataService`] — the simulator's path;
//! * the IMDS-compatible HTTP endpoint — real-time mode, a real GET +
//!   JSON parse + POST ack round-trip per poll.

use crate::cloud::metadata::{
    parse_document, EventStatus, MetadataService,
};
use crate::httpd::{http_get, http_post};
use crate::json::{self, Value};
use crate::simclock::SimTime;
use anyhow::{Context, Result};

/// A detected eviction notice.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Notice {
    pub event_id: String,
    /// The platform will not act before this instant.
    pub not_before: SimTime,
}

/// Poller for Preempt events addressed to one instance.
#[derive(Debug, Clone)]
pub struct ScheduledEventsMonitor {
    /// Instance (resource) name this coordinator protects.
    resource: String,
    /// Incarnation last seen (skip re-parsing unchanged documents — the
    /// IMDS contract's intended cheap-poll pattern).
    last_incarnation: Option<u64>,
}

impl ScheduledEventsMonitor {
    pub fn new(resource: &str) -> Self {
        Self { resource: resource.to_string(), last_incarnation: None }
    }

    pub fn resource(&self) -> &str {
        &self.resource
    }

    /// Extract the first actionable Preempt notice from a document.
    fn scan_document(&mut self, doc: &Value) -> Result<Option<Notice>> {
        let (incarnation, events) = parse_document(doc)?;
        if self.last_incarnation == Some(incarnation) {
            return Ok(None);
        }
        self.last_incarnation = Some(incarnation);
        for e in events {
            if e.event_type == "Preempt"
                && e.status == EventStatus::Scheduled
                && e.resource == self.resource
            {
                return Ok(Some(Notice {
                    event_id: e.event_id,
                    not_before: e.not_before,
                }));
            }
        }
        Ok(None)
    }

    /// Poll the in-process service. An unreachable endpoint (chaos: IMDS
    /// outage) looks like an empty poll, not an error: the real
    /// coordinator retries on transport failure, and the notice is still
    /// in the document once the endpoint recovers because incarnation
    /// tracking never advanced.
    pub fn poll_inproc(
        &mut self,
        service: &MetadataService,
    ) -> Result<Option<Notice>> {
        if !service.is_available() {
            return Ok(None);
        }
        self.scan_document(&service.document())
    }

    /// Poll the HTTP endpoint (real-time mode).
    pub fn poll_http(&mut self, events_url: &str) -> Result<Option<Notice>> {
        let (status, body) =
            http_get(events_url).context("polling scheduled events")?;
        if status != 200 {
            anyhow::bail!("scheduled events GET returned {status}: {body}");
        }
        let doc =
            json::parse(&body).map_err(|e| anyhow::anyhow!("{e}"))?;
        self.scan_document(&doc)
    }

    /// Acknowledge readiness (StartRequests) against the in-proc service.
    pub fn ack_inproc(&self, service: &mut MetadataService, event_id: &str) {
        let mut body = Value::obj();
        let mut req = Value::obj();
        req.set("EventId", event_id);
        body.set("StartRequests", Value::Array(vec![req]));
        service.start_requests(&body);
    }

    /// Acknowledge readiness over HTTP.
    pub fn ack_http(&self, events_url: &str, event_id: &str) -> Result<()> {
        let body = format!(
            "{{\"StartRequests\":[{{\"EventId\":\"{event_id}\"}}]}}"
        );
        let (status, resp) =
            http_post(events_url, &body).context("acking event")?;
        if status != 200 {
            anyhow::bail!("StartRequests POST returned {status}: {resp}");
        }
        Ok(())
    }

    /// Reset incarnation tracking (new instance, fresh poller).
    pub fn reset(&mut self) {
        self.last_incarnation = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::imds_http::ImdsHttp;
    use crate::httpd::http_post;

    #[test]
    fn detects_own_preempt_only() {
        let mut svc = MetadataService::new();
        let mut mon = ScheduledEventsMonitor::new("vm-7");
        assert_eq!(mon.poll_inproc(&svc).unwrap(), None);
        svc.post_preempt("vm-other", SimTime::from_secs(100));
        assert_eq!(mon.poll_inproc(&svc).unwrap(), None);
        let id = svc.post_preempt("vm-7", SimTime::from_secs(200));
        let n = mon.poll_inproc(&svc).unwrap().unwrap();
        assert_eq!(n.event_id, id);
        assert_eq!(n.not_before, SimTime::from_secs(200));
    }

    #[test]
    fn incarnation_skip_suppresses_duplicate_notices() {
        let mut svc = MetadataService::new();
        let mut mon = ScheduledEventsMonitor::new("vm-1");
        svc.post_preempt("vm-1", SimTime::from_secs(50));
        assert!(mon.poll_inproc(&svc).unwrap().is_some());
        // unchanged document: no duplicate notice
        assert!(mon.poll_inproc(&svc).unwrap().is_none());
        // reset (new instance) sees it again
        mon.reset();
        assert!(mon.poll_inproc(&svc).unwrap().is_some());
    }

    #[test]
    fn acked_event_no_longer_scheduled() {
        let mut svc = MetadataService::new();
        let mut mon = ScheduledEventsMonitor::new("vm-2");
        let id = svc.post_preempt("vm-2", SimTime::from_secs(10));
        let n = mon.poll_inproc(&svc).unwrap().unwrap();
        mon.ack_inproc(&mut svc, &n.event_id);
        assert_eq!(id, n.event_id);
        mon.reset();
        // after ack the event is Started, not Scheduled
        assert!(mon.poll_inproc(&svc).unwrap().is_none());
    }

    #[test]
    fn outage_hides_notice_until_recovery() {
        let mut svc = MetadataService::new();
        let mut mon = ScheduledEventsMonitor::new("vm-5");
        let id = svc.post_preempt("vm-5", SimTime::from_secs(90));
        svc.set_available(false);
        // down: the notice is invisible, but nothing is consumed
        assert!(mon.poll_inproc(&svc).unwrap().is_none());
        assert!(mon.poll_inproc(&svc).unwrap().is_none());
        svc.set_available(true);
        // recovered: the same notice surfaces (incarnation never advanced)
        let n = mon.poll_inproc(&svc).unwrap().unwrap();
        assert_eq!(n.event_id, id);
    }

    #[test]
    fn http_round_trip() {
        let imds = ImdsHttp::spawn(30).unwrap();
        let mut mon = ScheduledEventsMonitor::new("vm-0");
        assert!(mon.poll_http(&imds.events_url()).unwrap().is_none());
        http_post(
            &format!(
                "{}/admin/simulate-eviction?resource=vm-0",
                imds.base_url()
            ),
            "",
        )
        .unwrap();
        let n = mon.poll_http(&imds.events_url()).unwrap().unwrap();
        mon.ack_http(&imds.events_url(), &n.event_id).unwrap();
        mon.reset();
        assert!(mon.poll_http(&imds.events_url()).unwrap().is_none());
    }
}
