//! Checkpoint policy: which method protects the run, when checkpoints are
//! due, and which checkpoint kinds a restart may restore from.
//!
//! The *cadence* of periodic checkpoints has two layers: this policy
//! carries the statically configured interval ([`periodic_interval`] /
//! [`periodic_due`](CheckpointPolicy::periodic_due), what the legacy loop
//! consults directly) plus an [`IntervalControllerCfg`] naming the
//! adaptive controller ([`crate::policy`]) the engine builds to tune that
//! interval online — `Fixed` (the default) reproduces the static
//! behaviour byte for byte.
//!
//! [`periodic_interval`]: CheckpointPolicy::periodic_interval

use crate::checkpoint::CkptKind;
use crate::config::{CheckpointMethodCfg, IntervalControllerCfg};
use crate::simclock::{SimDuration, SimTime};

/// The coordinator's checkpointing behaviour, derived from its
/// configuration file (paper §II: "the coordinator is able to invoke the
/// corresponding interfaces through its configuration files").
#[derive(Debug, Clone, PartialEq)]
pub struct CheckpointPolicy {
    method: CheckpointMethodCfg,
    /// Compress the termination checkpoint when the raw image would not
    /// fit the notice window (see
    /// [`crate::coordinator::handlers::on_poll_tick`]).
    compress_termination: bool,
    /// Which interval controller tunes the periodic cadence online
    /// (`[checkpoint.adaptive]`; [`crate::policy::build_controller`]).
    controller: IntervalControllerCfg,
}

impl CheckpointPolicy {
    pub fn new(method: CheckpointMethodCfg) -> Self {
        Self {
            method,
            compress_termination: false,
            controller: IntervalControllerCfg::Fixed,
        }
    }

    /// Enable/disable termination-checkpoint compression (off by
    /// default, matching the paper's setup).
    pub fn with_compression(mut self, on: bool) -> Self {
        self.compress_termination = on;
        self
    }

    /// Should the coordinator try compressing a termination checkpoint
    /// that would otherwise miss the notice deadline?
    pub fn compress_termination(&self) -> bool {
        self.compress_termination
    }

    /// Select the adaptive interval controller tuning the periodic
    /// cadence (default [`IntervalControllerCfg::Fixed`] — the static
    /// interval, byte-identical to the pre-policy engine).
    pub fn with_controller(mut self, cfg: IntervalControllerCfg) -> Self {
        self.controller = cfg;
        self
    }

    /// The configured interval controller
    /// ([`crate::policy::build_controller`] turns it into a live one).
    pub fn controller(&self) -> &IntervalControllerCfg {
        &self.controller
    }

    pub fn method(&self) -> &CheckpointMethodCfg {
        &self.method
    }

    pub fn label(&self) -> String {
        self.method.label()
    }

    /// Periodic (transparent) checkpoint interval, if any.
    pub fn periodic_interval(&self) -> Option<SimDuration> {
        match &self.method {
            CheckpointMethodCfg::Transparent { interval } => Some(*interval),
            _ => None,
        }
    }

    /// Is a periodic checkpoint due at `now` given the last one?
    pub fn periodic_due(&self, now: SimTime, last: SimTime) -> bool {
        match self.periodic_interval() {
            Some(interval) => now.since(last) >= interval,
            None => false,
        }
    }

    /// Can this method take an on-demand checkpoint when an eviction
    /// notice arrives? (Paper §III-A: "application-specific checkpointing
    /// cannot be taken on demand.")
    pub fn takes_termination_checkpoint(&self) -> bool {
        matches!(self.method, CheckpointMethodCfg::Transparent { .. })
    }

    /// Should the application's milestone checkpoints be persisted?
    pub fn persists_app_milestones(&self) -> bool {
        matches!(self.method, CheckpointMethodCfg::AppNative)
    }

    /// Restore-surface filter for [`crate::checkpoint::CheckpointStore`]:
    /// transparent methods restore transparent checkpoints, app-native
    /// restores app checkpoints, unprotected runs restore nothing.
    pub fn restore_surface(&self) -> Option<bool> {
        match self.method {
            CheckpointMethodCfg::None => None,
            CheckpointMethodCfg::AppNative => Some(false),
            CheckpointMethodCfg::Transparent { .. } => Some(true),
        }
    }

    /// Does this policy protect the workload at all?
    pub fn protected(&self) -> bool {
        self.method != CheckpointMethodCfg::None
    }

    /// Kind tag for a periodic capture under this policy.
    pub fn periodic_kind(&self) -> CkptKind {
        match self.method {
            CheckpointMethodCfg::AppNative => CkptKind::AppNative,
            _ => CkptKind::Periodic,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transparent_policy() {
        let p = CheckpointPolicy::new(CheckpointMethodCfg::Transparent {
            interval: SimDuration::from_mins(30),
        });
        assert!(p.protected());
        assert!(p.takes_termination_checkpoint());
        assert!(!p.persists_app_milestones());
        assert_eq!(p.restore_surface(), Some(true));
        assert_eq!(p.periodic_interval(), Some(SimDuration::from_mins(30)));
        let t0 = SimTime::ZERO;
        assert!(!p.periodic_due(SimTime::from_secs(1799), t0));
        assert!(p.periodic_due(SimTime::from_secs(1800), t0));
    }

    #[test]
    fn app_native_policy() {
        let p = CheckpointPolicy::new(CheckpointMethodCfg::AppNative);
        assert!(p.protected());
        assert!(!p.takes_termination_checkpoint(), "paper §III-A");
        assert!(p.persists_app_milestones());
        assert_eq!(p.restore_surface(), Some(false));
        assert_eq!(p.periodic_interval(), None);
        assert!(!p.periodic_due(SimTime::from_secs(99999), SimTime::ZERO));
        assert_eq!(p.periodic_kind(), CkptKind::AppNative);
    }

    #[test]
    fn carries_the_interval_controller_cfg() {
        let p = CheckpointPolicy::new(CheckpointMethodCfg::Transparent {
            interval: SimDuration::from_mins(30),
        });
        assert_eq!(p.controller(), &IntervalControllerCfg::Fixed);
        let p = p.with_controller(IntervalControllerCfg::young_daly());
        assert_eq!(
            p.controller(),
            &IntervalControllerCfg::young_daly(),
            "controller cfg must survive the builder"
        );
        // the static due test is untouched by the controller selection
        assert!(p.periodic_due(SimTime::from_secs(1800), SimTime::ZERO));
    }

    #[test]
    fn unprotected_policy() {
        let p = CheckpointPolicy::new(CheckpointMethodCfg::None);
        assert!(!p.protected());
        assert!(!p.takes_termination_checkpoint());
        assert!(!p.persists_app_milestones());
        assert_eq!(p.restore_surface(), None);
    }
}
