//! Coordinator reactions as discrete-event handlers.
//!
//! The simulation engine ([`crate::sim::engine`]) owns *when* things
//! happen; this module owns *what the coordinator does* when they do —
//! the same policy/monitor/writer composition the real-time loop uses,
//! factored so the engine's `PollTick` / `TerminationCkptDone` events
//! dispatch here instead of inlining coordinator logic in driver code.

use super::monitor::{Notice, ScheduledEventsMonitor};
use super::policy::CheckpointPolicy;
use crate::checkpoint::{CheckpointWriter, CkptKind, WriteOutcome};
use crate::cloud::metadata::MetadataService;
use crate::simclock::SimTime;
use crate::storage::SharedStore;
use crate::workload::Workload;
use anyhow::{Context, Result};

/// What the coordinator decided at a poll tick that surfaced a Preempt.
#[derive(Debug)]
pub enum PollReaction {
    /// A termination checkpoint is racing the notice deadline; it finishes
    /// (committed or dead mid-transfer) after `outcome.cost()`. The notice
    /// must be acked once the write completes.
    TerminationCkpt { notice: Notice, outcome: WriteOutcome },
    /// The policy cannot checkpoint on demand (paper §III-A); the notice
    /// was acked immediately and the instance just waits to die.
    AckOnly,
}

/// Coordinator reaction to its poll tick detecting an eviction notice:
/// poll the scheduled-events document, and — if the policy supports
/// on-demand capture — start an opportunistic termination checkpoint
/// bounded by the time left until `reclaim_deadline` (paper §II).
#[allow(clippy::too_many_arguments)]
pub fn on_poll_tick(
    monitor: &mut ScheduledEventsMonitor,
    metadata: &mut MetadataService,
    policy: &CheckpointPolicy,
    writer: &mut CheckpointWriter,
    store: &mut dyn SharedStore,
    workload: &dyn Workload,
    now: SimTime,
    reclaim_deadline: SimTime,
) -> Result<PollReaction> {
    let notice = monitor
        .poll_inproc(metadata)?
        .context("notice must be visible")?;
    if policy.takes_termination_checkpoint() {
        let budget = reclaim_deadline.since(now);
        let snap = workload.snapshot()?;
        let outcome = writer.write_with_budget(
            store,
            now,
            CkptKind::Termination,
            workload,
            &snap,
            Some(budget),
        )?;
        Ok(PollReaction::TerminationCkpt { notice, outcome })
    } else {
        monitor.ack_inproc(metadata, &notice.event_id);
        Ok(PollReaction::AckOnly)
    }
}

/// Acknowledge a notice (StartRequests) once the termination checkpoint
/// attempt — successful or not — has finished.
pub fn ack_notice(
    monitor: &ScheduledEventsMonitor,
    metadata: &mut MetadataService,
    notice: &Notice,
) {
    monitor.ack_inproc(metadata, &notice.event_id);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CheckpointMethodCfg;
    use crate::simclock::SimDuration;
    use crate::storage::BlobStore;
    use crate::workload::sleeper::{Sleeper, SleeperCfg};

    fn setup(
        method: CheckpointMethodCfg,
    ) -> (
        ScheduledEventsMonitor,
        MetadataService,
        CheckpointPolicy,
        CheckpointWriter,
        BlobStore,
        Sleeper,
    ) {
        (
            ScheduledEventsMonitor::new("vm-0"),
            MetadataService::new(),
            CheckpointPolicy::new(method),
            CheckpointWriter::new(),
            BlobStore::for_tests(),
            Sleeper::new(SleeperCfg::small(), 9),
        )
    }

    #[test]
    fn transparent_policy_races_a_termination_checkpoint() {
        let (mut mon, mut md, policy, mut writer, mut store, w) =
            setup(CheckpointMethodCfg::Transparent {
                interval: SimDuration::from_mins(30),
            });
        let now = SimTime::from_secs(100);
        let dl = now + SimDuration::from_secs(30);
        md.post_preempt("vm-0", dl);
        let r = on_poll_tick(
            &mut mon, &mut md, &policy, &mut writer, &mut store, &w, now, dl,
        )
        .unwrap();
        match r {
            PollReaction::TerminationCkpt { notice, outcome } => {
                assert_eq!(notice.not_before, dl);
                // 3 GiB at the test store's generous bandwidth commits
                assert!(outcome.committed().is_some());
                ack_notice(&mon, &mut md, &notice);
                // acked event no longer Scheduled
                mon.reset();
                assert!(mon.poll_inproc(&md).unwrap().is_none());
            }
            other => panic!("expected termination ckpt, got {other:?}"),
        }
    }

    #[test]
    fn zero_budget_yields_partial_outcome() {
        let (mut mon, mut md, policy, mut writer, mut store, w) =
            setup(CheckpointMethodCfg::Transparent {
                interval: SimDuration::from_mins(30),
            });
        let now = SimTime::from_secs(50);
        md.post_preempt("vm-0", now); // deadline already here
        let r = on_poll_tick(
            &mut mon, &mut md, &policy, &mut writer, &mut store, &w, now, now,
        )
        .unwrap();
        match r {
            PollReaction::TerminationCkpt { outcome, .. } => {
                assert!(outcome.committed().is_none());
                assert_eq!(outcome.cost(), SimDuration::ZERO);
            }
            other => panic!("expected partial termination ckpt, got {other:?}"),
        }
    }

    #[test]
    fn app_native_policy_acks_without_checkpoint() {
        let (mut mon, mut md, policy, mut writer, mut store, w) =
            setup(CheckpointMethodCfg::AppNative);
        let now = SimTime::from_secs(10);
        let dl = now + SimDuration::from_secs(30);
        md.post_preempt("vm-0", dl);
        let r = on_poll_tick(
            &mut mon, &mut md, &policy, &mut writer, &mut store, &w, now, dl,
        )
        .unwrap();
        assert!(matches!(r, PollReaction::AckOnly));
        // nothing written to the share
        assert!(store.list("ckpt/").unwrap().is_empty());
        // and the notice is already acked
        mon.reset();
        assert!(mon.poll_inproc(&md).unwrap().is_none());
    }

    #[test]
    fn missing_notice_is_a_hard_error() {
        let (mut mon, mut md, policy, mut writer, mut store, w) =
            setup(CheckpointMethodCfg::AppNative);
        let now = SimTime::from_secs(10);
        let err = on_poll_tick(
            &mut mon, &mut md, &policy, &mut writer, &mut store, &w, now, now,
        )
        .unwrap_err();
        assert!(err.to_string().contains("visible"));
    }
}
