//! Coordinator reactions as discrete-event handlers.
//!
//! The simulation engine ([`crate::sim::engine`]) owns *when* things
//! happen; this module owns *what the coordinator does* when they do —
//! the same policy/monitor/writer composition the real-time loop uses,
//! factored so the engine's `PollTick` / `TerminationCkptDone` events
//! dispatch here instead of inlining coordinator logic in driver code.

use super::monitor::{Notice, ScheduledEventsMonitor};
use super::policy::CheckpointPolicy;
use crate::checkpoint::{compress, CheckpointWriter, CkptKind, WriteOutcome};
use crate::cloud::metadata::MetadataService;
use crate::simclock::SimTime;
use crate::storage::SharedStore;
use crate::workload::{Snapshot, Workload};
use anyhow::{Context, Result};

/// What the coordinator decided at a poll tick that surfaced a Preempt.
#[derive(Debug)]
pub enum PollReaction {
    /// A termination checkpoint is racing the notice deadline; it finishes
    /// (committed or dead mid-transfer) after `outcome.cost()`. The notice
    /// must be acked once the write completes.
    TerminationCkpt { notice: Notice, outcome: WriteOutcome },
    /// The policy cannot checkpoint on demand (paper §III-A); the notice
    /// was acked immediately and the instance just waits to die.
    AckOnly,
}

/// Coordinator reaction to its poll tick detecting an eviction notice:
/// poll the scheduled-events document, and — if the policy supports
/// on-demand capture — start an opportunistic termination checkpoint
/// bounded by the time left until `reclaim_deadline` (paper §II).
///
/// When the policy enables compression, a raw image that cannot fit the
/// budget is re-estimated at its sampled compression ratio
/// ([`compress::ratio`] over the real serialized state): if the
/// compressed transfer fits, the coordinator ships the compressed frame
/// instead of racing a doomed raw write — a compressible image survives a
/// notice the uncompressed size would miss. Incompressible images (ratio
/// ≥ what the budget allows) keep the raw race and its partial-write
/// semantics.
#[allow(clippy::too_many_arguments)]
pub fn on_poll_tick(
    monitor: &mut ScheduledEventsMonitor,
    metadata: &mut MetadataService,
    policy: &CheckpointPolicy,
    writer: &mut CheckpointWriter,
    store: &mut dyn SharedStore,
    workload: &dyn Workload,
    now: SimTime,
    reclaim_deadline: SimTime,
) -> Result<PollReaction> {
    let notice = monitor
        .poll_inproc(metadata)?
        .context("notice must be visible")?;
    if policy.takes_termination_checkpoint() {
        let budget = reclaim_deadline.since(now);
        let mut snap = workload.snapshot()?;
        if policy.compress_termination()
            && store.transfer_cost(snap.charged_bytes) > budget
        {
            // The modeled (charged) image compresses like the sampled
            // serialized state does — same estimate a CRIU pre-dump pass
            // would make before committing to the transfer. One deflate
            // yields both the ratio and the frame to ship.
            let (framed, ratio) = compress::compress_with_ratio(&snap.bytes)?;
            let effective =
                (snap.charged_bytes as f64 * ratio).ceil() as u64;
            if store.transfer_cost(effective) <= budget {
                snap = Snapshot { bytes: framed, charged_bytes: effective };
            }
        }
        // An injected storage fault (chaos) mid-race is the same shape as
        // running out the budget: the generation is lost, the instance
        // still dies, and the notice — already consumed from the monitor —
        // must reach the ack path, so it degrades to a Partial outcome
        // instead of erroring out of the poll tick.
        let outcome = match writer.write_with_budget(
            store,
            now,
            CkptKind::Termination,
            workload,
            &snap,
            Some(budget),
        ) {
            Ok(outcome) => outcome,
            Err(e) => match e.downcast_ref::<crate::storage::InjectedFault>() {
                Some(fault) => WriteOutcome::Partial { cost: fault.burned },
                None => return Err(e),
            },
        };
        Ok(PollReaction::TerminationCkpt { notice, outcome })
    } else {
        monitor.ack_inproc(metadata, &notice.event_id);
        Ok(PollReaction::AckOnly)
    }
}

/// Acknowledge a notice (StartRequests) once the termination checkpoint
/// attempt — successful or not — has finished.
pub fn ack_notice(
    monitor: &ScheduledEventsMonitor,
    metadata: &mut MetadataService,
    notice: &Notice,
) {
    monitor.ack_inproc(metadata, &notice.event_id);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CheckpointMethodCfg;
    use crate::simclock::SimDuration;
    use crate::storage::BlobStore;
    use crate::workload::sleeper::{Sleeper, SleeperCfg};

    fn setup(
        method: CheckpointMethodCfg,
    ) -> (
        ScheduledEventsMonitor,
        MetadataService,
        CheckpointPolicy,
        CheckpointWriter,
        BlobStore,
        Sleeper,
    ) {
        (
            ScheduledEventsMonitor::new("vm-0"),
            MetadataService::new(),
            CheckpointPolicy::new(method),
            CheckpointWriter::new(),
            BlobStore::for_tests(),
            Sleeper::new(SleeperCfg::small(), 9),
        )
    }

    #[test]
    fn transparent_policy_races_a_termination_checkpoint() {
        let (mut mon, mut md, policy, mut writer, mut store, w) =
            setup(CheckpointMethodCfg::Transparent {
                interval: SimDuration::from_mins(30),
            });
        let now = SimTime::from_secs(100);
        let dl = now + SimDuration::from_secs(30);
        md.post_preempt("vm-0", dl);
        let r = on_poll_tick(
            &mut mon, &mut md, &policy, &mut writer, &mut store, &w, now, dl,
        )
        .unwrap();
        match r {
            PollReaction::TerminationCkpt { notice, outcome } => {
                assert_eq!(notice.not_before, dl);
                // 3 GiB at the test store's generous bandwidth commits
                assert!(outcome.committed().is_some());
                ack_notice(&mon, &mut md, &notice);
                // acked event no longer Scheduled
                mon.reset();
                assert!(mon.poll_inproc(&md).unwrap().is_none());
            }
            other => panic!("expected termination ckpt, got {other:?}"),
        }
    }

    #[test]
    fn zero_budget_yields_partial_outcome() {
        let (mut mon, mut md, policy, mut writer, mut store, w) =
            setup(CheckpointMethodCfg::Transparent {
                interval: SimDuration::from_mins(30),
            });
        let now = SimTime::from_secs(50);
        md.post_preempt("vm-0", now); // deadline already here
        let r = on_poll_tick(
            &mut mon, &mut md, &policy, &mut writer, &mut store, &w, now, now,
        )
        .unwrap();
        match r {
            PollReaction::TerminationCkpt { outcome, .. } => {
                assert!(outcome.committed().is_none());
                assert_eq!(outcome.cost(), SimDuration::ZERO);
            }
            other => panic!("expected partial termination ckpt, got {other:?}"),
        }
    }

    #[test]
    fn app_native_policy_acks_without_checkpoint() {
        let (mut mon, mut md, policy, mut writer, mut store, w) =
            setup(CheckpointMethodCfg::AppNative);
        let now = SimTime::from_secs(10);
        let dl = now + SimDuration::from_secs(30);
        md.post_preempt("vm-0", dl);
        let r = on_poll_tick(
            &mut mon, &mut md, &policy, &mut writer, &mut store, &w, now, dl,
        )
        .unwrap();
        assert!(matches!(r, PollReaction::AckOnly));
        // nothing written to the share
        assert!(store.list("ckpt/").unwrap().is_empty());
        // and the notice is already acked
        mon.reset();
        assert!(mon.poll_inproc(&md).unwrap().is_none());
    }

    /// Sleeper whose transparent snapshot bytes are overridden, so tests
    /// control the sampled compression ratio while keeping the modeled
    /// 3 GiB charged size.
    struct SnapshotOverride {
        inner: Sleeper,
        bytes: Vec<u8>,
    }

    impl crate::workload::Workload for SnapshotOverride {
        fn name(&self) -> &str {
            self.inner.name()
        }
        fn num_stages(&self) -> u32 {
            self.inner.num_stages()
        }
        fn stage_label(&self, s: u32) -> String {
            self.inner.stage_label(s)
        }
        fn stage_steps(&self, s: u32) -> u64 {
            self.inner.stage_steps(s)
        }
        fn progress(&self) -> crate::workload::Progress {
            self.inner.progress()
        }
        fn is_done(&self) -> bool {
            self.inner.is_done()
        }
        fn step(&mut self) -> Result<crate::workload::StepOutcome> {
            self.inner.step()
        }
        fn snapshot(&self) -> Result<Snapshot> {
            let inner = self.inner.snapshot()?;
            Ok(Snapshot {
                bytes: self.bytes.clone(),
                charged_bytes: inner.charged_bytes,
            })
        }
        fn restore(&mut self, b: &[u8]) -> Result<()> {
            self.inner.restore(b)
        }
        fn app_snapshot(&self) -> Result<Option<Snapshot>> {
            self.inner.app_snapshot()
        }
        fn app_restore(&mut self, b: &[u8]) -> Result<()> {
            self.inner.app_restore(b)
        }
        fn fingerprint(&self) -> u64 {
            self.inner.fingerprint()
        }
    }

    /// Run one poll tick against a 250 MiB/s share with the given notice
    /// budget; returns whether the termination checkpoint committed.
    fn poll_commits(
        snapshot_bytes: Vec<u8>,
        notice_secs: u64,
        compress_on: bool,
    ) -> bool {
        use crate::storage::TransferModel;
        let w = SnapshotOverride {
            inner: Sleeper::new(SleeperCfg::small(), 9),
            bytes: snapshot_bytes,
        };
        let mut store = BlobStore::new(
            TransferModel {
                bandwidth_mib_s: 250.0,
                latency: SimDuration::from_millis(20),
            },
            None,
        );
        let policy = CheckpointPolicy::new(CheckpointMethodCfg::Transparent {
            interval: SimDuration::from_mins(30),
        })
        .with_compression(compress_on);
        let mut mon = ScheduledEventsMonitor::new("vm-0");
        let mut md = MetadataService::new();
        let mut writer = CheckpointWriter::new();
        let now = SimTime::from_secs(100);
        let dl = now + SimDuration::from_secs(notice_secs);
        md.post_preempt("vm-0", dl);
        let r = on_poll_tick(
            &mut mon, &mut md, &policy, &mut writer, &mut store, &w, now, dl,
        )
        .unwrap();
        match r {
            PollReaction::TerminationCkpt { outcome, .. } => {
                outcome.committed().is_some()
            }
            other => panic!("expected termination ckpt, got {other:?}"),
        }
    }

    #[test]
    fn notice_sweep_with_and_without_compression() {
        // 3 GiB at 250 MiB/s needs ~12.3 s raw. The all-zero sample
        // compresses >100x (ratio < 0.01 asserted in checkpoint::compress
        // tests), so the effective transfer is < 30 MiB.
        let zeros = vec![0u8; 64 * 1024];
        for (notice_secs, compress_on, expect) in [
            (30u64, false, true), // raw fits the paper's 30 s notice
            (30, true, true),     // raw fits: compression never consulted
            (5, false, false),    // raw misses a 5 s notice
            (5, true, true),      // compressed image fits where raw missed
            (1, true, true),      // even 1 s fits the compressed transfer
        ] {
            assert_eq!(
                poll_commits(zeros.clone(), notice_secs, compress_on),
                expect,
                "notice={notice_secs}s compress={compress_on}"
            );
        }
    }

    #[test]
    fn incompressible_image_is_not_rescued() {
        // High-entropy sample: ratio ≈ 1, the compressed estimate still
        // misses the 5 s budget, so the raw race (and its partial write)
        // proceeds unchanged.
        let mut noise = vec![0u8; 64 * 1024];
        crate::util::Prng::new(11).fill_bytes(&mut noise);
        assert!(!poll_commits(noise.clone(), 5, true));
        // and a committed compressed frame never has worse integrity: the
        // 30 s budget commits the raw image for the same sample
        assert!(poll_commits(noise, 30, true));
    }

    #[test]
    fn injected_fault_degrades_to_partial_outcome() {
        // A chaos write fault during the termination race must not escape
        // as an error: the notice is already consumed from the monitor, so
        // the reaction carries it with a Partial outcome instead.
        use crate::config::ChaosStorageCfg;
        use crate::storage::ChaosStore;
        let (mut mon, mut md, policy, mut writer, store, w) =
            setup(CheckpointMethodCfg::Transparent {
                interval: SimDuration::from_mins(30),
            });
        let mut store = ChaosStore::new(
            store,
            ChaosStorageCfg {
                write_fail_prob: 1.0,
                ..ChaosStorageCfg::default()
            },
            7,
        );
        let now = SimTime::from_secs(100);
        let dl = now + SimDuration::from_secs(30);
        md.post_preempt("vm-0", dl);
        let r = on_poll_tick(
            &mut mon, &mut md, &policy, &mut writer, &mut store, &w, now, dl,
        )
        .unwrap();
        match r {
            PollReaction::TerminationCkpt { outcome, .. } => {
                assert!(outcome.committed().is_none());
            }
            other => panic!("expected partial termination ckpt, got {other:?}"),
        }
    }

    #[test]
    fn missing_notice_is_a_hard_error() {
        let (mut mon, mut md, policy, mut writer, mut store, w) =
            setup(CheckpointMethodCfg::AppNative);
        let now = SimTime::from_secs(10);
        let err = on_poll_tick(
            &mut mon, &mut md, &policy, &mut writer, &mut store, &w, now, now,
        )
        .unwrap_err();
        assert!(err.to_string().contains("visible"));
    }
}
