//! The wall-clock coordinator loop (real-time mode).
//!
//! This is the process the paper launches through the scale set's Custom
//! Data on every new instance: it restores from the most recent valid
//! checkpoint, then drives the workload while polling scheduled events
//! and writing periodic checkpoints — all against the real clock and, in
//! HTTP mode, a real IMDS-shaped endpoint. Integration tests run this
//! loop end to end with second-scale intervals; the CLI `run`/`resume`
//! commands wrap it.
//!
//! (The paper's *measurements* come from the virtual-time driver in
//! [`crate::sim`], which composes the same policy/monitor/restart pieces;
//! this loop exists to prove the coordination logic works against real
//! transports and real time.)

use super::monitor::ScheduledEventsMonitor;
use super::policy::CheckpointPolicy;
use super::restart::RestartManager;
use crate::checkpoint::{CheckpointStore, CheckpointWriter, CkptKind};
use crate::cloud::metadata::MetadataService;
use crate::metrics::{EventKind, Timeline};
use crate::simclock::SimTime;
use crate::storage::SharedStore;
use crate::workload::{StepOutcome, Workload};
use anyhow::{Context, Result};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Event transport the monitor polls.
pub enum Transport {
    /// Shared in-process service (unit tests, single-process demos).
    InProc(Arc<Mutex<MetadataService>>),
    /// IMDS-compatible HTTP endpoint (integration tests, real deployments
    /// would point this at 169.254.169.254).
    Http { events_url: String },
}

/// Wall-clock parameters.
pub struct RealtimeParams {
    pub poll_interval: Duration,
    /// Periodic-checkpoint interval override; defaults to the policy's
    /// interval interpreted in *seconds as wall seconds*.
    pub periodic_interval: Option<Duration>,
    /// Give-up bound for the whole attempt.
    pub run_timeout: Duration,
    /// Checkpoints retained on the share after GC.
    pub keep_checkpoints: usize,
}

impl Default for RealtimeParams {
    fn default() -> Self {
        Self {
            poll_interval: Duration::from_millis(50),
            periodic_interval: None,
            run_timeout: Duration::from_secs(120),
            keep_checkpoints: 3,
        }
    }
}

/// How one coordinator attempt ended.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RealtimeOutcome {
    /// Workload ran to completion.
    Completed,
    /// Evicted; `termination_checkpoint` says whether the opportunistic
    /// checkpoint committed before the deadline.
    Evicted { termination_checkpoint: bool },
}

/// One coordinator attempt on one instance.
pub struct RealtimeCoordinator {
    pub instance: String,
    pub policy: CheckpointPolicy,
    pub params: RealtimeParams,
    pub timeline: Timeline,
}

impl RealtimeCoordinator {
    pub fn new(
        instance: &str,
        policy: CheckpointPolicy,
        params: RealtimeParams,
    ) -> Self {
        Self {
            instance: instance.to_string(),
            policy,
            params,
            timeline: Timeline::new(),
        }
    }

    fn now_sim(epoch: Instant) -> SimTime {
        SimTime(epoch.elapsed().as_millis() as u64)
    }

    /// Run the coordinator until completion or eviction.
    pub fn run(
        &mut self,
        workload: &mut dyn Workload,
        store: &mut dyn SharedStore,
        transport: &Transport,
    ) -> Result<RealtimeOutcome> {
        let epoch = Instant::now();
        let mut monitor = ScheduledEventsMonitor::new(&self.instance);
        let mut writer = CheckpointWriter::new();
        writer.resume_after(CheckpointStore::max_id(store)?);

        self.timeline.record(
            Self::now_sim(epoch),
            EventKind::InstanceLaunch,
            self.instance.clone(),
        );

        // Restart path: most recent valid checkpoint, if any.
        if let Some(report) =
            RestartManager::find_and_restore(store, &self.policy, workload)
                .context("restart")?
        {
            self.timeline.record(
                Self::now_sim(epoch),
                EventKind::RestoreFromCheckpoint,
                format!(
                    "ckpt {} ({}) -> step {}",
                    report.manifest.id,
                    report.manifest.kind.as_str(),
                    report.resumed_total_steps
                ),
            );
        }

        let periodic = self.params.periodic_interval.or_else(|| {
            self.policy
                .periodic_interval()
                .map(|d| Duration::from_millis(d.as_millis()))
        });
        let mut last_ckpt = Instant::now();
        let mut last_poll = Instant::now() - self.params.poll_interval;

        loop {
            if epoch.elapsed() > self.params.run_timeout {
                self.timeline.record(
                    Self::now_sim(epoch),
                    EventKind::Aborted,
                    "run timeout",
                );
                anyhow::bail!("coordinator run timeout");
            }

            // 1. Poll scheduled events.
            if last_poll.elapsed() >= self.params.poll_interval {
                last_poll = Instant::now();
                let notice = match transport {
                    Transport::InProc(svc) => {
                        // spoton-lint: allow(D3, reason = "lock poisoning means a panicked holder; unrecoverable by design")
                        monitor.poll_inproc(&svc.lock().unwrap())?
                    }
                    Transport::Http { events_url } => {
                        monitor.poll_http(events_url)?
                    }
                };
                if let Some(n) = notice {
                    self.timeline.record(
                        Self::now_sim(epoch),
                        EventKind::EvictionNotice,
                        n.event_id.clone(),
                    );
                    let mut termination_ok = false;
                    if self.policy.takes_termination_checkpoint() {
                        let snap = workload.snapshot()?;
                        let out = writer.write(
                            store,
                            Self::now_sim(epoch),
                            CkptKind::Termination,
                            workload,
                            &snap,
                        )?;
                        termination_ok = out.committed().is_some();
                        self.timeline.record(
                            Self::now_sim(epoch),
                            if termination_ok {
                                EventKind::CheckpointCommitted
                            } else {
                                EventKind::CheckpointFailed
                            },
                            "termination checkpoint",
                        );
                    }
                    // Ack readiness so the platform can proceed.
                    match transport {
                        Transport::InProc(svc) => monitor
                            // spoton-lint: allow(D3, reason = "lock poisoning means a panicked holder; unrecoverable by design")
                            .ack_inproc(&mut svc.lock().unwrap(), &n.event_id),
                        Transport::Http { events_url } => {
                            monitor.ack_http(events_url, &n.event_id)?
                        }
                    }
                    self.timeline.record(
                        Self::now_sim(epoch),
                        EventKind::InstanceEvicted,
                        self.instance.clone(),
                    );
                    return Ok(RealtimeOutcome::Evicted {
                        termination_checkpoint: termination_ok,
                    });
                }
            }

            // 2. Periodic transparent checkpoint.
            if let Some(interval) = periodic {
                if last_ckpt.elapsed() >= interval {
                    let snap = workload.snapshot()?;
                    let out = writer.write(
                        store,
                        Self::now_sim(epoch),
                        CkptKind::Periodic,
                        workload,
                        &snap,
                    )?;
                    if let Some(m) = out.committed() {
                        self.timeline.record(
                            Self::now_sim(epoch),
                            EventKind::CheckpointCommitted,
                            format!("periodic ckpt {}", m.id),
                        );
                    }
                    CheckpointStore::gc(store, self.params.keep_checkpoints)?;
                    last_ckpt = Instant::now();
                }
            }

            // 3. One workload step.
            match workload.step()? {
                StepOutcome::Done => {
                    self.timeline.record(
                        Self::now_sim(epoch),
                        EventKind::WorkloadDone,
                        format!("{} steps", workload.progress().total_steps),
                    );
                    return Ok(RealtimeOutcome::Completed);
                }
                StepOutcome::StageComplete(s) => {
                    self.timeline.record(
                        Self::now_sim(epoch),
                        EventKind::StageComplete,
                        workload.stage_label(s),
                    );
                    self.persist_milestone(workload, store, &mut writer, epoch)?;
                }
                StepOutcome::Milestone => {
                    self.persist_milestone(workload, store, &mut writer, epoch)?;
                }
                StepOutcome::Advanced => {}
            }
        }
    }

    fn persist_milestone(
        &mut self,
        workload: &mut dyn Workload,
        store: &mut dyn SharedStore,
        writer: &mut CheckpointWriter,
        epoch: Instant,
    ) -> Result<()> {
        if !self.policy.persists_app_milestones() {
            return Ok(());
        }
        if let Some(snap) = workload.app_snapshot()? {
            let out = writer.write(
                store,
                Self::now_sim(epoch),
                CkptKind::AppNative,
                workload,
                &snap,
            )?;
            if let Some(m) = out.committed() {
                self.timeline.record(
                    Self::now_sim(epoch),
                    EventKind::CheckpointCommitted,
                    format!("application ckpt {}", m.id),
                );
            }
            CheckpointStore::gc(store, self.params.keep_checkpoints)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CheckpointMethodCfg;
    use crate::simclock::SimDuration;
    use crate::storage::BlobStore;
    use crate::workload::sleeper::{Sleeper, SleeperCfg};

    fn transparent() -> CheckpointPolicy {
        CheckpointPolicy::new(CheckpointMethodCfg::Transparent {
            interval: SimDuration::from_millis(10),
        })
    }

    #[test]
    fn completes_without_eviction() {
        let mut w = Sleeper::new(SleeperCfg::small(), 5);
        let mut store = BlobStore::for_tests();
        let svc = Arc::new(Mutex::new(MetadataService::new()));
        let mut coord = RealtimeCoordinator::new(
            "vm-0",
            transparent(),
            RealtimeParams {
                // the sleeper finishes in a few ms of wall clock; force at
                // least one periodic checkpoint with a tiny interval
                periodic_interval: Some(Duration::from_millis(0)),
                ..RealtimeParams::default()
            },
        );
        let out = coord
            .run(&mut w, &mut store, &Transport::InProc(svc))
            .unwrap();
        assert_eq!(out, RealtimeOutcome::Completed);
        assert!(w.is_done());
        assert!(coord.timeline.count(EventKind::CheckpointCommitted) > 0);
        assert!(coord.timeline.is_monotone());
    }

    #[test]
    fn eviction_takes_termination_checkpoint_and_resumes() {
        let svc = Arc::new(Mutex::new(MetadataService::new()));
        let mut store = BlobStore::for_tests();

        // Reference run: uninterrupted.
        let mut reference = Sleeper::new(SleeperCfg::small(), 5);
        while !reference.is_done() {
            reference.step().unwrap();
        }

        // Attempt 1: post a Preempt shortly after start from another
        // thread (the platform).
        let svc2 = svc.clone();
        let injector = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            svc2.lock()
                .unwrap()
                .post_preempt("vm-0", SimTime::from_secs(3600));
        });
        let mut w = Sleeper::new(SleeperCfg::small(), 5);
        let mut coord = RealtimeCoordinator::new(
            "vm-0",
            transparent(),
            RealtimeParams {
                poll_interval: Duration::from_millis(5),
                // slow the workload so the eviction lands mid-run
                periodic_interval: Some(Duration::from_millis(20)),
                ..RealtimeParams::default()
            },
        );
        // Sleeper steps are instant; interleave a tiny sleep via many
        // steps — the 200-step workload outlasts 30 ms comfortably only
        // with the poll loop; to be robust, use a bigger workload.
        let out = loop {
            // restart loop body: single run call
            break coord.run(&mut w, &mut store, &Transport::InProc(svc.clone()));
        }
        .unwrap();
        injector.join().unwrap();

        match out {
            RealtimeOutcome::Evicted { termination_checkpoint } => {
                assert!(termination_checkpoint);
            }
            RealtimeOutcome::Completed => {
                // Workload was too fast for the injection on this machine;
                // the integration tests cover the slow path deterministically.
                return;
            }
        }

        // Attempt 2 (replacement instance): restore + finish.
        let mut w2 = Sleeper::new(SleeperCfg::small(), 5);
        let mut coord2 = RealtimeCoordinator::new(
            "vm-1",
            transparent(),
            RealtimeParams::default(),
        );
        let out2 = coord2
            .run(&mut w2, &mut store, &Transport::InProc(svc))
            .unwrap();
        assert_eq!(out2, RealtimeOutcome::Completed);
        assert_eq!(
            coord2.timeline.count(EventKind::RestoreFromCheckpoint),
            1
        );
        // Bit-exact: the resumed run ends in the same state as the
        // uninterrupted reference.
        assert_eq!(w2.fingerprint(), reference.fingerprint());
    }

    #[test]
    fn app_native_persists_milestones_not_termination() {
        let svc = Arc::new(Mutex::new(MetadataService::new()));
        let mut store = BlobStore::for_tests();
        let mut w = Sleeper::new(SleeperCfg::small(), 5);
        let mut coord = RealtimeCoordinator::new(
            "vm-0",
            CheckpointPolicy::new(CheckpointMethodCfg::AppNative),
            RealtimeParams::default(),
        );
        let out = coord
            .run(&mut w, &mut store, &Transport::InProc(svc))
            .unwrap();
        assert_eq!(out, RealtimeOutcome::Completed);
        // milestones were persisted as application checkpoints
        let latest =
            CheckpointStore::latest_valid(&mut store, Some(false)).unwrap();
        assert!(latest.is_some());
        assert_eq!(latest.unwrap().kind, CkptKind::AppNative);
        // and no transparent checkpoint ever appeared
        assert!(CheckpointStore::latest_valid(&mut store, Some(true))
            .unwrap()
            .is_none());
    }
}
