//! Restart: find the most recent valid checkpoint and resume (paper §II).

use super::policy::CheckpointPolicy;
use crate::checkpoint::{CheckpointManifest, CheckpointStore};
use crate::simclock::SimDuration;
use crate::storage::SharedStore;
use crate::workload::Workload;
use anyhow::{bail, Context, Result};

/// What a restart found and did.
#[derive(Debug, Clone)]
pub struct RestoreReport {
    pub manifest: CheckpointManifest,
    /// Virtual cost: payload fetch + (app-native) restart overhead.
    pub cost: SimDuration,
    /// Steps the workload lost relative to `steps_at_interruption`
    /// (filled by the caller, which knows where the workload was).
    pub resumed_total_steps: u64,
}

/// Stateless restart manager.
pub struct RestartManager;

impl RestartManager {
    /// Search the share and restore `workload` from the most recent valid
    /// checkpoint compatible with `policy`. Returns `None` (fresh start)
    /// when nothing usable exists.
    pub fn find_and_restore(
        store: &mut dyn SharedStore,
        policy: &CheckpointPolicy,
        workload: &mut dyn Workload,
    ) -> Result<Option<RestoreReport>> {
        let Some(surface) = policy.restore_surface() else {
            return Ok(None); // unprotected run: always fresh
        };
        let Some(manifest) = CheckpointStore::latest_valid(store, Some(surface))?
        else {
            return Ok(None);
        };
        if manifest.workload != workload.name() {
            bail!(
                "checkpoint on share belongs to workload '{}', running '{}'",
                manifest.workload,
                workload.name()
            );
        }
        let (payload, fetch_cost) =
            CheckpointStore::fetch_payload(store, &manifest)
                .context("fetching checkpoint payload")?;
        // Compressed termination checkpoints (notice-window rescue) are
        // framed; anything else passes through untouched.
        let payload = crate::checkpoint::compress::decompress(&payload)
            .context("decompressing checkpoint payload")?;
        let mut cost = fetch_cost;
        if surface {
            workload
                .restore(&payload)
                .context("transparent restore")?;
            // CRIU-analog restore lands in the exact captured state.
            let fp = workload.fingerprint();
            if fp != manifest.fingerprint {
                bail!(
                    "restored state fingerprint {fp:016x} does not match \
                     manifest {:016x}",
                    manifest.fingerprint
                );
            }
        } else {
            workload
                .app_restore(&payload)
                .context("application-native restore")?;
            cost += workload.app_restart_overhead();
        }
        let p = workload.progress();
        Ok(Some(RestoreReport {
            manifest,
            cost,
            resumed_total_steps: p.total_steps,
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::{CheckpointWriter, CkptKind};
    use crate::config::CheckpointMethodCfg;
    use crate::simclock::SimTime;
    use crate::storage::BlobStore;
    use crate::workload::sleeper::{Sleeper, SleeperCfg};

    fn transparent_policy() -> CheckpointPolicy {
        CheckpointPolicy::new(CheckpointMethodCfg::Transparent {
            interval: SimDuration::from_mins(30),
        })
    }

    #[test]
    fn fresh_start_when_no_checkpoints() {
        let mut store = BlobStore::for_tests();
        let mut w = Sleeper::new(SleeperCfg::small(), 1);
        let got = RestartManager::find_and_restore(
            &mut store,
            &transparent_policy(),
            &mut w,
        )
        .unwrap();
        assert!(got.is_none());
        assert_eq!(w.progress().total_steps, 0);
    }

    #[test]
    fn restores_latest_transparent_checkpoint() {
        let mut store = BlobStore::for_tests();
        let mut writer = CheckpointWriter::new();
        let mut w = Sleeper::new(SleeperCfg::small(), 1);
        for _ in 0..30 {
            w.step().unwrap();
        }
        let snap = w.snapshot().unwrap();
        writer
            .write(&mut store, SimTime::from_secs(10), CkptKind::Periodic, &w,
                   &snap)
            .unwrap();
        // crash: new workload instance
        let mut fresh = Sleeper::new(SleeperCfg::small(), 1);
        let report = RestartManager::find_and_restore(
            &mut store,
            &transparent_policy(),
            &mut fresh,
        )
        .unwrap()
        .unwrap();
        assert_eq!(report.resumed_total_steps, 30);
        assert_eq!(fresh.progress().total_steps, 30);
        assert_eq!(fresh.fingerprint(), w.fingerprint());
        assert!(report.cost > SimDuration::ZERO);
    }

    #[test]
    fn restores_compressed_payload() {
        // A termination checkpoint written as a compressed frame (the
        // notice-window rescue) restores transparently: fetch verifies
        // the frame bytes, decompress recovers the raw state.
        use crate::checkpoint::compress;
        use crate::workload::Snapshot;
        let mut store = BlobStore::for_tests();
        let mut writer = CheckpointWriter::new();
        let mut w = Sleeper::new(SleeperCfg::small(), 5);
        for _ in 0..17 {
            w.step().unwrap();
        }
        let raw = w.snapshot().unwrap();
        let framed = compress::compress(&raw.bytes).unwrap();
        let ratio = compress::ratio(&raw.bytes).unwrap();
        let snap = Snapshot {
            bytes: framed,
            charged_bytes: (raw.charged_bytes as f64 * ratio).ceil() as u64,
        };
        writer
            .write(&mut store, SimTime::from_secs(9), CkptKind::Termination,
                   &w, &snap)
            .unwrap()
            .committed()
            .expect("compressed write commits");
        let mut fresh = Sleeper::new(SleeperCfg::small(), 5);
        let report = RestartManager::find_and_restore(
            &mut store,
            &transparent_policy(),
            &mut fresh,
        )
        .unwrap()
        .unwrap();
        assert_eq!(report.resumed_total_steps, 17);
        assert_eq!(fresh.fingerprint(), w.fingerprint());
    }

    #[test]
    fn app_restore_adds_restart_overhead() {
        let mut store = BlobStore::for_tests();
        let mut writer = CheckpointWriter::new();
        let mut w = Sleeper::new(SleeperCfg::small(), 1);
        for _ in 0..20 {
            w.step().unwrap();
        }
        let app = w.app_snapshot().unwrap().expect("at milestone");
        writer
            .write(&mut store, SimTime::ZERO, CkptKind::AppNative, &w, &app)
            .unwrap();
        let policy = CheckpointPolicy::new(CheckpointMethodCfg::AppNative);
        let mut fresh = Sleeper::new(SleeperCfg::small(), 1);
        let report =
            RestartManager::find_and_restore(&mut store, &policy, &mut fresh)
                .unwrap()
                .unwrap();
        assert!(report.cost >= fresh.app_restart_overhead());
        assert_eq!(fresh.progress().total_steps, 20);
    }

    #[test]
    fn surface_mismatch_is_invisible() {
        // app-native run must not restore a transparent checkpoint
        let mut store = BlobStore::for_tests();
        let mut writer = CheckpointWriter::new();
        let mut w = Sleeper::new(SleeperCfg::small(), 1);
        for _ in 0..5 {
            w.step().unwrap();
        }
        let snap = w.snapshot().unwrap();
        writer
            .write(&mut store, SimTime::ZERO, CkptKind::Periodic, &w, &snap)
            .unwrap();
        let policy = CheckpointPolicy::new(CheckpointMethodCfg::AppNative);
        let mut fresh = Sleeper::new(SleeperCfg::small(), 1);
        let got =
            RestartManager::find_and_restore(&mut store, &policy, &mut fresh)
                .unwrap();
        assert!(got.is_none());
    }

    #[test]
    fn workload_name_mismatch_fails() {
        let mut store = BlobStore::for_tests();
        let mut writer = CheckpointWriter::new();
        let mut w = Sleeper::new(SleeperCfg::small(), 1);
        w.step().unwrap();
        let snap = w.snapshot().unwrap();
        // Forge a manifest claiming a different workload by writing with a
        // renamed sleeper — easiest: write then tamper is complex, so use
        // a direct manifest mutation through a custom write. Simpler:
        // restore into a workload with a different name via a wrapper.
        struct Renamed(Sleeper);
        impl crate::workload::Workload for Renamed {
            fn name(&self) -> &str {
                "other"
            }
            fn num_stages(&self) -> u32 {
                self.0.num_stages()
            }
            fn stage_label(&self, s: u32) -> String {
                self.0.stage_label(s)
            }
            fn stage_steps(&self, s: u32) -> u64 {
                self.0.stage_steps(s)
            }
            fn progress(&self) -> crate::workload::Progress {
                self.0.progress()
            }
            fn is_done(&self) -> bool {
                self.0.is_done()
            }
            fn step(&mut self) -> Result<crate::workload::StepOutcome> {
                self.0.step()
            }
            fn snapshot(&self) -> Result<crate::workload::Snapshot> {
                self.0.snapshot()
            }
            fn restore(&mut self, b: &[u8]) -> Result<()> {
                self.0.restore(b)
            }
            fn app_snapshot(&self) -> Result<Option<crate::workload::Snapshot>> {
                self.0.app_snapshot()
            }
            fn app_restore(&mut self, b: &[u8]) -> Result<()> {
                self.0.app_restore(b)
            }
            fn fingerprint(&self) -> u64 {
                self.0.fingerprint()
            }
        }
        writer
            .write(&mut store, SimTime::ZERO, CkptKind::Periodic, &w, &snap)
            .unwrap();
        let mut renamed = Renamed(Sleeper::new(SleeperCfg::small(), 1));
        let err = RestartManager::find_and_restore(
            &mut store,
            &transparent_policy(),
            &mut renamed,
        )
        .unwrap_err();
        assert!(err.to_string().contains("belongs to workload"));
    }
}
