//! Restart: find the most recent valid checkpoint and resume (paper §II).
//!
//! Two search strategies share one restore path:
//!
//! * [`RestartManager::find_and_restore`] — the classic "most recent
//!   valid generation" lookup;
//! * [`RestartManager::find_and_restore_with_fallback`] — the
//!   chaos-hardened variant: walk generations newest-first, skip every
//!   committed-but-unverifiable one (corrupted payload, unreadable
//!   manifest), and restore the newest generation that actually passes
//!   verification. Each skip is reported so the engine can account the
//!   fallback ([`crate::metrics::EventKind::RestoreFallback`]).

use super::policy::CheckpointPolicy;
use crate::checkpoint::{CheckpointManifest, CheckpointStore};
use crate::simclock::SimDuration;
use crate::storage::SharedStore;
use crate::workload::Workload;
use anyhow::{bail, Context, Result};

/// What a restart found and did.
#[derive(Debug, Clone)]
pub struct RestoreReport {
    pub manifest: CheckpointManifest,
    /// Virtual cost: payload fetch + (app-native) restart overhead.
    pub cost: SimDuration,
    /// Steps the workload lost relative to `steps_at_interruption`
    /// (filled by the caller, which knows where the workload was).
    pub resumed_total_steps: u64,
}

/// Result of a fallback restore search.
#[derive(Debug, Default)]
pub struct RestoreSearch {
    /// The restore that succeeded, if any generation was usable.
    pub report: Option<RestoreReport>,
    /// `(checkpoint id, problem)` for each committed generation newer
    /// than the restored one that failed verification and was skipped.
    /// Partial writes without a COMMIT marker are *not* listed: they were
    /// never promised to readers, so skipping them is normal operation.
    pub skipped: Vec<(u64, String)>,
}

/// Checkpoint id from a `ckpt/{id:010}-{kind}` directory key.
fn dir_id(dir: &str) -> u64 {
    dir.rsplit('/')
        .next()
        .and_then(|name| name.split('-').next())
        .and_then(|id| id.parse().ok())
        .unwrap_or(0)
}

/// Stateless restart manager.
pub struct RestartManager;

impl RestartManager {
    /// Search the share and restore `workload` from the most recent valid
    /// checkpoint compatible with `policy`. Returns `None` (fresh start)
    /// when nothing usable exists.
    pub fn find_and_restore(
        store: &mut dyn SharedStore,
        policy: &CheckpointPolicy,
        workload: &mut dyn Workload,
    ) -> Result<Option<RestoreReport>> {
        let Some(surface) = policy.restore_surface() else {
            return Ok(None); // unprotected run: always fresh
        };
        let Some(manifest) = CheckpointStore::latest_valid(store, Some(surface))?
        else {
            return Ok(None);
        };
        Self::restore_from(store, surface, workload, manifest).map(Some)
    }

    /// Like [`find_and_restore`](Self::find_and_restore), but when the
    /// newest committed generation fails verification, fall back to the
    /// next-newest and keep walking — the coordinator never restores a
    /// generation it could not verify, and never gives up while an older
    /// verified one remains.
    pub fn find_and_restore_with_fallback(
        store: &mut dyn SharedStore,
        policy: &CheckpointPolicy,
        workload: &mut dyn Workload,
    ) -> Result<RestoreSearch> {
        let Some(surface) = policy.restore_surface() else {
            return Ok(RestoreSearch::default()); // unprotected: always fresh
        };
        let entries = CheckpointStore::scan(store)?;
        let mut skipped = Vec::new();
        // scan() returns ascending by id; walk newest-first
        for e in entries.iter().rev() {
            if let Some(m) = &e.manifest {
                if m.kind.is_transparent() != surface {
                    continue; // other surface: invisible, as in latest_valid
                }
            }
            if !e.is_valid() {
                // Only COMMIT-bearing generations were promised to
                // readers; their failure is a real fallback. (A torn
                // manifest leaves no COMMIT — that is a partial write,
                // handled silently here as everywhere else.)
                if store.exists(&format!("{}/COMMIT", e.dir)) {
                    let problem = e
                        .problem
                        .clone()
                        .unwrap_or_else(|| "failed verification".into());
                    skipped.push((dir_id(&e.dir), problem));
                }
                continue;
            }
            let Some(manifest) = e.manifest.clone() else {
                // scan() only marks manifest-bearing entries valid; a
                // None here means the store scan invariant broke, and
                // restoring "something" would be worse than stopping.
                bail!(
                    "checkpoint generation {} is marked valid but \
                     carries no manifest",
                    dir_id(&e.dir)
                );
            };
            let report =
                Self::restore_from(store, surface, workload, manifest)?;
            return Ok(RestoreSearch { report: Some(report), skipped });
        }
        Ok(RestoreSearch { report: None, skipped })
    }

    /// Restore `workload` from one verified manifest.
    fn restore_from(
        store: &mut dyn SharedStore,
        surface: bool,
        workload: &mut dyn Workload,
        manifest: CheckpointManifest,
    ) -> Result<RestoreReport> {
        if manifest.workload != workload.name() {
            bail!(
                "checkpoint on share belongs to workload '{}', running '{}'",
                manifest.workload,
                workload.name()
            );
        }
        let (payload, fetch_cost) =
            CheckpointStore::fetch_payload(store, &manifest)
                .with_context(|| {
                    format!(
                        "fetching checkpoint payload for generation {}",
                        manifest.id
                    )
                })?;
        // Compressed termination checkpoints (notice-window rescue) are
        // framed; anything else passes through untouched.
        let payload = crate::checkpoint::compress::decompress(&payload)
            .context("decompressing checkpoint payload")?;
        let mut cost = fetch_cost;
        if surface {
            workload
                .restore(&payload)
                .context("transparent restore")?;
            // CRIU-analog restore lands in the exact captured state.
            let fp = workload.fingerprint();
            if fp != manifest.fingerprint {
                bail!(
                    "restored state fingerprint {fp:016x} does not match \
                     manifest {:016x}",
                    manifest.fingerprint
                );
            }
        } else {
            workload
                .app_restore(&payload)
                .context("application-native restore")?;
            cost += workload.app_restart_overhead();
        }
        let p = workload.progress();
        Ok(RestoreReport {
            manifest,
            cost,
            resumed_total_steps: p.total_steps,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::{CheckpointWriter, CkptKind};
    use crate::config::CheckpointMethodCfg;
    use crate::simclock::SimTime;
    use crate::storage::BlobStore;
    use crate::workload::sleeper::{Sleeper, SleeperCfg};

    fn transparent_policy() -> CheckpointPolicy {
        CheckpointPolicy::new(CheckpointMethodCfg::Transparent {
            interval: SimDuration::from_mins(30),
        })
    }

    #[test]
    fn fresh_start_when_no_checkpoints() {
        let mut store = BlobStore::for_tests();
        let mut w = Sleeper::new(SleeperCfg::small(), 1);
        let got = RestartManager::find_and_restore(
            &mut store,
            &transparent_policy(),
            &mut w,
        )
        .unwrap();
        assert!(got.is_none());
        assert_eq!(w.progress().total_steps, 0);
    }

    #[test]
    fn restores_latest_transparent_checkpoint() {
        let mut store = BlobStore::for_tests();
        let mut writer = CheckpointWriter::new();
        let mut w = Sleeper::new(SleeperCfg::small(), 1);
        for _ in 0..30 {
            w.step().unwrap();
        }
        let snap = w.snapshot().unwrap();
        writer
            .write(&mut store, SimTime::from_secs(10), CkptKind::Periodic, &w,
                   &snap)
            .unwrap();
        // crash: new workload instance
        let mut fresh = Sleeper::new(SleeperCfg::small(), 1);
        let report = RestartManager::find_and_restore(
            &mut store,
            &transparent_policy(),
            &mut fresh,
        )
        .unwrap()
        .unwrap();
        assert_eq!(report.resumed_total_steps, 30);
        assert_eq!(fresh.progress().total_steps, 30);
        assert_eq!(fresh.fingerprint(), w.fingerprint());
        assert!(report.cost > SimDuration::ZERO);
    }

    #[test]
    fn restores_compressed_payload() {
        // A termination checkpoint written as a compressed frame (the
        // notice-window rescue) restores transparently: fetch verifies
        // the frame bytes, decompress recovers the raw state.
        use crate::checkpoint::compress;
        use crate::workload::Snapshot;
        let mut store = BlobStore::for_tests();
        let mut writer = CheckpointWriter::new();
        let mut w = Sleeper::new(SleeperCfg::small(), 5);
        for _ in 0..17 {
            w.step().unwrap();
        }
        let raw = w.snapshot().unwrap();
        let framed = compress::compress(&raw.bytes).unwrap();
        let ratio = compress::ratio(&raw.bytes).unwrap();
        let snap = Snapshot {
            bytes: framed,
            charged_bytes: (raw.charged_bytes as f64 * ratio).ceil() as u64,
        };
        writer
            .write(&mut store, SimTime::from_secs(9), CkptKind::Termination,
                   &w, &snap)
            .unwrap()
            .committed()
            .expect("compressed write commits");
        let mut fresh = Sleeper::new(SleeperCfg::small(), 5);
        let report = RestartManager::find_and_restore(
            &mut store,
            &transparent_policy(),
            &mut fresh,
        )
        .unwrap()
        .unwrap();
        assert_eq!(report.resumed_total_steps, 17);
        assert_eq!(fresh.fingerprint(), w.fingerprint());
    }

    #[test]
    fn app_restore_adds_restart_overhead() {
        let mut store = BlobStore::for_tests();
        let mut writer = CheckpointWriter::new();
        let mut w = Sleeper::new(SleeperCfg::small(), 1);
        for _ in 0..20 {
            w.step().unwrap();
        }
        let app = w.app_snapshot().unwrap().expect("at milestone");
        writer
            .write(&mut store, SimTime::ZERO, CkptKind::AppNative, &w, &app)
            .unwrap();
        let policy = CheckpointPolicy::new(CheckpointMethodCfg::AppNative);
        let mut fresh = Sleeper::new(SleeperCfg::small(), 1);
        let report =
            RestartManager::find_and_restore(&mut store, &policy, &mut fresh)
                .unwrap()
                .unwrap();
        assert!(report.cost >= fresh.app_restart_overhead());
        assert_eq!(fresh.progress().total_steps, 20);
    }

    #[test]
    fn surface_mismatch_is_invisible() {
        // app-native run must not restore a transparent checkpoint
        let mut store = BlobStore::for_tests();
        let mut writer = CheckpointWriter::new();
        let mut w = Sleeper::new(SleeperCfg::small(), 1);
        for _ in 0..5 {
            w.step().unwrap();
        }
        let snap = w.snapshot().unwrap();
        writer
            .write(&mut store, SimTime::ZERO, CkptKind::Periodic, &w, &snap)
            .unwrap();
        let policy = CheckpointPolicy::new(CheckpointMethodCfg::AppNative);
        let mut fresh = Sleeper::new(SleeperCfg::small(), 1);
        let got =
            RestartManager::find_and_restore(&mut store, &policy, &mut fresh)
                .unwrap();
        assert!(got.is_none());
    }

    /// Write `n` periodic checkpoints 10 steps apart; returns the
    /// committed manifests in id order.
    fn write_generations(
        store: &mut BlobStore,
        w: &mut Sleeper,
        n: u64,
    ) -> Vec<CheckpointManifest> {
        let mut writer = CheckpointWriter::new();
        let mut out = Vec::new();
        for i in 0..n {
            for _ in 0..10 {
                w.step().unwrap();
            }
            let snap = w.snapshot().unwrap();
            let m = writer
                .write(store, SimTime::from_secs(i), CkptKind::Periodic, w,
                       &snap)
                .unwrap()
                .committed()
                .expect("unbudgeted write commits")
                .clone();
            out.push(m);
        }
        out
    }

    #[test]
    fn fallback_restores_newest_verified_generation() {
        let mut store = BlobStore::for_tests();
        let mut w = Sleeper::new(SleeperCfg::small(), 3);
        let gens = write_generations(&mut store, &mut w, 3);
        // the two newest payloads rot on the share
        store.corrupt(&gens[1].payload_key, 0).unwrap();
        store.corrupt(&gens[2].payload_key, 0).unwrap();
        let mut fresh = Sleeper::new(SleeperCfg::small(), 3);
        let search = RestartManager::find_and_restore_with_fallback(
            &mut store,
            &transparent_policy(),
            &mut fresh,
        )
        .unwrap();
        let report = search.report.expect("oldest generation still verifies");
        assert_eq!(report.manifest.id, gens[0].id);
        assert_eq!(report.resumed_total_steps, 10);
        // both bad generations reported, newest first
        let ids: Vec<u64> = search.skipped.iter().map(|(id, _)| *id).collect();
        assert_eq!(ids, vec![gens[2].id, gens[1].id]);
    }

    #[test]
    fn fallback_matches_classic_search_when_all_valid() {
        let mut store = BlobStore::for_tests();
        let mut w = Sleeper::new(SleeperCfg::small(), 4);
        let gens = write_generations(&mut store, &mut w, 3);
        let mut fresh = Sleeper::new(SleeperCfg::small(), 4);
        let search = RestartManager::find_and_restore_with_fallback(
            &mut store,
            &transparent_policy(),
            &mut fresh,
        )
        .unwrap();
        assert!(search.skipped.is_empty());
        assert_eq!(search.report.unwrap().manifest.id, gens[2].id);
        let mut again = Sleeper::new(SleeperCfg::small(), 4);
        let classic = RestartManager::find_and_restore(
            &mut store,
            &transparent_policy(),
            &mut again,
        )
        .unwrap()
        .unwrap();
        assert_eq!(classic.manifest.id, gens[2].id);
        assert_eq!(fresh.fingerprint(), again.fingerprint());
    }

    #[test]
    fn fallback_ignores_partial_writes() {
        // A generation with no COMMIT marker was never promised to
        // readers: skipping it is not a fallback and is not reported.
        let mut store = BlobStore::for_tests();
        let mut w = Sleeper::new(SleeperCfg::small(), 6);
        let gens = write_generations(&mut store, &mut w, 2);
        let dir = crate::checkpoint::ckpt_dir(gens[1].id, gens[1].kind);
        store.delete(&format!("{dir}/COMMIT")).unwrap();
        let mut fresh = Sleeper::new(SleeperCfg::small(), 6);
        let search = RestartManager::find_and_restore_with_fallback(
            &mut store,
            &transparent_policy(),
            &mut fresh,
        )
        .unwrap();
        assert!(search.skipped.is_empty());
        assert_eq!(search.report.unwrap().manifest.id, gens[0].id);
    }

    #[test]
    fn fallback_property_never_restores_unverified() {
        // Property, over seeded corruption patterns: the coordinator
        // never restores a generation that failed verification, restores
        // the newest one that passes, reports exactly the committed
        // failures newer than the restore, and — with K generations
        // retained — falls back at most K-1 times.
        const KEEP: usize = 3;
        for seed in 0..24u64 {
            let mut rng = crate::util::Prng::new(seed * 31 + 7);
            let mut store = BlobStore::for_tests();
            let mut w = Sleeper::new(SleeperCfg::small(), 2);
            let gens = write_generations(&mut store, &mut w, 5);
            CheckpointStore::gc(&mut store, KEEP).unwrap();
            let kept = &gens[gens.len() - KEEP..];
            let mut corrupted = std::collections::BTreeSet::new();
            for m in kept {
                if rng.below(2) == 1 {
                    store.corrupt(&m.payload_key, 0).unwrap();
                    corrupted.insert(m.id);
                }
            }
            let mut fresh = Sleeper::new(SleeperCfg::small(), 2);
            let search = RestartManager::find_and_restore_with_fallback(
                &mut store,
                &transparent_policy(),
                &mut fresh,
            )
            .unwrap();
            assert!(search.skipped.len() <= KEEP, "seed {seed}");
            match search.report {
                Some(report) => {
                    let best = kept
                        .iter()
                        .map(|m| m.id)
                        .filter(|id| !corrupted.contains(id))
                        .max()
                        .expect("a restore implies a clean generation");
                    assert_eq!(report.manifest.id, best, "seed {seed}");
                    assert!(
                        !corrupted.contains(&report.manifest.id),
                        "seed {seed}: restored an unverified generation"
                    );
                    // exactly the corrupted generations newer than the
                    // restore were skipped — at most K-1 of them
                    let expect: Vec<u64> = corrupted
                        .iter()
                        .rev()
                        .copied()
                        .filter(|&id| id > best)
                        .collect();
                    let got: Vec<u64> =
                        search.skipped.iter().map(|(id, _)| *id).collect();
                    assert_eq!(got, expect, "seed {seed}");
                    assert!(search.skipped.len() <= KEEP - 1, "seed {seed}");
                }
                None => {
                    assert_eq!(
                        corrupted.len(),
                        KEEP,
                        "seed {seed}: gave up with a clean generation left"
                    );
                    assert_eq!(search.skipped.len(), KEEP, "seed {seed}");
                }
            }
        }
    }

    #[test]
    fn workload_name_mismatch_fails() {
        let mut store = BlobStore::for_tests();
        let mut writer = CheckpointWriter::new();
        let mut w = Sleeper::new(SleeperCfg::small(), 1);
        w.step().unwrap();
        let snap = w.snapshot().unwrap();
        // Forge a manifest claiming a different workload by writing with a
        // renamed sleeper — easiest: write then tamper is complex, so use
        // a direct manifest mutation through a custom write. Simpler:
        // restore into a workload with a different name via a wrapper.
        struct Renamed(Sleeper);
        impl crate::workload::Workload for Renamed {
            fn name(&self) -> &str {
                "other"
            }
            fn num_stages(&self) -> u32 {
                self.0.num_stages()
            }
            fn stage_label(&self, s: u32) -> String {
                self.0.stage_label(s)
            }
            fn stage_steps(&self, s: u32) -> u64 {
                self.0.stage_steps(s)
            }
            fn progress(&self) -> crate::workload::Progress {
                self.0.progress()
            }
            fn is_done(&self) -> bool {
                self.0.is_done()
            }
            fn step(&mut self) -> Result<crate::workload::StepOutcome> {
                self.0.step()
            }
            fn snapshot(&self) -> Result<crate::workload::Snapshot> {
                self.0.snapshot()
            }
            fn restore(&mut self, b: &[u8]) -> Result<()> {
                self.0.restore(b)
            }
            fn app_snapshot(&self) -> Result<Option<crate::workload::Snapshot>> {
                self.0.app_snapshot()
            }
            fn app_restore(&mut self, b: &[u8]) -> Result<()> {
                self.0.app_restore(b)
            }
            fn fingerprint(&self) -> u64 {
                self.0.fingerprint()
            }
        }
        writer
            .write(&mut store, SimTime::ZERO, CkptKind::Periodic, &w, &snap)
            .unwrap();
        let mut renamed = Renamed(Sleeper::new(SleeperCfg::small(), 1));
        let err = RestartManager::find_and_restore(
            &mut store,
            &transparent_policy(),
            &mut renamed,
        )
        .unwrap_err();
        assert!(err.to_string().contains("belongs to workload"));
    }

    #[test]
    fn corrupt_manifest_falls_back_without_panicking() {
        // Regression: a checkpoint whose manifest bytes are damaged on
        // the share must surface as a skipped generation (with the
        // restore falling back to the previous one), never a panic.
        let mut store = BlobStore::for_tests();
        let mut writer = CheckpointWriter::new();
        let mut w = Sleeper::new(SleeperCfg::small(), 7);
        for _ in 0..10 {
            w.step().unwrap();
        }
        let snap = w.snapshot().unwrap();
        let m1 = writer
            .write(&mut store, SimTime::from_secs(1), CkptKind::Periodic, &w,
                   &snap)
            .unwrap()
            .committed()
            .expect("first write commits")
            .clone();
        for _ in 0..10 {
            w.step().unwrap();
        }
        let snap = w.snapshot().unwrap();
        let m2 = writer
            .write(&mut store, SimTime::from_secs(2), CkptKind::Periodic, &w,
                   &snap)
            .unwrap()
            .committed()
            .expect("second write commits")
            .clone();
        let key = format!(
            "{}/manifest.json",
            crate::checkpoint::ckpt_dir(m2.id, CkptKind::Periodic)
        );
        store.truncate(&key, 5).unwrap(); // unparseable JSON
        let mut fresh = Sleeper::new(SleeperCfg::small(), 7);
        let search = RestartManager::find_and_restore_with_fallback(
            &mut store,
            &transparent_policy(),
            &mut fresh,
        )
        .expect("a corrupt manifest must not abort the whole search");
        let report = search.report.expect("older generation restores");
        assert_eq!(report.resumed_total_steps, 10);
        assert_eq!(fresh.progress().total_steps, 10);
        assert_eq!(search.skipped.len(), 1, "{:?}", search.skipped);
        assert_eq!(search.skipped[0].0, m2.id);
        assert!(search.skipped[0].1.contains("manifest"));
        assert_eq!(report.manifest.id, m1.id);
    }
}
