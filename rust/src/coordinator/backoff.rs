//! Bounded jittered-exponential backoff for checkpoint-commit retries.
//!
//! When chaos injects a storage fault into a periodic or app-native
//! checkpoint write ([`crate::storage::chaos`]), the coordinator does not
//! give the generation up on the first failure: it re-attempts the commit
//! under this policy — `attempts` tries total, attempt `k` waiting
//!
//! ```text
//! delay(k) = min(base · factor^k · (1 + jitter·u), max),   u ∈ [0, 1)
//! ```
//!
//! before the retry. The configuration ([`BackoffCfg`], TOML
//! `[checkpoint.retry]`) is validated so the delay sequence is provably
//! monotone non-decreasing up to the cap (`factor >= 1 + jitter`) and
//! always within `[base, max]` — both pinned by property tests below.
//! Jitter draws come from a dedicated salted PRNG stream, so retry timing
//! is a function of the scenario seed only and sweep digests stay
//! byte-identical at any thread count.

use crate::config::BackoffCfg;
use crate::simclock::SimDuration;
use crate::util::prng::Prng;
use anyhow::Result;

/// Salt decorrelating the backoff jitter stream from every other consumer
/// of the scenario seed.
pub const BACKOFF_SEED_SALT: u64 = 0xB0FF_0FF5_1A77_E12D;

/// A validated retry policy: [`BackoffCfg`] plus the jitter stream.
#[derive(Debug, Clone)]
pub struct Backoff {
    cfg: BackoffCfg,
    rng: Prng,
}

impl Backoff {
    /// Build a policy from a validated configuration; `seed` should be
    /// `mix64(scenario_seed ^ salt ^ BACKOFF_SEED_SALT)` so the jitter
    /// stream is decorrelated but reproducible.
    pub fn new(cfg: BackoffCfg, seed: u64) -> Result<Self> {
        cfg.validate()?;
        Ok(Self { cfg, rng: Prng::new(seed) })
    }

    /// Total write attempts, including the first.
    pub fn attempts(&self) -> u32 {
        self.cfg.attempts
    }

    /// True if attempt index `attempt` (0-based, counting the failures so
    /// far) still has a retry left.
    pub fn retries_left(&self, attempt: u32) -> bool {
        attempt + 1 < self.cfg.attempts
    }

    /// Delay before the retry following failed attempt `attempt`
    /// (0-based). Always in `[base, max]`; consumes one jitter draw.
    pub fn delay(&mut self, attempt: u32) -> SimDuration {
        let u = self.rng.f64();
        let grown = self.cfg.base.as_secs_f64()
            * self.cfg.factor.powi(attempt.min(64) as i32)
            * (1.0 + self.cfg.jitter * u);
        let capped = grown.min(self.cfg.max.as_secs_f64());
        let d = SimDuration::from_secs_f64(capped);
        // guard the integer floor: from_secs_f64 truncates to millis, and
        // the policy's contract is delay >= base
        d.max(self.cfg.base)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{forall, shrink_none, Config};

    fn cfg_from(rng: &mut Prng) -> BackoffCfg {
        let base_ms = rng.range_u64(1, 5_000);
        let max_ms = base_ms + rng.below(60_000);
        let jitter = rng.f64() * 0.999;
        let factor = 1.0 + jitter + rng.f64() * 3.0;
        let attempts = 1 + rng.below(9) as u32;
        BackoffCfg {
            attempts,
            base: SimDuration::from_millis(base_ms),
            max: SimDuration::from_millis(max_ms),
            factor,
            jitter,
        }
    }

    #[test]
    fn delays_are_monotone_and_bounded() {
        forall(
            Config::default().cases(300),
            |rng| (cfg_from(rng), rng.next_u64()),
            shrink_none,
            |(cfg, seed)| {
                let mut policy = Backoff::new(cfg.clone(), *seed)
                    .map_err(|e| e.to_string())?;
                let mut prev = SimDuration::ZERO;
                for attempt in 0..cfg.attempts {
                    let d = policy.delay(attempt);
                    if d < cfg.base || d > cfg.max.max(cfg.base) {
                        return Err(format!(
                            "delay {d} outside [{}, {}] at attempt {attempt}",
                            cfg.base, cfg.max
                        ));
                    }
                    if d < prev {
                        return Err(format!(
                            "delay shrank {prev} -> {d} at attempt {attempt} \
                             (factor {}, jitter {})",
                            cfg.factor, cfg.jitter
                        ));
                    }
                    prev = d;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn deterministic_for_fixed_seed() {
        forall(
            Config::default().cases(100),
            |rng| (cfg_from(rng), rng.next_u64()),
            shrink_none,
            |(cfg, seed)| {
                let mut a = Backoff::new(cfg.clone(), *seed).unwrap();
                let mut b = Backoff::new(cfg.clone(), *seed).unwrap();
                for attempt in 0..cfg.attempts {
                    let (da, db) = (a.delay(attempt), b.delay(attempt));
                    if da != db {
                        return Err(format!(
                            "same seed diverged at attempt {attempt}: \
                             {da} vs {db}"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn retries_left_counts_attempts() {
        let mut rng = Prng::new(3);
        let cfg = BackoffCfg { attempts: 3, ..cfg_from(&mut rng) };
        let policy = Backoff::new(cfg, 1).unwrap();
        assert!(policy.retries_left(0));
        assert!(policy.retries_left(1));
        assert!(!policy.retries_left(2));
        assert!(!policy.retries_left(7));
    }

    #[test]
    fn invalid_configs_are_rejected_at_build() {
        let ok = BackoffCfg::default();
        assert!(Backoff::new(ok.clone(), 1).is_ok());
        let zero_attempts = BackoffCfg { attempts: 0, ..ok.clone() };
        assert!(Backoff::new(zero_attempts, 1).is_err());
        let inverted = BackoffCfg {
            base: SimDuration::from_secs(10),
            max: SimDuration::from_secs(1),
            ..ok.clone()
        };
        assert!(Backoff::new(inverted, 1).is_err());
        let shrinking = BackoffCfg { factor: 0.9, ..ok };
        assert!(Backoff::new(shrinking, 1).is_err());
    }
}
