//! The Spot-on checkpoint coordinator — the paper's contribution.
//!
//! "When a workload is launched on the spot instance, a checkpoint
//! coordinator, Spot-On, is launched simultaneously. … it schedules
//! periodic checkpointing and monitors VM eviction events using APIs
//! provided by the cloud. Upon detecting an eviction event, the
//! coordinator creates a 'termination checkpoint' in addition to periodic
//! checkpoints. … After a spot instance is terminated … the checkpoint
//! coordinator then automatically searches for the most recent valid
//! checkpoint and resumes the workload." (§II)
//!
//! Pieces:
//! * [`policy`] — which checkpoint method protects the run and when
//!   checkpoints are due (from the coordinator's configuration file).
//! * [`monitor`] — the eviction watcher over the scheduled-events
//!   service, both in-process (simulation) and HTTP (real-time mode).
//! * [`restart`] — find-latest-valid + restore with fingerprint
//!   verification.
//! * [`realtime`] — the wall-clock coordinator loop the CLI runs
//!   (workload + periodic checkpoints + IMDS polling + termination
//!   checkpoint on Preempt), exercised end-to-end by integration tests.
//!
//! The virtual-time experiment driver in [`crate::sim`] composes the same
//! policy/monitor/restart pieces under the discrete-event clock.

pub mod policy;
pub mod monitor;
pub mod restart;
pub mod realtime;

pub use monitor::{Notice, ScheduledEventsMonitor};
pub use policy::CheckpointPolicy;
pub use realtime::{RealtimeCoordinator, RealtimeOutcome, RealtimeParams};
pub use restart::RestartManager;
