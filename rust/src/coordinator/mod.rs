//! The Spot-on checkpoint coordinator — the paper's contribution.
//!
//! "When a workload is launched on the spot instance, a checkpoint
//! coordinator, Spot-On, is launched simultaneously. … it schedules
//! periodic checkpointing and monitors VM eviction events using APIs
//! provided by the cloud. Upon detecting an eviction event, the
//! coordinator creates a 'termination checkpoint' in addition to periodic
//! checkpoints. … After a spot instance is terminated … the checkpoint
//! coordinator then automatically searches for the most recent valid
//! checkpoint and resumes the workload." (§II)
//!
//! Pieces:
//! * [`policy`] — which checkpoint method protects the run and when
//!   checkpoints are due (from the coordinator's configuration file).
//! * [`monitor`] — the eviction watcher over the scheduled-events
//!   service, both in-process (simulation) and HTTP (real-time mode).
//! * [`restart`] — find-latest-valid + restore with fingerprint
//!   verification.
//! * [`handlers`] — the coordinator's reactions (poll-tick detection,
//!   termination-checkpoint race, notice ack) as discrete-event handlers
//!   the simulation engine dispatches to.
//! * [`realtime`] — the wall-clock coordinator loop the CLI runs
//!   (workload + periodic checkpoints + IMDS polling + termination
//!   checkpoint on Preempt), exercised end-to-end by integration tests.
//!
//! The event-driven engine in [`crate::sim::engine`] composes the same
//! policy/monitor/restart pieces under the discrete-event clock, routing
//! its `PollTick`/`TerminationCkptDone` events through [`handlers`].

pub mod policy;
pub mod monitor;
pub mod restart;
pub mod handlers;
pub mod realtime;
pub mod backoff;

pub use backoff::Backoff;
pub use handlers::PollReaction;
pub use monitor::{Notice, ScheduledEventsMonitor};
pub use policy::CheckpointPolicy;
pub use realtime::{RealtimeCoordinator, RealtimeOutcome, RealtimeParams};
pub use restart::{RestartManager, RestoreSearch};
