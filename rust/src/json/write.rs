//! JSON serialization: compact and pretty writers with full string
//! escaping. Integer-valued numbers are written without a decimal point so
//! manifests stay stable and diff-friendly.

use super::Value;

/// Compact serialization (no whitespace).
pub fn to_string(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, None, 0, &mut out);
    out
}

/// Pretty serialization with 2-space indentation.
pub fn to_string_pretty(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, Some(2), 0, &mut out);
    out
}

fn write_value(v: &Value, indent: Option<usize>, level: usize, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Num(n) => write_num(*n, out),
        Value::Str(s) => write_string(s, out),
        Value::Array(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(indent, level + 1, out);
                write_value(item, indent, level + 1, out);
            }
            newline_indent(indent, level, out);
            out.push(']');
        }
        Value::Object(map) => {
            if map.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(indent, level + 1, out);
                write_string(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_value(val, indent, level + 1, out);
            }
            newline_indent(indent, level, out);
            out.push('}');
        }
    }
}

fn newline_indent(indent: Option<usize>, level: usize, out: &mut String) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..(w * level) {
            out.push(' ');
        }
    }
}

fn write_num(n: f64, out: &mut String) {
    if n.is_nan() || n.is_infinite() {
        // JSON has no NaN/Inf; null is the conventional degradation.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9.1e18 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{8}' => out.push_str("\\b"),
            '\u{c}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::super::parse;
    use super::*;

    #[test]
    fn compact_format() {
        let v = parse(r#"{"b": 2, "a": [1, true, null, "x"]}"#).unwrap();
        // BTreeMap orders keys
        assert_eq!(to_string(&v), r#"{"a":[1,true,null,"x"],"b":2}"#);
    }

    #[test]
    fn integers_have_no_decimal() {
        assert_eq!(to_string(&Value::Num(5.0)), "5");
        assert_eq!(to_string(&Value::Num(-5.0)), "-5");
        assert_eq!(to_string(&Value::Num(2.5)), "2.5");
    }

    #[test]
    fn nan_degrades_to_null() {
        assert_eq!(to_string(&Value::Num(f64::NAN)), "null");
        assert_eq!(to_string(&Value::Num(f64::INFINITY)), "null");
    }

    #[test]
    fn escapes_control_chars() {
        let v = Value::Str("a\"b\\c\nd\u{1}".to_string());
        let s = to_string(&v);
        assert_eq!(s, "\"a\\\"b\\\\c\\nd\\u0001\"");
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn pretty_is_parseable_and_indented() {
        let v = parse(r#"{"a":{"b":[1,2]},"c":[]}"#).unwrap();
        let p = to_string_pretty(&v);
        assert!(p.contains("\n  \"a\": {"));
        assert!(p.contains("\"c\": []"));
        assert_eq!(parse(&p).unwrap(), v);
    }

    #[test]
    fn empty_collections() {
        assert_eq!(to_string(&Value::Array(vec![])), "[]");
        assert_eq!(to_string(&Value::obj()), "{}");
    }
}
