//! Recursive-descent JSON parser (RFC 8259 subset: full syntax, f64
//! numbers, `\uXXXX` escapes incl. surrogate pairs).

use super::Value;
use std::collections::BTreeMap;

/// Parse error with byte offset for diagnostics.
#[derive(Debug, PartialEq)]
pub struct ParseError {
    pub offset: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

const MAX_DEPTH: usize = 128;

/// Parse a complete JSON document (trailing whitespace allowed, trailing
/// garbage rejected).
pub fn parse(src: &str) -> Result<Value, ParseError> {
    let mut p = Parser { bytes: src.as_bytes(), pos: 0, depth: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing garbage"));
    }
    Ok(v)
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while let Some(b) = self.peek() {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal, expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        if self.depth >= MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        self.skip_ws();
        match self.peek() {
            Some(b'{') => {
                self.depth += 1;
                let v = self.object();
                self.depth -= 1;
                v
            }
            Some(b'[') => {
                self.depth += 1;
                let v = self.array();
                self.depth -= 1;
                v
            }
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(b'-') | Some(b'0'..=b'9') => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Object(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            let d = match b {
                b'0'..=b'9' => (b - b'0') as u32,
                b'a'..=b'f' => (b - b'a' + 10) as u32,
                b'A'..=b'F' => (b - b'A' + 10) as u32,
                _ => return Err(self.err("bad hex digit in \\u")),
            };
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let b = self.bump().ok_or_else(|| self.err("eof in string"))?;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.bump().ok_or_else(|| self.err("eof in escape"))?;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                if self.bump() != Some(b'\\')
                                    || self.bump() != Some(b'u')
                                {
                                    return Err(
                                        self.err("lone high surrogate")
                                    );
                                }
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err(self.err("bad low surrogate"));
                                }
                                0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                            } else if (0xDC00..0xE000).contains(&hi) {
                                return Err(self.err("lone low surrogate"));
                            } else {
                                hi
                            };
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                0x00..=0x1f => return Err(self.err("raw control char")),
                _ => {
                    // Re-decode UTF-8 from the source slice.
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let s = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        // int part
        match self.peek() {
            Some(b'0') => {
                self.pos += 1;
            }
            Some(b'1'..=b'9') => {
                while matches!(self.peek(), Some(b'0'..=b'9')) {
                    self.pos += 1;
                }
            }
            _ => return Err(self.err("bad number")),
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            if !matches!(self.peek(), Some(b'0'..=b'9')) {
                return Err(self.err("bad fraction"));
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            if !matches!(self.peek(), Some(b'0'..=b'9')) {
                return Err(self.err("bad exponent"));
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("non-utf8 number"))?;
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("unparseable number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::super::to_string;
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("0").unwrap(), Value::Num(0.0));
        assert_eq!(parse("-12.5e2").unwrap(), Value::Num(-1250.0));
        assert_eq!(parse(r#""hi""#).unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn nested_structures() {
        let v = parse(r#"{"a": [1, {"b": [true, null]}], "c": "d"}"#).unwrap();
        assert_eq!(
            v.get("a").unwrap().at(1).unwrap().get("b").unwrap().at(0),
            Some(&Value::Bool(true))
        );
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#""a\n\t\"\\Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\A\u{e9}");
    }

    #[test]
    fn surrogate_pairs() {
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "\u{1F600}");
        assert!(parse(r#""\ud83d""#).is_err());
        assert!(parse(r#""\ude00""#).is_err());
    }

    #[test]
    fn raw_utf8_passthrough() {
        let v = parse("\"caf\u{e9} \u{1F600}\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "caf\u{e9} \u{1F600}");
    }

    #[test]
    fn rejects_garbage() {
        for bad in [
            "", "{", "[1,", "{\"a\"}", "{\"a\":}", "01", "1.", "1e",
            "tru", "\"\x01\"", "[1]x", "nul", "+1", "--1", "[1,]",
            "{\"a\":1,}",
        ] {
            assert!(parse(bad).is_err(), "should reject: {bad:?}");
        }
    }

    #[test]
    fn deep_nesting_bounded() {
        let deep = "[".repeat(200) + &"]".repeat(200);
        assert!(parse(&deep).is_err());
        let ok = "[".repeat(100) + &"]".repeat(100);
        assert!(parse(&ok).is_ok());
    }

    #[test]
    fn error_offsets() {
        let e = parse("{\"a\": @}").unwrap_err();
        assert_eq!(e.offset, 6);
    }

    #[test]
    fn fuzz_round_trip_via_prng() {
        // structured fuzz: generate random values, write, re-parse, compare
        use crate::util::Prng;
        fn gen(rng: &mut Prng, depth: usize) -> Value {
            match if depth > 3 { rng.below(4) } else { rng.below(6) } {
                0 => Value::Null,
                1 => Value::Bool(rng.chance(0.5)),
                2 => Value::Num((rng.below(1 << 20) as f64) / 8.0),
                3 => {
                    let n = rng.below(8) as usize;
                    let mut s = String::new();
                    for _ in 0..n {
                        s.push(
                            char::from_u32(32 + rng.below(0x2000) as u32)
                                .unwrap_or('x'),
                        );
                    }
                    Value::Str(s)
                }
                4 => Value::Array(
                    (0..rng.below(5)).map(|_| gen(rng, depth + 1)).collect(),
                ),
                _ => {
                    let mut m = std::collections::BTreeMap::new();
                    for i in 0..rng.below(5) {
                        m.insert(format!("k{i}"), gen(rng, depth + 1));
                    }
                    Value::Object(m)
                }
            }
        }
        let mut rng = Prng::new(2026);
        for _ in 0..200 {
            let v = gen(&mut rng, 0);
            let s = to_string(&v);
            assert_eq!(parse(&s).unwrap(), v, "failed for {s}");
        }
    }
}
