//! From-scratch JSON: parser, serializer, and a small builder API.
//!
//! Used for every structured interchange in the system: the Azure-IMDS
//! scheduled-events wire format, checkpoint manifests, the AOT artifact
//! manifest written by `python/compile/aot.py`, experiment reports.
//! (`serde` is not in the offline vendored crate set — DESIGN.md §8.)

mod parse;
mod write;

pub use parse::{parse, ParseError};
pub use write::{to_string, to_string_pretty};

use std::collections::BTreeMap;

/// A JSON value. Objects use `BTreeMap` so serialization is deterministic
/// (manifest hashes must be stable across runs).
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

impl Value {
    pub fn obj() -> Value {
        Value::Object(BTreeMap::new())
    }

    /// Insert into an object; panics if `self` is not an object.
    pub fn set(&mut self, key: &str, v: impl Into<Value>) -> &mut Value {
        match self {
            Value::Object(m) => {
                m.insert(key.to_string(), v.into());
            }
            _ => panic!("set() on non-object"),
        }
        self
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Object(m) => m.get(key),
            _ => None,
        }
    }

    /// Array index lookup.
    pub fn at(&self, idx: usize) -> Option<&Value> {
        match self {
            Value::Array(a) => a.get(idx),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Num(n) if n.fract() == 0.0 && n.abs() < 9.1e18 => {
                Some(*n as i64)
            }
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_i64().and_then(|v| u64::try_from(v).ok())
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(m) => Some(m),
            _ => None,
        }
    }

    /// Required-field helpers that produce good error messages for
    /// manifest parsing.
    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Value::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing string field '{key}'"))
    }

    pub fn req_u64(&self, key: &str) -> anyhow::Result<u64> {
        self.get(key)
            .and_then(Value::as_u64)
            .ok_or_else(|| anyhow::anyhow!("missing u64 field '{key}'"))
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(Value::as_f64)
            .ok_or_else(|| anyhow::anyhow!("missing number field '{key}'"))
    }

    pub fn req_array(&self, key: &str) -> anyhow::Result<&[Value]> {
        self.get(key)
            .and_then(Value::as_array)
            .ok_or_else(|| anyhow::anyhow!("missing array field '{key}'"))
    }
}

impl From<bool> for Value {
    fn from(v: bool) -> Self {
        Value::Bool(v)
    }
}
impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::Num(v)
    }
}
impl From<i64> for Value {
    fn from(v: i64) -> Self {
        Value::Num(v as f64)
    }
}
impl From<u64> for Value {
    fn from(v: u64) -> Self {
        Value::Num(v as f64)
    }
}
impl From<usize> for Value {
    fn from(v: usize) -> Self {
        Value::Num(v as f64)
    }
}
impl From<u32> for Value {
    fn from(v: u32) -> Self {
        Value::Num(v as f64)
    }
}
impl From<&str> for Value {
    fn from(v: &str) -> Self {
        Value::Str(v.to_string())
    }
}
impl From<String> for Value {
    fn from(v: String) -> Self {
        Value::Str(v)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Self {
        Value::Array(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_and_accessors() {
        let mut v = Value::obj();
        v.set("name", "ckpt-3")
            .set("size", 1024u64)
            .set("valid", true)
            .set("tags", vec!["a", "b"]);
        assert_eq!(v.req_str("name").unwrap(), "ckpt-3");
        assert_eq!(v.req_u64("size").unwrap(), 1024);
        assert_eq!(v.get("valid").unwrap().as_bool(), Some(true));
        assert_eq!(v.req_array("tags").unwrap().len(), 2);
        assert!(v.req_str("missing").is_err());
    }

    #[test]
    fn as_i64_rejects_fractions() {
        assert_eq!(Value::Num(1.5).as_i64(), None);
        assert_eq!(Value::Num(-3.0).as_i64(), Some(-3));
        assert_eq!(Value::Num(-1.0).as_u64(), None);
    }

    #[test]
    fn round_trip_parse_write() {
        let src = r#"{"a":[1,2.5,null,true,"x\n"],"b":{"c":-7}}"#;
        let v = parse(src).unwrap();
        let out = to_string(&v);
        assert_eq!(parse(&out).unwrap(), v);
    }
}
