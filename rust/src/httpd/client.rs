//! Blocking HTTP/1.1 client for `http://host:port/...` URLs.

use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

const TIMEOUT: Duration = Duration::from_secs(10);

fn split_url(url: &str) -> Result<(String, String)> {
    let rest = url
        .strip_prefix("http://")
        .context("only http:// URLs supported")?;
    let (host, path) = match rest.split_once('/') {
        Some((h, p)) => (h.to_string(), format!("/{p}")),
        None => (rest.to_string(), "/".to_string()),
    };
    Ok((host, path))
}

fn request(method: &str, url: &str, body: Option<&str>) -> Result<(u16, String)> {
    let (host, path) = split_url(url)?;
    let mut stream =
        TcpStream::connect(&host).with_context(|| format!("connect {host}"))?;
    stream.set_read_timeout(Some(TIMEOUT))?;
    stream.set_write_timeout(Some(TIMEOUT))?;
    let body_bytes = body.unwrap_or("").as_bytes();
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: {host}\r\nMetadata: true\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body_bytes.len()
    );
    stream.write_all(req.as_bytes())?;
    stream.write_all(body_bytes)?;
    stream.flush()?;

    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line).context("status line")?;
    let mut parts = status_line.trim_end().splitn(3, ' ');
    let version = parts.next().unwrap_or("");
    if !version.starts_with("HTTP/1.") {
        bail!("bad status line: {status_line:?}");
    }
    let status: u16 = parts
        .next()
        .context("missing status code")?
        .parse()
        .context("bad status code")?;

    let mut content_length: Option<usize> = None;
    loop {
        let mut line = String::new();
        reader.read_line(&mut line)?;
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        if let Some((name, value)) = line.split_once(':') {
            if name.trim().eq_ignore_ascii_case("content-length") {
                content_length =
                    Some(value.trim().parse().context("bad content-length")?);
            }
        }
    }
    let body = match content_length {
        Some(n) => {
            let mut buf = vec![0u8; n];
            reader.read_exact(&mut buf).context("response body")?;
            buf
        }
        None => {
            // Connection: close semantics — read to EOF.
            let mut buf = Vec::new();
            reader.read_to_end(&mut buf)?;
            buf
        }
    };
    Ok((status, String::from_utf8_lossy(&body).into_owned()))
}

/// GET a URL; returns (status, body). The `Metadata: true` header required
/// by Azure IMDS is always sent.
pub fn http_get(url: &str) -> Result<(u16, String)> {
    request("GET", url, None)
}

/// POST a string body.
pub fn http_post(url: &str, body: &str) -> Result<(u16, String)> {
    request("POST", url, Some(body))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn url_splitting() {
        assert_eq!(
            split_url("http://127.0.0.1:8080/metadata?x=1").unwrap(),
            ("127.0.0.1:8080".into(), "/metadata?x=1".into())
        );
        assert_eq!(
            split_url("http://127.0.0.1:8080").unwrap(),
            ("127.0.0.1:8080".into(), "/".into())
        );
        assert!(split_url("https://x").is_err());
        assert!(split_url("ftp://x").is_err());
    }

    #[test]
    fn connect_refused_errors() {
        // Port 1 is essentially never listening.
        assert!(http_get("http://127.0.0.1:1/x").is_err());
    }
}
