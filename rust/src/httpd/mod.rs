//! Minimal HTTP/1.1 server + client over `std::net` (no tokio offline —
//! DESIGN.md §8).
//!
//! Purpose-built for the IMDS scheduled-events facade
//! ([`crate::cloud::imds_http`]): GET/POST with `Content-Length` bodies,
//! query strings, custom headers, keep-alive disabled (connection per
//! request, which matches how short metadata polls behave and keeps the
//! implementation obviously correct).

mod server;
mod client;

pub use client::{http_get, http_post};
pub use server::{HttpServer, Request, Response};

use std::collections::BTreeMap;

/// Parse `name: value` header lines (case-insensitive names).
pub(crate) fn parse_headers(
    lines: &[&str],
) -> anyhow::Result<BTreeMap<String, String>> {
    let mut headers = BTreeMap::new();
    for line in lines {
        let (name, value) = line
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("malformed header line: {line}"))?;
        headers.insert(
            name.trim().to_ascii_lowercase(),
            value.trim().to_string(),
        );
    }
    Ok(headers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_parsing() {
        let h = parse_headers(&["Content-Length: 12", "X-Test:  hi "]).unwrap();
        assert_eq!(h.get("content-length").map(String::as_str), Some("12"));
        assert_eq!(h.get("x-test").map(String::as_str), Some("hi"));
        assert!(parse_headers(&["garbage"]).is_err());
    }
}
