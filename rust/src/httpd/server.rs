//! Threaded HTTP/1.1 server.

use super::parse_headers;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

/// A parsed request.
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    /// Path without the query string.
    pub path: String,
    /// Decoded query parameters (no %-decoding; IMDS uses plain tokens).
    pub query: Vec<(String, String)>,
    pub headers: std::collections::BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn query_param(&self, name: &str) -> Option<&str> {
        self.query
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }

    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.get(&name.to_ascii_lowercase()).map(String::as_str)
    }
}

/// A response under construction.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub reason: &'static str,
    pub content_type: String,
    pub body: Vec<u8>,
}

impl Response {
    pub fn ok_json(body: String) -> Self {
        Self {
            status: 200,
            reason: "OK",
            content_type: "application/json".into(),
            body: body.into_bytes(),
        }
    }

    pub fn ok_text(body: &str) -> Self {
        Self {
            status: 200,
            reason: "OK",
            content_type: "text/plain".into(),
            body: body.as_bytes().to_vec(),
        }
    }

    pub fn bad_request(msg: &str) -> Self {
        Self {
            status: 400,
            reason: "Bad Request",
            content_type: "text/plain".into(),
            body: msg.as_bytes().to_vec(),
        }
    }

    pub fn not_found() -> Self {
        Self {
            status: 404,
            reason: "Not Found",
            content_type: "text/plain".into(),
            body: b"not found".to_vec(),
        }
    }

    fn write_to(&self, stream: &mut TcpStream) -> std::io::Result<()> {
        let head = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
            self.status,
            self.reason,
            self.content_type,
            self.body.len()
        );
        stream.write_all(head.as_bytes())?;
        stream.write_all(&self.body)?;
        stream.flush()
    }
}

pub(crate) fn read_request(stream: &mut TcpStream) -> Result<Request> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut request_line = String::new();
    reader.read_line(&mut request_line).context("request line")?;
    let mut parts = request_line.trim_end().split_whitespace();
    let method = parts.next().context("missing method")?.to_string();
    let target = parts.next().context("missing target")?.to_string();
    let version = parts.next().unwrap_or("");
    if !version.starts_with("HTTP/1.") {
        bail!("unsupported HTTP version '{version}'");
    }
    let mut header_lines = Vec::new();
    loop {
        let mut line = String::new();
        reader.read_line(&mut line).context("header line")?;
        let trimmed = line.trim_end().to_string();
        if trimmed.is_empty() {
            break;
        }
        header_lines.push(trimmed);
    }
    let refs: Vec<&str> = header_lines.iter().map(String::as_str).collect();
    let headers = parse_headers(&refs)?;
    let content_length: usize = headers
        .get("content-length")
        .map(|v| v.parse().context("bad content-length"))
        .transpose()?
        .unwrap_or(0);
    if content_length > 64 * 1024 * 1024 {
        bail!("body too large");
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).context("request body")?;

    let (path, query_str) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target, String::new()),
    };
    let query = query_str
        .split('&')
        .filter(|s| !s.is_empty())
        .map(|pair| match pair.split_once('=') {
            Some((k, v)) => (k.to_string(), v.to_string()),
            None => (pair.to_string(), String::new()),
        })
        .collect();
    Ok(Request { method, path, query, headers, body })
}

type Handler = Arc<dyn Fn(&Request) -> Response + Send + Sync>;

/// A running server; drop or [`HttpServer::shutdown`] to stop.
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

impl HttpServer {
    /// Bind 127.0.0.1 on an ephemeral port and serve `handler` on a
    /// background thread (connection-per-request).
    pub fn spawn(handler: Handler) -> Result<Self> {
        let listener =
            TcpListener::bind("127.0.0.1:0").context("bind 127.0.0.1")?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let accept_thread = std::thread::Builder::new()
            .name("imds-http".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if stop2.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(mut stream) = conn else { continue };
                    let handler = handler.clone();
                    // Handle inline: metadata polls are small and serial;
                    // a thread per connection would only add schedule
                    // noise to the latency benches.
                    let resp = match read_request(&mut stream) {
                        Ok(req) => handler(&req),
                        Err(e) => Response::bad_request(&e.to_string()),
                    };
                    let _ = resp.write_to(&mut stream);
                }
            })?;
        Ok(Self { addr, stop, accept_thread: Some(accept_thread) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn base_url(&self) -> String {
        format!("http://{}", self.addr)
    }

    /// Stop accepting and join the accept thread.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Unblock accept() with a dummy connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::super::client::{http_get, http_post};
    use super::*;

    fn echo_server() -> HttpServer {
        HttpServer::spawn(Arc::new(|req: &Request| match req.path.as_str() {
            "/echo" => Response::ok_json(format!(
                "{{\"method\":\"{}\",\"len\":{},\"v\":\"{}\"}}",
                req.method,
                req.body.len(),
                req.query_param("api-version").unwrap_or("")
            )),
            _ => Response::not_found(),
        }))
        .unwrap()
    }

    #[test]
    fn get_with_query() {
        let srv = echo_server();
        let (status, body) =
            http_get(&format!("{}/echo?api-version=2020-07-01", srv.base_url()))
                .unwrap();
        assert_eq!(status, 200);
        let v = crate::json::parse(&body).unwrap();
        assert_eq!(v.req_str("method").unwrap(), "GET");
        assert_eq!(v.req_str("v").unwrap(), "2020-07-01");
    }

    #[test]
    fn post_with_body() {
        let srv = echo_server();
        let (status, body) = http_post(
            &format!("{}/echo", srv.base_url()),
            "{\"StartRequests\":[]}",
        )
        .unwrap();
        assert_eq!(status, 200);
        let v = crate::json::parse(&body).unwrap();
        assert_eq!(v.req_u64("len").unwrap(), 20);
        assert_eq!(v.req_str("method").unwrap(), "POST");
    }

    #[test]
    fn unknown_path_404() {
        let srv = echo_server();
        let (status, _) = http_get(&format!("{}/nope", srv.base_url())).unwrap();
        assert_eq!(status, 404);
    }

    #[test]
    fn many_sequential_requests() {
        let srv = echo_server();
        for _ in 0..50 {
            let (status, _) =
                http_get(&format!("{}/echo", srv.base_url())).unwrap();
            assert_eq!(status, 200);
        }
    }

    #[test]
    fn shutdown_then_connect_fails() {
        let mut srv = echo_server();
        let url = format!("{}/echo", srv.base_url());
        srv.shutdown();
        // After shutdown the listener is dropped; request must error.
        assert!(http_get(&url).is_err());
    }
}
