//! Fault-wrapping storage backend: seeded chaos over any [`SharedStore`].
//!
//! [`ChaosStore`] wraps a real backend (`local`/`nfs`/`blob`) and injects
//! storage failures into checkpoint writes (keys under `ckpt/`), drawing
//! every decision from a salted per-run PRNG stream so fault timing is a
//! function of the scenario seed only — never thread, worker or shard
//! count ([`crate::sim::chaos`] holds the plan-level counterpart):
//!
//! * **write failure** — the put dies before any bytes move; the caller
//!   sees an [`InjectedFault`] with zero burned transfer time.
//! * **torn write** — the connection dies mid-transfer: the first half of
//!   the object lands under the real key (a torn `payload.bin` or
//!   `manifest.json` that manifest-hash verification later rejects), and
//!   the caller is charged the partial transfer.
//! * **corruption** — the payload is stored bit-flipped and the put
//!   *succeeds*; nothing notices until restore-time CRC/SHA verification
//!   fails and the coordinator falls back a generation
//!   ([`crate::coordinator::restart`]).
//! * **latency spike** — the put succeeds but costs extra virtual time.
//!
//! Injected failures are typed ([`InjectedFault`]) so the retry path can
//! distinguish them from real I/O errors (which still abort the run), and
//! every injection is appended to an in-order fault log the engines drain
//! into their timelines for the `report/` fault-accounting table.
//!
//! A disabled wrapper (chaos off) is pure delegation — no PRNG draws, no
//! log writes — which is what keeps chaos-off digests byte-identical.

use super::{IoMeter, SharedStore};
use crate::config::ChaosStorageCfg;
use crate::simclock::SimDuration;
use crate::util::prng::Prng;
use anyhow::Result;
use std::fmt;

/// Salt decorrelating the storage-fault stream from every other consumer
/// of the scenario seed.
pub const STORAGE_CHAOS_SALT: u64 = 0x5707_A6E0_FAB1_7CA0;

/// What kind of failure was injected into a storage operation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    WriteFail,
    TornWrite,
    Corrupt,
    LatencySpike,
}

impl FaultKind {
    pub fn as_str(self) -> &'static str {
        match self {
            FaultKind::WriteFail => "write-fail",
            FaultKind::TornWrite => "torn-write",
            FaultKind::Corrupt => "corrupt",
            FaultKind::LatencySpike => "latency-spike",
        }
    }
}

/// A typed injected failure: downcast via
/// `err.downcast_ref::<InjectedFault>()` to tell chaos from a real I/O
/// error. `burned` is the virtual transfer time consumed before the
/// operation died (zero for an outright write failure, the partial
/// transfer for a torn write) — the caller still pays it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InjectedFault {
    pub kind: FaultKind,
    pub burned: SimDuration,
}

impl fmt::Display for InjectedFault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "injected {} fault ({} burned)",
            self.kind.as_str(),
            self.burned
        )
    }
}

impl std::error::Error for InjectedFault {}

/// One injection, recorded in occurrence order for the timeline.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultEvent {
    pub kind: FaultKind,
    pub key: String,
}

/// Seeded fault injection over an inner [`SharedStore`].
#[derive(Debug, Clone)]
pub struct ChaosStore<S> {
    inner: S,
    cfg: ChaosStorageCfg,
    rng: Prng,
    enabled: bool,
    log: Vec<FaultEvent>,
}

impl<S: SharedStore> ChaosStore<S> {
    /// An armed wrapper. `seed` should be
    /// `mix64(scenario_seed ^ chaos_salt ^ STORAGE_CHAOS_SALT)` (plus a
    /// per-job stride in the cluster) so fault draws are decorrelated but
    /// reproducible.
    pub fn new(inner: S, cfg: ChaosStorageCfg, seed: u64) -> Self {
        Self { inner, cfg, rng: Prng::new(seed), enabled: true, log: Vec::new() }
    }

    /// A disabled wrapper: pure delegation, no PRNG draws, byte-identical
    /// behaviour to the bare inner store.
    pub fn passthrough(inner: S) -> Self {
        Self {
            inner,
            cfg: ChaosStorageCfg::default(),
            rng: Prng::new(0),
            enabled: false,
            log: Vec::new(),
        }
    }

    /// Drain the injections recorded since the last call, in order.
    pub fn take_faults(&mut self) -> Vec<FaultEvent> {
        std::mem::take(&mut self.log)
    }

    pub fn inner(&self) -> &S {
        &self.inner
    }

    pub fn inner_mut(&mut self) -> &mut S {
        &mut self.inner
    }

    fn record(&mut self, kind: FaultKind, key: &str) {
        self.log.push(FaultEvent { kind, key: key.to_string() });
    }
}

impl<S: SharedStore> SharedStore for ChaosStore<S> {
    fn put_sized(
        &mut self,
        key: &str,
        data: &[u8],
        charged_bytes: u64,
    ) -> Result<SimDuration> {
        if !self.enabled || !key.starts_with("ckpt/") {
            return self.inner.put_sized(key, data, charged_bytes);
        }
        if self.rng.chance(self.cfg.write_fail_prob) {
            self.record(FaultKind::WriteFail, key);
            return Err(InjectedFault {
                kind: FaultKind::WriteFail,
                burned: SimDuration::ZERO,
            }
            .into());
        }
        if self.rng.chance(self.cfg.torn_write_prob) {
            // the connection dies halfway: the prefix lands under the real
            // key (manifest verification rejects it later) and the caller
            // pays for the partial transfer
            let burned = self
                .inner
                .put_sized(key, &data[..data.len() / 2], charged_bytes / 2)?;
            self.record(FaultKind::TornWrite, key);
            return Err(InjectedFault { kind: FaultKind::TornWrite, burned }
                .into());
        }
        let spike = if self.rng.chance(self.cfg.latency_spike_prob) {
            self.record(FaultKind::LatencySpike, key);
            self.cfg.latency_spike
        } else {
            SimDuration::ZERO
        };
        if key.ends_with("/payload.bin")
            && !data.is_empty()
            && self.rng.chance(self.cfg.corrupt_prob)
        {
            // silent bit rot: the put succeeds, the damage only surfaces
            // when restore-time CRC/SHA verification rejects the snapshot
            let pos = self.rng.below(data.len() as u64) as usize;
            let bit = self.rng.below(8) as u8;
            let mut copy = data.to_vec();
            copy[pos] ^= 1 << bit;
            let cost = self.inner.put_sized(key, &copy, charged_bytes)?;
            self.record(FaultKind::Corrupt, key);
            return Ok(cost + spike);
        }
        Ok(self.inner.put_sized(key, data, charged_bytes)? + spike)
    }

    fn get(&mut self, key: &str) -> Result<(Vec<u8>, SimDuration)> {
        self.inner.get(key)
    }

    fn list(&self, prefix: &str) -> Result<Vec<String>> {
        self.inner.list(prefix)
    }

    fn exists(&self, key: &str) -> bool {
        self.inner.exists(key)
    }

    fn delete(&mut self, key: &str) -> Result<bool> {
        self.inner.delete(key)
    }

    fn transfer_cost(&self, bytes: u64) -> SimDuration {
        self.inner.transfer_cost(bytes)
    }

    fn used_bytes(&self) -> u64 {
        self.inner.used_bytes()
    }

    fn capacity_bytes(&self) -> Option<u64> {
        self.inner.capacity_bytes()
    }

    fn meter(&self) -> IoMeter {
        self.inner.meter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::BlobStore;

    fn store() -> BlobStore {
        BlobStore::for_tests()
    }

    fn all_on() -> ChaosStorageCfg {
        ChaosStorageCfg {
            write_fail_prob: 1.0,
            torn_write_prob: 0.0,
            corrupt_prob: 0.0,
            latency_spike_prob: 0.0,
            ..ChaosStorageCfg::default()
        }
    }

    #[test]
    fn passthrough_is_byte_identical() {
        let mut plain = store();
        let mut wrapped = ChaosStore::passthrough(store());
        let cost_a = plain.put("ckpt/a/payload.bin", b"hello").unwrap();
        let cost_b = wrapped.put("ckpt/a/payload.bin", b"hello").unwrap();
        assert_eq!(cost_a, cost_b);
        assert_eq!(
            plain.get("ckpt/a/payload.bin").unwrap(),
            wrapped.get("ckpt/a/payload.bin").unwrap()
        );
        assert_eq!(plain.meter(), wrapped.meter());
        assert!(wrapped.take_faults().is_empty());
    }

    #[test]
    fn zero_probability_chaos_changes_nothing_observable() {
        let mut plain = store();
        let mut armed =
            ChaosStore::new(store(), ChaosStorageCfg::default(), 42);
        for i in 0..8 {
            let key = format!("ckpt/{i:010}-periodic/payload.bin");
            let a = plain.put_sized(&key, b"state", 1 << 20).unwrap();
            let b = armed.put_sized(&key, b"state", 1 << 20).unwrap();
            assert_eq!(a, b);
        }
        assert_eq!(plain.meter(), armed.meter());
        assert!(armed.take_faults().is_empty());
    }

    #[test]
    fn write_fail_is_typed_and_burns_nothing() {
        let mut chaos = ChaosStore::new(store(), all_on(), 7);
        let err = chaos.put("ckpt/0/payload.bin", b"state").unwrap_err();
        let fault = err.downcast_ref::<InjectedFault>().expect("typed");
        assert_eq!(fault.kind, FaultKind::WriteFail);
        assert_eq!(fault.burned, SimDuration::ZERO);
        assert!(!chaos.exists("ckpt/0/payload.bin"));
        let faults = chaos.take_faults();
        assert_eq!(faults.len(), 1);
        assert_eq!(faults[0].kind, FaultKind::WriteFail);
        // non-checkpoint keys are never touched
        assert!(chaos.put("scratch/x", b"ok").is_ok());
    }

    #[test]
    fn torn_write_leaves_a_prefix_and_charges_it() {
        let cfg = ChaosStorageCfg {
            torn_write_prob: 1.0,
            ..ChaosStorageCfg::default()
        };
        let mut chaos = ChaosStore::new(store(), cfg, 7);
        let err =
            chaos.put_sized("ckpt/0/payload.bin", b"0123456789", 10).unwrap_err();
        let fault = err.downcast_ref::<InjectedFault>().expect("typed");
        assert_eq!(fault.kind, FaultKind::TornWrite);
        let (data, _) = chaos.get("ckpt/0/payload.bin").unwrap();
        assert_eq!(data, b"01234");
    }

    #[test]
    fn corruption_succeeds_but_flips_one_bit() {
        let cfg = ChaosStorageCfg {
            corrupt_prob: 1.0,
            ..ChaosStorageCfg::default()
        };
        let mut chaos = ChaosStore::new(store(), cfg, 7);
        let original = b"checkpoint payload bytes".to_vec();
        chaos.put("ckpt/0/payload.bin", &original).unwrap();
        let (stored, _) = chaos.get("ckpt/0/payload.bin").unwrap();
        assert_eq!(stored.len(), original.len());
        let flipped: u32 = stored
            .iter()
            .zip(&original)
            .map(|(a, b)| (a ^ b).count_ones())
            .sum();
        assert_eq!(flipped, 1, "exactly one bit flips");
        // manifests are spared: only payloads rot silently
        chaos.put("ckpt/0/manifest.json", &original).unwrap();
        let (m, _) = chaos.get("ckpt/0/manifest.json").unwrap();
        assert_eq!(m, original);
    }

    #[test]
    fn latency_spike_adds_cost_without_failing() {
        let cfg = ChaosStorageCfg {
            latency_spike_prob: 1.0,
            latency_spike: SimDuration::from_secs(3),
            ..ChaosStorageCfg::default()
        };
        let mut plain = store();
        let mut chaos = ChaosStore::new(store(), cfg, 7);
        let base = plain.put("ckpt/0/payload.bin", b"state").unwrap();
        let spiked = chaos.put("ckpt/0/payload.bin", b"state").unwrap();
        assert_eq!(spiked, base + SimDuration::from_secs(3));
        assert_eq!(
            chaos.get("ckpt/0/payload.bin").unwrap().0,
            plain.get("ckpt/0/payload.bin").unwrap().0
        );
    }

    #[test]
    fn fault_stream_is_deterministic_for_a_seed() {
        let cfg = ChaosStorageCfg {
            write_fail_prob: 0.4,
            torn_write_prob: 0.3,
            latency_spike_prob: 0.2,
            ..ChaosStorageCfg::default()
        };
        let run = |seed: u64| {
            let mut chaos = ChaosStore::new(store(), cfg.clone(), seed);
            let mut outcomes = Vec::new();
            for i in 0..32 {
                let key = format!("ckpt/{i:010}-periodic/payload.bin");
                outcomes.push(match chaos.put(&key, b"state") {
                    Ok(cost) => format!("ok:{}", cost.as_millis()),
                    Err(e) => format!(
                        "fault:{}",
                        e.downcast_ref::<InjectedFault>().unwrap().kind.as_str()
                    ),
                });
            }
            (outcomes, chaos.take_faults())
        };
        assert_eq!(run(11), run(11));
        assert_ne!(run(11).0, run(12).0);
    }
}
