//! Shared storage: where checkpoints live across instance evictions.
//!
//! The paper transfers checkpoints between spot instances "through shared
//! cloud storage services such as elastic block stores, network or
//! distributed file systems, object, and blob stores", and its testbed
//! uses Azure Files NFS at $16 per 100 GiB provisioned (§III-A). This
//! module provides that substrate:
//!
//! * [`NfsStore`] — a real directory-backed share with a provisioned-
//!   capacity limit and a bandwidth/latency transfer model; every I/O is
//!   metered (bytes + virtual transfer cost) and feeds Fig 2's billing.
//! * [`BlobStore`] — in-memory object store with the same trait, used by
//!   unit tests and as the alternative backend the paper mentions.
//! * [`LocalScratch`] — instance-local state that is *lost on eviction*,
//!   modeling the D8s_v3 local disk; exists so tests can prove the
//!   coordinator never depends on it across restarts.
//!
//! Sizes are dual-tracked (DESIGN.md §6): `data.len()` is what's really
//! stored and checksummed; `charged_bytes` is the modeled transfer size
//! (a CRIU image of a 32 GiB VM is GBs even when the simulated workload's
//! real state is KBs) and drives transfer time, capacity and billing.

pub mod nfs;
pub mod blob;
pub mod local;
pub mod chaos;

pub use blob::BlobStore;
pub use chaos::{ChaosStore, FaultEvent, FaultKind, InjectedFault};
pub use local::LocalScratch;
pub use nfs::NfsStore;

use crate::simclock::SimDuration;
use anyhow::Result;

/// Transfer-time model: latency + size/bandwidth.
#[derive(Debug, Clone, Copy)]
pub struct TransferModel {
    pub bandwidth_mib_s: f64,
    pub latency: SimDuration,
}

impl TransferModel {
    pub fn cost(&self, bytes: u64) -> SimDuration {
        let secs = bytes as f64 / (self.bandwidth_mib_s * 1024.0 * 1024.0);
        self.latency + SimDuration::from_secs_f64(secs)
    }
}

/// Cumulative I/O accounting for one store.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct IoMeter {
    pub puts: u64,
    pub gets: u64,
    pub deletes: u64,
    pub bytes_written: u64,
    pub bytes_read: u64,
    /// Modeled bytes (charged sizes), the Fig-2-relevant number.
    pub charged_written: u64,
    pub charged_read: u64,
    /// Total virtual time spent in transfers.
    pub transfer_time: SimDuration,
}

/// A shared store reachable from every instance in the scale set.
pub trait SharedStore {
    /// Store `data` under `key`, charging `charged_bytes` against capacity
    /// and the transfer model. Returns the virtual transfer cost.
    fn put_sized(
        &mut self,
        key: &str,
        data: &[u8],
        charged_bytes: u64,
    ) -> Result<SimDuration>;

    /// Store with charged size == real size.
    fn put(&mut self, key: &str, data: &[u8]) -> Result<SimDuration> {
        self.put_sized(key, data, data.len() as u64)
    }

    /// Fetch `key`; returns data + virtual transfer cost (charged at the
    /// size recorded by the original put).
    fn get(&mut self, key: &str) -> Result<(Vec<u8>, SimDuration)>;

    /// Keys with the given prefix, sorted.
    fn list(&self, prefix: &str) -> Result<Vec<String>>;

    fn exists(&self, key: &str) -> bool;

    /// Delete a key (idempotent); returns whether it existed.
    fn delete(&mut self, key: &str) -> Result<bool>;

    /// Modeled transfer cost for a hypothetical payload (used to decide
    /// whether a termination checkpoint can beat the notice deadline).
    fn transfer_cost(&self, bytes: u64) -> SimDuration;

    /// Charged bytes currently stored.
    fn used_bytes(&self) -> u64;

    /// Provisioned capacity, if bounded.
    fn capacity_bytes(&self) -> Option<u64>;

    fn meter(&self) -> IoMeter;
}

/// Mutable references delegate, so wrappers like
/// [`chaos::ChaosStore<&mut dyn SharedStore>`] can stack over a borrowed
/// backend without taking ownership.
impl<T: SharedStore + ?Sized> SharedStore for &mut T {
    fn put_sized(
        &mut self,
        key: &str,
        data: &[u8],
        charged_bytes: u64,
    ) -> Result<SimDuration> {
        (**self).put_sized(key, data, charged_bytes)
    }

    fn get(&mut self, key: &str) -> Result<(Vec<u8>, SimDuration)> {
        (**self).get(key)
    }

    fn list(&self, prefix: &str) -> Result<Vec<String>> {
        (**self).list(prefix)
    }

    fn exists(&self, key: &str) -> bool {
        (**self).exists(key)
    }

    fn delete(&mut self, key: &str) -> Result<bool> {
        (**self).delete(key)
    }

    fn transfer_cost(&self, bytes: u64) -> SimDuration {
        (**self).transfer_cost(bytes)
    }

    fn used_bytes(&self) -> u64 {
        (**self).used_bytes()
    }

    fn capacity_bytes(&self) -> Option<u64> {
        (**self).capacity_bytes()
    }

    fn meter(&self) -> IoMeter {
        (**self).meter()
    }
}

/// Validate a storage key: path-like, no escapes.
pub(crate) fn validate_key(key: &str) -> Result<()> {
    if key.is_empty() || key.len() > 512 {
        anyhow::bail!("bad key length");
    }
    if key.starts_with('/') || key.ends_with('/') {
        anyhow::bail!("key must not start/end with '/'");
    }
    for part in key.split('/') {
        if part.is_empty() || part == "." || part == ".." {
            anyhow::bail!("bad key segment '{part}'");
        }
        if !part
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || "-_.".contains(c))
        {
            anyhow::bail!("bad character in key segment '{part}'");
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_model_math() {
        let m = TransferModel {
            bandwidth_mib_s: 100.0,
            latency: SimDuration::from_millis(20),
        };
        // 100 MiB at 100 MiB/s = 1 s + 20 ms
        assert_eq!(m.cost(100 * 1024 * 1024).as_millis(), 1020);
        assert_eq!(m.cost(0).as_millis(), 20);
        // 3 GiB CRIU image at 250 MiB/s ≈ 12.3 s — beats a 30 s notice
        let azure = TransferModel {
            bandwidth_mib_s: 250.0,
            latency: SimDuration::from_millis(20),
        };
        let t = azure.cost(3 * 1024 * 1024 * 1024);
        assert!(t.as_secs() >= 12 && t.as_secs() <= 13, "{t}");
    }

    #[test]
    fn key_validation() {
        assert!(validate_key("ckpt/000123/manifest.json").is_ok());
        assert!(validate_key("a-b_c.d").is_ok());
        for bad in [
            "", "/abs", "trail/", "a//b", "a/../b", "a/./b", "sp ace",
            "quo\"te", "back\\slash",
        ] {
            assert!(validate_key(bad).is_err(), "should reject {bad:?}");
        }
        let long = "x".repeat(600);
        assert!(validate_key(&long).is_err());
    }
}
