//! In-memory object store (blob-store backend).
//!
//! Same [`SharedStore`] contract as [`super::NfsStore`] without touching
//! the filesystem: used by unit tests, the pure-simulation fast path of
//! the benches, and as the "object and blob stores" alternative backend
//! the paper lists for checkpoint sharing.

use super::{validate_key, IoMeter, SharedStore, TransferModel};
use crate::simclock::SimDuration;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

#[derive(Debug, Clone)]
struct Object {
    data: Vec<u8>,
    charged: u64,
}

/// In-memory blob store with the same capacity/transfer semantics.
#[derive(Debug)]
pub struct BlobStore {
    objects: BTreeMap<String, Object>,
    model: TransferModel,
    capacity: Option<u64>,
    meter: IoMeter,
}

impl BlobStore {
    pub fn new(model: TransferModel, capacity_gib: Option<f64>) -> Self {
        Self {
            objects: BTreeMap::new(),
            model,
            capacity: capacity_gib
                .map(|g| (g * 1024.0 * 1024.0 * 1024.0) as u64),
            meter: IoMeter::default(),
        }
    }

    /// A fast default for tests: 250 MiB/s, 20 ms latency, unbounded.
    pub fn for_tests() -> Self {
        Self::new(
            TransferModel {
                bandwidth_mib_s: 250.0,
                latency: SimDuration::from_millis(20),
            },
            None,
        )
    }

    /// Corrupt a stored object in place (failure-injection helper used by
    /// checkpoint-validation tests; not part of [`SharedStore`]).
    pub fn corrupt(&mut self, key: &str, at: usize) -> Result<()> {
        let obj = self
            .objects
            .get_mut(key)
            .with_context(|| format!("no object {key}"))?;
        if obj.data.is_empty() {
            bail!("empty object");
        }
        let i = at % obj.data.len();
        obj.data[i] ^= 0xff;
        Ok(())
    }

    /// Truncate a stored object (models a partial write that lost its
    /// tail when the instance died mid-transfer).
    pub fn truncate(&mut self, key: &str, keep: usize) -> Result<()> {
        let obj = self
            .objects
            .get_mut(key)
            .with_context(|| format!("no object {key}"))?;
        obj.data.truncate(keep);
        Ok(())
    }
}

impl SharedStore for BlobStore {
    fn put_sized(
        &mut self,
        key: &str,
        data: &[u8],
        charged_bytes: u64,
    ) -> Result<SimDuration> {
        validate_key(key)?;
        let new_total = self.used_bytes()
            - self.objects.get(key).map_or(0, |o| o.charged)
            + charged_bytes;
        if let Some(cap) = self.capacity {
            if new_total > cap {
                bail!("blob store full");
            }
        }
        self.objects.insert(
            key.to_string(),
            Object { data: data.to_vec(), charged: charged_bytes },
        );
        let cost = self.model.cost(charged_bytes);
        self.meter.puts += 1;
        self.meter.bytes_written += data.len() as u64;
        self.meter.charged_written += charged_bytes;
        self.meter.transfer_time += cost;
        Ok(cost)
    }

    fn get(&mut self, key: &str) -> Result<(Vec<u8>, SimDuration)> {
        validate_key(key)?;
        let obj = self
            .objects
            .get(key)
            .with_context(|| format!("no object {key}"))?;
        let cost = self.model.cost(obj.charged);
        let data = obj.data.clone();
        self.meter.gets += 1;
        self.meter.bytes_read += data.len() as u64;
        self.meter.charged_read += obj.charged;
        self.meter.transfer_time += cost;
        Ok((data, cost))
    }

    fn list(&self, prefix: &str) -> Result<Vec<String>> {
        Ok(self
            .objects
            .keys()
            .filter(|k| k.starts_with(prefix))
            .cloned()
            .collect())
    }

    fn exists(&self, key: &str) -> bool {
        self.objects.contains_key(key)
    }

    fn delete(&mut self, key: &str) -> Result<bool> {
        validate_key(key)?;
        let existed = self.objects.remove(key).is_some();
        if existed {
            self.meter.deletes += 1;
        }
        Ok(existed)
    }

    fn transfer_cost(&self, bytes: u64) -> SimDuration {
        self.model.cost(bytes)
    }

    fn used_bytes(&self) -> u64 {
        self.objects.values().map(|o| o.charged).sum()
    }

    fn capacity_bytes(&self) -> Option<u64> {
        self.capacity
    }

    fn meter(&self) -> IoMeter {
        self.meter
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn behaves_like_shared_store() {
        let mut s = BlobStore::for_tests();
        s.put("a/b", b"one").unwrap();
        s.put_sized("a/c", b"two", 1000).unwrap();
        assert_eq!(s.list("a/").unwrap(), vec!["a/b", "a/c"]);
        assert_eq!(s.get("a/b").unwrap().0, b"one");
        assert_eq!(s.used_bytes(), 3 + 1000);
        assert!(s.delete("a/b").unwrap());
        assert!(!s.delete("a/b").unwrap());
    }

    #[test]
    fn capacity_enforced() {
        let mut s = BlobStore::new(
            TransferModel {
                bandwidth_mib_s: 1.0,
                latency: SimDuration::ZERO,
            },
            Some(1.0 / 1024.0 / 1024.0), // 1 KiB
        );
        s.put_sized("a", b"x", 600).unwrap();
        assert!(s.put_sized("b", b"y", 600).is_err());
        // replacing a's charge is fine
        s.put_sized("a", b"x", 1000).unwrap();
    }

    #[test]
    fn corruption_helpers() {
        let mut s = BlobStore::for_tests();
        s.put("k", b"hello").unwrap();
        s.corrupt("k", 1).unwrap();
        assert_ne!(s.get("k").unwrap().0, b"hello");
        s.truncate("k", 2).unwrap();
        assert_eq!(s.get("k").unwrap().0.len(), 2);
        assert!(s.corrupt("missing", 0).is_err());
    }
}
