//! Instance-local scratch storage — destroyed with the instance.
//!
//! Spot eviction "terminates all workloads running on the instance, and
//! the instance is destroyed" (paper §I): anything on the local disk is
//! gone. The coordinator must therefore never rely on local state across
//! restarts; tests use this type to prove it (a restart after
//! [`LocalScratch::wipe`] must still find everything it needs on the
//! shared store).

use std::collections::BTreeMap;

/// Ephemeral per-instance key-value scratch.
#[derive(Debug, Default)]
pub struct LocalScratch {
    data: BTreeMap<String, Vec<u8>>,
    wipes: u32,
}

impl LocalScratch {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn put(&mut self, key: &str, data: &[u8]) {
        self.data.insert(key.to_string(), data.to_vec());
    }

    pub fn get(&self, key: &str) -> Option<&[u8]> {
        self.data.get(key).map(Vec::as_slice)
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// The eviction: all local state vanishes.
    pub fn wipe(&mut self) {
        self.data.clear();
        self.wipes += 1;
    }

    pub fn wipes(&self) -> u32 {
        self.wipes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wipe_destroys_everything() {
        let mut s = LocalScratch::new();
        s.put("tmp/kmer-cache", b"bytes");
        s.put("tmp/log", b"more");
        assert_eq!(s.len(), 2);
        assert_eq!(s.get("tmp/log"), Some(b"more".as_slice()));
        s.wipe();
        assert!(s.is_empty());
        assert_eq!(s.get("tmp/log"), None);
        assert_eq!(s.wipes(), 1);
    }
}
