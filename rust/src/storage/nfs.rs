//! Directory-backed NFS-share model (Azure Files analog).
//!
//! Real files on the local filesystem (checkpoint integrity is tested
//! against real I/O, including partial-write crash injection), wrapped in
//! a provisioned-capacity + transfer-time model so the virtual-time and
//! billing behaviour matches a provisioned cloud share.

use super::{validate_key, IoMeter, SharedStore, TransferModel};
use crate::simclock::SimDuration;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

/// Sidecar extension storing each object's charged size.
const META_EXT: &str = ".charged";

/// A provisioned file share rooted at a directory.
#[derive(Debug)]
pub struct NfsStore {
    root: PathBuf,
    model: TransferModel,
    capacity: Option<u64>,
    /// key -> charged bytes (rebuilt from sidecars on open).
    charged: BTreeMap<String, u64>,
    meter: IoMeter,
}

impl NfsStore {
    /// Open (or create) a share rooted at `root`.
    pub fn open(
        root: &Path,
        model: TransferModel,
        capacity_gib: Option<f64>,
    ) -> Result<Self> {
        fs::create_dir_all(root)
            .with_context(|| format!("creating share root {root:?}"))?;
        let mut store = Self {
            root: root.to_path_buf(),
            model,
            capacity: capacity_gib
                .map(|g| (g * 1024.0 * 1024.0 * 1024.0) as u64),
            charged: BTreeMap::new(),
            meter: IoMeter::default(),
        };
        store.rescan()?;
        Ok(store)
    }

    /// Rebuild the charged-size index from disk (share reattach after an
    /// instance replacement — exactly what a new spot VM does on mount).
    pub fn rescan(&mut self) -> Result<()> {
        self.charged.clear();
        let mut stack = vec![self.root.clone()];
        while let Some(dir) = stack.pop() {
            for entry in fs::read_dir(&dir)? {
                let entry = entry?;
                let path = entry.path();
                if path.is_dir() {
                    stack.push(path);
                    continue;
                }
                let Some(name) = path.to_str() else { continue };
                if name.ends_with(META_EXT) {
                    continue;
                }
                // every path under the walk is below root, but a racing
                // rename could break that — skip rather than panic
                let Ok(rel) = path.strip_prefix(&self.root) else {
                    continue;
                };
                let key = rel.to_string_lossy().replace('\\', "/");
                let charged = fs::read_to_string(sidecar(&path))
                    .ok()
                    .and_then(|s| s.trim().parse::<u64>().ok())
                    .unwrap_or_else(|| {
                        path.metadata().map(|m| m.len()).unwrap_or(0)
                    });
                self.charged.insert(key, charged);
            }
        }
        Ok(())
    }

    fn path_for(&self, key: &str) -> PathBuf {
        self.root.join(key)
    }

    pub fn model(&self) -> TransferModel {
        self.model
    }
}

fn sidecar(path: &Path) -> PathBuf {
    let mut s = path.as_os_str().to_owned();
    s.push(META_EXT);
    PathBuf::from(s)
}

impl SharedStore for NfsStore {
    fn put_sized(
        &mut self,
        key: &str,
        data: &[u8],
        charged_bytes: u64,
    ) -> Result<SimDuration> {
        validate_key(key)?;
        let new_total = self.used_bytes()
            - self.charged.get(key).copied().unwrap_or(0)
            + charged_bytes;
        if let Some(cap) = self.capacity {
            if new_total > cap {
                bail!(
                    "share full: {} charged + {} requested exceeds provisioned {}",
                    crate::util::fmt::bytes(self.used_bytes()),
                    crate::util::fmt::bytes(charged_bytes),
                    crate::util::fmt::bytes(cap)
                );
            }
        }
        let path = self.path_for(key);
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        fs::write(&path, data).with_context(|| format!("writing {key}"))?;
        fs::write(sidecar(&path), charged_bytes.to_string())?;
        self.charged.insert(key.to_string(), charged_bytes);
        let cost = self.model.cost(charged_bytes);
        self.meter.puts += 1;
        self.meter.bytes_written += data.len() as u64;
        self.meter.charged_written += charged_bytes;
        self.meter.transfer_time += cost;
        Ok(cost)
    }

    fn get(&mut self, key: &str) -> Result<(Vec<u8>, SimDuration)> {
        validate_key(key)?;
        let path = self.path_for(key);
        let data =
            fs::read(&path).with_context(|| format!("reading {key}"))?;
        let charged = self
            .charged
            .get(key)
            .copied()
            .unwrap_or(data.len() as u64);
        let cost = self.model.cost(charged);
        self.meter.gets += 1;
        self.meter.bytes_read += data.len() as u64;
        self.meter.charged_read += charged;
        self.meter.transfer_time += cost;
        Ok((data, cost))
    }

    fn list(&self, prefix: &str) -> Result<Vec<String>> {
        Ok(self
            .charged
            .keys()
            .filter(|k| k.starts_with(prefix))
            .cloned()
            .collect())
    }

    fn exists(&self, key: &str) -> bool {
        self.charged.contains_key(key) && self.path_for(key).exists()
    }

    fn delete(&mut self, key: &str) -> Result<bool> {
        validate_key(key)?;
        let path = self.path_for(key);
        let existed = self.charged.remove(key).is_some();
        if path.exists() {
            fs::remove_file(&path)?;
        }
        let sc = sidecar(&path);
        if sc.exists() {
            fs::remove_file(sc)?;
        }
        if existed {
            self.meter.deletes += 1;
        }
        Ok(existed)
    }

    fn transfer_cost(&self, bytes: u64) -> SimDuration {
        self.model.cost(bytes)
    }

    fn used_bytes(&self) -> u64 {
        self.charged.values().sum()
    }

    fn capacity_bytes(&self) -> Option<u64> {
        self.capacity
    }

    fn meter(&self) -> IoMeter {
        self.meter
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "spoton-nfs-{tag}-{}-{}",
            std::process::id(),
            crate::util::next_seq()
        ));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn model() -> TransferModel {
        TransferModel {
            bandwidth_mib_s: 100.0,
            latency: SimDuration::from_millis(10),
        }
    }

    #[test]
    fn put_get_round_trip() {
        let mut s = NfsStore::open(&tmpdir("rt"), model(), None).unwrap();
        let cost = s.put("ckpt/1/payload.bin", b"hello").unwrap();
        assert!(cost >= SimDuration::from_millis(10));
        let (data, _) = s.get("ckpt/1/payload.bin").unwrap();
        assert_eq!(data, b"hello");
        assert!(s.exists("ckpt/1/payload.bin"));
        assert!(!s.exists("ckpt/2/payload.bin"));
    }

    #[test]
    fn charged_size_drives_cost_and_capacity() {
        let mut s =
            NfsStore::open(&tmpdir("charged"), model(), Some(1.0)).unwrap();
        // tiny real payload charged as 512 MiB
        let half_gib = 512 * 1024 * 1024;
        let cost = s.put_sized("a", b"x", half_gib).unwrap();
        assert!(cost.as_secs() >= 5, "512MiB at 100MiB/s ≈ 5.1s, got {cost}");
        assert_eq!(s.used_bytes(), half_gib);
        // second 512 MiB fits exactly; third must fail
        s.put_sized("b", b"y", half_gib).unwrap();
        let err = s.put_sized("c", b"z", 1).unwrap_err();
        assert!(err.to_string().contains("share full"), "{err}");
        // overwrite replaces the charge rather than double-counting
        s.put_sized("a", b"x2", half_gib).unwrap();
        assert_eq!(s.used_bytes(), 2 * half_gib);
    }

    #[test]
    fn list_sorted_by_prefix() {
        let mut s = NfsStore::open(&tmpdir("list"), model(), None).unwrap();
        s.put("ckpt/2/m", b"b").unwrap();
        s.put("ckpt/10/m", b"c").unwrap();
        s.put("ckpt/1/m", b"a").unwrap();
        s.put("other/x", b"d").unwrap();
        assert_eq!(
            s.list("ckpt/").unwrap(),
            vec!["ckpt/1/m", "ckpt/10/m", "ckpt/2/m"]
        );
        assert_eq!(s.list("").unwrap().len(), 4);
    }

    #[test]
    fn delete_is_idempotent() {
        let mut s = NfsStore::open(&tmpdir("del"), model(), None).unwrap();
        s.put("k", b"v").unwrap();
        assert!(s.delete("k").unwrap());
        assert!(!s.delete("k").unwrap());
        assert!(!s.exists("k"));
        assert_eq!(s.used_bytes(), 0);
    }

    #[test]
    fn rescan_survives_reopen() {
        let dir = tmpdir("reopen");
        {
            let mut s = NfsStore::open(&dir, model(), None).unwrap();
            s.put_sized("ckpt/5/payload", b"data", 12345).unwrap();
        }
        // a "new instance" mounts the same share
        let mut s2 = NfsStore::open(&dir, model(), None).unwrap();
        assert!(s2.exists("ckpt/5/payload"));
        assert_eq!(s2.used_bytes(), 12345);
        let (data, _) = s2.get("ckpt/5/payload").unwrap();
        assert_eq!(data, b"data");
    }

    #[test]
    fn meter_accumulates() {
        let mut s = NfsStore::open(&tmpdir("meter"), model(), None).unwrap();
        s.put_sized("a", b"aaaa", 100).unwrap();
        s.get("a").unwrap();
        s.delete("a").unwrap();
        let m = s.meter();
        assert_eq!(m.puts, 1);
        assert_eq!(m.gets, 1);
        assert_eq!(m.deletes, 1);
        assert_eq!(m.bytes_written, 4);
        assert_eq!(m.charged_written, 100);
        assert_eq!(m.charged_read, 100);
        assert!(m.transfer_time > SimDuration::ZERO);
    }

    #[test]
    fn get_missing_errors() {
        let mut s = NfsStore::open(&tmpdir("missing"), model(), None).unwrap();
        assert!(s.get("nope").is_err());
        assert!(s.put("../escape", b"x").is_err());
    }
}
