//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts and executes
//! them on the request path.
//!
//! `make artifacts` (the only Python invocation) leaves `artifacts/` with
//! one HLO-text module per compiled function plus `manifest.json`
//! describing shapes, dtypes and SHA-256 digests. This module is the
//! Rust side of that contract:
//!
//! * [`ArtifactManifest`] — parse + validate the manifest, verify file
//!   digests (a stale or hand-edited artifact fails closed).
//! * [`Runtime`] — `PjRtClient::cpu()` → `HloModuleProto::from_text_file`
//!   → `compile` → cached [`Executable`]s executed with concrete inputs.
//!
//! Interchange is HLO **text** (not serialized proto): jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md). Python lowers
//! with `return_tuple=True`, so results unwrap via `decompose_tuple()`.
//!
//! ## The `pjrt` feature
//!
//! The `xla` binding (and the xla_extension native library behind it) is
//! only present on machines provisioned for kernel work, so the PJRT
//! execution path is gated behind the `pjrt` cargo feature. Without it,
//! [`Runtime::load`] still parses and digest-verifies the artifact
//! manifest (so `spoton artifacts-info` and workload construction work),
//! but [`Executable::call_f32`] returns an error directing the caller to
//! rebuild with `--features pjrt`. Everything else in the crate — the
//! coordinator, checkpoint engine, simulator, scheduler, and the sleeper
//! calibration workload — is pure Rust and fully functional either way.
//!
//! Building *with* `--features pjrt` on an ordinary machine resolves the
//! `xla::` paths below to the in-repo `stub_xla.rs` shim (manifest
//! loading works, compilation errors out) so CI can keep the feature
//! gate compiling. Vendoring the real crate: add the `xla` dependency
//! and delete the `mod xla` declaration below — the call sites are
//! written against the real crate's API.

pub mod artifact;

#[cfg(feature = "pjrt")]
#[path = "stub_xla.rs"]
mod xla;

pub use artifact::{ArtifactManifest, ArtifactSig, Geometry, TensorSig};

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A compiled, callable artifact.
pub struct Executable {
    name: String,
    sig: ArtifactSig,
    #[cfg(feature = "pjrt")]
    exe: xla::PjRtLoadedExecutable,
}

/// Typed input for an execution.
pub enum Arg<'a> {
    I32(&'a [i32]),
    F32(&'a [f32]),
}

impl Executable {
    /// Shape/dtype-check `args` against the manifest signature.
    fn check_args(&self, args: &[Arg<'_>]) -> Result<()> {
        if args.len() != self.sig.inputs.len() {
            bail!(
                "{}: expected {} args, got {}",
                self.name,
                self.sig.inputs.len(),
                args.len()
            );
        }
        for (i, (arg, sig)) in args.iter().zip(&self.sig.inputs).enumerate() {
            let (len, ok) = match (arg, sig.dtype.as_str()) {
                (Arg::I32(v), "int32") => (v.len(), true),
                (Arg::F32(v), "float32") => (v.len(), true),
                _ => (0, false),
            };
            if !ok {
                bail!(
                    "{}: arg {i} dtype mismatch (manifest says {})",
                    self.name,
                    sig.dtype
                );
            }
            if len as u64 != sig.elements() {
                bail!(
                    "{}: arg {i} has {} elements, expected {}",
                    self.name,
                    len,
                    sig.elements()
                );
            }
        }
        Ok(())
    }

    /// Execute with shape/dtype-checked args; returns the flattened f32
    /// outputs (all artifacts in this project return f32 tensors).
    #[cfg(feature = "pjrt")]
    pub fn call_f32(&self, args: &[Arg<'_>]) -> Result<Vec<Vec<f32>>> {
        self.check_args(args)?;
        let mut literals = Vec::with_capacity(args.len());
        for (arg, sig) in args.iter().zip(&self.sig.inputs) {
            let dims: Vec<i64> = sig.shape.iter().map(|&d| d as i64).collect();
            let lit = match arg {
                Arg::I32(v) => xla::Literal::vec1(v).reshape(&dims)?,
                Arg::F32(v) => xla::Literal::vec1(v).reshape(&dims)?,
            };
            literals.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let mut tuple = result[0][0].to_literal_sync()?;
        // return_tuple=True: outputs arrive as a tuple literal.
        let parts = tuple.decompose_tuple()?;
        if parts.len() != self.sig.outputs.len() {
            bail!(
                "{}: got {} outputs, manifest says {}",
                self.name,
                parts.len(),
                self.sig.outputs.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (part, osig) in parts.iter().zip(&self.sig.outputs) {
            let v = part.to_vec::<f32>()?;
            if v.len() as u64 != osig.elements() {
                bail!(
                    "{}: output has {} elements, expected {}",
                    self.name,
                    v.len(),
                    osig.elements()
                );
            }
            out.push(v);
        }
        Ok(out)
    }

    /// Without the `pjrt` feature no execution backend exists; argument
    /// validation still runs so shape bugs surface identically.
    #[cfg(not(feature = "pjrt"))]
    pub fn call_f32(&self, args: &[Arg<'_>]) -> Result<Vec<Vec<f32>>> {
        self.check_args(args)?;
        bail!(
            "{}: spoton was built without the `pjrt` feature; rebuild with \
             `--features pjrt` (requires the vendored xla crate) to execute \
             compiled artifacts",
            self.name
        );
    }

    pub fn sig(&self) -> &ArtifactSig {
        &self.sig
    }
}

/// The PJRT client + compiled-executable cache.
pub struct Runtime {
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: ArtifactManifest,
    cache: HashMap<String, Executable>,
}

impl Runtime {
    /// Load `artifacts/` (manifest + digest verification; compilation is
    /// lazy per artifact).
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = ArtifactManifest::load(dir)?;
        manifest
            .verify_digests(dir)
            .context("artifact digest verification")?;
        Ok(Self {
            #[cfg(feature = "pjrt")]
            client: xla::PjRtClient::cpu()
                .context("creating PJRT CPU client")?,
            dir: dir.to_path_buf(),
            manifest,
            cache: HashMap::new(),
        })
    }

    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    pub fn geometry(&self) -> &Geometry {
        &self.manifest.geometry
    }

    /// Compile (or fetch from cache) an artifact by name.
    pub fn executable(&mut self, name: &str) -> Result<&Executable> {
        if !self.cache.contains_key(name) {
            let sig = self
                .manifest
                .artifacts
                .get(name)
                .with_context(|| format!("unknown artifact '{name}'"))?
                .clone();
            let exe = self.build_executable(name, sig)?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    #[cfg(feature = "pjrt")]
    fn build_executable(
        &mut self,
        name: &str,
        sig: ArtifactSig,
    ) -> Result<Executable> {
        let path = self.dir.join(&sig.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        Ok(Executable { name: name.to_string(), sig, exe })
    }

    /// Feature-off build: hand back a stub whose `call_f32` explains how
    /// to enable execution. The artifact file must still exist, so missing
    /// or renamed artifacts fail here exactly as the real path would.
    #[cfg(not(feature = "pjrt"))]
    fn build_executable(
        &mut self,
        name: &str,
        sig: ArtifactSig,
    ) -> Result<Executable> {
        let path = self.dir.join(&sig.file);
        if !path.exists() {
            bail!("artifact file missing: {}", path.display());
        }
        Ok(Executable { name: name.to_string(), sig })
    }

    /// Compile every artifact up front (warm start for latency benches).
    pub fn warm_all(&mut self) -> Result<()> {
        let names: Vec<String> =
            self.manifest.artifacts.keys().cloned().collect();
        for n in names {
            self.executable(&n)?;
        }
        Ok(())
    }

    #[cfg(feature = "pjrt")]
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    #[cfg(not(feature = "pjrt"))]
    pub fn platform(&self) -> String {
        "unavailable (built without the `pjrt` feature)".to_string()
    }
}

/// Default artifacts directory: `$SPOTON_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("SPOTON_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;

    pub(crate) fn artifacts_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn load_and_execute_denoise() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let mut rt = Runtime::load(&dir).unwrap();
        let b = rt.geometry().num_buckets as usize;
        let taps = 2 * rt.geometry().denoise_half_width as usize + 1;
        let exe = rt.executable("denoise").unwrap();
        let counts: Vec<f32> = (0..b).map(|i| (i % 17) as f32).collect();
        // identity stencil: output == input where above threshold 0
        let mut stencil = vec![0f32; taps];
        stencil[taps / 2] = 1.0;
        let params = vec![0.0f32, 0.5];
        let out = exe
            .call_f32(&[Arg::F32(&counts), Arg::F32(&stencil), Arg::F32(&params)])
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].len(), b);
        assert_eq!(out[0], counts);
    }

    #[test]
    fn execute_stats() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let mut rt = Runtime::load(&dir).unwrap();
        let b = rt.geometry().num_buckets as usize;
        let exe = rt.executable("spectrum_stats").unwrap();
        let mut counts = vec![0f32; b];
        counts[3] = 5.0;
        counts[100] = 2.0;
        let out = exe.call_f32(&[Arg::F32(&counts)]).unwrap();
        assert_eq!(out[0], vec![7.0, 2.0, 5.0]); // mass, occupied, max
    }

    #[test]
    fn shape_and_dtype_mismatches_rejected() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let mut rt = Runtime::load(&dir).unwrap();
        let b = rt.geometry().num_buckets as usize;
        let exe = rt.executable("spectrum_stats").unwrap();
        let wrong = vec![0f32; 3];
        assert!(exe.call_f32(&[Arg::F32(&wrong)]).is_err());
        assert!(exe.call_f32(&[]).is_err());
        let ints = vec![0i32; b];
        assert!(exe.call_f32(&[Arg::I32(&ints)]).is_err(), "dtype mismatch");
    }

    #[test]
    fn unknown_artifact_errors() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let mut rt = Runtime::load(&dir).unwrap();
        assert!(rt.executable("nonexistent").is_err());
    }
}
