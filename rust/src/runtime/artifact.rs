//! Artifact manifest: the build-time contract between `python/compile/`
//! and the Rust runtime.

use crate::json::{self, Value};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Supported manifest schema version (must match `aot.MANIFEST_VERSION`).
pub const MANIFEST_VERSION: u64 = 1;

/// Shape + dtype of one tensor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSig {
    pub shape: Vec<u64>,
    pub dtype: String,
}

impl TensorSig {
    pub fn elements(&self) -> u64 {
        self.shape.iter().product()
    }

    fn from_json(v: &Value) -> Result<Self> {
        let shape = v
            .req_array("shape")?
            .iter()
            .map(|d| d.as_u64().context("shape dims must be u64"))
            .collect::<Result<Vec<_>>>()?;
        Ok(Self { shape, dtype: v.req_str("dtype")?.to_string() })
    }
}

/// One compiled artifact's signature.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactSig {
    pub file: String,
    pub sha256: String,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

/// Kernel geometry shared by all artifacts in one build (must agree with
/// the workload config at runtime).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Geometry {
    pub num_buckets: u64,
    pub read_len: u64,
    pub reads_per_call: u64,
    pub read_tile: u64,
    pub bucket_tile: u64,
    pub denoise_half_width: u64,
    pub ks: Vec<u32>,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    pub geometry: Geometry,
    pub artifacts: BTreeMap<String, ArtifactSig>,
}

impl ArtifactManifest {
    pub fn parse(text: &str) -> Result<Self> {
        let v = json::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let version = v.req_u64("version")?;
        if version != MANIFEST_VERSION {
            bail!("unsupported artifact manifest version {version}");
        }
        let g = v
            .get("geometry")
            .context("missing geometry")?;
        let geometry = Geometry {
            num_buckets: g.req_u64("num_buckets")?,
            read_len: g.req_u64("read_len")?,
            reads_per_call: g.req_u64("reads_per_call")?,
            read_tile: g.req_u64("read_tile")?,
            bucket_tile: g.req_u64("bucket_tile")?,
            denoise_half_width: g.req_u64("denoise_half_width")?,
            ks: g
                .req_array("ks")?
                .iter()
                .map(|k| {
                    k.as_u64()
                        .and_then(|x| u32::try_from(x).ok())
                        .context("ks must be u32")
                })
                .collect::<Result<Vec<_>>>()?,
        };
        let mut artifacts = BTreeMap::new();
        let arts = v
            .get("artifacts")
            .and_then(Value::as_object)
            .context("missing artifacts object")?;
        for (name, a) in arts {
            let inputs = a
                .req_array("inputs")?
                .iter()
                .map(TensorSig::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = a
                .req_array("outputs")?
                .iter()
                .map(TensorSig::from_json)
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactSig {
                    file: a.req_str("file")?.to_string(),
                    sha256: a.req_str("sha256")?.to_string(),
                    inputs,
                    outputs,
                },
            );
        }
        if artifacts.is_empty() {
            bail!("manifest lists no artifacts");
        }
        Ok(Self { geometry, artifacts })
    }

    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    /// Verify every artifact file's SHA-256 against the manifest.
    pub fn verify_digests(&self, dir: &Path) -> Result<()> {
        for (name, sig) in &self.artifacts {
            let path = dir.join(&sig.file);
            let data = std::fs::read(&path)
                .with_context(|| format!("reading artifact {name}"))?;
            let digest = crate::util::sha256_hex(&data);
            if digest != sig.sha256 {
                bail!(
                    "artifact '{name}' digest mismatch: {} on disk vs {} in \
                     manifest — rerun `make artifacts`",
                    &digest[..12],
                    &sig.sha256[..12]
                );
            }
        }
        Ok(())
    }

    /// The count artifact name for a k value.
    pub fn count_artifact(k: u32) -> String {
        format!("count_k{k}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "geometry": {
        "num_buckets": 8192, "read_len": 160, "reads_per_call": 1024,
        "read_tile": 8, "bucket_tile": 2048, "denoise_half_width": 2,
        "ks": [33, 55]
      },
      "artifacts": {
        "count_k33": {
          "file": "count_k33.hlo.txt",
          "sha256": "abc",
          "inputs": [
            {"shape": [1024, 160], "dtype": "int32"},
            {"shape": [8192], "dtype": "float32"}
          ],
          "outputs": [{"shape": [8192], "dtype": "float32"}]
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = ArtifactManifest::parse(SAMPLE).unwrap();
        assert_eq!(m.geometry.num_buckets, 8192);
        assert_eq!(m.geometry.ks, vec![33, 55]);
        let a = &m.artifacts["count_k33"];
        assert_eq!(a.inputs[0].elements(), 1024 * 160);
        assert_eq!(a.outputs[0].dtype, "float32");
    }

    #[test]
    fn rejects_bad_versions_and_shapes() {
        assert!(ArtifactManifest::parse("{}").is_err());
        let v2 = SAMPLE.replace("\"version\": 1", "\"version\": 9");
        assert!(ArtifactManifest::parse(&v2).is_err());
        let noart = SAMPLE.replace("count_k33", "").replace(
            r#""": {"#,
            r#""x": {"#,
        );
        // even if that edit mangles, an empty artifacts map must fail:
        let empty = r#"{"version":1,"geometry":{"num_buckets":1,"read_len":1,
          "reads_per_call":1,"read_tile":1,"bucket_tile":1,
          "denoise_half_width":0,"ks":[]},"artifacts":{}}"#;
        assert!(ArtifactManifest::parse(empty).is_err());
        let _ = noart;
    }

    #[test]
    fn digest_verification_detects_drift() {
        let dir = std::env::temp_dir().join(format!(
            "spoton-manifest-{}-{}",
            std::process::id(),
            crate::util::next_seq()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let hlo = "HloModule fake";
        std::fs::write(dir.join("count_k33.hlo.txt"), hlo).unwrap();
        let good = SAMPLE.replace(
            "\"sha256\": \"abc\"",
            &format!("\"sha256\": \"{}\"", crate::util::sha256_hex(hlo.as_bytes())),
        );
        let m = ArtifactManifest::parse(&good).unwrap();
        m.verify_digests(&dir).unwrap();
        // drift the file
        std::fs::write(dir.join("count_k33.hlo.txt"), "HloModule changed")
            .unwrap();
        let err = m.verify_digests(&dir).unwrap_err();
        assert!(err.to_string().contains("digest mismatch"));
    }

    #[test]
    fn count_artifact_names() {
        assert_eq!(ArtifactManifest::count_artifact(127), "count_k127");
    }
}
