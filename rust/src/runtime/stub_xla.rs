//! Stub of the vendored `xla` crate's API surface, compiled when the
//! `pjrt` feature is on but the real crate is not vendored.
//!
//! Purpose: keep every `#[cfg(feature = "pjrt")]` call site in
//! [`super`] type-checked on ordinary machines (CI builds
//! `--features pjrt` against this stub so the feature gate cannot rot).
//! The stub loads manifests fine but refuses to compile/execute HLO —
//! each entry point returns a clear "vendored xla not present" error.
//!
//! On a kernel-provisioned machine with the vendored crate available,
//! add `xla = { path = "../vendor/xla-rs" }` to `Cargo.toml` and delete
//! the `mod xla` declaration in `runtime/mod.rs`; the call sites then
//! resolve to the real crate unchanged.

use anyhow::{bail, Result};
use std::path::Path;

const STUB: &str = "pjrt stub runtime: the vendored `xla` crate is not \
                    present in this build; see rust/src/runtime/stub_xla.rs";

/// Stand-in for `xla::Literal`.
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        bail!(STUB)
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        bail!(STUB)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        bail!(STUB)
    }
}

/// Stand-in for `xla::PjRtBuffer` (what `execute` hands back).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        bail!(STUB)
    }
}

/// Stand-in for `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        bail!(STUB)
    }
}

/// Stand-in for `xla::PjRtClient`. Construction succeeds (so
/// `Runtime::load` still verifies manifests and digests); compilation is
/// where the stub refuses.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        bail!(STUB)
    }

    pub fn platform_name(&self) -> String {
        "stub (vendored xla not present)".to_string()
    }
}

/// Stand-in for `xla::HloModuleProto`.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        bail!(STUB)
    }
}

/// Stand-in for `xla::XlaComputation`.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}
