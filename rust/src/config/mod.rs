//! Configuration: a from-scratch TOML-subset parser plus the typed
//! scenario schema the CLI and experiment drivers consume.
//!
//! The coordinator is configured through files (paper §II: "the
//! coordinator is able to invoke the corresponding interfaces through its
//! configuration files"); `scenario.rs` defines that schema and maps it
//! onto the simulator and the real-time coordinator alike.

pub mod toml;
pub mod scenario;

pub use scenario::{
    ArrivalCfg, AutoscaleCfg, BackoffCfg, BidPolicyCfg, ChaosCfg,
    ChaosImdsCfg, ChaosMarketCfg, ChaosStorageCfg, CheckpointMethodCfg,
    ClampCfg, CloudCfg, ClusterCfg, EvictionPlanCfg, ExpectCfg, FleetCfg,
    IntervalControllerCfg, PlacementPolicyCfg, PoolCfg, PoolPricingCfg,
    ScenarioConfig, StorageCfg, WorkloadCfg,
};
pub use toml::{TomlDoc, TomlValue};
