//! Typed scenario schema: everything one experiment run needs.
//!
//! A scenario file (TOML subset) fully determines a run: the workload and
//! its calibration, the eviction plan, the checkpoint policy, cloud
//! pricing/latency parameters and the shared-storage model. Defaults
//! reproduce the paper's testbed: Standard_D8s_v3 ($0.38 on-demand /
//! $0.076 spot per hour), Azure Files NFS at $16 per 100 GiB-month, 30 s
//! minimum eviction notice, and Table I row-1 baseline stage durations.

use crate::cloud::trace::{PoolTrace, PriceTrace, PriceWalkCfg};
use crate::config::toml::{TomlDoc, TomlValue};
use crate::metrics::RecordLevel;
use crate::simclock::SimDuration;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Which checkpoint mechanism protects the workload (paper §III-A).
#[derive(Debug, Clone, PartialEq)]
pub enum CheckpointMethodCfg {
    /// No protection (Table I rows 1–2).
    None,
    /// Application-native: checkpoints only at the workload's own
    /// milestones (metaSPAdes-style); cannot be taken on demand.
    AppNative,
    /// Transparent (CRIU-analog): periodic full-state snapshots at the
    /// given interval, plus opportunistic termination checkpoints.
    Transparent { interval: SimDuration },
}

impl CheckpointMethodCfg {
    pub fn label(&self) -> String {
        match self {
            CheckpointMethodCfg::None => "none".into(),
            CheckpointMethodCfg::AppNative => "application".into(),
            CheckpointMethodCfg::Transparent { interval } => {
                format!("transparent/{}m", interval.as_secs() / 60)
            }
        }
    }
}

/// When the spot instance gets evicted (paper §III-B: evictions are
/// injected, mirroring `az vmss simulate-eviction`).
#[derive(Debug, Clone, PartialEq)]
pub enum EvictionPlanCfg {
    /// Never evicted (on-demand semantics, or lucky spot).
    None,
    /// Evict every `interval` of *instance uptime* (the paper's
    /// "Eviction every 60/90 min").
    Fixed { interval: SimDuration },
    /// Poisson process with the given mean inter-arrival time.
    Poisson { mean: SimDuration },
    /// Explicit eviction instants measured from each instance's start —
    /// replays an empirical spot-market trace.
    Trace { offsets: Vec<SimDuration> },
}

impl EvictionPlanCfg {
    pub fn label(&self) -> String {
        match self {
            EvictionPlanCfg::None => "N/A".into(),
            EvictionPlanCfg::Fixed { interval } => {
                format!("every {} min", interval.as_secs() / 60)
            }
            EvictionPlanCfg::Poisson { mean } => {
                format!("poisson mean {} min", mean.as_secs() / 60)
            }
            EvictionPlanCfg::Trace { offsets } => {
                format!("trace ({} events)", offsets.len())
            }
        }
    }
}

/// How a pool's price moves over the experiment
/// ([`crate::cloud::trace`]).
#[derive(Debug, Clone, PartialEq, Default)]
pub enum PoolPricingCfg {
    /// Flat price for the whole run (the paper's 80%-off spot market).
    #[default]
    Static,
    /// Replay an explicit price trace: each point's factor multiplies
    /// the pool's static level (catalog × `price_factor`) from its
    /// offset on. TOML: `price_trace = "traces/east-spike.trace"`.
    Trace(PriceTrace),
    /// Generate a seeded random-walk trace at fleet construction
    /// (decorrelated per pool — Monte Carlo sweeps replay a different
    /// market per seed). TOML: a `[pool.NAME.price_walk]` section.
    Walk(PriceWalkCfg),
}

/// One pool of a [`FleetCfg`]: a region / VM-size combination with its
/// own price level, eviction behaviour and provisioning delay.
#[derive(Debug, Clone, PartialEq)]
pub struct PoolCfg {
    /// Pool name (billing attribution tag; must be unique in the fleet).
    pub name: String,
    /// VM size looked up in the pool's price book.
    pub vm_size: String,
    /// Spot pricing/eviction semantics, or on-demand.
    pub spot: bool,
    /// Replacement provisioning delay for instances placed in this pool.
    pub provisioning_delay: SimDuration,
    /// Multiplier applied to the default price catalog (a cheap region is
    /// < 1, an expensive one > 1). Must be positive and finite.
    pub price_factor: f64,
    /// Eviction behaviour of instances placed in this pool.
    pub eviction: EvictionPlanCfg,
    /// Price dynamics on top of `price_factor` (static by default).
    pub pricing: PoolPricingCfg,
    /// Maximum concurrently-running instances in this pool (the scale
    /// set's capacity). The paper's single-job testbed is capacity 1; a
    /// contended cluster ([`ClusterCfg`]) raises it so several jobs share
    /// the pool and the rest queue. Must be >= 1.
    pub capacity: u32,
    /// Static bid price ($/h) every instance launched in this pool
    /// carries: when a traced price epoch pushes the pool's effective
    /// price *above* the bid, the market reclaims the instance (an
    /// eviction notice fires from the crossing and billing stops at the
    /// crossing boundary). Requires a spot pool with traced or walked
    /// pricing — a static-priced pool can never cross a bid, so a bid
    /// there is rejected as inert. `None` (the default) never evicts by
    /// outbid.
    pub bid: Option<f64>,
}

impl Default for PoolCfg {
    fn default() -> Self {
        Self {
            name: "pool-0".into(),
            vm_size: "Standard_D8s_v3".into(),
            spot: true,
            provisioning_delay: SimDuration::from_secs(90),
            price_factor: 1.0,
            eviction: EvictionPlanCfg::None,
            pricing: PoolPricingCfg::Static,
            capacity: 1,
            bid: None,
        }
    }
}

impl PoolCfg {
    /// A default pool with the given name.
    pub fn named(name: &str) -> Self {
        Self { name: name.into(), ..Self::default() }
    }

    /// The single pool the paper's testbed corresponds to: the `[cloud]`
    /// section's scale set plus the scenario-level eviction plan.
    pub fn from_cloud(cloud: &CloudCfg, eviction: EvictionPlanCfg) -> Self {
        Self {
            name: "pool-0".into(),
            vm_size: cloud.vm_size.clone(),
            spot: cloud.spot,
            provisioning_delay: cloud.provisioning_delay,
            price_factor: 1.0,
            eviction,
            pricing: PoolPricingCfg::Static,
            capacity: 1,
            bid: None,
        }
    }

    pub fn vm_size(mut self, size: &str) -> Self {
        self.vm_size = size.to_string();
        self
    }

    pub fn spot(mut self, spot: bool) -> Self {
        self.spot = spot;
        self
    }

    pub fn provisioning_delay(mut self, delay: SimDuration) -> Self {
        self.provisioning_delay = delay;
        self
    }

    pub fn price_factor(mut self, factor: f64) -> Self {
        self.price_factor = factor;
        self
    }

    pub fn eviction(mut self, plan: EvictionPlanCfg) -> Self {
        self.eviction = plan;
        self
    }

    pub fn pricing(mut self, pricing: PoolPricingCfg) -> Self {
        self.pricing = pricing;
        self
    }

    pub fn capacity(mut self, capacity: u32) -> Self {
        self.capacity = capacity;
        self
    }

    pub fn bid(mut self, bid: f64) -> Self {
        self.bid = Some(bid);
        self
    }
}

/// Clamp around an adaptive interval controller's raw output
/// ([`crate::policy::Clamp`]): hard min/max bounds plus a hysteresis
/// dead-band so a noisy online estimate cannot thrash the checkpoint
/// cadence. All knobs are validated — at TOML parse and again at
/// controller construction — so a zero, non-finite or inverted
/// (`min > max`) clamp never reaches a run.
#[derive(Debug, Clone, PartialEq)]
pub struct ClampCfg {
    /// Shortest interval the controller may emit. Must be non-zero.
    pub min: SimDuration,
    /// Longest interval the controller may emit. Must be >= `min`.
    pub max: SimDuration,
    /// Dead-band fraction in `[0, 1)`: a newly computed interval within
    /// this relative distance of the last emitted one keeps the old
    /// value (0 disables hysteresis).
    pub hysteresis: f64,
}

impl Default for ClampCfg {
    fn default() -> Self {
        Self {
            min: SimDuration::from_mins(2),
            max: SimDuration::from_mins(120),
            hysteresis: 0.0,
        }
    }
}

/// Which interval controller tunes the periodic (transparent) checkpoint
/// cadence ([`crate::policy`]). TOML: the `[checkpoint.adaptive]`
/// section.
#[derive(Debug, Clone, PartialEq, Default)]
pub enum IntervalControllerCfg {
    /// Always the configured `[checkpoint] interval_mins` — byte-for-byte
    /// the pre-policy engine (pinned against the legacy oracle).
    #[default]
    Fixed,
    /// Young/Daly optimum from an online per-pool eviction-rate estimate
    /// seeded with `prior_mtbf`. `higher_order = false` (the default) is
    /// the first-order form `√(2 · ckpt_cost · MTBF)`; `true` applies
    /// Daly's higher-order correction, which matters when the checkpoint
    /// cost is no longer small against the MTBF and reduces to the
    /// first-order form as `ckpt_cost / MTBF → 0`.
    YoungDaly {
        prior_mtbf: SimDuration,
        clamp: ClampCfg,
        higher_order: bool,
    },
    /// Young/Daly scaled by the active pool's current traced price
    /// factor raised to `sensitivity`: checkpoints cluster when the pool
    /// is cheap, spread out across a price spike.
    CostAware {
        sensitivity: f64,
        prior_mtbf: SimDuration,
        clamp: ClampCfg,
    },
}

impl IntervalControllerCfg {
    /// Young/Daly with the default prior and clamp (first-order form).
    pub fn young_daly() -> Self {
        Self::YoungDaly {
            prior_mtbf: SimDuration::from_mins(60),
            clamp: ClampCfg::default(),
            higher_order: false,
        }
    }

    /// Cost-aware Young/Daly with the default prior and clamp.
    pub fn cost_aware(sensitivity: f64) -> Self {
        Self::CostAware {
            sensitivity,
            prior_mtbf: SimDuration::from_mins(60),
            clamp: ClampCfg::default(),
        }
    }

    pub fn label(&self) -> String {
        match self {
            IntervalControllerCfg::Fixed => "fixed".into(),
            IntervalControllerCfg::YoungDaly { .. } => "young-daly".into(),
            IntervalControllerCfg::CostAware { sensitivity, .. } => {
                format!("cost-aware/{sensitivity}")
            }
        }
    }
}

/// Which placement policy picks the pool for each replacement
/// ([`crate::cloud::fleet`]).
#[derive(Debug, Clone, PartialEq, Default)]
pub enum PlacementPolicyCfg {
    /// Always replace in the pool the evicted instance came from —
    /// byte-for-byte the single-scale-set world on a 1-pool fleet.
    #[default]
    Sticky,
    /// Always pick the pool with the lowest hourly price.
    CheapestSpot,
    /// Pick the pool minimizing `price × (1 + penalty × eviction_rate)`,
    /// where the eviction rate is the pool's observed evictions per
    /// launch — heterogeneous-spot placement à la Qu et al.
    EvictionAware { penalty: f64 },
}

impl PlacementPolicyCfg {
    pub fn label(&self) -> String {
        match self {
            PlacementPolicyCfg::Sticky => "sticky".into(),
            PlacementPolicyCfg::CheapestSpot => "cheapest-spot".into(),
            PlacementPolicyCfg::EvictionAware { penalty } => {
                format!("eviction-aware/{penalty}")
            }
        }
    }
}

/// The fleet: which pools replacements may be placed in, and the policy
/// that picks among them.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FleetCfg {
    /// Explicit pools. Empty (the default) means "one pool derived from
    /// `[cloud]` + `[eviction]`" — the paper's single capacity-1 scale
    /// set.
    pub pools: Vec<PoolCfg>,
    pub placement: PlacementPolicyCfg,
}

/// When the cluster's jobs are submitted.
#[derive(Debug, Clone, PartialEq, Default)]
pub enum ArrivalCfg {
    /// Every job submitted at t = 0 (a batch drop — maximum contention).
    #[default]
    Batch,
    /// Job `i` arrives at `i × spacing`. `spacing` must be positive.
    Uniform { spacing: SimDuration },
    /// Poisson arrivals with the given mean inter-arrival time, drawn
    /// deterministically from the scenario seed. `mean` must be positive.
    Poisson { mean: SimDuration },
}

/// A contended multi-job cluster: many copies of the scenario's workload
/// submitted against **one** shared fleet with finite per-pool capacity
/// ([`crate::sim::cluster`]). Jobs that find every slot taken queue FIFO
/// per priority and admit as slots free up.
///
/// TOML reference — the `[cluster]` section:
///
/// ```toml
/// [cluster]
/// # job population: a count (names auto-generated "job-0", "job-1", …)
/// jobs = 200
/// # …or an explicit (unique) name list — give one or the other:
/// # names = ["align", "assemble", "polish"]
///
/// # arrival process: "batch" (default, all at t = 0), "uniform"
/// # (one job every arrival_spacing_mins), or "poisson"
/// # (seeded, mean arrival_mean_mins)
/// arrival = "uniform"
/// arrival_spacing_mins = 5
///
/// # capacity of the implicit [cloud]-derived pool. With explicit
/// # [pool.*] sections, set `capacity` per pool instead.
/// capacity = 8
///
/// # optional per-job admission priorities (lower value admits first;
/// # FIFO within a priority). Omitted = all equal.
/// # priorities = [0, 0, 1]
/// ```
///
/// Zero/negative capacities or counts, non-finite arrival parameters and
/// duplicate job names are rejected at parse time *and* re-checked by
/// [`ClusterCfg::validate`] at build time, each error naming the
/// offending key.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ClusterCfg {
    /// Job names, one concurrent job each. Must be non-empty and unique.
    pub jobs: Vec<String>,
    /// Submission process for the job population.
    pub arrival: ArrivalCfg,
    /// Admission priority per job (lower admits first; FIFO within a
    /// priority). Empty means all jobs share priority 0; otherwise the
    /// length must match `jobs`.
    pub priorities: Vec<u32>,
    /// Capacity for the implicit single pool derived from `[cloud]` +
    /// `[eviction]`. Ignored when explicit fleet pools are configured —
    /// those carry their own per-pool `capacity`.
    pub capacity: Option<u32>,
}

impl ClusterCfg {
    /// `n` identically-configured jobs named `job-0 … job-{n-1}`.
    pub fn with_count(n: usize) -> Self {
        Self {
            jobs: (0..n).map(|i| format!("job-{i}")).collect(),
            ..Self::default()
        }
    }

    pub fn arrival(mut self, arrival: ArrivalCfg) -> Self {
        self.arrival = arrival;
        self
    }

    pub fn capacity(mut self, capacity: u32) -> Self {
        self.capacity = Some(capacity);
        self
    }

    pub fn priorities(mut self, priorities: Vec<u32>) -> Self {
        self.priorities = priorities;
        self
    }

    /// Admission priority of job `i` (0 when no priorities were given).
    pub fn priority(&self, job: usize) -> u32 {
        self.priorities.get(job).copied().unwrap_or(0)
    }

    /// Build-side validation, mirroring the `[cluster]` parse rules for
    /// configs assembled through the builder API.
    pub fn validate(&self) -> Result<()> {
        if self.jobs.is_empty() {
            bail!("cluster.jobs must name at least one job");
        }
        let mut seen = std::collections::BTreeSet::new();
        for name in &self.jobs {
            if name.is_empty() {
                bail!("cluster job names must be non-empty");
            }
            if !seen.insert(name.as_str()) {
                bail!("duplicate cluster job name '{name}'");
            }
        }
        if !self.priorities.is_empty()
            && self.priorities.len() != self.jobs.len()
        {
            bail!(
                "cluster.priorities has {} entries for {} jobs",
                self.priorities.len(),
                self.jobs.len()
            );
        }
        match &self.arrival {
            ArrivalCfg::Uniform { spacing } if spacing.is_zero() => {
                bail!("cluster.arrival_spacing_mins must be positive")
            }
            ArrivalCfg::Poisson { mean } if mean.is_zero() => {
                bail!("cluster.arrival_mean_mins must be positive")
            }
            _ => {}
        }
        if self.capacity == Some(0) {
            bail!("cluster.capacity must be >= 1, got 0");
        }
        Ok(())
    }
}

/// Which bid-pricing strategy the autoscaler uses when it places a job
/// on a spot pool ([`crate::autoscale`]). Every strategy is a pure
/// function of the pool's observable state (current price, factor
/// history, eviction rate) — no RNG — so autoscaled sweeps stay
/// byte-identical at any parallelism.
#[derive(Debug, Clone, PartialEq)]
pub enum BidPolicyCfg {
    /// Bid the pool's current effective price times `1 + margin`
    /// (`margin >= 0`, finite).
    FixedMargin { margin: f64 },
    /// Bid the pool's base price times the `q`-quantile (nearest-rank,
    /// `q` in (0, 1]) of the pool's full traced factor stream —
    /// application-centric bidding à la Khatua et al.: the quantile
    /// bounds the fraction of trace time spent above the bid.
    Percentile { q: f64 },
    /// Fixed margin inflated by the pool's observed eviction rate:
    /// `current × (1 + margin × (1 + weight × eviction_rate))` —
    /// reliability-aware bidding à la Voorsluys & Buyya. Both knobs must
    /// be finite and >= 0.
    Reliability { margin: f64, weight: f64 },
}

impl BidPolicyCfg {
    pub fn label(&self) -> String {
        match self {
            BidPolicyCfg::FixedMargin { margin } => {
                format!("fixed-margin/{margin}")
            }
            BidPolicyCfg::Percentile { q } => format!("percentile/{q}"),
            BidPolicyCfg::Reliability { margin, weight } => {
                format!("reliability/{margin}/{weight}")
            }
        }
    }

    /// Build-side validation, mirroring the `[autoscale]` parse rules.
    pub fn validate(&self) -> Result<()> {
        match self {
            BidPolicyCfg::FixedMargin { margin } => {
                if !(margin.is_finite() && *margin >= 0.0) {
                    bail!(
                        "autoscale.margin must be finite and non-negative, \
                         got {margin}"
                    );
                }
            }
            BidPolicyCfg::Percentile { q } => {
                if !(q.is_finite() && *q > 0.0 && *q <= 1.0) {
                    bail!("autoscale.percentile must be in (0, 1], got {q}");
                }
            }
            BidPolicyCfg::Reliability { margin, weight } => {
                if !(margin.is_finite() && *margin >= 0.0) {
                    bail!(
                        "autoscale.margin must be finite and non-negative, \
                         got {margin}"
                    );
                }
                if !(weight.is_finite() && *weight >= 0.0) {
                    bail!(
                        "autoscale.reliability_weight must be finite and \
                         non-negative, got {weight}"
                    );
                }
            }
        }
        Ok(())
    }
}

/// The hybrid spot/on-demand autoscaler ([`crate::autoscale`]): wraps
/// the cluster's placement policy, bidding on spot pools via a
/// [`BidPolicyCfg`] strategy and shifting jobs to the named on-demand
/// fallback pool when the deadline SLA is at risk. TOML: the
/// `[autoscale]` section (full reference in the `crate::autoscale`
/// module docs):
///
/// ```toml
/// [job]
/// deadline_mins = 400            # per-job SLA (required by [autoscale])
///
/// [autoscale]
/// policy = "percentile"          # "fixed-margin" | "percentile"
///                                # | "reliability"
/// percentile = 0.9               # policy knob (see BidPolicyCfg)
/// on_demand_pool = "fallback"    # must name a kind = "on-demand" pool
/// slack_mins = 60                # shift to on-demand when less than
///                                # this much headroom remains before
///                                # the deadline
/// max_queue = 4                  # shift to on-demand when the
///                                # admission queue is this deep
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct AutoscaleCfg {
    /// Bid strategy for spot placements.
    pub policy: BidPolicyCfg,
    /// Name of the on-demand fallback pool (must exist in the fleet,
    /// be `kind = "on-demand"`, and carry no eviction plan or price
    /// dynamics).
    pub on_demand_pool: String,
    /// Shift a job to on-demand when its remaining time-to-deadline
    /// drops below this slack. Must be positive.
    pub slack: SimDuration,
    /// Shift newly placed jobs to on-demand while the admission queue
    /// holds at least this many waiting jobs. Must be >= 1.
    pub max_queue: u32,
}

impl AutoscaleCfg {
    /// Build-side validation, mirroring the `[autoscale]` parse rules.
    /// Fleet/cluster cross-checks (the fallback pool exists and is
    /// on-demand) live in the cluster engine, which sees the whole
    /// scenario.
    pub fn validate(&self) -> Result<()> {
        self.policy.validate()?;
        if self.on_demand_pool.is_empty() {
            bail!("autoscale.on_demand_pool must name a pool");
        }
        if self.slack.is_zero() {
            bail!("autoscale.slack_mins must be positive");
        }
        if self.max_queue == 0 {
            bail!("autoscale.max_queue must be >= 1, got 0");
        }
        Ok(())
    }
}

/// Workload selection + calibration.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadCfg {
    /// "minimeta" (PJRT-backed assembler) or "sleeper" (pure-Rust
    /// calibration workload used by unit tests and fast benches).
    pub kind: String,
    /// k values, one pipeline stage each (paper: 33,55,77,99,127).
    pub ks: Vec<u32>,
    /// Uninterrupted virtual duration of each stage, seconds (paper Table
    /// I row 1: 33:50, 38:53, 39:51, 40:19, 30:33).
    pub stage_secs: Vec<u64>,
    /// Read count for the MiniMeta workload (drives real compute volume).
    pub total_reads: u64,
    /// Denoise sweeps per stage (real compute volume of the stage tail).
    pub denoise_sweeps: u32,
    /// App-native checkpoint milestones per stage (metaSPAdes writes
    /// several internal checkpoints per k; restart loses progress since
    /// the last milestone).
    pub app_milestones_per_stage: u32,
    /// Modeled (virtual) size of a transparent checkpoint image — the
    /// CRIU memory-image analog. Real serialized bytes are small at this
    /// scale; transfer time and NFS billing use this value (DESIGN.md §6).
    pub state_gib: f64,
    /// Modeled size of an app-native checkpoint (on-disk intermediate
    /// files are typically smaller than a full memory image).
    pub app_ckpt_gib: f64,
    /// PRNG seed for read synthesis.
    pub seed: u64,
}

impl Default for WorkloadCfg {
    fn default() -> Self {
        Self {
            kind: "minimeta".into(),
            ks: vec![33, 55, 77, 99, 127],
            // Table I row 1 (baseline, Spot-on OFF).
            stage_secs: vec![2030, 2333, 2391, 2419, 1833],
            total_reads: 32 * 1024,
            denoise_sweeps: 24,
            app_milestones_per_stage: 2,
            state_gib: 3.0,
            app_ckpt_gib: 1.2,
            seed: 2022,
        }
    }
}

/// Cloud model parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct CloudCfg {
    /// VM size name looked up in the price book.
    pub vm_size: String,
    /// Use spot pricing (and spot eviction semantics) or on-demand.
    pub spot: bool,
    /// Scale-set replacement provisioning delay after an eviction.
    pub provisioning_delay: SimDuration,
    /// Eviction notice the metadata service gives (Azure: minimum 30 s).
    pub notice: SimDuration,
    /// Coordinator's scheduled-events poll period.
    pub poll_interval: SimDuration,
    /// Fractional slowdown the coordinator imposes on the workload (the
    /// paper's rows 1→2 delta: ~1%).
    pub coordinator_overhead: f64,
}

impl Default for CloudCfg {
    fn default() -> Self {
        Self {
            vm_size: "Standard_D8s_v3".into(),
            spot: true,
            provisioning_delay: SimDuration::from_secs(90),
            notice: SimDuration::from_secs(30),
            poll_interval: SimDuration::from_secs(10),
            coordinator_overhead: 0.011,
        }
    }
}

/// Shared-storage (Azure-Files-NFS analog) parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct StorageCfg {
    /// Sustained transfer bandwidth, MiB/s.
    pub bandwidth_mib_s: f64,
    /// Per-operation latency.
    pub latency: SimDuration,
    /// Provisioned share size, GiB (billed whether used or not).
    pub provisioned_gib: f64,
    /// $ per 100 GiB provisioned per month (paper: $16.00).
    pub price_per_100gib_month: f64,
}

impl Default for StorageCfg {
    fn default() -> Self {
        Self {
            bandwidth_mib_s: 250.0,
            latency: SimDuration::from_millis(20),
            provisioned_gib: 100.0,
            price_per_100gib_month: 16.0,
        }
    }
}

/// Jittered-exponential-backoff policy for retrying failed checkpoint
/// commits ([`crate::coordinator::backoff`]). TOML: the
/// `[checkpoint.retry]` section:
///
/// ```toml
/// [checkpoint.retry]
/// attempts = 4      # total write attempts (>= 1; 1 = no retry)
/// base_ms = 500     # first retry delay
/// max_ms = 8000     # delay cap (>= base_ms)
/// factor = 2.0      # exponential growth per attempt (>= 1 + jitter)
/// jitter = 0.25     # uniform jitter fraction in [0, 1)
/// ```
///
/// `factor >= 1 + jitter` guarantees the jittered delay sequence is
/// monotone non-decreasing up to the cap (property-tested in
/// `coordinator::backoff`). All knobs are validated at TOML parse AND
/// again at policy construction.
#[derive(Debug, Clone, PartialEq)]
pub struct BackoffCfg {
    /// Total write attempts, including the first (must be >= 1).
    pub attempts: u32,
    /// Delay before the first retry. Must be non-zero.
    pub base: SimDuration,
    /// Upper bound on any retry delay. Must be >= `base`.
    pub max: SimDuration,
    /// Exponential growth factor per attempt. Must be finite and
    /// >= `1 + jitter` (keeps jittered delays monotone).
    pub factor: f64,
    /// Uniform jitter fraction in `[0, 1)`: attempt `k` waits
    /// `min(base · factor^k · (1 + jitter·u), max)` with `u ∈ [0, 1)`.
    pub jitter: f64,
}

impl Default for BackoffCfg {
    fn default() -> Self {
        Self {
            attempts: 4,
            base: SimDuration::from_millis(500),
            max: SimDuration::from_secs(8),
            factor: 2.0,
            jitter: 0.25,
        }
    }
}

impl BackoffCfg {
    /// Build-side validation, mirrored by the `[checkpoint.retry]` parse.
    pub fn validate(&self) -> Result<()> {
        if self.attempts == 0 {
            bail!("checkpoint.retry.attempts must be >= 1, got 0");
        }
        if self.base.is_zero() {
            bail!("checkpoint.retry.base_ms must be positive");
        }
        if self.max < self.base {
            bail!(
                "checkpoint.retry.max_ms ({}) is below base_ms ({}) — the \
                 backoff bounds are inverted",
                self.max,
                self.base
            );
        }
        if !(self.jitter.is_finite() && (0.0..1.0).contains(&self.jitter)) {
            bail!(
                "checkpoint.retry.jitter must be in [0, 1), got {}",
                self.jitter
            );
        }
        if !(self.factor.is_finite() && self.factor >= 1.0 + self.jitter) {
            bail!(
                "checkpoint.retry.factor must be finite and >= 1 + jitter \
                 ({}) so delays stay monotone, got {}",
                1.0 + self.jitter,
                self.factor
            );
        }
        Ok(())
    }
}

/// Storage-layer fault injection ([`crate::storage::chaos`]). TOML: the
/// `[chaos.storage]` section:
///
/// ```toml
/// [chaos.storage]
/// write_fail_prob = 0.10    # checkpoint object write fails outright
/// torn_write_prob = 0.05    # write dies mid-transfer (prefix lands)
/// corrupt_prob = 0.05       # payload lands bit-flipped (caught at
///                           # restore by manifest CRC/SHA verification)
/// latency_spike_prob = 0.2  # write completes but takes extra time
/// latency_spike_ms = 1500   # size of the injected latency spike
/// ```
///
/// Probabilities are per stored object (the two-phase writer puts
/// payload, manifest and COMMIT separately) and must be finite values in
/// `[0, 1]`. All draws come from a salted per-run PRNG stream, so sweeps
/// stay byte-identical at any thread or process count.
#[derive(Debug, Clone, PartialEq)]
pub struct ChaosStorageCfg {
    pub write_fail_prob: f64,
    pub torn_write_prob: f64,
    pub corrupt_prob: f64,
    pub latency_spike_prob: f64,
    pub latency_spike: SimDuration,
}

impl Default for ChaosStorageCfg {
    fn default() -> Self {
        Self {
            write_fail_prob: 0.0,
            torn_write_prob: 0.0,
            corrupt_prob: 0.0,
            latency_spike_prob: 0.0,
            latency_spike: SimDuration::from_millis(250),
        }
    }
}

/// IMDS (scheduled-events endpoint) outage injection. TOML: the
/// `[chaos.imds]` section:
///
/// ```toml
/// [chaos.imds]
/// outages = 2               # outage windows drawn inside [chaos]'s
///                           # window_mins
/// outage_mins = 2.0         # length of each outage window
/// degraded_poll_factor = 6  # poll cadence multiplier while the
///                           # endpoint is down (the monitor degrades
///                           # instead of silently losing the notice)
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct ChaosImdsCfg {
    pub outages: u32,
    pub outage_duration: SimDuration,
    pub degraded_poll_factor: u32,
}

impl Default for ChaosImdsCfg {
    fn default() -> Self {
        Self {
            outages: 0,
            outage_duration: SimDuration::from_mins(2),
            degraded_poll_factor: 6,
        }
    }
}

/// Trace-spliced price shocks ([`crate::sim::chaos`]): spike segments
/// spliced into every traced pool's price stream at seeded instants.
/// TOML: the `[chaos.market]` section:
///
/// ```toml
/// [chaos.market]
/// shocks = 2           # spike windows drawn inside [chaos]'s
///                      # window_mins (off the salted seed)
/// factor = 2.5         # price multiplier inside each window (> 1)
/// duration_mins = 30   # length of each spike window
/// ```
///
/// A shock multiplies the pool's traced factor inside its window and
/// restores the underlying trace at the window end, so an instance whose
/// bid the spike crosses is reclaimed by outbid mid-window. Requires at
/// least one pool with traced or walked pricing — a shock against
/// static-only pricing would be silently inert and is rejected.
#[derive(Debug, Clone, PartialEq)]
pub struct ChaosMarketCfg {
    pub shocks: u32,
    pub factor: f64,
    pub duration: SimDuration,
}

impl Default for ChaosMarketCfg {
    fn default() -> Self {
        Self {
            shocks: 0,
            factor: 2.0,
            duration: SimDuration::from_mins(30),
        }
    }
}

/// Seeded fault injection ([`crate::sim::chaos`]). TOML: the `[chaos]`
/// section plus its `[chaos.storage]` / `[chaos.imds]` /
/// `[chaos.market]` subsections:
///
/// ```toml
/// [chaos]
/// salt = 99            # decorrelates this scenario's fault stream
/// storms = 2           # coordinated multi-pool eviction storms
/// window_mins = 120    # storms + IMDS outages are drawn inside this
///                      # window from the run start
/// ```
///
/// Every fault instant and probability draw is a function of
/// `(scenario seed, salt)` only — never thread, worker or shard count —
/// so chaos-enabled sweeps merge byte-identically
/// (`tests/sweep_determinism.rs`).
#[derive(Debug, Clone, PartialEq)]
pub struct ChaosCfg {
    /// Salt decorrelating this scenario's fault stream from the seed's
    /// other consumers (eviction plans, price walks, arrivals).
    pub salt: u64,
    /// Coordinated eviction storms: each storm instantly schedules an
    /// eviction notice for every live instance in every pool.
    pub storms: u32,
    /// Window (from run start) inside which storms and IMDS outages are
    /// drawn. Must be positive when storms or outages are configured.
    pub window: SimDuration,
    pub storage: ChaosStorageCfg,
    pub imds: ChaosImdsCfg,
    pub market: ChaosMarketCfg,
}

impl Default for ChaosCfg {
    fn default() -> Self {
        Self {
            salt: 0,
            storms: 0,
            window: SimDuration::from_hours(4),
            storage: ChaosStorageCfg::default(),
            imds: ChaosImdsCfg::default(),
            market: ChaosMarketCfg::default(),
        }
    }
}

impl ChaosCfg {
    /// Build-side validation, mirroring the `[chaos]` parse rules.
    pub fn validate(&self) -> Result<()> {
        let probs = [
            ("chaos.storage.write_fail_prob", self.storage.write_fail_prob),
            ("chaos.storage.torn_write_prob", self.storage.torn_write_prob),
            ("chaos.storage.corrupt_prob", self.storage.corrupt_prob),
            (
                "chaos.storage.latency_spike_prob",
                self.storage.latency_spike_prob,
            ),
        ];
        for (key, p) in probs {
            if !(p.is_finite() && (0.0..=1.0).contains(&p)) {
                bail!("{key} must be a finite probability in [0, 1], got {p}");
            }
        }
        if self.storage.latency_spike_prob > 0.0
            && self.storage.latency_spike.is_zero()
        {
            bail!(
                "chaos.storage.latency_spike_ms must be positive when \
                 latency_spike_prob > 0"
            );
        }
        if (self.storms > 0
            || self.imds.outages > 0
            || self.market.shocks > 0)
            && self.window.is_zero()
        {
            bail!(
                "chaos.window_mins must be positive when storms, IMDS \
                 outages or market shocks are configured"
            );
        }
        if self.market.shocks > 0 {
            if !(self.market.factor.is_finite() && self.market.factor > 1.0) {
                bail!(
                    "chaos.market.factor must be finite and > 1 (a shock \
                     is a price *spike*), got {}",
                    self.market.factor
                );
            }
            if self.market.duration.is_zero() {
                bail!(
                    "chaos.market.duration_mins must be positive when \
                     shocks are configured"
                );
            }
        }
        if self.imds.outages > 0 && self.imds.outage_duration.is_zero() {
            bail!(
                "chaos.imds.outage_mins must be positive when outages are \
                 configured"
            );
        }
        if self.imds.degraded_poll_factor < 2 {
            bail!(
                "chaos.imds.degraded_poll_factor must be >= 2 (a degraded \
                 cadence slower than the healthy one), got {}",
                self.imds.degraded_poll_factor
            );
        }
        Ok(())
    }
}

/// Post-run expectations ([`crate::report::expect`]): bounds a scenario
/// must satisfy to count as healthy, evaluated after a run or sweep by
/// `spoton check`. TOML: the `[expect]` section:
///
/// ```toml
/// [expect]
/// seeds = 16                    # evaluate over a 16-seed sweep
/// must_complete = true          # every run finishes its workload
/// max_lost_steps = 40000        # per-run recomputation bound
/// max_cost = 2.50               # per-run total cost ceiling ($)
/// max_makespan_mins = 600       # per-run wall-clock bound
/// p95_makespan_mins = 480       # population percentile bound
/// p95_turnaround_mins = 480     # cluster-job turnaround percentile
/// max_restore_fallbacks = 4     # restores may skip at most this many
///                               # unverifiable generations
/// max_unrecovered_restores = 0  # no restart may lose all generations
/// zero_dead_letter = true       # no job aborts / fails to finish
/// max_deadline_misses = 2       # jobs past their [job] deadline,
///                               # summed across the whole sweep
/// min_sla_attainment = 0.99     # fraction of deadline-carrying jobs
///                               # that met their deadline, in [0, 1]
/// ```
///
/// Every bound is optional, but an empty `[expect]` section is rejected
/// (it would make `spoton check` vacuously green).
#[derive(Debug, Clone, PartialEq)]
pub struct ExpectCfg {
    /// Seeds to sweep when evaluating (`seed .. seed + seeds`).
    pub seeds: u64,
    pub must_complete: bool,
    pub max_lost_steps: Option<u64>,
    pub max_cost: Option<f64>,
    pub max_makespan: Option<SimDuration>,
    pub p95_makespan: Option<SimDuration>,
    pub p95_turnaround: Option<SimDuration>,
    pub max_restore_fallbacks: Option<u64>,
    pub max_unrecovered_restores: Option<u64>,
    pub zero_dead_letter: bool,
    /// Total deadline misses allowed across the whole sweep (requires a
    /// `[job] deadline_mins` SLA to be configured).
    pub max_deadline_misses: Option<u64>,
    /// Minimum fraction of deadline-carrying jobs that met their
    /// deadline, aggregated across the sweep. Finite, in `[0, 1]`.
    pub min_sla_attainment: Option<f64>,
}

impl Default for ExpectCfg {
    fn default() -> Self {
        Self {
            seeds: 1,
            must_complete: false,
            max_lost_steps: None,
            max_cost: None,
            max_makespan: None,
            p95_makespan: None,
            p95_turnaround: None,
            max_restore_fallbacks: None,
            max_unrecovered_restores: None,
            zero_dead_letter: false,
            max_deadline_misses: None,
            min_sla_attainment: None,
        }
    }
}

impl ExpectCfg {
    /// True when at least one bound is actually asserted.
    pub fn names_any_bound(&self) -> bool {
        self.must_complete
            || self.zero_dead_letter
            || self.max_lost_steps.is_some()
            || self.max_cost.is_some()
            || self.max_makespan.is_some()
            || self.p95_makespan.is_some()
            || self.p95_turnaround.is_some()
            || self.max_restore_fallbacks.is_some()
            || self.max_unrecovered_restores.is_some()
            || self.max_deadline_misses.is_some()
            || self.min_sla_attainment.is_some()
    }

    /// Build-side validation, mirroring the `[expect]` parse rules.
    pub fn validate(&self) -> Result<()> {
        if self.seeds == 0 {
            bail!("expect.seeds must be >= 1, got 0");
        }
        if !self.names_any_bound() {
            bail!(
                "[expect] names no expectations — add at least one bound \
                 or remove the section"
            );
        }
        if let Some(v) = self.max_cost {
            if !(v.is_finite() && v >= 0.0) {
                bail!(
                    "expect.max_cost must be finite and non-negative, got {v}"
                );
            }
        }
        if let Some(v) = self.min_sla_attainment {
            if !(v.is_finite() && (0.0..=1.0).contains(&v)) {
                bail!(
                    "expect.min_sla_attainment must be a finite fraction \
                     in [0, 1], got {v}"
                );
            }
        }
        Ok(())
    }
}

/// A complete experiment scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioConfig {
    pub name: String,
    pub seed: u64,
    /// Is the Spot-on coordinator attached? (Table I row 1 is OFF: no
    /// polling overhead, no checkpoints, no eviction detection.)
    pub coordinator_attached: bool,
    pub workload: WorkloadCfg,
    pub eviction: EvictionPlanCfg,
    pub checkpoint: CheckpointMethodCfg,
    /// Adaptive checkpoint-interval controller tuning the periodic
    /// cadence online ([`crate::policy`]); the default
    /// [`IntervalControllerCfg::Fixed`] reproduces the static
    /// `interval_mins` behaviour byte for byte.
    pub adaptive: IntervalControllerCfg,
    /// Compress the opportunistic termination checkpoint when the raw
    /// image would not fit the notice window (the coordinator samples the
    /// snapshot's compression ratio to decide — `checkpoint::compress`).
    pub compress_termination: bool,
    pub cloud: CloudCfg,
    /// Replacement pools + placement policy. Defaults to a single pool
    /// derived from `cloud`/`eviction` with sticky placement (the paper's
    /// capacity-1 scale set).
    pub fleet: FleetCfg,
    /// Contended multi-job cluster ([`ClusterCfg`]): `Some` multiplexes
    /// many copies of this scenario's workload onto the shared fleet via
    /// [`crate::sim::cluster`]; `None` (the default) is the single-job
    /// world.
    pub cluster: Option<ClusterCfg>,
    /// Per-job deadline SLA (`[job] deadline_mins`), measured from each
    /// job's submission (run start in the single-job world). Purely
    /// observational — a run past its deadline still finishes, but
    /// reports `deadline_missed` and a `DeadlineMissed` timeline event.
    /// Distinct from the top-level `deadline_mins` *abort* threshold.
    pub job_deadline: Option<SimDuration>,
    /// Hybrid spot/on-demand autoscaler (`[autoscale]`), consulted at
    /// every cluster placement. Requires `cluster` and `job_deadline`.
    pub autoscale: Option<AutoscaleCfg>,
    pub storage: StorageCfg,
    /// Verified checkpoint generations the store retains (`[checkpoint]
    /// retain`, default 3). Restores fall back generation by generation
    /// when the newest snapshot fails manifest verification, so `k > 1`
    /// is what makes corrupted-snapshot chaos survivable.
    pub retain: u32,
    /// Retry policy for failed checkpoint commits (`[checkpoint.retry]`).
    /// `None` (the default) fails fast on the first storage error —
    /// the pre-chaos behaviour.
    pub retry: Option<BackoffCfg>,
    /// Seeded fault injection (`[chaos]`). `None` (the default) injects
    /// nothing and leaves every digest byte-identical.
    pub chaos: Option<ChaosCfg>,
    /// Post-run expectations (`[expect]`) evaluated by `spoton check`.
    pub expect: Option<ExpectCfg>,
    /// Abort threshold: give up if the run exceeds this much virtual time
    /// (catches never-completing configurations — paper §IV).
    pub deadline: SimDuration,
    /// Timeline recording level. [`RecordLevel::Full`] keeps every event
    /// with its detail string; [`RecordLevel::Counts`] keeps per-kind
    /// counters only (the Monte Carlo sweep hot path).
    pub metrics: RecordLevel,
}

impl Default for ScenarioConfig {
    fn default() -> Self {
        Self {
            name: "default".into(),
            seed: 7,
            coordinator_attached: true,
            workload: WorkloadCfg::default(),
            eviction: EvictionPlanCfg::None,
            checkpoint: CheckpointMethodCfg::None,
            adaptive: IntervalControllerCfg::default(),
            compress_termination: false,
            cloud: CloudCfg::default(),
            fleet: FleetCfg::default(),
            cluster: None,
            job_deadline: None,
            autoscale: None,
            storage: StorageCfg::default(),
            retain: 3,
            retry: None,
            chaos: None,
            expect: None,
            deadline: SimDuration::from_hours(48),
            metrics: RecordLevel::Full,
        }
    }
}

fn mins(doc: &TomlDoc, sec: &str, key: &str) -> Option<SimDuration> {
    doc.get_f64(sec, key)
        .map(|m| SimDuration::from_secs_f64(m * 60.0))
}

fn secs(doc: &TomlDoc, sec: &str, key: &str) -> Option<SimDuration> {
    doc.get_f64(sec, key).map(SimDuration::from_secs_f64)
}

/// Parse `sec.capacity` (which the caller verified is present) as an
/// instance count >= 1; zero, negative and out-of-range values are parse
/// errors naming the key.
fn parse_capacity(doc: &TomlDoc, sec: &str) -> Result<u32> {
    let v = doc
        .get(sec, "capacity")
        .with_context(|| format!("{sec}.capacity missing"))?;
    let n = v.as_u64().with_context(|| {
        format!("{sec}.capacity must be a non-negative integer")
    })?;
    if n == 0 {
        bail!("{sec}.capacity must be >= 1, got 0");
    }
    u32::try_from(n)
        .map_err(|_| anyhow::anyhow!("{sec}.capacity {n} is out of range"))
}

/// Parse an eviction plan out of `sec` (used by both the scenario-level
/// `[eviction]` section and per-pool `[pool.NAME]` sections).
fn eviction_plan_from(doc: &TomlDoc, sec: &str) -> Result<EvictionPlanCfg> {
    let plan = doc.get_str(sec, "plan").unwrap_or("none");
    Ok(match plan {
        "none" => EvictionPlanCfg::None,
        "fixed" => EvictionPlanCfg::Fixed {
            interval: mins(doc, sec, "interval_mins")
                .with_context(|| format!("{sec}.interval_mins required for fixed"))?,
        },
        "poisson" => EvictionPlanCfg::Poisson {
            mean: mins(doc, sec, "mean_mins")
                .with_context(|| format!("{sec}.mean_mins required for poisson"))?,
        },
        "trace" => {
            let arr = doc
                .get(sec, "offsets_mins")
                .and_then(TomlValue::as_array)
                .with_context(|| {
                    format!("{sec}.offsets_mins required for trace")
                })?;
            EvictionPlanCfg::Trace {
                offsets: arr
                    .iter()
                    .map(|v| {
                        v.as_f64()
                            .map(|m| SimDuration::from_secs_f64(m * 60.0))
                            .context("offsets_mins must be numbers")
                    })
                    .collect::<Result<_>>()?,
            }
        }
        other => bail!("unknown {sec}.plan '{other}'"),
    })
}

impl ScenarioConfig {
    /// Parse a scenario TOML document; unspecified fields keep defaults.
    /// `price_trace` paths resolve relative to the process working
    /// directory — use [`ScenarioConfig::load`] (or
    /// [`ScenarioConfig::from_toml_with_base`]) to resolve them relative
    /// to the scenario file instead.
    pub fn from_toml(doc: &TomlDoc) -> Result<Self> {
        Self::from_toml_with_base(doc, None)
    }

    /// Parse a scenario TOML document, resolving relative `price_trace`
    /// paths against `base`.
    pub fn from_toml_with_base(
        doc: &TomlDoc,
        base: Option<&Path>,
    ) -> Result<Self> {
        let mut cfg = ScenarioConfig::default();
        if let Some(n) = doc.get_str("", "name") {
            cfg.name = n.to_string();
        }
        if let Some(s) = doc.get_u64("", "seed") {
            cfg.seed = s;
        }
        if let Some(d) = mins(doc, "", "deadline_mins") {
            cfg.deadline = d;
        }
        if let Some(v) = doc.get_bool("", "spoton") {
            cfg.coordinator_attached = v;
        }
        if let Some(v) = doc.get_str("", "metrics_level") {
            cfg.metrics = match v {
                "full" => RecordLevel::Full,
                "counts" => RecordLevel::Counts,
                other => bail!("unknown metrics_level '{other}'"),
            };
        }

        // [workload]
        if let Some(k) = doc.get_str("workload", "kind") {
            if !["minimeta", "sleeper"].contains(&k) {
                bail!("unknown workload.kind '{k}'");
            }
            cfg.workload.kind = k.to_string();
        }
        if let Some(arr) = doc.get("workload", "ks").and_then(TomlValue::as_array)
        {
            cfg.workload.ks = arr
                .iter()
                .map(|v| {
                    v.as_u64()
                        .and_then(|x| u32::try_from(x).ok())
                        .context("workload.ks must be positive ints")
                })
                .collect::<Result<_>>()?;
        }
        if let Some(arr) =
            doc.get("workload", "stage_secs").and_then(TomlValue::as_array)
        {
            cfg.workload.stage_secs = arr
                .iter()
                .map(|v| v.as_u64().context("workload.stage_secs must be ints"))
                .collect::<Result<_>>()?;
        }
        if let Some(v) = doc.get_u64("workload", "total_reads") {
            cfg.workload.total_reads = v;
        }
        if let Some(v) = doc.get_u64("workload", "denoise_sweeps") {
            cfg.workload.denoise_sweeps = v as u32;
        }
        if let Some(v) = doc.get_u64("workload", "app_milestones_per_stage") {
            cfg.workload.app_milestones_per_stage = v as u32;
        }
        if let Some(v) = doc.get_f64("workload", "state_gib") {
            cfg.workload.state_gib = v;
        }
        if let Some(v) = doc.get_f64("workload", "app_ckpt_gib") {
            cfg.workload.app_ckpt_gib = v;
        }
        if let Some(v) = doc.get_u64("workload", "seed") {
            cfg.workload.seed = v;
        }
        if cfg.workload.ks.len() != cfg.workload.stage_secs.len() {
            bail!(
                "workload.ks ({}) and workload.stage_secs ({}) lengths differ",
                cfg.workload.ks.len(),
                cfg.workload.stage_secs.len()
            );
        }

        // [eviction]
        if doc.has_section("eviction") {
            cfg.eviction = eviction_plan_from(doc, "eviction")?;
        }

        // [checkpoint]
        if doc.has_section("checkpoint") {
            let method = doc.get_str("checkpoint", "method").unwrap_or("none");
            cfg.checkpoint = match method {
                "none" => CheckpointMethodCfg::None,
                "application" => CheckpointMethodCfg::AppNative,
                "transparent" => CheckpointMethodCfg::Transparent {
                    interval: mins(doc, "checkpoint", "interval_mins").context(
                        "checkpoint.interval_mins required for transparent",
                    )?,
                },
                other => bail!("unknown checkpoint.method '{other}'"),
            };
            if let Some(v) = doc.get_bool("checkpoint", "compress") {
                cfg.compress_termination = v;
            }
            if let Some(raw) = doc.get("checkpoint", "retain") {
                let v = raw.as_u64().context(
                    "checkpoint.retain must be a non-negative integer",
                )?;
                if v == 0 {
                    bail!(
                        "checkpoint.retain must be >= 1 (retaining zero \
                         generations leaves nothing to restore), got 0"
                    );
                }
                if matches!(cfg.checkpoint, CheckpointMethodCfg::None) {
                    bail!(
                        "checkpoint.retain has no effect with checkpoint.\
                         method = \"none\" — remove it or enable checkpoints"
                    );
                }
                cfg.retain = u32::try_from(v)
                    .context("checkpoint.retain is out of range")?;
            }
        }

        // [checkpoint.retry] — bounded jittered-exponential backoff for
        // failed checkpoint commits. Same validation posture as
        // [checkpoint.adaptive]: every knob checked here AND at policy
        // construction (`coordinator::backoff::Backoff::new`).
        if doc.has_section("checkpoint.retry") {
            let sec = "checkpoint.retry";
            if matches!(cfg.checkpoint, CheckpointMethodCfg::None) {
                bail!(
                    "[{sec}] requires a checkpointing method (retries apply \
                     to checkpoint commits) — set checkpoint.method"
                );
            }
            let mut retry = BackoffCfg::default();
            if let Some(raw) = doc.get(sec, "attempts") {
                let v = raw
                    .as_u64()
                    .with_context(|| format!("{sec}.attempts must be an integer"))?;
                retry.attempts = u32::try_from(v)
                    .with_context(|| format!("{sec}.attempts is out of range"))?;
            }
            let pos_ms = |key: &str| -> Result<Option<SimDuration>> {
                match doc.get_f64(sec, key) {
                    None => Ok(None),
                    Some(v) if v.is_finite() && v > 0.0 => {
                        Ok(Some(SimDuration::from_secs_f64(v / 1000.0)))
                    }
                    Some(v) => bail!(
                        "{sec}.{key} must be positive and finite, got {v}"
                    ),
                }
            };
            if let Some(v) = pos_ms("base_ms")? {
                retry.base = v;
            }
            if let Some(v) = pos_ms("max_ms")? {
                retry.max = v;
            }
            if let Some(v) = doc.get_f64(sec, "factor") {
                retry.factor = v;
            }
            if let Some(v) = doc.get_f64(sec, "jitter") {
                retry.jitter = v;
            }
            retry.validate()?;
            cfg.retry = Some(retry);
        }

        // [checkpoint.adaptive] — interval-controller selection + knobs.
        // Every knob is validated here, in PR-4 `build_policy` style: a
        // non-finite, zero or inverted value is a parse error naming the
        // offending key, never a silently-degraded controller.
        if doc.has_section("checkpoint.adaptive") {
            let sec = "checkpoint.adaptive";
            if !matches!(cfg.checkpoint, CheckpointMethodCfg::Transparent { .. })
            {
                bail!(
                    "[{sec}] requires checkpoint.method = \"transparent\" \
                     (adaptive controllers tune the periodic interval)"
                );
            }
            let pos_mins = |key: &str| -> Result<Option<SimDuration>> {
                match doc.get_f64(sec, key) {
                    None => Ok(None),
                    Some(v) if v.is_finite() && v > 0.0 => {
                        Ok(Some(SimDuration::from_secs_f64(v * 60.0)))
                    }
                    Some(v) => bail!(
                        "{sec}.{key} must be positive and finite, got {v}"
                    ),
                }
            };
            let mut clamp = ClampCfg::default();
            if let Some(v) = pos_mins("min_interval_mins")? {
                clamp.min = v;
            }
            if let Some(v) = pos_mins("max_interval_mins")? {
                clamp.max = v;
            }
            if clamp.min > clamp.max {
                bail!(
                    "{sec}: min_interval_mins ({}) exceeds max_interval_mins \
                     ({}) — the clamp is inverted",
                    clamp.min,
                    clamp.max
                );
            }
            if let Some(v) = doc.get_f64(sec, "hysteresis") {
                if !(v.is_finite() && (0.0..1.0).contains(&v)) {
                    bail!("{sec}.hysteresis must be in [0, 1), got {v}");
                }
                clamp.hysteresis = v;
            }
            let prior_mtbf = pos_mins("mtbf_prior_mins")?
                .unwrap_or(SimDuration::from_mins(60));
            let sensitivity = doc.get_f64(sec, "sensitivity");
            if let Some(v) = sensitivity {
                if !(v.is_finite() && v > 0.0) {
                    bail!(
                        "{sec}.sensitivity must be positive and finite, \
                         got {v}"
                    );
                }
            }
            let higher_order = doc.get_bool(sec, "higher_order");
            if doc.get(sec, "higher_order").is_some() && higher_order.is_none()
            {
                bail!("{sec}.higher_order must be a boolean");
            }
            cfg.adaptive = match doc.get_str(sec, "controller").unwrap_or("fixed")
            {
                "fixed" => {
                    // every other knob configures the adaptive
                    // controllers; accepting them here would silently
                    // run the static interval the user thought they
                    // replaced
                    for key in [
                        "min_interval_mins",
                        "max_interval_mins",
                        "hysteresis",
                        "mtbf_prior_mins",
                        "sensitivity",
                        "higher_order",
                    ] {
                        if doc.get(sec, key).is_some() {
                            bail!(
                                "{sec}.{key} has no effect with controller \
                                 = \"fixed\" — remove it or pick an \
                                 adaptive controller"
                            );
                        }
                    }
                    IntervalControllerCfg::Fixed
                }
                "young-daly" => {
                    if sensitivity.is_some() {
                        bail!(
                            "{sec}.sensitivity only applies to the \
                             cost-aware controller"
                        );
                    }
                    IntervalControllerCfg::YoungDaly {
                        prior_mtbf,
                        clamp,
                        higher_order: higher_order.unwrap_or(false),
                    }
                }
                "cost-aware" => {
                    if higher_order.is_some() {
                        bail!(
                            "{sec}.higher_order only applies to the \
                             young-daly controller"
                        );
                    }
                    IntervalControllerCfg::CostAware {
                        sensitivity: sensitivity.unwrap_or(1.0),
                        prior_mtbf,
                        clamp,
                    }
                }
                other => bail!("unknown {sec}.controller '{other}'"),
            };
        }

        // [cloud]
        if let Some(v) = doc.get_str("cloud", "vm_size") {
            cfg.cloud.vm_size = v.to_string();
        }
        if let Some(v) = doc.get_bool("cloud", "spot") {
            cfg.cloud.spot = v;
        }
        if let Some(v) = secs(doc, "cloud", "provisioning_delay_secs") {
            cfg.cloud.provisioning_delay = v;
        }
        if let Some(v) = secs(doc, "cloud", "notice_secs") {
            cfg.cloud.notice = v;
        }
        if let Some(v) = secs(doc, "cloud", "poll_interval_secs") {
            cfg.cloud.poll_interval = v;
        }
        if let Some(v) = doc.get_f64("cloud", "coordinator_overhead") {
            if !(0.0..1.0).contains(&v) {
                bail!("cloud.coordinator_overhead must be in [0,1)");
            }
            cfg.cloud.coordinator_overhead = v;
        }

        // [storage]
        if let Some(v) = doc.get_f64("storage", "bandwidth_mib_s") {
            if v <= 0.0 {
                bail!("storage.bandwidth_mib_s must be positive");
            }
            cfg.storage.bandwidth_mib_s = v;
        }
        if let Some(v) = doc.get_f64("storage", "latency_ms") {
            cfg.storage.latency = SimDuration::from_millis(v as u64);
        }
        if let Some(v) = doc.get_f64("storage", "provisioned_gib") {
            if !(v.is_finite() && v >= 0.0) {
                bail!("storage.provisioned_gib must be finite and non-negative");
            }
            cfg.storage.provisioned_gib = v;
        }
        if let Some(v) = doc.get_f64("storage", "price_per_100gib_month") {
            if !(v.is_finite() && v >= 0.0) {
                bail!(
                    "storage.price_per_100gib_month must be finite and \
                     non-negative"
                );
            }
            cfg.storage.price_per_100gib_month = v;
        }

        // [fleet] + [pool.NAME] sections (multi-pool replacement fleets).
        // Pools are collected in section-name order (the parser keeps
        // sections in a sorted map), which fixes pool indices and thereby
        // per-pool eviction-plan seeds.
        if doc.has_section("fleet") {
            cfg.fleet.placement = match doc.get_str("fleet", "placement") {
                None | Some("sticky") => PlacementPolicyCfg::Sticky,
                Some("cheapest-spot") => PlacementPolicyCfg::CheapestSpot,
                Some("eviction-aware") => {
                    let penalty =
                        doc.get_f64("fleet", "penalty").unwrap_or(4.0);
                    if !(penalty.is_finite() && penalty >= 0.0) {
                        bail!(
                            "fleet.penalty must be finite and non-negative, \
                             got {penalty}"
                        );
                    }
                    PlacementPolicyCfg::EvictionAware { penalty }
                }
                Some(other) => bail!("unknown fleet.placement '{other}'"),
            };
        }
        let mut pool_names: Vec<String> = Vec::new();
        for sec in doc.sections.keys() {
            let Some(rest) = sec.strip_prefix("pool.") else { continue };
            match rest.split_once('.') {
                None => pool_names.push(rest.to_string()),
                Some((name, "price_walk")) => {
                    if !doc.has_section(&format!("pool.{name}")) {
                        bail!(
                            "[pool.{name}.price_walk] without a [pool.{name}] \
                             section"
                        );
                    }
                }
                Some((name, other)) => bail!(
                    "unknown pool subsection [pool.{name}.{other}] (only \
                     price_walk is recognized)"
                ),
            }
        }
        for name in pool_names {
            let sec = format!("pool.{name}");
            if cfg.fleet.pools.iter().any(|p| p.name == name) {
                bail!("duplicate pool '{name}'");
            }
            let mut pool = PoolCfg::named(&name);
            if let Some(v) = doc.get_str(&sec, "vm_size") {
                pool.vm_size = v.to_string();
            }
            if let Some(v) = doc.get_bool(&sec, "spot") {
                pool.spot = v;
            }
            // kind = "spot" | "on-demand": readable sugar over `spot`.
            // The on-demand kind is strict: it never evicts and its
            // price never moves, so eviction plans, price dynamics and
            // bids on it are rejected as contradictions (a bare
            // `spot = false` keeps the historical permissive semantics).
            let kind = match doc.get_str(&sec, "kind") {
                None => None,
                Some(k) => {
                    if doc.get(&sec, "spot").is_some() {
                        bail!(
                            "{sec}.kind conflicts with {sec}.spot — give one \
                             or the other"
                        );
                    }
                    match k {
                        "spot" => pool.spot = true,
                        "on-demand" => pool.spot = false,
                        other => bail!(
                            "unknown {sec}.kind '{other}' (expected \"spot\" \
                             or \"on-demand\")"
                        ),
                    }
                    Some(k)
                }
            };
            if kind == Some("on-demand") {
                for key in ["bid", "plan", "price_trace"] {
                    if doc.get(&sec, key).is_some() {
                        bail!(
                            "{sec}.{key} contradicts kind = \"on-demand\" — \
                             on-demand pools never evict and their price \
                             never moves"
                        );
                    }
                }
                if doc.has_section(&format!("{sec}.price_walk")) {
                    bail!(
                        "[{sec}.price_walk] contradicts kind = \"on-demand\" \
                         — on-demand prices never move"
                    );
                }
            }
            if let Some(v) = secs(doc, &sec, "provisioning_delay_secs") {
                pool.provisioning_delay = v;
            }
            if let Some(v) = doc.get_f64(&sec, "price_factor") {
                if !(v.is_finite() && v > 0.0) {
                    bail!("{sec}.price_factor must be positive and finite");
                }
                pool.price_factor = v;
            }
            if doc.get(&sec, "capacity").is_some() {
                pool.capacity = parse_capacity(doc, &sec)?;
            }
            pool.eviction = eviction_plan_from(doc, &sec)?;
            // price dynamics: a replayed trace file, or a generated walk
            let wsec = format!("{sec}.price_walk");
            if let Some(path) = doc.get_str(&sec, "price_trace") {
                if doc.has_section(&wsec) {
                    bail!(
                        "{sec}.price_trace conflicts with [{wsec}] — a pool's \
                         prices are traced or walked, not both"
                    );
                }
                let full = match base {
                    Some(dir) => dir.join(path),
                    None => Path::new(path).to_path_buf(),
                };
                let trace = PoolTrace::load(&full)?;
                if !trace.evictions.is_empty() {
                    // the trace file carries this pool's eviction
                    // schedule; a section-level plan would shadow it
                    if doc.get(&sec, "plan").is_some() {
                        bail!(
                            "{sec}: trace file {path} carries eviction \
                             offsets, which conflict with {sec}.plan"
                        );
                    }
                    pool.eviction =
                        EvictionPlanCfg::Trace { offsets: trace.evictions };
                }
                pool.pricing = PoolPricingCfg::Trace(trace.price);
            } else if doc.has_section(&wsec) {
                let mut walk = PriceWalkCfg::default();
                if let Some(v) = doc.get_f64(&wsec, "start") {
                    walk.start = v;
                }
                if let Some(v) = doc.get_f64(&wsec, "volatility") {
                    walk.volatility = v;
                }
                if let Some(v) = mins(doc, &wsec, "step_mins") {
                    walk.interval = v;
                }
                if let Some(v) = doc.get_u64(&wsec, "steps") {
                    walk.steps = u32::try_from(v).map_err(|_| {
                        anyhow::anyhow!("{wsec}.steps {v} is out of range")
                    })?;
                }
                if let Some(v) = doc.get_f64(&wsec, "floor") {
                    walk.floor = v;
                }
                if let Some(v) = doc.get_f64(&wsec, "ceil") {
                    walk.ceil = v;
                }
                walk.validate().with_context(|| format!("[{wsec}]"))?;
                pool.pricing = PoolPricingCfg::Walk(walk);
            }
            // bid last: its validity depends on the pricing just parsed
            if let Some(v) = doc.get_f64(&sec, "bid") {
                if !(v.is_finite() && v > 0.0) {
                    bail!("{sec}.bid must be positive and finite, got {v}");
                }
                if !pool.spot {
                    bail!(
                        "{sec}.bid requires a spot pool — on-demand \
                         instances are never outbid"
                    );
                }
                if matches!(pool.pricing, PoolPricingCfg::Static) {
                    bail!(
                        "{sec}.bid is inert without price dynamics — add a \
                         price_trace or [{sec}.price_walk] so the price can \
                         cross the bid"
                    );
                }
                pool.bid = Some(v);
            }
            cfg.fleet.pools.push(pool);
        }
        // With explicit pools, eviction behaviour lives on the pools; a
        // scenario-level [eviction] plan would be silently ignored, so
        // reject the ambiguous combination outright.
        if !cfg.fleet.pools.is_empty() && cfg.eviction != EvictionPlanCfg::None
        {
            bail!(
                "[eviction] conflicts with explicit [pool.*] sections — move \
                 the plan into the pools (each pool has its own)"
            );
        }

        // [job] — per-job SLA knobs (the *observational* deadline, as
        // opposed to the top-level deadline_mins abort threshold).
        if doc.has_section("job") {
            let sec = "job";
            match doc.get_f64(sec, "deadline_mins") {
                Some(v) if v.is_finite() && v > 0.0 => {
                    cfg.job_deadline =
                        Some(SimDuration::from_secs_f64(v * 60.0));
                }
                Some(v) => bail!(
                    "{sec}.deadline_mins must be positive and finite, got {v}"
                ),
                None => bail!(
                    "[{sec}] requires {sec}.deadline_mins (the per-job SLA \
                     deadline)"
                ),
            }
        }

        // [cluster] — contended multi-job scenarios on the shared fleet.
        if doc.has_section("cluster") {
            let sec = "cluster";
            let mut cluster = ClusterCfg::default();
            let count = doc.get(sec, "jobs");
            let names = doc.get(sec, "names").and_then(TomlValue::as_array);
            match (count, names) {
                (Some(_), Some(_)) => bail!(
                    "{sec}.jobs conflicts with {sec}.names — give a count or \
                     an explicit name list, not both"
                ),
                (Some(v), None) => {
                    let n = v.as_u64().with_context(|| {
                        format!("{sec}.jobs must be a non-negative integer")
                    })?;
                    if n == 0 {
                        bail!("{sec}.jobs must be >= 1, got 0");
                    }
                    let n = usize::try_from(n).map_err(|_| {
                        anyhow::anyhow!("{sec}.jobs {n} is out of range")
                    })?;
                    cluster.jobs =
                        (0..n).map(|i| format!("job-{i}")).collect();
                }
                (None, Some(arr)) => {
                    cluster.jobs = arr
                        .iter()
                        .map(|v| {
                            v.as_str().map(str::to_string).with_context(|| {
                                format!("{sec}.names must be strings")
                            })
                        })
                        .collect::<Result<_>>()?;
                }
                (None, None) => bail!(
                    "[{sec}] requires {sec}.jobs (a count) or {sec}.names \
                     (an explicit list)"
                ),
            }
            let pos_mins = |key: &str| -> Result<Option<SimDuration>> {
                match doc.get_f64(sec, key) {
                    None => Ok(None),
                    Some(v) if v.is_finite() && v > 0.0 => {
                        Ok(Some(SimDuration::from_secs_f64(v * 60.0)))
                    }
                    Some(v) => bail!(
                        "{sec}.{key} must be positive and finite, got {v}"
                    ),
                }
            };
            let spacing = pos_mins("arrival_spacing_mins")?;
            let mean = pos_mins("arrival_mean_mins")?;
            cluster.arrival = match doc
                .get_str(sec, "arrival")
                .unwrap_or("batch")
            {
                "batch" => {
                    if spacing.is_some() || mean.is_some() {
                        bail!(
                            "{sec}.arrival_spacing_mins / \
                             {sec}.arrival_mean_mins have no effect with \
                             arrival = \"batch\""
                        );
                    }
                    ArrivalCfg::Batch
                }
                "uniform" => {
                    if mean.is_some() {
                        bail!(
                            "{sec}.arrival_mean_mins only applies to \
                             poisson arrivals"
                        );
                    }
                    ArrivalCfg::Uniform {
                        spacing: spacing.with_context(|| {
                            format!(
                                "{sec}.arrival_spacing_mins required for \
                                 uniform arrivals"
                            )
                        })?,
                    }
                }
                "poisson" => {
                    if spacing.is_some() {
                        bail!(
                            "{sec}.arrival_spacing_mins only applies to \
                             uniform arrivals"
                        );
                    }
                    ArrivalCfg::Poisson {
                        mean: mean.with_context(|| {
                            format!(
                                "{sec}.arrival_mean_mins required for \
                                 poisson arrivals"
                            )
                        })?,
                    }
                }
                other => bail!("unknown {sec}.arrival '{other}'"),
            };
            if doc.get(sec, "capacity").is_some() {
                if !cfg.fleet.pools.is_empty() {
                    bail!(
                        "{sec}.capacity conflicts with explicit [pool.*] \
                         sections — set capacity per pool instead"
                    );
                }
                cluster.capacity = Some(parse_capacity(doc, sec)?);
            }
            if let Some(arr) =
                doc.get(sec, "priorities").and_then(TomlValue::as_array)
            {
                cluster.priorities = arr
                    .iter()
                    .map(|v| {
                        v.as_u64()
                            .and_then(|x| u32::try_from(x).ok())
                            .with_context(|| {
                                format!(
                                    "{sec}.priorities must be non-negative \
                                     integers"
                                )
                            })
                    })
                    .collect::<Result<_>>()?;
            }
            cluster.validate()?;
            cfg.cluster = Some(cluster);
        }

        // [autoscale] — hybrid spot/on-demand autoscaler over the
        // cluster's placement. Inert-knob combinations are rejected in
        // [checkpoint.adaptive] style: every knob must belong to the
        // selected policy.
        if doc.has_section("autoscale") {
            let sec = "autoscale";
            if cfg.cluster.is_none() {
                bail!(
                    "[{sec}] requires a [cluster] section — the autoscaler \
                     drives cluster placement"
                );
            }
            if cfg.job_deadline.is_none() {
                bail!(
                    "[{sec}] requires [job] deadline_mins — the autoscaler \
                     holds per-job deadlines"
                );
            }
            let fin = |key: &str| -> Result<Option<f64>> {
                match doc.get_f64(sec, key) {
                    None => Ok(None),
                    Some(v) if v.is_finite() => Ok(Some(v)),
                    Some(v) => {
                        bail!("{sec}.{key} must be finite, got {v}")
                    }
                }
            };
            let margin = fin("margin")?;
            let percentile = fin("percentile")?;
            let weight = fin("reliability_weight")?;
            let policy = match doc.get_str(sec, "policy") {
                None => bail!(
                    "[{sec}] requires {sec}.policy (\"fixed-margin\", \
                     \"percentile\" or \"reliability\")"
                ),
                Some("fixed-margin") => {
                    for (key, v) in
                        [("percentile", percentile), ("reliability_weight", weight)]
                    {
                        if v.is_some() {
                            bail!(
                                "{sec}.{key} has no effect with policy = \
                                 \"fixed-margin\" — remove it or pick the \
                                 matching policy"
                            );
                        }
                    }
                    BidPolicyCfg::FixedMargin { margin: margin.unwrap_or(0.5) }
                }
                Some("percentile") => {
                    for (key, v) in
                        [("margin", margin), ("reliability_weight", weight)]
                    {
                        if v.is_some() {
                            bail!(
                                "{sec}.{key} has no effect with policy = \
                                 \"percentile\" — remove it or pick the \
                                 matching policy"
                            );
                        }
                    }
                    BidPolicyCfg::Percentile { q: percentile.unwrap_or(0.9) }
                }
                Some("reliability") => {
                    if percentile.is_some() {
                        bail!(
                            "{sec}.percentile has no effect with policy = \
                             \"reliability\" — remove it or pick the \
                             matching policy"
                        );
                    }
                    BidPolicyCfg::Reliability {
                        margin: margin.unwrap_or(0.5),
                        weight: weight.unwrap_or(1.0),
                    }
                }
                Some(other) => bail!("unknown {sec}.policy '{other}'"),
            };
            let on_demand_pool = doc
                .get_str(sec, "on_demand_pool")
                .with_context(|| {
                    format!(
                        "[{sec}] requires {sec}.on_demand_pool (the \
                         fallback pool's name)"
                    )
                })?
                .to_string();
            let Some(fallback) =
                cfg.fleet.pools.iter().find(|p| p.name == on_demand_pool)
            else {
                bail!(
                    "{sec}.on_demand_pool '{on_demand_pool}' does not name \
                     a [pool.*] section"
                );
            };
            if fallback.spot {
                bail!(
                    "{sec}.on_demand_pool '{on_demand_pool}' is a spot pool \
                     — the fallback must be kind = \"on-demand\""
                );
            }
            if fallback.eviction != EvictionPlanCfg::None
                || fallback.pricing != PoolPricingCfg::Static
            {
                bail!(
                    "{sec}.on_demand_pool '{on_demand_pool}' must carry no \
                     eviction plan or price dynamics"
                );
            }
            let mut autoscale = AutoscaleCfg {
                policy,
                on_demand_pool,
                slack: SimDuration::from_mins(60),
                max_queue: 4,
            };
            match doc.get_f64(sec, "slack_mins") {
                None => {}
                Some(v) if v.is_finite() && v > 0.0 => {
                    autoscale.slack = SimDuration::from_secs_f64(v * 60.0);
                }
                Some(v) => bail!(
                    "{sec}.slack_mins must be positive and finite, got {v}"
                ),
            }
            if let Some(raw) = doc.get(sec, "max_queue") {
                let v = raw.as_u64().with_context(|| {
                    format!("{sec}.max_queue must be a non-negative integer")
                })?;
                if v == 0 {
                    bail!("{sec}.max_queue must be >= 1, got 0");
                }
                autoscale.max_queue = u32::try_from(v).with_context(|| {
                    format!("{sec}.max_queue {v} is out of range")
                })?;
            }
            autoscale.validate()?;
            cfg.autoscale = Some(autoscale);
        }

        // [chaos] + [chaos.storage] + [chaos.imds] — seeded fault
        // injection. Any of the three sections enables chaos; unknown
        // chaos subsections are rejected like unknown pool subsections.
        for sec in doc.sections.keys() {
            if let Some(rest) = sec.strip_prefix("chaos.") {
                if rest != "storage" && rest != "imds" && rest != "market" {
                    bail!(
                        "unknown chaos subsection [chaos.{rest}] (only \
                         storage, imds and market are recognized)"
                    );
                }
            }
        }
        if doc.has_section("chaos")
            || doc.has_section("chaos.storage")
            || doc.has_section("chaos.imds")
            || doc.has_section("chaos.market")
        {
            let mut chaos = ChaosCfg::default();
            if let Some(raw) = doc.get("chaos", "salt") {
                chaos.salt = raw
                    .as_u64()
                    .context("chaos.salt must be a non-negative integer")?;
            }
            if let Some(raw) = doc.get("chaos", "storms") {
                let v = raw
                    .as_u64()
                    .context("chaos.storms must be a non-negative integer")?;
                chaos.storms = u32::try_from(v)
                    .context("chaos.storms is out of range")?;
            }
            if let Some(v) = doc.get_f64("chaos", "window_mins") {
                if !(v.is_finite() && v > 0.0) {
                    bail!(
                        "chaos.window_mins must be positive and finite, \
                         got {v}"
                    );
                }
                chaos.window = SimDuration::from_secs_f64(v * 60.0);
            }
            let ssec = "chaos.storage";
            let prob = |key: &str, into: &mut f64| -> Result<()> {
                if let Some(v) = doc.get_f64(ssec, key) {
                    if !(v.is_finite() && (0.0..=1.0).contains(&v)) {
                        bail!(
                            "{ssec}.{key} must be a finite probability in \
                             [0, 1], got {v}"
                        );
                    }
                    *into = v;
                }
                Ok(())
            };
            prob("write_fail_prob", &mut chaos.storage.write_fail_prob)?;
            prob("torn_write_prob", &mut chaos.storage.torn_write_prob)?;
            prob("corrupt_prob", &mut chaos.storage.corrupt_prob)?;
            prob("latency_spike_prob", &mut chaos.storage.latency_spike_prob)?;
            if let Some(v) = doc.get_f64(ssec, "latency_spike_ms") {
                if !(v.is_finite() && v > 0.0) {
                    bail!(
                        "{ssec}.latency_spike_ms must be positive and \
                         finite, got {v}"
                    );
                }
                chaos.storage.latency_spike =
                    SimDuration::from_secs_f64(v / 1000.0);
            }
            let isec = "chaos.imds";
            if let Some(raw) = doc.get(isec, "outages") {
                let v = raw
                    .as_u64()
                    .with_context(|| format!("{isec}.outages must be an integer"))?;
                chaos.imds.outages = u32::try_from(v)
                    .with_context(|| format!("{isec}.outages is out of range"))?;
            }
            if let Some(v) = doc.get_f64(isec, "outage_mins") {
                if !(v.is_finite() && v > 0.0) {
                    bail!(
                        "{isec}.outage_mins must be positive and finite, \
                         got {v}"
                    );
                }
                chaos.imds.outage_duration =
                    SimDuration::from_secs_f64(v * 60.0);
            }
            if let Some(raw) = doc.get(isec, "degraded_poll_factor") {
                let v = raw.as_u64().with_context(|| {
                    format!("{isec}.degraded_poll_factor must be an integer")
                })?;
                chaos.imds.degraded_poll_factor = u32::try_from(v)
                    .with_context(|| {
                        format!("{isec}.degraded_poll_factor is out of range")
                    })?;
            }
            let msec = "chaos.market";
            if let Some(raw) = doc.get(msec, "shocks") {
                let v = raw.as_u64().with_context(|| {
                    format!("{msec}.shocks must be a non-negative integer")
                })?;
                chaos.market.shocks = u32::try_from(v)
                    .with_context(|| format!("{msec}.shocks is out of range"))?;
            }
            if let Some(v) = doc.get_f64(msec, "factor") {
                if !(v.is_finite() && v > 1.0) {
                    bail!(
                        "{msec}.factor must be finite and > 1 (a shock is a \
                         price *spike*), got {v}"
                    );
                }
                chaos.market.factor = v;
            }
            if let Some(v) = doc.get_f64(msec, "duration_mins") {
                if !(v.is_finite() && v > 0.0) {
                    bail!(
                        "{msec}.duration_mins must be positive and finite, \
                         got {v}"
                    );
                }
                chaos.market.duration = SimDuration::from_secs_f64(v * 60.0);
            }
            if chaos.market.shocks > 0
                && !cfg.fleet.pools.iter().any(|p| {
                    !matches!(p.pricing, PoolPricingCfg::Static)
                })
            {
                bail!(
                    "{msec}.shocks require at least one pool with traced or \
                     walked pricing — a shock against static-only pricing \
                     is inert"
                );
            }
            chaos.validate()?;
            cfg.chaos = Some(chaos);
        }

        // [expect] — post-run expectations for `spoton check`.
        if doc.has_section("expect") {
            let sec = "expect";
            let mut expect = ExpectCfg::default();
            if let Some(raw) = doc.get(sec, "seeds") {
                let v = raw
                    .as_u64()
                    .with_context(|| format!("{sec}.seeds must be an integer"))?;
                if v == 0 {
                    bail!("{sec}.seeds must be >= 1, got 0");
                }
                expect.seeds = v;
            }
            for (key, into) in [
                ("must_complete", &mut expect.must_complete),
                ("zero_dead_letter", &mut expect.zero_dead_letter),
            ] {
                match doc.get_bool(sec, key) {
                    Some(v) => *into = v,
                    None if doc.get(sec, key).is_some() => {
                        bail!("{sec}.{key} must be a boolean")
                    }
                    None => {}
                }
            }
            let count = |key: &str| -> Result<Option<u64>> {
                match doc.get(sec, key) {
                    None => Ok(None),
                    Some(raw) => Ok(Some(raw.as_u64().with_context(|| {
                        format!("{sec}.{key} must be a non-negative integer")
                    })?)),
                }
            };
            expect.max_lost_steps = count("max_lost_steps")?;
            expect.max_restore_fallbacks = count("max_restore_fallbacks")?;
            expect.max_unrecovered_restores =
                count("max_unrecovered_restores")?;
            expect.max_deadline_misses = count("max_deadline_misses")?;
            if let Some(v) = doc.get_f64(sec, "min_sla_attainment") {
                if !(v.is_finite() && (0.0..=1.0).contains(&v)) {
                    bail!(
                        "{sec}.min_sla_attainment must be a finite fraction \
                         in [0, 1], got {v}"
                    );
                }
                expect.min_sla_attainment = Some(v);
            }
            if (expect.max_deadline_misses.is_some()
                || expect.min_sla_attainment.is_some())
                && cfg.job_deadline.is_none()
            {
                bail!(
                    "{sec}.max_deadline_misses / {sec}.min_sla_attainment \
                     require [job] deadline_mins — without an SLA there is \
                     nothing to miss"
                );
            }
            if let Some(v) = doc.get_f64(sec, "max_cost") {
                if !(v.is_finite() && v >= 0.0) {
                    bail!(
                        "{sec}.max_cost must be finite and non-negative, \
                         got {v}"
                    );
                }
                expect.max_cost = Some(v);
            }
            let bound_mins = |key: &str| -> Result<Option<SimDuration>> {
                match doc.get_f64(sec, key) {
                    None => Ok(None),
                    Some(v) if v.is_finite() && v > 0.0 => {
                        Ok(Some(SimDuration::from_secs_f64(v * 60.0)))
                    }
                    Some(v) => bail!(
                        "{sec}.{key} must be positive and finite, got {v}"
                    ),
                }
            };
            expect.max_makespan = bound_mins("max_makespan_mins")?;
            expect.p95_makespan = bound_mins("p95_makespan_mins")?;
            expect.p95_turnaround = bound_mins("p95_turnaround_mins")?;
            expect.validate()?;
            cfg.expect = Some(expect);
        }

        Ok(cfg)
    }

    pub fn from_str_toml(src: &str) -> Result<Self> {
        let doc = TomlDoc::parse(src).map_err(|e| anyhow::anyhow!("{e}"))?;
        Self::from_toml(&doc)
    }

    /// Parse from a string with an explicit base directory for relative
    /// trace paths — for callers that moved the TOML text away from the
    /// file it came from (the sharded sweep runner copies the scenario
    /// into its run directory but resolves traces against the original
    /// location recorded in `PLAN.json`).
    pub fn from_str_toml_with_base(
        src: &str,
        base: Option<&std::path::Path>,
    ) -> Result<Self> {
        let doc = TomlDoc::parse(src).map_err(|e| anyhow::anyhow!("{e}"))?;
        Self::from_toml_with_base(&doc, base)
    }

    pub fn load(path: &std::path::Path) -> Result<Self> {
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let doc = TomlDoc::parse(&src).map_err(|e| anyhow::anyhow!("{e}"))?;
        // trace files referenced by the scenario live next to it
        Self::from_toml_with_base(&doc, path.parent())
    }

    /// Total uninterrupted virtual duration of the workload.
    pub fn baseline_total(&self) -> SimDuration {
        SimDuration::from_secs(self.workload.stage_secs.iter().sum())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_testbed() {
        let cfg = ScenarioConfig::default();
        assert_eq!(cfg.cloud.vm_size, "Standard_D8s_v3");
        assert_eq!(cfg.cloud.notice.as_secs(), 30);
        assert_eq!(cfg.storage.price_per_100gib_month, 16.0);
        assert_eq!(cfg.workload.ks, vec![33, 55, 77, 99, 127]);
        // Table I row 1 total: 3:03:26
        assert_eq!(cfg.baseline_total().hms(), "3:03:26");
    }

    #[test]
    fn full_scenario_round_trip() {
        let cfg = ScenarioConfig::from_str_toml(
            r#"
name = "row5"
seed = 99

[workload]
kind = "sleeper"
ks = [33, 55]
stage_secs = [100, 200]
total_reads = 4096
app_milestones_per_stage = 3
state_gib = 2.5

[eviction]
plan = "fixed"
interval_mins = 90

[checkpoint]
method = "transparent"
interval_mins = 30

[cloud]
spot = true
notice_secs = 30
provisioning_delay_secs = 120
coordinator_overhead = 0.01

[storage]
bandwidth_mib_s = 100.0
provisioned_gib = 200.0
"#,
        )
        .unwrap();
        assert_eq!(cfg.name, "row5");
        assert_eq!(cfg.workload.kind, "sleeper");
        assert_eq!(
            cfg.eviction,
            EvictionPlanCfg::Fixed { interval: SimDuration::from_mins(90) }
        );
        assert_eq!(
            cfg.checkpoint,
            CheckpointMethodCfg::Transparent {
                interval: SimDuration::from_mins(30)
            }
        );
        assert_eq!(cfg.cloud.provisioning_delay.as_secs(), 120);
        assert_eq!(cfg.storage.provisioned_gib, 200.0);
        assert_eq!(cfg.baseline_total().as_secs(), 300);
    }

    #[test]
    fn trace_eviction_plan() {
        let cfg = ScenarioConfig::from_str_toml(
            "[eviction]\nplan = \"trace\"\noffsets_mins = [10, 25.5, 60]",
        )
        .unwrap();
        match cfg.eviction {
            EvictionPlanCfg::Trace { offsets } => {
                assert_eq!(offsets.len(), 3);
                assert_eq!(offsets[1].as_millis(), 1_530_000);
            }
            other => panic!("wrong plan: {other:?}"),
        }
    }

    #[test]
    fn metrics_level_parses() {
        let cfg = ScenarioConfig::from_str_toml("metrics_level = \"counts\"")
            .unwrap();
        assert_eq!(cfg.metrics, RecordLevel::Counts);
        let cfg = ScenarioConfig::from_str_toml("metrics_level = \"full\"")
            .unwrap();
        assert_eq!(cfg.metrics, RecordLevel::Full);
        assert_eq!(ScenarioConfig::default().metrics, RecordLevel::Full);
        assert!(
            ScenarioConfig::from_str_toml("metrics_level = \"loud\"").is_err()
        );
    }

    #[test]
    fn rejects_bad_configs() {
        assert!(ScenarioConfig::from_str_toml(
            "[workload]\nkind = \"sparkles\""
        )
        .is_err());
        assert!(ScenarioConfig::from_str_toml(
            "[workload]\nks = [1, 2]\nstage_secs = [5]"
        )
        .is_err());
        assert!(ScenarioConfig::from_str_toml(
            "[eviction]\nplan = \"fixed\""
        )
        .is_err());
        assert!(ScenarioConfig::from_str_toml(
            "[checkpoint]\nmethod = \"criu\""
        )
        .is_err());
        assert!(ScenarioConfig::from_str_toml(
            "[cloud]\ncoordinator_overhead = 1.5"
        )
        .is_err());
        assert!(ScenarioConfig::from_str_toml(
            "[storage]\nbandwidth_mib_s = 0.0"
        )
        .is_err());
        assert!(ScenarioConfig::from_str_toml(
            "[storage]\nprovisioned_gib = -5.0"
        )
        .is_err());
        assert!(ScenarioConfig::from_str_toml(
            "[storage]\nprice_per_100gib_month = -16.0"
        )
        .is_err());
    }

    #[test]
    fn checkpoint_adaptive_section_parses() {
        let cfg = ScenarioConfig::from_str_toml(
            r#"
[checkpoint]
method = "transparent"
interval_mins = 30

[checkpoint.adaptive]
controller = "young-daly"
min_interval_mins = 5
max_interval_mins = 90
hysteresis = 0.15
mtbf_prior_mins = 45
"#,
        )
        .unwrap();
        match cfg.adaptive {
            IntervalControllerCfg::YoungDaly {
                prior_mtbf,
                clamp,
                higher_order,
            } => {
                assert_eq!(prior_mtbf, SimDuration::from_mins(45));
                assert_eq!(clamp.min, SimDuration::from_mins(5));
                assert_eq!(clamp.max, SimDuration::from_mins(90));
                assert_eq!(clamp.hysteresis, 0.15);
                assert!(!higher_order, "higher_order defaults off");
            }
            other => panic!("wrong controller: {other:?}"),
        }

        // the higher-order Daly correction is a young-daly knob
        let cfg = ScenarioConfig::from_str_toml(
            "[checkpoint]\nmethod = \"transparent\"\ninterval_mins = 30\n\
             [checkpoint.adaptive]\ncontroller = \"young-daly\"\n\
             higher_order = true\n",
        )
        .unwrap();
        assert!(matches!(
            cfg.adaptive,
            IntervalControllerCfg::YoungDaly { higher_order: true, .. }
        ));

        // cost-aware picks up sensitivity (default 1.0)
        let cfg = ScenarioConfig::from_str_toml(
            "[checkpoint]\nmethod = \"transparent\"\ninterval_mins = 30\n\
             [checkpoint.adaptive]\ncontroller = \"cost-aware\"\n\
             sensitivity = 2.0\n",
        )
        .unwrap();
        match cfg.adaptive {
            IntervalControllerCfg::CostAware { sensitivity, .. } => {
                assert_eq!(sensitivity, 2.0);
            }
            other => panic!("wrong controller: {other:?}"),
        }

        // no section → Fixed, byte-identical to the pre-policy engine
        assert_eq!(
            ScenarioConfig::from_str_toml("name = \"x\"").unwrap().adaptive,
            IntervalControllerCfg::Fixed
        );
        // explicit fixed round-trips
        let cfg = ScenarioConfig::from_str_toml(
            "[checkpoint]\nmethod = \"transparent\"\ninterval_mins = 30\n\
             [checkpoint.adaptive]\ncontroller = \"fixed\"\n",
        )
        .unwrap();
        assert_eq!(cfg.adaptive, IntervalControllerCfg::Fixed);
    }

    #[test]
    fn checkpoint_adaptive_rejects_bad_knobs() {
        let transparent = "[checkpoint]\nmethod = \"transparent\"\n\
                           interval_mins = 30\n";
        // requires the transparent method
        assert!(ScenarioConfig::from_str_toml(
            "[checkpoint.adaptive]\ncontroller = \"young-daly\"\n"
        )
        .is_err());
        assert!(ScenarioConfig::from_str_toml(
            "[checkpoint]\nmethod = \"application\"\n\
             [checkpoint.adaptive]\ncontroller = \"young-daly\"\n"
        )
        .is_err());
        // unknown controller name
        assert!(ScenarioConfig::from_str_toml(&format!(
            "{transparent}[checkpoint.adaptive]\ncontroller = \"daily\"\n"
        ))
        .is_err());
        // zero / negative / inverted interval knobs
        for bad in [
            "min_interval_mins = 0",
            "min_interval_mins = -3",
            "max_interval_mins = 0",
            "mtbf_prior_mins = 0",
            "min_interval_mins = 60\nmax_interval_mins = 5",
            "hysteresis = 1.0",
            "hysteresis = -0.2",
        ] {
            let src = format!(
                "{transparent}[checkpoint.adaptive]\n\
                 controller = \"young-daly\"\n{bad}\n"
            );
            let err = ScenarioConfig::from_str_toml(&src)
                .expect_err(&format!("{bad} must be rejected"));
            assert!(
                err.to_string().contains("checkpoint.adaptive"),
                "{bad}: {err}"
            );
        }
        // sensitivity is a cost-aware-only knob
        assert!(ScenarioConfig::from_str_toml(&format!(
            "{transparent}[checkpoint.adaptive]\n\
             controller = \"young-daly\"\nsensitivity = 2.0\n"
        ))
        .is_err());
        // adaptive knobs on the fixed controller would be silently
        // dropped — rejected instead (incl. when "fixed" is implicit)
        for src in [
            "controller = \"fixed\"\nmin_interval_mins = 5",
            "mtbf_prior_mins = 20",
        ] {
            let err = ScenarioConfig::from_str_toml(&format!(
                "{transparent}[checkpoint.adaptive]\n{src}\n"
            ))
            .expect_err(&format!("{src} must be rejected under fixed"));
            assert!(err.to_string().contains("fixed"), "{src}: {err}");
        }
        assert!(ScenarioConfig::from_str_toml(&format!(
            "{transparent}[checkpoint.adaptive]\n\
             controller = \"cost-aware\"\nsensitivity = 0\n"
        ))
        .is_err());
        // higher_order is young-daly-only (and must be a boolean)
        let err = ScenarioConfig::from_str_toml(&format!(
            "{transparent}[checkpoint.adaptive]\n\
             controller = \"cost-aware\"\nhigher_order = true\n"
        ))
        .unwrap_err();
        assert!(err.to_string().contains("higher_order"), "{err}");
        assert!(ScenarioConfig::from_str_toml(&format!(
            "{transparent}[checkpoint.adaptive]\n\
             controller = \"young-daly\"\nhigher_order = 3\n"
        ))
        .is_err());
        assert!(ScenarioConfig::from_str_toml(&format!(
            "{transparent}[checkpoint.adaptive]\nhigher_order = true\n"
        ))
        .is_err());
    }

    #[test]
    fn fleet_and_pool_sections_parse() {
        let cfg = ScenarioConfig::from_str_toml(
            r#"
[checkpoint]
method = "transparent"
interval_mins = 15
compress = true

[fleet]
placement = "eviction-aware"
penalty = 3.5

[pool.east]
vm_size = "Standard_D8s_v3"
price_factor = 0.85
plan = "fixed"
interval_mins = 5
provisioning_delay_secs = 1200

[pool.west]
price_factor = 1.2
plan = "poisson"
mean_mins = 480
"#,
        )
        .unwrap();
        assert!(cfg.compress_termination);
        assert_eq!(
            cfg.fleet.placement,
            PlacementPolicyCfg::EvictionAware { penalty: 3.5 }
        );
        assert_eq!(cfg.fleet.pools.len(), 2);
        // sections arrive in sorted order: east before west
        let east = &cfg.fleet.pools[0];
        assert_eq!(east.name, "east");
        assert_eq!(east.price_factor, 0.85);
        assert_eq!(east.provisioning_delay.as_secs(), 1200);
        assert_eq!(
            east.eviction,
            EvictionPlanCfg::Fixed { interval: SimDuration::from_mins(5) }
        );
        let west = &cfg.fleet.pools[1];
        assert_eq!(west.name, "west");
        assert!(west.spot);
        assert_eq!(
            west.eviction,
            EvictionPlanCfg::Poisson { mean: SimDuration::from_mins(480) }
        );
        // defaults: no fleet section → empty pools, sticky placement
        let plain = ScenarioConfig::from_str_toml("name = \"x\"").unwrap();
        assert!(plain.fleet.pools.is_empty());
        assert_eq!(plain.fleet.placement, PlacementPolicyCfg::Sticky);
        assert!(!plain.compress_termination);
    }

    #[test]
    fn price_walk_section_parses_and_validates() {
        let cfg = ScenarioConfig::from_str_toml(
            r#"
[fleet]
placement = "cheapest-spot"

[pool.east]
price_factor = 0.9

[pool.east.price_walk]
start = 0.8
volatility = 0.2
step_mins = 45
steps = 8
floor = 0.4
ceil = 1.6

[pool.west]
"#,
        )
        .unwrap();
        assert_eq!(cfg.fleet.pools.len(), 2);
        let east = &cfg.fleet.pools[0];
        assert_eq!(east.name, "east");
        match &east.pricing {
            PoolPricingCfg::Walk(w) => {
                assert_eq!(w.start, 0.8);
                assert_eq!(w.volatility, 0.2);
                assert_eq!(w.interval, SimDuration::from_mins(45));
                assert_eq!(w.steps, 8);
                assert_eq!(w.floor, 0.4);
                assert_eq!(w.ceil, 1.6);
            }
            other => panic!("expected walk pricing: {other:?}"),
        }
        assert_eq!(cfg.fleet.pools[1].pricing, PoolPricingCfg::Static);

        // invalid walk parameters are rejected at parse time
        assert!(ScenarioConfig::from_str_toml(
            "[pool.a]\n[pool.a.price_walk]\nvolatility = 1.5"
        )
        .is_err());
        // steps beyond u32 must error, not silently truncate; huge
        // in-range counts hit the MAX_STEPS cap instead of allocating
        assert!(ScenarioConfig::from_str_toml(
            "[pool.a]\n[pool.a.price_walk]\nsteps = 4294967297"
        )
        .is_err());
        assert!(ScenarioConfig::from_str_toml(
            "[pool.a]\n[pool.a.price_walk]\nsteps = 3000000000"
        )
        .is_err());
        // a walk for a pool that was never declared is rejected
        assert!(ScenarioConfig::from_str_toml(
            "[pool.a.price_walk]\nsteps = 4"
        )
        .is_err());
        // unknown pool subsections are rejected, not silently ignored
        let err = ScenarioConfig::from_str_toml("[pool.a]\n[pool.a.surge]\n")
            .unwrap_err();
        assert!(err.to_string().contains("price_walk"), "{err}");
    }

    #[test]
    fn price_trace_file_parses_with_evictions() {
        let dir = std::env::temp_dir().join("spoton-scenario-trace-test");
        std::fs::create_dir_all(&dir).unwrap();
        let trace_path = dir.join("east.trace");
        std::fs::write(
            &trace_path,
            "price 0 0.8\nprice 80 1.6\nevict 40\nevict 40\n",
        )
        .unwrap();
        let scenario_path = dir.join("scenario.toml");
        std::fs::write(
            &scenario_path,
            "[pool.east]\nprice_trace = \"east.trace\"\n\n[pool.west]\n",
        )
        .unwrap();

        // load() resolves the trace relative to the scenario file
        let cfg = ScenarioConfig::load(&scenario_path).unwrap();
        let east = &cfg.fleet.pools[0];
        match &east.pricing {
            PoolPricingCfg::Trace(t) => {
                assert_eq!(t.points().len(), 2);
                assert_eq!(t.initial_factor(), 0.8);
            }
            other => panic!("expected trace pricing: {other:?}"),
        }
        assert_eq!(
            east.eviction,
            EvictionPlanCfg::Trace {
                offsets: vec![
                    SimDuration::from_mins(40),
                    SimDuration::from_mins(40)
                ]
            }
        );

        // trace-file evictions conflict with an explicit plan
        std::fs::write(
            &scenario_path,
            "[pool.east]\nprice_trace = \"east.trace\"\nplan = \"fixed\"\n\
             interval_mins = 90\n",
        )
        .unwrap();
        let err = ScenarioConfig::load(&scenario_path).unwrap_err();
        assert!(err.to_string().contains("conflict"), "{err}");

        // price_trace conflicts with a price_walk section
        std::fs::write(
            &scenario_path,
            "[pool.east]\nprice_trace = \"east.trace\"\n\
             [pool.east.price_walk]\nsteps = 2\n",
        )
        .unwrap();
        assert!(ScenarioConfig::load(&scenario_path).is_err());

        // a missing trace file is a load error, not a silent default
        std::fs::write(
            &scenario_path,
            "[pool.east]\nprice_trace = \"nonexistent.trace\"\n",
        )
        .unwrap();
        assert!(ScenarioConfig::load(&scenario_path).is_err());
    }

    #[test]
    fn bad_fleet_configs_rejected() {
        assert!(ScenarioConfig::from_str_toml(
            "[fleet]\nplacement = \"round-robin\""
        )
        .is_err());
        assert!(ScenarioConfig::from_str_toml(
            "[fleet]\nplacement = \"eviction-aware\"\npenalty = -2.0"
        )
        .is_err());
        assert!(ScenarioConfig::from_str_toml(
            "[pool.a]\nprice_factor = 0.0"
        )
        .is_err());
        assert!(ScenarioConfig::from_str_toml(
            "[pool.a]\nplan = \"fixed\""
        )
        .is_err());
        // a scenario-level eviction plan would be silently shadowed by
        // explicit pools — rejected as ambiguous
        let err = ScenarioConfig::from_str_toml(
            "[eviction]\nplan = \"fixed\"\ninterval_mins = 90\n\n[pool.a]\n",
        )
        .unwrap_err();
        assert!(err.to_string().contains("conflicts"), "{err}");
    }

    #[test]
    fn pool_capacity_parses_and_validates() {
        let cfg = ScenarioConfig::from_str_toml(
            "[pool.east]\ncapacity = 8\n\n[pool.west]\n",
        )
        .unwrap();
        assert_eq!(cfg.fleet.pools[0].capacity, 8);
        assert_eq!(cfg.fleet.pools[1].capacity, 1, "capacity defaults to 1");
        // zero / negative / oversized / non-integer capacities are parse
        // errors naming the offending key
        for bad in [
            "capacity = 0",
            "capacity = -4",
            "capacity = 4294967296",
            "capacity = 2.5",
        ] {
            let err = ScenarioConfig::from_str_toml(&format!(
                "[pool.east]\n{bad}\n"
            ))
            .expect_err(&format!("{bad} must be rejected"));
            assert!(
                err.to_string().contains("pool.east.capacity"),
                "{bad}: {err}"
            );
        }
    }

    #[test]
    fn cluster_section_parses() {
        let cfg = ScenarioConfig::from_str_toml(
            "[cluster]\njobs = 3\ncapacity = 2\narrival = \"uniform\"\n\
             arrival_spacing_mins = 5\npriorities = [0, 1, 0]\n",
        )
        .unwrap();
        let cluster = cfg.cluster.expect("cluster section parsed");
        assert_eq!(cluster.jobs, ["job-0", "job-1", "job-2"]);
        assert_eq!(cluster.capacity, Some(2));
        assert_eq!(
            cluster.arrival,
            ArrivalCfg::Uniform { spacing: SimDuration::from_mins(5) }
        );
        assert_eq!(cluster.priorities, [0, 1, 0]);
        assert_eq!(cluster.priority(1), 1);
        assert_eq!(cluster.priority(99), 0);

        // explicit names + poisson arrivals
        let cfg = ScenarioConfig::from_str_toml(
            "[cluster]\nnames = [\"align\", \"polish\"]\n\
             arrival = \"poisson\"\narrival_mean_mins = 12\n",
        )
        .unwrap();
        let cluster = cfg.cluster.unwrap();
        assert_eq!(cluster.jobs, ["align", "polish"]);
        assert_eq!(
            cluster.arrival,
            ArrivalCfg::Poisson { mean: SimDuration::from_mins(12) }
        );
        assert!(cluster.priorities.is_empty());

        // no section → no cluster
        assert!(ScenarioConfig::from_str_toml("name = \"x\"")
            .unwrap()
            .cluster
            .is_none());
    }

    #[test]
    fn cluster_section_rejects_bad_knobs() {
        // population is required, single-sourced and positive
        assert!(ScenarioConfig::from_str_toml("[cluster]\n").is_err());
        assert!(ScenarioConfig::from_str_toml(
            "[cluster]\njobs = 2\nnames = [\"a\"]\n"
        )
        .is_err());
        let err =
            ScenarioConfig::from_str_toml("[cluster]\njobs = 0\n").unwrap_err();
        assert!(err.to_string().contains("cluster.jobs"), "{err}");
        assert!(ScenarioConfig::from_str_toml("[cluster]\njobs = -2\n")
            .is_err());
        // duplicate job names are rejected at parse (via validate)
        let err = ScenarioConfig::from_str_toml(
            "[cluster]\nnames = [\"a\", \"b\", \"a\"]\n",
        )
        .unwrap_err();
        assert!(err.to_string().contains("duplicate"), "{err}");
        assert!(err.to_string().contains('a'), "{err}");
        // arrival params must be positive/finite and match the kind
        for bad in [
            "jobs = 2\narrival = \"uniform\"",
            "jobs = 2\narrival = \"uniform\"\narrival_spacing_mins = 0",
            "jobs = 2\narrival = \"uniform\"\narrival_spacing_mins = -5",
            "jobs = 2\narrival = \"poisson\"\narrival_mean_mins = 0",
            "jobs = 2\narrival = \"poisson\"\narrival_spacing_mins = 5",
            "jobs = 2\narrival_spacing_mins = 5",
            "jobs = 2\narrival = \"thundering-herd\"",
        ] {
            let err =
                ScenarioConfig::from_str_toml(&format!("[cluster]\n{bad}\n"))
                    .expect_err(&format!("{bad} must be rejected"));
            assert!(err.to_string().contains("cluster"), "{bad}: {err}");
        }
        // capacity: zero rejected, and with explicit pools it belongs on
        // the pools
        let err = ScenarioConfig::from_str_toml(
            "[cluster]\njobs = 2\ncapacity = 0\n",
        )
        .unwrap_err();
        assert!(err.to_string().contains("cluster.capacity"), "{err}");
        let err = ScenarioConfig::from_str_toml(
            "[cluster]\njobs = 2\ncapacity = 4\n\n[pool.east]\n",
        )
        .unwrap_err();
        assert!(err.to_string().contains("per pool"), "{err}");
        // priorities must cover every job
        let err = ScenarioConfig::from_str_toml(
            "[cluster]\njobs = 3\npriorities = [1]\n",
        )
        .unwrap_err();
        assert!(err.to_string().contains("priorities"), "{err}");
    }

    #[test]
    fn cluster_builder_validation_mirrors_parse() {
        assert!(ClusterCfg::with_count(4).validate().is_ok());
        assert!(ClusterCfg::default().validate().is_err());
        let dup = ClusterCfg {
            jobs: vec!["a".into(), "a".into()],
            ..ClusterCfg::default()
        };
        assert!(dup.validate().unwrap_err().to_string().contains("duplicate"));
        assert!(ClusterCfg::with_count(2)
            .capacity(0)
            .validate()
            .is_err());
        assert!(ClusterCfg::with_count(2)
            .arrival(ArrivalCfg::Uniform { spacing: SimDuration::ZERO })
            .validate()
            .is_err());
        assert!(ClusterCfg::with_count(2)
            .priorities(vec![1, 2, 3])
            .validate()
            .is_err());
        assert!(ClusterCfg::with_count(2)
            .priorities(vec![1, 0])
            .capacity(3)
            .validate()
            .is_ok());
    }

    #[test]
    fn labels() {
        assert_eq!(
            CheckpointMethodCfg::Transparent {
                interval: SimDuration::from_mins(15)
            }
            .label(),
            "transparent/15m"
        );
        assert_eq!(
            EvictionPlanCfg::Fixed { interval: SimDuration::from_mins(60) }
                .label(),
            "every 60 min"
        );
    }

    const TRANSPARENT: &str =
        "[checkpoint]\nmethod = \"transparent\"\ninterval_mins = 15\n";

    #[test]
    fn checkpoint_retain_and_retry_parse() {
        let cfg = ScenarioConfig::from_str_toml(&format!(
            "{TRANSPARENT}retain = 5\n\
             [checkpoint.retry]\nattempts = 3\nbase_ms = 200\nmax_ms = 4000\n\
             factor = 2.5\njitter = 0.5\n"
        ))
        .unwrap();
        assert_eq!(cfg.retain, 5);
        let retry = cfg.retry.unwrap();
        assert_eq!(retry.attempts, 3);
        assert_eq!(retry.base, SimDuration::from_millis(200));
        assert_eq!(retry.max, SimDuration::from_secs(4));
        assert_eq!(retry.factor, 2.5);
        assert_eq!(retry.jitter, 0.5);
        // defaults: retain 3, no retry, no chaos, no expectations
        let cfg = ScenarioConfig::from_str_toml(TRANSPARENT).unwrap();
        assert_eq!(cfg.retain, 3);
        assert_eq!(cfg.retry, None);
        assert_eq!(cfg.chaos, None);
        assert_eq!(cfg.expect, None);
        // bare [checkpoint.retry] picks up the validated defaults
        let cfg = ScenarioConfig::from_str_toml(&format!(
            "{TRANSPARENT}[checkpoint.retry]\n"
        ))
        .unwrap();
        assert_eq!(cfg.retry, Some(BackoffCfg::default()));
    }

    #[test]
    fn checkpoint_retain_and_retry_reject_bad_knobs() {
        // retention k = 0 leaves nothing to restore
        let err = ScenarioConfig::from_str_toml(&format!(
            "{TRANSPARENT}retain = 0\n"
        ))
        .unwrap_err();
        assert!(err.to_string().contains("retain"), "{err}");
        // retain without any checkpointing method is inert
        let err =
            ScenarioConfig::from_str_toml("[checkpoint]\nretain = 2\n")
                .unwrap_err();
        assert!(err.to_string().contains("no effect"), "{err}");
        // retry without a checkpointing method is inert
        let err =
            ScenarioConfig::from_str_toml("[checkpoint.retry]\nattempts = 2\n")
                .unwrap_err();
        assert!(err.to_string().contains("checkpoint.retry"), "{err}");
        for bad in [
            "attempts = 0",
            "base_ms = 0",
            "base_ms = -5",
            "base_ms = 1e400", // overflows to +inf
            "max_ms = 0",
            "base_ms = 500\nmax_ms = 100", // inverted bounds
            "factor = 0.5",                // shrinking delays
            "factor = 1e400",
            "jitter = 1.5",
            "jitter = -0.1",
            "factor = 1.1\njitter = 0.5", // factor < 1 + jitter
        ] {
            let src = format!("{TRANSPARENT}[checkpoint.retry]\n{bad}\n");
            let err = ScenarioConfig::from_str_toml(&src)
                .expect_err(&format!("accepted: {bad}"));
            assert!(
                err.to_string().contains("checkpoint.retry"),
                "error for {bad:?} should name the section: {err}"
            );
        }
        // NaN can't be written in TOML; the build-side validator is the
        // line of defence for programmatic configs.
        let nan = BackoffCfg { jitter: f64::NAN, ..BackoffCfg::default() };
        assert!(nan.validate().is_err());
        let nan = BackoffCfg { factor: f64::NAN, ..BackoffCfg::default() };
        assert!(nan.validate().is_err());
        assert!(BackoffCfg::default().validate().is_ok());
    }

    #[test]
    fn chaos_section_parses() {
        let cfg = ScenarioConfig::from_str_toml(&format!(
            "{TRANSPARENT}\
             [chaos]\nsalt = 99\nstorms = 2\nwindow_mins = 120\n\
             [chaos.storage]\nwrite_fail_prob = 0.1\ntorn_write_prob = 0.05\n\
             corrupt_prob = 0.02\nlatency_spike_prob = 0.2\n\
             latency_spike_ms = 1500\n\
             [chaos.imds]\noutages = 2\noutage_mins = 2.5\n\
             degraded_poll_factor = 4\n"
        ))
        .unwrap();
        let chaos = cfg.chaos.unwrap();
        assert_eq!(chaos.salt, 99);
        assert_eq!(chaos.storms, 2);
        assert_eq!(chaos.window, SimDuration::from_mins(120));
        assert_eq!(chaos.storage.write_fail_prob, 0.1);
        assert_eq!(chaos.storage.latency_spike, SimDuration::from_millis(1500));
        assert_eq!(chaos.imds.outages, 2);
        assert_eq!(chaos.imds.outage_duration, SimDuration::from_secs(150));
        assert_eq!(chaos.imds.degraded_poll_factor, 4);
        // a subsection alone enables chaos with parent defaults
        let cfg = ScenarioConfig::from_str_toml(
            "[chaos.storage]\nwrite_fail_prob = 0.3\n",
        )
        .unwrap();
        assert_eq!(cfg.chaos.unwrap().storage.write_fail_prob, 0.3);
    }

    #[test]
    fn chaos_section_rejects_bad_knobs() {
        for bad in [
            "[chaos.storage]\nwrite_fail_prob = -0.1\n",
            "[chaos.storage]\nwrite_fail_prob = 1.5\n",
            "[chaos.storage]\ncorrupt_prob = 1e400\n",
            "[chaos.storage]\nlatency_spike_prob = 0.5\nlatency_spike_ms = 0\n",
            "[chaos]\nstorms = 1\nwindow_mins = 0\n",
            "[chaos]\nwindow_mins = -3\n",
            "[chaos.imds]\noutages = 1\noutage_mins = 0\n",
            "[chaos.imds]\ndegraded_poll_factor = 1\n",
            "[chaos.bogus]\nx = 1\n",
        ] {
            let err = ScenarioConfig::from_str_toml(bad)
                .expect_err(&format!("accepted: {bad}"));
            assert!(
                err.to_string().contains("chaos"),
                "error for {bad:?} should name the section: {err}"
            );
        }
        // build-side validation mirrors the parse
        let mut chaos = ChaosCfg::default();
        chaos.storage.corrupt_prob = f64::NAN;
        assert!(chaos.validate().is_err());
        let mut chaos = ChaosCfg::default();
        chaos.imds.degraded_poll_factor = 0;
        assert!(chaos.validate().is_err());
        assert!(ChaosCfg::default().validate().is_ok());
    }

    #[test]
    fn expect_section_parses() {
        let cfg = ScenarioConfig::from_str_toml(
            "[expect]\nseeds = 16\nmust_complete = true\n\
             max_lost_steps = 40000\nmax_cost = 2.5\n\
             max_makespan_mins = 600\np95_makespan_mins = 480\n\
             p95_turnaround_mins = 500\nmax_restore_fallbacks = 4\n\
             max_unrecovered_restores = 0\nzero_dead_letter = true\n",
        )
        .unwrap();
        let expect = cfg.expect.unwrap();
        assert_eq!(expect.seeds, 16);
        assert!(expect.must_complete);
        assert!(expect.zero_dead_letter);
        assert_eq!(expect.max_lost_steps, Some(40_000));
        assert_eq!(expect.max_cost, Some(2.5));
        assert_eq!(expect.max_makespan, Some(SimDuration::from_mins(600)));
        assert_eq!(expect.p95_makespan, Some(SimDuration::from_mins(480)));
        assert_eq!(expect.p95_turnaround, Some(SimDuration::from_mins(500)));
        assert_eq!(expect.max_restore_fallbacks, Some(4));
        assert_eq!(expect.max_unrecovered_restores, Some(0));
    }

    #[test]
    fn expect_section_rejects_bad_knobs() {
        for bad in [
            "[expect]\n",                     // vacuously green
            "[expect]\nseeds = 4\n",          // still no bounds
            "[expect]\nseeds = 0\nmust_complete = true\n",
            "[expect]\nmust_complete = 3\n",  // not a boolean
            "[expect]\nmax_cost = -1.0\n",
            "[expect]\nmax_cost = 1e400\n",
            "[expect]\nmax_makespan_mins = 0\n",
            "[expect]\np95_makespan_mins = -2\n",
            "[expect]\nmax_lost_steps = -4\n",
        ] {
            let err = ScenarioConfig::from_str_toml(bad)
                .expect_err(&format!("accepted: {bad}"));
            assert!(
                err.to_string().contains("expect"),
                "error for {bad:?} should name the section: {err}"
            );
        }
    }

    #[test]
    fn pool_kind_and_bid_parse() {
        let cfg = ScenarioConfig::from_str_toml(
            "[fleet]\nplacement = \"cheapest-spot\"\n\
             [pool.east]\nkind = \"spot\"\ncapacity = 4\nbid = 0.2\n\
             [pool.east.price_walk]\nstart = 1.0\n\
             [pool.ondemand]\nkind = \"on-demand\"\ncapacity = 2\n",
        )
        .unwrap();
        let pools = &cfg.fleet.pools;
        assert_eq!(pools.len(), 2);
        assert!(pools[0].spot);
        assert_eq!(pools[0].bid, Some(0.2));
        assert_eq!(pools[0].capacity, 4);
        assert!(matches!(pools[0].pricing, PoolPricingCfg::Walk(_)));
        assert!(!pools[1].spot);
        assert_eq!(pools[1].bid, None);
        assert_eq!(pools[1].capacity, 2);
        assert!(matches!(pools[1].pricing, PoolPricingCfg::Static));
    }

    #[test]
    fn pool_kind_rejects_contradictions() {
        for bad in [
            // kind is sugar over spot: giving both is ambiguous
            "[pool.a]\nkind = \"spot\"\nspot = true\n",
            "[pool.a]\nkind = \"balloon\"\n",
            // a strict on-demand pool never evicts and its price never
            // moves — the knobs below contradict it
            "[pool.a]\nkind = \"on-demand\"\nbid = 0.1\n",
            "[pool.a]\nkind = \"on-demand\"\nplan = \"fixed\"\n",
            "[pool.a]\nkind = \"on-demand\"\nprice_trace = \"x.trace\"\n",
            "[pool.a]\nkind = \"on-demand\"\n[pool.a.price_walk]\n",
        ] {
            let err = ScenarioConfig::from_str_toml(bad)
                .expect_err(&format!("accepted: {bad}"));
            assert!(
                err.to_string().contains("kind")
                    || err.to_string().contains("on-demand"),
                "error for {bad:?} should explain the kind rule: {err}"
            );
        }
    }

    #[test]
    fn pool_bid_rejects_bad_values() {
        for bad in [
            "[pool.a]\nbid = 0.0\n[pool.a.price_walk]\nstart = 1.0\n",
            "[pool.a]\nbid = -0.5\n[pool.a.price_walk]\nstart = 1.0\n",
            "[pool.a]\nbid = 1e400\n[pool.a.price_walk]\nstart = 1.0\n",
            // bids only mean something where an auction can be lost
            "[pool.a]\nspot = false\nbid = 0.1\n\
             [pool.a.price_walk]\nstart = 1.0\n",
            // and only where the price can actually move
            "[pool.a]\nbid = 0.1\n",
        ] {
            let err = ScenarioConfig::from_str_toml(bad)
                .expect_err(&format!("accepted: {bad}"));
            assert!(
                err.to_string().contains("bid"),
                "error for {bad:?} should name the bid: {err}"
            );
        }
    }

    #[test]
    fn job_section_parses_and_rejects() {
        let cfg =
            ScenarioConfig::from_str_toml("[job]\ndeadline_mins = 360\n")
                .unwrap();
        assert_eq!(cfg.job_deadline, Some(SimDuration::from_mins(360)));
        assert_eq!(
            ScenarioConfig::from_str_toml("name = \"x\"").unwrap().job_deadline,
            None
        );
        for bad in [
            "[job]\n",
            "[job]\ndeadline_mins = 0\n",
            "[job]\ndeadline_mins = -5\n",
            "[job]\ndeadline_mins = 1e400\n",
        ] {
            let err = ScenarioConfig::from_str_toml(bad)
                .expect_err(&format!("accepted: {bad}"));
            assert!(
                err.to_string().contains("deadline_mins"),
                "error for {bad:?} should name the knob: {err}"
            );
        }
    }

    /// A hybrid fleet + cluster + SLA skeleton the `[autoscale]` tests
    /// graft different autoscale bodies onto.
    fn hybrid_scenario(autoscale: &str) -> String {
        format!(
            "[fleet]\nplacement = \"cheapest-spot\"\n\
             [pool.east]\ncapacity = 4\n\
             [pool.east.price_walk]\nstart = 1.0\n\
             [pool.ondemand]\nkind = \"on-demand\"\ncapacity = 4\n\
             [cluster]\njobs = 4\n\
             [job]\ndeadline_mins = 240\n\
             {autoscale}"
        )
    }

    #[test]
    fn autoscale_section_parses() {
        let cfg = ScenarioConfig::from_str_toml(&hybrid_scenario(
            "[autoscale]\npolicy = \"percentile\"\npercentile = 0.25\n\
             on_demand_pool = \"ondemand\"\nslack_mins = 45\nmax_queue = 6\n",
        ))
        .unwrap();
        let auto = cfg.autoscale.unwrap();
        assert_eq!(auto.policy, BidPolicyCfg::Percentile { q: 0.25 });
        assert_eq!(auto.on_demand_pool, "ondemand");
        assert_eq!(auto.slack, SimDuration::from_mins(45));
        assert_eq!(auto.max_queue, 6);

        // policy knobs default per policy; slack/max_queue globally
        let cfg = ScenarioConfig::from_str_toml(&hybrid_scenario(
            "[autoscale]\npolicy = \"fixed-margin\"\n\
             on_demand_pool = \"ondemand\"\n",
        ))
        .unwrap();
        let auto = cfg.autoscale.unwrap();
        assert_eq!(auto.policy, BidPolicyCfg::FixedMargin { margin: 0.5 });
        assert_eq!(auto.slack, SimDuration::from_mins(60));
        assert_eq!(auto.max_queue, 4);

        let cfg = ScenarioConfig::from_str_toml(&hybrid_scenario(
            "[autoscale]\npolicy = \"reliability\"\nmargin = 0.3\n\
             reliability_weight = 2.0\non_demand_pool = \"ondemand\"\n",
        ))
        .unwrap();
        assert_eq!(
            cfg.autoscale.unwrap().policy,
            BidPolicyCfg::Reliability { margin: 0.3, weight: 2.0 }
        );
    }

    #[test]
    fn autoscale_section_rejects_bad_knobs() {
        let cases: Vec<String> = vec![
            // the autoscaler drives cluster placement over an SLA: both
            // the [cluster] and the [job] deadline must exist
            "[autoscale]\npolicy = \"percentile\"\n\
             on_demand_pool = \"x\"\n"
                .to_string(),
            "[pool.od]\nkind = \"on-demand\"\n[cluster]\njobs = 2\n\
             [autoscale]\npolicy = \"percentile\"\n\
             on_demand_pool = \"od\"\n"
                .to_string(),
            hybrid_scenario("[autoscale]\non_demand_pool = \"ondemand\"\n"),
            hybrid_scenario(
                "[autoscale]\npolicy = \"greedy\"\n\
                 on_demand_pool = \"ondemand\"\n",
            ),
            // inert knobs are rejected per policy
            hybrid_scenario(
                "[autoscale]\npolicy = \"fixed-margin\"\npercentile = 0.5\n\
                 on_demand_pool = \"ondemand\"\n",
            ),
            hybrid_scenario(
                "[autoscale]\npolicy = \"percentile\"\nmargin = 0.5\n\
                 on_demand_pool = \"ondemand\"\n",
            ),
            hybrid_scenario(
                "[autoscale]\npolicy = \"reliability\"\npercentile = 0.5\n\
                 on_demand_pool = \"ondemand\"\n",
            ),
            // the fallback must exist, and must really be on-demand
            hybrid_scenario("[autoscale]\npolicy = \"percentile\"\n"),
            hybrid_scenario(
                "[autoscale]\npolicy = \"percentile\"\n\
                 on_demand_pool = \"nope\"\n",
            ),
            hybrid_scenario(
                "[autoscale]\npolicy = \"percentile\"\n\
                 on_demand_pool = \"east\"\n",
            ),
            hybrid_scenario(
                "[autoscale]\npolicy = \"percentile\"\n\
                 on_demand_pool = \"ondemand\"\nslack_mins = 0\n",
            ),
            hybrid_scenario(
                "[autoscale]\npolicy = \"percentile\"\n\
                 on_demand_pool = \"ondemand\"\nmax_queue = 0\n",
            ),
        ];
        for bad in &cases {
            let err = ScenarioConfig::from_str_toml(bad)
                .expect_err(&format!("accepted: {bad}"));
            assert!(
                err.to_string().contains("autoscale")
                    || err.to_string().contains("on_demand_pool"),
                "error for {bad:?} should name the section: {err}"
            );
        }
        // a permissive `spot = false` fallback still may not carry price
        // dynamics
        let bad = "[fleet]\nplacement = \"cheapest-spot\"\n\
                   [pool.east]\ncapacity = 4\n\
                   [pool.east.price_walk]\nstart = 1.0\n\
                   [pool.od]\nspot = false\n\
                   [pool.od.price_walk]\nstart = 1.0\n\
                   [cluster]\njobs = 4\n[job]\ndeadline_mins = 240\n\
                   [autoscale]\npolicy = \"percentile\"\n\
                   on_demand_pool = \"od\"\n";
        let err = ScenarioConfig::from_str_toml(bad).unwrap_err();
        assert!(err.to_string().contains("price dynamics"), "{err}");
    }

    #[test]
    fn chaos_market_parses_and_rejects() {
        let cfg = ScenarioConfig::from_str_toml(
            "[pool.east]\n[pool.east.price_walk]\nstart = 1.0\n\
             [chaos.market]\nshocks = 2\nfactor = 1.4\n\
             duration_mins = 20\n",
        )
        .unwrap();
        let market = cfg.chaos.unwrap().market;
        assert_eq!(market.shocks, 2);
        assert_eq!(market.factor, 1.4);
        assert_eq!(market.duration, SimDuration::from_mins(20));
        // a shock is a *spike*: the factor must exceed 1
        for bad_factor in ["1.0", "0.5", "-2.0", "1e400"] {
            let err = ScenarioConfig::from_str_toml(&format!(
                "[pool.east]\n[pool.east.price_walk]\nstart = 1.0\n\
                 [chaos.market]\nshocks = 1\nfactor = {bad_factor}\n"
            ))
            .expect_err(&format!("accepted factor {bad_factor}"));
            assert!(err.to_string().contains("factor"), "{err}");
        }
        // shocks against static-only pricing are inert
        let err = ScenarioConfig::from_str_toml(
            "[pool.east]\n[chaos.market]\nshocks = 1\n",
        )
        .unwrap_err();
        assert!(err.to_string().contains("traced or walked"), "{err}");
        // shocks = 0 with moving prices is a valid (inert) baseline
        let cfg = ScenarioConfig::from_str_toml(
            "[pool.east]\n[pool.east.price_walk]\nstart = 1.0\n\
             [chaos.market]\nshocks = 0\n",
        )
        .unwrap();
        assert_eq!(cfg.chaos.unwrap().market.shocks, 0);
    }

    #[test]
    fn expect_deadline_bounds_require_a_job_deadline() {
        for bad in [
            "[expect]\nmax_deadline_misses = 0\n",
            "[expect]\nmin_sla_attainment = 0.99\n",
        ] {
            let err = ScenarioConfig::from_str_toml(bad)
                .expect_err(&format!("accepted: {bad}"));
            assert!(err.to_string().contains("deadline_mins"), "{err}");
        }
        for bad_frac in ["1.5", "-0.1", "1e400"] {
            let err = ScenarioConfig::from_str_toml(&format!(
                "[job]\ndeadline_mins = 100\n\
                 [expect]\nmin_sla_attainment = {bad_frac}\n"
            ))
            .expect_err(&format!("accepted fraction {bad_frac}"));
            assert!(err.to_string().contains("min_sla_attainment"), "{err}");
        }
        let cfg = ScenarioConfig::from_str_toml(
            "[job]\ndeadline_mins = 100\n\
             [expect]\nseeds = 2\nmax_deadline_misses = 1\n\
             min_sla_attainment = 0.9\n",
        )
        .unwrap();
        let expect = cfg.expect.unwrap();
        assert_eq!(expect.seeds, 2);
        assert_eq!(expect.max_deadline_misses, Some(1));
        assert_eq!(expect.min_sla_attainment, Some(0.9));
    }
}
