//! TOML-subset parser (offline build: no `toml` crate — DESIGN.md §8).
//!
//! Supported: `[section]` / `[a.b]` headers, `key = value` pairs with
//! string / integer / float / boolean / flat-array values, `#` comments,
//! bare and quoted keys. Deliberately omitted: dates, inline tables,
//! multiline strings, array-of-tables — the scenario schema doesn't need
//! them, and a smaller grammar is easier to validate exhaustively.

use std::collections::BTreeMap;
use std::fmt;

/// A scalar or flat array value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(v) => Some(*v),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_i64().and_then(|v| u64::try_from(v).ok())
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(v) => Some(*v),
            TomlValue::Int(v) => Some(*v as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// A parsed document: dotted section path -> key -> value. Root-level keys
/// live under the empty path `""`.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct TomlDoc {
    pub sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

#[derive(Debug, PartialEq)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml parse error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

impl TomlDoc {
    pub fn parse(src: &str) -> Result<TomlDoc, TomlError> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        doc.sections.entry(section.clone()).or_default();
        for (idx, raw) in src.lines().enumerate() {
            let line_no = idx + 1;
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or(TomlError {
                    line: line_no,
                    msg: "unterminated section header".into(),
                })?;
                let name = name.trim();
                if name.is_empty()
                    || !name.split('.').all(|p| is_bare_key(p.trim()))
                {
                    return Err(TomlError {
                        line: line_no,
                        msg: format!("bad section name '{name}'"),
                    });
                }
                section = name
                    .split('.')
                    .map(|p| p.trim())
                    .collect::<Vec<_>>()
                    .join(".");
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let eq = line.find('=').ok_or(TomlError {
                line: line_no,
                msg: "expected 'key = value'".into(),
            })?;
            let key = line[..eq].trim();
            let key = parse_key(key).ok_or(TomlError {
                line: line_no,
                msg: format!("bad key '{key}'"),
            })?;
            let (value, rest) =
                parse_value(line[eq + 1..].trim()).map_err(|msg| TomlError {
                    line: line_no,
                    msg,
                })?;
            if !rest.trim().is_empty() {
                return Err(TomlError {
                    line: line_no,
                    msg: format!("trailing garbage '{rest}'"),
                });
            }
            let sec = doc.sections.entry(section.clone()).or_default();
            if sec.contains_key(&key) {
                return Err(TomlError {
                    line: line_no,
                    msg: format!("duplicate key '{key}'"),
                });
            }
            sec.insert(key, value);
        }
        Ok(doc)
    }

    /// Look up `section` + `key` (section `""` = root).
    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section)?.get(key)
    }

    pub fn get_str(&self, section: &str, key: &str) -> Option<&str> {
        self.get(section, key)?.as_str()
    }

    pub fn get_u64(&self, section: &str, key: &str) -> Option<u64> {
        self.get(section, key)?.as_u64()
    }

    pub fn get_f64(&self, section: &str, key: &str) -> Option<f64> {
        self.get(section, key)?.as_f64()
    }

    pub fn get_bool(&self, section: &str, key: &str) -> Option<bool> {
        self.get(section, key)?.as_bool()
    }

    pub fn has_section(&self, section: &str) -> bool {
        self.sections.contains_key(section)
    }
}

impl fmt::Display for TomlDoc {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (sec, kv) in &self.sections {
            if kv.is_empty() && sec.is_empty() {
                continue;
            }
            if !sec.is_empty() {
                writeln!(f, "[{sec}]")?;
            }
            for (k, v) in kv {
                writeln!(f, "{k} = {}", render(v))?;
            }
        }
        Ok(())
    }
}

fn render(v: &TomlValue) -> String {
    match v {
        TomlValue::Str(s) => format!("{:?}", s),
        TomlValue::Int(i) => i.to_string(),
        TomlValue::Float(x) => {
            if x.fract() == 0.0 {
                format!("{x:.1}")
            } else {
                x.to_string()
            }
        }
        TomlValue::Bool(b) => b.to_string(),
        TomlValue::Array(items) => {
            let inner: Vec<String> = items.iter().map(render).collect();
            format!("[{}]", inner.join(", "))
        }
    }
}

fn strip_comment(line: &str) -> &str {
    // A '#' outside a quoted string starts a comment.
    let mut in_str = false;
    let mut escaped = false;
    for (i, c) in line.char_indices() {
        if in_str {
            if escaped {
                escaped = false;
            } else if c == '\\' {
                escaped = true;
            } else if c == '"' {
                in_str = false;
            }
        } else if c == '"' {
            in_str = true;
        } else if c == '#' {
            return &line[..i];
        }
    }
    line
}

fn is_bare_key(s: &str) -> bool {
    !s.is_empty()
        && s.chars()
            .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
}

fn parse_key(s: &str) -> Option<String> {
    if let Some(stripped) = s.strip_prefix('"') {
        let inner = stripped.strip_suffix('"')?;
        if inner.is_empty() {
            return None;
        }
        return Some(inner.to_string());
    }
    if is_bare_key(s) {
        Some(s.to_string())
    } else {
        None
    }
}

/// Parse one value from the front of `s`; return (value, rest).
fn parse_value(s: &str) -> Result<(TomlValue, &str), String> {
    let s = s.trim_start();
    if s.is_empty() {
        return Err("missing value".into());
    }
    if let Some(rest) = s.strip_prefix('[') {
        let mut items = Vec::new();
        let mut cur = rest.trim_start();
        if let Some(r) = cur.strip_prefix(']') {
            return Ok((TomlValue::Array(items), r));
        }
        loop {
            let (v, rest) = parse_value(cur)?;
            items.push(v);
            cur = rest.trim_start();
            if let Some(r) = cur.strip_prefix(',') {
                cur = r.trim_start();
                if let Some(r2) = cur.strip_prefix(']') {
                    // allow trailing comma
                    return Ok((TomlValue::Array(items), r2));
                }
                continue;
            }
            if let Some(r) = cur.strip_prefix(']') {
                return Ok((TomlValue::Array(items), r));
            }
            return Err("expected ',' or ']' in array".into());
        }
    }
    if let Some(rest) = s.strip_prefix('"') {
        let mut out = String::new();
        let mut chars = rest.char_indices();
        while let Some((i, c)) = chars.next() {
            match c {
                '"' => return Ok((TomlValue::Str(out), &rest[i + 1..])),
                '\\' => match chars.next() {
                    Some((_, 'n')) => out.push('\n'),
                    Some((_, 't')) => out.push('\t'),
                    Some((_, 'r')) => out.push('\r'),
                    Some((_, '"')) => out.push('"'),
                    Some((_, '\\')) => out.push('\\'),
                    other => {
                        return Err(format!("bad escape {other:?}"));
                    }
                },
                c => out.push(c),
            }
        }
        return Err("unterminated string".into());
    }
    // bare scalar: read until delimiter
    let end = s
        .find(|c: char| c == ',' || c == ']' || c.is_whitespace())
        .unwrap_or(s.len());
    let (tok, rest) = s.split_at(end);
    let v = match tok {
        "true" => TomlValue::Bool(true),
        "false" => TomlValue::Bool(false),
        _ => {
            if tok.contains('.') || tok.contains('e') || tok.contains('E') {
                TomlValue::Float(
                    tok.parse::<f64>().map_err(|_| format!("bad float '{tok}'"))?,
                )
            } else {
                TomlValue::Int(
                    tok.parse::<i64>().map_err(|_| format!("bad int '{tok}'"))?,
                )
            }
        }
    };
    Ok((v, rest))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let doc = TomlDoc::parse(
            r#"
# experiment scenario
name = "table1-row5"
seed = 42

[eviction]
plan = "fixed"
interval_mins = 90
enabled = true
jitter = 0.25

[checkpoint.transparent]
interval_mins = 30
"#,
        )
        .unwrap();
        assert_eq!(doc.get_str("", "name"), Some("table1-row5"));
        assert_eq!(doc.get_u64("", "seed"), Some(42));
        assert_eq!(doc.get_str("eviction", "plan"), Some("fixed"));
        assert_eq!(doc.get_u64("eviction", "interval_mins"), Some(90));
        assert_eq!(doc.get_bool("eviction", "enabled"), Some(true));
        assert_eq!(doc.get_f64("eviction", "jitter"), Some(0.25));
        assert_eq!(
            doc.get_u64("checkpoint.transparent", "interval_mins"),
            Some(30)
        );
    }

    #[test]
    fn arrays() {
        let doc = TomlDoc::parse("ks = [33, 55, 77]\nnames = [\"a\", \"b\"]\nempty = []\ntrail = [1, 2,]")
            .unwrap();
        let ks: Vec<i64> = doc
            .get("", "ks")
            .unwrap()
            .as_array()
            .unwrap()
            .iter()
            .map(|v| v.as_i64().unwrap())
            .collect();
        assert_eq!(ks, [33, 55, 77]);
        assert_eq!(doc.get("", "empty").unwrap().as_array().unwrap().len(), 0);
        assert_eq!(doc.get("", "trail").unwrap().as_array().unwrap().len(), 2);
    }

    #[test]
    fn comments_and_strings_with_hash() {
        let doc =
            TomlDoc::parse("a = \"x # not a comment\" # real comment\n").unwrap();
        assert_eq!(doc.get_str("", "a"), Some("x # not a comment"));
    }

    #[test]
    fn string_escapes() {
        let doc = TomlDoc::parse(r#"s = "a\nb\t\"c\\""#).unwrap();
        assert_eq!(doc.get_str("", "s"), Some("a\nb\t\"c\\"));
    }

    #[test]
    fn negative_and_float_numbers() {
        let doc = TomlDoc::parse("a = -5\nb = -2.5\nc = 1e3").unwrap();
        assert_eq!(doc.get("", "a").unwrap().as_i64(), Some(-5));
        assert_eq!(doc.get_f64("", "b"), Some(-2.5));
        assert_eq!(doc.get_f64("", "c"), Some(1000.0));
    }

    #[test]
    fn rejects_malformed() {
        for bad in [
            "[unclosed",
            "[]",
            "[a..b]",
            "novalue =",
            "= 5",
            "a = 1 2",
            "a = \"unterminated",
            "a = [1, 2",
            "dup = 1\ndup = 2",
            "a = @",
            "a b = 1",
        ] {
            assert!(TomlDoc::parse(bad).is_err(), "should reject: {bad}");
        }
    }

    #[test]
    fn display_round_trips() {
        let src = r#"
root_key = 5
[a]
s = "hi"
f = 2.5
g = 4.0
arr = [1, 2]
[b.c]
flag = false
"#;
        let doc = TomlDoc::parse(src).unwrap();
        let rendered = doc.to_string();
        let re = TomlDoc::parse(&rendered).unwrap();
        assert_eq!(doc, re);
    }

    #[test]
    fn quoted_keys() {
        let doc = TomlDoc::parse("\"weird key\" = 1").unwrap();
        assert_eq!(doc.get_u64("", "weird key"), Some(1));
    }
}
