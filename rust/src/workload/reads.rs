//! Synthetic metagenome read generation.
//!
//! The paper's dataset (50 M reads from a wastewater-treatment-plant
//! metagenome, ~4 GiB) is not redistributable here; this generator is the
//! documented substitution (DESIGN.md §2): G reference genomes with a
//! skewed abundance distribution, error-bearing reads sampled from them,
//! padded to a fixed row length with the invalid-base sentinel.
//!
//! Crucially, reads are a **pure function of (seed, chunk index)** — like
//! the input FASTQ on disk, they are *not* checkpoint state. A restarted
//! instance regenerates any chunk bit-identically, which the resume tests
//! rely on.

use crate::util::Prng;

/// Base encoding: 0..3 = ACGT, 4 = N / padding (masked by the kernels).
pub const BASE_INVALID: u8 = 4;

/// Generator configuration.
#[derive(Debug, Clone)]
pub struct ReadGenCfg {
    pub seed: u64,
    /// Number of reference genomes in the community.
    pub genomes: usize,
    /// Length of each reference genome.
    pub genome_len: usize,
    /// Emitted read length (bases; rows are padded to `row_len`).
    pub read_len: usize,
    /// Row length (the kernel's L; `read_len <= row_len`).
    pub row_len: usize,
    /// Per-base substitution error rate.
    pub error_rate: f64,
    /// Fraction of bases replaced by N (sequencer no-calls).
    pub n_rate: f64,
}

impl Default for ReadGenCfg {
    fn default() -> Self {
        Self {
            seed: 2022,
            genomes: 12,
            genome_len: 20_000,
            read_len: 150,
            row_len: 160,
            error_rate: 0.005,
            n_rate: 0.002,
        }
    }
}

/// Deterministic metagenome read source.
#[derive(Debug, Clone)]
pub struct ReadGen {
    cfg: ReadGenCfg,
    genomes: Vec<Vec<u8>>,
    /// Cumulative abundance distribution over genomes (skewed, like real
    /// communities: abundance_i ∝ 1/(i+1)).
    cdf: Vec<f64>,
}

impl ReadGen {
    pub fn new(cfg: ReadGenCfg) -> Self {
        assert!(cfg.read_len <= cfg.row_len, "read_len > row_len");
        assert!(cfg.genomes > 0 && cfg.genome_len > cfg.read_len);
        let mut rng = Prng::new(cfg.seed ^ 0x6E0A_57A1);
        let genomes: Vec<Vec<u8>> = (0..cfg.genomes)
            .map(|_| {
                (0..cfg.genome_len).map(|_| rng.below(4) as u8).collect()
            })
            .collect();
        let weights: Vec<f64> =
            (0..cfg.genomes).map(|i| 1.0 / (i as f64 + 1.0)).collect();
        let total: f64 = weights.iter().sum();
        let mut acc = 0.0;
        let cdf = weights
            .iter()
            .map(|w| {
                acc += w / total;
                acc
            })
            .collect();
        Self { cfg, genomes, cdf }
    }

    pub fn cfg(&self) -> &ReadGenCfg {
        &self.cfg
    }

    /// Generate read `index` (pure function of seed + index).
    pub fn read(&self, index: u64) -> Vec<u8> {
        let mut rng = Prng::new(
            self.cfg.seed ^ index.wrapping_mul(0x2545F4914F6CDD1D),
        );
        // pick a genome by abundance
        let u = rng.f64();
        let g = self
            .cdf
            .iter()
            .position(|&c| u <= c)
            .unwrap_or(self.genomes.len() - 1);
        let genome = &self.genomes[g];
        let start =
            rng.below((genome.len() - self.cfg.read_len) as u64 + 1) as usize;
        let mut row = Vec::with_capacity(self.cfg.row_len);
        for i in 0..self.cfg.read_len {
            let mut base = genome[start + i];
            if rng.chance(self.cfg.error_rate) {
                // substitution to a different base
                base = ((base as u64 + 1 + rng.below(3)) % 4) as u8;
            }
            if rng.chance(self.cfg.n_rate) {
                base = BASE_INVALID;
            }
            row.push(base);
        }
        row.resize(self.cfg.row_len, BASE_INVALID);
        row
    }

    /// Generate a chunk of `count` reads starting at read `first`,
    /// flattened row-major as i32 (the kernel input layout).
    pub fn chunk_i32(&self, first: u64, count: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(count * self.cfg.row_len);
        for r in 0..count {
            for &b in &self.read(first + r as u64) {
                out.push(b as i32);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_index() {
        let g = ReadGen::new(ReadGenCfg::default());
        let g2 = ReadGen::new(ReadGenCfg::default());
        for idx in [0u64, 1, 999, 123_456_789] {
            assert_eq!(g.read(idx), g2.read(idx), "read {idx}");
        }
        // and chunk == concatenation of reads
        let chunk = g.chunk_i32(10, 3);
        assert_eq!(chunk.len(), 3 * 160);
        let manual: Vec<i32> = (10..13)
            .flat_map(|i| g.read(i).into_iter().map(|b| b as i32))
            .collect();
        assert_eq!(chunk, manual);
    }

    #[test]
    fn different_seeds_differ() {
        let a = ReadGen::new(ReadGenCfg::default());
        let b = ReadGen::new(ReadGenCfg { seed: 9999, ..ReadGenCfg::default() });
        assert_ne!(a.read(0), b.read(0));
    }

    #[test]
    fn rows_padded_with_invalid() {
        let g = ReadGen::new(ReadGenCfg::default());
        let row = g.read(5);
        assert_eq!(row.len(), 160);
        assert!(row[150..].iter().all(|&b| b == BASE_INVALID));
        // payload is mostly valid bases
        let valid = row[..150].iter().filter(|&&b| b < 4).count();
        assert!(valid > 140, "too many Ns: {valid}");
    }

    #[test]
    fn abundance_is_skewed() {
        // genome 0 (weight 1) should yield clearly more reads than genome
        // 11 (weight 1/12). We can't observe the genome directly; instead
        // check reproducibility of the cdf shape.
        let g = ReadGen::new(ReadGenCfg::default());
        assert!(g.cdf[0] > 0.3); // 1/H(12) ≈ 0.32
        assert!((g.cdf[g.cdf.len() - 1] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn all_bases_in_range() {
        let g = ReadGen::new(ReadGenCfg::default());
        for idx in 0..50 {
            assert!(g.read(idx).iter().all(|&b| b <= BASE_INVALID));
        }
    }

    #[test]
    #[should_panic(expected = "read_len > row_len")]
    fn rejects_bad_lengths() {
        ReadGen::new(ReadGenCfg {
            read_len: 200,
            row_len: 160,
            ..ReadGenCfg::default()
        });
    }
}
