//! Sleeper: a pure-Rust calibration workload.
//!
//! Same structural shape as the MiniMeta assembler (stages, steps,
//! milestones, both checkpoint surfaces) with trivial deterministic
//! compute, so unit tests, property tests and the fast benches can run
//! thousands of simulated evictions per second without PJRT.

use super::{fnv1a, Progress, Snapshot, StepOutcome, Workload};
use crate::util::wire::{WireReader, WireWriter};
use anyhow::{bail, Result};

const MAGIC: u32 = 0x534C_4550; // "SLEP"
const APP_MAGIC: u32 = 0x534C_4150; // "SLAP"
const VERSION: u32 = 1;

/// Configuration for a sleeper workload.
#[derive(Debug, Clone)]
pub struct SleeperCfg {
    pub stages: Vec<(String, u64)>, // (label, steps)
    pub milestones_per_stage: u32,
    pub charged_bytes: u64,
    pub app_charged_bytes: u64,
}

impl SleeperCfg {
    /// Shape matching the paper's 5-k pipeline, tiny step counts.
    pub fn small() -> Self {
        Self {
            stages: ["K33", "K55", "K77", "K99", "K127"]
                .iter()
                .map(|s| (s.to_string(), 40u64))
                .collect(),
            milestones_per_stage: 2,
            charged_bytes: 3 << 30,     // 3 GiB CRIU-image analog
            app_charged_bytes: 1 << 30, // 1 GiB intermediate files
        }
    }
}

/// The workload: a state vector mixed deterministically per step.
#[derive(Debug, Clone)]
pub struct Sleeper {
    cfg: SleeperCfg,
    stage: u32,
    step_in_stage: u64,
    total_steps: u64,
    state: [u64; 8],
    done: bool,
    /// State as of the last milestone (what the "application" would have
    /// written to its own checkpoint files).
    milestone_state: Option<(u32, u64, u64, [u64; 8])>, // stage, step, total, state
}

impl Sleeper {
    pub fn new(cfg: SleeperCfg, seed: u64) -> Self {
        let mut state = [0u64; 8];
        for (i, s) in state.iter_mut().enumerate() {
            *s = seed.wrapping_mul(0x9E3779B97F4A7C15).rotate_left(i as u32);
        }
        let mut w = Self {
            cfg,
            stage: 0,
            step_in_stage: 0,
            total_steps: 0,
            state,
            done: false,
            milestone_state: None,
        };
        // step 0 is itself a milestone boundary ("start of stage")
        w.record_milestone();
        w
    }

    fn record_milestone(&mut self) {
        self.milestone_state =
            Some((self.stage, self.step_in_stage, self.total_steps, self.state));
    }

    fn mix(&mut self) {
        // SplitMix-ish state evolution keyed by position, so identical
        // (seed, step) always produce identical state — the bit-exact
        // resume invariant is testable.
        for i in 0..8 {
            let x = self.state[i]
                ^ (self.total_steps.wrapping_add(i as u64))
                    .wrapping_mul(0xBF58476D1CE4E5B9);
            self.state[i] = x.rotate_left(17).wrapping_mul(0x94D049BB133111EB);
        }
    }

    fn steps_between_milestones(&self, stage: u32) -> u64 {
        let steps = self.cfg.stages[stage as usize].1;
        (steps / self.cfg.milestones_per_stage.max(1) as u64).max(1)
    }

    fn encode(&self, app: bool) -> Vec<u8> {
        self.encode_to(app, Vec::new())
    }

    /// Encode into `buf` (cleared, capacity reused) and hand it back.
    fn encode_to(&self, app: bool, buf: Vec<u8>) -> Vec<u8> {
        let mut w = WireWriter::with_buf(buf);
        w.put_u32(if app { APP_MAGIC } else { MAGIC });
        w.put_u32(VERSION);
        let (stage, step, total, state) = if app {
            // spoton-lint: allow(D3, reason = "milestone_state is seeded in new() before any step")
            self.milestone_state.expect("milestone recorded at init")
        } else {
            (self.stage, self.step_in_stage, self.total_steps, self.state)
        };
        w.put_u32(stage);
        w.put_u64(step);
        w.put_u64(total);
        w.put_u64s(&state);
        w.put_u8(self.done as u8);
        w.finish()
    }

    fn decode(&mut self, bytes: &[u8], app: bool) -> Result<()> {
        let mut r = WireReader::new(bytes);
        let magic = r.get_u32()?;
        let want = if app { APP_MAGIC } else { MAGIC };
        if magic != want {
            bail!("bad sleeper snapshot magic {magic:#x}");
        }
        let version = r.get_u32()?;
        if version != VERSION {
            bail!("unsupported sleeper snapshot version {version}");
        }
        let stage = r.get_u32()?;
        let step = r.get_u64()?;
        let total = r.get_u64()?;
        let state_v = r.get_u64s()?;
        let done = r.get_u8()? != 0;
        r.finish()?;
        if state_v.len() != 8 {
            bail!("bad state vector length {}", state_v.len());
        }
        if stage as usize >= self.cfg.stages.len() && !done {
            bail!("snapshot stage {stage} out of range");
        }
        self.stage = stage;
        self.step_in_stage = step;
        self.total_steps = total;
        self.state.copy_from_slice(&state_v);
        self.done = done;
        self.record_milestone();
        Ok(())
    }
}

impl Workload for Sleeper {
    fn name(&self) -> &str {
        "sleeper"
    }

    fn num_stages(&self) -> u32 {
        self.cfg.stages.len() as u32
    }

    fn stage_label(&self, stage: u32) -> String {
        self.cfg.stages[stage as usize].0.clone()
    }

    fn stage_steps(&self, stage: u32) -> u64 {
        self.cfg.stages[stage as usize].1
    }

    fn progress(&self) -> Progress {
        Progress {
            stage: self.stage,
            step_in_stage: self.step_in_stage,
            total_steps: self.total_steps,
        }
    }

    fn is_done(&self) -> bool {
        self.done
    }

    fn step(&mut self) -> Result<StepOutcome> {
        if self.done {
            bail!("step() after Done");
        }
        self.mix();
        self.step_in_stage += 1;
        self.total_steps += 1;
        let stage_steps = self.stage_steps(self.stage);
        if self.step_in_stage >= stage_steps {
            let finished = self.stage;
            self.stage += 1;
            self.step_in_stage = 0;
            self.record_milestone();
            if self.stage as usize >= self.cfg.stages.len() {
                self.done = true;
                return Ok(StepOutcome::Done);
            }
            return Ok(StepOutcome::StageComplete(finished));
        }
        if self.step_in_stage % self.steps_between_milestones(self.stage) == 0 {
            self.record_milestone();
            return Ok(StepOutcome::Milestone);
        }
        Ok(StepOutcome::Advanced)
    }

    fn snapshot(&self) -> Result<Snapshot> {
        Ok(Snapshot {
            bytes: self.encode(false),
            charged_bytes: self.cfg.charged_bytes,
        })
    }

    fn snapshot_into(&self, out: &mut Snapshot) -> Result<()> {
        out.bytes = self.encode_to(false, std::mem::take(&mut out.bytes));
        out.charged_bytes = self.cfg.charged_bytes;
        Ok(())
    }

    fn restore(&mut self, bytes: &[u8]) -> Result<()> {
        self.decode(bytes, false)
    }

    fn app_snapshot(&self) -> Result<Option<Snapshot>> {
        // Only at the boundary itself (milestone state == live state).
        match self.milestone_state {
            Some((s, st, t, _)) if s == self.stage
                && st == self.step_in_stage
                && t == self.total_steps =>
            {
                Ok(Some(Snapshot {
                    bytes: self.encode(true),
                    charged_bytes: self.cfg.app_charged_bytes,
                }))
            }
            _ => Ok(None),
        }
    }

    fn app_restore(&mut self, bytes: &[u8]) -> Result<()> {
        self.decode(bytes, true)
    }

    fn fingerprint(&self) -> u64 {
        fnv1a(&self.encode(false))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk() -> Sleeper {
        Sleeper::new(SleeperCfg::small(), 42)
    }

    #[test]
    fn runs_to_completion_with_expected_steps() {
        let mut w = mk();
        let mut stages_done = 0;
        let mut milestones = 0;
        let mut steps = 0;
        loop {
            match w.step().unwrap() {
                StepOutcome::Advanced => {}
                StepOutcome::Milestone => milestones += 1,
                StepOutcome::StageComplete(_) => stages_done += 1,
                StepOutcome::Done => break,
            }
            steps += 1;
            assert!(steps < 10_000, "runaway");
        }
        assert!(w.is_done());
        assert_eq!(w.progress().total_steps, 5 * 40);
        assert_eq!(stages_done, 4); // last stage ends with Done
        assert_eq!(milestones, 5); // one interior milestone per stage (m=2)
    }

    #[test]
    fn snapshot_into_matches_snapshot_and_reuses_buffer() {
        let mut w = mk();
        for _ in 0..13 {
            w.step().unwrap();
        }
        let fresh = w.snapshot().unwrap();
        let mut reused = Snapshot { bytes: Vec::new(), charged_bytes: 0 };
        w.snapshot_into(&mut reused).unwrap();
        assert_eq!(reused.bytes, fresh.bytes);
        assert_eq!(reused.charged_bytes, fresh.charged_bytes);
        // a second capture reuses the allocation (same or larger capacity,
        // no fresh Vec) and stays byte-identical
        let cap = reused.bytes.capacity();
        w.step().unwrap();
        w.snapshot_into(&mut reused).unwrap();
        assert!(reused.bytes.capacity() >= cap);
        assert_eq!(reused.bytes, w.snapshot().unwrap().bytes);
    }

    #[test]
    fn transparent_snapshot_restores_bit_exact() {
        let mut w = mk();
        for _ in 0..57 {
            w.step().unwrap();
        }
        let snap = w.snapshot().unwrap();
        let fp = w.fingerprint();
        // keep running the original
        let mut cont = w.clone();
        for _ in 0..10 {
            cont.step().unwrap();
        }
        // restore a fresh instance and replay the same 10 steps
        let mut fresh = mk();
        fresh.restore(&snap.bytes).unwrap();
        assert_eq!(fresh.fingerprint(), fp);
        for _ in 0..10 {
            fresh.step().unwrap();
        }
        assert_eq!(fresh.fingerprint(), cont.fingerprint());
    }

    #[test]
    fn app_snapshot_only_at_milestones() {
        let mut w = mk();
        assert!(w.app_snapshot().unwrap().is_some(), "start is a milestone");
        w.step().unwrap(); // step 1 of 40, milestone spacing 20
        assert!(w.app_snapshot().unwrap().is_none());
        for _ in 1..20 {
            w.step().unwrap();
        }
        // at step 20: milestone
        assert!(w.app_snapshot().unwrap().is_some());
    }

    #[test]
    fn app_restore_loses_mid_milestone_progress() {
        let mut w = mk();
        // run to milestone at step 20, grab app ckpt
        for _ in 0..20 {
            w.step().unwrap();
        }
        let app = w.app_snapshot().unwrap().unwrap();
        // run 15 more steps (inside the milestone window)
        for _ in 0..15 {
            w.step().unwrap();
        }
        assert_eq!(w.progress().step_in_stage, 35);
        let mut fresh = mk();
        fresh.app_restore(&app.bytes).unwrap();
        // back to step 20 — the 15 steps are lost
        assert_eq!(fresh.progress().step_in_stage, 20);
        assert_eq!(fresh.progress().total_steps, 20);
    }

    #[test]
    fn charged_sizes_differ_by_surface() {
        let w = mk();
        assert_eq!(w.snapshot().unwrap().charged_bytes, 3 << 30);
        assert_eq!(
            w.app_snapshot().unwrap().unwrap().charged_bytes,
            1 << 30
        );
    }

    #[test]
    fn snapshot_rejects_corruption() {
        let w = mk();
        let snap = w.snapshot().unwrap();
        let mut fresh = mk();
        // truncated
        assert!(fresh.restore(&snap.bytes[..snap.bytes.len() - 3]).is_err());
        // wrong magic
        let mut bad = snap.bytes.clone();
        bad[0] ^= 0xff;
        assert!(fresh.restore(&bad).is_err());
        // cross-surface confusion rejected
        assert!(fresh.app_restore(&snap.bytes).is_err());
    }

    #[test]
    fn step_after_done_errors() {
        let mut w = mk();
        while !w.is_done() {
            w.step().unwrap();
        }
        assert!(w.step().is_err());
    }

    #[test]
    fn different_seeds_different_fingerprints() {
        let a = Sleeper::new(SleeperCfg::small(), 1);
        let b = Sleeper::new(SleeperCfg::small(), 2);
        assert_ne!(a.fingerprint(), b.fingerprint());
    }
}
