//! Contig extraction over the bucketed k-mer spectrum.
//!
//! The Rust tail of each k-stage: after counting + denoising, occupied
//! bucket runs are contracted into "contigs" (the bucket-graph analog of
//! unitig extraction — DESIGN.md §2 documents the substitution) and
//! summarized with the assembler's usual statistics (count, total length,
//! max, N50).

/// Summary statistics for one stage's assembly output.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ContigStats {
    pub n_contigs: u64,
    pub total_len: u64,
    pub max_len: u64,
    pub n50: u64,
}

impl ContigStats {
    pub fn empty() -> Self {
        Self { n_contigs: 0, total_len: 0, max_len: 0, n50: 0 }
    }
}

/// Extract maximal runs of buckets with coverage ≥ `threshold` and
/// summarize them.
pub fn extract_contigs(counts: &[f32], threshold: f32) -> ContigStats {
    let mut lengths: Vec<u64> = Vec::new();
    let mut run: u64 = 0;
    for &c in counts {
        if c >= threshold && c > 0.0 {
            run += 1;
        } else if run > 0 {
            lengths.push(run);
            run = 0;
        }
    }
    if run > 0 {
        lengths.push(run);
    }
    summarize(&lengths)
}

/// N50 etc. over a set of contig lengths.
pub fn summarize(lengths: &[u64]) -> ContigStats {
    if lengths.is_empty() {
        return ContigStats::empty();
    }
    let total: u64 = lengths.iter().sum();
    let max = lengths.iter().copied().max().unwrap_or(0);
    let mut sorted: Vec<u64> = lengths.to_vec();
    sorted.sort_unstable_by(|a, b| b.cmp(a)); // descending
    let mut acc = 0u64;
    let mut n50 = 0u64;
    for &len in &sorted {
        acc += len;
        if acc * 2 >= total {
            n50 = len;
            break;
        }
    }
    ContigStats { n_contigs: lengths.len() as u64, total_len: total, max_len: max, n50 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_spectrum() {
        assert_eq!(extract_contigs(&[], 1.0), ContigStats::empty());
        assert_eq!(extract_contigs(&[0.0; 8], 1.0), ContigStats::empty());
    }

    #[test]
    fn single_run() {
        let counts = [0.0, 2.0, 3.0, 2.0, 0.0];
        let s = extract_contigs(&counts, 1.0);
        assert_eq!(s.n_contigs, 1);
        assert_eq!(s.total_len, 3);
        assert_eq!(s.max_len, 3);
        assert_eq!(s.n50, 3);
    }

    #[test]
    fn multiple_runs_and_threshold() {
        //            run(2)     cut      run(1)  run(3 @>=2: only 5,9)
        let counts = [2.0, 2.0, 0.5, 0.0, 1.0, 0.0, 5.0, 9.0, 2.0];
        let s1 = extract_contigs(&counts, 1.0);
        assert_eq!(s1.n_contigs, 3);
        assert_eq!(s1.total_len, 2 + 1 + 3);
        assert_eq!(s1.max_len, 3);
        let s2 = extract_contigs(&counts, 2.0);
        assert_eq!(s2.n_contigs, 2);
        assert_eq!(s2.total_len, 2 + 3);
    }

    #[test]
    fn run_at_end_is_closed() {
        let s = extract_contigs(&[0.0, 1.0, 1.0], 1.0);
        assert_eq!(s.n_contigs, 1);
        assert_eq!(s.total_len, 2);
    }

    #[test]
    fn n50_definition() {
        // lengths 5, 4, 1 (total 10): cumulative 5 (>=5) -> n50 = 5
        assert_eq!(summarize(&[1, 5, 4]).n50, 5);
        // lengths 3, 3, 2, 2 (total 10): 3+3=6 >= 5 -> n50 = 3
        assert_eq!(summarize(&[2, 3, 2, 3]).n50, 3);
        // single contig
        assert_eq!(summarize(&[7]).n50, 7);
    }

    #[test]
    fn zero_counts_below_any_threshold() {
        // threshold 0.0 must not count empty buckets as covered
        let s = extract_contigs(&[0.0, 0.0, 3.0], 0.0);
        assert_eq!(s.n_contigs, 1);
        assert_eq!(s.total_len, 1);
    }
}
