//! MiniMeta: the metaSPAdes-analog multi-k assembly workload.
//!
//! The paper's case study assembles a metagenome with metaSPAdes over
//! five k-mer sizes (33, 55, 77, 99, 127), each k a long-running stage.
//! MiniMeta reproduces that *systems* shape with real compute
//! (DESIGN.md §2):
//!
//! ```text
//! per stage k:
//!   count phase    — one step per read chunk: the Pallas k-mer-count
//!                    artifact (count_k<k>) accumulates the bucketed
//!                    spectrum via PJRT
//!   denoise phase  — one step per sweep: the Pallas banded-smoothing
//!                    artifact with an annealed coverage threshold
//!   stage close    — spectrum_stats artifact + Rust contig extraction;
//!                    the stage summary joins the cross-stage state
//! ```
//!
//! All state that matters (the evolving spectrum, position counters,
//! per-stage summaries) lives in this struct and serializes through the
//! transparent snapshot surface at any step; application-native snapshots
//! are only captured at metaSPAdes-style milestones. The read set is NOT
//! state — chunks regenerate deterministically from (seed, index)
//! (see [`super::reads`]).

pub mod contig;

use super::reads::{ReadGen, ReadGenCfg};
use super::{fnv1a, Progress, Snapshot, StepOutcome, Workload};
use crate::runtime::{Arg, ArtifactManifest, Runtime};
use crate::util::wire::{WireReader, WireWriter};
use anyhow::{bail, Context, Result};
use contig::ContigStats;
use std::cell::RefCell;
use std::rc::Rc;

const MAGIC: u32 = 0x4D4D_4554; // "MMET"
const APP_MAGIC: u32 = 0x4D4D_4150; // "MMAP"
const VERSION: u32 = 1;

/// Assembly parameters (geometry comes from the artifact manifest).
#[derive(Debug, Clone)]
pub struct MiniMetaCfg {
    /// Total reads per stage (every k re-scans the read set, like
    /// metaSPAdes re-reading the input for each k).
    pub total_reads: u64,
    /// Denoise sweeps per stage.
    pub denoise_sweeps: u32,
    /// App-native milestones per stage (metaSPAdes writes several
    /// internal checkpoints per k).
    pub milestones_per_stage: u32,
    /// Modeled checkpoint image sizes (DESIGN.md §6).
    pub charged_bytes: u64,
    pub app_charged_bytes: u64,
    /// Read synthesis seed.
    pub seed: u64,
    /// Coverage threshold floor for denoising / contig extraction.
    pub base_threshold: f32,
}

impl Default for MiniMetaCfg {
    fn default() -> Self {
        Self {
            total_reads: 32 * 1024,
            denoise_sweeps: 24,
            milestones_per_stage: 2,
            charged_bytes: 3 << 30,
            app_charged_bytes: 1 << 30,
            seed: 2022,
            base_threshold: 2.0,
        }
    }
}

/// Closed-stage summary carried across stages (cross-stage state).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageSummary {
    pub k: u32,
    pub mass: f32,
    pub occupied: f32,
    pub max_count: f32,
    pub contigs: ContigStats,
}

/// Captured live state at the last milestone (what the application's own
/// checkpoint files would contain).
#[derive(Debug, Clone)]
struct MilestoneState {
    stage: u32,
    step_in_stage: u64,
    total_steps: u64,
    counts: Vec<f32>,
    summaries: Vec<StageSummary>,
    done: bool,
}

/// The MiniMeta workload. Holds a shared PJRT runtime (compilation is
/// per-process, not per-run).
pub struct MiniMeta {
    cfg: MiniMetaCfg,
    rt: Rc<RefCell<Runtime>>,
    ks: Vec<u32>,
    reads: ReadGen,
    // live state
    stage: u32,
    step_in_stage: u64,
    total_steps: u64,
    counts: Vec<f32>,
    summaries: Vec<StageSummary>,
    done: bool,
    milestone: Option<MilestoneState>,
    // derived per-build constants
    num_buckets: usize,
    reads_per_call: usize,
    row_len: usize,
    chunks_per_stage: u64,
}

impl MiniMeta {
    pub fn new(cfg: MiniMetaCfg, rt: Rc<RefCell<Runtime>>) -> Result<Self> {
        let (ks, num_buckets, reads_per_call, row_len, half_width) = {
            let r = rt.borrow();
            let g = r.geometry();
            (
                g.ks.clone(),
                g.num_buckets as usize,
                g.reads_per_call as usize,
                g.read_len as usize,
                g.denoise_half_width as usize,
            )
        };
        if ks.is_empty() {
            bail!("artifact manifest lists no k values");
        }
        let _ = half_width;
        let chunks_per_stage =
            (cfg.total_reads + reads_per_call as u64 - 1)
                / reads_per_call as u64;
        if chunks_per_stage == 0 {
            bail!("total_reads must be positive");
        }
        let reads = ReadGen::new(ReadGenCfg {
            seed: cfg.seed,
            row_len,
            read_len: row_len.saturating_sub(10),
            ..ReadGenCfg::default()
        });
        let mut w = Self {
            counts: vec![0.0; num_buckets],
            cfg,
            rt,
            ks,
            reads,
            stage: 0,
            step_in_stage: 0,
            total_steps: 0,
            summaries: Vec::new(),
            done: false,
            milestone: None,
            num_buckets,
            reads_per_call,
            row_len,
            chunks_per_stage,
        };
        w.record_milestone();
        Ok(w)
    }

    fn record_milestone(&mut self) {
        self.milestone = Some(MilestoneState {
            stage: self.stage,
            step_in_stage: self.step_in_stage,
            total_steps: self.total_steps,
            counts: self.counts.clone(),
            summaries: self.summaries.clone(),
            done: self.done,
        });
    }

    fn steps_per_stage(&self) -> u64 {
        self.chunks_per_stage + self.cfg.denoise_sweeps as u64
    }

    fn milestone_spacing(&self) -> u64 {
        (self.steps_per_stage() / self.cfg.milestones_per_stage.max(1) as u64)
            .max(1)
    }

    /// Denoise parameters for a sweep: annealed coverage threshold, fixed
    /// smoothing stencil. Pure function of (stage, sweep) for resume
    /// determinism.
    fn denoise_params(&self, sweep: u32) -> (Vec<f32>, [f32; 2]) {
        let r = self.rt.borrow();
        let taps = 2 * r.geometry().denoise_half_width as usize + 1;
        drop(r);
        // smoothing kernel: center-heavy, normalized
        let mut stencil = vec![0.0f32; taps];
        let mid = taps / 2;
        let mut total = 0.0;
        for (i, s) in stencil.iter_mut().enumerate() {
            let d = (i as i32 - mid as i32).abs() as f32;
            *s = 1.0 / (1.0 + d * d);
            total += *s;
        }
        for s in stencil.iter_mut() {
            *s /= total;
        }
        // anneal: threshold ramps from base/4 to base over the sweeps
        let frac = (sweep as f32 + 1.0) / self.cfg.denoise_sweeps.max(1) as f32;
        let threshold = self.cfg.base_threshold * (0.25 + 0.75 * frac);
        (stencil, [threshold, 0.5])
    }

    /// The read chunk for count step `chunk_idx`, padded to
    /// `reads_per_call` rows with invalid bases (which the kernel masks).
    fn chunk(&self, chunk_idx: u64) -> Vec<i32> {
        let first = chunk_idx * self.reads_per_call as u64;
        let remaining = self.cfg.total_reads.saturating_sub(first);
        let real = remaining.min(self.reads_per_call as u64) as usize;
        let mut chunk = self.reads.chunk_i32(first, real);
        chunk.resize(self.reads_per_call * self.row_len, 4); // pad rows
        chunk
    }

    fn close_stage(&mut self) -> Result<()> {
        let k = self.ks[self.stage as usize];
        let mut rt = self.rt.borrow_mut();
        let stats = rt
            .executable("spectrum_stats")?
            .call_f32(&[Arg::F32(&self.counts)])
            .context("spectrum_stats")?;
        drop(rt);
        let contigs =
            contig::extract_contigs(&self.counts, self.cfg.base_threshold);
        self.summaries.push(StageSummary {
            k,
            mass: stats[0][0],
            occupied: stats[0][1],
            max_count: stats[0][2],
            contigs,
        });
        // next k starts from a fresh spectrum (the cross-stage signal is
        // the summaries/contig set, as in multi-k assembly)
        self.counts.iter_mut().for_each(|c| *c = 0.0);
        Ok(())
    }

    pub fn summaries(&self) -> &[StageSummary] {
        &self.summaries
    }

    fn encode(&self, app: bool) -> Vec<u8> {
        let ms;
        let (stage, step, total, counts, summaries, done) = if app {
            // spoton-lint: allow(D3, reason = "milestone is recorded at stage entry before use")
            ms = self.milestone.as_ref().expect("milestone exists");
            (ms.stage, ms.step_in_stage, ms.total_steps, &ms.counts,
             &ms.summaries, ms.done)
        } else {
            (self.stage, self.step_in_stage, self.total_steps, &self.counts,
             &self.summaries, self.done)
        };
        let mut w = WireWriter::new();
        w.put_u32(if app { APP_MAGIC } else { MAGIC });
        w.put_u32(VERSION);
        w.put_u64(self.cfg.seed);
        w.put_u32(stage);
        w.put_u64(step);
        w.put_u64(total);
        w.put_u8(done as u8);
        w.put_f32s(counts);
        w.put_u32(summaries.len() as u32);
        for s in summaries {
            w.put_u32(s.k);
            w.put_f32(s.mass);
            w.put_f32(s.occupied);
            w.put_f32(s.max_count);
            w.put_u64(s.contigs.n_contigs);
            w.put_u64(s.contigs.total_len);
            w.put_u64(s.contigs.max_len);
            w.put_u64(s.contigs.n50);
        }
        w.finish()
    }

    fn decode(&mut self, bytes: &[u8], app: bool) -> Result<()> {
        let mut r = WireReader::new(bytes);
        let magic = r.get_u32()?;
        let want = if app { APP_MAGIC } else { MAGIC };
        if magic != want {
            bail!("bad minimeta snapshot magic {magic:#x}");
        }
        if r.get_u32()? != VERSION {
            bail!("unsupported minimeta snapshot version");
        }
        let seed = r.get_u64()?;
        if seed != self.cfg.seed {
            bail!(
                "snapshot was taken with seed {seed}, workload configured \
                 with {}",
                self.cfg.seed
            );
        }
        let stage = r.get_u32()?;
        let step = r.get_u64()?;
        let total = r.get_u64()?;
        let done = r.get_u8()? != 0;
        let counts = r.get_f32s()?;
        if counts.len() != self.num_buckets {
            bail!(
                "snapshot spectrum has {} buckets, runtime geometry {}",
                counts.len(),
                self.num_buckets
            );
        }
        if !done && stage as usize >= self.ks.len() {
            bail!("snapshot stage {stage} out of range");
        }
        let n = r.get_u32()? as usize;
        if n > self.ks.len() {
            bail!("snapshot has too many stage summaries");
        }
        let mut summaries = Vec::with_capacity(n);
        for _ in 0..n {
            summaries.push(StageSummary {
                k: r.get_u32()?,
                mass: r.get_f32()?,
                occupied: r.get_f32()?,
                max_count: r.get_f32()?,
                contigs: ContigStats {
                    n_contigs: r.get_u64()?,
                    total_len: r.get_u64()?,
                    max_len: r.get_u64()?,
                    n50: r.get_u64()?,
                },
            });
        }
        r.finish()?;
        self.stage = stage;
        self.step_in_stage = step;
        self.total_steps = total;
        self.done = done;
        self.counts = counts;
        self.summaries = summaries;
        self.record_milestone();
        Ok(())
    }
}

impl Workload for MiniMeta {
    fn name(&self) -> &str {
        "minimeta"
    }

    fn num_stages(&self) -> u32 {
        self.ks.len() as u32
    }

    fn stage_label(&self, stage: u32) -> String {
        format!("K{}", self.ks[stage as usize])
    }

    fn stage_steps(&self, _stage: u32) -> u64 {
        self.steps_per_stage()
    }

    fn progress(&self) -> Progress {
        Progress {
            stage: self.stage,
            step_in_stage: self.step_in_stage,
            total_steps: self.total_steps,
        }
    }

    fn is_done(&self) -> bool {
        self.done
    }

    fn step(&mut self) -> Result<StepOutcome> {
        if self.done {
            bail!("step() after Done");
        }
        let k = self.ks[self.stage as usize];
        if self.step_in_stage < self.chunks_per_stage {
            // count phase: one chunk through the Pallas count kernel
            let chunk = self.chunk(self.step_in_stage);
            let name = ArtifactManifest::count_artifact(k);
            let mut rt = self.rt.borrow_mut();
            let out = rt
                .executable(&name)?
                .call_f32(&[Arg::I32(&chunk), Arg::F32(&self.counts)])
                .with_context(|| format!("count step k={k}"))?;
            drop(rt);
            self.counts = out
                .into_iter()
                .next()
                .with_context(|| format!("count kernel k={k} returned no output buffer"))?;
        } else {
            // denoise phase
            let sweep =
                (self.step_in_stage - self.chunks_per_stage) as u32;
            let (stencil, params) = self.denoise_params(sweep);
            let mut rt = self.rt.borrow_mut();
            let out = rt
                .executable("denoise")?
                .call_f32(&[
                    Arg::F32(&self.counts),
                    Arg::F32(&stencil),
                    Arg::F32(&params),
                ])
                .with_context(|| format!("denoise sweep {sweep} k={k}"))?;
            drop(rt);
            self.counts = out
                .into_iter()
                .next()
                .with_context(|| format!("denoise sweep {sweep} returned no output buffer"))?;
        }

        self.step_in_stage += 1;
        self.total_steps += 1;

        if self.step_in_stage >= self.steps_per_stage() {
            let finished = self.stage;
            self.close_stage()?;
            self.stage += 1;
            self.step_in_stage = 0;
            self.record_milestone();
            if self.stage as usize >= self.ks.len() {
                self.done = true;
                return Ok(StepOutcome::Done);
            }
            return Ok(StepOutcome::StageComplete(finished));
        }
        if self.step_in_stage % self.milestone_spacing() == 0 {
            self.record_milestone();
            return Ok(StepOutcome::Milestone);
        }
        Ok(StepOutcome::Advanced)
    }

    fn snapshot(&self) -> Result<Snapshot> {
        Ok(Snapshot {
            bytes: self.encode(false),
            charged_bytes: self.cfg.charged_bytes,
        })
    }

    fn restore(&mut self, bytes: &[u8]) -> Result<()> {
        self.decode(bytes, false)
    }

    fn app_snapshot(&self) -> Result<Option<Snapshot>> {
        match &self.milestone {
            Some(ms)
                if ms.stage == self.stage
                    && ms.step_in_stage == self.step_in_stage
                    && ms.total_steps == self.total_steps =>
            {
                Ok(Some(Snapshot {
                    bytes: self.encode(true),
                    charged_bytes: self.cfg.app_charged_bytes,
                }))
            }
            _ => Ok(None),
        }
    }

    fn app_restore(&mut self, bytes: &[u8]) -> Result<()> {
        self.decode(bytes, true)
    }

    fn fingerprint(&self) -> u64 {
        fnv1a(&self.encode(false))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn runtime() -> Option<Rc<RefCell<Runtime>>> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        Some(Rc::new(RefCell::new(Runtime::load(&dir).unwrap())))
    }

    fn tiny_cfg() -> MiniMetaCfg {
        MiniMetaCfg {
            total_reads: 2048, // 2 chunks per stage at RC=1024
            denoise_sweeps: 3,
            milestones_per_stage: 2,
            seed: 7,
            ..MiniMetaCfg::default()
        }
    }

    #[test]
    fn counts_accumulate_real_kmers() {
        let Some(rt) = runtime() else { return };
        let mut w = MiniMeta::new(tiny_cfg(), rt).unwrap();
        // one count step: spectrum mass equals valid windows
        w.step().unwrap();
        let mass: f32 = w.counts.iter().sum();
        assert!(mass > 0.0, "count kernel produced nothing");
        // 1024 reads x up to (150 - 33 + 1) windows; Ns knock a few out
        let max_possible = 1024.0 * (160 - 33 + 1) as f32;
        assert!(mass <= max_possible);
    }

    #[test]
    fn full_run_produces_summaries() {
        let Some(rt) = runtime() else { return };
        let cfg = MiniMetaCfg {
            total_reads: 1024,
            denoise_sweeps: 2,
            ..tiny_cfg()
        };
        let mut w = MiniMeta::new(cfg, rt).unwrap();
        let mut guard = 0;
        while !w.is_done() {
            w.step().unwrap();
            guard += 1;
            assert!(guard < 1000, "runaway");
        }
        assert_eq!(w.summaries().len(), 5);
        for (s, k) in w.summaries().iter().zip([33u32, 55, 77, 99, 127]) {
            assert_eq!(s.k, k);
            assert!(s.mass >= 0.0);
            assert!(s.contigs.n_contigs > 0, "k{k} produced no contigs");
        }
    }

    #[test]
    fn transparent_resume_is_bit_exact_mid_stage() {
        let Some(rt) = runtime() else { return };
        let mut w = MiniMeta::new(tiny_cfg(), rt.clone()).unwrap();
        for _ in 0..3 {
            w.step().unwrap(); // inside stage 0 (2 chunks + 3 sweeps)
        }
        let snap = w.snapshot().unwrap();
        let fp = w.fingerprint();
        // continue original 2 steps
        w.step().unwrap();
        w.step().unwrap();
        let fp_after = w.fingerprint();
        // restore into a fresh workload, replay
        let mut w2 = MiniMeta::new(tiny_cfg(), rt).unwrap();
        w2.restore(&snap.bytes).unwrap();
        assert_eq!(w2.fingerprint(), fp);
        w2.step().unwrap();
        w2.step().unwrap();
        assert_eq!(
            w2.fingerprint(),
            fp_after,
            "resumed compute diverged from uninterrupted run"
        );
    }

    #[test]
    fn app_restore_rolls_back_to_milestone() {
        let Some(rt) = runtime() else { return };
        let mut w = MiniMeta::new(tiny_cfg(), rt.clone()).unwrap();
        // steps_per_stage = 2 + 3 = 5; spacing = 2
        w.step().unwrap();
        let o = w.step().unwrap(); // step 2 -> milestone
        assert_eq!(o, StepOutcome::Milestone);
        let app = w.app_snapshot().unwrap().expect("at milestone");
        w.step().unwrap(); // past milestone
        assert!(w.app_snapshot().unwrap().is_none());
        let mut w2 = MiniMeta::new(tiny_cfg(), rt).unwrap();
        w2.app_restore(&app.bytes).unwrap();
        assert_eq!(w2.progress().step_in_stage, 2);
        assert_eq!(w2.progress().total_steps, 2);
    }

    #[test]
    fn snapshot_guards_seed_and_geometry() {
        let Some(rt) = runtime() else { return };
        let w = MiniMeta::new(tiny_cfg(), rt.clone()).unwrap();
        let snap = w.snapshot().unwrap();
        let mut other = MiniMeta::new(
            MiniMetaCfg { seed: 999, ..tiny_cfg() },
            rt,
        )
        .unwrap();
        let err = other.restore(&snap.bytes).unwrap_err();
        assert!(err.to_string().contains("seed"));
    }

    #[test]
    fn padded_final_chunk_masks_out() {
        let Some(rt) = runtime() else { return };
        // 1500 reads -> chunk 0 full, chunk 1 has 476 real + padding
        let cfg = MiniMetaCfg {
            total_reads: 1500,
            denoise_sweeps: 1,
            ..tiny_cfg()
        };
        let mut w = MiniMeta::new(cfg, rt).unwrap();
        w.step().unwrap();
        let mass_full: f32 = w.counts.iter().sum();
        w.step().unwrap();
        let mass_partial: f32 = w.counts.iter().sum::<f32>() - mass_full;
        assert!(mass_partial > 0.0);
        assert!(
            mass_partial < mass_full,
            "padded chunk must contribute less: {mass_partial} vs {mass_full}"
        );
    }
}
