//! The workload abstraction: what Spot-on protects.
//!
//! A [`Workload`] is a long-running, multi-stage computation driven one
//! step at a time by the coordinator (the *loop* lives in Rust and is what
//! gets checkpointed; the *math* of the flagship [`assembler`] workload
//! lives in the AOT-compiled JAX/Pallas artifacts).
//!
//! Two checkpoint surfaces, mirroring the paper's §III-A comparison:
//!
//! * **transparent** ([`Workload::snapshot`] / [`Workload::restore`]) —
//!   the CRIU analog: the *complete* live state, captureable at any step,
//!   restoring to exactly the captured step (bit-exact, which tests
//!   verify via [`Workload::fingerprint`]).
//! * **application-native** ([`Workload::app_snapshot`] /
//!   [`Workload::app_restore`]) — only available at the workload's own
//!   milestones (metaSPAdes writes checkpoints at internal phase
//!   boundaries); restoring loses all progress since that milestone and
//!   cannot be triggered on demand by an eviction notice.

pub mod sleeper;
pub mod reads;
pub mod assembler;

use crate::simclock::SimDuration;
use anyhow::Result;

/// Where a workload currently is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Progress {
    /// Current stage (0-based; the paper's K33..K127 are stages 0..4).
    pub stage: u32,
    /// Steps completed within the current stage.
    pub step_in_stage: u64,
    /// Total steps completed across all stages.
    pub total_steps: u64,
}

/// Result of executing one step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepOutcome {
    /// Normal progress.
    Advanced,
    /// Reached an application checkpoint milestone (app_snapshot is now
    /// available for the coordinator to persist).
    Milestone,
    /// Finished a stage (also a milestone).
    StageComplete(u32),
    /// The whole workload finished with this step.
    Done,
}

/// A serialized state capture.
#[derive(Debug, Clone)]
pub struct Snapshot {
    /// The real serialized bytes (integrity-checked end to end).
    pub bytes: Vec<u8>,
    /// Modeled transfer size (CRIU-image / intermediate-file analog) used
    /// for virtual transfer time, capacity and billing — DESIGN.md §6.
    pub charged_bytes: u64,
}

/// A long-running multi-stage computation under coordinator control.
pub trait Workload {
    fn name(&self) -> &str;

    fn num_stages(&self) -> u32;

    /// Human label for a stage ("K33", …).
    fn stage_label(&self, stage: u32) -> String;

    /// Steps in the given stage (drives virtual-time calibration).
    fn stage_steps(&self, stage: u32) -> u64;

    fn progress(&self) -> Progress;

    fn is_done(&self) -> bool;

    /// Execute one step of real compute.
    fn step(&mut self) -> Result<StepOutcome>;

    // --- transparent (CRIU-analog) surface --------------------------------

    /// Full-state capture; valid at any step.
    fn snapshot(&self) -> Result<Snapshot>;

    /// Capture into an existing [`Snapshot`], reusing its byte buffer.
    /// The default allocates via [`Workload::snapshot`]; workloads on the
    /// periodic-checkpoint hot path (thousands of sweep runs) override it
    /// to serialize in place.
    fn snapshot_into(&self, out: &mut Snapshot) -> Result<()> {
        *out = self.snapshot()?;
        Ok(())
    }

    /// Restore from a transparent snapshot.
    fn restore(&mut self, bytes: &[u8]) -> Result<()>;

    // --- application-native surface ---------------------------------------

    /// State capture at the application's own milestone; `None` unless
    /// the workload is exactly at a milestone boundary.
    fn app_snapshot(&self) -> Result<Option<Snapshot>>;

    /// Restore from an application checkpoint (milestone state).
    fn app_restore(&mut self, bytes: &[u8]) -> Result<()>;

    /// Extra virtual time an application-native restart burns re-loading
    /// inputs and rebuilding in-memory indices (metaSPAdes
    /// `--restart-from` re-reads its intermediate files).
    fn app_restart_overhead(&self) -> SimDuration {
        SimDuration::from_secs(120)
    }

    // --- verification ------------------------------------------------------

    /// Order-sensitive hash of live state; two workloads with equal
    /// fingerprints are in the same computational state (the bit-exact
    /// resume invariant).
    fn fingerprint(&self) -> u64;
}

/// FNV-1a for state fingerprints.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_known_vectors() {
        assert_eq!(fnv1a(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a(b"a"), 0xaf63dc4c8601ec8c);
        assert_ne!(fnv1a(b"ab"), fnv1a(b"ba"));
    }
}
