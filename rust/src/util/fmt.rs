//! Human-readable formatting: durations (paper's `H:MM:SS` table format),
//! byte sizes, dollars.

/// Format whole seconds as the paper's Table I style: `MM:SS` under an
/// hour, `H:MM:SS` above.
pub fn hms(total_secs: u64) -> String {
    let h = total_secs / 3600;
    let m = (total_secs % 3600) / 60;
    let s = total_secs % 60;
    if h > 0 {
        format!("{h}:{m:02}:{s:02}")
    } else {
        format!("{m}:{s:02}")
    }
}

/// [`hms`] for a fractional seconds count (distribution summaries carry
/// f64 metrics): rounds to millisecond precision like
/// [`SimDuration::from_secs_f64`](crate::simclock::SimDuration), clamps
/// negatives to zero.
pub fn hms_f64(secs: f64) -> String {
    crate::simclock::SimDuration::from_secs_f64(secs.max(0.0)).hms()
}

/// Parse `H:MM:SS` / `MM:SS` / `SS` into whole seconds.
pub fn parse_hms(s: &str) -> Option<u64> {
    let parts: Vec<&str> = s.split(':').collect();
    if parts.is_empty() || parts.len() > 3 {
        return None;
    }
    let mut secs: u64 = 0;
    for p in &parts {
        if p.is_empty() || !p.chars().all(|c| c.is_ascii_digit()) {
            return None;
        }
        secs = secs * 60 + p.parse::<u64>().ok()?;
    }
    Some(secs)
}

/// Format bytes with binary units (`KiB`, `MiB`, `GiB`).
pub fn bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format dollars with 4 decimal places (spot prices are sub-cent scale).
pub fn dollars(v: f64) -> String {
    let v = if v == 0.0 { 0.0 } else { v }; // normalize -0.0
    format!("${v:.4}")
}

/// Format a ratio as a signed percentage, e.g. `-12.3%`.
pub fn pct(ratio: f64) -> String {
    format!("{:+.1}%", ratio * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hms_matches_paper_style() {
        assert_eq!(hms(2030), "33:50"); // K33 baseline row
        assert_eq!(hms(11006), "3:03:26"); // Table I row 1 total
        assert_eq!(hms(0), "0:00");
        assert_eq!(hms(59), "0:59");
        assert_eq!(hms(3600), "1:00:00");
    }

    #[test]
    fn hms_f64_rounds_and_clamps() {
        assert_eq!(hms_f64(11006.0), "3:03:26");
        assert_eq!(hms_f64(59.9996), "1:00"); // rounds at ms precision
        assert_eq!(hms_f64(-5.0), "0:00"); // negatives clamp to zero
    }

    #[test]
    fn parse_round_trips() {
        for s in [0u64, 59, 60, 61, 3599, 3600, 11006, 16102] {
            assert_eq!(parse_hms(&hms(s)), Some(s), "{s}");
        }
        assert_eq!(parse_hms("33:50"), Some(2030));
        assert_eq!(parse_hms("4:28:22"), Some(16102));
        assert_eq!(parse_hms(""), None);
        assert_eq!(parse_hms("1:2:3:4"), None);
        assert_eq!(parse_hms("ab:cd"), None);
    }

    #[test]
    fn bytes_units() {
        assert_eq!(bytes(512), "512 B");
        assert_eq!(bytes(2048), "2.00 KiB");
        assert_eq!(bytes(3 * 1024 * 1024), "3.00 MiB");
        assert_eq!(bytes(5 * 1024 * 1024 * 1024), "5.00 GiB");
    }

    #[test]
    fn dollars_and_pct() {
        assert_eq!(dollars(0.076), "$0.0760");
        assert_eq!(pct(-0.77), "-77.0%");
        assert_eq!(pct(0.155), "+15.5%");
    }
}
