//! Tiny binary serialization helpers (length-prefixed, little-endian).
//!
//! Checkpoint payloads are hand-rolled binary (no serde offline): each
//! snapshot is a magic + version header followed by typed fields written
//! through [`WireWriter`] and read back with [`WireReader`], which checks
//! bounds on every read so truncated/corrupt payloads fail loudly instead
//! of yielding garbage state.

use anyhow::{bail, Context, Result};

/// Append-only binary writer.
#[derive(Debug, Default)]
pub struct WireWriter {
    buf: Vec<u8>,
}

impl WireWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// A writer reusing an existing buffer (cleared, capacity kept) —
    /// checkpoint hot paths recycle their snapshot allocation instead of
    /// growing a fresh `Vec` per write.
    pub fn with_buf(mut buf: Vec<u8>) -> Self {
        buf.clear();
        Self { buf }
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_bytes(&mut self, v: &[u8]) {
        self.put_u64(v.len() as u64);
        self.buf.extend_from_slice(v);
    }

    pub fn put_str(&mut self, v: &str) {
        self.put_bytes(v.as_bytes());
    }

    pub fn put_f32s(&mut self, v: &[f32]) {
        self.put_u64(v.len() as u64);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    pub fn put_u64s(&mut self, v: &[u64]) {
        self.put_u64(v.len() as u64);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

/// Bounds-checked binary reader.
pub struct WireReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> WireReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!(
                "wire underrun: need {n} bytes at offset {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn get_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn get_u32(&mut self) -> Result<u32> {
        // spoton-lint: allow(D3, reason = "take(4)? returned exactly 4 bytes")
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_u64(&mut self) -> Result<u64> {
        // spoton-lint: allow(D3, reason = "take(8)? returned exactly 8 bytes")
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_f32(&mut self) -> Result<f32> {
        // spoton-lint: allow(D3, reason = "take(4)? returned exactly 4 bytes")
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_f64(&mut self) -> Result<f64> {
        // spoton-lint: allow(D3, reason = "take(8)? returned exactly 8 bytes")
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.get_u64()? as usize;
        if n > self.buf.len() {
            bail!("wire length {n} exceeds buffer");
        }
        Ok(self.take(n)?.to_vec())
    }

    pub fn get_str(&mut self) -> Result<String> {
        String::from_utf8(self.get_bytes()?).context("invalid utf-8 string")
    }

    pub fn get_f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.get_u64()? as usize;
        if n.saturating_mul(4) > self.buf.len() {
            bail!("wire f32 array length {n} exceeds buffer");
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.get_f32()?);
        }
        Ok(out)
    }

    pub fn get_u64s(&mut self) -> Result<Vec<u64>> {
        let n = self.get_u64()? as usize;
        if n.saturating_mul(8) > self.buf.len() {
            bail!("wire u64 array length {n} exceeds buffer");
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.get_u64()?);
        }
        Ok(out)
    }

    /// Assert every byte was consumed (snapshot formats are exact).
    pub fn finish(self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!(
                "wire trailing bytes: consumed {} of {}",
                self.pos,
                self.buf.len()
            );
        }
        Ok(())
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_all_types() {
        let mut w = WireWriter::new();
        w.put_u8(7);
        w.put_u32(0xDEAD_BEEF);
        w.put_u64(u64::MAX);
        w.put_f32(1.5);
        w.put_f64(-2.25);
        w.put_str("stage-k55");
        w.put_bytes(&[1, 2, 3]);
        w.put_f32s(&[0.0, -1.0, 3.5]);
        w.put_u64s(&[9, 8]);
        let buf = w.finish();

        let mut r = WireReader::new(&buf);
        assert_eq!(r.get_u8().unwrap(), 7);
        assert_eq!(r.get_u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.get_u64().unwrap(), u64::MAX);
        assert_eq!(r.get_f32().unwrap(), 1.5);
        assert_eq!(r.get_f64().unwrap(), -2.25);
        assert_eq!(r.get_str().unwrap(), "stage-k55");
        assert_eq!(r.get_bytes().unwrap(), vec![1, 2, 3]);
        assert_eq!(r.get_f32s().unwrap(), vec![0.0, -1.0, 3.5]);
        assert_eq!(r.get_u64s().unwrap(), vec![9, 8]);
        r.finish().unwrap();
    }

    #[test]
    fn truncation_detected() {
        let mut w = WireWriter::new();
        w.put_f32s(&[1.0; 100]);
        let buf = w.finish();
        for cut in [0, 1, 7, 8, 9, 50, buf.len() - 1] {
            let mut r = WireReader::new(&buf[..cut]);
            assert!(r.get_f32s().is_err(), "cut at {cut} accepted");
        }
    }

    #[test]
    fn trailing_bytes_detected() {
        let mut w = WireWriter::new();
        w.put_u32(1);
        let mut buf = w.finish();
        buf.push(0);
        let mut r = WireReader::new(&buf);
        r.get_u32().unwrap();
        assert!(r.finish().is_err());
    }

    #[test]
    fn absurd_length_rejected_without_alloc() {
        // a corrupt length prefix must not cause a huge allocation
        let mut w = WireWriter::new();
        w.put_u64(u64::MAX);
        let buf = w.finish();
        let mut r = WireReader::new(&buf);
        assert!(r.get_bytes().is_err());
        let mut r2 = WireReader::new(&buf);
        assert!(r2.get_f32s().is_err());
    }
}
