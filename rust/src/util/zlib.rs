//! Minimal zlib (RFC 1950/1951) codec for checkpoint payloads.
//!
//! The compressor emits a single fixed-Huffman DEFLATE block using greedy
//! run-length matching (distance-1 matches up to 258 bytes) — exactly the
//! redundancy checkpoint payloads have (sparse count tables, zeroed
//! regions), at a fraction of the code a full LZ77 matcher needs. The
//! output is a standards-conforming zlib stream any inflater accepts.
//!
//! The decompressor handles stored and fixed-Huffman blocks with the full
//! length/distance code tables (so it also accepts third-party `Z_FIXED`
//! streams), verifies the Adler-32 trailer, and fails closed on any
//! malformed input. Dynamic-Huffman blocks are rejected: nothing in this
//! codebase produces them, and a checkpoint restore must never guess.

use anyhow::{bail, Result};

/// Match-length code table (RFC 1951 §3.2.5): base length per code
/// 257..=285 and the number of extra bits that follow it.
const LENGTH_BASE: [u16; 29] = [
    3, 4, 5, 6, 7, 8, 9, 10, 11, 13, 15, 17, 19, 23, 27, 31, 35, 43, 51,
    59, 67, 83, 99, 115, 131, 163, 195, 227, 258,
];
const LENGTH_EXTRA: [u8; 29] = [
    0, 0, 0, 0, 0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3, 4, 4, 4,
    4, 5, 5, 5, 5, 0,
];

/// Distance code table: base distance per code 0..=29 and extra bits.
const DIST_BASE: [u16; 30] = [
    1, 2, 3, 4, 5, 7, 9, 13, 17, 25, 33, 49, 65, 97, 129, 193, 257, 385,
    513, 769, 1025, 1537, 2049, 3073, 4097, 6145, 8193, 12289, 16385,
    24577,
];
const DIST_EXTRA: [u8; 30] = [
    0, 0, 0, 0, 1, 1, 2, 2, 3, 3, 4, 4, 5, 5, 6, 6, 7, 7, 8, 8, 9, 9, 10,
    10, 11, 11, 12, 12, 13, 13,
];

const END_OF_BLOCK: u16 = 256;
const MAX_MATCH: usize = 258;
const MIN_MATCH: usize = 3;

// ---------------------------------------------------------------- writer

struct BitWriter {
    out: Vec<u8>,
    bit: u8,
    nbits: u8,
}

impl BitWriter {
    fn new() -> Self {
        Self { out: Vec::new(), bit: 0, nbits: 0 }
    }

    /// LSB-first packing (block headers, extra bits) — RFC 1951 §3.1.1.
    fn write_bits(&mut self, value: u32, n: u8) {
        for i in 0..n {
            self.bit |= (((value >> i) & 1) as u8) << self.nbits;
            self.nbits += 1;
            if self.nbits == 8 {
                self.out.push(self.bit);
                self.bit = 0;
                self.nbits = 0;
            }
        }
    }

    /// Huffman codes pack most-significant code bit first.
    fn write_huff(&mut self, code: u16, n: u8) {
        for i in (0..n).rev() {
            self.write_bits(((code >> i) & 1) as u32, 1);
        }
    }

    fn align(&mut self) {
        if self.nbits > 0 {
            self.out.push(self.bit);
            self.bit = 0;
            self.nbits = 0;
        }
    }
}

/// Fixed-Huffman (code, bit-count) for a literal/length symbol.
fn litlen_code(sym: u16) -> (u16, u8) {
    match sym {
        0..=143 => (0x30 + sym, 8),
        144..=255 => (0x190 + (sym - 144), 9),
        256..=279 => (sym - 256, 7),
        _ => (0xC0 + (sym - 280), 8),
    }
}

/// (symbol, extra-bit count, extra value) for a match length 3..=258.
fn length_symbol(len: usize) -> (u16, u8, u32) {
    debug_assert!((MIN_MATCH..=MAX_MATCH).contains(&len));
    let mut idx = LENGTH_BASE.len() - 1;
    while LENGTH_BASE[idx] as usize > len {
        idx -= 1;
    }
    (
        257 + idx as u16,
        LENGTH_EXTRA[idx],
        (len - LENGTH_BASE[idx] as usize) as u32,
    )
}

/// One fixed-Huffman final block with greedy distance-1 run matches
/// (everything after the zlib header, before the Adler-32 trailer).
fn fixed_block_body(data: &[u8]) -> Vec<u8> {
    let mut w = BitWriter::new();
    w.write_bits(1, 1); // BFINAL
    w.write_bits(1, 2); // BTYPE = 01: fixed Huffman

    let mut i = 0;
    while i < data.len() {
        if i > 0 {
            let b = data[i - 1];
            let mut run = 0;
            while i + run < data.len() && data[i + run] == b && run < MAX_MATCH
            {
                run += 1;
            }
            if run >= MIN_MATCH {
                let (sym, ebits, eval) = length_symbol(run);
                let (code, n) = litlen_code(sym);
                w.write_huff(code, n);
                w.write_bits(eval, ebits);
                w.write_huff(0, 5); // distance code 0 == distance 1
                i += run;
                continue;
            }
        }
        let (code, n) = litlen_code(data[i] as u16);
        w.write_huff(code, n);
        i += 1;
    }
    let (code, n) = litlen_code(END_OF_BLOCK);
    w.write_huff(code, n);
    w.align();
    w.out
}

/// Stored blocks only cap a 16-bit LEN each (RFC 1951 §3.2.4).
const STORED_MAX: usize = 65535;

/// Incompressible fallback: raw stored blocks, ≤ 5 bytes overhead per
/// 64 KiB instead of the fixed tree's ~6–12 % literal expansion.
fn stored_blocks_body(data: &[u8]) -> Vec<u8> {
    debug_assert!(!data.is_empty());
    let n_blocks = data.len().div_ceil(STORED_MAX);
    let mut out = Vec::with_capacity(data.len() + 5 * n_blocks);
    for (idx, chunk) in data.chunks(STORED_MAX).enumerate() {
        // BFINAL in bit 0, BTYPE=00 in bits 1-2, rest of the byte padding
        // (stored block headers are byte-aligned).
        out.push(u8::from(idx == n_blocks - 1));
        let len = chunk.len() as u16;
        out.extend_from_slice(&len.to_le_bytes());
        out.extend_from_slice(&(!len).to_le_bytes());
        out.extend_from_slice(chunk);
    }
    out
}

/// Compress `data` into a zlib stream: a fixed-Huffman block with greedy
/// distance-1 run matches, falling back to stored (raw) blocks whenever
/// that would be smaller — so incompressible payloads pay bytes of
/// overhead, not percent.
pub fn deflate(data: &[u8]) -> Vec<u8> {
    let fixed = fixed_block_body(data);
    let stored_len = data.len() + 5 * data.len().div_ceil(STORED_MAX);
    let body = if !data.is_empty() && stored_len < fixed.len() {
        stored_blocks_body(data)
    } else {
        fixed
    };
    // CM=8 (deflate), CINFO=7 (32 KiB window); FLG chosen so the header
    // passes the mod-31 check — the conventional 0x78 0x9C pair.
    let mut out = Vec::with_capacity(body.len() + 6);
    out.extend_from_slice(&[0x78, 0x9C]);
    out.extend_from_slice(&body);
    out.extend_from_slice(&super::hash::adler32(data).to_be_bytes());
    out
}

// ---------------------------------------------------------------- reader

struct BitReader<'a> {
    data: &'a [u8],
    pos: usize,
    nbits: u8,
}

impl<'a> BitReader<'a> {
    fn read_bit(&mut self) -> Result<u32> {
        let Some(&byte) = self.data.get(self.pos) else {
            bail!("unexpected end of zlib stream");
        };
        let bit = (byte >> self.nbits) & 1;
        self.nbits += 1;
        if self.nbits == 8 {
            self.nbits = 0;
            self.pos += 1;
        }
        Ok(bit as u32)
    }

    /// LSB-first field.
    fn read_bits(&mut self, n: u8) -> Result<u32> {
        let mut v = 0;
        for i in 0..n {
            v |= self.read_bit()? << i;
        }
        Ok(v)
    }

    /// Append one bit to a Huffman accumulator (MSB-first).
    fn read_huff_bit(&mut self, acc: u32) -> Result<u32> {
        Ok((acc << 1) | self.read_bit()?)
    }

    fn align(&mut self) {
        if self.nbits > 0 {
            self.nbits = 0;
            self.pos += 1;
        }
    }
}

/// Decode one fixed-Huffman literal/length symbol.
fn decode_litlen(r: &mut BitReader<'_>) -> Result<u16> {
    let mut c = 0u32;
    for _ in 0..7 {
        c = r.read_huff_bit(c)?;
    }
    if c <= 0b0010111 {
        return Ok(256 + c as u16);
    }
    c = r.read_huff_bit(c)?; // 8 bits
    if (0x30..=0xBF).contains(&c) {
        return Ok((c - 0x30) as u16);
    }
    if (0xC0..=0xC7).contains(&c) {
        return Ok(280 + (c - 0xC0) as u16);
    }
    c = r.read_huff_bit(c)?; // 9 bits
    if (0x190..=0x1FF).contains(&c) {
        return Ok(144 + (c - 0x190) as u16);
    }
    bail!("invalid fixed-Huffman literal/length code");
}

/// Decompress a zlib stream, refusing to produce more than `limit` bytes.
pub fn inflate(data: &[u8], limit: usize) -> Result<Vec<u8>> {
    if data.len() < 6 {
        bail!("zlib stream too short ({} bytes)", data.len());
    }
    let (cmf, flg) = (data[0], data[1]);
    if cmf & 0x0F != 8 {
        bail!("not a deflate stream (CM={})", cmf & 0x0F);
    }
    if (cmf as u32 * 256 + flg as u32) % 31 != 0 {
        bail!("zlib header check failed");
    }
    if flg & 0x20 != 0 {
        bail!("preset dictionaries unsupported");
    }

    let mut r = BitReader { data, pos: 2, nbits: 0 };
    let mut out: Vec<u8> = Vec::new();
    loop {
        let bfinal = r.read_bit()?;
        let btype = r.read_bits(2)?;
        match btype {
            0 => {
                // Stored block: byte-aligned LEN/NLEN then raw bytes.
                r.align();
                let Some(hdr) = data.get(r.pos..r.pos + 4) else {
                    bail!("truncated stored-block header");
                };
                let len = hdr[0] as usize | ((hdr[1] as usize) << 8);
                let nlen = hdr[2] as usize | ((hdr[3] as usize) << 8);
                if (len ^ nlen) != 0xFFFF {
                    bail!("stored-block length check failed");
                }
                r.pos += 4;
                let Some(body) = data.get(r.pos..r.pos + len) else {
                    bail!("truncated stored block");
                };
                out.extend_from_slice(body);
                r.pos += len;
                if out.len() > limit {
                    bail!("decompressed output exceeds {limit} bytes");
                }
            }
            1 => loop {
                let sym = decode_litlen(&mut r)?;
                if sym == END_OF_BLOCK {
                    break;
                }
                if sym <= 255 {
                    out.push(sym as u8);
                } else {
                    if sym > 285 {
                        bail!("invalid length symbol {sym}");
                    }
                    let idx = (sym - 257) as usize;
                    let len = LENGTH_BASE[idx] as usize
                        + r.read_bits(LENGTH_EXTRA[idx])? as usize;
                    let mut dcode = 0u32;
                    for _ in 0..5 {
                        dcode = r.read_huff_bit(dcode)?;
                    }
                    if dcode > 29 {
                        bail!("invalid distance code {dcode}");
                    }
                    let dist = DIST_BASE[dcode as usize] as usize
                        + r.read_bits(DIST_EXTRA[dcode as usize])? as usize;
                    if dist > out.len() {
                        bail!(
                            "distance {dist} reaches before stream start"
                        );
                    }
                    let start = out.len() - dist;
                    // Overlapping copies are the point (RLE): byte by byte.
                    for k in 0..len {
                        let b = out[start + k];
                        out.push(b);
                    }
                }
                if out.len() > limit {
                    bail!("decompressed output exceeds {limit} bytes");
                }
            },
            2 => bail!("dynamic-Huffman blocks unsupported"),
            _ => bail!("reserved block type"),
        }
        if bfinal == 1 {
            break;
        }
    }
    r.align();
    let Some(trailer) = data.get(r.pos..r.pos + 4) else {
        bail!("truncated adler32 trailer");
    };
    let want =
        u32::from_be_bytes([trailer[0], trailer[1], trailer[2], trailer[3]]);
    let got = super::hash::adler32(&out);
    if got != want {
        bail!("adler32 mismatch: stream {want:#010x}, payload {got:#010x}");
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    fn mixed(rng: &mut Prng, n: usize) -> Vec<u8> {
        let mut v = Vec::with_capacity(n);
        while v.len() < n {
            let run = (rng.below(64) + 1) as usize;
            let b = if rng.chance(0.5) { 0 } else { rng.next_u64() as u8 };
            v.extend(std::iter::repeat(b).take(run.min(n - v.len())));
        }
        v
    }

    #[test]
    fn round_trips() {
        let mut rng = Prng::new(1);
        let mut cases: Vec<Vec<u8>> = vec![
            vec![],
            b"a".to_vec(),
            b"ab".to_vec(),
            b"abc".to_vec(),
            vec![0u8; 32768],
            (0..=255u8).collect(),
        ];
        for _ in 0..100 {
            let n = rng.below(4096) as usize;
            cases.push(mixed(&mut rng, n));
        }
        for data in cases {
            let z = deflate(&data);
            let back = inflate(&z, data.len().max(1) * 2 + 64).unwrap();
            assert_eq!(back, data, "len {}", data.len());
        }
    }

    #[test]
    fn runs_compress_well() {
        let z = deflate(&vec![0u8; 32768]);
        assert!(z.len() < 300, "all-zero 32k compressed to {}", z.len());
        // mixed-run data compresses too
        let mut rng = Prng::new(2);
        let data = mixed(&mut rng, 65536);
        let z = deflate(&data);
        assert!(z.len() < data.len() / 4, "{} vs {}", z.len(), data.len());
    }

    #[test]
    fn incompressible_data_falls_back_to_stored_blocks() {
        // Random bytes can't beat the stored encoding; expansion must be
        // bytes of framing, not the fixed tree's ~6% literal bloat.
        let mut rng = Prng::new(4);
        let mut data = vec![0u8; 2048];
        rng.fill_bytes(&mut data);
        let z = deflate(&data);
        assert!(
            z.len() <= data.len() + 5 + 6,
            "incompressible 2 KiB expanded to {}",
            z.len()
        );
        assert_eq!(inflate(&z, data.len() * 2 + 64).unwrap(), data);
        // corruption detection holds on the stored path too
        for pos in 0..z.len() {
            let mut bad = z.clone();
            bad[pos] ^= 0xFF;
            assert!(
                inflate(&bad, data.len() * 2 + 64).is_err(),
                "stored-path flip at {pos} produced a valid stream"
            );
        }
    }

    #[test]
    fn stored_fallback_spans_multiple_blocks() {
        // > 65535 bytes forces several stored blocks (16-bit LEN each).
        let mut rng = Prng::new(5);
        let mut data = vec![0u8; 70_000];
        rng.fill_bytes(&mut data);
        let z = deflate(&data);
        assert!(z.len() <= data.len() + 2 * 5 + 6, "got {}", z.len());
        assert_eq!(inflate(&z, data.len() * 2 + 64).unwrap(), data);
    }

    #[test]
    fn header_and_trailer_are_zlib() {
        let z = deflate(b"hello hello hello hello");
        assert_eq!(z[0], 0x78);
        assert_eq!((z[0] as u32 * 256 + z[1] as u32) % 31, 0);
        let want = crate::util::hash::adler32(b"hello hello hello hello");
        assert_eq!(&z[z.len() - 4..], want.to_be_bytes());
    }

    #[test]
    fn corruption_rejected_everywhere() {
        let mut rng = Prng::new(3);
        let data = mixed(&mut rng, 2048);
        let z = deflate(&data);
        for pos in 0..z.len() {
            let mut bad = z.clone();
            bad[pos] ^= 0xFF;
            // the adler32 gate (plus structural checks) must reject every
            // flip — a "successful" decode of corrupt data is the failure
            // mode two-phase checkpointing exists to prevent
            assert!(
                inflate(&bad, data.len() * 2 + 64).is_err(),
                "flip at {pos} produced a valid stream"
            );
        }
    }

    #[test]
    fn truncation_rejected() {
        let z = deflate(&vec![7u8; 4096]);
        for cut in [0, 1, 3, z.len() / 2, z.len() - 1] {
            assert!(inflate(&z[..cut], 10_000).is_err(), "cut {cut}");
        }
    }

    #[test]
    fn limit_enforced() {
        let z = deflate(&vec![0u8; 10_000]);
        assert!(inflate(&z, 100).is_err());
        assert!(inflate(&z, 10_000).is_ok());
    }

    #[test]
    fn stored_block_decodes() {
        // Hand-built zlib stream with one stored block: "hi".
        let payload = b"hi";
        let mut z = vec![0x78, 0x9C];
        z.push(0x01); // BFINAL=1, BTYPE=00 (bits 0b001 LSB-first), aligned
        z.extend_from_slice(&[0x02, 0x00, 0xFD, 0xFF]); // LEN / NLEN
        z.extend_from_slice(payload);
        z.extend_from_slice(
            &crate::util::hash::adler32(payload).to_be_bytes(),
        );
        assert_eq!(inflate(&z, 100).unwrap(), payload);
    }

    #[test]
    fn dynamic_blocks_rejected() {
        // BFINAL=1, BTYPE=10 -> first byte 0b101 LSB-first = 0x05
        let z = [0x78, 0x9C, 0x05, 0, 0, 0, 0, 0];
        let err = inflate(&z, 100).unwrap_err().to_string();
        assert!(err.contains("dynamic"), "{err}");
    }
}
