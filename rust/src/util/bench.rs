//! Micro-bench harness (no criterion offline — DESIGN.md §8).
//!
//! `cargo bench` targets are `harness = false` binaries that use
//! [`bench_fn`] for hot-path timing (warmup + N samples + mean/p50/p95)
//! and plain experiment runs for the table/figure reproductions.

use std::time::{Duration, Instant};

/// Timing summary over samples.
#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    pub samples: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchStats {
    pub fn throughput_per_sec(&self) -> f64 {
        if self.mean.is_zero() {
            f64::INFINITY
        } else {
            1.0 / self.mean.as_secs_f64()
        }
    }
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "mean {:>10.3?}  p50 {:>10.3?}  p95 {:>10.3?}  min {:>10.3?}  \
             max {:>10.3?}  ({} samples)",
            self.mean, self.p50, self.p95, self.min, self.max, self.samples
        )
    }
}

/// Run `f` `warmup` times untimed, then `samples` timed iterations.
pub fn bench_fn<F: FnMut()>(
    warmup: usize,
    samples: usize,
    mut f: F,
) -> BenchStats {
    assert!(samples > 0);
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<Duration> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    times.sort();
    let total: Duration = times.iter().sum();
    BenchStats {
        samples,
        mean: total / samples as u32,
        p50: times[samples / 2],
        p95: times[(samples * 95 / 100).min(samples - 1)],
        min: times[0],
        max: times[samples - 1],
    }
}

/// Bench-report section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_ordered() {
        let s = bench_fn(2, 50, || {
            std::hint::black_box((0..1000u64).sum::<u64>());
        });
        assert_eq!(s.samples, 50);
        assert!(s.min <= s.p50);
        assert!(s.p50 <= s.p95);
        assert!(s.p95 <= s.max);
        assert!(s.throughput_per_sec() > 0.0);
    }

    #[test]
    #[should_panic]
    fn zero_samples_rejected() {
        bench_fn(0, 0, || {});
    }
}
