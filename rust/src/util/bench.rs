//! Micro-bench harness (no criterion offline — DESIGN.md §8).
//!
//! `cargo bench` targets are `harness = false` binaries that use
//! [`bench_fn`] for hot-path timing (warmup + N samples + mean/p50/p95)
//! and plain experiment runs for the table/figure reproductions.
//!
//! Results are machine-readable too: a [`BenchReport`] collects named
//! [`BenchStats`] (and free-form values) and writes `BENCH_<name>.json`
//! via the in-repo [`crate::json`] writer, so the perf trajectory can be
//! tracked across commits and uploaded as a CI artifact. The output
//! directory defaults to the working directory and is overridable with
//! `BENCH_JSON_DIR`.
//!
//! Emission is **key-order-deterministic and atomic**: object keys
//! serialize sorted regardless of the order `stat`/`value` were called
//! in (`json::Value` objects are `BTreeMap`s; pinned by a test below),
//! so two reports carrying the same data are byte-identical and
//! `BENCH_*.json` diffs stay meaningful across runs — and the file is
//! written via [`crate::util::atomic_write`], so an interrupted bench
//! never leaves a truncated report for CI upload steps or the shard
//! merger to trip over.

use crate::json::Value;
use std::path::PathBuf;
use std::time::{Duration, Instant};

/// Timing summary over samples.
#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    pub samples: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchStats {
    pub fn throughput_per_sec(&self) -> f64 {
        if self.mean.is_zero() {
            f64::INFINITY
        } else {
            1.0 / self.mean.as_secs_f64()
        }
    }

    /// JSON shape (nanosecond integers + derived per-second rate).
    pub fn to_json(&self) -> Value {
        let ns = |d: Duration| d.as_nanos() as u64;
        let mut v = Value::obj();
        v.set("samples", self.samples)
            .set("mean_ns", ns(self.mean))
            .set("p50_ns", ns(self.p50))
            .set("p95_ns", ns(self.p95))
            .set("min_ns", ns(self.min))
            .set("max_ns", ns(self.max))
            .set("per_sec", self.throughput_per_sec());
        v
    }
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "mean {:>10.3?}  p50 {:>10.3?}  p95 {:>10.3?}  min {:>10.3?}  \
             max {:>10.3?}  ({} samples)",
            self.mean, self.p50, self.p95, self.min, self.max, self.samples
        )
    }
}

/// Run `f` `warmup` times untimed, then `samples` timed iterations.
pub fn bench_fn<F: FnMut()>(
    warmup: usize,
    samples: usize,
    mut f: F,
) -> BenchStats {
    assert!(samples > 0);
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<Duration> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    times.sort();
    let total: Duration = times.iter().sum();
    BenchStats {
        samples,
        mean: total / samples as u32,
        p50: times[samples / 2],
        p95: times[(samples * 95 / 100).min(samples - 1)],
        min: times[0],
        max: times[samples - 1],
    }
}

/// Bench-report section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Collects a bench target's results and writes `BENCH_<name>.json`.
#[derive(Debug)]
pub struct BenchReport {
    name: String,
    root: Value,
}

impl BenchReport {
    pub fn new(name: &str) -> Self {
        let mut root = Value::obj();
        root.set("bench", name);
        Self { name: name.to_string(), root }
    }

    /// Record one timed result under `key` (dotted keys are plain keys —
    /// the object stays flat and sorted).
    pub fn stat(&mut self, key: &str, stats: &BenchStats) -> &mut Self {
        self.root.set(key, stats.to_json());
        self
    }

    /// Record a free-form value (run counts, throughput aggregates,
    /// nested summaries like `SweepDistributions::to_json`).
    pub fn value(&mut self, key: &str, v: impl Into<Value>) -> &mut Self {
        self.root.set(key, v);
        self
    }

    /// Target path: `$BENCH_JSON_DIR` (default `.`) `/BENCH_<name>.json`.
    pub fn path(&self) -> PathBuf {
        let dir = std::env::var_os("BENCH_JSON_DIR")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("."));
        dir.join(format!("BENCH_{}.json", self.name))
    }

    /// Write the canonical report atomically (temp file + rename, so a
    /// killed bench never leaves a partial `BENCH_*.json`); returns
    /// where it landed.
    pub fn write(&self) -> std::io::Result<PathBuf> {
        let path = self.path();
        crate::util::atomic_write(&path, self.to_string().as_bytes())?;
        println!("\nwrote {}", path.display());
        Ok(path)
    }
}

/// Canonical serialized form: pretty-printed JSON with sorted keys plus
/// a trailing newline. Two reports with the same contents stringify
/// byte-identically no matter the insertion order (the shape `write`
/// persists).
impl std::fmt::Display for BenchReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&crate::json::to_string_pretty(&self.root))?;
        f.write_str("\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_ordered() {
        let s = bench_fn(2, 50, || {
            std::hint::black_box((0..1000u64).sum::<u64>());
        });
        assert_eq!(s.samples, 50);
        assert!(s.min <= s.p50);
        assert!(s.p50 <= s.p95);
        assert!(s.p95 <= s.max);
        assert!(s.throughput_per_sec() > 0.0);
    }

    #[test]
    #[should_panic]
    fn zero_samples_rejected() {
        bench_fn(0, 0, || {});
    }

    #[test]
    fn report_round_trips_through_json() {
        let s = bench_fn(1, 10, || {
            std::hint::black_box((0..10_000u64).sum::<u64>());
        });
        let mut report = BenchReport::new("unit");
        report.stat("hot.loop", &s).value("runs", 10u64);
        let v = report.root.clone();
        assert_eq!(v.req_str("bench").unwrap(), "unit");
        assert_eq!(v.req_u64("runs").unwrap(), 10);
        let stat = v.get("hot.loop").unwrap();
        assert_eq!(stat.req_u64("samples").unwrap(), 10);
        assert!(stat.req_u64("mean_ns").unwrap() > 0);
        // serialized form parses back
        let text = crate::json::to_string_pretty(&v);
        assert!(crate::json::parse(&text).is_ok());
        assert!(report.path().ends_with("BENCH_unit.json"));
    }

    #[test]
    fn emission_is_key_order_deterministic() {
        // same data, opposite insertion order → byte-identical output
        let mut a = BenchReport::new("order");
        a.value("alpha", 1u64).value("zeta", 2u64).value("mid", 3u64);
        let mut b = BenchReport::new("order");
        b.value("zeta", 2u64).value("mid", 3u64).value("alpha", 1u64);
        assert_eq!(a.to_string(), b.to_string());
        // keys really come out sorted
        let text = a.to_string();
        let pos = |k: &str| text.find(k).unwrap();
        assert!(pos("alpha") < pos("bench"));
        assert!(pos("bench") < pos("mid"));
        assert!(pos("mid") < pos("zeta"));
        assert!(text.ends_with('\n'));
    }

    #[test]
    fn write_lands_atomically_in_bench_json_dir() {
        // BENCH_JSON_DIR is process-global: write to a private dir via a
        // path check only (no env mutation — tests run in parallel).
        let mut report = BenchReport::new("atomic-unit");
        report.value("k", 1u64);
        let dir = std::env::temp_dir().join(format!(
            "spoton-bench-{}-{}",
            std::process::id(),
            crate::util::next_seq()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_atomic-unit.json");
        crate::util::atomic_write(&path, report.to_string().as_bytes())
            .unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert_eq!(body, report.to_string());
        assert!(crate::json::parse(&body).is_ok());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
