//! Small self-contained utilities shared across the crate.
//!
//! The offline build environment vendors only the `xla` crate's dependency
//! closure, so the usual ecosystem crates (rand, humantime, proptest,
//! sha2, flate2, …) are re-implemented here at the size this project
//! needs: [`prng`], [`proptest`], [`hash`] (SHA-256 / CRC32 / Adler-32)
//! and [`zlib`] (checkpoint payload compression).

pub mod prng;
pub mod fmt;
pub mod proptest;
pub mod wire;
pub mod bench;
pub mod hash;
pub mod zlib;

pub use prng::Prng;

use std::sync::atomic::{AtomicU64, Ordering};

/// Monotonic process-wide sequence numbers (checkpoint ids, event ids, …).
static SEQ: AtomicU64 = AtomicU64::new(1);

/// Next process-wide unique sequence number.
pub fn next_seq() -> u64 {
    SEQ.fetch_add(1, Ordering::Relaxed)
}

/// Hex-encode bytes (lowercase).
pub fn hex(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        s.push_str(&format!("{b:02x}"));
    }
    s
}

/// SHA-256 of a byte slice, hex-encoded.
pub fn sha256_hex(bytes: &[u8]) -> String {
    hex(&hash::sha256(bytes))
}

/// CRC32 of a byte slice (fast integrity check for checkpoint payloads).
pub fn crc32(bytes: &[u8]) -> u32 {
    hash::crc32(bytes)
}

/// Write `bytes` to `path` atomically: write a uniquely-named temp
/// sibling, then rename it over the target. A reader never observes a
/// partially-written file and a crash mid-write leaves only the temp
/// file behind — the invariant that lets the shard merger
/// ([`crate::sim::shard`]) treat "parses and validates" as "complete",
/// and keeps `BENCH_*.json` whole under interrupted benches.
pub fn atomic_write(
    path: &std::path::Path,
    bytes: &[u8],
) -> std::io::Result<()> {
    let name = path
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_else(|| "file".to_string());
    // pid + process-wide sequence keeps concurrent writers (other shard
    // workers, threads in this process) off each other's temp files
    let tmp = path.with_file_name(format!(
        "{name}.tmp.{}.{}",
        std::process::id(),
        next_seq()
    ));
    std::fs::write(&tmp, bytes)?;
    std::fs::rename(&tmp, path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seq_is_monotonic() {
        let a = next_seq();
        let b = next_seq();
        assert!(b > a);
    }

    #[test]
    fn hex_encodes() {
        assert_eq!(hex(&[0x00, 0xff, 0x10]), "00ff10");
        assert_eq!(hex(&[]), "");
    }

    #[test]
    fn sha256_known_vector() {
        // sha256("abc")
        assert_eq!(
            sha256_hex(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
    }

    #[test]
    fn crc32_known_vector() {
        // crc32("123456789") = 0xCBF43926 (IEEE)
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
    }

    #[test]
    fn atomic_write_replaces_and_leaves_no_temp() {
        let dir = std::env::temp_dir()
            .join(format!("spoton-aw-{}-{}", std::process::id(), next_seq()));
        std::fs::create_dir_all(&dir).unwrap();
        let target = dir.join("out.json");
        atomic_write(&target, b"first").unwrap();
        assert_eq!(std::fs::read(&target).unwrap(), b"first");
        atomic_write(&target, b"second").unwrap();
        assert_eq!(std::fs::read(&target).unwrap(), b"second");
        // no .tmp.* siblings survive a successful write
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| {
                e.file_name().to_string_lossy().contains(".tmp.")
            })
            .collect();
        assert!(leftovers.is_empty(), "{leftovers:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
