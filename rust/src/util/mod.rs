//! Small self-contained utilities shared across the crate.
//!
//! The offline build environment vendors only the `xla` crate's dependency
//! closure, so the usual ecosystem crates (rand, humantime, proptest,
//! sha2, flate2, …) are re-implemented here at the size this project
//! needs: [`prng`], [`proptest`], [`hash`] (SHA-256 / CRC32 / Adler-32)
//! and [`zlib`] (checkpoint payload compression).

pub mod prng;
pub mod fmt;
pub mod proptest;
pub mod wire;
pub mod bench;
pub mod hash;
pub mod zlib;

pub use prng::Prng;

use std::sync::atomic::{AtomicU64, Ordering};

/// Monotonic process-wide sequence numbers (checkpoint ids, event ids, …).
static SEQ: AtomicU64 = AtomicU64::new(1);

/// Next process-wide unique sequence number.
pub fn next_seq() -> u64 {
    SEQ.fetch_add(1, Ordering::Relaxed)
}

/// Hex-encode bytes (lowercase).
pub fn hex(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        s.push_str(&format!("{b:02x}"));
    }
    s
}

/// SHA-256 of a byte slice, hex-encoded.
pub fn sha256_hex(bytes: &[u8]) -> String {
    hex(&hash::sha256(bytes))
}

/// CRC32 of a byte slice (fast integrity check for checkpoint payloads).
pub fn crc32(bytes: &[u8]) -> u32 {
    hash::crc32(bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seq_is_monotonic() {
        let a = next_seq();
        let b = next_seq();
        assert!(b > a);
    }

    #[test]
    fn hex_encodes() {
        assert_eq!(hex(&[0x00, 0xff, 0x10]), "00ff10");
        assert_eq!(hex(&[]), "");
    }

    #[test]
    fn sha256_known_vector() {
        // sha256("abc")
        assert_eq!(
            sha256_hex(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
    }

    #[test]
    fn crc32_known_vector() {
        // crc32("123456789") = 0xCBF43926 (IEEE)
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
    }
}
