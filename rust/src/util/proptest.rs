//! Minimal property-testing framework (no `proptest` crate offline).
//!
//! Deterministic, seeded case generation with greedy shrinking:
//!
//! ```no_run
//! use spoton::util::proptest::{forall, Config, shrinks_u64};
//!
//! forall(
//!     Config::default().cases(200),
//!     |rng| rng.range_u64(0, 1_000_000),
//!     shrinks_u64,
//!     |&n| {
//!         if n.checked_add(1).is_some() { Ok(()) } else { Err("overflow".into()) }
//!     },
//! );
//! ```
//!
//! On a failing case the framework greedily applies the supplied shrinker
//! until no smaller counterexample fails, then panics with the minimal
//! case and the seed that reproduces the run.

use super::prng::Prng;
use std::fmt::Debug;

/// Run configuration for [`forall`].
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 100, seed: 0x5907_0A11, max_shrink_steps: 2000 }
    }
}

impl Config {
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }
    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
}

/// No shrinking (for opaque case types).
pub fn shrink_none<T>(_: &T) -> Vec<T> {
    Vec::new()
}

/// Standard shrink candidates for a u64: 0, halves, decrements.
pub fn shrinks_u64(&n: &u64) -> Vec<u64> {
    let mut out = Vec::new();
    if n == 0 {
        return out;
    }
    out.push(0);
    out.push(n / 2);
    out.push(n - 1);
    out.dedup();
    out.retain(|&m| m != n);
    out
}

/// Standard shrink candidates for a vector: drop halves, drop single
/// elements (first/last), shrink nothing element-wise (keep it cheap).
pub fn shrinks_vec<T: Clone>(v: &Vec<T>) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    if v.is_empty() {
        return out;
    }
    out.push(Vec::new());
    out.push(v[..v.len() / 2].to_vec());
    out.push(v[v.len() / 2..].to_vec());
    if v.len() > 1 {
        out.push(v[1..].to_vec());
        out.push(v[..v.len() - 1].to_vec());
    }
    out
}

/// Run `prop` against `cases` generated values; panic with a shrunk
/// counterexample (and reproduction seed) on failure.
pub fn forall<T, G, S, P>(cfg: Config, generate: G, shrink: S, prop: P)
where
    T: Clone + Debug,
    G: Fn(&mut Prng) -> T,
    S: Fn(&T) -> Vec<T>,
    P: Fn(&T) -> Result<(), String>,
{
    let mut rng = Prng::new(cfg.seed);
    for case_idx in 0..cfg.cases {
        let value = generate(&mut rng);
        if let Err(first_err) = prop(&value) {
            // Greedy shrink.
            let mut best = value;
            let mut best_err = first_err;
            let mut steps = 0;
            'outer: loop {
                if steps >= cfg.max_shrink_steps {
                    break;
                }
                for cand in shrink(&best) {
                    steps += 1;
                    if let Err(e) = prop(&cand) {
                        best = cand;
                        best_err = e;
                        continue 'outer;
                    }
                    if steps >= cfg.max_shrink_steps {
                        break 'outer;
                    }
                }
                break;
            }
            panic!(
                "property failed (case {case_idx}, seed {:#x}):\n  \
                 counterexample: {best:?}\n  error: {best_err}",
                cfg.seed
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(
            Config::default().cases(50),
            |rng| rng.below(100),
            shrinks_u64,
            |&n| if n < 100 { Ok(()) } else { Err("oob".into()) },
        );
    }

    #[test]
    fn failing_property_shrinks_to_minimal() {
        let result = std::panic::catch_unwind(|| {
            forall(
                Config::default().cases(200),
                |rng| rng.range_u64(0, 1000),
                shrinks_u64,
                // fails for everything >= 17; minimal counterexample is 17
                |&n| if n < 17 { Ok(()) } else { Err(format!("{n} >= 17")) },
            );
        });
        let msg = match result {
            Err(e) => *e.downcast::<String>().unwrap(),
            Ok(()) => panic!("property should have failed"),
        };
        assert!(msg.contains("counterexample: 17"), "got: {msg}");
    }

    #[test]
    fn vec_shrinker_produces_smaller() {
        let v = vec![1, 2, 3, 4];
        for s in shrinks_vec(&v) {
            assert!(s.len() < v.len());
        }
        assert!(shrinks_vec::<u8>(&vec![]).is_empty());
    }

    #[test]
    fn deterministic_given_seed() {
        let collect = |seed| {
            let mut out = Vec::new();
            let mut rng = Prng::new(seed);
            for _ in 0..10 {
                out.push(rng.below(1000));
            }
            out
        };
        assert_eq!(collect(5), collect(5));
    }
}
