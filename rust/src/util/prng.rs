//! Deterministic PRNG: SplitMix64 seeding a xoshiro256** core.
//!
//! Every stochastic component in the simulator (read generation, Poisson
//! eviction plans, property-test case generation) draws from this so that
//! runs are exactly reproducible from a single `u64` seed — a requirement
//! for the bit-exact-resume test invariant (DESIGN.md §6).

/// xoshiro256** seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// One SplitMix64 step as a standalone bijective mixer: golden-ratio
/// increment + finalizer. Nearby inputs map to decorrelated outputs,
/// which is what salted sweep seed streams need ([`crate::sim::shard`]:
/// the salted seed for global index `j` must depend only on `j` and the
/// salt, never on shard boundaries).
pub fn mix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Prng {
    /// Seed deterministically from a single u64.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Panics if `n == 0`.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        // Lemire-style rejection to kill modulo bias.
        let threshold = n.wrapping_neg() % n;
        loop {
            let r = self.next_u64();
            if r >= threshold {
                return r % n;
            }
        }
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Exponentially distributed sample with the given mean (for Poisson
    /// eviction inter-arrival times).
    pub fn exp(&mut self, mean: f64) -> f64 {
        assert!(mean > 0.0);
        let mut u = self.f64();
        if u == 0.0 {
            u = f64::MIN_POSITIVE;
        }
        -mean * u.ln()
    }

    /// Random boolean with probability `p` of `true`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fill a byte buffer.
    pub fn fill_bytes(&mut self, buf: &mut [u8]) {
        for chunk in buf.chunks_mut(8) {
            let v = self.next_u64().to_le_bytes();
            chunk.copy_from_slice(&v[..chunk.len()]);
        }
    }

    /// Fork a child PRNG with a decorrelated stream (for subsystems that
    /// must not perturb each other's sequences).
    pub fn fork(&mut self, label: u64) -> Prng {
        Prng::new(self.next_u64() ^ label.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_respects_bound() {
        let mut p = Prng::new(7);
        for n in [1u64, 2, 3, 10, 1000] {
            for _ in 0..200 {
                assert!(p.below(n) < n);
            }
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut p = Prng::new(9);
        for _ in 0..1000 {
            let x = p.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn exp_mean_roughly_right() {
        let mut p = Prng::new(11);
        let n = 20_000;
        let mean = 90.0;
        let sum: f64 = (0..n).map(|_| p.exp(mean)).sum();
        let got = sum / n as f64;
        assert!((got - mean).abs() < mean * 0.05, "mean {got}");
    }

    #[test]
    fn below_is_roughly_uniform() {
        let mut p = Prng::new(13);
        let mut hist = [0u32; 8];
        for _ in 0..8000 {
            hist[p.below(8) as usize] += 1;
        }
        for h in hist {
            assert!((800..1200).contains(&h), "bucket {h}");
        }
    }

    #[test]
    fn fork_decorrelates() {
        let mut root = Prng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn mix64_is_deterministic_and_decorrelated() {
        assert_eq!(mix64(0), mix64(0));
        // sequential inputs must not produce correlated outputs: count
        // matching bits between neighbours — should hover around 32
        for x in 0u64..64 {
            let diff = (mix64(x) ^ mix64(x + 1)).count_ones();
            assert!((10..=54).contains(&diff), "x={x} diff={diff}");
        }
        // matches Prng::new's first word (same SplitMix64 step)
        let mut p = Prng::new(42);
        let first = p.next_u64();
        let mut q = Prng::new(42);
        assert_eq!(first, q.next_u64());
    }

    #[test]
    fn fill_bytes_covers_tail() {
        let mut p = Prng::new(17);
        let mut buf = [0u8; 13];
        p.fill_bytes(&mut buf);
        assert!(buf.iter().any(|&b| b != 0));
    }
}
