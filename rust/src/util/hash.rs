//! In-repo digest primitives: SHA-256, CRC32 (IEEE) and Adler-32.
//!
//! The offline build environment vendors no hashing crates, so — like the
//! PRNG and the property-test framework — the digests the checkpoint
//! engine depends on are implemented here at the size this project needs.
//! All three are verified against published test vectors in the unit
//! tests below; SHA-256 follows FIPS 180-4, CRC32 is the reflected IEEE
//! polynomial (the one zlib/PNG use), Adler-32 is RFC 1950's checksum
//! (used by [`super::zlib`]).

/// SHA-256 round constants: frac(cbrt(p)) * 2^32 for the first 64 primes.
const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5,
    0x3956c25b, 0x59f111f1, 0x923f82a4, 0xab1c5ed5,
    0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3,
    0x72be5d74, 0x80deb1fe, 0x9bdc06a7, 0xc19bf174,
    0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc,
    0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da,
    0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7,
    0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967,
    0x27b70a85, 0x2e1b2138, 0x4d2c6dfc, 0x53380d13,
    0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85,
    0xa2bfe8a1, 0xa81a664b, 0xc24b8b70, 0xc76c51a3,
    0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070,
    0x19a4c116, 0x1e376c08, 0x2748774c, 0x34b0bcb5,
    0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
    0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208,
    0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
];

/// Initial hash state: frac(sqrt(p)) * 2^32 for the first 8 primes.
const H0: [u32; 8] = [
    0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a,
    0x510e527f, 0x9b05688c, 0x1f83d9ab, 0x5be0cd19,
];

/// One FIPS 180-4 compression round over a 64-byte block.
fn compress_block(h: &mut [u32; 8], block: &[u8]) {
    let mut w = [0u32; 64];
    for (i, word) in block.chunks_exact(4).enumerate() {
        w[i] = u32::from_be_bytes([word[0], word[1], word[2], word[3]]);
    }
    for i in 16..64 {
        let s0 = w[i - 15].rotate_right(7)
            ^ w[i - 15].rotate_right(18)
            ^ (w[i - 15] >> 3);
        let s1 = w[i - 2].rotate_right(17)
            ^ w[i - 2].rotate_right(19)
            ^ (w[i - 2] >> 10);
        w[i] = w[i - 16]
            .wrapping_add(s0)
            .wrapping_add(w[i - 7])
            .wrapping_add(s1);
    }
    let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut hh] = *h;
    for i in 0..64 {
        let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
        let ch = (e & f) ^ (!e & g);
        let t1 = hh
            .wrapping_add(s1)
            .wrapping_add(ch)
            .wrapping_add(K[i])
            .wrapping_add(w[i]);
        let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
        let maj = (a & b) ^ (a & c) ^ (b & c);
        let t2 = s0.wrapping_add(maj);
        hh = g;
        g = f;
        f = e;
        e = d.wrapping_add(t1);
        d = c;
        c = b;
        b = a;
        a = t1.wrapping_add(t2);
    }
    for (s, v) in h.iter_mut().zip([a, b, c, d, e, f, g, hh]) {
        *s = s.wrapping_add(v);
    }
}

/// SHA-256 digest of a byte slice. Streams the input block by block —
/// checkpoint payloads are hashed on every write, so the digest must not
/// allocate a second copy of the payload.
pub fn sha256(data: &[u8]) -> [u8; 32] {
    let mut h = H0;
    for block in data.chunks_exact(64) {
        compress_block(&mut h, block);
    }

    // FIPS 180-4 padding for the tail: 0x80, zeros, then the 64-bit
    // big-endian bit length — one final block, or two when the tail
    // leaves fewer than 8 spare bytes.
    let rem = data.len() % 64;
    let tail = &data[data.len() - rem..];
    let mut buf = [0u8; 128];
    buf[..rem].copy_from_slice(tail);
    buf[rem] = 0x80;
    let total = if rem < 56 { 64 } else { 128 };
    let bit_len = (data.len() as u64).wrapping_mul(8);
    buf[total - 8..total].copy_from_slice(&bit_len.to_be_bytes());
    for block in buf[..total].chunks_exact(64) {
        compress_block(&mut h, block);
    }

    let mut out = [0u8; 32];
    for (chunk, word) in out.chunks_exact_mut(4).zip(h) {
        chunk.copy_from_slice(&word.to_be_bytes());
    }
    out
}

/// Reflected-IEEE CRC32 lookup table (polynomial 0xEDB88320).
const CRC_TABLE: [u32; 256] = build_crc_table();

const fn build_crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut bit = 0;
        while bit < 8 {
            c = if c & 1 != 0 { (c >> 1) ^ 0xEDB8_8320 } else { c >> 1 };
            bit += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

/// CRC32 (IEEE, reflected — the zlib/PNG variant).
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = u32::MAX;
    for &b in data {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ u32::MAX
}

/// Adler-32 (RFC 1950), the zlib stream checksum.
pub fn adler32(data: &[u8]) -> u32 {
    const MOD: u32 = 65521;
    let mut a: u32 = 1;
    let mut b: u32 = 0;
    // Deferred modulo: 5552 is the largest n with worst-case sums in u32.
    for chunk in data.chunks(5552) {
        for &byte in chunk {
            a += byte as u32;
            b += a;
        }
        a %= MOD;
        b %= MOD;
    }
    (b << 16) | a
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sha256_fips_vectors() {
        let hex = |d: [u8; 32]| crate::util::hex(&d);
        assert_eq!(
            hex(sha256(b"")),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            hex(sha256(b"abc")),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            hex(sha256(
                b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"
            )),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
    }

    #[test]
    fn sha256_padding_boundaries() {
        // Each length crosses a different padding case (55/56/63/64/65).
        let known = [
            (55usize,
             "9f4390f8d30c2dd92ec9f095b65e2b9ae9b0a925a5258e241c9f1e910f734318"),
            (56,
             "b35439a4ac6f0948b6d6f9e3c6af0f5f590ce20f1bde7090ef7970686ec6738a"),
            (63,
             "7d3e74a05d7db15bce4ad9ec0658ea98e3f06eeecf16b4c6fff2da457ddc2f34"),
            (64,
             "ffe054fe7ae0cb6dc65c3af9b61d5209f439851db43d0ba5997337df154668eb"),
            (65,
             "635361c48bb9eab14198e76ea8ab7f1a41685d6ad62aa9146d301d4f17eb0ae0"),
        ];
        for (n, want) in known {
            assert_eq!(crate::util::hex(&sha256(&vec![b'a'; n])), want, "len {n}");
        }
    }

    #[test]
    fn crc32_known_vectors() {
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"),
                   0x414FA339);
    }

    #[test]
    fn adler32_known_vectors() {
        assert_eq!(adler32(b""), 1);
        assert_eq!(adler32(b"Wikipedia"), 0x11E60398);
        // chunked path (> 5552 bytes) matches the naive definition
        let big = vec![0xABu8; 20_000];
        let naive = {
            let (mut a, mut b) = (1u64, 0u64);
            for &byte in &big {
                a = (a + byte as u64) % 65521;
                b = (b + a) % 65521;
            }
            ((b << 16) | a) as u32
        };
        assert_eq!(adler32(&big), naive);
    }
}
