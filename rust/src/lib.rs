//! # Spot-on — fault-tolerant long-running workloads on cloud spot instances
//!
//! Production-quality reproduction of *"Spot-on: A Checkpointing Framework
//! for Fault-Tolerant Long-running Workloads on Cloud Spot Instances"*
//! (CS.DC 2022) as a three-layer Rust + JAX + Pallas stack.
//!
//! The paper's contribution is a **checkpoint coordinator** that runs beside
//! a long-running workload on a spot instance: it schedules periodic
//! checkpoints (application-native or transparent/CRIU-style), watches the
//! cloud metadata service for eviction notices, takes opportunistic
//! *termination checkpoints* on a notice, and — once the scale set has
//! provisioned a replacement instance — finds the most recent valid
//! checkpoint on shared storage and resumes the workload.
//!
//! ## Layer map
//!
//! * **Layer 3 (this crate)** — the coordinator ([`coordinator`]) plus every
//!   substrate it needs, built around a **discrete-event core**: virtual
//!   time and the deterministic event queue live in [`simclock`]
//!   ([`simclock::EventQueue`] with FIFO tie-breaking and token
//!   cancellation), and the experiment engine ([`sim::engine`]) runs each
//!   scenario as a chain of typed `SimEvent`s — step completions,
//!   checkpoint commits, eviction notices, coordinator poll ticks,
//!   provisioning completions — dispatched to per-concern handlers (the
//!   coordinator's reactions live in [`coordinator::handlers`]). Around
//!   it: a virtual cloud with spot semantics ([`cloud`]), whose
//!   [`cloud::fleet`] layer runs each experiment on N replacement pools —
//!   per-pool price books, eviction plans and provisioning delays — with
//!   a pluggable placement policy deciding where every replacement lands
//!   (`ReplacementRequested → PlacementDecided → InstanceProvisioned` on
//!   the queue, cost attributed per pool), and whose [`cloud::trace`]
//!   layer makes those prices *move*: empirical or seeded-random-walk
//!   spot-price histories (files under `traces/`) replayed as
//!   `PoolPriceChanged` events, so placement re-decides as the market
//!   shifts and billing splits instance uptime piecewise at every price
//!   boundary. Traced pools are **bid-aware spot markets**: a pool (or
//!   the [`autoscale`] subsystem's bid policies — fixed-margin,
//!   percentile-of-trace à la Khatua, reliability-aware à la
//!   Voorsluys) attaches a maximum hourly price to each launch, and
//!   when a price epoch crosses the bid the market *outbids* the
//!   instance — the eviction notice fires from the crossing and
//!   billing stops at the crossing boundary. Above the market sits the
//!   hybrid spot/on-demand [`autoscale::Autoscaler`]: driven by queue
//!   depth, bid viability and time-to-deadline, it shifts
//!   deadline-SLA jobs (`[job] deadline_mins`) onto a never-evicting
//!   on-demand fallback pool, and [`report::frontier`] tabulates the
//!   resulting cost-vs-attainment frontier. The checkpoint cadence
//!   itself is tuned online by the
//!   [`policy`] subsystem: pluggable interval controllers (fixed,
//!   Young/Daly from an online per-pool eviction-rate estimator,
//!   cost-aware scaling with the traced price) consulted at every step
//!   boundary, clamped so noisy estimates can't thrash; metered shared storage
//!   ([`storage`]), the checkpoint engine ([`checkpoint`]; compressible
//!   images can rescue termination checkpoints from short notice windows
//!   via [`checkpoint::compress`]), an IMDS-compatible scheduled-events
//!   HTTP service ([`httpd`], [`cloud::imds_http`]), billing/pricing
//!   ([`cloud::billing`], [`cloud::pricing`]), run instrumentation
//!   ([`metrics`]), and two cluster schedulers: the event-driven
//!   multi-slot requeue scheduler ([`sched`]) that interleaves whole
//!   jobs as atomic attempts (the Slurm/LSF path of paper §II), and the
//!   **multiplexed cluster engine** ([`sim::cluster`]) that runs
//!   thousands of jobs *concurrently* as subject-tagged events on one
//!   queue around one live capacity-bounded fleet — evictions, price
//!   epochs and placement evidence accumulate cluster-wide, jobs queue
//!   FIFO-per-priority when pools fill, and throughput is measured in
//!   events/sec (`BENCH_cluster.json`). [`sim::SimDriver`] is the stable
//!   facade over the engine; [`sim::legacy`] preserves the pre-refactor
//!   loop as the equivalence oracle; [`sim::sweep`] fans thousands of
//!   seeded runs across threads (merged deterministically by seed) and
//!   [`report::distribution`] reduces the population to mean/percentile
//!   summaries — distributions, not point estimates, for the paper's
//!   figures and the placement-policy comparisons. Past one process,
//!   [`sim::shard`] shards a sweep across worker OS processes
//!   (`spoton sweep` / `sweep-worker`): a fingerprinted
//!   [`sim::shard::ShardPlan`] partitions seed range × controller
//!   matrix, each worker writes a rename-atomic artifact into a
//!   `shards/<run_id>/` run directory beside a checkpointed
//!   `MANIFEST.json`, interrupted sweeps resume (only missing or
//!   corrupt shards re-run; persistent failures dead-letter with their
//!   cell list), and the merge is byte-identical to the in-process
//!   sweep at any process count. All of it is chaos-hardened:
//!   [`sim::chaos`] draws seeded fault plans (coordinated eviction
//!   storms, IMDS outages with degraded poll cadence) and
//!   [`storage::chaos`] fault-wraps the checkpoint store (failed, torn,
//!   silently-corrupted and slow writes), while the coordinator retries
//!   commits under bounded jittered backoff ([`coordinator::backoff`])
//!   and restores fall back past unverifiable generations; `[expect]`
//!   scenario sections ([`report::expect`], evaluated by
//!   `spoton check`) plus the [`report::faults`] ledger make chaos
//!   scenarios self-checking in CI.
//! * **Layer 2/1 (build-time Python)** — the MiniMeta metagenome-assembly
//!   analog workload's compute: JAX stage functions calling Pallas kernels,
//!   AOT-lowered to HLO-text artifacts (`python/compile/`), executed from
//!   Rust through PJRT ([`runtime`]) by the [`workload::assembler`] driver.
//!   The PJRT binding is gated behind the `pjrt` cargo feature (the `xla`
//!   crate and its native library are only present on kernel-provisioned
//!   machines); without it, the whole coordination/simulation stack and
//!   the sleeper calibration workload remain fully functional.
//!
//! Python never runs on the request path: `make artifacts` is the only
//! Python invocation, after which the `spoton` binary is self-contained.
//!
//! ## Quick start
//!
//! ```no_run
//! use spoton::sim::experiment::Experiment;
//! use spoton::simclock::SimDuration;
//!
//! // Row 5 of the paper's Table I: spot instance, evictions every 90 min,
//! // transparent checkpointing every 30 min.
//! let exp = Experiment::table1()
//!     .eviction_every(SimDuration::from_mins(90))
//!     .transparent(SimDuration::from_mins(30));
//! let result = exp.run_sleeper().unwrap();
//! println!("{}", result.summary());
//! ```
//!
//! See `examples/` for runnable end-to-end drivers and `rust/benches/` for
//! the Table I / Fig 2 / Fig 3 reproductions.
//!
//! ## Running the linter
//!
//! The repo enforces its determinism & robustness contract statically
//! with an in-repo analysis pass (see [`analysis`] for the rule set and
//! rationale):
//!
//! ```text
//! spoton lint                  # scan rust/src, rust/benches, rust/tests, examples
//! spoton lint --json           # deterministic sorted-key JSON for CI artifacts
//! spoton lint --fix-baseline   # ratchet analysis/BASELINE.json to current counts
//! ```
//!
//! CI's `lint-smoke` job fails on any finding that is new relative to the
//! committed baseline — and on any baseline entry that no longer matches
//! a finding, so the baseline can only shrink deliberately.

#![deny(unsafe_code)]

pub mod util;
pub mod json;
pub mod config;
pub mod simclock;
pub mod httpd;
pub mod cloud;
pub mod storage;
pub mod checkpoint;
pub mod runtime;
pub mod workload;
pub mod coordinator;
pub mod policy;
pub mod autoscale;
pub mod sim;
pub mod metrics;
pub mod report;
pub mod sched;
pub mod analysis;
