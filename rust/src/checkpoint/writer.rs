//! Two-phase checkpoint writer with crash-point injection and
//! deadline-bounded (opportunistic) writes.
//!
//! Write order: `payload.bin` → `manifest.json` → `COMMIT`. Only the
//! marker makes a checkpoint visible to [`super::CheckpointStore`], so
//! death at any intermediate point (instance reclaimed mid-transfer)
//! degrades to "checkpoint absent", never "checkpoint corrupt but
//! accepted".
//!
//! Termination checkpoints race the eviction deadline (paper §II:
//! "opportunistic due to their possible failures caused by the short
//! eviction notification"). [`CheckpointWriter::write_with_budget`] models
//! the race: if the modeled transfer cannot finish inside the budget the
//! writer produces exactly the partial on-share state a mid-transfer
//! death would leave.

use super::manifest::{CheckpointManifest, CkptKind, MANIFEST_VERSION};
use super::{ckpt_dir};
use crate::simclock::{SimDuration, SimTime};
use crate::storage::SharedStore;
use crate::workload::{Snapshot, Workload};
use anyhow::Result;

/// Injectable crash points for fault-tolerance tests.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CrashPoint {
    /// No injected failure.
    #[default]
    None,
    /// Die before anything reaches the share.
    BeforePayload,
    /// Die mid-payload: a truncated payload.bin exists.
    MidPayload,
    /// Payload written, manifest missing.
    BeforeManifest,
    /// Payload + manifest written, COMMIT missing.
    BeforeCommit,
}

/// Result of a deadline-bounded write.
#[derive(Debug, Clone)]
pub enum WriteOutcome {
    /// Fully committed.
    Committed { manifest: CheckpointManifest, cost: SimDuration },
    /// Ran out of budget mid-transfer; a partial (invalid) checkpoint may
    /// exist on the share. `cost` is the time burned before death.
    Partial { cost: SimDuration },
}

impl WriteOutcome {
    pub fn committed(&self) -> Option<&CheckpointManifest> {
        match self {
            WriteOutcome::Committed { manifest, .. } => Some(manifest),
            WriteOutcome::Partial { .. } => None,
        }
    }

    pub fn cost(&self) -> SimDuration {
        match self {
            WriteOutcome::Committed { cost, .. }
            | WriteOutcome::Partial { cost } => *cost,
        }
    }
}

/// Monotonic checkpoint id allocator + writer.
#[derive(Debug, Default)]
pub struct CheckpointWriter {
    next_id: u64,
    pub crash_point: CrashPoint,
    /// Scratch for the manifest/COMMIT object keys, reused across writes
    /// (the payload key must be owned — it lands in the manifest).
    key_buf: String,
}

impl CheckpointWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Resume id allocation above everything already on the share (a new
    /// instance must not reuse ids).
    pub fn resume_after(&mut self, max_existing_id: Option<u64>) {
        if let Some(m) = max_existing_id {
            self.next_id = self.next_id.max(m + 1);
        }
    }

    fn build_manifest(
        id: u64,
        kind: CkptKind,
        now: SimTime,
        workload: &dyn Workload,
        snapshot: &Snapshot,
        payload_key: &str,
    ) -> CheckpointManifest {
        let p = workload.progress();
        CheckpointManifest {
            version: MANIFEST_VERSION,
            id,
            kind,
            created_at_ms: now.as_millis(),
            workload: workload.name().to_string(),
            stage: p.stage,
            step_in_stage: p.step_in_stage,
            total_steps: p.total_steps,
            payload_key: payload_key.to_string(),
            payload_len: snapshot.bytes.len() as u64,
            payload_crc32: crate::util::crc32(&snapshot.bytes),
            payload_sha256: crate::util::sha256_hex(&snapshot.bytes),
            charged_bytes: snapshot.charged_bytes,
            fingerprint: workload.fingerprint(),
        }
    }

    /// Write a checkpoint of `workload` (no deadline). Returns the
    /// committed manifest and the total virtual cost, or — under an
    /// injected crash point — the partial state and cost so far.
    pub fn write(
        &mut self,
        store: &mut dyn SharedStore,
        now: SimTime,
        kind: CkptKind,
        workload: &dyn Workload,
        snapshot: &Snapshot,
    ) -> Result<WriteOutcome> {
        self.write_with_budget(store, now, kind, workload, snapshot, None)
    }

    /// Write with an optional time budget (the eviction-notice race).
    pub fn write_with_budget(
        &mut self,
        store: &mut dyn SharedStore,
        now: SimTime,
        kind: CkptKind,
        workload: &dyn Workload,
        snapshot: &Snapshot,
        budget: Option<SimDuration>,
    ) -> Result<WriteOutcome> {
        use std::fmt::Write as _;
        let id = self.next_id;
        self.next_id += 1;
        let dir = ckpt_dir(id, kind);
        let payload_key = format!("{dir}/payload.bin");

        if self.crash_point == CrashPoint::BeforePayload {
            return Ok(WriteOutcome::Partial { cost: SimDuration::ZERO });
        }

        // The payload transfer dominates cost; check it against the budget
        // *before* transferring (the coordinator knows the image size and
        // share bandwidth up front — same estimate a CRIU pre-dump makes).
        let payload_cost = store.transfer_cost(snapshot.charged_bytes);
        let over_budget =
            budget.map_or(false, |b| payload_cost > b);
        if over_budget || self.crash_point == CrashPoint::MidPayload {
            // Mid-transfer death: a truncated payload lands on the share.
            let burn = budget.unwrap_or(payload_cost);
            let frac = if payload_cost.is_zero() {
                0.0
            } else {
                (burn.as_millis() as f64 / payload_cost.as_millis() as f64)
                    .min(1.0)
            };
            let keep = (snapshot.bytes.len() as f64 * frac) as usize;
            let partial = &snapshot.bytes[..keep.min(snapshot.bytes.len())];
            let charged =
                (snapshot.charged_bytes as f64 * frac) as u64;
            // Best effort; if even this fails the share just has less.
            let _ = store.put_sized(&payload_key, partial, charged);
            return Ok(WriteOutcome::Partial { cost: burn });
        }

        let mut cost = store.put_sized(
            &payload_key,
            &snapshot.bytes,
            snapshot.charged_bytes,
        )?;

        if self.crash_point == CrashPoint::BeforeManifest {
            return Ok(WriteOutcome::Partial { cost });
        }

        let manifest =
            Self::build_manifest(id, kind, now, workload, snapshot, &payload_key);
        self.key_buf.clear();
        let _ = write!(self.key_buf, "{dir}/manifest.json");
        cost += store.put(&self.key_buf, manifest.to_json_string().as_bytes())?;

        if self.crash_point == CrashPoint::BeforeCommit {
            return Ok(WriteOutcome::Partial { cost });
        }

        self.key_buf.clear();
        let _ = write!(self.key_buf, "{dir}/COMMIT");
        cost += store.put(&self.key_buf, b"1")?;

        // Budget check over the full sequence: the manifest/commit objects
        // are tiny but still take latency; a budget that can't cover them
        // means the commit never landed.
        if let Some(b) = budget {
            if cost > b {
                // Roll the visible commit back: the instance died during
                // the final latency window, so the marker never hit disk.
                // Re-derive the key rather than trusting key_buf still
                // holds it — deleting a stale key here would leave a
                // committed marker for a checkpoint the instance died
                // writing.
                self.key_buf.clear();
                let _ = write!(self.key_buf, "{dir}/COMMIT");
                let _ = store.delete(&self.key_buf);
                return Ok(WriteOutcome::Partial { cost: b });
            }
        }

        Ok(WriteOutcome::Committed { manifest, cost })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::{BlobStore, SharedStore, TransferModel};
    use crate::workload::sleeper::{Sleeper, SleeperCfg};

    fn setup() -> (BlobStore, Sleeper, CheckpointWriter) {
        (
            BlobStore::for_tests(),
            Sleeper::new(SleeperCfg::small(), 7),
            CheckpointWriter::new(),
        )
    }

    #[test]
    fn committed_write_produces_three_objects() {
        let (mut store, mut w, mut writer) = setup();
        for _ in 0..5 {
            w.step().unwrap();
        }
        let snap = w.snapshot().unwrap();
        let out = writer
            .write(&mut store, SimTime::from_secs(100), CkptKind::Periodic, &w,
                   &snap)
            .unwrap();
        let m = out.committed().expect("committed");
        assert_eq!(m.id, 0);
        assert_eq!(m.total_steps, 5);
        assert!(store.exists("ckpt/0000000000-periodic/payload.bin"));
        assert!(store.exists("ckpt/0000000000-periodic/manifest.json"));
        assert!(store.exists("ckpt/0000000000-periodic/COMMIT"));
        assert!(out.cost() > SimDuration::ZERO);
        // payload verifies
        let (payload, _) =
            store.get("ckpt/0000000000-periodic/payload.bin").unwrap();
        m.verify_payload(&payload).unwrap();
    }

    #[test]
    fn ids_monotonic_and_resumable() {
        let (mut store, w, mut writer) = setup();
        let snap = w.snapshot().unwrap();
        for expect in 0..3u64 {
            let out = writer
                .write(&mut store, SimTime::ZERO, CkptKind::Periodic, &w, &snap)
                .unwrap();
            assert_eq!(out.committed().unwrap().id, expect);
        }
        let mut writer2 = CheckpointWriter::new();
        writer2.resume_after(Some(2));
        let out = writer2
            .write(&mut store, SimTime::ZERO, CkptKind::Periodic, &w, &snap)
            .unwrap();
        assert_eq!(out.committed().unwrap().id, 3);
    }

    #[test]
    fn crash_points_leave_partial_state() {
        let (_, w, _) = setup();
        let snap = w.snapshot().unwrap();
        let cases = [
            (CrashPoint::BeforePayload, false, false, false),
            (CrashPoint::MidPayload, true, false, false),
            (CrashPoint::BeforeManifest, true, false, false),
            (CrashPoint::BeforeCommit, true, true, false),
        ];
        for (cp, payload, manifest, commit) in cases {
            let mut store = BlobStore::for_tests();
            let mut writer = CheckpointWriter::new();
            writer.crash_point = cp;
            let out = writer
                .write(&mut store, SimTime::ZERO, CkptKind::Termination, &w,
                       &snap)
                .unwrap();
            assert!(out.committed().is_none(), "{cp:?} must not commit");
            let dir = "ckpt/0000000000-termination";
            assert_eq!(
                store.exists(&format!("{dir}/payload.bin")),
                payload,
                "{cp:?} payload"
            );
            assert_eq!(
                store.exists(&format!("{dir}/manifest.json")),
                manifest,
                "{cp:?} manifest"
            );
            assert_eq!(
                store.exists(&format!("{dir}/COMMIT")),
                commit,
                "{cp:?} commit"
            );
        }
    }

    #[test]
    fn budget_race_models_notice_deadline() {
        let (_, w, _) = setup();
        // 3 GiB at 250 MiB/s ≈ 12.3 s
        let snap = w.snapshot().unwrap();
        let mut store = BlobStore::new(
            TransferModel {
                bandwidth_mib_s: 250.0,
                latency: SimDuration::from_millis(20),
            },
            None,
        );
        let mut writer = CheckpointWriter::new();
        // 30 s notice: fits
        let out = writer
            .write_with_budget(
                &mut store,
                SimTime::ZERO,
                CkptKind::Termination,
                &w,
                &snap,
                Some(SimDuration::from_secs(30)),
            )
            .unwrap();
        assert!(out.committed().is_some(), "30s notice must fit 3GiB");
        // 5 s notice: cannot fit — partial, truncated payload on share
        let out2 = writer
            .write_with_budget(
                &mut store,
                SimTime::ZERO,
                CkptKind::Termination,
                &w,
                &snap,
                Some(SimDuration::from_secs(5)),
            )
            .unwrap();
        match out2 {
            WriteOutcome::Partial { cost } => {
                assert_eq!(cost, SimDuration::from_secs(5));
            }
            other => panic!("expected partial, got {other:?}"),
        }
        let (partial, _) = store
            .get("ckpt/0000000001-termination/payload.bin")
            .unwrap();
        assert!(partial.len() < snap.bytes.len());
        assert!(!store.exists("ckpt/0000000001-termination/COMMIT"));
    }

    #[test]
    fn zero_budget_writes_nothing_useful() {
        let (_, w, _) = setup();
        let snap = w.snapshot().unwrap();
        let mut store = BlobStore::for_tests();
        let mut writer = CheckpointWriter::new();
        let out = writer
            .write_with_budget(
                &mut store,
                SimTime::ZERO,
                CkptKind::Termination,
                &w,
                &snap,
                Some(SimDuration::ZERO),
            )
            .unwrap();
        assert!(out.committed().is_none());
    }
}
