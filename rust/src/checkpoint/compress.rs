//! Checkpoint payload compression.
//!
//! CRIU images and assembler intermediates compress well (sparse count
//! tables, zeroed regions); compressing before the NFS transfer trades
//! CPU for transfer time — directly shrinking the termination-checkpoint
//! race window against the 30 s notice (see `ablation_notice`). Framed
//! with a magic + original length so restores are self-describing and
//! uncompressed payloads from older runs keep working.

use crate::util::zlib;
use anyhow::{bail, Context, Result};

/// Frame magic ("SPZ1").
const MAGIC: [u8; 4] = *b"SPZ1";

/// Maximum decompressed size we will accept (defense against a corrupt
/// length field allocating unbounded memory).
const MAX_DECOMPRESSED: u64 = 64 << 30;

/// Compress a checkpoint payload (zlib frame, in-repo codec).
pub fn compress(payload: &[u8]) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(payload.len() / 2 + 16);
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(&zlib::deflate(payload));
    Ok(out)
}

/// Is this buffer a compressed frame?
pub fn is_compressed(data: &[u8]) -> bool {
    data.len() >= 12 && data[..4] == MAGIC
}

/// Decompress a frame produced by [`compress`]; passes through
/// uncompressed payloads untouched (back-compat with shares written
/// before compression was enabled).
pub fn decompress(data: &[u8]) -> Result<Vec<u8>> {
    if !is_compressed(data) {
        return Ok(data.to_vec());
    }
    let header: [u8; 8] = data[4..12]
        .try_into()
        .context("compressed frame header truncated")?;
    let expected = u64::from_le_bytes(header);
    if expected > MAX_DECOMPRESSED {
        bail!("compressed frame claims absurd size {expected}");
    }
    let out = zlib::inflate(&data[12..], expected as usize)?;
    if out.len() as u64 != expected {
        bail!(
            "decompressed {} bytes, frame header claims {expected}",
            out.len()
        );
    }
    Ok(out)
}

/// Compress and report the achieved ratio in one pass (the
/// termination-notice race path needs both and must not deflate twice).
pub fn compress_with_ratio(payload: &[u8]) -> Result<(Vec<u8>, f64)> {
    let compressed = compress(payload)?;
    let ratio = if payload.is_empty() {
        1.0
    } else {
        compressed.len() as f64 / payload.len() as f64
    };
    Ok((compressed, ratio))
}

/// Compression ratio estimate on a sample (used by the coordinator to
/// decide whether compressing shrinks the termination-race window:
/// effective transfer size = charged_bytes × ratio).
pub fn ratio(payload: &[u8]) -> Result<f64> {
    if payload.is_empty() {
        return Ok(1.0);
    }
    Ok(compress_with_ratio(payload)?.1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    #[test]
    fn round_trip_sparse_payload() {
        // count-table-like: mostly zeros
        let mut payload = vec![0u8; 64 * 1024];
        let mut rng = Prng::new(1);
        for _ in 0..500 {
            let i = rng.below(payload.len() as u64) as usize;
            payload[i] = rng.next_u64() as u8;
        }
        let framed = compress(&payload).unwrap();
        assert!(is_compressed(&framed));
        assert!(
            framed.len() < payload.len() / 4,
            "sparse data should compress >4x, got {}/{}",
            framed.len(),
            payload.len()
        );
        assert_eq!(decompress(&framed).unwrap(), payload);
    }

    #[test]
    fn round_trip_incompressible_payload() {
        let mut payload = vec![0u8; 8 * 1024];
        Prng::new(2).fill_bytes(&mut payload);
        let framed = compress(&payload).unwrap();
        assert_eq!(decompress(&framed).unwrap(), payload);
    }

    #[test]
    fn passthrough_uncompressed() {
        let raw = b"legacy uncompressed checkpoint payload";
        assert!(!is_compressed(raw));
        assert_eq!(decompress(raw).unwrap(), raw.to_vec());
    }

    #[test]
    fn corrupt_frames_rejected() {
        let payload = vec![7u8; 4096];
        let mut framed = compress(&payload).unwrap();
        // tamper with the compressed body
        let n = framed.len();
        framed[n - 5] ^= 0xff;
        assert!(decompress(&framed).is_err());
        // tamper with the length header
        let mut framed2 = compress(&payload).unwrap();
        framed2[4] ^= 0x01;
        assert!(decompress(&framed2).is_err());
        // absurd length
        let mut framed3 = compress(&payload).unwrap();
        framed3[4..12].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(decompress(&framed3).is_err());
        // truncated
        let framed4 = compress(&payload).unwrap();
        assert!(decompress(&framed4[..framed4.len() / 2]).is_err());
    }

    #[test]
    fn empty_payload() {
        let framed = compress(&[]).unwrap();
        assert_eq!(decompress(&framed).unwrap(), Vec::<u8>::new());
        assert_eq!(ratio(&[]).unwrap(), 1.0);
    }

    #[test]
    fn ratio_reflects_compressibility() {
        let sparse = vec![0u8; 32 * 1024];
        let mut dense = vec![0u8; 32 * 1024];
        Prng::new(3).fill_bytes(&mut dense);
        let rs = ratio(&sparse).unwrap();
        let rd = ratio(&dense).unwrap();
        assert!(rs < 0.01, "all-zero ratio {rs}");
        assert!(rd > 0.9, "random ratio {rd}");
    }

    #[test]
    fn prop_round_trip_random_payloads() {
        use crate::util::proptest::{forall, shrinks_vec, Config};
        forall(
            Config::default().cases(100),
            |rng| {
                let n = rng.below(4096) as usize;
                let mut v = vec![0u8; n];
                // mix of runs and noise
                let mut i = 0;
                while i < n {
                    let run = (rng.below(64) + 1) as usize;
                    let b = if rng.chance(0.5) {
                        0
                    } else {
                        rng.next_u64() as u8
                    };
                    for j in i..(i + run).min(n) {
                        v[j] = b;
                    }
                    i += run;
                }
                v
            },
            shrinks_vec,
            |payload| {
                let framed =
                    compress(payload).map_err(|e| e.to_string())?;
                let back =
                    decompress(&framed).map_err(|e| e.to_string())?;
                if &back != payload {
                    return Err("round trip mismatch".into());
                }
                Ok(())
            },
        );
    }
}
