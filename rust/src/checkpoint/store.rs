//! Checkpoint discovery, validation, latest-valid search and GC.
//!
//! After a replacement instance comes up, "the checkpoint coordinator
//! automatically searches for the most recent valid checkpoint and
//! resumes the workload" (paper §II). Validity is strict: COMMIT marker
//! present, manifest parses, payload exists with matching length, CRC32
//! and SHA-256 — partial termination checkpoints and bit-rot both fail
//! closed.

use super::manifest::CheckpointManifest;
use super::CKPT_PREFIX;
use crate::simclock::SimDuration;
use crate::storage::SharedStore;
use anyhow::{Context, Result};

/// One discovered checkpoint and its validation status.
#[derive(Debug, Clone)]
pub struct CkptEntry {
    pub dir: String,
    pub manifest: Option<CheckpointManifest>,
    /// `None` until validated; `Some(Err)` describes why it's unusable.
    pub problem: Option<String>,
}

impl CkptEntry {
    pub fn is_valid(&self) -> bool {
        self.manifest.is_some() && self.problem.is_none()
    }
}

/// Stateless facade over the share's `ckpt/` namespace.
pub struct CheckpointStore;

impl CheckpointStore {
    /// All checkpoint directories (valid or not), ascending by id.
    pub fn scan(store: &mut dyn SharedStore) -> Result<Vec<CkptEntry>> {
        let keys = store.list(&format!("{CKPT_PREFIX}/"))?;
        let mut dirs: Vec<String> = keys
            .iter()
            .filter_map(|k| {
                let rest = k.strip_prefix(&format!("{CKPT_PREFIX}/"))?;
                let dir = rest.split('/').next()?;
                Some(format!("{CKPT_PREFIX}/{dir}"))
            })
            .collect();
        dirs.sort();
        dirs.dedup();

        let mut entries = Vec::new();
        for dir in dirs {
            entries.push(Self::inspect(store, &dir));
        }
        Ok(entries)
    }

    /// Validate one checkpoint directory.
    fn inspect(store: &mut dyn SharedStore, dir: &str) -> CkptEntry {
        let commit_key = format!("{dir}/COMMIT");
        let manifest_key = format!("{dir}/manifest.json");
        if !store.exists(&commit_key) {
            return CkptEntry {
                dir: dir.to_string(),
                manifest: None,
                problem: Some("missing COMMIT marker (partial write)".into()),
            };
        }
        let manifest = match store.get(&manifest_key) {
            Ok((bytes, _)) => match std::str::from_utf8(&bytes)
                .map_err(|e| e.to_string())
                .and_then(|s| {
                    CheckpointManifest::parse(s).map_err(|e| e.to_string())
                }) {
                Ok(m) => m,
                Err(e) => {
                    return CkptEntry {
                        dir: dir.to_string(),
                        manifest: None,
                        problem: Some(format!("manifest unreadable: {e}")),
                    }
                }
            },
            Err(e) => {
                return CkptEntry {
                    dir: dir.to_string(),
                    manifest: None,
                    problem: Some(format!("manifest missing: {e}")),
                }
            }
        };
        // Payload integrity.
        let problem = match store.get(&manifest.payload_key) {
            Ok((payload, _)) => {
                manifest.verify_payload(&payload).err().map(|e| e.to_string())
            }
            Err(e) => Some(format!("payload missing: {e}")),
        };
        CkptEntry { dir: dir.to_string(), manifest: Some(manifest), problem }
    }

    /// The most recent valid checkpoint, optionally filtered by restore
    /// surface (`Some(true)` = transparent only, `Some(false)` =
    /// application-native only).
    pub fn latest_valid(
        store: &mut dyn SharedStore,
        transparent: Option<bool>,
    ) -> Result<Option<CheckpointManifest>> {
        let entries = Self::scan(store)?;
        Ok(entries
            .into_iter()
            .filter(|e| e.is_valid())
            .filter_map(|e| e.manifest)
            .filter(|m| {
                transparent.map_or(true, |t| m.kind.is_transparent() == t)
            })
            .max_by_key(|m| m.id))
    }

    /// Highest id present on the share (valid or not) — id allocation must
    /// never collide with leftovers.
    pub fn max_id(store: &mut dyn SharedStore) -> Result<Option<u64>> {
        let entries = Self::scan(store)?;
        Ok(entries
            .iter()
            .filter_map(|e| {
                // parse the id from the directory name even when the
                // manifest is unreadable
                e.dir
                    .strip_prefix(&format!("{CKPT_PREFIX}/"))?
                    .split('-')
                    .next()?
                    .parse::<u64>()
                    .ok()
            })
            .max())
    }

    /// Fetch + verify the payload for a manifest; returns (bytes, cost).
    pub fn fetch_payload(
        store: &mut dyn SharedStore,
        manifest: &CheckpointManifest,
    ) -> Result<(Vec<u8>, SimDuration)> {
        let (payload, cost) =
            store.get(&manifest.payload_key).with_context(|| {
                format!(
                    "fetching payload '{}' of generation {}",
                    manifest.payload_key, manifest.id
                )
            })?;
        manifest.verify_payload(&payload).with_context(|| {
            format!("verifying payload of generation {}", manifest.id)
        })?;
        Ok((payload, cost))
    }

    /// Delete all but the newest `keep` *valid* checkpoints (and every
    /// invalid leftover). Returns the number of directories removed.
    pub fn gc(store: &mut dyn SharedStore, keep: usize) -> Result<usize> {
        let entries = Self::scan(store)?;
        let mut valid: Vec<&CkptEntry> =
            entries.iter().filter(|e| e.is_valid()).collect();
        // directory names are `ckpt/{id:010}-{kind}`, so the
        // lexicographic dir order IS ascending id order — no need to
        // assume a manifest is present
        valid.sort_by(|a, b| a.dir.cmp(&b.dir));
        let cutoff = valid.len().saturating_sub(keep);
        let doomed: Vec<String> = valid[..cutoff]
            .iter()
            .map(|e| e.dir.clone())
            .chain(
                entries
                    .iter()
                    .filter(|e| !e.is_valid())
                    .map(|e| e.dir.clone()),
            )
            .collect();
        let mut removed = 0;
        for dir in doomed {
            for key in store.list(&format!("{dir}/"))? {
                store.delete(&key)?;
            }
            removed += 1;
        }
        Ok(removed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::writer::{CheckpointWriter, CrashPoint};
    use crate::checkpoint::CkptKind;
    use crate::simclock::SimTime;
    use crate::storage::BlobStore;
    use crate::workload::sleeper::{Sleeper, SleeperCfg};
    use crate::workload::Workload;

    fn write_n(
        store: &mut BlobStore,
        writer: &mut CheckpointWriter,
        w: &mut Sleeper,
        n: usize,
        kind: CkptKind,
    ) -> Vec<CheckpointManifest> {
        let mut out = Vec::new();
        for i in 0..n {
            for _ in 0..3 {
                w.step().unwrap();
            }
            let snap = w.snapshot().unwrap();
            let m = writer
                .write(store, SimTime::from_secs(i as u64 * 100), kind, w, &snap)
                .unwrap()
                .committed()
                .unwrap()
                .clone();
            out.push(m);
        }
        out
    }

    #[test]
    fn latest_valid_finds_newest() {
        let mut store = BlobStore::for_tests();
        let mut writer = CheckpointWriter::new();
        let mut w = Sleeper::new(SleeperCfg::small(), 3);
        let ms = write_n(&mut store, &mut writer, &mut w, 3, CkptKind::Periodic);
        let latest =
            CheckpointStore::latest_valid(&mut store, None).unwrap().unwrap();
        assert_eq!(latest.id, ms[2].id);
        assert_eq!(latest.total_steps, 9);
    }

    #[test]
    fn partial_writes_are_skipped() {
        let mut store = BlobStore::for_tests();
        let mut writer = CheckpointWriter::new();
        let mut w = Sleeper::new(SleeperCfg::small(), 3);
        write_n(&mut store, &mut writer, &mut w, 2, CkptKind::Periodic);
        // a failed termination checkpoint lands after them
        writer.crash_point = CrashPoint::MidPayload;
        for _ in 0..3 {
            w.step().unwrap();
        }
        let snap = w.snapshot().unwrap();
        let out = writer
            .write(&mut store, SimTime::from_secs(999), CkptKind::Termination,
                   &w, &snap)
            .unwrap();
        assert!(out.committed().is_none());
        // scan sees 3 dirs, 1 invalid
        let entries = CheckpointStore::scan(&mut store).unwrap();
        assert_eq!(entries.len(), 3);
        assert_eq!(entries.iter().filter(|e| e.is_valid()).count(), 2);
        let bad = entries.iter().find(|e| !e.is_valid()).unwrap();
        assert!(bad.problem.as_ref().unwrap().contains("COMMIT"));
        // latest valid is the second periodic, not the newer partial
        let latest =
            CheckpointStore::latest_valid(&mut store, None).unwrap().unwrap();
        assert_eq!(latest.total_steps, 6);
        // but max_id sees the partial's id (no id reuse)
        assert_eq!(CheckpointStore::max_id(&mut store).unwrap(), Some(2));
    }

    #[test]
    fn corrupted_payload_detected() {
        let mut store = BlobStore::for_tests();
        let mut writer = CheckpointWriter::new();
        let mut w = Sleeper::new(SleeperCfg::small(), 3);
        let ms = write_n(&mut store, &mut writer, &mut w, 1, CkptKind::Periodic);
        store.corrupt(&ms[0].payload_key, 5).unwrap();
        let entries = CheckpointStore::scan(&mut store).unwrap();
        assert!(!entries[0].is_valid());
        assert!(entries[0].problem.as_ref().unwrap().contains("crc"));
        assert!(CheckpointStore::latest_valid(&mut store, None)
            .unwrap()
            .is_none());
        // fetch_payload double-checks too
        assert!(
            CheckpointStore::fetch_payload(&mut store, &ms[0]).is_err()
        );
    }

    #[test]
    fn truncated_payload_detected() {
        let mut store = BlobStore::for_tests();
        let mut writer = CheckpointWriter::new();
        let mut w = Sleeper::new(SleeperCfg::small(), 3);
        let ms = write_n(&mut store, &mut writer, &mut w, 1, CkptKind::Periodic);
        store.truncate(&ms[0].payload_key, 4).unwrap();
        let entries = CheckpointStore::scan(&mut store).unwrap();
        assert!(!entries[0].is_valid());
        assert!(entries[0].problem.as_ref().unwrap().contains("length"));
    }

    #[test]
    fn surface_filter() {
        let mut store = BlobStore::for_tests();
        let mut writer = CheckpointWriter::new();
        let mut w = Sleeper::new(SleeperCfg::small(), 3);
        write_n(&mut store, &mut writer, &mut w, 1, CkptKind::AppNative);
        write_n(&mut store, &mut writer, &mut w, 1, CkptKind::Periodic);
        let t = CheckpointStore::latest_valid(&mut store, Some(true))
            .unwrap()
            .unwrap();
        assert_eq!(t.kind, CkptKind::Periodic);
        let a = CheckpointStore::latest_valid(&mut store, Some(false))
            .unwrap()
            .unwrap();
        assert_eq!(a.kind, CkptKind::AppNative);
    }

    #[test]
    fn gc_keeps_newest_and_purges_invalid() {
        let mut store = BlobStore::for_tests();
        let mut writer = CheckpointWriter::new();
        let mut w = Sleeper::new(SleeperCfg::small(), 3);
        write_n(&mut store, &mut writer, &mut w, 5, CkptKind::Periodic);
        writer.crash_point = CrashPoint::BeforeCommit;
        let snap = w.snapshot().unwrap();
        writer
            .write(&mut store, SimTime::ZERO, CkptKind::Termination, &w, &snap)
            .unwrap();
        let removed = CheckpointStore::gc(&mut store, 2).unwrap();
        assert_eq!(removed, 4); // 3 old valid + 1 invalid
        let entries = CheckpointStore::scan(&mut store).unwrap();
        assert_eq!(entries.len(), 2);
        assert!(entries.iter().all(|e| e.is_valid()));
        // newest survived
        let latest =
            CheckpointStore::latest_valid(&mut store, None).unwrap().unwrap();
        assert_eq!(latest.total_steps, 15);
    }

    #[test]
    fn empty_share_is_fine() {
        let mut store = BlobStore::for_tests();
        assert!(CheckpointStore::scan(&mut store).unwrap().is_empty());
        assert!(CheckpointStore::latest_valid(&mut store, None)
            .unwrap()
            .is_none());
        assert_eq!(CheckpointStore::max_id(&mut store).unwrap(), None);
        assert_eq!(CheckpointStore::gc(&mut store, 3).unwrap(), 0);
    }

    #[test]
    fn prop_latest_valid_is_max_id_of_valid() {
        use crate::util::proptest::{forall, shrink_none, Config};
        forall(
            Config::default().cases(60),
            |rng| {
                // sequence of (commit: bool) checkpoint writes
                (0..rng.range_u64(0, 10))
                    .map(|_| rng.chance(0.7))
                    .collect::<Vec<bool>>()
            },
            shrink_none,
            |commits| {
                let mut store = BlobStore::for_tests();
                let mut writer = CheckpointWriter::new();
                let mut w = Sleeper::new(SleeperCfg::small(), 1);
                let mut last_valid_id = None;
                for &ok in commits {
                    w.step().map_err(|e| e.to_string())?;
                    writer.crash_point = if ok {
                        CrashPoint::None
                    } else {
                        CrashPoint::BeforeCommit
                    };
                    let snap = w.snapshot().map_err(|e| e.to_string())?;
                    let out = writer
                        .write(
                            &mut store,
                            SimTime::ZERO,
                            CkptKind::Periodic,
                            &w,
                            &snap,
                        )
                        .map_err(|e| e.to_string())?;
                    if let Some(m) = out.committed() {
                        last_valid_id = Some(m.id);
                    }
                }
                let got = CheckpointStore::latest_valid(&mut store, None)
                    .map_err(|e| e.to_string())?
                    .map(|m| m.id);
                if got != last_valid_id {
                    return Err(format!(
                        "latest_valid {got:?} != expected {last_valid_id:?}"
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn fetch_payload_error_names_generation_and_key() {
        // Regression: a payload that disappears between scan and fetch
        // is an error whose context names the generation and the key —
        // not a panic, and not an anonymous I/O error.
        use crate::storage::SharedStore;
        let mut store = BlobStore::for_tests();
        let mut writer = CheckpointWriter::new();
        let mut w = Sleeper::new(SleeperCfg::small(), 3);
        let ms = write_n(&mut store, &mut writer, &mut w, 1, CkptKind::Periodic);
        let m = &ms[0];
        store.delete(&m.payload_key).unwrap();
        let err = CheckpointStore::fetch_payload(&mut store, m)
            .expect_err("missing payload is an error, not a panic");
        let msg = format!("{err:#}");
        assert!(msg.contains(&format!("generation {}", m.id)), "{msg}");
        assert!(msg.contains(&m.payload_key), "{msg}");
    }

    #[test]
    fn corrupt_payload_error_names_generation() {
        let mut store = BlobStore::for_tests();
        let mut writer = CheckpointWriter::new();
        let mut w = Sleeper::new(SleeperCfg::small(), 3);
        let ms = write_n(&mut store, &mut writer, &mut w, 1, CkptKind::Periodic);
        let m = &ms[0];
        store.corrupt(&m.payload_key, 0).unwrap();
        let err = CheckpointStore::fetch_payload(&mut store, m)
            .expect_err("corrupt payload fails verification");
        let msg = format!("{err:#}");
        assert!(msg.contains(&format!("generation {}", m.id)), "{msg}");
    }

    #[test]
    fn gc_orders_by_directory_and_tolerates_invalid_entries() {
        // Regression: gc used to sort valid entries by unwrapping their
        // manifests; it now orders by the zero-padded directory name.
        // An entry whose manifest is damaged must still be collected.
        let mut store = BlobStore::for_tests();
        let mut writer = CheckpointWriter::new();
        let mut w = Sleeper::new(SleeperCfg::small(), 3);
        let ms = write_n(&mut store, &mut writer, &mut w, 3, CkptKind::Periodic);
        let key = format!(
            "{}/manifest.json",
            crate::checkpoint::ckpt_dir(ms[1].id, CkptKind::Periodic)
        );
        store.truncate(&key, 4).unwrap();
        let removed = CheckpointStore::gc(&mut store, 1).unwrap();
        // oldest valid generation + the invalid middle one
        assert_eq!(removed, 2);
        let latest =
            CheckpointStore::latest_valid(&mut store, None).unwrap().unwrap();
        assert_eq!(latest.id, ms[2].id);
    }
}
