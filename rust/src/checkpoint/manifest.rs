//! Checkpoint manifests: metadata + integrity anchors.

use crate::json::{self, Value};
use anyhow::{bail, Result};

/// Why this checkpoint was taken (paper §II).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CkptKind {
    /// Scheduled periodic checkpoint (transparent method).
    Periodic,
    /// Opportunistic checkpoint on an eviction notice.
    Termination,
    /// The application's own milestone checkpoint.
    AppNative,
}

impl CkptKind {
    pub fn as_str(self) -> &'static str {
        match self {
            CkptKind::Periodic => "periodic",
            CkptKind::Termination => "termination",
            CkptKind::AppNative => "application",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "periodic" => CkptKind::Periodic,
            "termination" => CkptKind::Termination,
            "application" => CkptKind::AppNative,
            other => bail!("unknown checkpoint kind '{other}'"),
        })
    }

    /// Does this checkpoint restore through the transparent surface?
    pub fn is_transparent(self) -> bool {
        matches!(self, CkptKind::Periodic | CkptKind::Termination)
    }
}

/// Manifest schema version.
pub const MANIFEST_VERSION: u64 = 1;

/// Everything needed to find, validate and restore one checkpoint.
#[derive(Debug, Clone, PartialEq)]
pub struct CheckpointManifest {
    pub version: u64,
    pub id: u64,
    pub kind: CkptKind,
    /// Virtual creation time (ms).
    pub created_at_ms: u64,
    /// Workload identity — a restore refuses a mismatched workload.
    pub workload: String,
    /// Captured progress.
    pub stage: u32,
    pub step_in_stage: u64,
    pub total_steps: u64,
    /// Payload location + integrity.
    pub payload_key: String,
    pub payload_len: u64,
    pub payload_crc32: u32,
    pub payload_sha256: String,
    /// Modeled transfer size (DESIGN.md §6).
    pub charged_bytes: u64,
    /// Workload state fingerprint at capture (resume verification).
    pub fingerprint: u64,
}

impl CheckpointManifest {
    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("version", self.version)
            .set("id", self.id)
            .set("kind", self.kind.as_str())
            .set("created_at_ms", self.created_at_ms)
            .set("workload", self.workload.as_str())
            .set("stage", self.stage as u64)
            .set("step_in_stage", self.step_in_stage)
            .set("total_steps", self.total_steps)
            .set("payload_key", self.payload_key.as_str())
            .set("payload_len", self.payload_len)
            .set("payload_crc32", self.payload_crc32 as u64)
            .set("payload_sha256", self.payload_sha256.as_str())
            .set("charged_bytes", self.charged_bytes)
            // u64 fingerprints can exceed f64-exact range; store as hex.
            .set("fingerprint_hex", format!("{:016x}", self.fingerprint));
        v
    }

    pub fn to_json_string(&self) -> String {
        json::to_string_pretty(&self.to_json())
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let version = v.req_u64("version")?;
        if version != MANIFEST_VERSION {
            bail!("unsupported manifest version {version}");
        }
        let fp_hex = v.req_str("fingerprint_hex")?;
        let fingerprint = u64::from_str_radix(fp_hex, 16)
            .map_err(|_| anyhow::anyhow!("bad fingerprint hex '{fp_hex}'"))?;
        Ok(Self {
            version,
            id: v.req_u64("id")?,
            kind: CkptKind::parse(v.req_str("kind")?)?,
            created_at_ms: v.req_u64("created_at_ms")?,
            workload: v.req_str("workload")?.to_string(),
            stage: v.req_u64("stage")? as u32,
            step_in_stage: v.req_u64("step_in_stage")?,
            total_steps: v.req_u64("total_steps")?,
            payload_key: v.req_str("payload_key")?.to_string(),
            payload_len: v.req_u64("payload_len")?,
            payload_crc32: v.req_u64("payload_crc32")? as u32,
            payload_sha256: v.req_str("payload_sha256")?.to_string(),
            charged_bytes: v.req_u64("charged_bytes")?,
            fingerprint,
        })
    }

    pub fn parse(text: &str) -> Result<Self> {
        let v = json::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
        Self::from_json(&v)
    }

    /// Check a payload against the recorded integrity anchors.
    pub fn verify_payload(&self, payload: &[u8]) -> Result<()> {
        if payload.len() as u64 != self.payload_len {
            bail!(
                "payload length mismatch: {} != recorded {}",
                payload.len(),
                self.payload_len
            );
        }
        let crc = crate::util::crc32(payload);
        if crc != self.payload_crc32 {
            bail!(
                "payload crc mismatch: {crc:#010x} != recorded {:#010x}",
                self.payload_crc32
            );
        }
        let sha = crate::util::sha256_hex(payload);
        if sha != self.payload_sha256 {
            bail!("payload sha256 mismatch");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk() -> CheckpointManifest {
        let payload = b"the state";
        CheckpointManifest {
            version: MANIFEST_VERSION,
            id: 42,
            kind: CkptKind::Termination,
            created_at_ms: 5_400_000,
            workload: "minimeta".into(),
            stage: 2,
            step_in_stage: 17,
            total_steps: 97,
            payload_key: "ckpt/0000000042-termination/payload.bin".into(),
            payload_len: payload.len() as u64,
            payload_crc32: crate::util::crc32(payload),
            payload_sha256: crate::util::sha256_hex(payload),
            charged_bytes: 3 << 30,
            fingerprint: 0xDEAD_BEEF_F00D_CAFE,
        }
    }

    #[test]
    fn json_round_trip() {
        let m = mk();
        let text = m.to_json_string();
        let back = CheckpointManifest::parse(&text).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn big_fingerprint_survives_json() {
        // u64 > 2^53 would corrupt through f64; the hex field must not.
        let mut m = mk();
        m.fingerprint = u64::MAX - 1;
        let back = CheckpointManifest::parse(&m.to_json_string()).unwrap();
        assert_eq!(back.fingerprint, u64::MAX - 1);
    }

    #[test]
    fn verify_payload_catches_tampering() {
        let m = mk();
        m.verify_payload(b"the state").unwrap();
        assert!(m.verify_payload(b"the stat").is_err()); // short
        assert!(m.verify_payload(b"the statf").is_err()); // flipped
        assert!(m.verify_payload(b"the state!").is_err()); // long
    }

    #[test]
    fn kind_round_trip_and_transparency() {
        for k in [CkptKind::Periodic, CkptKind::Termination, CkptKind::AppNative]
        {
            assert_eq!(CkptKind::parse(k.as_str()).unwrap(), k);
        }
        assert!(CkptKind::Periodic.is_transparent());
        assert!(CkptKind::Termination.is_transparent());
        assert!(!CkptKind::AppNative.is_transparent());
        assert!(CkptKind::parse("criu").is_err());
    }

    #[test]
    fn rejects_future_versions_and_junk() {
        let mut v = mk().to_json();
        v.set("version", 999u64);
        assert!(CheckpointManifest::from_json(&v).is_err());
        assert!(CheckpointManifest::parse("{}").is_err());
        assert!(CheckpointManifest::parse("not json").is_err());
        let mut v2 = mk().to_json();
        v2.set("fingerprint_hex", "zznotahex");
        assert!(CheckpointManifest::from_json(&v2).is_err());
    }
}
