//! The checkpoint engine: durable, integrity-checked state captures on
//! shared storage.
//!
//! Layout on the share (one directory per checkpoint):
//!
//! ```text
//! ckpt/0000000042-transparent/payload.bin      the serialized snapshot
//! ckpt/0000000042-transparent/manifest.json    metadata + checksums
//! ckpt/0000000042-transparent/COMMIT           two-phase commit marker
//! ```
//!
//! A checkpoint is **valid** iff all three objects exist, the manifest
//! parses, and the payload matches both its recorded length and checksums.
//! The COMMIT marker is written last, so an instance dying at any point
//! mid-write (the paper's "opportunistic" termination checkpoints that
//! may fail on a short notice, §II) leaves an *invalid* checkpoint that
//! [`store::CheckpointStore`] skips — never a silently-corrupt restore.
//! [`writer::CheckpointWriter`] exposes crash points to tests.

pub mod manifest;
pub mod writer;
pub mod store;
pub mod compress;

pub use manifest::{CheckpointManifest, CkptKind};
pub use store::CheckpointStore;
pub use writer::{CheckpointWriter, CrashPoint, WriteOutcome};

/// Shared-store key prefix all checkpoints live under.
pub const CKPT_PREFIX: &str = "ckpt";

/// Directory key for a checkpoint id + kind.
pub fn ckpt_dir(id: u64, kind: CkptKind) -> String {
    format!("{CKPT_PREFIX}/{id:010}-{}", kind.as_str())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dir_layout_sorts_numerically() {
        // zero-padded ids keep lexicographic order == numeric order
        let a = ckpt_dir(9, CkptKind::Periodic);
        let b = ckpt_dir(10, CkptKind::Termination);
        let c = ckpt_dir(100, CkptKind::AppNative);
        assert!(a < b && b < c);
        assert_eq!(a, "ckpt/0000000009-periodic");
        assert_eq!(b, "ckpt/0000000010-termination");
        assert_eq!(c, "ckpt/0000000100-application");
    }
}
