//! `spoton` — CLI for the Spot-on checkpointing framework.
//!
//! Subcommands (hand-rolled parser; no clap in the offline crate set):
//!
//! ```text
//! spoton run --scenario cfg.toml [--workload sleeper|minimeta]
//!            [--artifacts DIR] [--share DIR] [--timeline]
//! spoton table1 [--workload sleeper|minimeta] [--artifacts DIR]
//! spoton serve-metadata [--notice-secs 30]
//! spoton simulate-eviction --url http://127.0.0.1:PORT --resource vm-0
//! spoton coordinator --share DIR --instance vm-0 --events-url URL
//! spoton artifacts-info [--artifacts DIR]
//! spoton generate-reads [--count 8] [--seed 2022]
//! ```

use anyhow::{bail, Context, Result};
use spoton::cloud::imds_http::ImdsHttp;
use spoton::config::ScenarioConfig;
use spoton::coordinator::realtime::Transport;
use spoton::coordinator::{
    CheckpointPolicy, RealtimeCoordinator, RealtimeParams,
};
use spoton::report;
use spoton::runtime::Runtime;
use spoton::sim::experiment::Experiment;
use spoton::storage::{NfsStore, TransferModel};
use spoton::workload::reads::{ReadGen, ReadGenCfg};
use spoton::workload::sleeper::{Sleeper, SleeperCfg};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// Trivial `--key value` / `--flag` argument map.
struct Args {
    cmd: String,
    kv: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    fn parse() -> Result<Self> {
        let mut it = std::env::args().skip(1);
        let cmd = it.next().unwrap_or_else(|| "help".into());
        let mut kv = HashMap::new();
        let mut flags = Vec::new();
        let rest: Vec<String> = it.collect();
        let mut i = 0;
        while i < rest.len() {
            let a = &rest[i];
            let key = a
                .strip_prefix("--")
                .with_context(|| format!("unexpected argument '{a}'"))?;
            if i + 1 < rest.len() && !rest[i + 1].starts_with("--") {
                kv.insert(key.to_string(), rest[i + 1].clone());
                i += 2;
            } else {
                flags.push(key.to_string());
                i += 1;
            }
        }
        Ok(Self { cmd, kv, flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.kv.get(key).map(String::as_str)
    }

    fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

fn artifacts_dir(args: &Args) -> PathBuf {
    args.get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(spoton::runtime::default_artifacts_dir)
}

fn main() -> Result<()> {
    let args = Args::parse()?;
    match args.cmd.as_str() {
        "run" => cmd_run(&args),
        "table1" => cmd_table1(&args),
        "serve-metadata" => cmd_serve_metadata(&args),
        "simulate-eviction" => cmd_simulate_eviction(&args),
        "coordinator" => cmd_coordinator(&args),
        "artifacts-info" => cmd_artifacts_info(&args),
        "generate-reads" => cmd_generate_reads(&args),
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `spoton help`)"),
    }
}

const HELP: &str = "\
spoton — fault-tolerant long-running workloads on cloud spot instances

USAGE:
  spoton run --scenario cfg.toml [--workload sleeper|minimeta]
             [--artifacts DIR] [--share DIR] [--timeline]
  spoton table1 [--workload sleeper|minimeta] [--artifacts DIR]
  spoton serve-metadata [--notice-secs 30]
  spoton simulate-eviction --url http://HOST:PORT --resource vm-0
  spoton coordinator --share DIR --instance vm-0 [--events-url URL]
  spoton artifacts-info [--artifacts DIR]
  spoton generate-reads [--count 8] [--seed 2022]
";

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = match args.get("scenario") {
        Some(path) => ScenarioConfig::load(Path::new(path))?,
        None => ScenarioConfig::default(),
    };
    let workload = args.get("workload").unwrap_or(cfg.workload.kind.as_str());
    let exp = Experiment { cfg: cfg.clone() };
    let result = match workload {
        "sleeper" => exp.run_sleeper()?,
        "minimeta" => {
            let dir = artifacts_dir(args);
            let rt = Rc::new(RefCell::new(Runtime::load(&dir)?));
            match args.get("share") {
                Some(share) => exp.run_minimeta_on_nfs(rt, Path::new(share))?,
                None => exp.run_minimeta(rt)?,
            }
        }
        other => bail!("unknown workload '{other}'"),
    };
    println!("{}", result.summary());
    println!("\nPer-stage wall time:");
    for (label, d) in &result.stage_times {
        println!("  {label:<6} {d}");
    }
    println!("\nInvoice:\n{}", result.invoice);
    if args.flag("timeline") {
        println!("Timeline:\n{}", result.timeline);
    }
    Ok(())
}

fn cmd_table1(args: &Args) -> Result<()> {
    let workload = args.get("workload").unwrap_or("sleeper");
    let rows = report::paper_rows();
    let mut results = Vec::new();
    let rt = if workload == "minimeta" {
        let dir = artifacts_dir(args);
        Some(Rc::new(RefCell::new(Runtime::load(&dir)?)))
    } else {
        None
    };
    for row in rows {
        eprintln!(
            "running {} ({} / {} / {})…",
            row.id, row.spoton, row.eviction, row.checkpoint
        );
        let exp = row.experiment();
        let result = match &rt {
            Some(rt) => exp.run_minimeta(rt.clone())?,
            None => exp.run_sleeper()?,
        };
        results.push((row, result));
    }
    println!("\nTable I — execution time of the metaSPAdes-analog workload");
    println!("(measured via the {workload} workload)\n");
    print!("{}", report::render_comparison(&results));
    Ok(())
}

fn cmd_serve_metadata(args: &Args) -> Result<()> {
    let notice: u64 = args
        .get("notice-secs")
        .unwrap_or("30")
        .parse()
        .context("bad --notice-secs")?;
    let imds = ImdsHttp::spawn(notice)?;
    println!("scheduled-events endpoint: {}", imds.events_url());
    println!(
        "inject an eviction with:\n  spoton simulate-eviction --url {} \
         --resource vm-0",
        imds.base_url()
    );
    println!("serving… (Ctrl-C to stop)");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_simulate_eviction(args: &Args) -> Result<()> {
    let url = args.get("url").context("--url required")?;
    let resource = args.get("resource").context("--resource required")?;
    let (status, body) = spoton::httpd::http_post(
        &format!("{url}/admin/simulate-eviction?resource={resource}"),
        "",
    )?;
    if status != 200 {
        bail!("simulate-eviction failed ({status}): {body}");
    }
    println!("eviction scheduled: {body}");
    Ok(())
}

fn cmd_coordinator(args: &Args) -> Result<()> {
    let share = args.get("share").context("--share required")?;
    let instance = args.get("instance").unwrap_or("vm-0");
    let mut store = NfsStore::open(
        Path::new(share),
        TransferModel {
            bandwidth_mib_s: 250.0,
            latency: spoton::simclock::SimDuration::from_millis(20),
        },
        None,
    )?;
    let mut workload = Sleeper::new(SleeperCfg::small(), 2022);
    let policy = CheckpointPolicy::new(
        spoton::config::CheckpointMethodCfg::Transparent {
            interval: spoton::simclock::SimDuration::from_secs(5),
        },
    );
    let mut coord = RealtimeCoordinator::new(
        instance,
        policy,
        RealtimeParams {
            poll_interval: std::time::Duration::from_millis(500),
            periodic_interval: Some(std::time::Duration::from_secs(5)),
            run_timeout: std::time::Duration::from_secs(600),
            keep_checkpoints: 3,
        },
    );
    let transport = match args.get("events-url") {
        Some(url) => Transport::Http { events_url: url.to_string() },
        None => {
            bail!("--events-url required (start `spoton serve-metadata`)")
        }
    };
    let outcome = coord.run(&mut workload, &mut store, &transport)?;
    println!("coordinator outcome: {outcome:?}");
    println!("timeline:\n{}", coord.timeline);
    Ok(())
}

fn cmd_artifacts_info(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let mut rt = Runtime::load(&dir)?;
    let g = rt.geometry().clone();
    println!("artifacts dir: {}", dir.display());
    println!("platform: {}", rt.platform());
    println!(
        "geometry: B={} L={} RC={} tile={}x{} taps={} ks={:?}",
        g.num_buckets,
        g.read_len,
        g.reads_per_call,
        g.read_tile,
        g.bucket_tile,
        2 * g.denoise_half_width + 1,
        g.ks
    );
    let names: Vec<String> = rt.manifest().artifacts.keys().cloned().collect();
    for name in names {
        let start = std::time::Instant::now();
        rt.executable(&name)?;
        println!("  {name}: compiled in {:?}", start.elapsed());
    }
    Ok(())
}

fn cmd_generate_reads(args: &Args) -> Result<()> {
    let count: u64 =
        args.get("count").unwrap_or("8").parse().context("bad --count")?;
    let seed: u64 =
        args.get("seed").unwrap_or("2022").parse().context("bad --seed")?;
    let gen = ReadGen::new(ReadGenCfg { seed, ..ReadGenCfg::default() });
    const BASES: [char; 5] = ['A', 'C', 'G', 'T', 'N'];
    for i in 0..count {
        let row: String =
            gen.read(i).iter().map(|&b| BASES[b as usize]).collect();
        println!(">read_{i}\n{}", row.trim_end_matches('N'));
    }
    Ok(())
}
