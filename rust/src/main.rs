//! `spoton` — CLI for the Spot-on checkpointing framework.
//!
//! Subcommands (hand-rolled parser; no clap in the offline crate set):
//!
//! ```text
//! spoton run --scenario cfg.toml [--workload sleeper|minimeta]
//!            [--artifacts DIR] [--share DIR] [--timeline]
//! spoton table1 [--workload sleeper|minimeta] [--artifacts DIR]
//! spoton serve-metadata [--notice-secs 30]
//! spoton simulate-eviction --url http://127.0.0.1:PORT --resource vm-0
//! spoton coordinator --share DIR --instance vm-0 --events-url URL
//! spoton artifacts-info [--artifacts DIR]
//! spoton generate-reads [--count 8] [--seed 2022]
//! spoton sweep --scenario cfg.toml [--seeds 256] [--seed-start 0]
//!              [--salt 0] [--controllers fixed,young-daly,...]
//!              [--shards 8] [--procs N] [--threads 1] [--retries 2]
//!              [--out shards] [--run-id ID]
//! spoton sweep-worker --dir shards/ID --shard K [--threads 1]
//! spoton check --scenario cfg.toml
//! spoton lint [--json] [--fix-baseline] [--root DIR] [--baseline FILE]
//! ```

use anyhow::{bail, Context, Result};
use spoton::cloud::imds_http::ImdsHttp;
use spoton::config::ScenarioConfig;
use spoton::coordinator::realtime::Transport;
use spoton::coordinator::{
    CheckpointPolicy, RealtimeCoordinator, RealtimeParams,
};
use spoton::report;
use spoton::runtime::Runtime;
use spoton::sim::experiment::Experiment;
use spoton::storage::{NfsStore, TransferModel};
use spoton::workload::reads::{ReadGen, ReadGenCfg};
use spoton::workload::sleeper::{Sleeper, SleeperCfg};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// Trivial `--key value` / `--flag` argument map.
struct Args {
    cmd: String,
    kv: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    fn parse() -> Result<Self> {
        let mut it = std::env::args().skip(1);
        let cmd = it.next().unwrap_or_else(|| "help".into());
        let mut kv = HashMap::new();
        let mut flags = Vec::new();
        let rest: Vec<String> = it.collect();
        let mut i = 0;
        while i < rest.len() {
            let a = &rest[i];
            let key = a
                .strip_prefix("--")
                .with_context(|| format!("unexpected argument '{a}'"))?;
            if i + 1 < rest.len() && !rest[i + 1].starts_with("--") {
                kv.insert(key.to_string(), rest[i + 1].clone());
                i += 2;
            } else {
                flags.push(key.to_string());
                i += 1;
            }
        }
        Ok(Self { cmd, kv, flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.kv.get(key).map(String::as_str)
    }

    fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

fn artifacts_dir(args: &Args) -> PathBuf {
    args.get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(spoton::runtime::default_artifacts_dir)
}

fn main() -> Result<()> {
    let args = Args::parse()?;
    match args.cmd.as_str() {
        "run" => cmd_run(&args),
        "table1" => cmd_table1(&args),
        "serve-metadata" => cmd_serve_metadata(&args),
        "simulate-eviction" => cmd_simulate_eviction(&args),
        "coordinator" => cmd_coordinator(&args),
        "artifacts-info" => cmd_artifacts_info(&args),
        "generate-reads" => cmd_generate_reads(&args),
        "sweep" => cmd_sweep(&args),
        "sweep-worker" => cmd_sweep_worker(&args),
        "check" => cmd_check(&args),
        "lint" => cmd_lint(&args),
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `spoton help`)"),
    }
}

const HELP: &str = "\
spoton — fault-tolerant long-running workloads on cloud spot instances

USAGE:
  spoton run --scenario cfg.toml [--workload sleeper|minimeta]
             [--artifacts DIR] [--share DIR] [--timeline]
  spoton table1 [--workload sleeper|minimeta] [--artifacts DIR]
  spoton serve-metadata [--notice-secs 30]
  spoton simulate-eviction --url http://HOST:PORT --resource vm-0
  spoton coordinator --share DIR --instance vm-0 [--events-url URL]
  spoton artifacts-info [--artifacts DIR]
  spoton generate-reads [--count 8] [--seed 2022]
  spoton sweep --scenario cfg.toml [--seeds 256] [--seed-start 0] [--salt 0]
               [--controllers fixed,young-daly,young-daly-ho,cost-aware[:S]]
               [--shards 8] [--procs N] [--threads 1] [--retries 2]
               [--out shards] [--run-id ID]
  spoton sweep-worker --dir shards/ID --shard K [--threads 1]
  spoton check --scenario cfg.toml
  spoton lint [--json] [--fix-baseline] [--root DIR] [--baseline FILE]

`lint` runs the in-repo determinism & robustness static analysis
(rules D1-D5; see the `spoton::analysis` rustdoc) over rust/src,
rust/benches, rust/tests and examples/, and exits non-zero on any
finding that is new relative to analysis/BASELINE.json — or on any
stale baseline entry. `--fix-baseline` rewrites the baseline to the
current counts; `--json` emits a deterministic sorted-key report.

`check` evaluates the scenario's [expect] section over an
`expect.seeds`-seed sweep (cluster sweep for [cluster] scenarios),
prints the fault-accounting ledger when chaos injected anything, and
exits non-zero on any violated bound — self-checking scenarios for CI.

`sweep` plans a sharded Monte Carlo sweep (seed range x configuration
matrix), fans shards out over worker processes, checkpoints completed
shards in shards/ID/MANIFEST.json, and merges per-shard artifacts into a
byte-identical digest + per-variant summaries. Interrupted? Re-run the
same command: completed shards are reused, only missing ones re-run.
";

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = match args.get("scenario") {
        Some(path) => ScenarioConfig::load(Path::new(path))?,
        None => ScenarioConfig::default(),
    };
    let workload = args.get("workload").unwrap_or(cfg.workload.kind.as_str());
    let exp = Experiment { cfg: cfg.clone() };
    let result = match workload {
        "sleeper" => exp.run_sleeper()?,
        "minimeta" => {
            let dir = artifacts_dir(args);
            let rt = Rc::new(RefCell::new(Runtime::load(&dir)?));
            match args.get("share") {
                Some(share) => exp.run_minimeta_on_nfs(rt, Path::new(share))?,
                None => exp.run_minimeta(rt)?,
            }
        }
        other => bail!("unknown workload '{other}'"),
    };
    println!("{}", result.summary());
    println!("\nPer-stage wall time:");
    for (label, d) in &result.stage_times {
        println!("  {label:<6} {d}");
    }
    println!("\nInvoice:\n{}", result.invoice);
    if args.flag("timeline") {
        println!("Timeline:\n{}", result.timeline);
    }
    Ok(())
}

fn cmd_table1(args: &Args) -> Result<()> {
    let workload = args.get("workload").unwrap_or("sleeper");
    let rows = report::paper_rows();
    let mut results = Vec::new();
    let rt = if workload == "minimeta" {
        let dir = artifacts_dir(args);
        Some(Rc::new(RefCell::new(Runtime::load(&dir)?)))
    } else {
        None
    };
    for row in rows {
        eprintln!(
            "running {} ({} / {} / {})…",
            row.id, row.spoton, row.eviction, row.checkpoint
        );
        let exp = row.experiment();
        let result = match &rt {
            Some(rt) => exp.run_minimeta(rt.clone())?,
            None => exp.run_sleeper()?,
        };
        results.push((row, result));
    }
    println!("\nTable I — execution time of the metaSPAdes-analog workload");
    println!("(measured via the {workload} workload)\n");
    print!("{}", report::render_comparison(&results));
    Ok(())
}

fn cmd_serve_metadata(args: &Args) -> Result<()> {
    let notice: u64 = args
        .get("notice-secs")
        .unwrap_or("30")
        .parse()
        .context("bad --notice-secs")?;
    let imds = ImdsHttp::spawn(notice)?;
    println!("scheduled-events endpoint: {}", imds.events_url());
    println!(
        "inject an eviction with:\n  spoton simulate-eviction --url {} \
         --resource vm-0",
        imds.base_url()
    );
    println!("serving… (Ctrl-C to stop)");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_simulate_eviction(args: &Args) -> Result<()> {
    let url = args.get("url").context("--url required")?;
    let resource = args.get("resource").context("--resource required")?;
    let (status, body) = spoton::httpd::http_post(
        &format!("{url}/admin/simulate-eviction?resource={resource}"),
        "",
    )?;
    if status != 200 {
        bail!("simulate-eviction failed ({status}): {body}");
    }
    println!("eviction scheduled: {body}");
    Ok(())
}

fn cmd_coordinator(args: &Args) -> Result<()> {
    let share = args.get("share").context("--share required")?;
    let instance = args.get("instance").unwrap_or("vm-0");
    let mut store = NfsStore::open(
        Path::new(share),
        TransferModel {
            bandwidth_mib_s: 250.0,
            latency: spoton::simclock::SimDuration::from_millis(20),
        },
        None,
    )?;
    let mut workload = Sleeper::new(SleeperCfg::small(), 2022);
    let policy = CheckpointPolicy::new(
        spoton::config::CheckpointMethodCfg::Transparent {
            interval: spoton::simclock::SimDuration::from_secs(5),
        },
    );
    let mut coord = RealtimeCoordinator::new(
        instance,
        policy,
        RealtimeParams {
            poll_interval: std::time::Duration::from_millis(500),
            periodic_interval: Some(std::time::Duration::from_secs(5)),
            run_timeout: std::time::Duration::from_secs(600),
            keep_checkpoints: 3,
        },
    );
    let transport = match args.get("events-url") {
        Some(url) => Transport::Http { events_url: url.to_string() },
        None => {
            bail!("--events-url required (start `spoton serve-metadata`)")
        }
    };
    let outcome = coord.run(&mut workload, &mut store, &transport)?;
    println!("coordinator outcome: {outcome:?}");
    println!("timeline:\n{}", coord.timeline);
    Ok(())
}

fn cmd_artifacts_info(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let mut rt = Runtime::load(&dir)?;
    let g = rt.geometry().clone();
    println!("artifacts dir: {}", dir.display());
    println!("platform: {}", rt.platform());
    println!(
        "geometry: B={} L={} RC={} tile={}x{} taps={} ks={:?}",
        g.num_buckets,
        g.read_len,
        g.reads_per_call,
        g.read_tile,
        g.bucket_tile,
        2 * g.denoise_half_width + 1,
        g.ks
    );
    let names: Vec<String> = rt.manifest().artifacts.keys().cloned().collect();
    for name in names {
        let start = std::time::Instant::now();
        rt.executable(&name)?;
        println!("  {name}: compiled in {:?}", start.elapsed());
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    use spoton::sim::shard::{SeedStream, ShardPlan, ShardRunner};
    let scenario_path =
        PathBuf::from(args.get("scenario").context("--scenario required")?);
    let scenario_text = std::fs::read_to_string(&scenario_path)
        .with_context(|| format!("reading {}", scenario_path.display()))?;
    let scenario_base = scenario_path
        .parent()
        .map(|p| {
            if p.as_os_str().is_empty() { Path::new(".") } else { p }
                .canonicalize()
        })
        .transpose()
        .context("resolving scenario directory")?;
    let scenario = ScenarioConfig::from_str_toml_with_base(
        &scenario_text,
        scenario_base.as_deref(),
    )?;
    let parse_u64 = |key: &str, default: u64| -> Result<u64> {
        match args.get(key) {
            Some(v) => {
                v.parse().with_context(|| format!("bad --{key} '{v}'"))
            }
            None => Ok(default),
        }
    };
    let seeds = SeedStream::salted(
        parse_u64("seed-start", 0)?,
        parse_u64("seeds", 256)? as usize,
        parse_u64("salt", 0)?,
    );
    let specs: Vec<String> = args
        .get("controllers")
        .map(|s| {
            s.split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(str::to_string)
                .collect()
        })
        .unwrap_or_default();
    // shard count is part of the plan (it defines the artifact layout),
    // so the default is fixed, never derived from this machine
    let shards = parse_u64("shards", 8)? as usize;
    let procs = match args.get("procs") {
        Some(v) => v.parse().with_context(|| format!("bad --procs '{v}'"))?,
        None => std::thread::available_parallelism().map_or(1, |n| n.get()),
    };
    let threads = parse_u64("threads", 1)? as usize;
    let retries = parse_u64("retries", 2)? as u32;

    // The fingerprint-derived default run id makes "re-run the same
    // command" resume and "change any parameter" start fresh.
    let probe = ShardPlan::new(
        "probe",
        seeds,
        &specs,
        &scenario,
        &scenario_text,
        shards,
    )?;
    let run_id = args.get("run-id").map(str::to_string).unwrap_or_else(|| {
        format!("sweep-{}", &probe.fingerprint()[..12])
    });
    let plan = ShardPlan::new(
        &run_id,
        seeds,
        &specs,
        &scenario,
        &scenario_text,
        shards,
    )?;
    for s in &plan.skipped {
        eprintln!("skipping config '{}': {}", s.spec, s.reason);
    }
    let dir = PathBuf::from(args.get("out").unwrap_or("shards")).join(&run_id);
    println!(
        "sweep {run_id}: {} cells ({} configs x {} seeds) in {} shards, \
         {procs} worker process(es) x {threads} thread(s)",
        plan.cells(),
        plan.configs.len(),
        plan.seeds.count,
        plan.shards,
    );
    println!("run dir: {}", dir.display());
    let exe = std::env::current_exe().context("locating spoton binary")?;
    let runner = ShardRunner::new(plan, &dir, exe)
        .procs(procs)
        .threads(threads)
        .retries(retries)
        .scenario_base(scenario_base);
    runner.init(&scenario_text)?;
    let outcome = runner.run()?;
    if !outcome.reused.is_empty() {
        println!(
            "resumed: reused {} completed shard(s), ran {}",
            outcome.reused.len(),
            outcome.ran.len()
        );
    }
    if !outcome.dead_letter.is_empty() {
        for d in &outcome.dead_letter {
            eprintln!(
                "DEAD LETTER shard {} after {} attempt(s): {} ({} cells)",
                d.shard,
                d.attempts,
                d.reason,
                d.cells.len()
            );
        }
        bail!(
            "{} shard(s) failed permanently; fix the cause and re-run the \
             same command to retry just those shards",
            outcome.dead_letter.len()
        );
    }
    let merged = outcome.merged.context("no merge despite no dead letters")?;
    print!("\n{}", merged.render());
    println!("merged digest: {}", merged.digest);
    println!("merged report: {}", dir.join("MERGED.json").display());
    Ok(())
}

/// Shard ids listed in a `SPOTON_TEST_*` fault-injection variable.
fn fault_list(var: &str) -> Vec<usize> {
    std::env::var(var)
        .ok()
        .map(|s| {
            s.split(',')
                .filter_map(|t| t.trim().parse().ok())
                .collect()
        })
        .unwrap_or_default()
}

fn cmd_sweep_worker(args: &Args) -> Result<()> {
    use spoton::sim::shard::{artifact_path, load_run_dir, run_shard};
    let dir = PathBuf::from(args.get("dir").context("--dir required")?);
    let shard: usize = args
        .get("shard")
        .context("--shard required")?
        .parse()
        .context("bad --shard")?;
    let threads: usize =
        args.get("threads").unwrap_or("1").parse().context("bad --threads")?;
    // Fault-injection hooks for the resume/dead-letter tests:
    //  - SPOTON_TEST_FAIL_SHARDS=2,3  → listed shards exit 17 up front
    //  - SPOTON_TEST_PARTIAL_SHARDS=1 → listed shards write half an
    //    artifact straight to the final path (simulating a worker killed
    //    mid-write with no atomic rename) and exit 9
    if fault_list("SPOTON_TEST_FAIL_SHARDS").contains(&shard) {
        eprintln!("injected failure for shard {shard}");
        std::process::exit(17);
    }
    let (plan, scenario) = load_run_dir(&dir)?;
    let artifact = run_shard(&plan, &scenario, shard, threads)?;
    let mut body = spoton::json::to_string_pretty(&artifact.to_json());
    body.push('\n');
    if fault_list("SPOTON_TEST_PARTIAL_SHARDS").contains(&shard) {
        eprintln!("injected partial artifact for shard {shard}");
        std::fs::write(
            artifact_path(&dir, shard),
            &body.as_bytes()[..body.len() / 2],
        )?;
        std::process::exit(9);
    }
    spoton::util::atomic_write(&artifact_path(&dir, shard), body.as_bytes())?;
    println!(
        "shard {shard}: {} cells in {} ms",
        artifact.cells.len(),
        artifact.wall_ms
    );
    Ok(())
}

fn cmd_check(args: &Args) -> Result<()> {
    let path = Path::new(args.get("scenario").context("--scenario required")?);
    let cfg = ScenarioConfig::load(path)?;
    let Some(expect) = cfg.expect.clone() else {
        bail!(
            "scenario '{}' has no [expect] section — nothing to check",
            cfg.name
        );
    };
    let exp = Experiment { cfg: cfg.clone() };
    let (checked, faults) = if cfg.cluster.is_some() {
        let runs = exp
            .cluster_sweep()
            .seed_range(cfg.seed, expect.seeds as usize)
            .run()?;
        let faults = report::faults::account_many(runs.iter().flat_map(|r| {
            r.result.jobs.iter().map(|j| &j.result.timeline)
        }));
        (report::expect::evaluate_cluster(&expect, &cfg.name, &runs), faults)
    } else {
        let runs = exp
            .sweep()
            .seed_range(cfg.seed, expect.seeds as usize)
            .run()?;
        let faults = report::faults::account_many(
            runs.iter().map(|r| &r.result.timeline),
        );
        (report::expect::evaluate_runs(&expect, &cfg.name, &runs), faults)
    };
    if faults.total() > 0 {
        println!("Fault accounting:");
        print!("{}", report::faults::render(&faults));
        println!();
    }
    print!("{}", report::expect::render(&checked));
    if !checked.passed() {
        bail!(
            "{} expectation(s) violated in '{}'",
            checked.violations.len(),
            cfg.name
        );
    }
    Ok(())
}

fn cmd_lint(args: &Args) -> Result<()> {
    use spoton::analysis::{self, Baseline, LintConfig, LintReport};
    let root = PathBuf::from(args.get("root").unwrap_or("."));
    let cfg = LintConfig::repo_default();
    let baseline_path = args
        .get("baseline")
        .map(PathBuf::from)
        .unwrap_or_else(|| root.join(analysis::BASELINE_PATH));
    let (diags, files_scanned) = analysis::collect_diags(&root, &cfg)?;
    if args.flag("fix-baseline") {
        let base = Baseline::from_diags(&diags);
        let groups: usize =
            base.counts.values().map(|files| files.len()).sum();
        base.save(&baseline_path)?;
        println!(
            "wrote {} ({} baselined (rule, file) group(s), {} finding(s))",
            baseline_path.display(),
            groups,
            diags.len()
        );
        return Ok(());
    }
    let baseline = Baseline::load(&baseline_path)?;
    let comparison = baseline.compare(&diags);
    let report = LintReport { diags, comparison, files_scanned };
    if args.flag("json") {
        let mut body = spoton::json::to_string_pretty(&report.to_json());
        body.push('\n');
        print!("{body}");
    } else {
        print!("{}", report.render());
    }
    if !report.clean() {
        std::process::exit(1);
    }
    Ok(())
}

fn cmd_generate_reads(args: &Args) -> Result<()> {
    let count: u64 =
        args.get("count").unwrap_or("8").parse().context("bad --count")?;
    let seed: u64 =
        args.get("seed").unwrap_or("2022").parse().context("bad --seed")?;
    let gen = ReadGen::new(ReadGenCfg { seed, ..ReadGenCfg::default() });
    const BASES: [char; 5] = ['A', 'C', 'G', 'T', 'N'];
    for i in 0..count {
        let row: String =
            gen.read(i).iter().map(|&b| BASES[b as usize]).collect();
        println!(">read_{i}\n{}", row.trim_end_matches('N'));
    }
    Ok(())
}
