//! Fig 2 (cost comparison) and Fig 3 (execution-time comparison)
//! renderers.
//!
//! Two families: the point-estimate variants ([`render_fig2`] /
//! [`render_fig3`]) reproduce the paper's single-schedule bars, and the
//! band variants ([`render_fig2_bands`] / [`render_fig3_bands`]) plot
//! each configuration's p50 with its p5–p95 band from a Monte Carlo
//! sweep population ([`crate::report::distribution`]) — the spread a
//! single eviction schedule hides.

use super::distribution::SweepDistributions;
use super::table::{bar_chart, TextTable};
use crate::sim::RunResult;
use crate::util::fmt::hms_f64 as hms;

/// Fig 2: total cost per configuration, with savings relative to the
/// on-demand baseline (first entry).
pub fn render_fig2(results: &[(&str, &RunResult)]) -> String {
    assert!(!results.is_empty());
    let baseline = results[0].1.total_cost();
    let mut out = String::new();
    out.push_str(
        "Fig 2 — Cost comparison, on-demand vs checkpoint-protected spot\n\n",
    );
    let bars: Vec<(String, f64)> = results
        .iter()
        .map(|(label, r)| (label.to_string(), r.total_cost()))
        .collect();
    out.push_str(&bar_chart(&bars, "USD", 40));
    out.push('\n');
    let mut t = TextTable::new(&[
        "Configuration", "Compute", "Storage", "Total", "Saving vs on-demand",
    ]);
    for (label, r) in results {
        let saving = 1.0 - r.total_cost() / baseline;
        t.row(&[
            label.to_string(),
            crate::util::fmt::dollars(r.compute_cost),
            crate::util::fmt::dollars(r.storage_cost),
            crate::util::fmt::dollars(r.total_cost()),
            if r.total_cost() == baseline {
                "—".to_string()
            } else {
                crate::util::fmt::pct(-saving).replace('-', "")
            },
        ]);
    }
    out.push_str(&t.render());
    out
}

/// Fig 3: execution time, application-native vs transparent, grouped by
/// eviction interval. `pairs` = (eviction label, app result, transparent
/// result).
pub fn render_fig3(pairs: &[(&str, &RunResult, &RunResult)]) -> String {
    let mut out = String::new();
    out.push_str(
        "Fig 3 — Execution time: application-native vs transparent \
         checkpointing on spot\n\n",
    );
    let mut bars = Vec::new();
    for (label, app, tr) in pairs {
        bars.push((
            format!("{label} / application"),
            app.total.as_secs() as f64 / 3600.0,
        ));
        bars.push((
            format!("{label} / transparent"),
            tr.total.as_secs() as f64 / 3600.0,
        ));
    }
    out.push_str(&bar_chart(&bars, "h", 40));
    out.push('\n');
    let mut t = TextTable::new(&[
        "Eviction", "Application", "Transparent", "Time saved",
    ]);
    for (label, app, tr) in pairs {
        let saving =
            1.0 - tr.total.as_millis() as f64 / app.total.as_millis() as f64;
        t.row(&[
            label.to_string(),
            app.total.hms(),
            tr.total.hms(),
            crate::util::fmt::pct(saving).replace('+', ""),
        ]);
    }
    out.push_str(&t.render());
    out
}

/// Fig 2 with uncertainty: total-cost p50 bars with the p5–p95 band of
/// each configuration's sweep population; savings are quoted at the p50
/// against the first entry (the on-demand baseline).
pub fn render_fig2_bands(entries: &[(&str, &SweepDistributions)]) -> String {
    assert!(!entries.is_empty());
    let baseline = entries[0].1.total_cost.p50;
    let mut out = String::new();
    out.push_str(
        "Fig 2 — Cost comparison with p5–p95 bands over sweep populations\n\n",
    );
    let bars: Vec<(String, f64)> = entries
        .iter()
        .map(|(label, d)| (label.to_string(), d.total_cost.p50))
        .collect();
    out.push_str(&bar_chart(&bars, "USD (p50)", 40));
    out.push('\n');
    let mut t = TextTable::new(&[
        "Configuration", "Runs", "Cost p50", "p5", "p95", "Band",
        "Saving vs baseline (p50)",
    ]);
    for (label, d) in entries {
        let c = &d.total_cost;
        let saving = 1.0 - c.p50 / baseline;
        t.row(&[
            label.to_string(),
            d.runs.to_string(),
            crate::util::fmt::dollars(c.p50),
            crate::util::fmt::dollars(c.p05),
            crate::util::fmt::dollars(c.p95),
            crate::util::fmt::dollars(c.p95 - c.p05),
            if c.p50 == baseline {
                "—".to_string()
            } else {
                crate::util::fmt::pct(-saving).replace('-', "")
            },
        ]);
    }
    out.push_str(&t.render());
    out
}

/// Fig 3 with uncertainty: execution-time p50 plus the p5–p95 band,
/// application-native vs transparent, grouped by eviction process.
/// `pairs` = (eviction label, app sweep, transparent sweep).
pub fn render_fig3_bands(
    pairs: &[(&str, &SweepDistributions, &SweepDistributions)],
) -> String {
    let mut out = String::new();
    out.push_str(
        "Fig 3 — Execution time with p5–p95 bands: application-native vs \
         transparent checkpointing on spot\n\n",
    );
    let mut bars = Vec::new();
    for (label, app, tr) in pairs {
        bars.push((
            format!("{label} / application"),
            app.makespan_secs.p50 / 3600.0,
        ));
        bars.push((
            format!("{label} / transparent"),
            tr.makespan_secs.p50 / 3600.0,
        ));
    }
    out.push_str(&bar_chart(&bars, "h (p50)", 40));
    out.push('\n');
    let mut t = TextTable::new(&[
        "Eviction", "Method", "p50", "p5", "p95", "Band", "Time saved (p50)",
    ]);
    for (label, app, tr) in pairs {
        let saving = 1.0 - tr.makespan_secs.p50 / app.makespan_secs.p50;
        for (method, d, saved) in [
            ("application", app, "—".to_string()),
            (
                "transparent",
                tr,
                crate::util::fmt::pct(saving).replace('+', ""),
            ),
        ] {
            let m = &d.makespan_secs;
            t.row(&[
                label.to_string(),
                method.to_string(),
                hms(m.p50),
                hms(m.p05),
                hms(m.p95),
                hms(m.p95 - m.p05),
                saved,
            ]);
        }
    }
    out.push_str(&t.render());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::report::distribution::summarize;
    use crate::sim::experiment::Experiment;
    use crate::simclock::SimDuration;

    #[test]
    fn fig2_renders_with_savings() {
        let od = Experiment::table1()
            .spoton_off()
            .ondemand()
            .run_sleeper()
            .unwrap();
        let spot = Experiment::table1()
            .eviction_every(SimDuration::from_mins(90))
            .transparent(SimDuration::from_mins(30))
            .run_sleeper()
            .unwrap();
        let s = render_fig2(&[
            ("on-demand baseline", &od),
            ("spot + transparent 30m", &spot),
        ]);
        assert!(s.contains("on-demand baseline"));
        assert!(s.contains("Saving"));
        assert!(s.contains('#'));
    }

    #[test]
    fn fig2_bands_render_p5_p95() {
        let od = Experiment::table1()
            .named("od")
            .spoton_off()
            .ondemand()
            .sweep()
            .seed_range(0, 6)
            .threads(2)
            .run()
            .unwrap();
        let spot = Experiment::table1()
            .named("spot")
            .eviction_poisson(SimDuration::from_mins(75))
            .transparent(SimDuration::from_mins(30))
            .sweep()
            .seed_range(0, 6)
            .threads(2)
            .run()
            .unwrap();
        let od_d = summarize("on-demand", &od);
        let spot_d = summarize("spot + transparent", &spot);
        let s = render_fig2_bands(&[
            ("on-demand", &od_d),
            ("spot + transparent", &spot_d),
        ]);
        assert!(s.contains("p5–p95"), "{s}");
        assert!(s.contains("on-demand"), "{s}");
        assert!(s.contains("Saving vs baseline"), "{s}");
        assert!(s.contains('#'), "{s}");
    }

    #[test]
    fn fig3_bands_render_both_methods() {
        let mk = |app: bool| {
            let e = Experiment::table1()
                .named("f3b")
                .eviction_poisson(SimDuration::from_mins(60))
                .deadline(SimDuration::from_hours(30));
            let e = if app {
                e.app_native()
            } else {
                e.transparent(SimDuration::from_mins(30))
            };
            summarize(
                if app { "app" } else { "tr" },
                &e.sweep().seed_range(0, 5).threads(2).run().unwrap(),
            )
        };
        let app = mk(true);
        let tr = mk(false);
        let s = render_fig3_bands(&[("poisson 60m", &app, &tr)]);
        assert!(s.contains("poisson 60m / application"), "{s}");
        assert!(s.contains("transparent"), "{s}");
        assert!(s.contains("Time saved"), "{s}");
        // band columns really carry order statistics
        assert!(app.makespan_secs.p05 <= app.makespan_secs.p95);
    }

    #[test]
    fn fig3_renders_time_saved() {
        let app = Experiment::table1()
            .eviction_every(SimDuration::from_mins(60))
            .app_native()
            .run_sleeper()
            .unwrap();
        let tr = Experiment::table1()
            .eviction_every(SimDuration::from_mins(60))
            .transparent(SimDuration::from_mins(30))
            .run_sleeper()
            .unwrap();
        let s = render_fig3(&[("every 60 min", &app, &tr)]);
        assert!(s.contains("every 60 min / application"));
        assert!(s.contains("Time saved"));
    }
}
