//! Fig 2 (cost comparison) and Fig 3 (execution-time comparison)
//! renderers.

use super::table::{bar_chart, TextTable};
use crate::sim::RunResult;

/// Fig 2: total cost per configuration, with savings relative to the
/// on-demand baseline (first entry).
pub fn render_fig2(results: &[(&str, &RunResult)]) -> String {
    assert!(!results.is_empty());
    let baseline = results[0].1.total_cost();
    let mut out = String::new();
    out.push_str(
        "Fig 2 — Cost comparison, on-demand vs checkpoint-protected spot\n\n",
    );
    let bars: Vec<(String, f64)> = results
        .iter()
        .map(|(label, r)| (label.to_string(), r.total_cost()))
        .collect();
    out.push_str(&bar_chart(&bars, "USD", 40));
    out.push('\n');
    let mut t = TextTable::new(&[
        "Configuration", "Compute", "Storage", "Total", "Saving vs on-demand",
    ]);
    for (label, r) in results {
        let saving = 1.0 - r.total_cost() / baseline;
        t.row(&[
            label.to_string(),
            crate::util::fmt::dollars(r.compute_cost),
            crate::util::fmt::dollars(r.storage_cost),
            crate::util::fmt::dollars(r.total_cost()),
            if r.total_cost() == baseline {
                "—".to_string()
            } else {
                crate::util::fmt::pct(-saving).replace('-', "")
            },
        ]);
    }
    out.push_str(&t.render());
    out
}

/// Fig 3: execution time, application-native vs transparent, grouped by
/// eviction interval. `pairs` = (eviction label, app result, transparent
/// result).
pub fn render_fig3(pairs: &[(&str, &RunResult, &RunResult)]) -> String {
    let mut out = String::new();
    out.push_str(
        "Fig 3 — Execution time: application-native vs transparent \
         checkpointing on spot\n\n",
    );
    let mut bars = Vec::new();
    for (label, app, tr) in pairs {
        bars.push((
            format!("{label} / application"),
            app.total.as_secs() as f64 / 3600.0,
        ));
        bars.push((
            format!("{label} / transparent"),
            tr.total.as_secs() as f64 / 3600.0,
        ));
    }
    out.push_str(&bar_chart(&bars, "h", 40));
    out.push('\n');
    let mut t = TextTable::new(&[
        "Eviction", "Application", "Transparent", "Time saved",
    ]);
    for (label, app, tr) in pairs {
        let saving =
            1.0 - tr.total.as_millis() as f64 / app.total.as_millis() as f64;
        t.row(&[
            label.to_string(),
            app.total.hms(),
            tr.total.hms(),
            crate::util::fmt::pct(saving).replace('+', ""),
        ]);
    }
    out.push_str(&t.render());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::experiment::Experiment;
    use crate::simclock::SimDuration;

    #[test]
    fn fig2_renders_with_savings() {
        let od = Experiment::table1()
            .spoton_off()
            .ondemand()
            .run_sleeper()
            .unwrap();
        let spot = Experiment::table1()
            .eviction_every(SimDuration::from_mins(90))
            .transparent(SimDuration::from_mins(30))
            .run_sleeper()
            .unwrap();
        let s = render_fig2(&[
            ("on-demand baseline", &od),
            ("spot + transparent 30m", &spot),
        ]);
        assert!(s.contains("on-demand baseline"));
        assert!(s.contains("Saving"));
        assert!(s.contains('#'));
    }

    #[test]
    fn fig3_renders_time_saved() {
        let app = Experiment::table1()
            .eviction_every(SimDuration::from_mins(60))
            .app_native()
            .run_sleeper()
            .unwrap();
        let tr = Experiment::table1()
            .eviction_every(SimDuration::from_mins(60))
            .transparent(SimDuration::from_mins(30))
            .run_sleeper()
            .unwrap();
        let s = render_fig3(&[("every 60 min", &app, &tr)]);
        assert!(s.contains("every 60 min / application"));
        assert!(s.contains("Time saved"));
    }
}
