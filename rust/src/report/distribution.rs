//! Distribution summaries over Monte Carlo sweeps.
//!
//! The paper reports point estimates; the sweep driver
//! ([`crate::sim::sweep`]) produces populations. This module reduces a
//! merged sweep into per-metric [`Summary`] statistics (mean / p50 / p95
//! / p99 / min / max) — makespan, cost, evictions, restores, lost steps —
//! plus per-pool attribution, and renders them as aligned text tables or
//! deterministic JSON (the `BENCH_sweep.json` payload).
//!
//! Every reduction walks the merged runs in seed order with a fixed
//! summation order, so two sweeps that merged identically summarize
//! identically — bit-for-bit, across thread counts.
//!
//! Reductions over *many* populations (per-controller comparisons, the
//! per-variant summaries a sharded merge produces) go through a
//! [`Summarizer`], which reuses its accumulation and sort-scratch
//! buffers across populations instead of reallocating per summary —
//! the allocation churn is what shows up first at million-seed scale.

use crate::json::Value;
use crate::report::table::TextTable;
use crate::sim::sweep::SeededRun;
use crate::util::fmt::{dollars, hms_f64 as hms};

/// Order statistics + mean over one metric's samples. `p05`/`p95` bound
/// the uncertainty band the Fig 2/3 renderers plot around `p50`
/// ([`crate::report::figures::render_fig2_bands`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub p05: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    /// Empty-sample summary (all zeros).
    pub const ZERO: Summary = Summary {
        n: 0,
        mean: 0.0,
        p05: 0.0,
        p50: 0.0,
        p95: 0.0,
        p99: 0.0,
        min: 0.0,
        max: 0.0,
    };

    /// Summarize `samples` (nearest-rank percentiles over a total-order
    /// sort; the mean sums in input order — deterministic for a
    /// deterministic input sequence). Allocates one scratch buffer; a
    /// loop over many populations should hold a [`Summarizer`] instead.
    pub fn from_samples(samples: &[f64]) -> Summary {
        let mut scratch = Vec::new();
        compute(samples, &mut scratch)
    }

    /// Deterministic JSON shape (object keys serialize sorted).
    pub fn to_json(self) -> Value {
        let mut v = Value::obj();
        v.set("n", self.n)
            .set("mean", self.mean)
            .set("p05", self.p05)
            .set("p50", self.p50)
            .set("p95", self.p95)
            .set("p99", self.p99)
            .set("min", self.min)
            .set("max", self.max);
        v
    }
}

/// The shared reduction: mean in input order, nearest-rank percentiles
/// over a total-order sort of `scratch` (cleared and refilled; its
/// capacity is the whole point of reusing it).
fn compute(samples: &[f64], scratch: &mut Vec<f64>) -> Summary {
    if samples.is_empty() {
        return Summary::ZERO;
    }
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    scratch.clear();
    scratch.extend_from_slice(samples);
    scratch.sort_by(f64::total_cmp);
    let pct = |q: f64| scratch[(((n - 1) as f64) * q).round() as usize];
    Summary {
        n,
        mean,
        p05: pct(0.05),
        p50: pct(0.50),
        p95: pct(0.95),
        p99: pct(0.99),
        min: scratch[0],
        max: scratch[n - 1],
    }
}

/// Reusable accumulation + sort-scratch buffers for reducing many
/// populations in sequence. At million-seed scale `Summary::from_samples`
/// reallocates two `Vec<f64>`s per metric per population (the collect
/// plus the sort copy); a `Summarizer` keeps both buffers across
/// populations, so a per-controller or per-shard-variant loop allocates
/// twice total instead of twice per summary. The reduction itself is
/// bit-identical to [`Summary::from_samples`].
#[derive(Debug, Default)]
pub struct Summarizer {
    samples: Vec<f64>,
    scratch: Vec<f64>,
}

impl Summarizer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Accumulate one sample of the current population.
    pub fn push(&mut self, sample: f64) {
        self.samples.push(sample);
    }

    /// Samples accumulated so far in the current population.
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Reduce the accumulated population and clear it for the next one
    /// (both buffers keep their capacity).
    pub fn finish(&mut self) -> Summary {
        let s = compute(&self.samples, &mut self.scratch);
        self.samples.clear();
        s
    }

    /// Reduce an externally-accumulated slice through the shared sort
    /// scratch (for populations that must stay separate while others
    /// accumulate, like per-pool costs). Leaves pushed samples alone.
    pub fn of_slice(&mut self, samples: &[f64]) -> Summary {
        compute(samples, &mut self.scratch)
    }
}

/// One pool's aggregate usage plus its per-run compute-cost distribution.
#[derive(Debug, Clone)]
pub struct PoolDistribution {
    pub pool: String,
    /// Launches summed across every run.
    pub launches: u32,
    /// Evictions summed across every run.
    pub evictions: u32,
    /// Distribution of the pool's attributed compute cost per run.
    pub compute_cost: Summary,
}

/// The reduced shape of one sweep.
#[derive(Debug, Clone)]
pub struct SweepDistributions {
    pub scenario: String,
    pub runs: usize,
    /// Runs that finished the workload (vs aborted at the deadline).
    pub completed: usize,
    pub makespan_secs: Summary,
    pub total_cost: Summary,
    pub evictions: Summary,
    pub restores: Summary,
    pub lost_steps: Summary,
    pub pools: Vec<PoolDistribution>,
}

/// Reduce a merged sweep (seed order) into distribution summaries.
pub fn summarize(scenario: &str, runs: &[SeededRun]) -> SweepDistributions {
    summarize_with(&mut Summarizer::new(), scenario, runs)
}

/// Like [`summarize`], but accumulating through a caller-owned
/// [`Summarizer`], so a loop over many populations (per-controller
/// sweeps, per-variant shard merges) reuses the same buffers instead of
/// reallocating per summary. Output is bit-identical to [`summarize`].
pub fn summarize_with(
    sz: &mut Summarizer,
    scenario: &str,
    runs: &[SeededRun],
) -> SweepDistributions {
    let mut metric = |f: &dyn Fn(&SeededRun) -> f64| -> Summary {
        for r in runs {
            sz.push(f(r));
        }
        sz.finish()
    };
    let makespan_secs = metric(&|r| r.result.total.as_secs_f64());
    let total_cost = metric(&|r| r.result.total_cost());
    let evictions = metric(&|r| r.result.evictions as f64);
    let restores = metric(&|r| r.result.restores as f64);
    let lost_steps = metric(&|r| r.result.lost_steps as f64);

    // Per-pool attribution: pools keyed by first-seen order (identical in
    // every run of one sweep — pool ids come from the shared config).
    let mut pools: Vec<(String, u32, u32, Vec<f64>)> = Vec::new();
    for run in runs {
        for p in &run.result.pool_stats {
            match pools.iter_mut().find(|e| e.0 == p.pool) {
                Some(e) => {
                    e.1 += p.launches;
                    e.2 += p.evictions;
                    e.3.push(p.compute_cost);
                }
                None => pools.push((
                    p.pool.clone(),
                    p.launches,
                    p.evictions,
                    vec![p.compute_cost],
                )),
            }
        }
    }

    SweepDistributions {
        scenario: scenario.to_string(),
        runs: runs.len(),
        completed: runs.iter().filter(|r| r.result.completed).count(),
        makespan_secs,
        total_cost,
        evictions,
        restores,
        lost_steps,
        pools: pools
            .into_iter()
            .map(|(pool, launches, evictions, costs)| PoolDistribution {
                pool,
                launches,
                evictions,
                compute_cost: sz.of_slice(&costs),
            })
            .collect(),
    }
}

/// Aligned text table: one row per metric, one column per statistic.
pub fn render(d: &SweepDistributions) -> String {
    let mut t = TextTable::new(&[
        "Metric", "Mean", "P5", "P50", "P95", "P99", "Min", "Max",
    ]);
    let time_row = |label: &str, s: &Summary| -> Vec<String> {
        vec![
            label.to_string(),
            hms(s.mean),
            hms(s.p05),
            hms(s.p50),
            hms(s.p95),
            hms(s.p99),
            hms(s.min),
            hms(s.max),
        ]
    };
    let cost_row = |label: &str, s: &Summary| -> Vec<String> {
        vec![
            label.to_string(),
            dollars(s.mean),
            dollars(s.p05),
            dollars(s.p50),
            dollars(s.p95),
            dollars(s.p99),
            dollars(s.min),
            dollars(s.max),
        ]
    };
    let count_row = |label: &str, s: &Summary| -> Vec<String> {
        vec![
            label.to_string(),
            format!("{:.2}", s.mean),
            format!("{:.0}", s.p05),
            format!("{:.0}", s.p50),
            format!("{:.0}", s.p95),
            format!("{:.0}", s.p99),
            format!("{:.0}", s.min),
            format!("{:.0}", s.max),
        ]
    };
    t.row(&time_row("makespan", &d.makespan_secs));
    t.row(&cost_row("total cost", &d.total_cost));
    t.row(&count_row("evictions", &d.evictions));
    t.row(&count_row("restores", &d.restores));
    t.row(&count_row("lost steps", &d.lost_steps));
    for p in &d.pools {
        t.row(&cost_row(&format!("pool {} cost", p.pool), &p.compute_cost));
    }
    let mut out = format!(
        "{}: {} runs, {} completed ({:.1}%)\n",
        d.scenario,
        d.runs,
        d.completed,
        if d.runs > 0 {
            100.0 * d.completed as f64 / d.runs as f64
        } else {
            0.0
        }
    );
    out.push_str(&t.render());
    for p in &d.pools {
        out.push_str(&format!(
            "  pool {}: {} launches, {} evictions across the sweep\n",
            p.pool, p.launches, p.evictions
        ));
    }
    out
}

impl SweepDistributions {
    /// Deterministic JSON shape (the `BENCH_sweep.json` payload; object
    /// keys serialize sorted).
    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("scenario", self.scenario.as_str())
            .set("runs", self.runs)
            .set("completed", self.completed)
            .set("makespan_secs", self.makespan_secs.to_json())
            .set("total_cost", self.total_cost.to_json())
            .set("evictions", self.evictions.to_json())
            .set("restores", self.restores.to_json())
            .set("lost_steps", self.lost_steps.to_json());
        let pools: Vec<Value> = self
            .pools
            .iter()
            .map(|p| {
                let mut pv = Value::obj();
                pv.set("pool", p.pool.as_str())
                    .set("launches", p.launches)
                    .set("evictions", p.evictions)
                    .set("compute_cost", p.compute_cost.to_json());
                pv
            })
            .collect();
        v.set("pools", Value::Array(pools));
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::experiment::Experiment;

    #[test]
    fn summary_order_statistics() {
        let s = Summary::from_samples(&[5.0, 1.0, 3.0, 2.0, 4.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.p50, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!(s.min <= s.p05 && s.p05 <= s.p50);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
        assert_eq!(Summary::from_samples(&[]), Summary::ZERO);
        let one = Summary::from_samples(&[7.5]);
        assert_eq!(one.mean, 7.5);
        assert_eq!(one.p99, 7.5);
    }

    #[test]
    fn summarizer_matches_from_samples_across_populations() {
        let pops: [&[f64]; 4] = [
            &[5.0, 1.0, 3.0, 2.0, 4.0],
            &[],
            &[7.5],
            &[0.1, -2.0, f64::MAX, 0.0, 1e-300, 42.0, 42.0],
        ];
        let mut sz = Summarizer::new();
        for samples in pops {
            for &s in samples {
                sz.push(s);
            }
            assert_eq!(sz.len(), samples.len());
            // the reused-buffer path is bit-identical to the one-shot one
            assert_eq!(sz.finish(), Summary::from_samples(samples));
            assert!(sz.is_empty(), "finish() must clear the population");
            // ... and so is the external-slice path
            assert_eq!(sz.of_slice(samples), Summary::from_samples(samples));
        }
    }

    #[test]
    fn summarize_with_matches_summarize() {
        use crate::simclock::SimDuration;
        let runs = Experiment::table1()
            .named("dist-with")
            .eviction_poisson(SimDuration::from_mins(70))
            .transparent(SimDuration::from_mins(20))
            .sweep()
            .seed_range(3, 6)
            .threads(2)
            .run()
            .unwrap();
        let one_shot = summarize("dist-with", &runs);
        let mut sz = Summarizer::new();
        // run twice through the same Summarizer: reuse must not leak
        // state between populations
        let first = summarize_with(&mut sz, "dist-with", &runs);
        let second = summarize_with(&mut sz, "dist-with", &runs);
        let json = |d: &SweepDistributions| crate::json::to_string(&d.to_json());
        assert_eq!(json(&one_shot), json(&first));
        assert_eq!(json(&one_shot), json(&second));
    }

    #[test]
    fn summarize_and_render_a_small_sweep() {
        use crate::simclock::SimDuration;
        let runs = Experiment::table1()
            .named("dist-unit")
            .eviction_poisson(SimDuration::from_mins(75))
            .transparent(SimDuration::from_mins(20))
            .sweep()
            .seed_range(0, 8)
            .threads(2)
            .run()
            .unwrap();
        let d = summarize("dist-unit", &runs);
        assert_eq!(d.runs, 8);
        assert_eq!(d.completed, 8);
        assert!(d.makespan_secs.min >= 11006.0, "below uninterrupted total");
        assert!(d.makespan_secs.min <= d.makespan_secs.p50);
        assert!(d.makespan_secs.p50 <= d.makespan_secs.max);
        assert!(d.total_cost.mean > 0.0);
        // single implicit pool carries every run
        assert_eq!(d.pools.len(), 1);
        assert!(d.pools[0].launches >= 8);
        let text = render(&d);
        assert!(text.contains("makespan"), "{text}");
        assert!(text.contains("8 runs"), "{text}");
        let json = crate::json::to_string(&d.to_json());
        assert!(json.contains("\"runs\":8"), "{json}");
        assert!(json.contains("\"p99\""), "{json}");
    }
}
