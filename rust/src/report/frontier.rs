//! The cost-vs-SLA frontier: what deadline attainment costs.
//!
//! Bid-aware spot placement and the hybrid autoscaler
//! ([`crate::autoscale`]) trade money for deadline attainment: all-spot
//! with aggressive bids is cheap but misses deadlines when the market
//! spikes; all-on-demand holds every deadline at the undiscounted
//! price; the hybrid sits between. This module reduces labeled cluster
//! populations (one label per configuration — e.g. `"all-spot"`,
//! `"hybrid"`, `"on-demand"`) to one [`FrontierPoint`] each — mean
//! cost, aggregate SLA attainment, total misses — marks Pareto
//! domination (a point is dominated when some other point costs no
//! more *and* attains no less), and renders the frontier as a
//! [`TextTable`]. `examples/bid_frontier.rs` drives it end to end.

use super::table::TextTable;
use crate::sim::cluster::ClusterResult;

/// One configuration's position on the cost-vs-SLA plane.
#[derive(Debug, Clone, PartialEq)]
pub struct FrontierPoint {
    /// Configuration label (stable; supplied by the caller).
    pub label: String,
    /// Mean total cost per run (compute + storage, all jobs).
    pub mean_cost: f64,
    /// Aggregate deadline attainment across every run's verdict-carrying
    /// jobs; `None` when no job carried a deadline.
    pub sla: Option<f64>,
    /// Total deadline misses across the population.
    pub misses: usize,
    /// Runs reduced into this point.
    pub runs: usize,
    /// Pareto-dominated: some other point costs no more and attains no
    /// less (strictly better on at least one axis).
    pub dominated: bool,
}

/// Reduce one labeled population to its frontier point (domination is
/// marked later, across points, by [`frontier`]).
fn reduce(label: &str, results: &[ClusterResult]) -> FrontierPoint {
    let runs = results.len();
    let mean_cost = if runs == 0 {
        0.0
    } else {
        results.iter().map(|r| r.total_cost()).sum::<f64>() / runs as f64
    };
    let (mut met, mut with_verdict) = (0usize, 0usize);
    let mut misses = 0usize;
    for r in results {
        for j in &r.jobs {
            if let Some(missed) = j.result.deadline_missed {
                with_verdict += 1;
                if missed {
                    misses += 1;
                } else {
                    met += 1;
                }
            }
        }
    }
    let sla =
        (with_verdict > 0).then(|| met as f64 / with_verdict as f64);
    FrontierPoint {
        label: label.to_string(),
        mean_cost,
        sla,
        misses,
        runs,
        dominated: false,
    }
}

/// Build the frontier from labeled populations, sorted cheapest first,
/// with Pareto domination marked. Input order among equal costs is
/// preserved (stable sort on a total-order key), so the table is
/// deterministic for any fixed input.
pub fn frontier(groups: &[(&str, Vec<ClusterResult>)]) -> Vec<FrontierPoint> {
    let mut points: Vec<FrontierPoint> =
        groups.iter().map(|(label, rs)| reduce(label, rs)).collect();
    points.sort_by(|a, b| {
        // costs are sums of validated finite prices; compare totally
        a.mean_cost
            .partial_cmp(&b.mean_cost)
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    for i in 0..points.len() {
        let (ci, si) = (points[i].mean_cost, points[i].sla.unwrap_or(1.0));
        points[i].dominated = points.iter().enumerate().any(|(k, other)| {
            if k == i {
                return false;
            }
            let (ck, sk) = (other.mean_cost, other.sla.unwrap_or(1.0));
            ck <= ci && sk >= si && (ck < ci || sk > si)
        });
    }
    points
}

/// Render the frontier as an aligned text table.
pub fn render_frontier(points: &[FrontierPoint]) -> String {
    let mut t = TextTable::new(&[
        "config",
        "mean cost",
        "SLA",
        "misses",
        "runs",
        "frontier",
    ]);
    for p in points {
        t.row(&[
            p.label.clone(),
            crate::util::fmt::dollars(p.mean_cost),
            match p.sla {
                Some(s) => format!("{:.2}%", s * 100.0),
                None => "n/a".into(),
            },
            p.misses.to_string(),
            p.runs.to_string(),
            if p.dominated { "dominated" } else { "*" }.to_string(),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(label: &str, cost: f64, sla: f64, misses: usize) -> FrontierPoint {
        FrontierPoint {
            label: label.into(),
            mean_cost: cost,
            sla: Some(sla),
            misses,
            runs: 10,
            dominated: false,
        }
    }

    /// Domination marking over hand-built points (the reduce path is
    /// exercised end to end by `examples/bid_frontier.rs`).
    fn mark(mut points: Vec<FrontierPoint>) -> Vec<FrontierPoint> {
        points.sort_by(|a, b| {
            a.mean_cost
                .partial_cmp(&b.mean_cost)
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        for i in 0..points.len() {
            let (ci, si) =
                (points[i].mean_cost, points[i].sla.unwrap_or(1.0));
            points[i].dominated =
                points.iter().enumerate().any(|(k, other)| {
                    if k == i {
                        return false;
                    }
                    let (ck, sk) =
                        (other.mean_cost, other.sla.unwrap_or(1.0));
                    ck <= ci && sk >= si && (ck < ci || sk > si)
                });
        }
        points
    }

    #[test]
    fn pareto_marks_strictly_worse_points() {
        let pts = mark(vec![
            pt("all-spot", 1.0, 0.80, 6),
            pt("hybrid", 1.5, 0.99, 1),
            pt("wasteful", 2.0, 0.90, 3), // costlier AND worse than hybrid
            pt("on-demand", 3.0, 1.00, 0),
        ]);
        let by_label = |l: &str| pts.iter().find(|p| p.label == l).unwrap();
        assert!(!by_label("all-spot").dominated);
        assert!(!by_label("hybrid").dominated);
        assert!(by_label("wasteful").dominated);
        assert!(!by_label("on-demand").dominated);
        // sorted cheapest first
        assert_eq!(pts[0].label, "all-spot");
        assert_eq!(pts[3].label, "on-demand");
    }

    #[test]
    fn equal_points_do_not_dominate_each_other() {
        let pts = mark(vec![pt("a", 1.0, 0.9, 1), pt("b", 1.0, 0.9, 1)]);
        assert!(pts.iter().all(|p| !p.dominated));
    }

    #[test]
    fn render_includes_every_label_and_flags() {
        let s = render_frontier(&mark(vec![
            pt("cheap", 1.0, 0.5, 5),
            pt("good", 1.0, 0.99, 1),
        ]));
        assert!(s.contains("cheap"));
        assert!(s.contains("good"));
        assert!(s.contains("dominated"), "{s}");
        assert!(s.contains("99.00%"), "{s}");
    }
}
