//! Result rendering: the paper's tables and figures as text + CSV.
//!
//! * [`table`] — generic aligned text tables.
//! * [`table1`] — the 8 rows of the paper's Table I: each row's scenario
//!   builder, the paper's published numbers, and a renderer that prints
//!   paper-vs-measured side by side.
//! * [`figures`] — Fig 2 (cost comparison) and Fig 3 (app-native vs
//!   transparent execution time) as ASCII bar charts + CSV series.
//! * [`fleet`] — per-pool cost attribution and placement-policy
//!   comparison for multi-pool fleet runs.
//! * [`distribution`] — mean/percentile summaries over Monte Carlo
//!   sweeps ([`crate::sim::sweep`]): distributions, not point estimates.
//! * [`policy`] — fixed-vs-adaptive checkpoint-interval comparison
//!   tables over per-controller sweep populations
//!   ([`crate::policy`] controllers).
//! * [`faults`] — per-kind chaos ledger over one or many timelines
//!   (what was injected, what the coordinator absorbed).
//! * [`expect`] — `[expect]` evaluation over sweeps and cluster sweeps,
//!   the engine behind `spoton check`.
//! * [`frontier`] — the cost-vs-SLA frontier over labeled cluster
//!   populations (bid policies and the hybrid autoscaler,
//!   [`crate::autoscale`]), with Pareto domination marked.

pub mod table;
pub mod table1;
pub mod figures;
pub mod fleet;
pub mod distribution;
pub mod policy;
pub mod faults;
pub mod expect;
pub mod frontier;

pub use distribution::{summarize, SweepDistributions};
pub use expect::{ExpectReport, Violation};
pub use frontier::{frontier as sla_frontier, render_frontier, FrontierPoint};
pub use faults::FaultAccounting;
pub use policy::{
    render_controller_comparison, summarize_controllers,
    ControllerDistributions,
};
pub use fleet::{
    render_policy_comparison, render_pool_breakdown, render_price_timeline,
};
pub use table::TextTable;
pub use table1::{paper_rows, render_comparison, Table1Row};
