//! Fleet reporting: per-pool cost attribution, placement-policy
//! comparison tables (the multi-pool companion to Table I), and the
//! price-over-time view of traced spot markets.

use super::table::TextTable;
use crate::metrics::EventKind;
use crate::sim::RunResult;
use crate::util::fmt::{dollars, pct};

/// Per-pool breakdown of one run: launches, evictions, and the compute
/// cost attributed to each pool, with the attribution total against the
/// run's compute cost (they must match — the billing invariant
/// `tests/fleet_placement.rs` pins).
pub fn render_pool_breakdown(r: &RunResult) -> String {
    let mut t = TextTable::new(&[
        "Pool", "VM size", "Type", "Launches", "Evictions", "Compute",
        "Share",
    ]);
    let attributed: f64 = r.pool_stats.iter().map(|p| p.compute_cost).sum();
    for p in &r.pool_stats {
        t.row(&[
            p.pool.clone(),
            p.vm_size.clone(),
            if p.spot { "spot" } else { "on-demand" }.to_string(),
            p.launches.to_string(),
            p.evictions.to_string(),
            dollars(p.compute_cost),
            if attributed > 0.0 {
                pct(p.compute_cost / attributed)
            } else {
                "—".to_string()
            },
        ]);
    }
    t.row(&[
        "TOTAL".to_string(),
        String::new(),
        String::new(),
        r.instances.to_string(),
        r.evictions.to_string(),
        dollars(attributed),
        String::new(),
    ]);
    let mut out = t.render();
    out.push_str(&format!(
        "  compute {} + storage {} = {}\n",
        dollars(r.compute_cost),
        dollars(r.storage_cost),
        dollars(r.total_cost()),
    ));
    out
}

/// Price-over-time attribution for traced spot markets: every
/// `PoolPriceChanged` event the run recorded (requires
/// [`RecordLevel::Full`](crate::metrics::RecordLevel)), i.e. when each
/// pool's hourly price moved and to what — read next to the invoice,
/// whose per-segment line items bill exactly these spans.
pub fn render_price_timeline(r: &RunResult) -> String {
    let moves: Vec<_> = r
        .timeline
        .events()
        .iter()
        .filter(|e| e.kind == EventKind::PoolPriceChanged)
        .collect();
    if moves.is_empty() {
        return "  (no price moves recorded)\n".to_string();
    }
    let mut t = TextTable::new(&["Time", "Price move"]);
    for e in moves {
        t.row(&[format!("{:?}", e.at), e.detail.to_string()]);
    }
    t.render()
}

/// Side-by-side comparison of several runs of the same scenario under
/// different placement policies (the `fleet_failover` example's table).
pub fn render_policy_comparison(results: &[(&str, &RunResult)]) -> String {
    let mut t = TextTable::new(&[
        "Policy", "Completed", "Makespan", "Evictions", "Instances",
        "Compute", "Storage", "Total",
    ]);
    for (label, r) in results {
        t.row(&[
            label.to_string(),
            if r.completed { "yes" } else { "DNF" }.to_string(),
            r.total.hms(),
            r.evictions.to_string(),
            r.instances.to_string(),
            dollars(r.compute_cost),
            dollars(r.storage_cost),
            dollars(r.total_cost()),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EvictionPlanCfg, PlacementPolicyCfg, PoolCfg};
    use crate::sim::experiment::Experiment;
    use crate::simclock::SimDuration;

    fn two_pool_run() -> RunResult {
        Experiment::table1()
            .named("fleet-report")
            .transparent(SimDuration::from_mins(15))
            .pool(PoolCfg::named("storm").price_factor(0.9).eviction(
                EvictionPlanCfg::Fixed { interval: SimDuration::from_mins(30) },
            ))
            .pool(PoolCfg::named("stable").price_factor(1.1))
            .placement(PlacementPolicyCfg::EvictionAware { penalty: 4.0 })
            .run_sleeper()
            .unwrap()
    }

    #[test]
    fn pool_breakdown_renders_attribution() {
        let r = two_pool_run();
        assert!(r.completed);
        let s = render_pool_breakdown(&r);
        assert!(s.contains("storm"), "{s}");
        assert!(s.contains("stable"), "{s}");
        assert!(s.contains("TOTAL"), "{s}");
        assert!(s.contains("compute"), "{s}");
    }

    #[test]
    fn price_timeline_renders_moves() {
        use crate::cloud::trace::{PricePoint, PriceTrace};
        use crate::config::PoolPricingCfg;
        let spike = PriceTrace::new(vec![PricePoint {
            offset: SimDuration::from_mins(30),
            factor: 1.5,
        }])
        .unwrap();
        let r = Experiment::table1()
            .named("price-report")
            .transparent(SimDuration::from_mins(15))
            .pool(PoolCfg::named("traced").pricing(PoolPricingCfg::Trace(spike)))
            .run_sleeper()
            .unwrap();
        assert!(r.completed);
        let s = render_price_timeline(&r);
        assert!(s.contains("traced"), "{s}");
        assert!(s.contains("->"), "{s}");
        // a run without traces renders the empty note
        let none = render_price_timeline(&two_pool_run());
        assert!(none.contains("no price moves"), "{none}");
    }

    #[test]
    fn policy_comparison_renders_rows() {
        let r = two_pool_run();
        let s = render_policy_comparison(&[
            ("eviction-aware", &r),
            ("again", &r),
        ]);
        assert!(s.contains("eviction-aware"), "{s}");
        assert!(s.contains("Makespan"), "{s}");
        assert!(s.contains("yes"), "{s}");
    }
}
