//! Aligned text tables (no external tabulation crates offline).

/// A simple column-aligned text table with a header row.
#[derive(Debug, Clone)]
pub struct TextTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TextTable {
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render with every column padded to its widest cell.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths = vec![0usize; ncols];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = widths[i].max(h.chars().count());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                let pad = widths[i] - c.chars().count();
                line.push_str(c);
                line.push_str(&" ".repeat(pad));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let total: usize =
            widths.iter().sum::<usize>() + 2 * (ncols.saturating_sub(1));
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// CSV rendering (quoted only when needed).
    pub fn csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(
            &self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(
                &row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","),
            );
            out.push('\n');
        }
        out
    }
}

/// An ASCII horizontal bar chart (for the figure renders).
pub fn bar_chart(items: &[(String, f64)], unit: &str, width: usize) -> String {
    let max = items.iter().map(|(_, v)| *v).fold(0.0f64, f64::max);
    let label_w = items
        .iter()
        .map(|(l, _)| l.chars().count())
        .max()
        .unwrap_or(0);
    let mut out = String::new();
    for (label, v) in items {
        let n = if max > 0.0 {
            ((v / max) * width as f64).round() as usize
        } else {
            0
        };
        out.push_str(&format!(
            "  {:<label_w$}  {}{} {v:.4} {unit}\n",
            label,
            "#".repeat(n),
            " ".repeat(width - n),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = TextTable::new(&["K33", "Total", "Type"]);
        t.row_strs(&["33:50", "3:03:26", "N/A"]);
        t.row_strs(&["29:22", "4:28:22", "Application"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("K33"));
        assert!(lines[1].starts_with("---"));
        // columns align: "Total" column starts at same offset everywhere
        let col = lines[0].find("Total").unwrap();
        assert_eq!(&lines[2][col..col + 7], "3:03:26");
        assert_eq!(&lines[3][col..col + 7], "4:28:22");
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_ragged_rows() {
        let mut t = TextTable::new(&["a", "b"]);
        t.row_strs(&["1"]);
    }

    #[test]
    fn csv_escaping() {
        let mut t = TextTable::new(&["name", "value"]);
        t.row_strs(&["with,comma", "with\"quote"]);
        let csv = t.csv();
        assert!(csv.contains("\"with,comma\""));
        assert!(csv.contains("\"with\"\"quote\""));
    }

    #[test]
    fn bars_scale_to_max() {
        let s = bar_chart(
            &[("on-demand".into(), 1.16), ("spot".into(), 0.29)],
            "$",
            20,
        );
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[0].matches('#').count(), 20);
        assert_eq!(lines[1].matches('#').count(), 5);
    }

    #[test]
    fn empty_chart_is_fine() {
        assert_eq!(bar_chart(&[], "x", 10), "");
    }
}
