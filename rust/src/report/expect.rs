//! `[expect]` evaluation: self-checking scenarios for `spoton check`.
//!
//! A scenario's optional `[expect]` section
//! ([`crate::config::ExpectCfg`]) names bounds the scenario must satisfy
//! to count as healthy — completion, recomputation, cost, wall-clock,
//! restore-fallback and dead-letter bounds, per run and at the
//! population p95. This module evaluates those bounds over a merged
//! sweep (single-job scenarios) or a merged cluster sweep (`[cluster]`
//! scenarios) and reduces the outcome to an [`ExpectReport`]: the list
//! of violations, empty when everything holds. `spoton check` renders
//! the report and exits non-zero on any violation, which is what makes
//! chaos scenarios CI-enforceable instead of eyeball-verified.
//!
//! Evaluation is deterministic: runs are walked in seed order, jobs in
//! job order, bounds in declaration order, so two evaluations of the
//! same population produce byte-identical reports.

use crate::config::ExpectCfg;
use crate::metrics::EventKind;
use crate::report::distribution::Summary;
use crate::report::table::TextTable;
use crate::sim::cluster::SeededClusterRun;
use crate::sim::sweep::SeededRun;
use crate::util::fmt::{dollars, hms_f64};

/// One bound that did not hold: which `[expect]` key, and the concrete
/// run/job evidence.
#[derive(Debug, Clone)]
pub struct Violation {
    /// The `[expect]` key, e.g. `"max_lost_steps"`.
    pub bound: String,
    /// Where and by how much, e.g. `"seed 3: 51200 lost steps > 40000"`.
    pub detail: String,
}

/// The outcome of evaluating one scenario's `[expect]` section.
#[derive(Debug, Clone)]
pub struct ExpectReport {
    pub scenario: String,
    /// Seeds evaluated, in evaluation order.
    pub seeds: Vec<u64>,
    /// How many bounds the section asserted.
    pub checks: usize,
    pub violations: Vec<Violation>,
}

impl ExpectReport {
    pub fn passed(&self) -> bool {
        self.violations.is_empty()
    }
}

/// How many bounds an `[expect]` section asserts (the report's `checks`).
fn active_bounds(cfg: &ExpectCfg) -> usize {
    usize::from(cfg.must_complete)
        + usize::from(cfg.zero_dead_letter)
        + usize::from(cfg.max_lost_steps.is_some())
        + usize::from(cfg.max_cost.is_some())
        + usize::from(cfg.max_makespan.is_some())
        + usize::from(cfg.p95_makespan.is_some())
        + usize::from(cfg.p95_turnaround.is_some())
        + usize::from(cfg.max_restore_fallbacks.is_some())
        + usize::from(cfg.max_unrecovered_restores.is_some())
        + usize::from(cfg.max_deadline_misses.is_some())
        + usize::from(cfg.min_sla_attainment.is_some())
}

/// Aggregate deadline-SLA bounds over every verdict in the population
/// (`[job] deadline_mins` gives each run/job a `deadline_missed`
/// verdict; parse rejects these bounds without one).
fn deadline_bounds(
    cfg: &ExpectCfg,
    v: &mut Vec<Violation>,
    verdicts: impl Iterator<Item = bool>,
) {
    if cfg.max_deadline_misses.is_none() && cfg.min_sla_attainment.is_none() {
        return;
    }
    let (mut misses, mut total) = (0u64, 0u64);
    for missed in verdicts {
        total += 1;
        if missed {
            misses += 1;
        }
    }
    if let Some(bound) = cfg.max_deadline_misses {
        if misses > bound {
            push(v, "max_deadline_misses", format!(
                "{misses} deadline miss(es) > {bound} across the sweep"
            ));
        }
    }
    if let Some(bound) = cfg.min_sla_attainment {
        if total == 0 {
            push(v, "min_sla_attainment", format!(
                "no job carried a deadline verdict (bound {bound})"
            ));
        } else {
            let att = (total - misses) as f64 / total as f64;
            if att < bound {
                push(v, "min_sla_attainment", format!(
                    "attainment {att:.4} < {bound} over {total} job(s)"
                ));
            }
        }
    }
}

/// Evaluate `[expect]` over a merged single-job sweep (seed order). With
/// one job per run, turnaround equals makespan (submission at t=0), so
/// `p95_turnaround` evaluates against the same population as
/// `p95_makespan`.
pub fn evaluate_runs(
    cfg: &ExpectCfg,
    scenario: &str,
    runs: &[SeededRun],
) -> ExpectReport {
    let mut v: Vec<Violation> = Vec::new();
    for r in runs {
        per_run_bounds(cfg, &mut v, r.seed, None, &r.result);
        if cfg.zero_dead_letter && !r.result.completed {
            push(&mut v, "zero_dead_letter", format!(
                "seed {}: run did not finish its workload",
                r.seed
            ));
        }
    }
    let makespans: Vec<f64> =
        runs.iter().map(|r| r.result.total.as_secs_f64()).collect();
    percentile_bound(cfg.p95_makespan, "p95_makespan", &makespans, &mut v);
    percentile_bound(cfg.p95_turnaround, "p95_turnaround", &makespans, &mut v);
    deadline_bounds(
        cfg,
        &mut v,
        runs.iter().filter_map(|r| r.result.deadline_missed),
    );
    ExpectReport {
        scenario: scenario.to_string(),
        seeds: runs.iter().map(|r| r.seed).collect(),
        checks: active_bounds(cfg),
        violations: v,
    }
}

/// Evaluate `[expect]` over a merged cluster sweep: per-run bounds apply
/// to every job of every seeded run; `max_makespan`/`p95_makespan` bound
/// the cluster makespan, `p95_turnaround` the per-job
/// submission-to-finish population, and `zero_dead_letter` demands every
/// job of every run completes.
pub fn evaluate_cluster(
    cfg: &ExpectCfg,
    scenario: &str,
    runs: &[SeededClusterRun],
) -> ExpectReport {
    let mut v: Vec<Violation> = Vec::new();
    for r in runs {
        for j in &r.result.jobs {
            per_job_bounds(cfg, &mut v, r.seed, j);
        }
        if let Some(bound) = cfg.max_makespan {
            if r.result.makespan > bound {
                push(&mut v, "max_makespan", format!(
                    "seed {}: makespan {} > {}",
                    r.seed, r.result.makespan, bound
                ));
            }
        }
    }
    let makespans: Vec<f64> =
        runs.iter().map(|r| r.result.makespan.as_secs_f64()).collect();
    percentile_bound(cfg.p95_makespan, "p95_makespan", &makespans, &mut v);
    let turnarounds: Vec<f64> = runs
        .iter()
        .flat_map(|r| {
            r.result.jobs.iter().map(|j| j.turnaround().as_secs_f64())
        })
        .collect();
    percentile_bound(
        cfg.p95_turnaround,
        "p95_turnaround",
        &turnarounds,
        &mut v,
    );
    deadline_bounds(
        cfg,
        &mut v,
        runs.iter().flat_map(|r| {
            r.result.jobs.iter().filter_map(|j| j.result.deadline_missed)
        }),
    );
    ExpectReport {
        scenario: scenario.to_string(),
        seeds: runs.iter().map(|r| r.seed).collect(),
        checks: active_bounds(cfg),
        violations: v,
    }
}

/// The bounds shared by both modes, applied to one run result. `job` is
/// `Some(name)` for a cluster job, folded into the evidence string.
fn per_run_bounds(
    cfg: &ExpectCfg,
    v: &mut Vec<Violation>,
    seed: u64,
    job: Option<&str>,
    r: &crate::sim::RunResult,
) {
    let whom = match job {
        Some(name) => format!("seed {seed} {name}"),
        None => format!("seed {seed}"),
    };
    if cfg.must_complete && !r.completed {
        push(v, "must_complete", format!(
            "{whom}: run did not finish its workload"
        ));
    }
    if let Some(bound) = cfg.max_lost_steps {
        if r.lost_steps > bound {
            push(v, "max_lost_steps", format!(
                "{whom}: {} lost steps > {bound}",
                r.lost_steps
            ));
        }
    }
    if let Some(bound) = cfg.max_cost {
        if r.total_cost() > bound {
            push(v, "max_cost", format!(
                "{whom}: {} > {}",
                dollars(r.total_cost()),
                dollars(bound)
            ));
        }
    }
    if job.is_none() {
        if let Some(bound) = cfg.max_makespan {
            if r.total > bound {
                push(v, "max_makespan", format!(
                    "{whom}: makespan {} > {}",
                    r.total, bound
                ));
            }
        }
    }
    if let Some(bound) = cfg.max_restore_fallbacks {
        let n = r.timeline.count(EventKind::RestoreFallback) as u64;
        if n > bound {
            push(v, "max_restore_fallbacks", format!(
                "{whom}: {n} restore fallbacks > {bound}"
            ));
        }
    }
    if let Some(bound) = cfg.max_unrecovered_restores {
        let n = r.timeline.count(EventKind::UnrecoveredRestore) as u64;
        if n > bound {
            push(v, "max_unrecovered_restores", format!(
                "{whom}: {n} unrecovered restores > {bound}"
            ));
        }
    }
}

fn per_job_bounds(
    cfg: &ExpectCfg,
    v: &mut Vec<Violation>,
    seed: u64,
    j: &crate::sim::cluster::JobOutcome,
) {
    per_run_bounds(cfg, v, seed, Some(&j.name), &j.result);
    if cfg.zero_dead_letter && !j.result.completed {
        push(v, "zero_dead_letter", format!(
            "seed {seed} {}: job did not finish its workload",
            j.name
        ));
    }
}

/// Nearest-rank p95 over `samples` (seconds) against `bound`.
fn percentile_bound(
    bound: Option<crate::simclock::SimDuration>,
    name: &str,
    samples: &[f64],
    v: &mut Vec<Violation>,
) {
    let Some(bound) = bound else { return };
    if samples.is_empty() {
        return;
    }
    let p95 = Summary::from_samples(samples).p95;
    if p95 > bound.as_secs_f64() {
        push(v, name, format!(
            "population p95 {} > {} over {} sample(s)",
            hms_f64(p95),
            bound,
            samples.len()
        ));
    }
}

fn push(v: &mut Vec<Violation>, bound: &str, detail: String) {
    v.push(Violation { bound: bound.to_string(), detail });
}

/// Render the report: a verdict line, then every violation as an
/// aligned table row (empty table elided on pass).
pub fn render(report: &ExpectReport) -> String {
    let mut out = format!(
        "{}: {} seed(s), {} check(s) — {}\n",
        report.scenario,
        report.seeds.len(),
        report.checks,
        if report.passed() {
            "PASS".to_string()
        } else {
            format!("FAIL ({} violation(s))", report.violations.len())
        }
    );
    if !report.passed() {
        let mut t = TextTable::new(&["Bound", "Evidence"]);
        for viol in &report.violations {
            t.row(&[viol.bound.clone(), viol.detail.clone()]);
        }
        out.push_str(&t.render());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::experiment::Experiment;
    use crate::simclock::SimDuration;

    fn sweep(n: usize) -> Vec<SeededRun> {
        Experiment::table1()
            .named("expect-unit")
            .eviction_poisson(SimDuration::from_mins(75))
            .transparent(SimDuration::from_mins(20))
            .sweep()
            .seed_range(0, n)
            .threads(1)
            .run()
            .unwrap()
    }

    #[test]
    fn healthy_sweep_passes_generous_bounds() {
        let runs = sweep(4);
        let cfg = ExpectCfg {
            seeds: 4,
            must_complete: true,
            max_unrecovered_restores: Some(0),
            p95_makespan: Some(SimDuration::from_hours(400)),
            ..ExpectCfg::default()
        };
        let rep = evaluate_runs(&cfg, "expect-unit", &runs);
        assert!(rep.passed(), "{:?}", rep.violations);
        assert_eq!(rep.checks, 3);
        assert_eq!(rep.seeds, [0, 1, 2, 3]);
        assert!(render(&rep).contains("PASS"));
    }

    #[test]
    fn impossible_bounds_fail_with_evidence() {
        let runs = sweep(3);
        let cfg = ExpectCfg {
            seeds: 3,
            max_cost: Some(0.0),
            max_makespan: Some(SimDuration::from_mins(1)),
            p95_makespan: Some(SimDuration::from_mins(1)),
            ..ExpectCfg::default()
        };
        let rep = evaluate_runs(&cfg, "expect-unit", &runs);
        assert!(!rep.passed());
        // every run violates the two per-run bounds; the percentile
        // bound violates once
        assert_eq!(rep.violations.len(), 3 * 2 + 1, "{:?}", rep.violations);
        let text = render(&rep);
        assert!(text.contains("FAIL"), "{text}");
        assert!(text.contains("max_cost"), "{text}");
        assert!(text.contains("seed 1"), "{text}");
        assert!(text.contains("population p95"), "{text}");
    }

    #[test]
    fn evaluation_is_deterministic() {
        let runs = sweep(3);
        let cfg = ExpectCfg {
            seeds: 3,
            max_cost: Some(0.0),
            ..ExpectCfg::default()
        };
        let a = render(&evaluate_runs(&cfg, "expect-unit", &runs));
        let b = render(&evaluate_runs(&cfg, "expect-unit", &runs));
        assert_eq!(a, b);
    }

    #[test]
    fn deadline_bounds_pass_and_fail_on_the_aggregate() {
        // A generous deadline: every run finishes well inside it.
        let mut exp = Experiment::table1()
            .named("expect-sla")
            .scale_stages(0.02)
            .transparent(SimDuration::from_mins(10))
            .deadline(SimDuration::from_hours(400));
        exp.cfg.job_deadline = Some(SimDuration::from_hours(300));
        let runs =
            exp.sweep().seed_range(0, 3).threads(1).run().unwrap();
        assert!(
            runs.iter().all(|r| r.result.deadline_missed == Some(false)),
            "generous deadline must be met"
        );
        let pass = ExpectCfg {
            seeds: 3,
            max_deadline_misses: Some(0),
            min_sla_attainment: Some(1.0),
            ..ExpectCfg::default()
        };
        let rep = evaluate_runs(&pass, "expect-sla", &runs);
        assert!(rep.passed(), "{:?}", rep.violations);
        assert_eq!(rep.checks, 2);

        // An impossible deadline: every run misses, both bounds trip.
        exp.cfg.job_deadline = Some(SimDuration::from_millis(1));
        let runs =
            exp.sweep().seed_range(0, 3).threads(1).run().unwrap();
        assert!(runs
            .iter()
            .all(|r| r.result.deadline_missed == Some(true)));
        let rep = evaluate_runs(&pass, "expect-sla", &runs);
        assert!(!rep.passed());
        let bounds: Vec<&str> =
            rep.violations.iter().map(|v| v.bound.as_str()).collect();
        assert_eq!(
            bounds,
            ["max_deadline_misses", "min_sla_attainment"],
            "{:?}",
            rep.violations
        );
        assert!(
            rep.violations[1].detail.contains("attainment 0.0000"),
            "{:?}",
            rep.violations
        );
    }

    #[test]
    fn cluster_mode_bounds_jobs_and_turnaround() {
        use crate::config::ClusterCfg;
        let mut exp = Experiment::table1()
            .named("expect-cluster")
            .scale_stages(0.02)
            .transparent(SimDuration::from_mins(10))
            .deadline(SimDuration::from_hours(400));
        exp.cfg.cluster = Some(ClusterCfg::with_count(3).capacity(1));
        let runs = exp.cluster_sweep().seed_range(0, 2).threads(1).run().unwrap();
        let pass = ExpectCfg {
            seeds: 2,
            must_complete: true,
            zero_dead_letter: true,
            p95_turnaround: Some(SimDuration::from_hours(400)),
            ..ExpectCfg::default()
        };
        let rep = evaluate_cluster(&pass, "expect-cluster", &runs);
        assert!(rep.passed(), "{:?}", rep.violations);
        // 3 jobs share 1 slot: a tight turnaround p95 must trip on the
        // queued jobs even though each job's own runtime is short
        let tight = ExpectCfg {
            seeds: 2,
            p95_turnaround: Some(SimDuration::from_millis(1)),
            ..ExpectCfg::default()
        };
        let rep = evaluate_cluster(&tight, "expect-cluster", &runs);
        assert!(!rep.passed());
        assert_eq!(rep.violations[0].bound, "p95_turnaround");
    }
}
