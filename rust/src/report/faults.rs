//! Fault accounting: what chaos injected and how the coordinator coped.
//!
//! Chaos runs ([`crate::sim::chaos`]) surface every injected fault and
//! every degradation as a chaos-tagged [`EventKind`] on the timeline —
//! write faults, torn writes, corruptions, latency spikes, storms, IMDS
//! outages, degraded polls, checkpoint retries, restore fallbacks and
//! unrecovered restores. This module reduces one or many timelines into
//! a per-kind ledger and renders it as an aligned table, so a chaos
//! scenario's outcome reads as an explicit account instead of a diff
//! over raw event streams.

use crate::metrics::{EventKind, Timeline};
use crate::report::table::TextTable;

/// Per-kind totals of every chaos-tagged timeline event, in
/// [`EventKind::ALL`] order (injected faults first, then the
/// coordinator's observed degradations and recoveries).
#[derive(Debug, Clone)]
pub struct FaultAccounting {
    pub counts: Vec<(EventKind, usize)>,
}

impl FaultAccounting {
    /// Total chaos events across every kind.
    pub fn total(&self) -> usize {
        self.counts.iter().map(|(_, n)| n).sum()
    }

    /// Count for one kind (0 for non-chaos kinds).
    pub fn count(&self, kind: EventKind) -> usize {
        self.counts
            .iter()
            .find(|(k, _)| *k == kind)
            .map_or(0, |(_, n)| *n)
    }
}

/// Reduce one timeline into its chaos ledger.
pub fn account(timeline: &Timeline) -> FaultAccounting {
    account_many([timeline])
}

/// Reduce many timelines (a sweep's runs, a cluster's jobs) into one
/// summed ledger. Counts work at every [`RecordLevel`] — a Counts-level
/// sweep still accounts its faults.
///
/// [`RecordLevel`]: crate::metrics::RecordLevel
pub fn account_many<'a>(
    timelines: impl IntoIterator<Item = &'a Timeline>,
) -> FaultAccounting {
    let mut counts: Vec<(EventKind, usize)> = EventKind::ALL
        .iter()
        .copied()
        .filter(|k| k.is_chaos())
        .map(|k| (k, 0))
        .collect();
    for t in timelines {
        for (k, n) in counts.iter_mut() {
            *n += t.count(*k);
        }
    }
    FaultAccounting { counts }
}

/// Aligned text table: one row per chaos kind (zeros included — an
/// accounting table that hides its zero rows can't show "no corruption
/// got through"), plus a totals row.
pub fn render(acc: &FaultAccounting) -> String {
    let mut t = TextTable::new(&["Fault event", "Count"]);
    for (k, n) in &acc.counts {
        t.row(&[k.as_str().to_string(), n.to_string()]);
    }
    t.row(&["total".to_string(), acc.total().to_string()]);
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::RecordLevel;
    use crate::simclock::SimTime;

    #[test]
    fn accounts_only_chaos_kinds() {
        let mut tl = Timeline::with_level(RecordLevel::Counts);
        tl.record(SimTime::ZERO, EventKind::ChaosWriteFault, "k");
        tl.record(SimTime::ZERO, EventKind::ChaosWriteFault, "k");
        tl.record(SimTime::ZERO, EventKind::CkptRetried, "r");
        tl.record(SimTime::ZERO, EventKind::InstanceLaunch, "i-0");
        let acc = account(&tl);
        assert_eq!(acc.count(EventKind::ChaosWriteFault), 2);
        assert_eq!(acc.count(EventKind::CkptRetried), 1);
        assert_eq!(acc.count(EventKind::InstanceLaunch), 0, "not chaos");
        assert_eq!(acc.total(), 3);
        assert!(acc.counts.iter().all(|(k, _)| k.is_chaos()));
    }

    #[test]
    fn sums_across_timelines_and_renders_zeros() {
        let mut a = Timeline::with_level(RecordLevel::Counts);
        let mut b = Timeline::with_level(RecordLevel::Counts);
        a.record(SimTime::ZERO, EventKind::ImdsOutage, "down");
        b.record(SimTime::ZERO, EventKind::ImdsOutage, "down");
        b.record(SimTime::ZERO, EventKind::RestoreFallback, "ckpt 3");
        let acc = account_many([&a, &b]);
        assert_eq!(acc.count(EventKind::ImdsOutage), 2);
        assert_eq!(acc.count(EventKind::RestoreFallback), 1);
        let text = render(&acc);
        assert!(text.contains("imds-outage"), "{text}");
        // zero rows stay visible
        assert!(text.contains("chaos-corrupt"), "{text}");
        assert!(text.contains("total"), "{text}");
    }
}
