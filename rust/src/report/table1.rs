//! Table I of the paper: row definitions, published values, and the
//! paper-vs-measured comparison renderer.

use super::table::TextTable;
use crate::sim::RunResult;
use crate::sim::experiment::Experiment;
use crate::simclock::SimDuration;
use crate::util::fmt::parse_hms;

/// One row of the paper's Table I.
#[derive(Debug, Clone)]
pub struct Table1Row {
    /// Short row id, e.g. "row5".
    pub id: &'static str,
    pub spoton: &'static str,          // "ON" | "OFF"
    pub eviction: &'static str,        // "N/A" | "Every 90 min" | ...
    pub checkpoint: &'static str,      // "N/A" | "Application" | ...
    /// Paper's published values: K33, K55, K77, K99, K127, Total.
    pub paper: [&'static str; 6],
}

impl Table1Row {
    /// The experiment reproducing this row.
    pub fn experiment(&self) -> Experiment {
        let mut e = Experiment::table1().named(self.id);
        if self.spoton == "OFF" {
            e = e.spoton_off();
        }
        e = match self.eviction {
            "N/A" => e,
            "Every 90 min" => e.eviction_every(SimDuration::from_mins(90)),
            "Every 60 min" => e.eviction_every(SimDuration::from_mins(60)),
            other => panic!("unknown eviction spec {other}"),
        };
        e = match self.checkpoint {
            "N/A" => e.unprotected(),
            "Application" => e.app_native(),
            "Transparent 30 min" => e.transparent(SimDuration::from_mins(30)),
            "Transparent 15 min" => e.transparent(SimDuration::from_mins(15)),
            other => panic!("unknown checkpoint spec {other}"),
        };
        e
    }

    /// Paper total in seconds.
    pub fn paper_total_secs(&self) -> u64 {
        // spoton-lint: allow(D3, reason = "hard-coded paper constant; parse checked by tests")
        parse_hms(self.paper[5]).expect("paper value parses")
    }
}

/// The paper's Table I, verbatim.
pub fn paper_rows() -> Vec<Table1Row> {
    vec![
        Table1Row {
            id: "row1",
            spoton: "OFF",
            eviction: "N/A",
            checkpoint: "N/A",
            paper: ["33:50", "38:53", "39:51", "40:19", "30:33", "3:03:26"],
        },
        Table1Row {
            id: "row2",
            spoton: "ON",
            eviction: "N/A",
            checkpoint: "N/A",
            paper: ["33:57", "39:03", "41:35", "40:41", "31:01", "3:05:32"],
        },
        Table1Row {
            id: "row3",
            spoton: "ON",
            eviction: "Every 90 min",
            checkpoint: "Application",
            paper: ["33:33", "40:15", "57:16", "38:56", "46:14", "3:36:14"],
        },
        Table1Row {
            id: "row4",
            spoton: "ON",
            eviction: "Every 60 min",
            checkpoint: "Application",
            paper: ["29:22", "1:05:25", "1:03:03", "59:25", "51:07", "4:28:22"],
        },
        Table1Row {
            id: "row5",
            spoton: "ON",
            eviction: "Every 90 min",
            checkpoint: "Transparent 30 min",
            paper: ["32:52", "37:03", "41:15", "39:53", "28:32", "2:59:35"],
        },
        Table1Row {
            id: "row6",
            spoton: "ON",
            eviction: "Every 90 min",
            checkpoint: "Transparent 15 min",
            paper: ["32:45", "38:13", "41:58", "39:50", "32:22", "3:05:08"],
        },
        Table1Row {
            id: "row7",
            spoton: "ON",
            eviction: "Every 60 min",
            checkpoint: "Transparent 30 min",
            paper: ["32:40", "38:52", "41:10", "39:45", "28:34", "3:01:01"],
        },
        Table1Row {
            id: "row8",
            spoton: "ON",
            eviction: "Every 60 min",
            checkpoint: "Transparent 15 min",
            paper: ["31:10", "38:15", "42:05", "40:01", "30:29", "3:02:00"],
        },
    ]
}

/// Render the paper-vs-measured comparison for a set of (row, result)
/// pairs.
pub fn render_comparison(results: &[(Table1Row, RunResult)]) -> String {
    let mut t = TextTable::new(&[
        "Row", "Spot-on", "Eviction", "Checkpoint", "K33", "K55", "K77",
        "K99", "K127", "Total", "Paper", "Δ",
    ]);
    for (row, r) in results {
        let stage = |label: &str| {
            r.stage(label).map(|d| d.hms()).unwrap_or_else(|| "—".into())
        };
        let measured = r.total.as_secs() as f64;
        let paper = row.paper_total_secs() as f64;
        let delta = (measured - paper) / paper;
        t.row(&[
            row.id.to_string(),
            row.spoton.to_string(),
            row.eviction.to_string(),
            row.checkpoint.to_string(),
            stage("K33"),
            stage("K55"),
            stage("K77"),
            stage("K99"),
            stage("K127"),
            if r.completed {
                r.total.hms()
            } else {
                "DNF".to_string()
            },
            row.paper[5].to_string(),
            crate::util::fmt::pct(delta),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eight_rows_matching_paper() {
        let rows = paper_rows();
        assert_eq!(rows.len(), 8);
        assert_eq!(rows[0].paper_total_secs(), 11006); // 3:03:26
        assert_eq!(rows[3].paper_total_secs(), 16102); // 4:28:22
        // per-stage values sum to ~the published total (±60s of rounding)
        for row in &rows {
            let sum: u64 = row.paper[..5]
                .iter()
                .map(|s| parse_hms(s).unwrap())
                .sum();
            let total = row.paper_total_secs();
            assert!(
                sum.abs_diff(total) <= 60,
                "{}: stages sum {sum} vs total {total}",
                row.id
            );
        }
    }

    #[test]
    fn experiments_match_row_specs() {
        use crate::config::{CheckpointMethodCfg, EvictionPlanCfg};
        let rows = paper_rows();
        let e1 = rows[0].experiment();
        assert!(!e1.cfg.coordinator_attached);
        assert_eq!(e1.cfg.eviction, EvictionPlanCfg::None);
        let e4 = rows[3].experiment();
        assert_eq!(
            e4.cfg.eviction,
            EvictionPlanCfg::Fixed { interval: SimDuration::from_mins(60) }
        );
        assert_eq!(e4.cfg.checkpoint, CheckpointMethodCfg::AppNative);
        let e8 = rows[7].experiment();
        assert_eq!(
            e8.cfg.checkpoint,
            CheckpointMethodCfg::Transparent {
                interval: SimDuration::from_mins(15)
            }
        );
    }

    #[test]
    fn comparison_renders_with_sleeper_run() {
        let rows = paper_rows();
        let row = rows[0].clone();
        let result = row.experiment().run_sleeper().unwrap();
        let s = render_comparison(&[(row, result)]);
        assert!(s.contains("row1"));
        assert!(s.contains("3:03:26"));
        assert!(s.contains("Paper"));
    }
}
