//! Fixed-vs-adaptive checkpoint-interval comparison over sweep
//! populations.
//!
//! The paper's Table I fixes the transparent interval offline; the
//! [`crate::policy`] controllers tune it online. This module reduces the
//! per-controller populations a [`crate::sim::sweep::Sweep`] produces
//! ([`Sweep::run_controllers`](crate::sim::Sweep::run_controllers)) into
//! one comparison table — makespan p50/p95, cost mean/p95, lost steps,
//! checkpoints taken — so "does Young/Daly beat the paper's interval?"
//! is answered by distributions, not a single lucky seed
//! (`examples/adaptive_interval.rs` is the headline driver).

use super::distribution::{self, Summary, SweepDistributions};
use super::table::TextTable;
use crate::sim::sweep::ControllerSweep;
use crate::util::fmt::{dollars, hms_f64 as hms};

/// One controller's reduced sweep: the standard distribution summaries
/// plus the checkpoint-activity metrics the interval controller directly
/// drives.
#[derive(Debug, Clone)]
pub struct ControllerDistributions {
    pub label: String,
    pub dist: SweepDistributions,
    /// Periodic (transparent) checkpoints per run.
    pub periodic_ckpts: Summary,
    /// Committed termination checkpoints per run.
    pub termination_ckpts: Summary,
}

/// Reduce each controller's merged population (walks runs in seed order,
/// like [`distribution::summarize`] — deterministic for a deterministic
/// sweep).
pub fn summarize_controllers(
    sweeps: &[ControllerSweep],
) -> Vec<ControllerDistributions> {
    // One Summarizer across every controller population: seven summaries
    // per controller share two buffers instead of reallocating each.
    let mut sz = distribution::Summarizer::new();
    sweeps
        .iter()
        .map(|s| {
            let dist = distribution::summarize_with(&mut sz, &s.label, &s.runs);
            for r in &s.runs {
                sz.push(r.result.periodic_ckpts as f64);
            }
            let periodic_ckpts = sz.finish();
            for r in &s.runs {
                sz.push(r.result.termination_ok as f64);
            }
            let termination_ckpts = sz.finish();
            ControllerDistributions {
                label: s.label.clone(),
                dist,
                periodic_ckpts,
                termination_ckpts,
            }
        })
        .collect()
}

/// The comparison table: one row per controller, the fixed baseline
/// first by convention (whatever order the sweeps were run in).
pub fn render_controller_comparison(
    entries: &[ControllerDistributions],
) -> String {
    let mut t = TextTable::new(&[
        "Controller",
        "Completed",
        "Makespan p50",
        "Makespan p95",
        "Cost mean",
        "Cost p95",
        "Lost steps",
        "Ckpts/run",
        "Term ckpts",
    ]);
    for e in entries {
        t.row(&[
            e.label.clone(),
            format!("{}/{}", e.dist.completed, e.dist.runs),
            hms(e.dist.makespan_secs.p50),
            hms(e.dist.makespan_secs.p95),
            dollars(e.dist.total_cost.mean),
            dollars(e.dist.total_cost.p95),
            format!("{:.1}", e.dist.lost_steps.mean),
            format!("{:.1}", e.periodic_ckpts.mean),
            format!("{:.1}", e.termination_ckpts.mean),
        ]);
    }
    let mut out = t.render();
    if let Some(fixed) =
        entries.iter().find(|e| e.label == "fixed").filter(|_| entries.len() > 1)
    {
        for e in entries.iter().filter(|e| e.label != "fixed") {
            let cost = 1.0 - e.dist.total_cost.mean / fixed.dist.total_cost.mean;
            let p95 = 1.0
                - e.dist.makespan_secs.p95 / fixed.dist.makespan_secs.p95;
            out.push_str(&format!(
                "  {} vs fixed: mean cost {:+.1}%, p95 makespan {:+.1}%\n",
                e.label,
                -100.0 * cost,
                -100.0 * p95,
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::IntervalControllerCfg;
    use crate::sim::experiment::Experiment;
    use crate::simclock::SimDuration;

    #[test]
    fn summarizes_and_renders_a_controller_comparison() {
        let sweeps = Experiment::table1()
            .named("policy-report")
            .eviction_poisson(SimDuration::from_mins(45))
            .transparent(SimDuration::from_mins(30))
            .deadline(SimDuration::from_hours(30))
            .sweep()
            .seed_range(0, 6)
            .threads(2)
            .run_controllers(&[
                IntervalControllerCfg::Fixed,
                IntervalControllerCfg::young_daly(),
            ])
            .unwrap();
        let entries = summarize_controllers(&sweeps);
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].label, "fixed");
        assert_eq!(entries[0].dist.runs, 6);
        assert!(entries[0].periodic_ckpts.mean > 0.0);
        // young-daly tightens the cadence under this storm
        assert!(
            entries[1].periodic_ckpts.mean > entries[0].periodic_ckpts.mean
        );
        let text = render_controller_comparison(&entries);
        assert!(text.contains("fixed"), "{text}");
        assert!(text.contains("young-daly"), "{text}");
        assert!(text.contains("Makespan p95"), "{text}");
        assert!(text.contains("vs fixed"), "{text}");
    }
}
