//! Mini requeue scheduler — the Slurm/LSF path of paper §II.
//!
//! "After a spot instance is terminated, a new one is created manually or
//! automatically through a cloud vendor's spot scheduling system or a
//! separate job/resource scheduler (e.g., Slurm and LSF)."
//!
//! The scale set covers the first path; this module models the second: a
//! single-slot batch queue (like a Slurm partition of spot nodes with
//! `--requeue`). Jobs run one at a time; an evicted job goes back to the
//! *tail* of the queue and pays a scheduling delay before its next
//! attempt, so queue wait — not just provisioning — contributes to
//! turnaround. Used by the `eviction_storm` example and queue-behaviour
//! tests.

use crate::sim::experiment::Experiment;
use crate::simclock::{SimDuration, SimTime};
use anyhow::Result;

/// A queued job: one scenario to completion.
#[derive(Debug, Clone)]
pub struct Job {
    pub id: u32,
    pub name: String,
    pub experiment: Experiment,
}

/// Per-job outcome.
#[derive(Debug)]
pub struct JobRecord {
    pub id: u32,
    pub name: String,
    pub submitted_at: SimTime,
    pub started_at: SimTime,
    pub finished_at: SimTime,
    pub attempts: u32,
    pub evictions: u32,
    pub completed: bool,
    pub cost: f64,
}

impl JobRecord {
    pub fn wait(&self) -> SimDuration {
        self.started_at.since(self.submitted_at)
    }

    pub fn turnaround(&self) -> SimDuration {
        self.finished_at.since(self.submitted_at)
    }
}

/// Single-slot requeue scheduler.
pub struct RequeueScheduler {
    /// Delay between an eviction and the next attempt starting (queue
    /// scheduling latency; replaces the scale set's provisioning delay in
    /// the requeue path).
    pub requeue_delay: SimDuration,
    /// Attempt cap per job (abandon pathological jobs).
    pub max_attempts: u32,
}

impl Default for RequeueScheduler {
    fn default() -> Self {
        Self {
            requeue_delay: SimDuration::from_secs(300),
            max_attempts: 16,
        }
    }
}

impl RequeueScheduler {
    /// Run all jobs to completion (or attempt exhaustion), FIFO with
    /// requeue-at-tail. The slot-level clock advances by each attempt's
    /// virtual duration.
    ///
    /// Each attempt reuses the job's shared checkpoint namespace: within
    /// one scheduler run, a job's later attempts restore what earlier
    /// attempts checkpointed (one run == one share), which is exactly how
    /// a Slurm requeue with shared NFS behaves.
    pub fn run(&self, jobs: Vec<Job>) -> Result<Vec<JobRecord>> {
        // Each job gets its own share (BlobStore) that persists across
        // its attempts.
        struct Pending {
            job: Job,
            submitted_at: SimTime,
            first_start: Option<SimTime>,
            attempts: u32,
            evictions: u32,
            cost: f64,
            store: crate::storage::BlobStore,
        }

        let mut now = SimTime::ZERO;
        let mut queue: std::collections::VecDeque<Pending> = jobs
            .into_iter()
            .map(|job| {
                let model = crate::storage::TransferModel {
                    bandwidth_mib_s: job.experiment.cfg.storage.bandwidth_mib_s,
                    latency: job.experiment.cfg.storage.latency,
                };
                Pending {
                    store: crate::storage::BlobStore::new(
                        model,
                        Some(job.experiment.cfg.storage.provisioned_gib),
                    ),
                    job,
                    submitted_at: SimTime::ZERO,
                    first_start: None,
                    attempts: 0,
                    evictions: 0,
                    cost: 0.0,
                }
            })
            .collect();
        let mut records = Vec::new();

        while let Some(mut p) = queue.pop_front() {
            if p.attempts > 0 {
                now += self.requeue_delay;
            }
            if p.first_start.is_none() {
                p.first_start = Some(now);
            }
            p.attempts += 1;

            // One attempt = one experiment run *bounded to a single
            // instance*: force the scale set to not auto-replace by
            // setting an immediate deadline after the first eviction.
            // Simpler: run the whole experiment (scale-set path) when the
            // job is protected; the requeue model applies between whole-
            // job failures. To surface requeue behaviour, treat each
            // eviction inside the run as an attempt boundary is
            // unnecessary — instead we run the experiment with
            // provisioning_delay = requeue_delay, which is the requeue
            // path's replacement semantics.
            let mut exp = p.job.experiment.clone();
            exp.cfg.cloud.provisioning_delay = self.requeue_delay;
            let bumped = exp.cfg.seed.wrapping_add(p.attempts as u64);
            exp = exp.seed(bumped);

            let cfg_sleeper = exp.cfg.workload.clone();
            let _ = cfg_sleeper;
            let result = {
                let mut factory = exp.sleeper_factory();
                crate::sim::driver::SimDriver::new(&exp.cfg, &mut p.store)
                    .run(&mut *factory)?
            };
            now += result.total;
            p.evictions += result.evictions;
            p.cost += result.total_cost();

            if result.completed || p.attempts >= self.max_attempts {
                records.push(JobRecord {
                    id: p.job.id,
                    name: p.job.name.clone(),
                    submitted_at: p.submitted_at,
                    started_at: p.first_start.unwrap(),
                    finished_at: now,
                    attempts: p.attempts,
                    evictions: p.evictions,
                    completed: result.completed,
                    cost: p.cost,
                });
            } else {
                queue.push_back(p);
            }
        }
        Ok(records)
    }
}

impl Experiment {
    /// A boxed sleeper factory for scheduler use.
    pub fn sleeper_factory(
        &self,
    ) -> Box<dyn FnMut() -> Result<Box<dyn crate::workload::Workload>>> {
        let w = &self.cfg.workload;
        let cfg = crate::workload::sleeper::SleeperCfg {
            stages: w.ks.iter().map(|k| (format!("K{k}"), 40u64)).collect(),
            milestones_per_stage: w.app_milestones_per_stage,
            charged_bytes: (w.state_gib * (1u64 << 30) as f64) as u64,
            app_charged_bytes: (w.app_ckpt_gib * (1u64 << 30) as f64) as u64,
        };
        let seed = w.seed;
        Box::new(move || {
            Ok(Box::new(crate::workload::sleeper::Sleeper::new(
                cfg.clone(),
                seed,
            )))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simclock::SimDuration;

    #[test]
    fn fifo_jobs_complete_in_order() {
        let mk = |i: u32| Job {
            id: i,
            name: format!("job-{i}"),
            experiment: Experiment::table1()
                .named("queued")
                .transparent(SimDuration::from_mins(30)),
        };
        let sched = RequeueScheduler::default();
        let records = sched.run(vec![mk(0), mk(1)]).unwrap();
        assert_eq!(records.len(), 2);
        assert!(records.iter().all(|r| r.completed));
        assert_eq!(records[0].id, 0);
        assert_eq!(records[1].id, 1);
        // job 1 waited for job 0
        assert!(records[1].turnaround() > records[0].turnaround());
        assert_eq!(records[0].attempts, 1);
    }

    #[test]
    fn evicted_protected_jobs_still_finish_with_requeue_delay() {
        let job = Job {
            id: 7,
            name: "stormy".into(),
            experiment: Experiment::table1()
                .eviction_every(SimDuration::from_mins(60))
                .transparent(SimDuration::from_mins(15)),
        };
        let sched = RequeueScheduler {
            requeue_delay: SimDuration::from_secs(600),
            max_attempts: 4,
        };
        let records = sched.run(vec![job]).unwrap();
        assert_eq!(records.len(), 1);
        let r = &records[0];
        assert!(r.completed, "protected job must finish");
        assert!(r.evictions >= 2);
        // requeue delay (600s) charged per replacement, visible in
        // turnaround vs the 3:03 baseline + overheads
        assert!(r.turnaround().as_secs() > 11006);
    }

    #[test]
    fn attempt_cap_abandons_doomed_jobs() {
        // unprotected + frequent evictions can never finish
        let job = Job {
            id: 1,
            name: "doomed".into(),
            experiment: Experiment::table1()
                .named("doomed")
                .eviction_every(SimDuration::from_mins(30))
                .unprotected()
                .deadline(SimDuration::from_hours(2)),
        };
        let sched = RequeueScheduler {
            requeue_delay: SimDuration::from_secs(60),
            max_attempts: 2,
        };
        let records = sched.run(vec![job]).unwrap();
        assert_eq!(records.len(), 1);
        assert!(!records[0].completed);
        assert_eq!(records[0].attempts, 2);
    }
}
