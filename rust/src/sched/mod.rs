//! Mini requeue scheduler — the Slurm/LSF path of paper §II.
//!
//! "After a spot instance is terminated, a new one is created manually or
//! automatically through a cloud vendor's spot scheduling system or a
//! separate job/resource scheduler (e.g., Slurm and LSF)."
//!
//! The scale set covers the first path; this module models the second: a
//! batch queue (like a Slurm partition of spot nodes with `--requeue`)
//! driven by the same deterministic `simclock::EventQueue` the experiment
//! engine runs on. The cluster has `slots` concurrent spot slots; jobs
//! are FIFO; an evicted/failed job goes back to the *tail* of the queue
//! after a scheduling delay, so queue wait — not just provisioning —
//! contributes to turnaround.
//!
//! Unlike the pre-event-core version (which serialized whole experiments
//! and charged requeue delays inline), the scheduler is genuinely
//! event-driven: while job A waits out its requeue delay, job B runs in
//! the freed slot — the [`SchedEvent::RequeueReady`] timer and job B's
//! [`SchedEvent::AttemptDone`] interleave on the shared queue. One
//! attempt occupies one slot for its whole (virtual) duration; jobs
//! interact only through slot contention, so each attempt's internals run
//! through the experiment engine as an atomic slot occupancy, with the
//! scale set's provisioning delay replaced by the requeue delay (the
//! requeue path's replacement semantics).
//!
//! Each job keeps one share (BlobStore) across its attempts: later
//! attempts restore what earlier attempts checkpointed — exactly how a
//! Slurm requeue with shared NFS behaves.
//!
//! With [`RequeueScheduler::fleet`] set, every job draws its instances
//! from the same multi-pool replacement fleet
//! ([`crate::cloud::fleet::Fleet`]) instead of each experiment's own
//! single scale set: the cluster's slots allocate from shared
//! heterogeneous spot pools, and [`aggregate_pool_stats`] reports the
//! cluster-wide per-pool usage and cost.
//!
//! ## The multiplexed path
//!
//! The requeue scheduler still builds **one engine per attempt**: a
//! slot's whole attempt is atomic, and its fleet state is rebuilt each
//! time. The multiplexed cluster engine ([`crate::sim::cluster`]) is the
//! scaled successor for contended-fleet studies — jobs interleave
//! event-by-event on one queue around one live capacity-bounded fleet,
//! and admission waits are real simulated queueing, not slot accounting.
//! [`cluster_records`] is the thin admission layer between the two
//! worlds: it maps each [`crate::sim::cluster::JobOutcome`] onto a
//! [`JobRecord`] whose `started_at` is the job's *first admission*
//! instant, so [`JobRecord::wait`] / [`JobRecord::turnaround`] — and
//! every report built on them, including [`aggregate_pool_stats`] —
//! reflect genuine capacity-induced queueing.

use crate::cloud::fleet::PoolStats;
use crate::config::FleetCfg;
use crate::metrics::{EventKind, Timeline};
use crate::sim::SimDriver;
use crate::sim::experiment::Experiment;
use crate::simclock::{Clock, EventQueue, SimDuration, SimTime};
use anyhow::Result;
use std::collections::VecDeque;

/// A queued job: one scenario to completion.
#[derive(Debug, Clone)]
pub struct Job {
    pub id: u32,
    pub name: String,
    pub experiment: Experiment,
}

/// Per-job outcome.
#[derive(Debug)]
pub struct JobRecord {
    pub id: u32,
    pub name: String,
    pub submitted_at: SimTime,
    pub started_at: SimTime,
    pub finished_at: SimTime,
    pub attempts: u32,
    pub evictions: u32,
    pub completed: bool,
    pub cost: f64,
    /// Per-pool launches/evictions/cost across all of this job's
    /// attempts (merged by pool name).
    pub pool_stats: Vec<PoolStats>,
}

impl JobRecord {
    pub fn wait(&self) -> SimDuration {
        self.started_at.since(self.submitted_at)
    }

    pub fn turnaround(&self) -> SimDuration {
        self.finished_at.since(self.submitted_at)
    }
}

/// Cluster-level scheduler events on the shared queue.
#[derive(Debug, Clone, Copy)]
enum SchedEvent {
    /// A job enters the pending queue.
    Submitted(usize),
    /// A running attempt's virtual duration elapsed; its slot frees.
    AttemptDone(usize),
    /// A requeued job's scheduling delay elapsed; it rejoins the tail.
    RequeueReady(usize),
}

/// Multi-slot requeue scheduler.
pub struct RequeueScheduler {
    /// Delay between an eviction/failure and the next attempt becoming
    /// eligible (queue scheduling latency; also replaces the scale set's
    /// provisioning delay inside each attempt — the requeue path's
    /// replacement semantics).
    pub requeue_delay: SimDuration,
    /// Attempt cap per job (abandon pathological jobs).
    pub max_attempts: u32,
    /// Concurrent spot slots in the cluster (a Slurm partition's width).
    pub slots: u32,
    /// Shared replacement fleet: when set, every job's attempts draw
    /// their instances from these pools (overriding each experiment's own
    /// fleet config), with the requeue delay as each pool's provisioning
    /// delay — the cluster analog of "all partitions allocate from the
    /// same heterogeneous spot pools". Per-job [`JobRecord::pool_stats`]
    /// (and [`aggregate_pool_stats`] across jobs) attribute the usage.
    pub fleet: Option<FleetCfg>,
}

impl Default for RequeueScheduler {
    fn default() -> Self {
        Self {
            requeue_delay: SimDuration::from_secs(300),
            max_attempts: 16,
            slots: 1,
            fleet: None,
        }
    }
}

/// Merge `add` into `acc` by pool name (cluster-wide fleet accounting).
fn merge_pool_stats(acc: &mut Vec<PoolStats>, add: &[PoolStats]) {
    for s in add {
        match acc.iter_mut().find(|e| e.pool == s.pool) {
            Some(e) => {
                e.launches += s.launches;
                e.evictions += s.evictions;
                e.compute_cost += s.compute_cost;
            }
            None => acc.push(s.clone()),
        }
    }
}

/// Fleet usage aggregated over a set of job records (pool by pool).
pub fn aggregate_pool_stats(records: &[JobRecord]) -> Vec<PoolStats> {
    let mut out = Vec::new();
    for r in records {
        merge_pool_stats(&mut out, &r.pool_stats);
    }
    out
}

/// Admission layer over the multiplexed cluster engine: one
/// [`JobRecord`] per [`crate::sim::cluster::JobOutcome`], in job order.
///
/// `started_at` is the job's first admission instant (when the fleet
/// first granted it a slot), so `wait()` is the real capacity-induced
/// queueing delay — the multiplexed analogue of the requeue scheduler's
/// slot wait. `attempts` counts instances (every launch is one attempt
/// at the workload); a job the run never admitted degenerates to
/// `started_at == finished_at` (zero-width occupancy, full-width wait).
pub fn cluster_records(
    result: &crate::sim::cluster::ClusterResult,
) -> Vec<JobRecord> {
    result
        .jobs
        .iter()
        .enumerate()
        .map(|(i, j)| JobRecord {
            id: i as u32,
            name: j.name.clone(),
            submitted_at: j.submitted_at,
            started_at: j.admitted_at.unwrap_or(j.finished_at),
            finished_at: j.finished_at,
            attempts: j.result.instances,
            evictions: j.result.evictions,
            completed: j.result.completed,
            cost: j.result.total_cost(),
            pool_stats: j.result.pool_stats.clone(),
        })
        .collect()
}

/// Live state of one job across its attempts.
struct JobState {
    job: Job,
    /// The job's share, persistent across attempts (one job == one share).
    store: crate::storage::BlobStore,
    first_start: Option<SimTime>,
    attempts: u32,
    evictions: u32,
    cost: f64,
    pool_stats: Vec<PoolStats>,
    last_completed: bool,
}

impl RequeueScheduler {
    /// Run all jobs to completion (or attempt exhaustion), FIFO with
    /// requeue-at-tail. Returns records in completion order.
    pub fn run(&self, jobs: Vec<Job>) -> Result<Vec<JobRecord>> {
        Ok(self.run_with_timeline(jobs)?.0)
    }

    /// Like [`RequeueScheduler::run`], also returning the cluster-level
    /// timeline (`JobSubmitted` / `JobStarted` / `JobRequeued` /
    /// `JobFinished` events) for queue-behaviour analysis and tests.
    pub fn run_with_timeline(
        &self,
        jobs: Vec<Job>,
    ) -> Result<(Vec<JobRecord>, Timeline)> {
        let slots = self.slots.max(1);
        let mut clock = Clock::new();
        let mut queue: EventQueue<SchedEvent> = EventQueue::new();
        let mut timeline = Timeline::new();
        let mut pending: VecDeque<usize> = VecDeque::new();
        let mut free_slots = slots;
        let mut records: Vec<JobRecord> = Vec::new();

        let mut states: Vec<JobState> = jobs
            .into_iter()
            .map(|job| JobState {
                store: job.experiment.fresh_store(),
                job,
                first_start: None,
                attempts: 0,
                evictions: 0,
                cost: 0.0,
                pool_stats: Vec::new(),
                last_completed: false,
            })
            .collect();
        for i in 0..states.len() {
            queue.schedule(SimTime::ZERO, SchedEvent::Submitted(i));
        }

        while let Some(sch) = queue.pop() {
            clock.advance_to(sch.at);
            let now = clock.now();
            match sch.event {
                SchedEvent::Submitted(i) => {
                    timeline.record(
                        now,
                        EventKind::JobSubmitted,
                        states[i].job.name.clone(),
                    );
                    pending.push_back(i);
                }
                SchedEvent::RequeueReady(i) => {
                    pending.push_back(i);
                }
                SchedEvent::AttemptDone(i) => {
                    free_slots += 1;
                    let state = &mut states[i];
                    let exhausted = state.attempts >= self.max_attempts;
                    if state.last_completed || exhausted {
                        timeline.record(
                            now,
                            EventKind::JobFinished,
                            format!(
                                "{} ({})",
                                state.job.name,
                                if state.last_completed {
                                    "completed"
                                } else {
                                    "abandoned"
                                }
                            ),
                        );
                        records.push(JobRecord {
                            id: state.job.id,
                            name: state.job.name.clone(),
                            submitted_at: SimTime::ZERO,
                            started_at: state
                                .first_start
                                // spoton-lint: allow(D3, reason = "finish() is only reached after start() recorded the time")
                                .expect("finished job must have started"),
                            finished_at: now,
                            attempts: state.attempts,
                            evictions: state.evictions,
                            completed: state.last_completed,
                            cost: state.cost,
                            pool_stats: std::mem::take(&mut state.pool_stats),
                        });
                    } else {
                        timeline.record(
                            now,
                            EventKind::JobRequeued,
                            format!(
                                "{} (attempt {} of {})",
                                state.job.name,
                                state.attempts,
                                self.max_attempts
                            ),
                        );
                        queue.schedule_in(
                            now,
                            self.requeue_delay,
                            SchedEvent::RequeueReady(i),
                        );
                    }
                }
            }

            // Fill freed slots from the pending queue at this instant.
            while free_slots > 0 {
                let Some(i) = pending.pop_front() else { break };
                free_slots -= 1;
                let attempt_total =
                    self.start_attempt(&mut states[i], now, &mut timeline)?;
                queue.schedule_in(now, attempt_total, SchedEvent::AttemptDone(i));
            }
        }

        Ok((records, timeline))
    }

    /// Begin one attempt in a slot at `now`: run the experiment (engine,
    /// virtual time) against the job's persistent share and return the
    /// attempt's virtual duration.
    fn start_attempt(
        &self,
        state: &mut JobState,
        now: SimTime,
        timeline: &mut Timeline,
    ) -> Result<SimDuration> {
        state.attempts += 1;
        if state.first_start.is_none() {
            state.first_start = Some(now);
        }
        timeline.record(
            now,
            EventKind::JobStarted,
            format!("{} attempt {}", state.job.name, state.attempts),
        );

        let mut exp = state.job.experiment.clone();
        // In the requeue path, replacements go through the batch queue,
        // not the scale set: the scheduling delay is the provisioning
        // delay.
        exp.cfg.cloud.provisioning_delay = self.requeue_delay;
        // A cluster-level fleet overrides the job's own: every attempt
        // draws replacements from the shared pools, and pool replacements
        // ride the batch queue too.
        if let Some(fleet) = &self.fleet {
            exp.cfg.fleet = fleet.clone();
            for pool in &mut exp.cfg.fleet.pools {
                pool.provisioning_delay = self.requeue_delay;
            }
        }
        let bumped = exp.cfg.seed.wrapping_add(state.attempts as u64);
        exp = exp.seed(bumped);

        let result = {
            let mut factory = exp.sleeper_factory();
            SimDriver::new(&exp.cfg, &mut state.store).run(&mut *factory)?
        };
        state.evictions += result.evictions;
        state.cost += result.total_cost();
        merge_pool_stats(&mut state.pool_stats, &result.pool_stats);
        state.last_completed = result.completed;
        Ok(result.total)
    }
}

impl Experiment {
    /// A boxed sleeper factory for scheduler use.
    pub fn sleeper_factory(
        &self,
    ) -> Box<dyn FnMut() -> Result<Box<dyn crate::workload::Workload>>> {
        let w = &self.cfg.workload;
        let cfg = crate::workload::sleeper::SleeperCfg {
            stages: w.ks.iter().map(|k| (format!("K{k}"), 40u64)).collect(),
            milestones_per_stage: w.app_milestones_per_stage,
            charged_bytes: (w.state_gib * (1u64 << 30) as f64) as u64,
            app_charged_bytes: (w.app_ckpt_gib * (1u64 << 30) as f64) as u64,
        };
        let seed = w.seed;
        Box::new(move || {
            Ok(Box::new(crate::workload::sleeper::Sleeper::new(
                cfg.clone(),
                seed,
            )))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simclock::SimDuration;

    #[test]
    fn fifo_jobs_complete_in_order() {
        let mk = |i: u32| Job {
            id: i,
            name: format!("job-{i}"),
            experiment: Experiment::table1()
                .named("queued")
                .transparent(SimDuration::from_mins(30)),
        };
        let sched = RequeueScheduler::default();
        let records = sched.run(vec![mk(0), mk(1)]).unwrap();
        assert_eq!(records.len(), 2);
        assert!(records.iter().all(|r| r.completed));
        assert_eq!(records[0].id, 0);
        assert_eq!(records[1].id, 1);
        // job 1 waited for job 0
        assert!(records[1].turnaround() > records[0].turnaround());
        assert_eq!(records[0].attempts, 1);
    }

    #[test]
    fn evicted_protected_jobs_still_finish_with_requeue_delay() {
        let job = Job {
            id: 7,
            name: "stormy".into(),
            experiment: Experiment::table1()
                .eviction_every(SimDuration::from_mins(60))
                .transparent(SimDuration::from_mins(15)),
        };
        let sched = RequeueScheduler {
            requeue_delay: SimDuration::from_secs(600),
            max_attempts: 4,
            slots: 1,
            fleet: None,
        };
        let records = sched.run(vec![job]).unwrap();
        assert_eq!(records.len(), 1);
        let r = &records[0];
        assert!(r.completed, "protected job must finish");
        assert!(r.evictions >= 2);
        // requeue delay (600s) charged per replacement, visible in
        // turnaround vs the 3:03 baseline + overheads
        assert!(r.turnaround().as_secs() > 11006);
    }

    #[test]
    fn attempt_cap_abandons_doomed_jobs() {
        // unprotected + frequent evictions can never finish
        let job = Job {
            id: 1,
            name: "doomed".into(),
            experiment: Experiment::table1()
                .named("doomed")
                .eviction_every(SimDuration::from_mins(30))
                .unprotected()
                .deadline(SimDuration::from_hours(2)),
        };
        let sched = RequeueScheduler {
            requeue_delay: SimDuration::from_secs(60),
            max_attempts: 2,
            slots: 1,
            fleet: None,
        };
        let records = sched.run(vec![job]).unwrap();
        assert_eq!(records.len(), 1);
        assert!(!records[0].completed);
        assert_eq!(records[0].attempts, 2);
    }

    #[test]
    fn jobs_interleave_during_requeue_delay() {
        // Job A is doomed (unprotected, aborts at its 2 h deadline) and
        // requeues with a 1 h delay; job B is clean. On one slot, B must
        // run in the slot A freed — during A's requeue wait — instead of
        // the cluster serializing whole jobs.
        use crate::metrics::EventKind;
        let job_a = Job {
            id: 0,
            name: "doomed-a".into(),
            experiment: Experiment::table1()
                .named("doomed-a")
                .eviction_every(SimDuration::from_mins(30))
                .unprotected()
                .deadline(SimDuration::from_hours(2)),
        };
        let job_b = Job {
            id: 1,
            name: "clean-b".into(),
            experiment: Experiment::table1()
                .named("clean-b")
                .transparent(SimDuration::from_mins(30)),
        };
        let sched = RequeueScheduler {
            requeue_delay: SimDuration::from_hours(1),
            max_attempts: 2,
            slots: 1,
            fleet: None,
        };
        let (records, timeline) =
            sched.run_with_timeline(vec![job_a, job_b]).unwrap();
        assert!(timeline.is_monotone());
        assert_eq!(records.len(), 2);
        let a = records.iter().find(|r| r.id == 0).unwrap();
        let b = records.iter().find(|r| r.id == 1).unwrap();
        assert!(!a.completed);
        assert_eq!(a.attempts, 2);
        assert!(b.completed);

        // A's first attempt ends exactly when it is requeued; B starts in
        // the freed slot at that same instant — strictly inside A's
        // requeue-delay window, so B makes progress while A waits.
        let requeued_at = timeline
            .events()
            .iter()
            .find(|e| e.kind == EventKind::JobRequeued)
            .expect("job A must requeue")
            .at;
        assert_eq!(b.started_at, requeued_at);
        assert!(
            b.started_at + sched.requeue_delay < b.finished_at,
            "B's run must span A's whole requeue window"
        );
        // B finishes before A's second attempt does
        assert!(b.finished_at < a.finished_at);

        // A's second attempt starts only when B frees the slot (B's run
        // outlives the requeue delay), i.e. at B's finish instant.
        let second_start_a = timeline
            .events()
            .iter()
            .filter(|e| {
                e.kind == EventKind::JobStarted
                    && e.detail.starts_with("doomed-a")
            })
            .nth(1)
            .expect("job A runs twice")
            .at;
        assert_eq!(second_start_a, b.finished_at);
    }

    #[test]
    fn multi_slot_cluster_runs_jobs_concurrently() {
        let mk = |i: u32| Job {
            id: i,
            name: format!("job-{i}"),
            experiment: Experiment::table1()
                .named("parallel")
                .transparent(SimDuration::from_mins(30)),
        };
        let sched = RequeueScheduler {
            requeue_delay: SimDuration::from_secs(300),
            max_attempts: 4,
            slots: 2,
            fleet: None,
        };
        let records = sched.run(vec![mk(0), mk(1), mk(2)]).unwrap();
        assert_eq!(records.len(), 3);
        assert!(records.iter().all(|r| r.completed));
        let r = |id: u32| records.iter().find(|r| r.id == id).unwrap();
        // two slots: jobs 0 and 1 start immediately, job 2 queues
        assert_eq!(r(0).started_at, SimTime::ZERO);
        assert_eq!(r(1).started_at, SimTime::ZERO);
        assert!(r(2).started_at > SimTime::ZERO);
        // identical jobs: job 2 starts exactly when job 0's slot frees
        assert_eq!(r(2).started_at, r(0).finished_at);
        // makespan beats the single-slot serialization of 3 runs
        let makespan = records
            .iter()
            .map(|r| r.finished_at)
            .max()
            .unwrap();
        let single = r(0).turnaround().as_millis() * 3;
        assert!(
            makespan.as_millis() < single,
            "2 slots must beat serialized: {} vs {}",
            makespan.as_millis(),
            single
        );
    }

    #[test]
    fn shared_fleet_attributes_cluster_usage_per_pool() {
        use crate::config::{
            EvictionPlanCfg, FleetCfg, PlacementPolicyCfg, PoolCfg,
        };
        let mk = |i: u32| Job {
            id: i,
            name: format!("job-{i}"),
            experiment: Experiment::table1()
                .named("fleeted")
                .transparent(SimDuration::from_mins(15)),
        };
        // storm pool evicts every 20 min; stable pool never does
        let fleet = FleetCfg {
            pools: vec![
                PoolCfg::named("storm").price_factor(0.9).eviction(
                    EvictionPlanCfg::Fixed {
                        interval: SimDuration::from_mins(20),
                    },
                ),
                PoolCfg::named("stable").price_factor(1.1),
            ],
            placement: PlacementPolicyCfg::EvictionAware { penalty: 4.0 },
        };
        let sched = RequeueScheduler {
            requeue_delay: SimDuration::from_secs(120),
            max_attempts: 8,
            slots: 2,
            fleet: Some(fleet),
        };
        let records = sched.run(vec![mk(0), mk(1)]).unwrap();
        assert_eq!(records.len(), 2);
        assert!(records.iter().all(|r| r.completed));
        // every record carries both pools' stats
        for r in &records {
            assert_eq!(r.pool_stats.len(), 2);
            let total: f64 =
                r.pool_stats.iter().map(|p| p.compute_cost).sum();
            assert!(total > 0.0);
        }
        let agg = aggregate_pool_stats(&records);
        assert_eq!(agg.len(), 2);
        let storm = agg.iter().find(|p| p.pool == "storm").unwrap();
        let stable = agg.iter().find(|p| p.pool == "stable").unwrap();
        // eviction-aware placement starts in the cheap storm pool, gets
        // burned, and finishes in the stable pool
        assert!(storm.evictions >= 2, "both jobs see storm evictions");
        assert!(stable.launches >= 2, "both jobs fail over to stable");
        // cluster-wide attribution sums to the jobs' compute spend
        let agg_cost: f64 = agg.iter().map(|p| p.compute_cost).sum();
        let rec_compute: f64 = records
            .iter()
            .flat_map(|r| r.pool_stats.iter())
            .map(|p| p.compute_cost)
            .sum();
        assert!((agg_cost - rec_compute).abs() < 1e-9);
    }

    #[test]
    fn cluster_timeline_records_job_lifecycle() {
        let job = Job {
            id: 3,
            name: "solo".into(),
            experiment: Experiment::table1()
                .named("solo")
                .transparent(SimDuration::from_mins(30)),
        };
        let sched = RequeueScheduler::default();
        let (records, timeline) = sched.run_with_timeline(vec![job]).unwrap();
        assert_eq!(records.len(), 1);
        assert_eq!(timeline.count(EventKind::JobSubmitted), 1);
        assert_eq!(timeline.count(EventKind::JobStarted), 1);
        assert_eq!(timeline.count(EventKind::JobRequeued), 0);
        assert_eq!(timeline.count(EventKind::JobFinished), 1);
        assert!(timeline.is_monotone());
    }

    #[test]
    fn cluster_records_expose_real_admission_waits() {
        use crate::config::ClusterCfg;
        let exp = Experiment::table1()
            .named("sched-bridge")
            .scale_stages(0.02)
            .transparent(SimDuration::from_mins(10));
        let mut cfg = exp.cfg.clone();
        cfg.cluster = Some(ClusterCfg::with_count(4).capacity(1));
        let exp = Experiment { cfg };
        let result = exp.run_cluster_sleeper().unwrap();
        let records = cluster_records(&result);
        assert_eq!(records.len(), 4);
        assert!(records.iter().all(|r| r.completed));
        // capacity 1 serializes the batch: only one record starts at
        // submission, the rest wait for a slot
        let immediate =
            records.iter().filter(|r| r.wait().is_zero()).count();
        assert_eq!(immediate, 1);
        assert!(records.iter().all(|r| r.turnaround() >= r.wait()));
        // ids are job order, names match the cluster's job list
        for (i, r) in records.iter().enumerate() {
            assert_eq!(r.id, i as u32);
            assert_eq!(r.name, format!("job-{i}"));
            assert!(r.attempts >= 1);
        }
        // pool attribution survives the bridge
        let agg = aggregate_pool_stats(&records);
        assert!(!agg.is_empty());
        assert!(agg.iter().map(|p| p.compute_cost).sum::<f64>() > 0.0);
    }
}
