//! VM size catalog and price book.
//!
//! Prices default to the paper's testbed: Standard_D8s_v3 (8 vCPU, 32 GiB)
//! at $0.38/h on-demand and $0.076/h spot (paper §III). Additional sizes
//! let the OOM-resume example (paper §IV) restore a checkpoint onto a
//! larger instance.

use anyhow::{bail, Result};

/// One VM size row in the catalog.
#[derive(Debug, Clone, PartialEq)]
pub struct VmSize {
    pub name: String,
    pub vcpus: u32,
    pub mem_gib: u32,
    pub ondemand_per_hour: f64,
    pub spot_per_hour: f64,
}

impl VmSize {
    pub fn price_per_hour(&self, spot: bool) -> f64 {
        if spot {
            self.spot_per_hour
        } else {
            self.ondemand_per_hour
        }
    }

    /// Spot discount fraction, e.g. 0.8 for 80% off.
    pub fn spot_discount(&self) -> f64 {
        1.0 - self.spot_per_hour / self.ondemand_per_hour
    }
}

/// The size catalog (Azure Dsv3-series analog).
///
/// Catalogs are validated at construction ([`PriceBook::new`]): every
/// price must be positive and finite and size names unique, so downstream
/// arithmetic — [`VmSize::spot_discount`]'s division, billing totals,
/// placement-policy scores — never meets a zero/negative price.
#[derive(Debug, Clone)]
pub struct PriceBook {
    sizes: Vec<VmSize>,
}

impl Default for PriceBook {
    fn default() -> Self {
        // D2s..D32s v3: on-demand scales linearly with cores; spot keeps
        // the paper's 80% discount.
        let mk = |name: &str, vcpus: u32, mem: u32, od: f64, spot: f64| VmSize {
            name: name.into(),
            vcpus,
            mem_gib: mem,
            ondemand_per_hour: od,
            spot_per_hour: spot,
        };
        Self::new(vec![
            mk("Standard_D2s_v3", 2, 8, 0.095, 0.019),
            mk("Standard_D4s_v3", 4, 16, 0.19, 0.038),
            mk("Standard_D8s_v3", 8, 32, 0.38, 0.076), // paper's VM
            mk("Standard_D16s_v3", 16, 64, 0.76, 0.152),
            mk("Standard_D32s_v3", 32, 128, 1.52, 0.304),
        ])
        // spoton-lint: allow(D3, reason = "default catalog is a static table; validity is tested")
        .expect("default catalog is valid")
    }
}

impl PriceBook {
    /// Build a catalog, rejecting zero/negative/non-finite prices and
    /// duplicate size names up front (instead of letting
    /// [`VmSize::spot_discount`] or billing divide by / multiply with
    /// garbage later).
    pub fn new(sizes: Vec<VmSize>) -> Result<Self> {
        if sizes.is_empty() {
            bail!("price book must contain at least one VM size");
        }
        let mut seen = std::collections::BTreeSet::new();
        for s in &sizes {
            if !(s.ondemand_per_hour.is_finite() && s.ondemand_per_hour > 0.0) {
                bail!(
                    "VM size '{}': on-demand price {} must be positive and \
                     finite",
                    s.name,
                    s.ondemand_per_hour
                );
            }
            if !(s.spot_per_hour.is_finite() && s.spot_per_hour > 0.0) {
                bail!(
                    "VM size '{}': spot price {} must be positive and finite",
                    s.name,
                    s.spot_per_hour
                );
            }
            if !seen.insert(s.name.clone()) {
                bail!("duplicate VM size '{}' in price book", s.name);
            }
        }
        Ok(Self { sizes })
    }

    /// Derive a region-priced catalog: every price scaled by `factor`
    /// (a cheap region < 1, an expensive one > 1). `1.0` returns the
    /// catalog unchanged, bit-for-bit.
    pub fn with_price_factor(&self, factor: f64) -> Result<Self> {
        if !(factor.is_finite() && factor > 0.0) {
            bail!("price factor {factor} must be positive and finite");
        }
        if factor == 1.0 {
            return Ok(self.clone());
        }
        Self::new(
            self.sizes
                .iter()
                .map(|s| VmSize {
                    ondemand_per_hour: s.ondemand_per_hour * factor,
                    spot_per_hour: s.spot_per_hour * factor,
                    ..s.clone()
                })
                .collect(),
        )
    }

    pub fn lookup(&self, name: &str) -> Result<&VmSize> {
        match self.sizes.iter().find(|s| s.name == name) {
            Some(s) => Ok(s),
            None => bail!(
                "unknown VM size '{name}' (have: {})",
                self.sizes
                    .iter()
                    .map(|s| s.name.as_str())
                    .collect::<Vec<_>>()
                    .join(", ")
            ),
        }
    }

    /// Smallest size with at least `mem_gib` memory (OOM-resume upsizing).
    pub fn smallest_with_mem(&self, mem_gib: u32) -> Option<&VmSize> {
        self.sizes
            .iter()
            .filter(|s| s.mem_gib >= mem_gib)
            .min_by_key(|s| s.mem_gib)
    }

    pub fn sizes(&self) -> &[VmSize] {
        &self.sizes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_vm_prices() {
        let book = PriceBook::default();
        let d8 = book.lookup("Standard_D8s_v3").unwrap();
        assert_eq!(d8.ondemand_per_hour, 0.38);
        assert_eq!(d8.spot_per_hour, 0.076);
        assert_eq!(d8.vcpus, 8);
        assert_eq!(d8.mem_gib, 32);
        // the paper's "simply from the price cuts": 80% discount
        assert!((d8.spot_discount() - 0.8).abs() < 1e-9);
    }

    #[test]
    fn unknown_size_errors() {
        assert!(PriceBook::default().lookup("Standard_Z1").is_err());
    }

    #[test]
    fn rejects_invalid_catalogs() {
        let good = |name: &str| VmSize {
            name: name.into(),
            vcpus: 2,
            mem_gib: 8,
            ondemand_per_hour: 0.1,
            spot_per_hour: 0.02,
        };
        // zero / negative / non-finite on-demand price
        for bad_od in [0.0, -0.38, f64::NAN, f64::INFINITY] {
            let mut s = good("A");
            s.ondemand_per_hour = bad_od;
            let err = PriceBook::new(vec![s]).unwrap_err();
            assert!(err.to_string().contains("on-demand price"), "{err}");
        }
        // zero / negative spot price
        for bad_spot in [0.0, -0.01] {
            let mut s = good("A");
            s.spot_per_hour = bad_spot;
            assert!(PriceBook::new(vec![s]).is_err());
        }
        // duplicate names
        let err =
            PriceBook::new(vec![good("A"), good("A")]).unwrap_err();
        assert!(err.to_string().contains("duplicate"), "{err}");
        // empty catalog
        assert!(PriceBook::new(vec![]).is_err());
        // a valid catalog passes
        assert!(PriceBook::new(vec![good("A"), good("B")]).is_ok());
    }

    #[test]
    fn price_factor_scales_and_validates() {
        let base = PriceBook::default();
        let cheap = base.with_price_factor(0.5).unwrap();
        let d8 = cheap.lookup("Standard_D8s_v3").unwrap();
        assert!((d8.ondemand_per_hour - 0.19).abs() < 1e-12);
        assert!((d8.spot_per_hour - 0.038).abs() < 1e-12);
        // discount ratio is preserved under scaling
        assert!((d8.spot_discount() - 0.8).abs() < 1e-9);
        // factor 1.0 is bit-identical
        let same = base.with_price_factor(1.0).unwrap();
        let a = same.lookup("Standard_D8s_v3").unwrap();
        let b = base.lookup("Standard_D8s_v3").unwrap();
        assert_eq!(a.ondemand_per_hour.to_bits(), b.ondemand_per_hour.to_bits());
        // invalid factors are rejected
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            assert!(base.with_price_factor(bad).is_err());
        }
    }

    #[test]
    fn upsizing_for_oom() {
        let book = PriceBook::default();
        assert_eq!(
            book.smallest_with_mem(33).unwrap().name,
            "Standard_D16s_v3"
        );
        assert_eq!(book.smallest_with_mem(64).unwrap().name, "Standard_D16s_v3");
        assert!(book.smallest_with_mem(1024).is_none());
    }

    #[test]
    fn price_selector() {
        let book = PriceBook::default();
        let d8 = book.lookup("Standard_D8s_v3").unwrap();
        assert_eq!(d8.price_per_hour(true), 0.076);
        assert_eq!(d8.price_per_hour(false), 0.38);
    }
}
