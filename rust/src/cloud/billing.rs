//! Billing meters and invoices.
//!
//! Every resource the experiment consumes books usage here: instance
//! uptime at the applicable hourly price, and provisioned shared-storage
//! capacity at $/100 GiB-month prorated by wall time (how Azure Files
//! bills the NFS share the paper uses for checkpoint transfer). Fig 2 is
//! rendered directly from these invoices.
//!
//! Prices, capacities and price factors are validated at booking time
//! (mirroring [`PriceBook::new`](super::pricing::PriceBook)): a negative
//! or non-finite input would silently poison every downstream total —
//! sweep summaries, Fig 2, policy comparisons — so it panics here, at the
//! line item that introduced it, instead.
//!
//! Pools with traced spot markets ([`super::trace`]) book uptime through
//! [`BillingMeter::book_instance_piecewise`]: the uptime is segmented at
//! the pool's price-change boundaries and each segment is billed at its
//! own price, so an instance that straddles a price move is invoiced
//! correctly per segment.

use crate::simclock::{SimDuration, SimTime};
use std::fmt;

/// One line item on an invoice.
#[derive(Debug, Clone, PartialEq)]
pub struct LineItem {
    pub resource: String,
    pub detail: String,
    pub amount: f64,
    /// Fleet pool this item is attributed to (multi-pool runs); `None`
    /// for storage and for pre-fleet single-scale-set booking.
    pub pool: Option<String>,
}

/// Accumulates usage over one experiment run.
#[derive(Debug, Clone, Default)]
pub struct BillingMeter {
    compute_items: Vec<LineItem>,
    storage_items: Vec<LineItem>,
}

/// Hours in the 30-day month Azure prorates against.
const HOURS_PER_MONTH: f64 = 30.0 * 24.0;

impl BillingMeter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Book instance uptime: `uptime` at `price_per_hour`.
    pub fn book_instance(
        &mut self,
        instance: &str,
        vm_size: &str,
        spot: bool,
        uptime: SimDuration,
        price_per_hour: f64,
    ) {
        self.book_instance_tagged(None, instance, vm_size, spot, uptime, price_per_hour);
    }

    /// Book instance uptime attributed to a fleet pool (per-pool cost
    /// breakdown next to the run total).
    pub fn book_instance_in_pool(
        &mut self,
        pool: &str,
        instance: &str,
        vm_size: &str,
        spot: bool,
        uptime: SimDuration,
        price_per_hour: f64,
    ) {
        self.book_instance_tagged(
            Some(pool),
            instance,
            vm_size,
            spot,
            uptime,
            price_per_hour,
        );
    }

    /// Book instance uptime split at price-change boundaries: `epochs`
    /// is the pool's price-factor history — `(since, factor)` pairs,
    /// time-ordered, the first at or before `start` — and each segment
    /// of `[start, end]` is billed at `base_price_per_hour × factor`.
    /// Consecutive epochs with the same factor coalesce into one
    /// segment, so a constant-factor history books exactly one line item
    /// with bit-identical arithmetic to [`BillingMeter::book_instance`].
    #[allow(clippy::too_many_arguments)]
    pub fn book_instance_piecewise(
        &mut self,
        pool: Option<&str>,
        instance: &str,
        vm_size: &str,
        spot: bool,
        start: SimTime,
        end: SimTime,
        base_price_per_hour: f64,
        epochs: &[(SimTime, f64)],
    ) {
        assert!(
            end >= start,
            "instance {instance}: uptime ends ({end}) before it starts \
             ({start})"
        );
        assert!(
            !epochs.is_empty(),
            "instance {instance}: piecewise booking needs at least one \
             price epoch"
        );
        assert!(
            epochs.windows(2).all(|w| w[0].0 <= w[1].0),
            "instance {instance}: price epochs must be time-ordered"
        );
        assert!(
            epochs[0].0 <= start,
            "instance {instance}: first price epoch ({}) must cover the \
             instance start ({start})",
            epochs[0].0
        );
        for &(at, factor) in epochs {
            assert!(
                factor.is_finite() && factor >= 0.0,
                "instance {instance}: price factor {factor} at {at} must \
                 be finite and non-negative"
            );
        }
        // factor in force when the instance started
        let mut factor = epochs
            .iter()
            .take_while(|e| e.0 <= start)
            .last()
            // spoton-lint: allow(D3, reason = "epoch list is seeded with a start-covering epoch")
            .expect("first epoch covers start")
            .1;
        let mut seg_start = start;
        for &(at, f) in epochs.iter().filter(|e| e.0 > start && e.0 < end) {
            if f == factor {
                continue; // no-op move: coalesce into the running segment
            }
            self.book_instance_tagged(
                pool,
                instance,
                vm_size,
                spot,
                at.since(seg_start),
                base_price_per_hour * factor,
            );
            seg_start = at;
            factor = f;
        }
        self.book_instance_tagged(
            pool,
            instance,
            vm_size,
            spot,
            end.since(seg_start),
            base_price_per_hour * factor,
        );
    }

    fn book_instance_tagged(
        &mut self,
        pool: Option<&str>,
        instance: &str,
        vm_size: &str,
        spot: bool,
        uptime: SimDuration,
        price_per_hour: f64,
    ) {
        assert!(
            price_per_hour.is_finite() && price_per_hour >= 0.0,
            "instance {instance}: price ${price_per_hour}/h must be finite \
             and non-negative"
        );
        let hours = uptime.as_hours_f64();
        self.compute_items.push(LineItem {
            resource: format!("vm/{instance}"),
            detail: format!(
                "{vm_size} {} {:.4} h @ ${price_per_hour}/h",
                if spot { "spot" } else { "on-demand" },
                hours
            ),
            amount: hours * price_per_hour,
            pool: pool.map(str::to_string),
        });
    }

    /// Compute total attributed to one fleet pool.
    pub fn pool_compute_total(&self, pool: &str) -> f64 {
        self.compute_items
            .iter()
            .filter(|i| i.pool.as_deref() == Some(pool))
            .map(|i| i.amount)
            .sum()
    }

    /// Book provisioned shared storage for the run's duration.
    pub fn book_storage(
        &mut self,
        share: &str,
        provisioned_gib: f64,
        duration: SimDuration,
        price_per_100gib_month: f64,
    ) {
        assert!(
            provisioned_gib.is_finite() && provisioned_gib >= 0.0,
            "share {share}: provisioned capacity {provisioned_gib} GiB must \
             be finite and non-negative"
        );
        assert!(
            price_per_100gib_month.is_finite() && price_per_100gib_month >= 0.0,
            "share {share}: price ${price_per_100gib_month}/100GiB-month \
             must be finite and non-negative"
        );
        let months = duration.as_hours_f64() / HOURS_PER_MONTH;
        let amount = provisioned_gib / 100.0 * price_per_100gib_month * months;
        self.storage_items.push(LineItem {
            resource: format!("storage/{share}"),
            detail: format!(
                "{provisioned_gib} GiB provisioned x {:.4} months",
                months
            ),
            amount,
            pool: None,
        });
    }

    pub fn compute_total(&self) -> f64 {
        self.compute_items.iter().map(|i| i.amount).sum()
    }

    pub fn storage_total(&self) -> f64 {
        self.storage_items.iter().map(|i| i.amount).sum()
    }

    pub fn total(&self) -> f64 {
        self.compute_total() + self.storage_total()
    }

    pub fn invoice(&self) -> Invoice {
        Invoice {
            items: self
                .compute_items
                .iter()
                .chain(self.storage_items.iter())
                .cloned()
                .collect(),
        }
    }
}

/// Finalized invoice for display.
#[derive(Debug, Clone)]
pub struct Invoice {
    pub items: Vec<LineItem>,
}

impl Invoice {
    pub fn total(&self) -> f64 {
        self.items.iter().map(|i| i.amount).sum()
    }
}

impl fmt::Display for Invoice {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for item in &self.items {
            let resource = match &item.pool {
                Some(pool) => format!("{}@{pool}", item.resource),
                None => item.resource.clone(),
            };
            writeln!(
                f,
                "  {:<24} {:<52} {:>9}",
                resource,
                item.detail,
                crate::util::fmt::dollars(item.amount)
            )?;
        }
        writeln!(
            f,
            "  {:<24} {:<52} {:>9}",
            "TOTAL",
            "",
            crate::util::fmt::dollars(self.total())
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{forall, shrink_none, Config};

    #[test]
    fn paper_baseline_cost() {
        // Table I row 1 on on-demand: 3:03:26 at $0.38/h ≈ $1.1617
        let mut m = BillingMeter::new();
        m.book_instance(
            "vm-0",
            "Standard_D8s_v3",
            false,
            SimDuration::from_secs(11006),
            0.38,
        );
        assert!((m.total() - 11006.0 / 3600.0 * 0.38).abs() < 1e-9);
        assert!((m.total() - 1.1618).abs() < 1e-3);
    }

    #[test]
    fn spot_price_cut_is_80pct() {
        let dur = SimDuration::from_secs(11006);
        let mut od = BillingMeter::new();
        od.book_instance("a", "D8s", false, dur, 0.38);
        let mut spot = BillingMeter::new();
        spot.book_instance("a", "D8s", true, dur, 0.076);
        let saving = 1.0 - spot.total() / od.total();
        assert!((saving - 0.8).abs() < 1e-9);
    }

    #[test]
    fn storage_prorated_by_month() {
        let mut m = BillingMeter::new();
        // 100 GiB for a full month at $16/100GiB-month = $16
        m.book_storage(
            "nfs",
            100.0,
            SimDuration::from_hours(720),
            16.0,
        );
        assert!((m.storage_total() - 16.0).abs() < 1e-9);
        // 3 hours is tiny
        let mut m2 = BillingMeter::new();
        m2.book_storage("nfs", 100.0, SimDuration::from_hours(3), 16.0);
        assert!((m2.storage_total() - 16.0 * 3.0 / 720.0).abs() < 1e-9);
    }

    #[test]
    fn invoice_renders_and_totals() {
        let mut m = BillingMeter::new();
        m.book_instance("vm-0", "D8s", true, SimDuration::from_hours(2), 0.076);
        m.book_storage("nfs", 100.0, SimDuration::from_hours(2), 16.0);
        let inv = m.invoice();
        assert_eq!(inv.items.len(), 2);
        let s = inv.to_string();
        assert!(s.contains("TOTAL"));
        assert!((inv.total() - m.total()).abs() < 1e-12);
    }

    #[test]
    fn pool_attribution_partitions_compute_total() {
        let mut m = BillingMeter::new();
        let h = SimDuration::from_hours(1);
        m.book_instance_in_pool("east", "vm-0", "D8s", true, h, 0.076);
        m.book_instance_in_pool("west", "vm-1", "D8s", true, h, 0.090);
        m.book_instance_in_pool("east", "vm-2", "D8s", true, h, 0.076);
        m.book_storage("nfs", 100.0, h, 16.0);
        assert!((m.pool_compute_total("east") - 0.152).abs() < 1e-12);
        assert!((m.pool_compute_total("west") - 0.090).abs() < 1e-12);
        assert_eq!(m.pool_compute_total("nowhere"), 0.0);
        // pools partition the compute total exactly
        assert!(
            (m.pool_compute_total("east") + m.pool_compute_total("west")
                - m.compute_total())
            .abs()
                < 1e-12
        );
        // pool tag surfaces on the rendered invoice
        let s = m.invoice().to_string();
        assert!(s.contains("vm/vm-0@east"), "{s}");
        assert!(s.contains("vm/vm-1@west"), "{s}");
    }

    #[test]
    fn piecewise_bills_each_price_segment() {
        // 2 h of uptime straddling a price move at the 30-minute mark:
        // 0.5 h at $0.076 + 1.5 h at $0.152.
        let mut m = BillingMeter::new();
        let epochs = [
            (SimTime::ZERO, 1.0),
            (SimTime::from_secs(1800), 2.0),
        ];
        m.book_instance_piecewise(
            Some("east"),
            "vm-0",
            "D8s",
            true,
            SimTime::ZERO,
            SimTime::from_secs(7200),
            0.076,
            &epochs,
        );
        let inv = m.invoice();
        assert_eq!(inv.items.len(), 2);
        assert!((inv.items[0].amount - 0.5 * 0.076).abs() < 1e-12);
        assert!((inv.items[1].amount - 1.5 * 0.152).abs() < 1e-12);
        assert!((m.pool_compute_total("east") - m.compute_total()).abs() < 1e-12);
        // epochs entirely before the launch don't split anything
        let mut late = BillingMeter::new();
        late.book_instance_piecewise(
            None,
            "vm-1",
            "D8s",
            true,
            SimTime::from_secs(3600),
            SimTime::from_secs(7200),
            0.076,
            &epochs,
        );
        assert_eq!(late.invoice().items.len(), 1);
        assert!((late.compute_total() - 0.152).abs() < 1e-12);
    }

    #[test]
    fn piecewise_constant_factor_is_bitwise_whole_booking() {
        // However many epochs repeat the same factor, the booking must
        // coalesce to ONE line item with arithmetic bit-identical to the
        // whole-uptime path — the constant-price-trace oracle guarantee.
        let mut split = BillingMeter::new();
        let epochs: Vec<(SimTime, f64)> = (0u64..5)
            .map(|i| (SimTime::from_secs(i * 600), 1.0))
            .collect();
        split.book_instance_piecewise(
            None,
            "vm-0",
            "D8s",
            true,
            SimTime::ZERO,
            SimTime::from_secs(11006),
            0.076,
            &epochs,
        );
        let mut whole = BillingMeter::new();
        whole.book_instance(
            "vm-0",
            "D8s",
            true,
            SimDuration::from_secs(11006),
            0.076,
        );
        assert_eq!(split.invoice().items.len(), 1);
        assert_eq!(
            split.compute_total().to_bits(),
            whole.compute_total().to_bits()
        );
        assert_eq!(split.invoice().items[0].detail, whole.invoice().items[0].detail);
    }

    #[test]
    fn prop_piecewise_matches_hand_computed_segments() {
        // Piecewise booking across N random price moves equals booking
        // each hand-computed segment individually — and when every epoch
        // carries the same factor, it equals the whole-uptime booking.
        forall(
            Config::default().cases(200),
            |rng| {
                let n = rng.range_u64(1, 6);
                let mut epochs = vec![(SimTime::ZERO, 0.5 + rng.f64())];
                let mut t = 0u64;
                for _ in 1..n {
                    t += rng.range_u64(1, 5_000);
                    epochs.push((SimTime(t), 0.5 + rng.f64()));
                }
                let start = SimTime(rng.below(3_000));
                let end = start + SimDuration::from_millis(rng.below(10_000));
                (epochs, start, end, 0.01 + rng.f64())
            },
            shrink_none,
            |(epochs, start, end, base)| {
                let mut piecewise = BillingMeter::new();
                piecewise.book_instance_piecewise(
                    None, "vm", "D8s", true, *start, *end, *base, epochs,
                );
                // hand-computed: walk the boundaries independently
                let mut manual = BillingMeter::new();
                let mut cuts: Vec<SimTime> = vec![*start];
                cuts.extend(
                    epochs
                        .iter()
                        .map(|e| e.0)
                        .filter(|&t| t > *start && t < *end),
                );
                cuts.push(*end);
                for w in cuts.windows(2) {
                    let factor = epochs
                        .iter()
                        .take_while(|e| e.0 <= w[0])
                        .last()
                        .unwrap()
                        .1;
                    manual.book_instance(
                        "vm",
                        "D8s",
                        true,
                        w[1].since(w[0]),
                        base * factor,
                    );
                }
                if (piecewise.total() - manual.total()).abs() > 1e-9 {
                    return Err(format!(
                        "piecewise {} != manual {}",
                        piecewise.total(),
                        manual.total()
                    ));
                }
                // constant factor: bitwise equal to the whole booking
                let flat: Vec<(SimTime, f64)> =
                    epochs.iter().map(|e| (e.0, epochs[0].1)).collect();
                let mut coalesced = BillingMeter::new();
                coalesced.book_instance_piecewise(
                    None, "vm", "D8s", true, *start, *end, *base, &flat,
                );
                let mut whole = BillingMeter::new();
                whole.book_instance(
                    "vm",
                    "D8s",
                    true,
                    end.since(*start),
                    base * epochs[0].1,
                );
                if coalesced.total().to_bits() != whole.total().to_bits() {
                    return Err(format!(
                        "constant-factor piecewise {} != whole {}",
                        coalesced.total(),
                        whole.total()
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    #[should_panic(expected = "finite and non-negative")]
    fn rejects_nan_instance_price() {
        BillingMeter::new().book_instance(
            "vm-0",
            "D8s",
            true,
            SimDuration::from_hours(1),
            f64::NAN,
        );
    }

    #[test]
    #[should_panic(expected = "finite and non-negative")]
    fn rejects_negative_instance_price() {
        BillingMeter::new().book_instance(
            "vm-0",
            "D8s",
            true,
            SimDuration::from_hours(1),
            -0.076,
        );
    }

    #[test]
    #[should_panic(expected = "finite and non-negative")]
    fn rejects_negative_storage_capacity() {
        BillingMeter::new().book_storage(
            "nfs",
            -100.0,
            SimDuration::from_hours(1),
            16.0,
        );
    }

    #[test]
    #[should_panic(expected = "finite and non-negative")]
    fn rejects_infinite_storage_price() {
        BillingMeter::new().book_storage(
            "nfs",
            100.0,
            SimDuration::from_hours(1),
            f64::INFINITY,
        );
    }

    #[test]
    #[should_panic(expected = "time-ordered")]
    fn rejects_unordered_price_epochs() {
        BillingMeter::new().book_instance_piecewise(
            None,
            "vm-0",
            "D8s",
            true,
            SimTime::ZERO,
            SimTime::from_secs(100),
            0.076,
            &[(SimTime::from_secs(50), 1.0), (SimTime::ZERO, 2.0)],
        );
    }

    #[test]
    #[should_panic(expected = "cover the instance start")]
    fn rejects_epochs_starting_after_launch() {
        BillingMeter::new().book_instance_piecewise(
            None,
            "vm-0",
            "D8s",
            true,
            SimTime::ZERO,
            SimTime::from_secs(100),
            0.076,
            &[(SimTime::from_secs(50), 1.0)],
        );
    }

    #[test]
    fn prop_billing_additivity() {
        // Booking uptime in pieces costs the same as booking it whole.
        forall(
            Config::default().cases(200),
            |rng| {
                let pieces: Vec<u64> =
                    (0..rng.range_u64(1, 6)).map(|_| rng.below(10_000)).collect();
                (pieces, 0.01 + rng.f64())
            },
            shrink_none,
            |(pieces, price)| {
                let mut split = BillingMeter::new();
                for (i, &p) in pieces.iter().enumerate() {
                    split.book_instance(
                        &format!("vm-{i}"),
                        "D8s",
                        true,
                        SimDuration::from_millis(p),
                        *price,
                    );
                }
                let mut whole = BillingMeter::new();
                whole.book_instance(
                    "vm",
                    "D8s",
                    true,
                    SimDuration::from_millis(pieces.iter().sum()),
                    *price,
                );
                if (split.total() - whole.total()).abs() > 1e-9 {
                    return Err(format!(
                        "split {} != whole {}",
                        split.total(),
                        whole.total()
                    ));
                }
                Ok(())
            },
        );
    }
}
