//! Billing meters and invoices.
//!
//! Every resource the experiment consumes books usage here: instance
//! uptime at the applicable hourly price, and provisioned shared-storage
//! capacity at $/100 GiB-month prorated by wall time (how Azure Files
//! bills the NFS share the paper uses for checkpoint transfer). Fig 2 is
//! rendered directly from these invoices.

use crate::simclock::SimDuration;
use std::fmt;

/// One line item on an invoice.
#[derive(Debug, Clone, PartialEq)]
pub struct LineItem {
    pub resource: String,
    pub detail: String,
    pub amount: f64,
    /// Fleet pool this item is attributed to (multi-pool runs); `None`
    /// for storage and for pre-fleet single-scale-set booking.
    pub pool: Option<String>,
}

/// Accumulates usage over one experiment run.
#[derive(Debug, Clone, Default)]
pub struct BillingMeter {
    compute_items: Vec<LineItem>,
    storage_items: Vec<LineItem>,
}

/// Hours in the 30-day month Azure prorates against.
const HOURS_PER_MONTH: f64 = 30.0 * 24.0;

impl BillingMeter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Book instance uptime: `uptime` at `price_per_hour`.
    pub fn book_instance(
        &mut self,
        instance: &str,
        vm_size: &str,
        spot: bool,
        uptime: SimDuration,
        price_per_hour: f64,
    ) {
        self.book_instance_tagged(None, instance, vm_size, spot, uptime, price_per_hour);
    }

    /// Book instance uptime attributed to a fleet pool (per-pool cost
    /// breakdown next to the run total).
    pub fn book_instance_in_pool(
        &mut self,
        pool: &str,
        instance: &str,
        vm_size: &str,
        spot: bool,
        uptime: SimDuration,
        price_per_hour: f64,
    ) {
        self.book_instance_tagged(
            Some(pool),
            instance,
            vm_size,
            spot,
            uptime,
            price_per_hour,
        );
    }

    fn book_instance_tagged(
        &mut self,
        pool: Option<&str>,
        instance: &str,
        vm_size: &str,
        spot: bool,
        uptime: SimDuration,
        price_per_hour: f64,
    ) {
        let hours = uptime.as_hours_f64();
        self.compute_items.push(LineItem {
            resource: format!("vm/{instance}"),
            detail: format!(
                "{vm_size} {} {:.4} h @ ${price_per_hour}/h",
                if spot { "spot" } else { "on-demand" },
                hours
            ),
            amount: hours * price_per_hour,
            pool: pool.map(str::to_string),
        });
    }

    /// Compute total attributed to one fleet pool.
    pub fn pool_compute_total(&self, pool: &str) -> f64 {
        self.compute_items
            .iter()
            .filter(|i| i.pool.as_deref() == Some(pool))
            .map(|i| i.amount)
            .sum()
    }

    /// Book provisioned shared storage for the run's duration.
    pub fn book_storage(
        &mut self,
        share: &str,
        provisioned_gib: f64,
        duration: SimDuration,
        price_per_100gib_month: f64,
    ) {
        let months = duration.as_hours_f64() / HOURS_PER_MONTH;
        let amount = provisioned_gib / 100.0 * price_per_100gib_month * months;
        self.storage_items.push(LineItem {
            resource: format!("storage/{share}"),
            detail: format!(
                "{provisioned_gib} GiB provisioned x {:.4} months",
                months
            ),
            amount,
            pool: None,
        });
    }

    pub fn compute_total(&self) -> f64 {
        self.compute_items.iter().map(|i| i.amount).sum()
    }

    pub fn storage_total(&self) -> f64 {
        self.storage_items.iter().map(|i| i.amount).sum()
    }

    pub fn total(&self) -> f64 {
        self.compute_total() + self.storage_total()
    }

    pub fn invoice(&self) -> Invoice {
        Invoice {
            items: self
                .compute_items
                .iter()
                .chain(self.storage_items.iter())
                .cloned()
                .collect(),
        }
    }
}

/// Finalized invoice for display.
#[derive(Debug, Clone)]
pub struct Invoice {
    pub items: Vec<LineItem>,
}

impl Invoice {
    pub fn total(&self) -> f64 {
        self.items.iter().map(|i| i.amount).sum()
    }
}

impl fmt::Display for Invoice {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for item in &self.items {
            let resource = match &item.pool {
                Some(pool) => format!("{}@{pool}", item.resource),
                None => item.resource.clone(),
            };
            writeln!(
                f,
                "  {:<24} {:<52} {:>9}",
                resource,
                item.detail,
                crate::util::fmt::dollars(item.amount)
            )?;
        }
        writeln!(
            f,
            "  {:<24} {:<52} {:>9}",
            "TOTAL",
            "",
            crate::util::fmt::dollars(self.total())
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{forall, shrink_none, Config};

    #[test]
    fn paper_baseline_cost() {
        // Table I row 1 on on-demand: 3:03:26 at $0.38/h ≈ $1.1617
        let mut m = BillingMeter::new();
        m.book_instance(
            "vm-0",
            "Standard_D8s_v3",
            false,
            SimDuration::from_secs(11006),
            0.38,
        );
        assert!((m.total() - 11006.0 / 3600.0 * 0.38).abs() < 1e-9);
        assert!((m.total() - 1.1618).abs() < 1e-3);
    }

    #[test]
    fn spot_price_cut_is_80pct() {
        let dur = SimDuration::from_secs(11006);
        let mut od = BillingMeter::new();
        od.book_instance("a", "D8s", false, dur, 0.38);
        let mut spot = BillingMeter::new();
        spot.book_instance("a", "D8s", true, dur, 0.076);
        let saving = 1.0 - spot.total() / od.total();
        assert!((saving - 0.8).abs() < 1e-9);
    }

    #[test]
    fn storage_prorated_by_month() {
        let mut m = BillingMeter::new();
        // 100 GiB for a full month at $16/100GiB-month = $16
        m.book_storage(
            "nfs",
            100.0,
            SimDuration::from_hours(720),
            16.0,
        );
        assert!((m.storage_total() - 16.0).abs() < 1e-9);
        // 3 hours is tiny
        let mut m2 = BillingMeter::new();
        m2.book_storage("nfs", 100.0, SimDuration::from_hours(3), 16.0);
        assert!((m2.storage_total() - 16.0 * 3.0 / 720.0).abs() < 1e-9);
    }

    #[test]
    fn invoice_renders_and_totals() {
        let mut m = BillingMeter::new();
        m.book_instance("vm-0", "D8s", true, SimDuration::from_hours(2), 0.076);
        m.book_storage("nfs", 100.0, SimDuration::from_hours(2), 16.0);
        let inv = m.invoice();
        assert_eq!(inv.items.len(), 2);
        let s = inv.to_string();
        assert!(s.contains("TOTAL"));
        assert!((inv.total() - m.total()).abs() < 1e-12);
    }

    #[test]
    fn pool_attribution_partitions_compute_total() {
        let mut m = BillingMeter::new();
        let h = SimDuration::from_hours(1);
        m.book_instance_in_pool("east", "vm-0", "D8s", true, h, 0.076);
        m.book_instance_in_pool("west", "vm-1", "D8s", true, h, 0.090);
        m.book_instance_in_pool("east", "vm-2", "D8s", true, h, 0.076);
        m.book_storage("nfs", 100.0, h, 16.0);
        assert!((m.pool_compute_total("east") - 0.152).abs() < 1e-12);
        assert!((m.pool_compute_total("west") - 0.090).abs() < 1e-12);
        assert_eq!(m.pool_compute_total("nowhere"), 0.0);
        // pools partition the compute total exactly
        assert!(
            (m.pool_compute_total("east") + m.pool_compute_total("west")
                - m.compute_total())
            .abs()
                < 1e-12
        );
        // pool tag surfaces on the rendered invoice
        let s = m.invoice().to_string();
        assert!(s.contains("vm/vm-0@east"), "{s}");
        assert!(s.contains("vm/vm-1@west"), "{s}");
    }

    #[test]
    fn prop_billing_additivity() {
        // Booking uptime in pieces costs the same as booking it whole.
        forall(
            Config::default().cases(200),
            |rng| {
                let pieces: Vec<u64> =
                    (0..rng.range_u64(1, 6)).map(|_| rng.below(10_000)).collect();
                (pieces, 0.01 + rng.f64())
            },
            shrink_none,
            |(pieces, price)| {
                let mut split = BillingMeter::new();
                for (i, &p) in pieces.iter().enumerate() {
                    split.book_instance(
                        &format!("vm-{i}"),
                        "D8s",
                        true,
                        SimDuration::from_millis(p),
                        *price,
                    );
                }
                let mut whole = BillingMeter::new();
                whole.book_instance(
                    "vm",
                    "D8s",
                    true,
                    SimDuration::from_millis(pieces.iter().sum()),
                    *price,
                );
                if (split.total() - whole.total()).abs() > 1e-9 {
                    return Err(format!(
                        "split {} != whole {}",
                        split.total(),
                        whole.total()
                    ));
                }
                Ok(())
            },
        );
    }
}
